# Offline-safe dev targets (no network, no extra installs).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke lint pimlint typecheck

# Tier-1 verify (ROADMAP.md). Hypothesis is optional; the suite runs
# deterministic fallback examples when it is absent.
test:
	$(PYTHON) -m pytest -x -q

# Kernel micro-bench in interpret mode + eager-vs-compiled executor
# comparison + the channel-overlap roofline report + the host-side
# scheduler/orchestration bench + the multi-tenant serving bench (grid,
# isolation, churn, hostile-admission legs) + the symbolic-analyzer cost
# trajectory; writes the bench-trajectory JSONs next to the repo.
bench-smoke:
	$(PYTHON) -m benchmarks.kernel_bench kernel_bench.json
	$(PYTHON) -m benchmarks.trace_replay
	$(PYTHON) -m benchmarks.roofline_report roofline_channels.json
	$(PYTHON) -m benchmarks.scheduler_bench scheduler_bench.json
	$(PYTHON) -m benchmarks.serve_bench serve_bench.json
	$(PYTHON) -m benchmarks.sem_bench sem_bench.json

# Syntax/bytecode check everywhere; upgrade to pyflakes when present.
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
	@$(PYTHON) -c "import pyflakes" 2>/dev/null \
	  && $(PYTHON) -m pyflakes src tests benchmarks examples \
	  || echo "pyflakes not installed - compileall syntax check only"

# Static PIM-program verifier (DESIGN.md §12) + the semantic proof tier
# (§14): every golden known-bad fixture must flag its seeded hazard (incl.
# the PIM4xx symbolic findings and the pim405 equivalence proof), the
# clean fixtures must stay clean, the canonical workload generators must
# be error-free, and every canonical kernel must pass its fused-vs-unfused
# equivalence proof (the `sem:` report entries). Writes the
# machine-readable report for CI artifact upload.
pimlint:
	$(PYTHON) -m repro.core.pim.lint tests/fixtures/lint/*.trace \
	  --workloads --json pimlint_report.json

# mypy (lenient profile, mypy.ini) over the pim core; gated on
# availability like pyflakes — clean environments skip, CI installs it.
typecheck:
	@$(PYTHON) -c "import mypy" 2>/dev/null \
	  && $(PYTHON) -m mypy --config-file mypy.ini src/repro/core/pim \
	  || echo "mypy not installed - skipping typecheck"
