"""Paper §5.1.4: bank-level parallelism — throughput scales linearly at
constant energy/op (8 banks/rank × 2 ranks × 2 channels = 32 banks)."""
import jax.numpy as jnp
import numpy as np

from repro.core import pim

from .common import timed

PAPER = {1: 4.82, 8: 38.56, 32: 154.24}   # MOps/s


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    report(f"{'banks':>6} {'MOps/s':>9} {'paper':>9} {'nJ/op':>8}")
    n_shifts = 64
    for banks in (1, 8, 32):
        data = jnp.asarray(rng.integers(0, 2**32, (banks, 2048),
                                        dtype=np.uint32))
        fn = pim.bank_parallel(
            lambda r: pim.run_shift_workload(r, n_shifts), banks)
        (states, wall_ns, energy), us = timed(fn, data)
        mops = banks * n_shifts / float(wall_ns) * 1e3
        nj_per_op = float(energy) / (banks * n_shifts)
        paper = PAPER[banks]
        report(f"{banks:6d} {mops:9.2f} {paper:9.2f} {nj_per_op:8.2f}")
        rows_out.append((f"bank_parallel_{banks}", us,
                         f"mops={mops:.2f};paper={paper};"
                         f"nj_per_op={nj_per_op:.2f}"))
    return rows_out


if __name__ == "__main__":
    run()
