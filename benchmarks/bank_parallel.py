"""Paper §5.1.4: bank-level parallelism — throughput scales linearly at
constant energy/op (8 banks/rank × 2 ranks × 2 channels = 32 banks).

Device-level version: each bank of a :class:`~repro.core.pim.DeviceConfig`
runs its own shift workload over its own data through the workload scheduler
(``pim.schedule``), so wall time is command-bus serialization + the slowest
bank's execution and energy is the sum over banks. A final heterogeneous
step (per-bank shift counts 8..64) exercises the scheduler's
mixed-program path: the wall clock still collapses to bus + max.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import pim
from repro.core.pim import ir as pim_ir

from .common import timed

PAPER = {1: 4.82, 8: 38.56, 32: 154.24}   # MOps/s

N_SHIFTS = 64


def _preloaded_device(dcfg: "pim.DeviceConfig", data) -> "pim.DeviceState":
    """Fresh device with ``data[b]`` preloaded into bank b's row 0."""
    dev = pim.make_device(dcfg)
    banks = dev.banks
    banks = pim.SubarrayState(
        bits=banks.bits.at[:, 0].set(jnp.asarray(data)),
        mig_top=banks.mig_top, mig_bot=banks.mig_bot, dcc=banks.dcc,
        meter=banks.meter)
    return dev.with_banks(banks)


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    report(f"{'banks':>6} {'MOps/s':>9} {'paper':>9} {'nJ/op':>8} "
           f"{'bus_ns':>8}")
    prog = pim.shift_workload_program(N_SHIFTS)
    for banks in (1, 8, 32):
        dcfg = pim.paper_device(banks)
        data = rng.integers(0, 2**32, (banks, dcfg.words), dtype=np.uint32)

        def step(d=data, c=dcfg):
            return pim.schedule(_preloaded_device(c, d), [prog] * c.n_banks,
                                refresh=True)

        res, us = timed(step)
        mops = banks * N_SHIFTS / float(res.wall_ns) * 1e3
        nj_per_op = float(res.energy_nj) / (banks * N_SHIFTS)
        paper = PAPER[banks]
        report(f"{banks:6d} {mops:9.2f} {paper:9.2f} {nj_per_op:8.2f} "
               f"{float(res.bus_ns):8.1f}")
        rows_out.append((f"bank_parallel_{banks}", us,
                         f"mops={mops:.2f};paper={paper};"
                         f"nj_per_op={nj_per_op:.2f}"))

    # Heterogeneous scheduling: 8 banks, shift counts 8..64. The scheduler
    # compiles one runner per distinct stream; wall = bus + max over banks.
    banks = 8
    dcfg = pim.paper_device(banks)
    shifts = [8 * (b + 1) for b in range(banks)]
    progs = [pim.shift_workload_program(n) for n in shifts]
    data = rng.integers(0, 2**32, (banks, dcfg.words), dtype=np.uint32)
    res, us = timed(
        lambda: pim.schedule(_preloaded_device(dcfg, data), progs))
    expect = float(res.bus_ns) + max(
        n * pim.DEFAULT_TIMING.t_shift for n in shifts)
    report(f"hetero {banks} banks (shifts {shifts[0]}..{shifts[-1]}): "
           f"wall={float(res.wall_ns):.1f} ns "
           f"(bus+max={expect:.1f}), energy={float(res.energy_nj):.0f} nJ")
    rows_out.append(("bank_parallel_hetero", us,
                     f"wall_ns={float(res.wall_ns):.1f};"
                     f"bus_ns={float(res.bus_ns):.1f}"))

    # Channel overlap: the same host-load + shift workload over 16 banks
    # arranged as 1 channel x 2 ranks vs 2 channels x 1 rank. Off-chip
    # HOSTW/HOSTR bursts serialize per channel, so the 2-channel layout
    # overlaps two burst streams; async host scheduling additionally hides
    # the second step's transfers under the first step's compute.
    n16 = 16
    data16 = rng.integers(0, 2**32, (2 * n16, dcfg.words), dtype=np.uint32)

    def build(b, rows):
        for r in rows:
            b.shift_k(r, r, 8)

    def chan_steps(cfg, async_host):
        dev = pim.make_device(cfg)
        walls = []
        for step in range(2):
            progs = pim.shard_rows(data16[step * n16:(step + 1) * n16],
                                   cfg.n_banks, num_rows=cfg.num_rows,
                                   build=build)
            res = pim.schedule(dev, progs, async_host=async_host)
            dev = res.state
            walls.append(float(res.wall_ns))
        return sum(walls), res

    cfg_1ch = pim.DeviceConfig(channels=1, ranks=2, banks_per_rank=8,
                               num_rows=dcfg.num_rows, words=dcfg.words)
    cfg_2ch = pim.DeviceConfig(channels=2, ranks=1, banks_per_rank=8,
                               num_rows=dcfg.num_rows, words=dcfg.words)
    (w1, r_1ch), us = timed(lambda: chan_steps(cfg_1ch, False),
                            warmup=0, iters=1)
    w2, r_2ch = chan_steps(cfg_2ch, False)
    w2a, r_2a = chan_steps(cfg_2ch, True)
    assert w2 < w1, "2-channel wall must beat 1-channel serialization"
    assert w2a <= w2, "async host must not be slower than sync"
    report(f"channel overlap, {n16} banks x 2 steps: 1ch={w1:.1f} ns "
           f"(switch {r_1ch.rank_switch_ns:.1f}), 2ch={w2:.1f} ns, "
           f"2ch+async={w2a:.1f} ns "
           f"(hidden {r_2a.host_overlap_ns:.1f} ns/step)")
    report(f"  per-channel busy 2ch: "
           f"{tuple(round(x, 1) for x in r_2ch.channel_bus_ns)}")
    rows_out.append(("bank_parallel_channels", us,
                     f"w_1ch={w1:.1f};w_2ch={w2:.1f};w_2ch_async={w2a:.1f}"))

    # Cross-lane reduction via in-DRAM COPY (LISA): XOR-fold the 8 banks'
    # shifted rows into bank 0 with zero host traffic — gather row 1 from
    # banks 1..7 into bank-0 scratch rows, then one Ambit XOR chain. The
    # only off-chip bytes are the final result read-back.
    dcfg = pim.paper_device(banks)
    data = rng.integers(0, 2**32, (banks, dcfg.words), dtype=np.uint32)
    res = pim.schedule(_preloaded_device(dcfg, data), [prog] * banks)

    def reduce_step(state=res.state):
        moves = [((b, 0, 1), (0, 0, 1 + b)) for b in range(1, banks)]
        r1 = pim.schedule(state, pim.gather_rows(dcfg, moves))
        fold = pim.xor_reduce_program(dcfg.num_rows, dcfg.words,
                                      list(range(1, banks + 1)), banks + 1)
        rb = pim.ProgramBuilder(dcfg.num_rows, dcfg.words)
        rb.read_row(banks + 1)
        r2 = pim.schedule(r1.state, [pim_ir.concat([fold, rb.build()])]
                          + [None] * (banks - 1))
        return r1, r2

    (r1, r2), us = timed(reduce_step)
    got = np.asarray(r2.reads[0][0])
    oracle = np.bitwise_xor.reduce(
        np.stack([np.asarray(res.state.bank(b).bits[1])
                  for b in range(banks)]))
    assert np.array_equal(got, oracle), "in-DRAM reduction != host XOR"
    assert r1.host_bytes == 0, "gather phase must move zero host bytes"
    assert r2.host_bytes == dcfg.words * 4, "only the result read goes off-chip"
    report(f"cross-lane reduce {banks} banks: wall="
           f"{float(r1.wall_ns) + float(r2.wall_ns):.1f} ns "
           f"(copy {r1.copy_ns:.1f} ns, queued {r1.copy_queue_ns:.1f} ns), "
           f"host bytes gather/fold = "
           f"{r1.host_bytes}/{r2.host_bytes} (result read only)")
    rows_out.append(("bank_parallel_reduce", us,
                     f"wall_ns={float(r1.wall_ns) + float(r2.wall_ns):.1f};"
                     f"copy_ns={r1.copy_ns:.1f};host_B={r1.host_bytes}"))
    return rows_out


if __name__ == "__main__":
    run()
