"""Benchmark plumbing: wall-clock helper + row collection."""
import time

import jax


def timed(fn, *args, warmup=1, iters=3, **kw):
    """Returns (result, us_per_call)."""
    result = None
    for _ in range(warmup):
        result = fn(*args, **kw)
    jax.block_until_ready(result) if result is not None else None
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kw)
    if result is not None:
        jax.block_until_ready(result)
    return result, (time.perf_counter() - t0) / iters * 1e6


def pct_err(model, paper):
    return 100.0 * (model / paper - 1.0)
