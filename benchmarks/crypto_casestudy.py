"""Paper §8.0.1/§8.0.2 future-work case study, implemented: in-DRAM adders,
shift-and-add multiply, AES xtime and Reed-Solomon encode — DDR3-modeled
time/energy per operation on full 8KB rows — then RS(12,8) at device level:
the codeword buffer lane-sharded across 1/8/32 banks through the workload
scheduler, bit-exact against the single-subarray reference, with the
paper's §5.1.4 linear throughput scaling."""
import numpy as np

from repro.core.bitplane import PimVM, arith, gf, rs

from .common import timed


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    # Full-row (8KB = 8192 byte lanes) operations, DDR3 cost model.
    report(f"{'operation (8KB row)':28s} {'DDR3 time':>14} {'energy':>12} "
           f"{'nJ/KB':>8}")
    specs = [
        ("ripple-carry add (w=8)", lambda vm, a, b: arith.add_ripple(vm, a, b)),
        ("kogge-stone add (w=8)", lambda vm, a, b: arith.add_kogge_stone(vm, a, b)),
        ("shift-add multiply (w=8)", lambda vm, a, b: arith.mul_shift_add(vm, a, b)),
        ("AES xtime", lambda vm, a, b: gf.xtime(vm, a)),
        ("GF(2^8) multiply", lambda vm, a, b: gf.gf_mul(vm, a, b)),
    ]
    for name, op in specs:
        vm = PimVM(width=8, num_rows=64, words=2048)   # full 8KB row
        a = vm.load(rng.integers(0, 256, vm.lanes))
        b = vm.load(rng.integers(0, 256, vm.lanes))
        t0, e0 = vm.time_ns, vm.energy_nj
        _, us = timed(op, vm, a, b, warmup=0, iters=1)
        dt, de = vm.time_ns - t0, vm.energy_nj - e0
        report(f"{name:28s} {dt/1e3:>11.1f} us {de:>10.1f} nJ "
               f"{de/8.0:>8.2f}")
        rows_out.append((f"crypto_{name.split()[0].lower()}", us,
                         f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};"
                         f"nJ_per_KB={de/8:.2f}"))
    # Reed-Solomon: k=8 data rows + 4 parity over 64-lane rows.
    vm = PimVM(width=8, num_rows=120, words=16)
    msg = rng.integers(0, 256, size=(8, vm.lanes))
    regs = [vm.load(msg[i]) for i in range(8)]
    t0, e0 = vm.time_ns, vm.energy_nj
    (par, us) = timed(rs.rs_encode, vm, regs, 4, warmup=0, iters=1)
    got = np.stack([vm.read(r) for r in par])
    ref = rs.ref_rs_encode(msg, 4)
    assert np.array_equal(got, ref)
    dt, de = vm.time_ns - t0, vm.energy_nj - e0
    nbytes = 8 * vm.lanes
    report(f"{'RS(12,8) encode/64 lanes':28s} {dt/1e3:>11.1f} us "
           f"{de:>10.1f} nJ {de/(nbytes/1024):>8.2f}")
    rows_out.append(("crypto_rs_encode", us,
                     f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};verified=1"))

    # Device level (§5.1.4): RS(12,8) parity, one codeword per byte lane,
    # 1KB of lanes per bank — the buffer grows with the bank count, wall
    # time stays flat, so encoded MB/s scales linearly at constant nJ/byte.
    k, npar = 8, 4
    bank_words = 256                       # 1KB row slice / 1024 lanes per bank
    report(f"\n{'RS(12,8) device-level':28s} {'buffer':>9} {'wall':>11} "
           f"{'MB/s':>8} {'nJ/byte':>8}")
    for banks in (1, 8, 32):
        vm = PimVM(width=8, num_rows=120, words=bank_words * banks,
                   n_banks=banks)
        msg = rng.integers(0, 256, size=(k, vm.lanes))
        regs = [vm.load(msg[i]) for i in range(k)]
        t0, e0 = vm.time_ns, vm.energy_nj

        def encode_and_read(vm=vm, regs=regs):
            par = rs.rs_encode(vm, regs, npar)
            return np.stack([vm.read(r) for r in par])

        got, us = timed(encode_and_read, warmup=0, iters=1)
        dt, de = vm.time_ns - t0, vm.energy_nj - e0
        nbytes = k * vm.lanes
        mbs = nbytes / dt * 1e3            # ns → MB/s
        report(f"{banks:4d} banks x {bank_words * 4}B rows    "
               f"{nbytes/1024:>7.0f}KB {dt/1e3:>8.1f} us {mbs:>8.1f} "
               f"{de/nbytes:>8.2f}")
        rows_out.append((f"crypto_rs_device_{banks}", us,
                         f"ddr3_us={dt/1e3:.1f};MBps={mbs:.1f};"
                         f"nJ_per_B={de/nbytes:.2f}"))
    # exact check: re-encode the 32-bank buffer on ONE wide subarray
    vm_ref = PimVM(width=8, num_rows=120, words=bank_words * 32)
    regs = [vm_ref.load(msg[i]) for i in range(k)]
    ref_par = np.stack([vm_ref.read(r)
                        for r in rs.rs_encode(vm_ref, regs, npar)])
    assert np.array_equal(got, ref_par), "sharded != single-subarray"
    report("32-bank parity bit-exact vs single-subarray reference: OK")
    return rows_out


if __name__ == "__main__":
    run()
