"""Paper §8.0.1/§8.0.2 future-work case study, implemented: in-DRAM adders,
shift-and-add multiply, AES xtime and Reed-Solomon encode — DDR3-modeled
time/energy per operation on full 8KB rows."""
import numpy as np

from repro.core.bitplane import PimVM, arith, gf, rs

from .common import timed


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    # Full-row (8KB = 8192 byte lanes) operations, DDR3 cost model.
    report(f"{'operation (8KB row)':28s} {'DDR3 time':>14} {'energy':>12} "
           f"{'nJ/KB':>8}")
    specs = [
        ("ripple-carry add (w=8)", lambda vm, a, b: arith.add_ripple(vm, a, b)),
        ("kogge-stone add (w=8)", lambda vm, a, b: arith.add_kogge_stone(vm, a, b)),
        ("shift-add multiply (w=8)", lambda vm, a, b: arith.mul_shift_add(vm, a, b)),
        ("AES xtime", lambda vm, a, b: gf.xtime(vm, a)),
        ("GF(2^8) multiply", lambda vm, a, b: gf.gf_mul(vm, a, b)),
    ]
    for name, op in specs:
        vm = PimVM(width=8, num_rows=64, words=2048)   # full 8KB row
        a = vm.load(rng.integers(0, 256, vm.lanes))
        b = vm.load(rng.integers(0, 256, vm.lanes))
        t0, e0 = vm.time_ns, vm.energy_nj
        _, us = timed(op, vm, a, b, warmup=0, iters=1)
        dt, de = vm.time_ns - t0, vm.energy_nj - e0
        report(f"{name:28s} {dt/1e3:>11.1f} us {de:>10.1f} nJ "
               f"{de/8.0:>8.2f}")
        rows_out.append((f"crypto_{name.split()[0].lower()}", us,
                         f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};"
                         f"nJ_per_KB={de/8:.2f}"))
    # Reed-Solomon: k=8 data rows + 4 parity over 64-lane rows.
    vm = PimVM(width=8, num_rows=120, words=16)
    msg = rng.integers(0, 256, size=(8, vm.lanes))
    regs = [vm.load(msg[i]) for i in range(8)]
    t0, e0 = vm.time_ns, vm.energy_nj
    (par, us) = timed(rs.rs_encode, vm, regs, 4, warmup=0, iters=1)
    got = np.stack([vm.read(r) for r in par])
    ref = rs.ref_rs_encode(msg, 4)
    assert np.array_equal(got, ref)
    dt, de = vm.time_ns - t0, vm.energy_nj - e0
    nbytes = 8 * vm.lanes
    report(f"{'RS(12,8) encode/64 lanes':28s} {dt/1e3:>11.1f} us "
           f"{de:>10.1f} nJ {de/(nbytes/1024):>8.2f}")
    rows_out.append(("crypto_rs_encode", us,
                     f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};verified=1"))
    return rows_out


if __name__ == "__main__":
    run()
