"""Paper §8.0.1/§8.0.2 future-work case study, implemented: in-DRAM adders,
shift-and-add multiply, AES xtime and Reed-Solomon encode — DDR3-modeled
time/energy per operation on full 8KB rows — then RS(12,8) at device level:
the codeword buffer lane-sharded across 1/8/32 banks through the workload
scheduler, bit-exact against the single-subarray reference, with the
paper's §5.1.4 linear throughput scaling. Finally, the LISA-COPY workload:
RS(12,8) syndrome rows from all 32 banks XOR-reduced into bank 0 entirely
in-DRAM (zero HOSTR/HOSTW bytes in the reduction phase), bit-exact against
the single-subarray reference."""
import json

import numpy as np

from repro.core import pim
from repro.core.bitplane import PimVM, arith, gf, layout, rs

from .common import timed


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    # Full-row (8KB = 8192 byte lanes) operations, DDR3 cost model.
    report(f"{'operation (8KB row)':28s} {'DDR3 time':>14} {'energy':>12} "
           f"{'nJ/KB':>8}")
    specs = [
        ("ripple-carry add (w=8)", lambda vm, a, b: arith.add_ripple(vm, a, b)),
        ("kogge-stone add (w=8)", lambda vm, a, b: arith.add_kogge_stone(vm, a, b)),
        ("shift-add multiply (w=8)", lambda vm, a, b: arith.mul_shift_add(vm, a, b)),
        ("AES xtime", lambda vm, a, b: gf.xtime(vm, a)),
        ("GF(2^8) multiply", lambda vm, a, b: gf.gf_mul(vm, a, b)),
    ]
    for name, op in specs:
        vm = PimVM(width=8, num_rows=64, words=2048)   # full 8KB row
        a = vm.load(rng.integers(0, 256, vm.lanes))
        b = vm.load(rng.integers(0, 256, vm.lanes))
        t0, e0 = vm.time_ns, vm.energy_nj
        _, us = timed(op, vm, a, b, warmup=0, iters=1)
        dt, de = vm.time_ns - t0, vm.energy_nj - e0
        report(f"{name:28s} {dt/1e3:>11.1f} us {de:>10.1f} nJ "
               f"{de/8.0:>8.2f}")
        rows_out.append((f"crypto_{name.split()[0].lower()}", us,
                         f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};"
                         f"nJ_per_KB={de/8:.2f}"))
    # Reed-Solomon: k=8 data rows + 4 parity over 64-lane rows.
    vm = PimVM(width=8, num_rows=120, words=16)
    msg = rng.integers(0, 256, size=(8, vm.lanes))
    regs = [vm.load(msg[i]) for i in range(8)]
    t0, e0 = vm.time_ns, vm.energy_nj
    (par, us) = timed(rs.rs_encode, vm, regs, 4, warmup=0, iters=1)
    got = np.stack([vm.read(r) for r in par])
    ref = rs.ref_rs_encode(msg, 4)
    assert np.array_equal(got, ref)
    dt, de = vm.time_ns - t0, vm.energy_nj - e0
    nbytes = 8 * vm.lanes
    report(f"{'RS(12,8) encode/64 lanes':28s} {dt/1e3:>11.1f} us "
           f"{de:>10.1f} nJ {de/(nbytes/1024):>8.2f}")
    rows_out.append(("crypto_rs_encode", us,
                     f"ddr3_us={dt/1e3:.1f};nJ={de:.1f};verified=1"))

    # Device level (§5.1.4): RS(12,8) parity, one codeword per byte lane,
    # 1KB of lanes per bank — the buffer grows with the bank count, wall
    # time stays flat, so encoded MB/s scales linearly at constant nJ/byte.
    k, npar = 8, 4
    bank_words = 256                       # 1KB row slice / 1024 lanes per bank
    report(f"\n{'RS(12,8) device-level':28s} {'buffer':>9} {'wall':>11} "
           f"{'MB/s':>8} {'nJ/byte':>8}")
    for banks in (1, 8, 32):
        vm = PimVM(width=8, num_rows=120, words=bank_words * banks,
                   n_banks=banks)
        msg = rng.integers(0, 256, size=(k, vm.lanes))
        regs = [vm.load(msg[i]) for i in range(k)]
        t0, e0 = vm.time_ns, vm.energy_nj

        def encode_and_read(vm=vm, regs=regs):
            par = rs.rs_encode(vm, regs, npar)
            return np.stack([vm.read(r) for r in par])

        got, us = timed(encode_and_read, warmup=0, iters=1)
        dt, de = vm.time_ns - t0, vm.energy_nj - e0
        nbytes = k * vm.lanes
        mbs = nbytes / dt * 1e3            # ns → MB/s
        report(f"{banks:4d} banks x {bank_words * 4}B rows    "
               f"{nbytes/1024:>7.0f}KB {dt/1e3:>8.1f} us {mbs:>8.1f} "
               f"{de/nbytes:>8.2f}")
        rows_out.append((f"crypto_rs_device_{banks}", us,
                         f"ddr3_us={dt/1e3:.1f};MBps={mbs:.1f};"
                         f"nJ_per_B={de/nbytes:.2f}"))
    # exact check: re-encode the 32-bank buffer on ONE wide subarray
    vm_ref = PimVM(width=8, num_rows=120, words=bank_words * 32)
    regs = [vm_ref.load(msg[i]) for i in range(k)]
    ref_par = np.stack([vm_ref.read(r)
                        for r in rs.rs_encode(vm_ref, regs, npar)])
    assert np.array_equal(got, ref_par), "sharded != single-subarray"
    report("32-bank parity bit-exact vs single-subarray reference: OK")

    rows_out.extend(_async_pipeline(report))
    rows_out.extend(_syndrome_reduction(report))
    return rows_out


def _async_pipeline(report, banks=8, k=8, npar=4, chunks=4, words=1024):
    """Multi-step RS(12,8) pipeline: each step loads the next codeword
    chunk (HOSTW) and encodes it. With ``async_host=True`` the device
    scheduler overlaps a step's host transfers with the previous step's
    compute (Shared-PIM double buffering), so the pipeline pays
    max(transfer, compute) per step instead of the sum — with bit-identical
    parity. RS(12,8) is compute-bound (bit-serial GF multiplies dwarf the
    burst time), so async hides essentially ALL steady-state host traffic;
    the transfer-bound end of the same model is shown by
    ``bank_parallel``/``roofline_report``'s channel-overlap sections."""
    rng = np.random.default_rng(7)
    msgs = [rng.integers(0, 256, size=(k, words * 32 // 8))
            for _ in range(chunks)]

    def encode_all(async_host):
        vm = PimVM(width=8, num_rows=120, words=words, n_banks=banks,
                   async_host=async_host)
        pars = []
        for msg in msgs:
            # one flush per pipeline step: loads + encode + parity reads
            regs = [vm.load(msg[i]) for i in range(k)]
            par = rs.rs_encode(vm, regs, npar)
            pars.append(np.stack(vm.read_many(par)))
            vm.free(*regs, *par)
        return vm, np.stack(pars)

    (vm_sync, got_sync), us = timed(lambda: encode_all(False),
                                    warmup=0, iters=1)
    vm_async, got_async = encode_all(True)
    assert np.array_equal(got_sync, got_async), "async changed the bits"
    for c, msg in enumerate(msgs):
        assert np.array_equal(got_sync[c], rs.ref_rs_encode(msg, npar)), c
    w_s, w_a = vm_sync.time_ns, vm_async.time_ns
    assert w_a < w_s, "async pipeline must beat the sync wall"
    assert abs(vm_sync.energy_nj - vm_async.energy_nj) \
        <= 1e-6 * vm_sync.energy_nj, "async changed the energy"
    hidden = vm_async.host_overlap_ns
    assert abs((w_s - w_a) - hidden) <= 1e-6 * w_s, \
        "wall reduction must equal the hidden host-transfer time"
    report(f"\nRS(12,8) {chunks}-step pipeline over {banks} banks "
           f"({chunks * k * words * 4 // 1024}KB data): "
           f"sync {w_s / 1e3:.1f} us vs async {w_a / 1e3:.1f} us "
           f"({hidden / 1e3:.1f} us of host transfer hidden under compute "
           f"— compute-bound, so async hides all steady-state bursts)")
    return [("crypto_rs_async_pipeline", us,
             f"sync_us={w_s / 1e3:.1f};async_us={w_a / 1e3:.1f};"
             f"speedup={w_s / w_a:.2f};verified=1")]


def _syndrome_reduction(report, banks=32, k=8, npar=4, words=64,
                        vm_rows=120):
    """RS(12,8) syndrome reduction across ``banks`` banks via LISA COPY.

    Every bank holds 12 codeword rows (8 data + 4 parity) for its own lane
    chunk and evaluates its 4 syndrome rows in-DRAM; a log2(banks)-round
    binary tree then XOR-reduces all syndrome rows into bank 0 — row
    movement exclusively via inter-bank ``COPY``, so the reduction phase
    moves ZERO host bytes. The reduced rows are a device-wide integrity
    checksum (zero iff no bank saw corruption); a few banks get flipped
    bytes so the checksum is non-trivial. Bit-exact against running every
    bank's recorded program on a single subarray and XORing on the host.
    """
    rng = np.random.default_rng(12)
    lanes = words * 32 // 8
    rows_out = []

    # Per-bank recorded programs: load codeword, evaluate syndromes.
    progs, oracle_syn, syn_rows, recv_rows = [], [], None, None
    for b in range(banks):
        vm = PimVM(width=8, num_rows=vm_rows, words=words)
        msg = rng.integers(0, 256, size=(k, lanes))
        par = rs.ref_rs_encode(msg, npar)
        cw = np.concatenate([msg.astype(np.uint64), par[::-1]],
                            axis=0)                     # highest degree first
        if b % 5 == 0:                                   # inject corruption
            cw[rng.integers(0, k + npar), rng.integers(0, lanes)] ^= 0x5A
        regs = [vm.load(cw[i]) for i in range(k + npar)]
        syn = rs.rs_syndromes(vm, regs, npar)
        recv = [vm.alloc() for _ in range(npar)]
        assert syn_rows in (None, syn) and recv_rows in (None, recv), \
            "allocation must be identical across banks (one stream group)"
        syn_rows, recv_rows = syn, recv
        progs.append(vm.take_recorded())
        oracle_syn.append(rs.ref_rs_syndromes(cw, npar))

    dcfg = pim.paper_device(banks, num_rows=vm_rows, words=words)
    dev = pim.make_device(dcfg)

    def run(dev=dev):
        res = pim.schedule(dev, progs)       # compute phase (loads included)
        state, load_bytes = res.state, res.host_bytes
        red_wall = red_energy = red_copy = red_queue = 0.0
        red_bytes = 0
        stride = 1
        merge = pim.PimProgram(ops=sum(
            (pim.xor_reduce_program(vm_rows, words, [s, r], s).ops
             for s, r in zip(syn_rows, recv_rows)), ()),
            num_rows=vm_rows, words=words)
        while stride < banks:
            moves = [((b + stride, 0, syn_rows[j]), (b, 0, recv_rows[j]))
                     for b in range(0, banks, 2 * stride)
                     for j in range(npar)]
            r1 = pim.schedule(state, pim.gather_rows(dcfg, moves))
            receivers = set(range(0, banks, 2 * stride))
            r2 = pim.schedule(r1.state, [
                merge if b in receivers else None for b in range(banks)])
            for r in (r1, r2):
                red_wall += float(r.wall_ns)
                red_energy += float(r.energy_nj)
                red_copy += float(r.copy_ns)
                red_queue += float(r.copy_queue_ns)
                red_bytes += r.host_bytes
            state = r2.state
            stride *= 2
        return (state, load_bytes, red_wall, red_energy, red_copy,
                red_queue, red_bytes)

    (state, load_bytes, red_wall, red_energy, red_copy, red_queue,
     red_bytes), us = timed(run, warmup=0, iters=1)
    assert red_bytes == 0, "reduction phase must move zero host bytes"
    assert red_queue > 0.0, \
        "a 32-bank gather must show internal-bus queueing delay"

    got_packed = np.asarray(state.slot(0).bits)[syn_rows]
    got = np.stack([layout.unpack_elements(got_packed[j], 8, lanes)
                    for j in range(npar)])
    # oracle: lane-wise XOR of every bank's reference syndromes
    oracle = np.bitwise_xor.reduce(np.stack(oracle_syn), axis=0)
    assert got.any(), "corrupted banks must yield a non-zero checksum"
    assert np.array_equal(got, oracle), "device checksum != numpy oracle"

    # single-subarray reference: same recorded programs, one subarray each,
    # XOR of the syndrome rows on the host — must match COPY path bit-exactly.
    # All banks share one stream, so ONE compiled runner takes each bank's
    # HOSTW payloads as an argument (exec payload_arg mode).
    runner = pim.make_runner(pim.compile_program(progs[0]), payload_arg=True)
    ref = np.zeros_like(got_packed)
    for p in progs:
        st = pim.reserve_control_rows(pim.make_subarray(vm_rows, words))
        out = runner(st, np.stack(p.payloads).astype(np.uint32))
        ref ^= np.asarray(out.state.bits)[syn_rows]
    assert np.array_equal(got_packed, ref), "COPY path != single-subarray"

    host_before = banks * npar * words * 4   # host path: read every syn row
    report(f"\nRS(12,8) syndrome reduction across {banks} banks "
           f"({banks * (k + npar) * words * 4 // 1024}KB codewords):")
    report(f"  reduction wall {red_wall / 1e3:8.1f} us "
           f"(copy {red_copy / 1e3:.1f} us, queued {red_queue / 1e3:.1f} "
           f"us), energy {red_energy:.0f} nJ")
    report(f"  host bytes in reduction: {red_bytes} (host-reduce path: "
           f"{host_before}), load phase: {load_bytes}")
    report("  checksum bit-exact vs single-subarray reference + numpy: OK")
    report("  " + json.dumps({
        "benchmark": "rs_syndrome_reduce", "banks": banks,
        "host_bytes_reduction_before": host_before,
        "host_bytes_reduction_after": red_bytes,
        "host_bytes_load": load_bytes,
        "reduction_wall_ns": round(red_wall, 1),
        "reduction_copy_ns": round(red_copy, 1),
        "reduction_copy_queue_ns": round(red_queue, 1),
        "reduction_energy_nj": round(red_energy, 1),
    }, sort_keys=True))
    rows_out.append(("crypto_rs_syndrome_reduce", us,
                     f"red_us={red_wall / 1e3:.1f};nJ={red_energy:.0f};"
                     f"host_B_after=0;host_B_before={host_before};"
                     f"banks={banks}"))
    return rows_out


if __name__ == "__main__":
    run()
