"""Pallas kernel micro-bench: wall time (interpret mode on CPU — correctness
executor, NOT TPU perf) + fused-vs-composed HBM-traffic accounting, plus
eager-ISA vs compiled-executor wall time and cost-pass speedup for the
Table 2/3 shift workload (JSON emitted for the bench trajectory)."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pim
from repro.core.pim import isa
from repro.kernels.pim_matmul import pim_matmul, quantize
from repro.kernels.rowops import bitwise, ripple_add, shift_cols

from .common import timed

TABLE23_SHIFTS = 1000     # the acceptance workload: N chained 1-bit shifts


def _eager_shift_workload(row, n_shifts, num_rows=64, words=2048):
    """The pre-IR path: one Python-level state transition per command."""
    s = pim.reserve_control_rows(pim.make_subarray(num_rows, words))
    s = pim.SubarrayState(bits=s.bits.at[0].set(row), mig_top=s.mig_top,
                          mig_bot=s.mig_bot, dcc=s.dcc, meter=s.meter)
    s = isa.issue(s)
    s = isa.shift(s, 0, 1, +1)
    for _ in range(n_shifts - 1):
        s = isa.shift(s, 1, 1, +1)
    return pim.SubarrayState(bits=s.bits, mig_top=s.mig_top,
                             mig_bot=s.mig_bot, dcc=s.dcc,
                             meter=pim.apply_refresh(s.meter))


def bench_compiled_vs_eager(n_shifts=TABLE23_SHIFTS, words=2048,
                            report=print):
    """Eager interpreter loop vs recorded-program executor on the Table 2/3
    workload; returns (csv_rows, json_dict)."""
    rng = np.random.default_rng(0)
    num_rows = 64
    row = jnp.asarray(rng.integers(0, 2**32, (words,), dtype=np.uint32))

    t0 = time.perf_counter()
    s_eager = _eager_shift_workload(row, n_shifts, num_rows, words)
    jax.block_until_ready(s_eager.bits)
    eager_us = (time.perf_counter() - t0) * 1e6

    prog = pim.shift_workload_program(n_shifts, num_rows, words)
    compiled = pim.compile_program(prog)
    _, compiled_us = timed(
        lambda: pim.execute(compiled, refresh=True).state.bits)

    # cost pass alone (meter without stepping the state pytree per command)
    t0 = time.perf_counter()
    meter = pim.cost_pass(prog)
    jax.block_until_ready(meter.time_ns)
    cost_first_us = (time.perf_counter() - t0) * 1e6
    _, cost_us = timed(lambda: pim.cost_pass(prog).time_ns)
    summary = pim.cost_summary(prog, refresh=True)

    exact = (float(s_eager.meter.time_ns)
             == float(pim.run_shift_workload(row, n_shifts, num_rows,
                                             words).meter.time_ns))
    result = {
        "workload": f"table23_shift_n{n_shifts}",
        "n_shifts": n_shifts,
        "eager_us": eager_us,
        "compiled_us": compiled_us,
        "speedup": eager_us / compiled_us,
        "cost_pass_us": cost_us,
        "cost_pass_first_us": cost_first_us,
        "cost_pass_speedup": eager_us / cost_us,
        "model_time_ns": summary["time_ns"],
        "model_energy_nj": summary["energy_nj"],
        "meter_bit_exact": exact,
    }
    report(f"eager ISA loop      : {eager_us:12.1f} us  (n={n_shifts})")
    report(f"compiled executor   : {compiled_us:12.1f} us  "
           f"({result['speedup']:.1f}x)")
    report(f"cost pass only      : {cost_us:12.1f} us  "
           f"({result['cost_pass_speedup']:.1f}x, bit-exact={exact})")
    rows = [
        (f"pim_eager_shift_n{n_shifts}", eager_us, "eager"),
        (f"pim_compiled_shift_n{n_shifts}", compiled_us,
         f"speedup={result['speedup']:.1f}x"),
        (f"pim_cost_pass_n{n_shifts}", cost_us,
         f"speedup={result['cost_pass_speedup']:.1f}x"),
    ]
    return rows, result


def run(report=print, json_path=None):
    rng = np.random.default_rng(0)
    rows_out = []
    a = jnp.asarray(rng.integers(0, 2**32, (64, 2048), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (64, 2048), dtype=np.uint32))

    _, us = timed(lambda: bitwise(a, b, op="and"))
    rows_out.append(("kernel_rowops_and_64x2048", us, "interpret"))
    _, us = timed(lambda: shift_cols(a, 1))
    rows_out.append(("kernel_rowops_shift1", us, "interpret"))
    _, us = timed(lambda: ripple_add(a, b, width=8))
    rows_out.append(("kernel_rowops_ripple_add_w8", us, "interpret"))

    # Fused adder vs ISA-by-ISA composition: HBM round-trips saved.
    w = 8
    n_ops_composed = 2 + (w - 1) * 3          # xor+and, then (shift,and,xor)*7
    traffic_composed = n_ops_composed * 3      # r+r+w rows per op
    traffic_fused = 3
    report(f"fused ripple_add: {traffic_fused} row-traffics vs "
           f"{traffic_composed} composed ({traffic_composed/3:.0f}x less HBM)")
    rows_out.append(("kernel_fused_adder_traffic_ratio", 0.0,
                     f"{traffic_composed/traffic_fused:.1f}x"))

    x = jnp.asarray(rng.normal(size=(128, 512)), jnp.bfloat16)
    wf = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    wi, sc = quantize(wf, 4)
    for mode in ("shift_add", "dequant"):
        _, us = timed(lambda m=mode: pim_matmul(x, wi, sc, mode=m, bits=4))
        rows_out.append((f"kernel_pim_matmul_{mode}_128x512x256", us,
                         "interpret"))
    # MXU flop ratio between the modes (the dry-run measures it for real).
    report("pim_matmul shift_add does 4 plane-dots per tile vs 1 for "
           "dequant → 4x MXU flops (w4), traded for no dequant step")

    cmp_rows, cmp_json = bench_compiled_vs_eager(report=report)
    rows_out.extend(cmp_rows)
    blob = json.dumps(cmp_json, indent=2, sort_keys=True)
    if json_path:
        with open(json_path, "w") as f:
            f.write(blob + "\n")
        report(f"wrote {json_path}")
    else:
        report(blob)

    for name, us, derived in rows_out:
        report(f"{name:42s} {us:12.1f} us  {derived}")
    return rows_out


if __name__ == "__main__":
    import sys
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
