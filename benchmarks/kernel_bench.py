"""Pallas kernel micro-bench: wall time (interpret mode on CPU — correctness
executor, NOT TPU perf) + fused-vs-composed HBM-traffic accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pim_matmul import pim_matmul, quantize
from repro.kernels.rowops import bitwise, ripple_add, shift_cols

from .common import timed


def run(report=print):
    rng = np.random.default_rng(0)
    rows_out = []
    a = jnp.asarray(rng.integers(0, 2**32, (64, 2048), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (64, 2048), dtype=np.uint32))

    _, us = timed(lambda: bitwise(a, b, op="and"))
    rows_out.append(("kernel_rowops_and_64x2048", us, "interpret"))
    _, us = timed(lambda: shift_cols(a, 1))
    rows_out.append(("kernel_rowops_shift1", us, "interpret"))
    _, us = timed(lambda: ripple_add(a, b, width=8))
    rows_out.append(("kernel_rowops_ripple_add_w8", us, "interpret"))

    # Fused adder vs ISA-by-ISA composition: HBM round-trips saved.
    w = 8
    n_ops_composed = 2 + (w - 1) * 3          # xor+and, then (shift,and,xor)*7
    traffic_composed = n_ops_composed * 3      # r+r+w rows per op
    traffic_fused = 3
    report(f"fused ripple_add: {traffic_fused} row-traffics vs "
           f"{traffic_composed} composed ({traffic_composed/3:.0f}x less HBM)")
    rows_out.append(("kernel_fused_adder_traffic_ratio", 0.0,
                     f"{traffic_composed/traffic_fused:.1f}x"))

    x = jnp.asarray(rng.normal(size=(128, 512)), jnp.bfloat16)
    wf = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    wi, sc = quantize(wf, 4)
    for mode in ("shift_add", "dequant"):
        _, us = timed(lambda m=mode: pim_matmul(x, wi, sc, mode=m, bits=4))
        rows_out.append((f"kernel_pim_matmul_{mode}_128x512x256", us,
                         "interpret"))
    # MXU flop ratio between the modes (the dry-run measures it for real).
    report("pim_matmul shift_add does 4 plane-dots per tile vs 1 for "
           "dequant → 4x MXU flops (w4), traded for no dequant step")
    for name, us, derived in rows_out:
        report(f"{name:42s} {us:12.1f} us  {derived}")
    return rows_out


if __name__ == "__main__":
    run()
