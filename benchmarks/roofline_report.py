"""Render the §Roofline table from the dry-run JSON records, plus the
device channel-overlap report.

The channel-overlap report drives the channel-aware device timing model
end to end on a host-load + shift workload over 16 banks: 1-channel vs
2-channel walls (per-channel bus serialization with tRTRS rank-switch
penalties), sync vs async host scheduling (Shared-PIM-style double
buffering), and the FCFS internal-bus queueing of a 32-bank gather.
Run as a module with an argument to write the JSON artifact CI uploads:

    PYTHONPATH=src python -m benchmarks.roofline_report roofline_channels.json
"""
import glob
import json
import os
import sys

import numpy as np

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

ROWS, WORDS = 64, 256


def load_records(tag=""):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if (r.get("tag", "") or "") == tag:
            recs.append(r)
    return recs


def channel_overlap_report(report=print):
    """1ch vs 2ch vs 2ch+async walls for a pipelined load+shift workload,
    and COPY queueing stats for a 32-bank gather. Returns the JSON dict."""
    from repro.core import pim

    rng = np.random.default_rng(0)
    n_banks, n_steps = 16, 3
    data = rng.integers(0, 2**32, (n_steps * n_banks, WORDS),
                        dtype=np.uint32)

    def build(b, rows):
        for r in rows:
            b.shift_k(r, r, 8)

    def pipeline(cfg, async_host):
        dev = pim.make_device(cfg)
        walls, host_bus = [], 0.0
        hidden = 0.0
        last = None
        for step in range(n_steps):
            progs = pim.shard_rows(data[step * n_banks:(step + 1) * n_banks],
                                   cfg.n_banks, num_rows=cfg.num_rows,
                                   build=build)
            last = pim.schedule(dev, progs, async_host=async_host)
            dev = last.state
            walls.append(float(last.wall_ns))
            host_bus += last.host_bus_ns
            hidden += last.host_overlap_ns
        return sum(walls), host_bus, hidden, last

    cfg_1ch = pim.DeviceConfig(channels=1, ranks=2, banks_per_rank=8,
                               num_rows=ROWS, words=WORDS)
    cfg_2ch = pim.DeviceConfig(channels=2, ranks=1, banks_per_rank=8,
                               num_rows=ROWS, words=WORDS)
    w1, host1, _, r1 = pipeline(cfg_1ch, False)
    w2, _, _, r2 = pipeline(cfg_2ch, False)
    w2a, _, hidden, _ = pipeline(cfg_2ch, True)
    assert w2 < w1 and w2a <= w2

    # 32-bank gather: FCFS internal-bus contention
    gcfg = pim.paper_device(32, num_rows=ROWS, words=WORDS)
    load = [pim.ProgramBuilder(ROWS, WORDS)
            .write_row(1, data[b % len(data)]).build() for b in range(32)]
    state = pim.schedule(pim.make_device(gcfg), load).state
    moves = [((b, 0, 1), (0, 0, 2 + (b - 1) % 12)) for b in range(1, 32)]
    g = pim.schedule(state, pim.gather_rows(gcfg, moves))
    assert g.copy_queue_ns > 0.0

    out = {
        "benchmark": "channel_overlap",
        "banks": n_banks, "steps": n_steps,
        "wall_1ch_sync_ns": round(w1, 1),
        "wall_2ch_sync_ns": round(w2, 1),
        "wall_2ch_async_ns": round(w2a, 1),
        "speedup_2ch": round(w1 / w2, 3),
        "speedup_2ch_async": round(w1 / w2a, 3),
        "host_bus_ns_per_step": round(host1 / n_steps, 1),
        "host_hidden_ns": round(hidden, 1),
        "rank_switch_ns_1ch": round(r1.rank_switch_ns, 1),
        "channel_bus_ns_2ch": [round(x, 1) for x in r2.channel_bus_ns],
        "gather32_copy_makespan_ns": round(g.copy_ns, 1),
        "gather32_copy_total_ns": round(g.copy_total_ns, 1),
        "gather32_copy_queue_ns": round(g.copy_queue_ns, 1),
    }
    report(f"channel overlap ({n_banks} banks x {n_steps} steps, "
           f"{WORDS * 4}B rows):")
    report(f"  wall 1ch {w1 / 1e3:9.1f} us   2ch {w2 / 1e3:9.1f} us "
           f"({w1 / w2:.2f}x)   2ch+async {w2a / 1e3:9.1f} us "
           f"({w1 / w2a:.2f}x)")
    report(f"  host bursts {host1 / n_steps / 1e3:.1f} us/step, "
           f"{hidden / 1e3:.1f} us hidden by the async engine")
    report(f"  32-bank gather: copy makespan {g.copy_ns / 1e3:.1f} us "
           f"(contention-free sum {g.copy_total_ns / 1e3:.1f} us, "
           f"queued {g.copy_queue_ns / 1e3:.1f} us)")
    return out


def run(report=print, json_path=None):
    recs = load_records()
    rows_out = []
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errored = [r for r in recs if r.get("status") == "error"]
    report(f"dry-run cells: {len(ok)} ok, {len(skipped)} skipped, "
           f"{len(errored)} error")
    report(f"{'arch':22s} {'shape':12s} {'mesh':6s} {'t_comp':>9} "
           f"{'t_mem':>9} {'t_coll':>9} {'bound':>10} {'frac':>6} "
           f"{'util':>6}")
    for r in ok:
        report(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
               f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} "
               f"{r['t_collective']:9.4f} {r['bottleneck']:>10} "
               f"{r.get('roofline_fraction_cell', 0):6.3f} "
               f"{min(r.get('flops_utilization', 0), 9.99):6.3f}")
        rows_out.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            r.get("compile_s", 0) * 1e6,
            f"bottleneck={r['bottleneck']};frac="
            f"{r.get('roofline_fraction_cell', 0):.3f}"))
    for r in skipped:
        report(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
               f"{'skipped: ' + r['reason'][:40]:>46}")

    overlap = channel_overlap_report(report)
    rows_out.append(("roofline_channel_overlap", 0.0,
                     f"speedup_2ch={overlap['speedup_2ch']};"
                     f"speedup_async={overlap['speedup_2ch_async']};"
                     f"gather_queue_ns="
                     f"{overlap['gather32_copy_queue_ns']}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(overlap, f, indent=2, sort_keys=True)
        report(f"wrote {json_path}")
    return rows_out


if __name__ == "__main__":
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
