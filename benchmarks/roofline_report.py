"""Render the §Roofline table from the dry-run JSON records."""
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(tag=""):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if (r.get("tag", "") or "") == tag:
            recs.append(r)
    return recs


def run(report=print):
    recs = load_records()
    rows_out = []
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errored = [r for r in recs if r.get("status") == "error"]
    report(f"dry-run cells: {len(ok)} ok, {len(skipped)} skipped, "
           f"{len(errored)} error")
    report(f"{'arch':22s} {'shape':12s} {'mesh':6s} {'t_comp':>9} "
           f"{'t_mem':>9} {'t_coll':>9} {'bound':>10} {'frac':>6} "
           f"{'util':>6}")
    for r in ok:
        report(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
               f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} "
               f"{r['t_collective']:9.4f} {r['bottleneck']:>10} "
               f"{r.get('roofline_fraction_cell', 0):6.3f} "
               f"{min(r.get('flops_utilization', 0), 9.99):6.3f}")
        rows_out.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            r.get("compile_s", 0) * 1e6,
            f"bottleneck={r['bottleneck']};frac="
            f"{r.get('roofline_fraction_cell', 0):.3f}"))
    for r in skipped:
        report(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
               f"{'skipped: ' + r['reason'][:40]:>46}")
    return rows_out


if __name__ == "__main__":
    run()
