"""Run every benchmark (one per paper table + extensions).

Prints a ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bank_parallel, crypto_casestudy, kernel_bench,
                   roofline_report, table2_energy, table3_perf,
                   table4_variation, table5_area)
    suites = [
        ("table2_energy (paper Table 2)", table2_energy),
        ("table3_perf (paper Table 3)", table3_perf),
        ("table4_variation (paper Table 4)", table4_variation),
        ("table5_area (paper Table 5 + \u00a76)", table5_area),
        ("bank_parallel (paper \u00a75.1.4)", bank_parallel),
        ("crypto_casestudy (paper \u00a78)", crypto_casestudy),
        ("kernel_bench (Pallas kernels)", kernel_bench),
        ("roofline_report (\u00a7Roofline)", roofline_report),
    ]
    rows = []
    failed = []
    for title, mod in suites:
        print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))
        try:
            rows.extend(mod.run())
        except Exception as e:                        # noqa: BLE001
            failed.append((title, e))
            traceback.print_exc()
    print("\n=== CSV " + "=" * 60)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"\n{len(failed)} suite(s) FAILED: "
              f"{[t for t, _ in failed]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
