"""Host-side scheduler/orchestration benchmark (``scheduler_bench.json``).

Measures the control-plane costs the columnar-IR + single-dispatch
scheduler rework targets (ISSUE 5), starting the perf trajectory for the
host orchestration path:

  * ``cost_pass_first_us``   — first call of the vectorized columnar cost
    pass on the Table 2/3 N=1000 shift stream, vs the per-op Python loop +
    jitted-scan fold it replaced (``cost_pass_loop_first_us``).
  * ``steady_steps_per_s``   — steady-state throughput of a recurring
    32-bank schedule step, per-step Python loop vs ``schedule_pipeline``'s
    single ``lax.scan`` dispatch.
  * ``dispatches_per_step``  — XLA dispatches per steady-state step
    (acceptance bar: <= 1 for the per-step path, << 1 for the pipeline).
  * ``first_compile_ms``     — one-time cost of the first schedule call on
    a fresh layout (plan build + trace + XLA compile).

Numbers are host-orchestration wall time on whatever machine runs the
bench (CPU in CI) — the point is the *ratio* trajectory, not the absolute
microseconds.
"""
import importlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pim
from repro.core.pim import compile as pim_compile

pim_schedule = importlib.import_module("repro.core.pim.schedule")

TABLE23_SHIFTS = 1000
PIPELINE_STEPS = 100
BANKS = 32
ROWS, WORDS = 64, 64


def bench_cost_pass(report=print):
    """Columnar gather + numpy fold vs per-op loop + jitted scan fold."""
    prog = pim.shift_workload_program(TABLE23_SHIFTS, ROWS, WORDS)

    # Reference (pre-columnar) path FIRST, before anything warms the
    # _fold_tables jit cache: per-op Python table build + compiled fold.
    t0 = time.perf_counter()
    f_tab, i_tab = pim.cost_tables_reference(prog)
    f0 = jnp.zeros(6, jnp.float32)
    i0 = jnp.zeros(6, jnp.int32)
    ff, fi = pim_compile._fold_tables(jnp.asarray(f_tab), jnp.asarray(i_tab),
                                      f0, i0)
    jax.block_until_ready(ff)
    loop_first_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    meter = pim.cost_pass(prog)
    cost_first_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    meter = pim.cost_pass(prog)
    cost_warm_us = (time.perf_counter() - t0) * 1e6

    exact = float(meter.time_ns) == float(ff[0])
    report(f"cost pass (loop+scan, first) : {loop_first_us:12.1f} us")
    report(f"cost pass (columnar, first)  : {cost_first_us:12.1f} us  "
           f"({loop_first_us / cost_first_us:.1f}x, bit-exact={exact})")
    report(f"cost pass (columnar, warm)   : {cost_warm_us:12.1f} us")
    return {
        "cost_pass_loop_first_us": loop_first_us,
        "cost_pass_first_us": cost_first_us,
        "cost_pass_warm_us": cost_warm_us,
        "cost_pass_first_speedup": loop_first_us / cost_first_us,
        "cost_pass_bit_exact": exact,
    }


def _step_programs(rng):
    """One recurring 32-bank step — the paper's streaming shape: load a
    fresh row into each bank, run the 40-shift chain in-DRAM, read the
    result back. Same stream everywhere, per-bank payload data."""
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, rng.integers(0, 2 ** 32, (WORDS,), dtype=np.uint32))
    b.shift_k(0, 1, 40)
    b.read_row(1)
    base = b.build()
    return [base] + [
        base.with_payloads(
            [rng.integers(0, 2 ** 32, (WORDS,), dtype=np.uint32)])
        for _ in range(BANKS - 1)]


def bench_pipeline(report=print, reps=3):
    rng = np.random.default_rng(0)
    cfg = pim.paper_device(BANKS, num_rows=ROWS, words=WORDS)
    progs = _step_programs(rng)

    # First schedule call on a fresh layout: plan + trace + XLA compile.
    dev = pim.make_device(cfg)
    t0 = time.perf_counter()
    res = pim.schedule(dev, progs)
    jax.block_until_ready(res.state.banks.bits)
    first_compile_ms = (time.perf_counter() - t0) * 1e3

    # Steady state (best of `reps` — host timing is noisy in CI),
    # per-step Python loop vs one lax.scan dispatch.
    stats = pim_schedule.SCHED_STATS
    dev = res.state
    pr = pim.schedule_pipeline(dev, progs, n_steps=PIPELINE_STEPS)
    jax.block_until_ready(pr.state.banks.bits)
    loop_s, pipe_s = float("inf"), float("inf")
    for _ in range(reps):
        d0 = stats["dispatches"]
        t0 = time.perf_counter()
        for _ in range(PIPELINE_STEPS):
            res = pim.schedule(dev, progs)
            dev = res.state
        jax.block_until_ready(dev.banks.bits)
        loop_s = min(loop_s, time.perf_counter() - t0)
        loop_dispatch = (stats["dispatches"] - d0) / PIPELINE_STEPS

        d0 = stats["dispatches"]
        t0 = time.perf_counter()
        pr = pim.schedule_pipeline(pr.state, progs, n_steps=PIPELINE_STEPS)
        jax.block_until_ready(pr.state.banks.bits)
        pipe_s = min(pipe_s, time.perf_counter() - t0)
        pipe_dispatch = (stats["dispatches"] - d0) / PIPELINE_STEPS

    loop_sps = PIPELINE_STEPS / loop_s
    pipe_sps = PIPELINE_STEPS / pipe_s
    report(f"first schedule (fresh layout): {first_compile_ms:10.1f} ms")
    report(f"steady loop ({BANKS} banks)       : {loop_sps:10.1f} steps/s  "
           f"({loop_dispatch:.2f} dispatches/step)")
    report(f"steady pipeline (lax.scan)   : {pipe_sps:10.1f} steps/s  "
           f"({pipe_dispatch:.2f} dispatches/step, "
           f"{pipe_sps / loop_sps:.1f}x)")
    return {
        "workload": f"recurring_{BANKS}bank_step_x{PIPELINE_STEPS}",
        "first_compile_ms": first_compile_ms,
        "steady_loop_steps_per_s": loop_sps,
        "steady_pipeline_steps_per_s": pipe_sps,
        "pipeline_speedup": pipe_sps / loop_sps,
        "dispatches_per_step_loop": loop_dispatch,
        "dispatches_per_step_pipeline": pipe_dispatch,
    }


def run(report=print, json_path=None):
    out = {"n_shifts": TABLE23_SHIFTS, "pipeline_steps": PIPELINE_STEPS}
    out.update(bench_cost_pass(report))
    out.update(bench_pipeline(report))
    blob = json.dumps(out, indent=2, sort_keys=True)
    if json_path:
        with open(json_path, "w") as f:
            f.write(blob + "\n")
        report(f"wrote {json_path}")
    else:
        report(blob)
    return out


if __name__ == "__main__":
    import sys
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
