"""Host-side scheduler/orchestration benchmark (``scheduler_bench.json``).

Measures the control-plane costs the columnar-IR + single-dispatch
scheduler rework targets (ISSUE 5), starting the perf trajectory for the
host orchestration path:

  * ``cost_pass_first_us``   — first call of the vectorized columnar cost
    pass on the Table 2/3 N=1000 shift stream, vs the per-op Python loop +
    jitted-scan fold it replaced (``cost_pass_loop_first_us``).
  * ``steady_steps_per_s``   — steady-state throughput of a recurring
    32-bank schedule step, per-step Python loop vs ``schedule_pipeline``'s
    single ``lax.scan`` dispatch.
  * ``dispatches_per_step``  — XLA dispatches per steady-state step
    (acceptance bar: <= 1 for the per-step path, << 1 for the pipeline).
  * ``first_compile_ms``     — one-time cost of the first schedule call on
    a fresh layout (plan build + trace + XLA compile).

Numbers are host-orchestration wall time on whatever machine runs the
bench (CPU in CI) — the point is the *ratio* trajectory, not the absolute
microseconds.
"""
import importlib
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pim
from repro.core.pim import compile as pim_compile

pim_schedule = importlib.import_module("repro.core.pim.schedule")

TABLE23_SHIFTS = 1000
PIPELINE_STEPS = 100
BANKS = 32
ROWS, WORDS = 64, 64


def bench_cost_pass(report=print):
    """Columnar gather + numpy fold vs per-op loop + jitted scan fold."""
    prog = pim.shift_workload_program(TABLE23_SHIFTS, ROWS, WORDS)

    # Reference (pre-columnar) path FIRST, before anything warms the
    # _fold_tables jit cache: per-op Python table build + compiled fold.
    t0 = time.perf_counter()
    f_tab, i_tab = pim.cost_tables_reference(prog)
    f0 = jnp.zeros(6, jnp.float32)
    i0 = jnp.zeros(6, jnp.int32)
    ff, fi = pim_compile._fold_tables(jnp.asarray(f_tab), jnp.asarray(i_tab),
                                      f0, i0)
    jax.block_until_ready(ff)
    loop_first_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    meter = pim.cost_pass(prog)
    cost_first_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    meter = pim.cost_pass(prog)
    cost_warm_us = (time.perf_counter() - t0) * 1e6

    exact = float(meter.time_ns) == float(ff[0])
    report(f"cost pass (loop+scan, first) : {loop_first_us:12.1f} us")
    report(f"cost pass (columnar, first)  : {cost_first_us:12.1f} us  "
           f"({loop_first_us / cost_first_us:.1f}x, bit-exact={exact})")
    report(f"cost pass (columnar, warm)   : {cost_warm_us:12.1f} us")
    return {
        "cost_pass_loop_first_us": loop_first_us,
        "cost_pass_first_us": cost_first_us,
        "cost_pass_warm_us": cost_warm_us,
        "cost_pass_first_speedup": loop_first_us / cost_first_us,
        "cost_pass_bit_exact": exact,
    }


def _step_programs(rng):
    """One recurring 32-bank step — the paper's streaming shape: load a
    fresh row into each bank, run the 40-shift chain in-DRAM, read the
    result back. Same stream everywhere, per-bank payload data."""
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, rng.integers(0, 2 ** 32, (WORDS,), dtype=np.uint32))
    b.shift_k(0, 1, 40)
    b.read_row(1)
    base = b.build()
    return [base] + [
        base.with_payloads(
            [rng.integers(0, 2 ** 32, (WORDS,), dtype=np.uint32)])
        for _ in range(BANKS - 1)]


def bench_pipeline(report=print, reps=3):
    rng = np.random.default_rng(0)
    cfg = pim.paper_device(BANKS, num_rows=ROWS, words=WORDS)
    progs = _step_programs(rng)

    # First schedule call on a fresh layout: plan + trace + XLA compile.
    dev = pim.make_device(cfg)
    t0 = time.perf_counter()
    res = pim.schedule(dev, progs)
    jax.block_until_ready(res.state.banks.bits)
    first_compile_ms = (time.perf_counter() - t0) * 1e3

    # Steady state (best of `reps` — host timing is noisy in CI),
    # per-step Python loop vs one lax.scan dispatch.
    stats = pim_schedule.SCHED_STATS
    dev = res.state
    pr = pim.schedule_pipeline(dev, progs, n_steps=PIPELINE_STEPS)
    jax.block_until_ready(pr.state.banks.bits)
    loop_s, pipe_s = float("inf"), float("inf")
    for _ in range(reps):
        d0 = stats["dispatches"]
        t0 = time.perf_counter()
        for _ in range(PIPELINE_STEPS):
            res = pim.schedule(dev, progs)
            dev = res.state
        jax.block_until_ready(dev.banks.bits)
        loop_s = min(loop_s, time.perf_counter() - t0)
        loop_dispatch = (stats["dispatches"] - d0) / PIPELINE_STEPS

        d0 = stats["dispatches"]
        t0 = time.perf_counter()
        pr = pim.schedule_pipeline(pr.state, progs, n_steps=PIPELINE_STEPS)
        jax.block_until_ready(pr.state.banks.bits)
        pipe_s = min(pipe_s, time.perf_counter() - t0)
        pipe_dispatch = (stats["dispatches"] - d0) / PIPELINE_STEPS

    loop_sps = PIPELINE_STEPS / loop_s
    pipe_sps = PIPELINE_STEPS / pipe_s
    report(f"first schedule (fresh layout): {first_compile_ms:10.1f} ms")
    report(f"steady loop ({BANKS} banks)       : {loop_sps:10.1f} steps/s  "
           f"({loop_dispatch:.2f} dispatches/step)")
    report(f"steady pipeline (lax.scan)   : {pipe_sps:10.1f} steps/s  "
           f"({pipe_dispatch:.2f} dispatches/step, "
           f"{pipe_sps / loop_sps:.1f}x)")
    return {
        "workload": f"recurring_{BANKS}bank_step_x{PIPELINE_STEPS}",
        "first_compile_ms": first_compile_ms,
        "steady_loop_steps_per_s": loop_sps,
        "steady_pipeline_steps_per_s": pipe_sps,
        "pipeline_speedup": pipe_sps / loop_sps,
        "dispatches_per_step_loop": loop_dispatch,
        "dispatches_per_step_pipeline": pipe_dispatch,
    }


MP_BANKS = 8            # multi-phase RS workload geometry
MP_CW_PER_BANK = 8
MP_WORDS = 16
MP_ROWS = 64
MP_REPS = 5


def _rs_workload(rng):
    """The 3-phase RS(12,8) workload: encode (XOR-fold every codeword into
    per-bank accumulator rows — the fold of valid codewords is itself a
    valid codeword), reduce (log2(banks) gather+merge tree down to bank 0),
    readback. Expressed as one heterogeneous phase list for
    ``schedule_workload``; one codeword is corrupted so the folded
    syndromes are non-zero and detection is observable end-to-end."""
    from repro.core.bitplane import rs
    from repro.core.pim import isa
    n, npar = 12, 4
    lanes = MP_WORDS * 32 // 8
    acc, recv, stage = list(range(n)), list(range(n, 2 * n)), 2 * n
    cw = np.zeros((MP_BANKS, MP_CW_PER_BANK, n, lanes), np.uint64)
    for b in range(MP_BANKS):
        for k in range(MP_CW_PER_BANK):
            msg = rng.integers(0, 256, size=(8, lanes))
            par = rs.ref_rs_encode(msg, npar)
            cw[b, k] = np.concatenate(
                [msg.astype(np.uint64), par[::-1]], axis=0)
    cw[1, 2, 5, 3] ^= 0x5A          # one corrupted byte lane

    from repro.core.bitplane import layout as bl

    def pack(row):
        return bl.pack_elements(row, 8, MP_WORDS)

    cfg = pim.paper_device(MP_BANKS, num_rows=MP_ROWS, words=MP_WORDS)
    bi = pim.ProgramBuilder(MP_ROWS, MP_WORDS)
    for r in acc:
        bi.rowclone(isa.C0, r)
    phases = [pim.Phase.repeat([bi.build()] * MP_BANKS, 1)]
    for j in range(n):                      # encode: fold codeword byte j
        b = pim.ProgramBuilder(MP_ROWS, MP_WORDS)
        b.issue()
        b.write_row(stage, np.zeros(MP_WORDS, np.uint32))
        b.ambit_xor(acc[j], stage, acc[j])
        enc = b.build()
        phases.append(pim.Phase(steps=tuple(
            [enc.with_payloads([pack(cw[bk, k, j])])
             for bk in range(MP_BANKS)]
            for k in range(MP_CW_PER_BANK))))
    bm = pim.ProgramBuilder(MP_ROWS, MP_WORDS)
    for j in range(n):
        bm.ambit_xor(acc[j], recv[j], acc[j])
    merge = bm.build()
    stride = 1
    while stride < MP_BANKS:                # reduce: gather+merge tree
        moves = [((b + stride, 0, acc[j]), (b, 0, recv[j]))
                 for b in range(0, MP_BANKS, 2 * stride) for j in range(n)]
        phases.append(pim.Phase.repeat(pim.gather_rows(cfg, moves), 1))
        alive = set(range(0, MP_BANKS, 2 * stride))
        phases.append(pim.Phase.repeat(
            [merge if b in alive else None for b in range(MP_BANKS)], 1))
        stride *= 2
    br = pim.ProgramBuilder(MP_ROWS, MP_WORDS)
    for j in range(n):
        br.read_row(acc[j])
    phases.append(pim.Phase.repeat(
        [br.build()] + [None] * (MP_BANKS - 1), 1))
    return cfg, phases, cw, acc


def bench_multi_phase(report=print):
    """The tentpole bar (ISSUE 6): the whole heterogeneous multi-phase
    workload as ONE dispatch vs the per-phase dispatch loop — one host
    dispatch per phase step, the O(phases x steps) baseline
    ``schedule_workload`` replaces. The ``schedule_pipeline``-per-phase
    loop (O(phases) dispatches) is reported as an extra datum."""
    from repro.core.bitplane import layout as bl
    from repro.core.bitplane import rs
    rng = np.random.default_rng(0)
    cfg, phases, cw, acc = _rs_workload(rng)
    n_steps = sum(len(p.steps) for p in phases)
    stats = pim_schedule.SCHED_STATS

    t0 = time.perf_counter()
    res = pim.schedule_workload(pim.make_device(cfg), phases)
    jax.block_until_ready(res.state.banks.bits)
    first_call_ms = (time.perf_counter() - t0) * 1e3

    # Correctness: the in-DRAM fold must equal the numpy XOR oracle, and
    # the folded syndromes must flag the injected corruption.
    lanes = MP_WORDS * 32 // 8
    got = np.stack([bl.unpack_elements(
        np.asarray(res.state.slot(0).bits)[acc][j], 8, lanes)
        for j in range(len(acc))])
    oracle = np.bitwise_xor.reduce(
        cw.reshape(-1, len(acc), lanes).astype(np.uint64), axis=0)
    bit_exact = np.array_equal(got, oracle)
    detected = bool(np.any(rs.ref_rs_syndromes(got, 4)))

    # Per-phase dispatch loop reference (also warms every step layout).
    seq = [s for p in phases for s in p.steps]
    dev = pim.make_device(cfg)
    wall = energy = 0.0
    for s in seq:
        r = pim.schedule(dev, s)
        dev, wall, energy = r.state, wall + r.wall_ns, energy + r.energy_nj
    jax.block_until_ready(dev.banks.bits)
    meters_exact = (
        np.array_equal(np.asarray(dev.banks.bits),
                       np.asarray(res.state.banks.bits))
        and abs(wall - res.total_wall_ns) <= 1e-6 * wall
        and abs(energy - res.total_energy_nj) <= 1e-6 * energy)

    # Steady state: thread the device state through repeated submissions.
    wl = pim.make_device(cfg)
    wl = pim.schedule_workload(wl, phases).state
    jax.block_until_ready(wl.banks.bits)
    d0 = stats["dispatches"]
    t0 = time.perf_counter()
    for _ in range(MP_REPS):
        wl = pim.schedule_workload(wl, phases).state
    jax.block_until_ready(wl.banks.bits)
    wl_ms = (time.perf_counter() - t0) / MP_REPS * 1e3
    wl_disp = (stats["dispatches"] - d0) / MP_REPS / n_steps

    d0 = stats["dispatches"]
    t0 = time.perf_counter()
    for _ in range(MP_REPS):
        for s in seq:
            dev = pim.schedule(dev, s).state
    jax.block_until_ready(dev.banks.bits)
    loop_ms = (time.perf_counter() - t0) / MP_REPS * 1e3
    loop_disp = (stats["dispatches"] - d0) / MP_REPS / n_steps

    pp = pim.make_device(cfg)
    for p in phases:
        pp = pim.schedule_pipeline(pp, list(p.steps)).state
    jax.block_until_ready(pp.banks.bits)
    t0 = time.perf_counter()
    for _ in range(MP_REPS):
        for p in phases:
            pp = pim.schedule_pipeline(pp, list(p.steps)).state
    jax.block_until_ready(pp.banks.bits)
    pipe_ms = (time.perf_counter() - t0) / MP_REPS * 1e3

    report(f"multi-phase RS(12,8) ({len(phases)} phase segments, "
           f"{n_steps} steps): first call {first_call_ms:.0f} ms")
    report(f"  workload (1 dispatch)      : {wl_ms:8.2f} ms  "
           f"({wl_disp:.4f} dispatches/step)")
    report(f"  per-phase dispatch loop    : {loop_ms:8.2f} ms  "
           f"({loop_disp:.2f} dispatches/step, "
           f"{loop_ms / wl_ms:.1f}x slower)")
    report(f"  pipeline-per-phase loop    : {pipe_ms:8.2f} ms  "
           f"({pipe_ms / wl_ms:.1f}x slower)")
    report(f"  bit-exact={bit_exact} corruption-detected={detected} "
           f"meters-exact={meters_exact}")
    return {"multi_phase": {
        "workload": "rs_12_8_encode_reduce_readback",
        "banks": MP_BANKS, "words": MP_WORDS,
        "codewords_per_bank": MP_CW_PER_BANK,
        "phase_segments": len(phases), "steps": n_steps,
        "first_call_ms": first_call_ms,
        "steady_state_workload_ms": wl_ms,
        "steady_state_per_phase_loop_ms": loop_ms,
        "steady_state_pipeline_per_phase_ms": pipe_ms,
        "dispatches_per_step_workload": wl_disp,
        "dispatches_per_step_loop": loop_disp,
        "speedup_vs_per_phase_dispatch_loop": loop_ms / wl_ms,
        "speedup_vs_pipeline_per_phase": pipe_ms / wl_ms,
        "bit_exact_vs_oracle": bool(bit_exact),
        "meters_match_per_step_schedule": bool(meters_exact),
        "corruption_detected": detected,
    }}


def run(report=print, json_path=None):
    out = {"n_shifts": TABLE23_SHIFTS, "pipeline_steps": PIPELINE_STEPS}
    out.update(bench_cost_pass(report))
    out.update(bench_pipeline(report))
    out.update(bench_multi_phase(report))
    blob = json.dumps(out, indent=2, sort_keys=True)
    if json_path:
        with open(json_path, "w") as f:
            f.write(blob + "\n")
        report(f"wrote {json_path}")
    else:
        report(blob)
    return out


if __name__ == "__main__":
    import sys
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
