"""pimsem benchmark (``sem_bench.json``): cost trajectory of the symbolic
semantic analyzer (ISSUE 9, DESIGN.md §14).

  * ``analyze_100k_cold_ms``  — first full abstract interpretation of a
    100k-op chained-shift stream (run-collapsed: the whole chain is one
    vectorized displacement). Acceptance bar: < 1000 ms, enforced here
    and in tests/test_pim_sem.py.
  * ``analyze_100k_warm_us``  — the same call against the content-digest
    cache; must rebuild ZERO column tables (``COLUMN_STATS``-pinned).
  * ``findings_100k_ms``      — the PIM4xx findings pass over the same
    stream.
  * ``prove_xor_us`` / ``fusion_*_ms`` — equivalence/fusion proofs over
    the canonical kernels (ambit_xor, the Table 2/3 shift workload, the
    recorded GF(2^8) xtime — 16 symbolic inputs, the analyzer's deepest
    real case).

Host wall time on whatever runs the bench (CPU in CI); the point is the
trajectory, not the absolute microseconds.
"""
import json
import time

from repro.core import pim
from repro.core.pim import ir, sem
from repro.core.pim.lint import _recorded_xtime
from repro.core.pim.program import ambit_xor_program, shift_workload_program

N_OPS = 100_000
ROWS, WORDS = 64, 4


def _shift_stream(n=N_OPS):
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.shift(0, 1, +1)
    for _ in range(n - 1):
        b.shift(1, 1, +1)
    prog = b.build()
    prog.columns                       # columnar encode outside the timers
    return prog


def run(report=print, json_path=None):
    out = {"n_ops": N_OPS, "rows": ROWS, "words": WORDS}

    prog = _shift_stream()
    t0 = time.perf_counter()
    sem.analyze(prog)
    out["analyze_100k_cold_ms"] = (time.perf_counter() - t0) * 1e3
    assert out["analyze_100k_cold_ms"] < 1000.0, \
        f"100k-op analysis over budget: {out['analyze_100k_cold_ms']:.0f}ms"

    pim.reset_stats()
    t0 = time.perf_counter()
    sem.analyze(prog)
    out["analyze_100k_warm_us"] = (time.perf_counter() - t0) * 1e6
    out["column_builds_warm"] = int(ir.COLUMN_STATS["builds"])
    out["analysis_hits_warm"] = int(sem.SEM_STATS["analysis_hits"])
    assert out["column_builds_warm"] == 0, \
        "warm digest hit rebuilt column tables"

    t0 = time.perf_counter()
    sem.semantic_findings(prog)
    out["findings_100k_ms"] = (time.perf_counter() - t0) * 1e3

    xor = ambit_xor_program()
    t0 = time.perf_counter()
    rep = sem.prove_equivalent(xor, xor)
    out["prove_xor_us"] = (time.perf_counter() - t0) * 1e6
    assert rep.verdict == sem.EQUIVALENT

    t0 = time.perf_counter()
    assert sem.fusion_report(xor).verdict == sem.EQUIVALENT
    out["fusion_xor_ms"] = (time.perf_counter() - t0) * 1e3

    shifts = shift_workload_program(256, num_rows=ROWS, words=32)
    t0 = time.perf_counter()
    assert sem.fusion_report(shifts).verdict == sem.EQUIVALENT
    out["fusion_shift256_ms"] = (time.perf_counter() - t0) * 1e3

    xtime = _recorded_xtime()
    t0 = time.perf_counter()
    assert sem.fusion_report(xtime).verdict == sem.EQUIVALENT
    out["fusion_gf_xtime_ms"] = (time.perf_counter() - t0) * 1e3

    report(f"analyze 100k ops: cold {out['analyze_100k_cold_ms']:.1f} ms, "
           f"warm {out['analyze_100k_warm_us']:.0f} us "
           f"(column rebuilds: {out['column_builds_warm']})")
    report(f"findings 100k ops: {out['findings_100k_ms']:.1f} ms")
    report(f"proofs: xor {out['prove_xor_us']:.0f} us, fusion xor "
           f"{out['fusion_xor_ms']:.1f} ms, shift256 "
           f"{out['fusion_shift256_ms']:.1f} ms, gf.xtime "
           f"{out['fusion_gf_xtime_ms']:.1f} ms")

    blob = json.dumps(out, indent=2, sort_keys=True)
    if json_path:
        with open(json_path, "w") as f:
            f.write(blob + "\n")
        report(f"wrote {json_path}")
    else:
        report(blob)
    return out


if __name__ == "__main__":
    import sys
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
