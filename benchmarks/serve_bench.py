"""Multi-tenant serving front-end benchmark (``serve_bench.json``).

Measures the request-level serving path (``repro.serve.pim_front``,
DESIGN.md §13) on one shared device:

  * ``grid``            — tenants x steps sweep: steady-state steps/s,
    XLA dispatches per device step (the continuous-batching loop rides
    ``schedule_pipeline``, so recurring windows cost << 1 dispatch/step),
    the cross-tenant coalescing factor (active slots per compiled stream
    group — N tenants on one digest coalesce to ~N), per-tenant energy,
    and per-tenant p50/p99 step latency from the sliced per-slot meters.
  * ``isolation``       — the bit-exactness bar: every tenant's host
    reads and final bank state under the coalesced schedule vs the same
    tenant running ALONE on a private device slice.
  * ``churn``           — admission/preemption behaviour: staggered
    tenant lengths plus queued arrivals admitted at step boundaries, and
    the warm-plan contract (plan misses stay bounded by the number of
    distinct layouts, not the number of membership changes).
  * ``hostile_admission`` — the admission gate: a known-bad program (the
    pim104 scratch-alias fixture) must be REJECTED at submit() with lint
    diagnostics — not admitted, not a crash.

Host wall times are whatever machine runs the bench (CPU in CI); the
meaningful numbers are the ratios and the dispatch/coalescing counters.
"""
import importlib
import json
import time

import jax
import numpy as np

from repro.core import pim
from repro.serve.pim_front import AdmissionError, PimServeFront

pim_schedule = importlib.import_module("repro.core.pim.schedule")

BANKS = 16
ROWS, WORDS = 32, 8
STEPS = 40
BANKS_PER_TENANT = 2
HOSTILE_FIXTURE = "tests/fixtures/lint/pim104.trace"


def _cfg(banks=BANKS):
    return pim.paper_device(banks, num_rows=ROWS, words=WORDS)


def _stream(rng):
    """The paper's streaming step: load a row, 40-shift chain, read back."""
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))
    b.shift_k(0, 1, 40)
    b.read_row(1)
    return b.build()


def _submit_all(front, n_tenants, rng, steps=STEPS,
                banks=BANKS_PER_TENANT):
    """N tenants, every one the same command stream over private data —
    the digest-coalescing steady state."""
    base = _stream(rng)
    for i in range(n_tenants):
        layout = [base.with_payloads(
            [rng.integers(0, 2**32, (WORDS,), dtype=np.uint32)])
            for _ in range(banks)]
        front.submit(f"tenant{i}", (layout, steps), banks=banks)


def bench_grid(report=print, reps=2):
    rng = np.random.default_rng(0)
    stats = pim_schedule.SCHED_STATS
    cells = []
    for n_tenants in (1, 2, 4, 8):
        best_s, cell = float("inf"), None
        for _ in range(reps):            # rep 1 pays the compiles
            front = PimServeFront(_cfg())
            _submit_all(front, n_tenants, rng)
            d0 = stats["dispatches"]
            t0 = time.perf_counter()
            results = front.run()
            jax.block_until_ready(front.device.banks.bits)
            dt = time.perf_counter() - t0
            n_steps = sum(r.n_steps for r in results)
            if dt < best_s:
                best_s = dt
                reports = front.reports()
                walls = np.concatenate(
                    [r.wall_ns for r in reports.values()])
                cell = {
                    "tenants": n_tenants,
                    "steps_per_tenant": STEPS,
                    "banks_per_tenant": BANKS_PER_TENANT,
                    "device_steps": n_steps,
                    "steps_per_s": n_steps / dt,
                    "dispatches_per_step":
                        (stats["dispatches"] - d0) / n_steps,
                    "coalescing_factor": float(np.mean(
                        [r.coalescing for r in results])),
                    "per_tenant_energy_nj": float(np.mean(
                        [r.energy_nj for r in reports.values()])),
                    "p50_step_wall_ns": float(np.percentile(walls, 50)),
                    "p99_step_wall_ns": float(np.percentile(walls, 99)),
                }
        report(f"grid {n_tenants:2d} tenants: "
               f"{cell['steps_per_s']:8.1f} steps/s  "
               f"{cell['dispatches_per_step']:.3f} disp/step  "
               f"coalescing {cell['coalescing_factor']:.1f}  "
               f"{cell['per_tenant_energy_nj']:.0f} nJ/tenant  "
               f"p50 {cell['p50_step_wall_ns']:.0f} ns  "
               f"p99 {cell['p99_step_wall_ns']:.0f} ns")
        cells.append(cell)
    return {"grid": cells}


def bench_isolation(report=print, n_tenants=4, steps=10):
    """Bit-exactness of the coalesced schedule vs isolated tenants."""
    rng = np.random.default_rng(1)
    cfg = _cfg()
    front = PimServeFront(cfg)
    base = _stream(rng)
    workloads = {}
    for i in range(n_tenants):
        tid = f"tenant{i}"
        layout = [base.with_payloads(
            [rng.integers(0, 2**32, (WORDS,), dtype=np.uint32)])
            for _ in range(BANKS_PER_TENANT)]
        workloads[tid] = [list(layout) for _ in range(steps)]
        front.submit(tid, (layout, steps), banks=BANKS_PER_TENANT)
    placements = front.placement()
    reads_front = {tid: [] for tid in workloads}
    coalescing = []
    for res in front.run():
        coalescing.append(res.coalescing)
        for tid in res.placements:
            got = res.tenant_reads(tid)
            reads_front[tid].extend(got if res.n_steps > 1 else [got])
    shared_bits = np.asarray(front.device.banks.bits)

    bit_exact = True
    for tid, tsteps in workloads.items():
        dev = pim.make_device(cfg.subdevice(BANKS_PER_TENANT))
        reads_iso = []
        for s in tsteps:
            r = pim.schedule(dev, s)
            dev = r.state
            reads_iso.append(r.reads)
        banks = list(placements[tid].banks)
        if not np.array_equal(shared_bits[banks],
                              np.asarray(dev.banks.bits)):
            bit_exact = False
        for k in range(steps):
            for sl in range(BANKS_PER_TENANT):
                for x, y in zip(reads_front[tid][k][sl], reads_iso[k][sl]):
                    if not np.array_equal(np.asarray(x), np.asarray(y)):
                        bit_exact = False
    rec = front.reconcile()
    reconciled = (abs(rec["tenant_energy_nj"] - rec["device_energy_nj"])
                  <= 1e-9 * abs(rec["device_energy_nj"])
                  and rec["tenant_host_bytes"] == rec["device_host_bytes"])
    report(f"isolation: bit_exact={bit_exact} "
           f"coalescing {float(np.mean(coalescing)):.1f} "
           f"accounting_reconciles={reconciled}")
    if not bit_exact or not reconciled:
        raise SystemExit("isolation gate FAILED: "
                         f"bit_exact={bit_exact} reconciled={reconciled}")
    return {"isolation": {
        "tenants": n_tenants, "steps": steps,
        "bit_exact_vs_isolated": bool(bit_exact),
        "coalescing_factor": float(np.mean(coalescing)),
        "accounting_reconciles": bool(reconciled),
        "tenant_energy_nj_sum": rec["tenant_energy_nj"],
        "device_energy_nj": rec["device_energy_nj"],
    }}


def bench_churn(report=print):
    """Continuous batching under churn: staggered lengths + queued
    arrivals; plan misses bounded by distinct layouts."""
    rng = np.random.default_rng(2)
    stats = pim_schedule.SCHED_STATS
    front = PimServeFront(_cfg(banks=8))
    base = _stream(rng)

    def layout(nb):
        return [base.with_payloads(
            [rng.integers(0, 2**32, (WORDS,), dtype=np.uint32)])
            for _ in range(nb)]

    front.submit("long", (layout(4), 60), banks=4)
    front.submit("short", (layout(4), 15), banks=4)
    front.submit("late1", (layout(4), 20), banks=4, queue=True)
    front.submit("late2", (layout(2), 10), banks=2, queue=True)
    d0, p0 = stats["dispatches"], stats["plan_misses"]
    t0 = time.perf_counter()
    results = front.run()
    jax.block_until_ready(front.device.banks.bits)
    dt = time.perf_counter() - t0
    n_steps = sum(r.n_steps for r in results)
    served = front.reports()
    rec = front.reconcile()
    out = {
        "tenants_served": len(served),
        "device_steps": n_steps,
        "dispatches": stats["dispatches"] - d0,
        "dispatches_per_step": (stats["dispatches"] - d0) / n_steps,
        "plan_misses": stats["plan_misses"] - p0,
        "steps_per_s": n_steps / dt,
        "per_tenant_steps": {t: r.n_steps for t, r in served.items()},
        "accounting_reconciles": bool(
            abs(rec["tenant_busy_ns"] - rec["device_busy_ns"])
            <= 1e-9 * max(1.0, abs(rec["device_busy_ns"]))),
    }
    report(f"churn: {out['tenants_served']} tenants, "
           f"{n_steps} steps, {out['dispatches']} dispatches, "
           f"{out['plan_misses']} plan misses, "
           f"{out['steps_per_s']:.1f} steps/s")
    return {"churn": out}


def bench_hostile_admission(report=print):
    """The admission gate on a known-bad tenant: rejection with
    diagnostics, never a crash of the shared device."""
    bad = pim.PimProgram.from_trace(open(HOSTILE_FIXTURE).read())
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=2,
                           num_rows=bad.num_rows, words=bad.words)
    front = PimServeFront(cfg)
    rejected, codes, crashed = False, (), False
    try:
        front.submit("hostile", (bad, 4), banks=1)
    except AdmissionError as e:
        rejected = True
        codes = e.report.codes() if e.report else ()
    except Exception:           # a crash would fail the acceptance bar
        crashed = True
    # the shared device still serves well-behaved tenants afterwards
    b = pim.ProgramBuilder(bad.num_rows, bad.words)
    b.write_row(2, np.zeros(bad.words, np.uint32))
    b.read_row(2)
    front.submit("good", (b.build(), 2), banks=1)
    front.run()
    survived = front.report("good").n_steps == 2
    report(f"hostile admission: rejected={rejected} codes={list(codes)} "
           f"crashed={crashed} device_survived={survived}")
    # CI gate: the bad tenant must be REJECTED with diagnostics — an
    # admission, a crash, or a disturbed device fails the bench run.
    if not rejected or crashed or not survived:
        raise SystemExit("hostile-admission gate FAILED: "
                         f"rejected={rejected} crashed={crashed} "
                         f"survived={survived}")
    return {"hostile_admission": {
        "fixture": HOSTILE_FIXTURE,
        "rejected": rejected,
        "lint_codes": sorted(set(codes)),
        "crashed": crashed,
        "device_survived": survived,
    }}


def run(report=print, json_path=None):
    out = {"banks": BANKS, "rows": ROWS, "words": WORDS}
    out.update(bench_grid(report))
    out.update(bench_isolation(report))
    out.update(bench_churn(report))
    out.update(bench_hostile_admission(report))
    blob = json.dumps(out, indent=2, sort_keys=True)
    if json_path:
        with open(json_path, "w") as f:
            f.write(blob + "\n")
        report(f"wrote {json_path}")
    else:
        report(blob)
    return out


if __name__ == "__main__":
    import sys
    run(json_path=sys.argv[1] if len(sys.argv) > 1 else None)
