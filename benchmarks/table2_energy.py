"""Paper Table 2: energy breakdown of shift workloads (1/50/100/512 shifts).

Reproduces the NVMain experiment on the JAX PIM runtime and reports
model-vs-paper errors per cell.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import pim

from .common import timed, pct_err

PAPER = {  # n: (total_nj, active_nj, refresh_nj, energy_per_shift_nj)
    1: (31.321, 30.24, 0.0, 31.321),
    50: (1592.52, 1515.4, 77.1171, 31.85),
    100: (3223.6, 3030.81, 192.793, 32.236),
    512: (16554.6, 15513.5, 1041.08, 32.333),
}


def run(report=print):
    rng = np.random.default_rng(0)
    row = jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))
    rows = []
    report(f"{'n_shifts':>9} {'total nJ':>12} {'paper':>10} {'err%':>7} "
           f"{'active nJ':>10} {'refresh nJ':>10} {'nJ/shift':>9} "
           f"{'nJ/KB':>7}")
    for n, (e_tot, e_act, e_ref, e_per) in PAPER.items():
        state, us = timed(pim.run_shift_workload, row, n)
        m = state.meter
        tot = float(m.total_energy_nj)
        report(f"{n:9d} {tot:12.2f} {e_tot:10.2f} {pct_err(tot, e_tot):+7.2f}"
               f" {float(m.e_act):10.2f} {float(m.e_refresh):10.2f}"
               f" {tot/n:9.3f} {tot/n/8:7.3f}")
        rows.append((f"table2_energy_n{n}", us,
                     f"total_nJ={tot:.2f};paper={e_tot};err_pct="
                     f"{pct_err(tot, e_tot):.2f}"))
        assert float(m.e_burst) == 0.0, "PIM workload must not burst"
    return rows


if __name__ == "__main__":
    run()
