"""Paper Table 3: latency / throughput of in-DRAM shift workloads."""
import jax.numpy as jnp
import numpy as np

from repro.core import pim

from .common import timed, pct_err

PAPER = {  # n: (total_time, per_shift_ns, mops)
    1: (208.7, 208.7, None),
    50: (10_291.0, 205.8, 4.86),
    100: (20_733.0, 207.3, 4.82),
    512: (106_272.0, 207.6, 4.82),
}


def run(report=print):
    rng = np.random.default_rng(0)
    row = jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))
    rows = []
    report(f"{'n_shifts':>9} {'total ns':>12} {'paper':>10} {'err%':>7} "
           f"{'ns/shift':>9} {'MOps/s':>8}")
    for n, (t_paper, per_paper, mops_paper) in PAPER.items():
        state, us = timed(pim.run_shift_workload, row, n)
        t = float(state.meter.time_ns)
        mops = n / t * 1e3
        report(f"{n:9d} {t:12.1f} {t_paper:10.1f} {pct_err(t, t_paper):+7.2f}"
               f" {t/n:9.2f} {mops:8.3f}")
        rows.append((f"table3_perf_n{n}", us,
                     f"total_ns={t:.1f};paper={t_paper};err_pct="
                     f"{pct_err(t, t_paper):.2f};mops={mops:.3f}"))
    return rows


if __name__ == "__main__":
    run()
