"""Paper Table 4: shift failure rate under process variation (Monte Carlo)."""
import jax

from repro.core.pim import variation as V

from .common import timed


def run(report=print):
    key = jax.random.PRNGKey(42)
    rows = []
    report(f"{'variation':>10} {'model %':>9} {'paper %':>9}")
    for p, paper in V.PAPER_TABLE4.items():
        rate, us = timed(lambda pp=p: V.shift_failure_rate(
            key, pp, n_trials=100_000))
        r = float(rate)
        report(f"{p:9.0f}% {100*r:9.2f} {100*paper:9.2f}")
        rows.append((f"table4_variation_{int(p)}pct", us,
                     f"model={100*r:.2f}%;paper={100*paper:.2f}%"))
    return rows


if __name__ == "__main__":
    run()
