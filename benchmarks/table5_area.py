"""Paper Table 5 + §6: area overhead model and MIM capacitor sizing."""
from repro.core.pim.area import AreaModel, PAPER_TABLE5, \
    mim_capacitor_plate_side_um

from .common import timed


def run(report=print):
    model = AreaModel()
    rows = []
    _, us = timed(lambda: model.overhead_pct, iters=10)
    report(f"migration-cell design overhead: {model.overhead_pct:.2f}% "
           f"(paper: <1%); with Ambit: {model.overhead_with_ambit_pct:.2f}% "
           f"(paper: ~1-2%)")
    report(f"{'design':22s} {'added circuitry':38s} {'overhead'}")
    for name, circuitry, overhead in PAPER_TABLE5:
        report(f"{name:22s} {circuitry:38s} {overhead}")
    side = mim_capacitor_plate_side_um()
    report(f"MIM capacitor plate side (25fF, HfO2 eps_r=20, d=8nm): "
           f"{side:.2f} um (paper: 1.06 um)")
    assert model.overhead_pct < 1.0
    assert model.overhead_with_ambit_pct < 2.0
    assert abs(side - 1.06) < 0.05
    rows.append(("table5_area_overhead", us,
                 f"overhead_pct={model.overhead_pct:.2f};"
                 f"with_ambit={model.overhead_with_ambit_pct:.2f};"
                 f"mim_side_um={side:.2f}"))
    return rows


if __name__ == "__main__":
    run()
