"""Replay an external PIM command trace through the compiling executor.

Accepts the repo's ``pim-trace v1`` text format (HBM-PIMulator-style: one
command per line, ``#``/``//`` comments, optional ``PIM`` prefix — see
DESIGN.md §6). Prints the analytical cost summary and the executed meter,
and optionally re-exports the parsed program (round-trip check).

    PYTHONPATH=src python -m benchmarks.trace_replay TRACE [--out TRACE2]

With no argument, replays the recorded Table 2/3 workload (N=1000) as a
self-check.
"""
from __future__ import annotations

import argparse
import json

from repro.core import pim


def replay(trace_path: str | None, out_path: str | None = None,
           report=print):
    if trace_path is None:
        prog = pim.shift_workload_program(1000, 64, 2048)
        report("no trace given — replaying the recorded Table 2/3 workload "
               f"(N=1000, {len(prog)} commands)")
    else:
        prog = pim.PimProgram.load_trace(trace_path)
        report(f"loaded {trace_path}: {len(prog)} commands, "
               f"{prog.num_rows} rows x {prog.words} words")
    report(f"opcode histogram: {prog.counts()}")

    summary = pim.cost_summary(prog, refresh=True)
    res = pim.execute(prog, refresh=True)
    meter = res.state.meter
    out = {
        "n_commands": len(prog),
        "summary_time_ns": summary["time_ns"],
        "summary_energy_nj": summary["energy_nj"],
        "meter_time_ns": float(meter.time_ns),
        "meter_energy_nj": float(meter.total_energy_nj),
        "n_reads": len(res.reads),
    }
    report(json.dumps(out, indent=2, sort_keys=True))

    if out_path:
        prog.save_trace(out_path)
        rt = pim.PimProgram.load_trace(out_path)
        assert rt.ops == prog.ops, "trace round-trip mismatch"
        report(f"wrote {out_path} (round-trip verified)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", nargs="?", default=None,
                    help="pim-trace v1 file to replay")
    ap.add_argument("--out", default=None,
                    help="re-export the parsed program to this path")
    args = ap.parse_args()
    replay(args.trace, args.out)


if __name__ == "__main__":
    main()
