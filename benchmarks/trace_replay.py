"""Replay an external PIM command trace through the compiling executor.

Accepts the repo's ``pim-trace`` text formats (HBM-PIMulator-style: one
command per line, ``#``/``//`` comments, optional ``PIM`` prefix — see
DESIGN.md §6/§7):

- ``pim-trace v1`` — one bank; replayed through ``pim.execute``.
- ``pim-trace v2`` — ``banks=N`` header plus ``BANK <b>`` line prefixes;
  replayed device-level through the workload scheduler (``pim.schedule``),
  reporting wall = bus serialization + max over banks and summed energy.
- ``pim-trace v3`` — adds ``subarrays=S`` and ``BANK <b> SUB <s>``
  prefixes (multi-subarray banks); ``COPY`` lines move rows between
  subarrays/banks in-DRAM and are drained by the scheduler.

Prints the analytical cost summary and the executed meter, and optionally
re-exports the parsed program(s) (round-trip check).

    PYTHONPATH=src python -m benchmarks.trace_replay TRACE [--out TRACE2]

With no argument, replays the recorded Table 2/3 workload (N=1000) as a
self-check.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import pim


def _replay_single(prog, report):
    report(f"opcode histogram: {prog.counts()}")
    summary = pim.cost_summary(prog, refresh=True)
    res = pim.execute(prog, refresh=True)
    meter = res.state.meter
    return {
        "n_commands": len(prog),
        "summary_time_ns": summary["time_ns"],
        "summary_energy_nj": summary["energy_nj"],
        "meter_time_ns": float(meter.time_ns),
        "meter_energy_nj": float(meter.total_energy_nj),
        "n_reads": len(res.reads),
    }


def _replay_device(programs, report):
    """programs: nested [bank][subarray] (v2 → one subarray per bank)."""
    subarrays = len(programs[0])
    flat = [p for bank in programs for p in bank]
    rows = flat[0].num_rows
    words = flat[0].words
    cfg = pim.DeviceConfig(channels=1, ranks=1,
                           banks_per_rank=len(programs),
                           subarrays=subarrays,
                           num_rows=rows, words=words)
    report(f"device replay: {len(programs)} banks x {subarrays} "
           f"subarray(s) x {rows} rows x {words} words")
    for b, bank in enumerate(programs):
        for s, p in enumerate(bank):
            if len(p):
                report(f"  bank {b} sub {s}: {len(p)} commands {p.counts()}")
    res = pim.schedule(pim.make_device(cfg), [list(bank) for bank in programs])
    return {
        "n_banks": len(programs),
        "n_subarrays": subarrays,
        "n_commands": sum(len(p) for p in flat),
        "wall_ns": float(res.wall_ns),
        "bus_ns": float(res.bus_ns),
        "copy_ns": float(res.copy_ns),
        "energy_nj": float(res.energy_nj),
        "host_bytes": int(res.host_bytes),
        "n_reads": sum(len(r) for r in res.reads),
    }


def replay(trace_path: str | None, out_path: str | None = None,
           report=print):
    if trace_path is None:
        programs = ((pim.shift_workload_program(1000, 64, 2048),),)
        report("no trace given — replaying the recorded Table 2/3 workload "
               f"(N=1000, {len(programs[0][0])} commands)")
    else:
        with open(trace_path) as f:
            programs = pim.from_trace_device(f.read())
        flat = [p for bank in programs for p in bank]
        report(f"loaded {trace_path}: {len(programs)} bank(s) x "
               f"{len(programs[0])} subarray(s), "
               f"{sum(len(p) for p in flat)} commands, "
               f"{flat[0].num_rows} rows x {flat[0].words} words")

    if len(programs) == 1 and len(programs[0]) == 1:
        out = _replay_single(programs[0][0], report)
    else:
        out = _replay_device(programs, report)
    report(json.dumps(out, indent=2, sort_keys=True))

    if out_path:
        if len(programs) == 1 and len(programs[0]) == 1:
            text = programs[0][0].to_trace()
        elif len(programs[0]) == 1:
            text = pim.to_trace_banks([bank[0] for bank in programs])
        else:
            text = pim.to_trace_device(programs)
        with open(out_path, "w") as f:
            f.write(text)
        rt = pim.from_trace_device(text)
        assert tuple(tuple(p.ops for p in bank) for bank in rt) == \
            tuple(tuple(p.ops for p in bank) for bank in programs), \
            "trace round-trip mismatch"
        assert all(
            np.array_equal(x, y)
            for bank_p, bank_q in zip(rt, programs)
            for p, q in zip(bank_p, bank_q)
            for x, y in zip(p.payloads, q.payloads)), \
            "trace payload round-trip mismatch"
        report(f"wrote {out_path} (round-trip verified)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", nargs="?", default=None,
                    help="pim-trace v1/v2 file to replay")
    ap.add_argument("--out", default=None,
                    help="re-export the parsed program(s) to this path")
    args = ap.parse_args()
    replay(args.trace, args.out)


if __name__ == "__main__":
    main()
