"""Crypto case study (paper §8.0.2): AES GF(2^8) arithmetic and Reed-Solomon
encoding entirely in-DRAM — horizontal data, migration-cell shifts, Ambit
bitwise ops — verified against numpy oracles, with DDR3 cost accounting.

    PYTHONPATH=src python examples/pim_crypto.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core.bitplane import PimVM, arith, gf, rs


def main():
    rng = np.random.default_rng(0)

    print("=== shift-and-add multiplication (paper §1 motivation) ===")
    vm = PimVM(width=8, num_rows=96, words=8)      # 32 byte lanes
    a = rng.integers(0, 256, vm.lanes)
    b = rng.integers(0, 256, vm.lanes)
    t0, e0 = vm.time_ns, vm.energy_nj
    prod = arith.mul_shift_add(vm, vm.load(a), vm.load(b))
    assert np.array_equal(vm.read(prod), arith.ref_mul(a, b, 8))
    print(f"8-bit x 8-bit on {vm.lanes} lanes: OK  "
          f"[{(vm.time_ns-t0)/1e3:.1f} us, {vm.energy_nj-e0:.0f} nJ DDR3]")

    print("\n=== AES xtime + GF(2^8) multiply (MixColumns core) ===")
    vm = PimVM(width=8, num_rows=96, words=8)
    state_col = rng.integers(0, 256, vm.lanes)
    coef = rng.integers(0, 256, vm.lanes)
    ra, rb = vm.load(state_col), vm.load(coef)
    x2 = gf.xtime(vm, ra)
    x3 = vm.alloc()
    vm.xor(x2, ra, x3)                              # x3 = xtime(a) ^ a = 3·a
    gm = gf.gf_mul(vm, ra, rb)
    assert np.array_equal(vm.read(x2), gf.ref_xtime(state_col))
    assert np.array_equal(
        vm.read(x3), gf.ref_xtime(state_col) ^ state_col.astype(np.uint64))
    assert np.array_equal(vm.read(gm), gf.ref_gf_mul(state_col, coef))
    print(f"xtime, 3x, full GF mul on {vm.lanes} lanes: OK  "
          f"(shifts used: {vm.counts()['n_shift']})")

    print("\n=== Reed-Solomon RS(n, k) parity, one codeword per lane ===")
    k, npar = 8, 4
    vm = PimVM(width=8, num_rows=120, words=4)
    msg = rng.integers(0, 256, size=(k, vm.lanes))
    regs = [vm.load(msg[i]) for i in range(k)]
    t0, e0 = vm.time_ns, vm.energy_nj
    parity = rs.rs_encode(vm, regs, npar)
    got = np.stack([vm.read(r) for r in parity])
    ref = rs.ref_rs_encode(msg, npar)
    assert np.array_equal(got, ref)
    cw = np.concatenate([msg.astype(np.uint64), ref[::-1]], axis=0)
    assert not rs.ref_rs_syndromes(cw, npar).any(), "syndromes nonzero!"
    cw[3, 0] ^= 0x11
    assert rs.ref_rs_syndromes(cw, npar).any(), "corruption undetected!"
    print(f"encoded {vm.lanes} codewords ({k} data + {npar} parity): OK; "
          f"syndromes zero; corruption detected")
    print(f"[{(vm.time_ns-t0)/1e3:.1f} us, {vm.energy_nj-e0:.0f} nJ DDR3 "
          f"model — zero bytes moved off-chip]")

    print("\n=== device level: the same RS encode, lanes sharded over "
          "8 banks (§5.1.4) ===")
    vm8 = PimVM(width=8, num_rows=120, words=32, n_banks=8)
    msg8 = rng.integers(0, 256, size=(k, vm8.lanes))
    regs8 = [vm8.load(msg8[i]) for i in range(k)]
    parity8 = rs.rs_encode(vm8, regs8, npar)
    got8 = np.stack([vm8.read(r) for r in parity8])
    assert np.array_equal(got8, rs.ref_rs_encode(msg8, npar))
    print(f"encoded {vm8.lanes} codewords across {vm8.n_banks} banks: OK")
    print(f"[wall {vm8.time_ns/1e3:.1f} us = bus + max over banks; "
          f"{vm8.energy_nj:.0f} nJ summed across banks]")


if __name__ == "__main__":
    main()
