"""Quickstart: the paper's in-DRAM shift on the JAX PIM runtime.

Shifts an 8KB row by one bit via the two migration-cell rows (4 AAP
commands), validates the result, and prints the DDR3-1333 timing/energy next
to the paper's NVMain numbers (Tables 2-3).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import pim


def main():
    rng = np.random.default_rng(0)
    row = jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))

    print("=== one full-row 1-bit right shift (4 AAPs, Fig. 3) ===")
    state = pim.reserve_control_rows(pim.make_subarray())
    state = pim.write_row(state, 0, row)
    e0, t0 = float(state.meter.total_energy_nj), float(state.meter.time_ns)
    state = pim.issue(state)
    state = pim.shift(state, src=0, dst=1, delta=+1)

    got = np.asarray(state.bits[1])
    expect = np.asarray(pim.shift_row_words(row, +1))
    assert np.array_equal(got, expect), "shift result mismatch!"
    print(f"shifted 65,536 bits: OK   "
          f"(mig_top captured even columns: "
          f"{bool((state.mig_top & pim.ODD_MASK).max() == 0)})")
    print(f"AAP commands: {int(state.meter.n_aap)}  "
          f"ACTIVATEs: {int(state.meter.n_act)}")
    print(f"latency : {float(state.meter.time_ns)-t0:8.1f} ns   "
          f"(paper: 208.7 ns)")
    print(f"energy  : {float(state.meter.total_energy_nj)-e0:8.2f} nJ   "
          f"(paper: 31.32 nJ)")

    print("\n=== Ambit + shift = functionally complete PIM ===")
    a = jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))
    s = pim.write_row(pim.write_row(state, 2, a), 3, b)
    s = pim.ambit_and(s, 2, 3, 4)
    s = pim.ambit_xor(s, 2, 3, 5)
    s = pim.shift(s, 5, 6, +1)
    assert np.array_equal(np.asarray(s.bits[4]), np.asarray(a & b))
    assert np.array_equal(np.asarray(s.bits[6]),
                          np.asarray(pim.shift_row_words(a ^ b, 1)))
    print("AND, XOR, then shift the XOR row: OK")

    print("\n=== the paper's Table 2/3 workloads ===")
    for n in (1, 50, 100, 512):
        st = pim.run_shift_workload(row, n)
        print(f"{n:4d} shifts: {float(st.meter.time_ns):10.1f} ns  "
              f"{float(st.meter.total_energy_nj):9.2f} nJ  "
              f"({float(st.meter.total_energy_nj)/n/8:4.2f} nJ/KB)")

    print("\n=== recorded program: IR -> cost pass -> compiled executor ===")
    b = pim.ProgramBuilder(num_rows=512, words=2048)
    b.reserve_control_rows()
    b.write_row(0, np.asarray(row))
    b.issue()
    b.shift_k(0, 1, 1000)
    prog = b.build()
    summ = pim.cost_summary(prog, refresh=True)
    print(f"recorded {len(prog)} commands; closed-form cost: "
          f"{summ['time_ns']:.1f} ns, {summ['energy_nj']:.1f} nJ")
    res = pim.execute(prog, refresh=True)
    print(f"compiled executor meter: {float(res.state.meter.time_ns):.1f} ns "
          f"(bit-exact vs the eager ISA; the 1000-shift chain runs as ONE "
          f"fused kernel shift)")
    trace = prog.to_trace()
    back = pim.PimProgram.from_trace(trace)
    print(f"trace round-trip: {len(trace.splitlines())} lines, "
          f"ops preserved: {back.ops == prog.ops}")


if __name__ == "__main__":
    main()
