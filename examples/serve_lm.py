"""Serve a small model with batched requests: prefill + jitted decode loop,
PIM-quantized (pim_w4) variant included.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import greedy_generate


def main():
    cfg = get_config("qwen3-4b")
    cfg = dataclasses.replace(
        cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=768, vocab_size=8_000, tie_embeddings=True, dtype="float32",
        remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, NEW = 4, 32, 24
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}

    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, prompts, max_new_tokens=NEW)
    dt = time.perf_counter() - t0
    print(f"batched greedy decode: batch={B} prompt={S} new={NEW}")
    print(f"tokens/s (incl. compile): {B*NEW/dt:.1f}")
    for i in range(B):
        print(f"  req{i}: {np.asarray(out[i])[:12]} ...")

    t0 = time.perf_counter()
    out2 = greedy_generate(cfg, params, prompts, max_new_tokens=NEW)
    print(f"tokens/s (warm): {B*NEW/(time.perf_counter()-t0):.1f}")
    assert jnp.array_equal(out, out2), "greedy decode must be deterministic"

    # The paper's technique in serving: bit-plane quantized linears.
    cfg_q = dataclasses.replace(cfg, quant="pim_w4", quant_mode="shift_add")
    params_q = init_params(cfg_q, jax.random.PRNGKey(0))
    out_q = greedy_generate(cfg_q, params_q, prompts, max_new_tokens=8)
    print(f"pim_w4 (shift-and-add bit planes) decode: {out_q.shape} OK")


if __name__ == "__main__":
    main()
