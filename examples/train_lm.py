"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing mid-run.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--preempt]
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import dataclasses

import numpy as np

from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train.loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    # defaults sized for the 1-core CPU container; on accelerators raise
    # --batch/--seq (the model and loop are the production ones)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M params: qwen3 family scaled down (12L, d=512, vocab 32k).
    cfg = get_config("qwen3-4b")
    cfg = dataclasses.replace(
        cfg, n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32_000, tie_embeddings=True, loss_chunk=128,
        dtype="float32", remat=False)
    n = cfg.n_params()
    print(f"arch={cfg.arch_id}-100m  params={n/1e6:.1f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    sched = lambda s: warmup_cosine(s, warmup_steps=20,
                                    total_steps=args.steps)
    params, hist = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        opt_cfg=AdamWConfig(lr=3e-3, weight_decay=0.01),
        schedule_fn=sched, ckpt_dir=ckpt_dir, ckpt_every=50)

    losses = hist["loss"]
    print(f"\nloss: first10={np.mean(losses[:10]):.4f}  "
          f"last10={np.mean(losses[-10:]):.4f}  "
          f"min={min(losses):.4f}")
    print(f"step time: {np.median(hist['step_time'])*1e3:.0f} ms median; "
          f"skipped={hist['skipped']} stragglers={hist['stragglers']} "
          f"retries={hist['retries']}")
    print(f"checkpoints in {ckpt_dir}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn!"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
