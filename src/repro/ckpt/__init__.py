from . import checkpoint
