"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json            (step, flat keys, shapes, dtypes, meta)
             host<P>.npz              (this host's addressable shard data)

Properties:
  * atomic    — written to step_<N>.tmp.<pid> then os.rename'd; a crash can
                never leave a half-valid checkpoint visible.
  * sharded   — each host saves only the addressable portion of every array
                (single-host saves everything); restore re-assembles and
                re-shards onto whatever mesh the restoring job uses, so the
                cluster may grow/shrink between runs (elastic scaling).
  * resumable — ``latest_step`` scans for the newest complete manifest;
                retention keeps the last K checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(SEP)] = tree
    return out


def _unflatten_into(skeleton, flat, prefix=""):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}{SEP}")
                for k, v in skeleton.items()}
    if isinstance(skeleton, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}{SEP}")
                for i, v in enumerate(skeleton)]
        return type(skeleton)(vals)
    if skeleton is None:
        return None
    return flat[prefix.rstrip(SEP)]


def save(ckpt_dir: str, step: int, tree, *, meta: dict | None = None,
         keep: int = 3):
    """Save a pytree checkpoint; atomic rename; retention of last ``keep``."""
    flat = _flatten(tree)
    proc = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)

    arrays = {}
    manifest = {"step": step, "meta": meta or {}, "keys": {}}
    for key, arr in flat.items():
        arr = jax.device_get(arr)
        np_arr = np.asarray(arr)
        manifest["keys"][key] = {"shape": list(np_arr.shape),
                                 "dtype": str(np_arr.dtype)}
        arrays[key.replace(SEP, "__")] = np_arr
    np.savez(os.path.join(tmp, f"host{proc}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(s for s in os.listdir(ckpt_dir)
                   if s.startswith("step_") and ".tmp" not in s)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, s), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for s in sorted(os.listdir(ckpt_dir)):
        if s.startswith("step_") and ".tmp" not in s:
            if os.path.exists(os.path.join(ckpt_dir, s, "manifest.json")):
                best = int(s.split("_")[1])
    return best


def restore(ckpt_dir: str, step: int, skeleton, *, shardings=None):
    """Load into ``skeleton``'s structure; re-shard with ``shardings`` (a
    matching pytree of jax.sharding.Sharding or None → default placement).
    The mesh used now may differ from the mesh at save time (elastic)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = {}
    for fn in os.listdir(path):
        if fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                for k in z.files:
                    data[k.replace("__", SEP)] = z[k]
    # npz round-trips ml_dtypes (bfloat16, ...) as raw void — reinterpret.
    import ml_dtypes
    for k, arr in data.items():
        want = manifest["keys"][k]["dtype"]
        if str(arr.dtype) != want:
            data[k] = arr.view(getattr(ml_dtypes, want, None)
                               or np.dtype(want))
    missing = set(manifest["keys"]) - set(data)
    if missing:
        raise FileNotFoundError(f"checkpoint incomplete, missing {missing}")

    flat_shardings = _flatten(shardings) if shardings is not None else {}

    def place(key, arr):
        sh = flat_shardings.get(key)
        if sh is not None:
            return jax.device_put(jnp.asarray(arr), sh)
        return jnp.asarray(arr)

    placed = {k: place(k, v) for k, v in data.items()}
    return _unflatten_into(skeleton, placed), manifest
