"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses
import importlib

from .base import MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig
from .shapes import SHAPES, ShapeSpec, applicable, skip_reason

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "yi-34b": "yi_34b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "musicgen-medium": "musicgen_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = list(_MODULES)


def _normalize(arch_id: str) -> str:
    a = arch_id.replace("_", "-").lower()
    if a in _MODULES:
        return a
    # allow module-style names (qwen2_5_32b) and dots
    for k, v in _MODULES.items():
        if a == v.replace("_", "-") or a.replace(".", "-") == k.replace(".", "-"):
            return k
    raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")


def get_config(arch_id: str, smoke: bool = False, **overrides) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{_MODULES[_normalize(arch_id)]}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = [
    "ARCH_IDS", "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig",
    "SHAPES", "SSMConfig", "ShapeSpec", "applicable", "get_config",
    "skip_reason",
]
