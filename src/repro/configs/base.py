"""Model configuration schema for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None     # V2-Lite projects q directly


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64                   # routed experts
    top_k: int = 6
    n_shared_experts: int = 0
    d_ff_expert: int = 1408
    first_k_dense: int = 0                # leading layers with dense FFN
    d_ff_dense: int = 0                   # dense d_ff for those layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    norm_topk_prob: bool = True
    dispatch_chunk: int = 4096            # tokens per dispatch-einsum chunk
    impl: str = "einsum"                  # einsum (GShard one-hot baseline)
    #                                       | gather (scatter/gather, §Perf)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block dims."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256
    extra_norms: bool = True              # falcon-mamba's RMSNorm on dt/B/C
    scan_chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma (Griffin) recurrent block dims."""
    lru_width: int = 2560
    d_conv: int = 4
    c_exponent: float = 8.0
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    scan_chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                           # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    pos_emb: str = "rope"                 # rope | sinusoidal
    attn_impl: str = "flash"              # flash (custom-vjp) | naive
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    sp_attn: bool = False                 # sequence-parallel attention (§Perf):
    #   replicate attn weights, shard activations on sequence over "model" —
    #   the fix for head counts not divisible by the model axis
    # ffn / norms
    act: str = "swiglu"                   # swiglu | gelu | geglu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    mlp_bias: bool = False
    tie_embeddings: bool = False
    final_logit_softcap: Optional[float] = None
    # sub-configs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # modality frontends (stubs per assignment)
    frontend: Optional[str] = None        # vision_patches | audio_frames
    n_patches: int = 576
    n_codebooks: int = 4
    # the paper's technique as a first-class feature
    quant: Optional[str] = None           # pim_w4 | pim_w8
    quant_mode: str = "shift_add"         # shift_add (paper) | dequant (opt)
    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512                 # sequence chunk for CE loss

    @property
    def attn_type(self) -> str:
        if self.mla is not None:
            return "mla"
        if self.family == "ssm":
            return "none"
        return "gqa"

    @property
    def quant_bits(self) -> int:
        return {"pim_w4": 4, "pim_w8": 8, None: 0}[self.quant]

    def _head_params(self) -> int:
        D, V = self.d_model, self.vocab_size
        if self.frontend == "audio_frames":      # n_codebooks output heads
            return V * D * (1 + self.n_codebooks)
        return V * D * (1 if self.tie_embeddings else 2)

    def n_params(self) -> int:
        """Total parameter count (analytic, for roofline MODEL_FLOPS)."""
        return self._head_params() + self._params_per_layer_all()

    def n_active_params(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        return self._head_params() \
            + self._params_per_layer_all(active_only=True)

    # -- internals ----------------------------------------------------------
    def _attn_params(self) -> int:
        D, dh = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            p = D * (m.kv_lora_rank + m.qk_rope_head_dim)          # kv down
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim)  # kv up
            p += D * self.n_heads * (m.qk_nope_head_dim
                                     + m.qk_rope_head_dim)         # q
            p += self.n_heads * m.v_head_dim * D                   # out
            return p
        return (D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh
                + self.n_heads * dh * D)

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _params_per_layer_all(self, active_only: bool = False) -> int:
        D, L = self.d_model, self.n_layers
        if self.family == "ssm":
            s = self.ssm
            di = s.expand * D
            per = (D * 2 * di + s.d_conv * di + di * (s.dt_rank + 2 * s.d_state)
                   + s.dt_rank * di + di * D + 2 * di * s.d_state)
            return L * per
        if self.rglru is not None:
            r = self.rglru
            w = r.lru_width
            rec = 2 * D * w + r.d_conv * w + 3 * w + w * D + 2 * w * w
            attn = self._attn_params()
            mlp = self._ffn_params(self.d_ff)
            n_attn = sum(1 for i in range(L)
                         if r.pattern[i % len(r.pattern)] == "attn")
            n_rec = L - n_attn
            return n_rec * (rec + mlp) + n_attn * (attn + mlp)
        attn = self._attn_params()
        if self.moe is not None:
            m = self.moe
            n_moe = L - m.first_k_dense
            k_eff = (m.top_k + m.n_shared_experts) if active_only \
                else (m.n_experts + m.n_shared_experts)
            moe_ffn = k_eff * self._ffn_params(m.d_ff_expert) \
                + self.d_model * m.n_experts                      # router
            dense_ffn = self._ffn_params(m.d_ff_dense or self.d_ff)
            return (m.first_k_dense * (attn + dense_ffn)
                    + n_moe * (attn + moe_ffn))
        return L * (attn + self._ffn_params(self.d_ff))
