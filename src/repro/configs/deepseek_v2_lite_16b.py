"""DeepSeek-V2-Lite 16B: 27L d=2048, MLA (kv_lora 512, rope 64), MoE 64
routed top-6 + 2 shared (d_ff 1408), first layer dense (d_ff 10944),
vocab 102400. [arXiv:2405.04434]

NB: the assignment line says "2 shared+160 routed"; 160 routed is the
DeepSeek-V2-236B figure — V2-Lite has 64 routed experts (paper Table 1 /
HF config). We follow the primary "MoE 64e top-6" spec; see DESIGN.md.
"""
import dataclasses
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10_944, vocab_size=102_400, rope_theta=10_000.0,
    act="swiglu", norm="rmsnorm",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10_944, norm_topk_prob=False),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, loss_chunk=32,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=48,
                  first_k_dense=1, d_ff_dense=128, dispatch_chunk=64,
                  norm_topk_prob=False, capacity_factor=4.0),
)
