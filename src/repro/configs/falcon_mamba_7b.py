"""Falcon-Mamba-7B: 64 pure Mamba-1 layers, d=4096, ssm_state=16, d_conv=4,
expand=2 (d_inner 8192), dt_rank 256, vocab 65024; extra RMSNorms on dt/B/C.
[arXiv:2410.05355; unverified]"""
import dataclasses
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65_024, act="swiglu", norm="rmsnorm",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256,
                  extra_norms=True, scan_chunk=128),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab_size=256, loss_chunk=32,
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2, dt_rank=8,
                  extra_norms=True, scan_chunk=16),
)
