"""LLaVA-NeXT (Mistral-7B backbone): 32L d=4096 32H(kv8) d_ff=14336
vocab 32000; anyres vision tiling is a STUB frontend — input_specs provides
precomputed patch embeddings at d_model (576 base-res patches).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Assumption (DESIGN.md): Mistral 4096-token sliding window retained (v0.1
lineage) — this is what qualifies the arch for the 500k decode cell.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=32_000, rope_theta=10_000.0,
    sliding_window=4096, act="swiglu", norm="rmsnorm",
    frontend="vision_patches", n_patches=576,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, sliding_window=16, n_patches=8, loss_chunk=32,
)
