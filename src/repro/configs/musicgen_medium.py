"""MusicGen-medium: 48L d=1536 24H(kv24, MHA) d_ff=6144 vocab 2048 (EnCodec
codebooks); decoder-only over audio tokens, sinusoidal positions, LayerNorm
+ GELU. The EnCodec frontend is a STUB — input_specs provides precomputed
frame embeddings; 4 codebook output heads. [arXiv:2306.05284]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048, pos_emb="sinusoidal", act="gelu",
    norm="layernorm", mlp_bias=True, qkv_bias=False,
    frontend="audio_frames", n_codebooks=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, n_codebooks=2, loss_chunk=32,
)
