"""Qwen2.5-32B: 64L d=5120 40H(kv8) d_ff=27648 vocab 152064, QKV bias.
[hf:Qwen/Qwen2.5-*]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27_648, vocab_size=152_064, rope_theta=1_000_000.0, qkv_bias=True,
    act="swiglu", norm="rmsnorm",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, loss_chunk=32,
)
