"""Qwen3-4B: 36L d=2560 32H(kv8) d_ff=9728 vocab 151936, qk_norm, tied
embeddings. [hf:Qwen/Qwen3-*]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151_936, rope_theta=1_000_000.0, qk_norm=True,
    tie_embeddings=True, act="swiglu", norm="rmsnorm",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, loss_chunk=32,
)
