"""Qwen3-30B-A3B: 48L d=2048 32H(kv4) MoE 128e top-8, d_ff_expert=768,
vocab 151936. [hf:Qwen/Qwen3-30B-A3B]"""
import dataclasses
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151_936, rope_theta=1_000_000.0, qk_norm=True,
    act="swiglu", norm="rmsnorm",
    moe=MoEConfig(n_experts=128, top_k=8, n_shared_experts=0,
                  d_ff_expert=768, norm_topk_prob=True),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, loss_chunk=32,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, dispatch_chunk=64,
                  capacity_factor=4.0),
)
