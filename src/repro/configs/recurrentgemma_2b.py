"""RecurrentGemma-2B (Griffin): 26L d=2560, RG-LRU + local attention 1:2
pattern (rec,rec,attn), 10H MQA(kv1) head_dim 256, window 2048, GeGLU
d_ff=7680, vocab 256000, tied embeddings, final logit softcap 30.
[arXiv:2402.19427]"""
import dataclasses
from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000, rope_theta=10_000.0,
    sliding_window=2048, act="geglu", norm="rmsnorm", tie_embeddings=True,
    final_logit_softcap=30.0,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, c_exponent=8.0,
                      pattern=("rec", "rec", "attn"), scan_chunk=256),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=256, sliding_window=16, loss_chunk=32,
    rglru=RGLRUConfig(lru_width=64, d_conv=4, pattern=("rec", "rec", "attn"),
                      scan_chunk=16),
)
