"""Assigned input shapes and the (arch × shape) applicability matrix.

Four shapes per arch (40 cells):
  train_4k     seq 4096,  global_batch 256  → train_step
  prefill_32k  seq 32768, global_batch 32   → prefill (inference)
  decode_32k   cache 32768, global_batch 128 → serve_step (1 new token)
  long_500k    cache 524288, global_batch 1  → serve_step; requires
               sub-quadratic attention state — runs only for SSM / hybrid /
               sliding-window archs, recorded as an explicit skip otherwise.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs with sub-quadratic (O(1) or windowed) decode state.
_SUBQUADRATIC = {
    "falcon-mamba-7b",          # O(1) SSM state
    "recurrentgemma-2b",        # RG-LRU state + 2k local window
    "starcoder2-7b",            # 4k sliding window
    "llava-next-mistral-7b",    # 4k sliding window (Mistral lineage)
}


def applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in _SUBQUADRATIC
    return True


def skip_reason(arch_id: str, shape_name: str) -> str | None:
    if applicable(arch_id, shape_name):
        return None
    return ("full attention: 500k decode requires sub-quadratic attention "
            "state (DESIGN.md §5)")
