"""StarCoder2-7B: 32L d=4608 36H(kv4) d_ff=18432 vocab 49152; LayerNorm,
GELU MLP, biases, RoPE, 4k sliding window. [arXiv:2402.19173]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18_432, vocab_size=49_152, rope_theta=100_000.0, qkv_bias=True,
    mlp_bias=True, sliding_window=4096, act="gelu", norm="layernorm",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, sliding_window=16, loss_chunk=32,
)
