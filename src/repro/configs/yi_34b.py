"""Yi-34B: 60L d=7168 56H(kv8) d_ff=20480 vocab 64000 (llama-arch GQA).
[arXiv:2403.04652]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20_480, vocab_size=64_000, rope_theta=5_000_000.0,
    act="swiglu", norm="rmsnorm",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, loss_chunk=32,
)
