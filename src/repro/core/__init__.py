"""Core: the paper's contribution — PIM shift runtime + bit-plane compute."""
