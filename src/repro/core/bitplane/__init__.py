"""Bit-parallel SIMD compute on horizontal data: the paper's app layer."""
from .vm import PimVM
from . import arith, gf, layout, rs

__all__ = ["PimVM", "arith", "gf", "layout", "rs"]
