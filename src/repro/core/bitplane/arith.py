"""In-DRAM SIMD arithmetic on horizontally-stored elements (paper §1, §8.0.1).

Every routine is a PIM program over {AAP, TRA, NOT, SHIFT} — the carry wires
of a conventional adder become the paper's migration-cell shifts. Each has a
numpy oracle (``ref_*``) used by the tests.

Cost intuition (w = element width):
  ripple-carry add : w-1 shift rounds          (the paper's §8.0.1 RCA)
  Kogge-Stone add  : log2(w) rounds, but round d needs a d-column shift
                     = d chained 1-bit migration shifts, so total shift ops
                     are ~w; the win is in fewer TRA/XOR levels (§8.0.1)
  shift-and-add mul: w partial products, each needing a bit-smear (the
                     paper's §1 motivating workload)
"""
from __future__ import annotations

import numpy as np

from .vm import PimVM


# ---------------------------------------------------------------------------
# Oracles
# ---------------------------------------------------------------------------

def ref_add(a: np.ndarray, b: np.ndarray, width: int) -> np.ndarray:
    mask = (1 << width) - 1
    return (a.astype(np.uint64) + b.astype(np.uint64)) & mask


def ref_mul(a: np.ndarray, b: np.ndarray, width: int) -> np.ndarray:
    mask = (1 << width) - 1
    return (a.astype(np.uint64) * b.astype(np.uint64)) & mask


# ---------------------------------------------------------------------------
# Adders
# ---------------------------------------------------------------------------

def add_ripple(vm: PimVM, a: int, b: int, dst: int | None = None) -> int:
    """Ripple-carry: S,C iteration with the carry moved by a 1-bit shift."""
    s = vm.xor(a, b)
    c = vm.and_(a, b)
    for _ in range(vm.width - 1):
        cs = vm.shift_elem(c, +1)          # carry wire = migration shift
        vm.and_(s, cs, c)                  # next carry (uses pre-update S)
        vm.xor(s, cs, s)
        vm.free(cs)
    vm.free(c)
    if dst is not None:
        vm.copy(s, dst)
        vm.free(s)
        return dst
    return s


def add_kogge_stone(vm: PimVM, a: int, b: int, dst: int | None = None) -> int:
    """Kogge-Stone parallel-prefix adder (paper §8.0.1 future-work item)."""
    g = vm.and_(a, b)
    p = vm.xor(a, b)
    s0 = vm.copy(p)                         # keep propagate for the final sum
    d = 1
    while d < vm.width:
        gs = vm.shift_elem(g, +d)
        ps = vm.shift_elem(p, +d)
        t = vm.and_(p, gs)
        vm.or_(g, t, g)
        vm.and_(p, ps, p)
        vm.free(gs, ps, t)
        d *= 2
    carries = vm.shift_elem(g, +1)          # carry INTO bit i = G at bit i-1
    out = vm.xor(s0, carries, dst)
    vm.free(g, p, s0, carries)
    return out


# ---------------------------------------------------------------------------
# Shift-and-add multiplication (mod 2^width)
# ---------------------------------------------------------------------------

def mul_shift_add(vm: PimVM, a: int, b: int, dst: int | None = None,
                  adder=add_ripple) -> int:
    """acc += (a << j) for every set bit j of b (bit smeared into a lane mask),
    i.e. exactly the paper's §1 'shift-and-add multiplication ... repeated
    shift operations to align partial products before the accumulation'."""
    acc = vm.zero()
    ashift = vm.copy(a)
    for j in range(vm.width):
        bj = vm.and_(b, vm.mask(1 << j))
        lane = vm.smear(bj)
        part = vm.and_(ashift, lane)
        nxt = adder(vm, acc, part)
        vm.free(acc, bj, lane, part)
        acc = nxt
        if j != vm.width - 1:
            vm.shift_elem(ashift, +1, ashift)
    vm.free(ashift)
    if dst is not None:
        vm.copy(acc, dst)
        vm.free(acc)
        return dst
    return acc
