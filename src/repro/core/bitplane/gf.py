"""Galois-field GF(2^8) arithmetic in-DRAM (paper §1, §8.0.2).

AES's field: GF(2^8) mod x^8 + x^4 + x^3 + x + 1 (0x11B). The primitive the
paper highlights: ``xtime`` (multiply by x) = one element-local shift plus a
conditional XOR with 0x1B — i.e. exactly {SHIFT, AND, XOR} on horizontal
data. Full GF multiply is 8 xtime/accumulate rounds (Russian peasant), and
``gf_mul_const`` (the Reed-Solomon workhorse) is a fixed xtime/XOR chain.

Oracles use numpy log/antilog tables.
"""
from __future__ import annotations

import numpy as np

from .vm import PimVM

AES_POLY = 0x11B       # x^8+x^4+x^3+x+1 (AES; NB: 0x02 is NOT primitive here)
RS_POLY = 0x11D        # x^8+x^4+x^3+x^2+1 (Reed-Solomon; 0x02 primitive)
REDUCE_PATTERN = 0x1B


# ---------------------------------------------------------------------------
# Oracles
# ---------------------------------------------------------------------------

def ref_xtime(a: np.ndarray, poly: int = AES_POLY) -> np.ndarray:
    a = np.asarray(a).astype(np.uint64)
    red = np.where(a & 0x80, np.uint64(poly & 0xFF), np.uint64(0))
    return ((a << np.uint64(1)) ^ red) & np.uint64(0xFF)


def ref_gf_mul(a: np.ndarray, b: np.ndarray,
               poly: int = AES_POLY) -> np.ndarray:
    a = np.asarray(a).astype(np.uint64).copy()
    b = np.asarray(b).astype(np.uint64).copy()
    acc = np.zeros_like(a)
    for _ in range(8):
        acc ^= np.where(b & np.uint64(1), a, np.uint64(0))
        b >>= np.uint64(1)
        a = ref_xtime(a, poly)
    return acc & np.uint64(0xFF)


# ---------------------------------------------------------------------------
# PIM programs (element width must be 8)
# ---------------------------------------------------------------------------

def xtime(vm: PimVM, a: int, dst: int | None = None,
          poly: int = AES_POLY) -> int:
    assert vm.width == 8, "GF(2^8) routines use byte lanes"
    msb = vm.and_(a, vm.mask(0x80))
    lane = vm.smear(msb)                    # lanes whose MSB was set
    red = vm.and_(lane, vm.mask(poly & 0xFF))
    t = vm.shift_elem(a, +1)                # (a << 1) & 0xFF per lane
    out = vm.xor(t, red, dst)
    vm.free(msb, lane, red, t)
    return out


def gf_mul(vm: PimVM, a: int, b: int, dst: int | None = None,
           poly: int = AES_POLY) -> int:
    """Lane-wise GF(2^8) multiply, 8 Russian-peasant rounds."""
    assert vm.width == 8
    acc = vm.zero()
    av = vm.copy(a)
    for j in range(8):
        bj = vm.and_(b, vm.mask(1 << j))
        lane = vm.smear(bj)
        part = vm.and_(av, lane)
        vm.xor(acc, part, acc)
        vm.free(bj, lane, part)
        if j != 7:
            xtime(vm, av, av, poly=poly)
    vm.free(av)
    if dst is not None:
        vm.copy(acc, dst)
        vm.free(acc)
        return dst
    return acc


def gf_mul_const(vm: PimVM, a: int, const: int,
                 dst: int | None = None, poly: int = AES_POLY) -> int:
    """Lane-wise multiply by a compile-time GF constant: fixed xtime chain."""
    assert vm.width == 8 and 0 <= const < 256
    acc = vm.zero()
    av = vm.copy(a)
    c = const
    j = 0
    while c:
        if c & 1:
            vm.xor(acc, av, acc)
        c >>= 1
        if c:
            xtime(vm, av, av, poly=poly)
        j += 1
    vm.free(av)
    if dst is not None:
        vm.copy(acc, dst)
        vm.free(acc)
        return dst
    return acc


def aes_xtime_cost(vm_words: int = 2048) -> dict:
    """Static cost of one full-row xtime (for the crypto case-study bench)."""
    vm = PimVM(width=8, num_rows=64, words=vm_words)
    a = vm.load(np.arange(vm.lanes) % 256)
    t0, e0 = vm.time_ns, vm.energy_nj
    xtime(vm, a)
    return {"time_ns": vm.time_ns - t0, "energy_nj": vm.energy_nj - e0,
            "bytes": vm.lanes}


# ---------------------------------------------------------------------------
# AES MixColumns — the paper's headline AES workload, fully in-DRAM
# ---------------------------------------------------------------------------

def ref_mixcolumns(state: np.ndarray) -> np.ndarray:
    """state: (..., 4) byte columns [a0..a3] → FIPS-197 MixColumns."""
    a = np.asarray(state).astype(np.uint64)
    a0, a1, a2, a3 = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    x = ref_xtime
    b0 = x(a0) ^ (x(a1) ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x(a1) ^ (x(a2) ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x(a2) ^ (x(a3) ^ a3)
    b3 = (x(a0) ^ a0) ^ a1 ^ a2 ^ x(a3)
    return np.stack([b0, b1, b2, b3], axis=-1)


def _rot_lane_up(vm: PimVM, a: int) -> int:
    """Rotate byte lanes left within each 4-lane column group:
    [a0,a1,a2,a3] → [a1,a2,a3,a0]. Lane movement = 8/24-column migration
    shifts + group-boundary masks (host-written once, cached via load)."""
    n_groups = vm.lanes // 4
    lane3 = vm.load(np.array([0, 0, 0, 255] * n_groups))
    not_lane3 = vm.load(np.array([255, 255, 255, 0] * n_groups))
    down = vm.shift_cols(a, -8)             # lane i ← lane i+1 (all lanes)
    wrap = vm.shift_cols(a, +24)            # lane 3 ← lane 0 of same group
    keep = vm.and_(down, not_lane3)
    edge = vm.and_(wrap, lane3)
    out = vm.or_(keep, edge)
    vm.free(lane3, not_lane3, down, wrap, keep, edge)
    return out


def mixcolumns(vm: PimVM, a: int, dst: int | None = None) -> int:
    """Lane-wise AES MixColumns: bytes laid out [a0,a1,a2,a3] per column
    group. b = 2·a ⊕ 3·rot1(a) ⊕ rot2(a) ⊕ rot3(a), all via {SHIFT, AND,
    OR, XOR} — zero transposition, matching the paper's §1/§8 pitch."""
    assert vm.width == 8 and vm.lanes % 4 == 0
    r1 = _rot_lane_up(vm, a)
    r2 = _rot_lane_up(vm, r1)
    r3 = _rot_lane_up(vm, r2)
    x2 = xtime(vm, a)
    x2r1 = xtime(vm, r1)
    acc = vm.xor(x2, x2r1)
    vm.xor(acc, r1, acc)                     # 3·a1 = 2·a1 ⊕ a1
    vm.xor(acc, r2, acc)
    vm.xor(acc, r3, acc)
    vm.free(r1, r2, r3, x2, x2r1)
    if dst is not None:
        vm.copy(acc, dst)
        vm.free(acc)
        return dst
    return acc
