"""Horizontal element layout for PIM bit-parallel arithmetic.

The paper's whole point: operands stay in the conventional *horizontal*
layout — a w-bit element occupies w consecutive bitlines. Element ``e`` of a
row lives at columns ``[e*w, (e+1)*w)``; column ``c`` is bit ``c % 32`` of
packed word ``c // 32`` (little-endian, matching ``pim.state``).

Masks (element-boundary control rows) are host-written once per width and
reused — their setup cost is charged through ``write_row`` like any data.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_elements(values: np.ndarray, width: int, words: int) -> jnp.ndarray:
    """Pack integer elements (< 2**width) into a (words,) uint32 row.

    Vectorized (bit-matrix + little-endian packbits): device-level runs pack
    multi-KB rows, where the old per-bit Python loop dominated wall time.
    """
    values = np.asarray(values, dtype=np.uint64)
    n = values.shape[0]
    assert n * width <= words * 32, "row overflow"
    bits = np.zeros(words * 32, dtype=np.uint8)
    shifts = np.arange(width, dtype=np.uint64)
    bits[:n * width] = ((values[:, None] >> shifts) & 1).reshape(-1)
    packed = np.packbits(bits, bitorder="little")
    return jnp.asarray(packed.view("<u4").astype(np.uint32))


def unpack_elements(row, width: int, count: int) -> np.ndarray:
    """Inverse of ``pack_elements``."""
    row = np.ascontiguousarray(np.asarray(row).astype("<u4"))
    bits = np.unpackbits(row.view(np.uint8), bitorder="little")
    assert count * width <= bits.size, "row underflow"
    mat = bits[:count * width].reshape(count, width).astype(np.uint64)
    return mat @ (np.uint64(1) << np.arange(width, dtype=np.uint64))


def _pattern_row(width: int, words: int, element_pattern: int) -> jnp.ndarray:
    """Tile a w-bit pattern across every element of the row."""
    n = (words * 32) // width
    vals = np.full(n, element_pattern, dtype=np.uint64)
    return pack_elements(vals, width, words)


def lsb_mask(width: int, words: int) -> jnp.ndarray:
    """Bit 0 of every element set."""
    return _pattern_row(width, words, 0b1)


def msb_mask(width: int, words: int) -> jnp.ndarray:
    """Bit w-1 of every element set."""
    return _pattern_row(width, words, 1 << (width - 1))


def interior_mask(width: int, words: int) -> jnp.ndarray:
    """All bits except bit 0 of each element (where shifted-in carries from a
    neighboring element would land after a +1 column shift)."""
    return _pattern_row(width, words, ((1 << width) - 1) & ~1)


def full_mask(width: int, words: int) -> jnp.ndarray:
    return _pattern_row(width, words, (1 << width) - 1)


def const_row(width: int, words: int, value: int) -> jnp.ndarray:
    """Every element = value (e.g. the GF(2^8) reduction pattern 0x1B)."""
    return _pattern_row(width, words, value)
