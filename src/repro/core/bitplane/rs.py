"""Reed-Solomon systematic encoding in-DRAM (paper §1, §8.0.2).

SIMD layout: one *codeword per byte lane*, message symbols streamed across
*rows* (row i holds symbol i of every lane's message). The LFSR encoder state
is ``n_parity`` parity rows; each message row advances the LFSR with one
lane-wise GF(2^8) constant multiply per generator coefficient — all of it
{SHIFT, AND, XOR} PIM programs from ``gf.py``.

Oracle: plain numpy GF(256) polynomial-division encoder + syndrome check.
"""
from __future__ import annotations

import numpy as np

from .vm import PimVM
from . import gf

# --- GF(256) tables for the oracle -----------------------------------------
_EXP = np.zeros(512, dtype=np.uint64)
_LOG = np.zeros(256, dtype=np.uint64)
_x = 1
for _i in range(255):
    _EXP[_i] = _x
    _LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= gf.RS_POLY
_EXP[255:510] = _EXP[:255]


def _gf_mul_scalar(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(_EXP[(int(_LOG[a]) + int(_LOG[b])) % 255])


def generator_poly(n_parity: int) -> list[int]:
    """g(x) = prod_{i=0}^{n_parity-1} (x - alpha^i); returns coeffs low→high,
    excluding the leading (monic) term."""
    g = [1]
    for i in range(n_parity):
        alpha_i = int(_EXP[i])
        nxt = [0] * (len(g) + 1)
        for j, c in enumerate(g):
            nxt[j + 1] ^= c
            nxt[j] ^= _gf_mul_scalar(c, alpha_i)
        g = nxt
    return g[:-1]


def ref_rs_encode(msg: np.ndarray, n_parity: int) -> np.ndarray:
    """msg: (k, lanes) symbols. Returns (n_parity, lanes) parity symbols."""
    gcoef = generator_poly(n_parity)
    k, lanes = msg.shape
    parity = np.zeros((n_parity, lanes), dtype=np.uint64)
    for i in range(k):
        fb = (msg[i].astype(np.uint64) ^ parity[-1]) & 0xFF
        shifted = np.zeros_like(parity)
        shifted[1:] = parity[:-1]
        for j in range(n_parity):
            mul = np.array([_gf_mul_scalar(int(f), gcoef[j]) for f in fb],
                           dtype=np.uint64)
            shifted[j] ^= mul
        parity = shifted
    return parity


def ref_rs_syndromes(codeword: np.ndarray, n_parity: int) -> np.ndarray:
    """codeword: (n, lanes), highest-degree symbol first. All-zero iff valid."""
    codeword = np.asarray(codeword).astype(np.uint64)
    n, lanes = codeword.shape
    out = np.zeros((n_parity, lanes), dtype=np.uint64)
    for i in range(n_parity):
        alpha_i = int(_EXP[i])
        acc = np.zeros(lanes, dtype=np.uint64)
        for sym in codeword:
            acc = np.array([_gf_mul_scalar(int(a), alpha_i) for a in acc],
                           dtype=np.uint64) ^ sym
        out[i] = acc
    return out


def rs_syndromes(vm: PimVM, cw_rows: list[int], n_parity: int) -> list[int]:
    """In-DRAM syndrome evaluation: s_i = c(alpha^i), Horner over the
    codeword rows (highest-degree symbol first, matching
    ``ref_rs_syndromes``). Returns ``n_parity`` syndrome registers — all
    zero iff every lane's codeword is valid, so the XOR of syndrome rows
    across shards is a device-level integrity checksum."""
    assert vm.width == 8
    out = []
    for i in range(n_parity):
        alpha_i = int(_EXP[i])
        acc = vm.zero()
        for r in cw_rows:
            if alpha_i != 1:
                gf.gf_mul_const(vm, acc, alpha_i, acc, poly=gf.RS_POLY)
            vm.xor(acc, r, acc)
        out.append(acc)
    return out


def rs_encode(vm: PimVM, msg_rows: list[int], n_parity: int) -> list[int]:
    """In-DRAM LFSR encode. ``msg_rows``: registers holding symbol i of every
    lane (highest-degree first). Returns ``n_parity`` parity registers
    (parity[-1] = highest-degree parity symbol)."""
    assert vm.width == 8
    gcoef = generator_poly(n_parity)
    parity = [vm.zero() for _ in range(n_parity)]
    for r in msg_rows:
        fb = vm.xor(r, parity[-1])
        new_parity = []
        for j in range(n_parity):
            term = gf.gf_mul_const(vm, fb, gcoef[j], poly=gf.RS_POLY)
            if j > 0:
                vm.xor(term, parity[j - 1], term)
            new_parity.append(term)
        vm.free(fb, *parity)
        parity = new_parity
    return parity
