"""A row-register virtual machine over one PIM subarray.

Thin convenience layer: registers are row indices and every method records
one or a few IR commands into a :class:`~..pim.ir.ProgramBuilder`. The
recorded stream is flushed through the compiling executor
(``pim/compile.py`` + ``pim/exec.py``) whenever a host-visible value is
needed (``read``/accounting) — so long op sequences run kernel-fused with a
one-fold cost pass instead of one Python-level pytree transition per
command, while staying bit- and meter-exact against the old eager path.

Element width ``w`` fixes the horizontal layout; mask/constant rows are
host-written once per pattern and cached (setup cost is charged via
``write_row`` like any other host traffic, and reported separately by
``setup_energy_nj``). ``PimVM(..., eager=True)`` keeps the old
command-at-a-time execution via the ``isa`` shim.

``PimVM(..., n_banks=N)`` shards the row's lanes across N device banks
(§5.1.4): every method records the SAME command stream, but host payloads
(loads, masks) are split lane-wise so bank ``b`` operates on lanes
``[b*L/N, (b+1)*L/N)``. Flushes run through the device scheduler
(``pim.schedule``) as ONE compiled runner vmapped over the banks;
``time_ns`` is then the device wall clock (per-channel bus serialization +
max over banks) and ``energy_nj`` the sum — the lanes-sharded results are
bit-exact against the same VM program on a single ``n_banks * words``-wide
subarray. ``async_host=True`` additionally lets each flush's HOSTW/HOSTR
bursts overlap the previous flush's compute (the scheduler's async host
engine, DESIGN.md §9); batch reads with ``read_many`` so a pipeline step
stays one flush.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..pim import isa
from ..pim import exec as pim_exec
from ..pim.device import DeviceConfig, make_device
from ..pim.ir import PimProgram, ProgramBuilder
from ..pim.schedule import (Phase, compiled_for, schedule, schedule_pipeline,
                            schedule_workload)
from ..pim.state import SubarrayState, make_subarray
from ..pim.timing import DDR3Timing, DEFAULT_TIMING
from . import layout


class PimVM:
    RESERVED_TAIL = 8  # C0/C1/T0..T3 + margin

    def __init__(self, width: int, num_rows: int = 128, words: int = 16,
                 cfg: DDR3Timing = DEFAULT_TIMING, eager: bool = False,
                 n_banks: int = 1, async_host: bool = False,
                 verify: bool = False):
        assert (words * 32) % width == 0
        assert words % n_banks == 0, (words, n_banks)
        assert not (async_host and n_banks == 1), \
            "async_host rides the device scheduler; use n_banks > 1"
        self.width = width
        self.words = words
        self.cfg = cfg
        self.eager = eager
        self.n_banks = n_banks
        self.async_host = async_host
        self.verify = bool(verify)
        self.lanes = (words * 32) // width
        self._num_rows = num_rows
        self._reads: tuple = ()
        self._free = list(range(num_rows - self.RESERVED_TAIL - 1, -1, -1))
        self._mask_rows: dict[int, int] = {}
        self._setup_energy_marker = 0.0
        if n_banks == 1:
            st = make_subarray(num_rows, words)
            self.state: SubarrayState = isa.reserve_control_rows(st)
            self._builder = ProgramBuilder(num_rows, words,
                                           verify=self.verify)
        else:
            assert not eager, "lane sharding needs the recorded-IR path"
            self.bank_words = words // n_banks
            assert (self.bank_words * 32) % width == 0, \
                "element width must tile the per-bank word slice"
            self.bank_lanes = (self.bank_words * 32) // width
            self._builder = ProgramBuilder(num_rows, self.bank_words,
                                           verify=self.verify)
            self._bank_payloads: list[list[np.ndarray]] = []
            self._read_result = None
            self._device = make_device(DeviceConfig(
                channels=1, ranks=1, banks_per_rank=n_banks,
                num_rows=num_rows, words=self.bank_words, timing=cfg))
            self._wall_ns = 0.0
            self._host_overlap_ns = 0.0

    # -- recording / flushing --------------------------------------------------
    def _op(self, name: str, *args) -> None:
        """Dispatch one ISA-surface call: eager shim or IR recording.
        ProgramBuilder mirrors isa minus the threaded state/cfg, so the
        same name and operand order serve both paths."""
        if self.eager:
            self.state = getattr(isa, name)(self.state, *args, self.cfg)
        else:
            getattr(self._builder, name)(*args)

    def _write_sharded(self, reg: int, full_row: np.ndarray) -> None:
        """Record one HOSTW whose payload differs per bank: the recorded op
        (and slot index) is shared, the data is the bank's word slice."""
        w = self.bank_words
        # copies, not views: recorded payloads must never alias caller data
        slices = [np.array(full_row[b * w:(b + 1) * w], dtype=np.uint32,
                           copy=True)
                  for b in range(self.n_banks)]
        self._builder.write_row(reg, slices[0])
        self._bank_payloads.append(slices)

    def _flush(self) -> None:
        """Execute the pending recorded stream against the current state."""
        if len(self._builder) == 0:
            return
        if self.n_banks == 1:
            res = pim_exec.execute(self._builder.build(), self.state, self.cfg)
            self.state = res.state
            self._reads = res.reads
            self._builder = ProgramBuilder(self._num_rows, self.words,
                                           verify=self.verify)
            return
        prog = self._builder.build()
        programs = [
            prog.with_payloads(rows[b] for rows in self._bank_payloads)
            for b in range(self.n_banks)]
        res = schedule(self._device, programs, async_host=self.async_host)
        self._device = res.state
        self._read_result = res            # reads unbatch lazily on access
        # lazy accumulation: no blocking device sync per flush — the
        # accounting properties convert on access
        self._wall_ns = self._wall_ns + res.wall_ns
        self._host_overlap_ns = (self._host_overlap_ns
                                 + res.host_overlap_ns_lazy)
        self._builder = ProgramBuilder(self._num_rows, self.bank_words,
                                       verify=self.verify)
        self._bank_payloads = []

    def take_recorded(self) -> PimProgram:
        """Hand the pending recorded stream over WITHOUT executing it.

        Device-composition hook: build a per-bank workload with the full VM
        vocabulary (loads, masks, GF ops...), then schedule the recorded
        program on a device slot (``pim.schedule``) instead of flushing it
        against this VM's private state. Only meaningful before any flush —
        a host-visible access (``read``/accounting) would have consumed the
        stream — and only in single-bank mode (sharded VMs split payloads
        per bank at flush time). Resets the recorder.
        """
        assert self.n_banks == 1, "take_recorded needs a single-bank VM"
        prog = self._builder.build()
        self._builder = ProgramBuilder(self._num_rows, self.words,
                                       verify=self.verify)
        return prog

    def run_pipeline(self, step, xs) -> list:
        """Execute ``step`` once per element of ``xs`` as ONE scanned
        dispatch (steady-state: one XLA scan iteration per step, no Python
        round-trip).

        ``step(vm, x)`` records one pipeline step through the normal VM
        vocabulary (``load``/``xor``/``shift_elem``/...) and returns the
        register (or sequence of registers) to read back; it must record
        the SAME command stream for every ``x`` (guaranteed when it only
        depends on shapes — HOSTW payload *data* may differ freely) and
        must not call ``read``/accounting mid-step (those flush). The
        allocator and mask cache are rewound to their pre-pipeline state
        before EVERY recording (that is what makes the streams recur), so
        a mask created inside ``step`` is host-written in every step —
        pre-create hot masks with ``vm.mask(...)`` before the pipeline to
        charge them once. Single-
        bank VMs run the K steps under ``exec.make_pipeline_runner``'s
        ``lax.scan``; lane-sharded VMs ride ``schedule_pipeline`` on the
        device (honoring ``async_host``). Returns one entry per step: the
        unpacked value of each returned register (a list when ``step``
        returns a sequence).
        """
        assert not self.eager, "run_pipeline needs the recorded-IR path"
        xs = list(xs)
        assert xs, "need at least one pipeline step"
        self._flush()                   # pending ops run before the pipeline
        free0, masks0 = list(self._free), dict(self._mask_rows)
        progs, bank_payloads = [], []
        read_slots, single = None, False
        for x in xs:
            self._free, self._mask_rows = list(free0), dict(masks0)
            out = step(self, x)
            regs = list(out) if isinstance(out, (list, tuple)) else [out]
            slots = [self._builder.read_row(r) for r in regs]
            progs.append(self._builder.build())
            if self.n_banks == 1:
                self._builder = ProgramBuilder(self._num_rows, self.words,
                                               verify=self.verify)
            else:
                bank_payloads.append(self._bank_payloads)
                self._bank_payloads = []
                self._builder = ProgramBuilder(self._num_rows,
                                               self.bank_words,
                                               verify=self.verify)
            if read_slots is None:
                read_slots = slots
                single = not isinstance(out, (list, tuple))
        # Registers allocated inside `step` are transient: their values come
        # back as host reads, so the allocator (and mask cache) return to
        # the pre-pipeline state — repeated run_pipeline calls record the
        # SAME rows and stay warm in every cache.
        self._free, self._mask_rows = list(free0), dict(masks0)
        key0 = (progs[0].digest, len(progs[0].payloads))
        for k, p in enumerate(progs[1:], 1):
            if (p.digest, len(p.payloads)) != key0:
                raise ValueError(
                    f"pipeline step {k} recorded a different command "
                    "stream than step 0; run_pipeline replays ONE "
                    "recurring step, so the step function must be "
                    "shape-deterministic")
        K = len(progs)
        if self.n_banks == 1:
            compiled = compiled_for(progs[0], self.cfg)
            pipe = pim_exec.make_pipeline_runner(compiled, self.cfg)
            if progs[0].payloads:
                payload_steps = jnp.asarray(np.stack(
                    [np.stack(p.payloads) for p in progs]
                ).astype(np.uint32))
            else:
                payload_steps = jnp.zeros((K, 0, self.words), jnp.uint32)
            self.state, reads_steps = pipe(self.state, payload_steps)

            def row(k, slot):
                return reads_steps[slot][k]
        else:
            steps = [[prog.with_payloads(rows[b] for rows in pays)
                      for b in range(self.n_banks)]
                     for prog, pays in zip(progs, bank_payloads)]
            res = schedule_pipeline(self._device, steps,
                                    async_host=self.async_host)
            self._device = res.state
            self._wall_ns = self._wall_ns + jnp.sum(res.wall_ns)
            self._host_overlap_ns = (
                self._host_overlap_ns
                + jnp.sum(jnp.asarray(res.host_overlap_ns_lazy)))
            per_step = res.reads          # [k][bank] -> per-read rows

            def row(k, slot):
                return np.concatenate(
                    [np.asarray(per_step[k][b][slot])
                     for b in range(self.n_banks)])
        out = []
        for k in range(K):
            vals = [layout.unpack_elements(np.asarray(row(k, s)),
                                           self.width, self.lanes)
                    for s in read_slots]
            out.append(vals[0] if single else vals)
        return out

    def run_workload(self, phases) -> list:
        """Execute a HETEROGENEOUS multi-phase workload as ONE dispatch.

        ``phases`` is a sequence of ``(step, xs)`` pairs: each phase is a
        ``run_pipeline``-style recurring step function replayed once per
        element of its ``xs``. The recurring contract applies WITHIN a
        phase — phases may record arbitrarily different streams from each
        other (compute, then gather, then readback...). The allocator and
        mask cache rewind before every recording exactly as in
        ``run_pipeline``, so registers that must survive a phase boundary
        (e.g. accumulators a later phase reduces) must be allocated BEFORE
        the call. Single-bank VMs run all phases under
        ``exec.make_workload_runner``'s chained scans; lane-sharded VMs
        ride ``schedule_workload`` on the device (honoring
        ``async_host``). Returns one ``run_pipeline``-shaped result list
        per phase.
        """
        assert not self.eager, "run_workload needs the recorded-IR path"
        phase_list = [(step, list(xs)) for step, xs in phases]
        assert phase_list, "need at least one phase"
        self._flush()                   # pending ops run before the workload
        free0, masks0 = list(self._free), dict(self._mask_rows)
        ph_progs, ph_slots, ph_single = [], [], []
        for p, (step, xs) in enumerate(phase_list):
            assert xs, f"workload phase {p} needs at least one step"
            progs, bank_payloads = [], []
            read_slots, single = None, False
            for x in xs:
                self._free, self._mask_rows = list(free0), dict(masks0)
                out = step(self, x)
                regs = (list(out) if isinstance(out, (list, tuple))
                        else [out])
                slots = [self._builder.read_row(r) for r in regs]
                progs.append(self._builder.build())
                if self.n_banks == 1:
                    self._builder = ProgramBuilder(self._num_rows,
                                                   self.words,
                                                   verify=self.verify)
                else:
                    bank_payloads.append(self._bank_payloads)
                    self._bank_payloads = []
                    self._builder = ProgramBuilder(self._num_rows,
                                                   self.bank_words,
                                                   verify=self.verify)
                if read_slots is None:
                    read_slots = slots
                    single = not isinstance(out, (list, tuple))
            key0 = (progs[0].digest, len(progs[0].payloads))
            for k, q in enumerate(progs[1:], 1):
                if (q.digest, len(q.payloads)) != key0:
                    raise ValueError(
                        f"workload phase {p} step {k} recorded a different "
                        "command stream than the phase's step 0; each "
                        "phase replays ONE recurring step — split "
                        "shape-divergent steps into separate phases")
            ph_progs.append((progs, bank_payloads))
            ph_slots.append(read_slots)
            ph_single.append(single)
        self._free, self._mask_rows = list(free0), dict(masks0)
        if self.n_banks == 1:
            runner = pim_exec.make_workload_runner(
                [compiled_for(progs[0], self.cfg) for progs, _ in ph_progs],
                self.cfg)
            payload_phases = tuple(
                jnp.asarray(np.stack(
                    [np.stack(q.payloads) for q in progs]).astype(np.uint32))
                if progs[0].payloads
                else jnp.zeros((len(progs), 0, self.words), jnp.uint32)
                for progs, _ in ph_progs)
            self.state, reads_phases = runner(self.state, payload_phases)

            def row(p, k, slot):
                return reads_phases[p][slot][k]
        else:
            wl = []
            for progs, pays_steps in ph_progs:
                wl.append(Phase(steps=tuple(
                    [prog.with_payloads(rows[b] for rows in pays)
                     for b in range(self.n_banks)]
                    for prog, pays in zip(progs, pays_steps))))
            res = schedule_workload(self._device, wl,
                                    async_host=self.async_host)
            self._device = res.state
            self._wall_ns = self._wall_ns + sum(
                jnp.sum(pr.wall_ns) for pr in res.phases)
            self._host_overlap_ns = (self._host_overlap_ns + sum(
                jnp.sum(jnp.asarray(pr.host_overlap_ns_lazy))
                for pr in res.phases))
            per_phase = [pr.reads for pr in res.phases]

            def row(p, k, slot):
                return np.concatenate(
                    [np.asarray(per_phase[p][k][b][slot])
                     for b in range(self.n_banks)])
        out_phases = []
        for p, (progs, _) in enumerate(ph_progs):
            outs = []
            for k in range(len(progs)):
                vals = [layout.unpack_elements(np.asarray(row(p, k, s)),
                                               self.width, self.lanes)
                        for s in ph_slots[p]]
                outs.append(vals[0] if ph_single[p] else vals)
            out_phases.append(outs)
        return out_phases

    # -- register management -------------------------------------------------
    def alloc(self) -> int:
        return self._free.pop()

    def free(self, *regs: int) -> None:
        self._free.extend(regs)

    # -- host I/O -------------------------------------------------------------
    def _host_write(self, reg: int, full_row: np.ndarray) -> None:
        if self.n_banks == 1:
            self._op("write_row", reg, full_row)
        else:
            self._write_sharded(reg, full_row)

    def load(self, values, reg: int | None = None) -> int:
        reg = self.alloc() if reg is None else reg
        row = layout.pack_elements(np.asarray(values), self.width, self.words)
        self._host_write(reg, np.asarray(row))
        return reg

    def read(self, reg: int) -> np.ndarray:
        if self.eager:
            self.state, row = isa.read_row(self.state, reg, self.cfg)
            return layout.unpack_elements(row, self.width, self.lanes)
        return self.read_many([reg])[0]

    def read_many(self, regs) -> list[np.ndarray]:
        """Read several registers with ONE flush. A per-``read`` flush
        splits the stream into many schedule steps whose trailing read-only
        steps carry no compute — which starves the async host engine's
        double buffer; batching keeps each pipeline step one flush."""
        if self.eager:
            return [self.read(r) for r in regs]
        slots = [self._builder.read_row(r) for r in regs]
        self._flush()
        if not slots:
            return []           # pending ops flushed; nothing to unbatch
        reads = (self._reads if self.n_banks == 1
                 else self._read_result.reads)
        out = []
        for slot in slots:
            if self.n_banks == 1:
                row = reads[slot]
            else:
                row = np.concatenate(
                    [np.asarray(reads[b][slot])
                     for b in range(self.n_banks)])
            out.append(layout.unpack_elements(row, self.width, self.lanes))
        return out

    def mask(self, element_pattern: int) -> int:
        """Row with ``element_pattern`` tiled into every element (cached)."""
        if element_pattern not in self._mask_rows:
            reg = self.alloc()
            row = layout.const_row(self.width, self.words, element_pattern)
            self._host_write(reg, np.asarray(row))
            self._mask_rows[element_pattern] = reg
        return self._mask_rows[element_pattern]

    # -- ISA ops (dst allocated when omitted; returns dst) --------------------
    def copy(self, a: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("rowclone", a, dst)
        return dst

    def and_(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("ambit_and", a, b, dst)
        return dst

    def or_(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("ambit_or", a, b, dst)
        return dst

    def xor(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("ambit_xor", a, b, dst)
        return dst

    def not_(self, a: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("ambit_not", a, dst)
        return dst

    def maj(self, a: int, b: int, c: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("ambit_maj", a, b, c, dst)
        return dst

    def zero(self, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self._op("rowclone", isa.C0, dst)
        return dst

    def shift_cols(self, a: int, k: int, dst: int | None = None) -> int:
        """Shift |k| columns via |k| migration-cell shifts (no masking)."""
        dst = self.alloc() if dst is None else dst
        if self.eager:
            if k == 0:
                self.state = isa.rowclone(self.state, a, dst, self.cfg)
                return dst
            delta = 1 if k > 0 else -1
            self.state = isa.shift(self.state, a, dst, delta, self.cfg)
            for _ in range(abs(k) - 1):
                self.state = isa.shift(self.state, dst, dst, delta, self.cfg)
        else:
            self._builder.shift_k(a, dst, k)
        return dst

    def shift_elem(self, a: int, k: int, dst: int | None = None) -> int:
        """Element-local shift: column shift + boundary mask (crossing bits
        dropped). k > 0 shifts toward the element MSB (i.e. ``x << k``)."""
        dst = self.shift_cols(a, k, dst)
        if k == 0:
            return dst
        w = self.width
        if k > 0:
            pattern = ((1 << w) - 1) & ~((1 << min(k, w)) - 1)
        else:
            pattern = ((1 << w) - 1) >> min(-k, w)
        return self.and_(dst, self.mask(pattern), dst)

    # -- derived --------------------------------------------------------------
    def smear(self, a: int, dst: int | None = None) -> int:
        """OR-spread any set bit of each element across the whole element
        (log2(w) doubling rounds in each direction)."""
        dst = self.copy(a, dst)
        s = 1
        while s < self.width:
            up = self.shift_elem(dst, +s)
            self.or_(dst, up, dst)
            self.free(up)
            s *= 2
        s = 1
        while s < self.width:
            dn = self.shift_elem(dst, -s)
            self.or_(dst, dn, dst)
            self.free(dn)
            s *= 2
        return dst

    # -- accounting -----------------------------------------------------------
    @property
    def time_ns(self) -> float:
        """Single bank: the subarray meter. Sharded: the device wall clock
        (bus serialization + max over banks) accumulated across flushes."""
        self._flush()
        if self.n_banks == 1:
            return float(self.state.meter.time_ns)
        return float(self._wall_ns)

    @property
    def energy_nj(self) -> float:
        self._flush()
        if self.n_banks == 1:
            return float(self.state.meter.total_energy_nj)
        return float(jnp.sum(self._device.banks.meter.total_energy_nj))

    @property
    def host_overlap_ns(self) -> float:
        """Host-transfer time hidden under compute by the async engine
        (sharded VMs with ``async_host=True``), accumulated across flushes."""
        self._flush()
        return 0.0 if self.n_banks == 1 else float(self._host_overlap_ns)

    @property
    def setup_energy_nj(self) -> float:
        self._flush()
        if self.n_banks == 1:
            return float(self.state.meter.e_burst)
        return float(jnp.sum(self._device.banks.meter.e_burst))

    def counts(self) -> dict:
        self._flush()
        keys = ("n_act", "n_pre", "n_aap", "n_shift", "n_tra")
        if self.n_banks == 1:
            m = self.state.meter
            return {k: int(getattr(m, k)) for k in keys}
        m = self._device.banks.meter
        return {k: int(jnp.sum(getattr(m, k))) for k in keys}
