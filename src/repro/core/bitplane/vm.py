"""A row-register virtual machine over one PIM subarray.

Thin convenience layer: registers are row indices, every method is one or a
few ISA commands, and the DDR3 cost meter advances underneath. Programs are
built eagerly in Python (this is the *programming model* layer; the Pallas
``kernels/rowops`` path is the performance path for bulk execution).

Element width ``w`` fixes the horizontal layout; mask/constant rows are
host-written once per pattern and cached (setup cost is charged via
``write_row`` like any other host traffic, and reported separately by
``setup_energy_nj``).
"""
from __future__ import annotations

import numpy as np

from ..pim import isa
from ..pim.state import SubarrayState, make_subarray
from ..pim.timing import DDR3Timing, DEFAULT_TIMING
from . import layout


class PimVM:
    RESERVED_TAIL = 8  # C0/C1/T0..T3 + margin

    def __init__(self, width: int, num_rows: int = 128, words: int = 16,
                 cfg: DDR3Timing = DEFAULT_TIMING):
        assert (words * 32) % width == 0
        self.width = width
        self.words = words
        self.cfg = cfg
        self.lanes = (words * 32) // width
        st = make_subarray(num_rows, words)
        self.state: SubarrayState = isa.reserve_control_rows(st)
        self._free = list(range(num_rows - self.RESERVED_TAIL - 1, -1, -1))
        self._mask_rows: dict[int, int] = {}
        self._setup_energy_marker = 0.0

    # -- register management -------------------------------------------------
    def alloc(self) -> int:
        return self._free.pop()

    def free(self, *regs: int) -> None:
        self._free.extend(regs)

    # -- host I/O -------------------------------------------------------------
    def load(self, values, reg: int | None = None) -> int:
        reg = self.alloc() if reg is None else reg
        row = layout.pack_elements(np.asarray(values), self.width, self.words)
        self.state = isa.write_row(self.state, reg, row, self.cfg)
        return reg

    def read(self, reg: int) -> np.ndarray:
        self.state, row = isa.read_row(self.state, reg, self.cfg)
        return layout.unpack_elements(row, self.width, self.lanes)

    def mask(self, element_pattern: int) -> int:
        """Row with ``element_pattern`` tiled into every element (cached)."""
        if element_pattern not in self._mask_rows:
            reg = self.alloc()
            row = layout.const_row(self.width, self.words, element_pattern)
            self.state = isa.write_row(self.state, reg, row, self.cfg)
            self._mask_rows[element_pattern] = reg
        return self._mask_rows[element_pattern]

    # -- ISA ops (dst allocated when omitted; returns dst) --------------------
    def copy(self, a: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.rowclone(self.state, a, dst, self.cfg)
        return dst

    def and_(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.ambit_and(self.state, a, b, dst, self.cfg)
        return dst

    def or_(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.ambit_or(self.state, a, b, dst, self.cfg)
        return dst

    def xor(self, a: int, b: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.ambit_xor(self.state, a, b, dst, self.cfg)
        return dst

    def not_(self, a: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.ambit_not(self.state, a, dst, self.cfg)
        return dst

    def maj(self, a: int, b: int, c: int, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.ambit_maj(self.state, a, b, c, dst, self.cfg)
        return dst

    def zero(self, dst: int | None = None) -> int:
        dst = self.alloc() if dst is None else dst
        self.state = isa.rowclone(self.state, isa.C0, dst, self.cfg)
        return dst

    def shift_cols(self, a: int, k: int, dst: int | None = None) -> int:
        """Shift |k| columns via |k| migration-cell shifts (no masking)."""
        dst = self.alloc() if dst is None else dst
        if k == 0:
            self.state = isa.rowclone(self.state, a, dst, self.cfg)
            return dst
        delta = 1 if k > 0 else -1
        self.state = isa.shift(self.state, a, dst, delta, self.cfg)
        for _ in range(abs(k) - 1):
            self.state = isa.shift(self.state, dst, dst, delta, self.cfg)
        return dst

    def shift_elem(self, a: int, k: int, dst: int | None = None) -> int:
        """Element-local shift: column shift + boundary mask (crossing bits
        dropped). k > 0 shifts toward the element MSB (i.e. ``x << k``)."""
        dst = self.shift_cols(a, k, dst)
        if k == 0:
            return dst
        w = self.width
        if k > 0:
            pattern = ((1 << w) - 1) & ~((1 << min(k, w)) - 1)
        else:
            pattern = ((1 << w) - 1) >> min(-k, w)
        return self.and_(dst, self.mask(pattern), dst)

    # -- derived --------------------------------------------------------------
    def smear(self, a: int, dst: int | None = None) -> int:
        """OR-spread any set bit of each element across the whole element
        (log2(w) doubling rounds in each direction)."""
        dst = self.copy(a, dst)
        s = 1
        while s < self.width:
            up = self.shift_elem(dst, +s)
            self.or_(dst, up, dst)
            self.free(up)
            s *= 2
        s = 1
        while s < self.width:
            dn = self.shift_elem(dst, -s)
            self.or_(dst, dn, dst)
            self.free(dn)
            s *= 2
        return dst

    # -- accounting -----------------------------------------------------------
    @property
    def time_ns(self) -> float:
        return float(self.state.meter.time_ns)

    @property
    def energy_nj(self) -> float:
        return float(self.state.meter.total_energy_nj)

    @property
    def setup_energy_nj(self) -> float:
        return float(self.state.meter.e_burst)

    def counts(self) -> dict:
        m = self.state.meter
        return {k: int(getattr(m, k)) for k in
                ("n_act", "n_pre", "n_aap", "n_shift", "n_tra")}
