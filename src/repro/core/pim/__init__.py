"""In-DRAM PIM runtime: the paper's migration-cell shift + Ambit ISA in JAX."""
from .state import (CostMeter, SubarrayState, make_bank, make_subarray,
                    EVEN_MASK, ODD_MASK, NUM_ROWS, ROW_BITS, ROW_WORDS,
                    WORD_BITS)
from .timing import (DDR3Timing, DEFAULT_TIMING, apply_refresh,
                     burst_time_ns, charge_copy, copy_cost,
                     cpu_movement_energy_nj, refresh_events)
from .isa import (C0, C1, T0, T1, T2, T3, ambit_and, ambit_maj, ambit_not,
                  ambit_or, ambit_xor, dcc_to, dra, issue, lisa_copy,
                  maj3_words, not_to_dcc, read_row, reserve_control_rows,
                  rowclone, run_on_bits, run_program, shift,
                  shift_row_words, tra, write_row)
from .program import (ambit_xor_program, bank_parallel, estimate_cost,
                      run_shift_workload, shift_k, shift_workload_program)
from .ir import (COPY_SELF, PimOp, PimProgram, ProgramBuilder,
                 decode_payload, from_trace_banks, from_trace_device, record,
                 rle_encode_payload, sequence_digest, to_trace_banks,
                 to_trace_device)
from .compile import (CompiledProgram, compile_program, cost_pass,
                      cost_summary, cost_tables, cost_tables_reference,
                      dead_copy_elimination, fuse)
from .exec import (ExecResult, execute, make_pipeline_runner, make_runner,
                   make_workload_runner)
from .device import (DeviceConfig, DeviceState, bus_time_ns,
                     channel_bus_model, channel_occupancy, device_wall_ns,
                     host_bus_ns, issue_bus_ns, make_device, paper_device)
from .schedule import (CopyDrainStats, Phase, PhaseResult, PipelinePlan,
                       PipelineResult, ScheduleResult, WorkloadResult,
                       compiled_for, gather_rows, schedule,
                       schedule_pipeline, schedule_workload, shard_lanes,
                       shard_rows, stream_key, xor_reduce_program)
from .lint import (CATALOG, Diagnostic, LintError, LintReport, lint_program,
                   lint_schedule, lint_trace, lint_trace_file)
from .sem import (DIFFERENT, EQUIVALENT, SEM_STATS, UNKNOWN, Analysis,
                  EquivalenceError, EquivReport, Witness, analyze,
                  check_witness, fusion_report, lane_const, prove_equivalent,
                  semantic_findings, summarize, verify_fusion)
from .variation import (PAPER_TABLE4, TECH22, Tech22nm, shift_failure_rate)
from .area import AreaModel, PAPER_TABLE5, mim_capacitor_plate_side_um


def reset_stats() -> None:
    """Zero the module-level instrumentation counters (column builds,
    scheduler plan/compile misses & dispatches, runner retraces). Test
    hygiene: lets stats-asserting tests run in any order."""
    from .exec import RUNNER_STATS
    from .ir import COLUMN_STATS
    from .schedule import SCHED_STATS
    from .sem import SEM_STATS
    for counters in (COLUMN_STATS, SCHED_STATS, RUNNER_STATS, SEM_STATS):
        for k in counters:
            counters[k] = 0


__all__ = [
    "CostMeter", "SubarrayState", "make_bank", "make_subarray",
    "EVEN_MASK", "ODD_MASK", "NUM_ROWS", "ROW_BITS", "ROW_WORDS", "WORD_BITS",
    "DDR3Timing", "DEFAULT_TIMING", "apply_refresh", "burst_time_ns",
    "charge_copy", "copy_cost", "cpu_movement_energy_nj", "refresh_events",
    "C0", "C1", "T0", "T1", "T2", "T3", "ambit_and", "ambit_maj", "ambit_not",
    "ambit_or", "ambit_xor", "dcc_to", "dra", "issue", "lisa_copy",
    "maj3_words", "not_to_dcc", "read_row", "reserve_control_rows",
    "rowclone", "run_on_bits", "run_program", "shift", "shift_row_words",
    "tra", "write_row",
    "ambit_xor_program", "bank_parallel", "estimate_cost",
    "run_shift_workload", "shift_k", "shift_workload_program",
    "COPY_SELF", "PimOp", "PimProgram", "ProgramBuilder", "record",
    "decode_payload", "rle_encode_payload", "sequence_digest",
    "from_trace_banks", "from_trace_device", "to_trace_banks",
    "to_trace_device",
    "CompiledProgram", "compile_program", "cost_pass", "cost_summary",
    "cost_tables", "cost_tables_reference", "dead_copy_elimination", "fuse",
    "ExecResult", "execute", "make_pipeline_runner", "make_runner",
    "make_workload_runner",
    "DeviceConfig", "DeviceState", "bus_time_ns", "channel_bus_model",
    "channel_occupancy", "device_wall_ns", "host_bus_ns", "issue_bus_ns",
    "make_device", "paper_device",
    "CopyDrainStats", "Phase", "PhaseResult", "PipelinePlan",
    "PipelineResult", "ScheduleResult", "WorkloadResult", "compiled_for",
    "gather_rows", "schedule", "schedule_pipeline", "schedule_workload",
    "shard_lanes", "shard_rows", "stream_key", "xor_reduce_program",
    "CATALOG", "Diagnostic", "LintError", "LintReport", "lint_program",
    "lint_schedule", "lint_trace", "lint_trace_file", "reset_stats",
    "DIFFERENT", "EQUIVALENT", "SEM_STATS", "UNKNOWN", "Analysis",
    "EquivalenceError", "EquivReport", "Witness", "analyze", "check_witness",
    "fusion_report", "lane_const", "prove_equivalent", "semantic_findings",
    "summarize", "verify_fusion",
    "PAPER_TABLE4", "TECH22", "Tech22nm", "shift_failure_rate",
    "AreaModel", "PAPER_TABLE5", "mim_capacitor_plate_side_um",
]
