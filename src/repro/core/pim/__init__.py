"""In-DRAM PIM runtime: the paper's migration-cell shift + Ambit ISA in JAX."""
from .state import (CostMeter, SubarrayState, make_bank, make_subarray,
                    EVEN_MASK, ODD_MASK, NUM_ROWS, ROW_BITS, ROW_WORDS,
                    WORD_BITS)
from .timing import (DDR3Timing, DEFAULT_TIMING, apply_refresh,
                     cpu_movement_energy_nj)
from .isa import (C0, C1, T0, T1, T2, T3, ambit_and, ambit_maj, ambit_not,
                  ambit_or, ambit_xor, dcc_to, dra, issue, maj3_words,
                  not_to_dcc, read_row, reserve_control_rows, rowclone, shift,
                  shift_row_words, tra, write_row)
from .program import (bank_parallel, estimate_cost, run_shift_workload,
                      shift_k, shift_workload_program)
from .ir import (PimOp, PimProgram, ProgramBuilder, from_trace_banks,
                 record, to_trace_banks)
from .compile import (CompiledProgram, compile_program, cost_pass,
                      cost_summary, dead_copy_elimination, fuse)
from .exec import ExecResult, execute, make_runner
from .device import (DeviceConfig, DeviceState, bus_time_ns, device_wall_ns,
                     make_device, paper_device)
from .schedule import (ScheduleResult, schedule, shard_lanes, shard_rows,
                       stream_key)
from .variation import (PAPER_TABLE4, TECH22, Tech22nm, shift_failure_rate)
from .area import AreaModel, PAPER_TABLE5, mim_capacitor_plate_side_um

__all__ = [
    "CostMeter", "SubarrayState", "make_bank", "make_subarray",
    "EVEN_MASK", "ODD_MASK", "NUM_ROWS", "ROW_BITS", "ROW_WORDS", "WORD_BITS",
    "DDR3Timing", "DEFAULT_TIMING", "apply_refresh", "cpu_movement_energy_nj",
    "C0", "C1", "T0", "T1", "T2", "T3", "ambit_and", "ambit_maj", "ambit_not",
    "ambit_or", "ambit_xor", "dcc_to", "dra", "issue", "maj3_words",
    "not_to_dcc", "read_row", "reserve_control_rows", "rowclone", "shift",
    "shift_row_words", "tra", "write_row",
    "bank_parallel", "estimate_cost", "run_shift_workload", "shift_k",
    "shift_workload_program",
    "PimOp", "PimProgram", "ProgramBuilder", "record",
    "from_trace_banks", "to_trace_banks",
    "CompiledProgram", "compile_program", "cost_pass", "cost_summary",
    "dead_copy_elimination", "fuse",
    "ExecResult", "execute", "make_runner",
    "DeviceConfig", "DeviceState", "bus_time_ns", "device_wall_ns",
    "make_device", "paper_device",
    "ScheduleResult", "schedule", "shard_lanes", "shard_rows", "stream_key",
    "PAPER_TABLE4", "TECH22", "Tech22nm", "shift_failure_rate",
    "AreaModel", "PAPER_TABLE5", "mim_capacitor_plate_side_um",
]
