"""Analytic area-overhead model (paper §5.3 / §6, Tables 5 and the layout).

The migration-cell design adds, per 512-row subarray:
  - 2 rows of migration cells (each migration cell = two standard 1T1C cells
    whose capacitor top plates are wired together — no new devices),
  - 2 extra wordlines to drive the second access ports,
  - the plate-connect wiring itself.

Cell area uses the open-bitline 6F^2 figure; the comparison numbers for
SIMDRAM / DRISA variants are the published figures quoted in the paper.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AreaModel:
    rows_per_subarray: int = 512
    migration_rows: int = 2
    cell_area_f2: float = 6.0
    # Wiring/wordline overhead expressed as equivalent extra rows.
    wiring_equiv_rows: float = 1.0
    ambit_extra_pct: float = 1.0  # paper: implementing on top of Ambit ~ +1%

    @property
    def overhead_pct(self) -> float:
        extra = self.migration_rows + self.wiring_equiv_rows
        return 100.0 * extra / self.rows_per_subarray

    @property
    def overhead_with_ambit_pct(self) -> float:
        return self.overhead_pct + self.ambit_extra_pct


# Published comparison points quoted by the paper (Table 5).
PAPER_TABLE5 = [
    ("w/ Migration Cells", "Wiring", "<1% (without Ambit)"),
    ("SIMDRAM", "Control unit + Transposition unit", "0.2% (vs Intel Xeon CPU)"),
    ("DRISA 3T1C", "Shifters, controllers, bus, buffers", "~6.8% (vs 8Gb DRAM)"),
    ("DRISA 1T1C-nor", "NOR gates + latches + shifters", "~34% added circuits"),
    ("DRISA 1T1C-mixed", "Mixed logic gates + shifters", "~40% added circuits"),
    ("DRISA 1T1C-adder", "Adders + shifters", "~60% added circuits"),
]


def mim_capacitor_plate_side_um(c_farads: float = 25e-15,
                                eps_r: float = 20.0,
                                thickness_m: float = 8e-9) -> float:
    """Paper §6: HfO2 MIM capacitor plate sizing.  C = eps0*eps_r*A/d."""
    eps0 = 8.8854e-12
    area_m2 = c_farads * thickness_m / (eps0 * eps_r)
    return (area_m2 ** 0.5) * 1e6  # um
