"""Compilation passes over a recorded :class:`~.ir.PimProgram`.

Three passes:

``cost_pass``
    Replaces the eager path's per-command ``charge_*`` threading with a
    single vectorized fold. Per-charge-event float32/int32 increment tables
    are built once (numpy, exact mirrors of ``timing.charge_*``), then one
    ``lax.scan`` with a 12-scalar carry folds them **in program order** —
    bit-exact against the eager meter (same IEEE adds, same order) without
    stepping the (rows × words) state pytree per command.
    ``cost_summary`` is the closed-form O(1) float64 companion for planning
    (analytical, not bit-exact; cross-checked against ``estimate_cost``).

``dead_copy_elimination``
    Backward-liveness pass removing pure row overwrites (AAP/DRA copies,
    host writes, fills) whose destination is rewritten before any read.
    An *optimization*: the optimized program is cheaper by construction, so
    its meter intentionally differs from the unoptimized stream.

``fuse``
    Lowers the stream into executor segments: maximal same-direction shift
    chains become one k-column kernel shift, Ambit MAJ/NOT macro-idioms
    become single bitwise kernel calls, and residual primitives batch into
    ``lax.scan``-able runs. Fusion is semantics-preserving (bit-exact,
    including migration-row and DCC side state); costs always come from the
    unfused stream.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ir, isa
from .state import CostMeter
from .timing import (DDR3Timing, DEFAULT_TIMING, burst_time_ns,
                     refresh_events_scalar)

_FLOAT_FIELDS = ("time_ns", "e_act", "e_pre", "e_refresh", "e_burst",
                 "e_background")
_INT_FIELDS = ("n_act", "n_pre", "n_aap", "n_shift", "n_tra", "n_refresh")


# ---------------------------------------------------------------------------
# Cost pass
# ---------------------------------------------------------------------------

def _event_rows(op: ir.PimOp, words: int, cfg: DDR3Timing):
    """Yield (float6, int6) increment rows for one command — one row per
    charge event, mirroring timing.charge_* float32-for-float32."""
    f32 = np.float32

    def aap(extra_shift=0):
        dt = f32(cfg.t_aap)
        return ([dt, f32(2 * cfg.e_act), f32(cfg.e_pre), 0.0, 0.0,
                 dt * f32(cfg.p_background)],
                [2, 1, 1, extra_shift, 0, 0])

    if op.op in (ir.OP_ROWCLONE, ir.OP_NOT2DCC, ir.OP_DCC2):
        yield aap()
    elif op.op == ir.OP_COPY:
        if not ir.copy_is_local(op):
            raise ValueError(
                f"cross-subarray COPY to ({op.delta}, {op.c}) cannot be "
                "compiled for one subarray — route it through the device "
                "scheduler (schedule.py), which strips and applies it")
        # timing.copy_cost(0) — a distance-0 LISA copy is exactly one AAP.
        yield aap()
    elif op.op == ir.OP_SHIFT:
        for i in range(4):                      # charge_shift = 4 × charge_aap
            yield aap(extra_shift=int(i == 3))
    elif op.op in (ir.OP_DRA, ir.OP_TRA):
        k = 2 if op.op == ir.OP_DRA else 3
        dt = f32(cfg.tRC)
        yield ([dt, f32(cfg.e_act + (k - 1) * cfg.e_act_extra_row),
                f32(cfg.e_pre), 0.0, 0.0, dt * f32(cfg.p_background)],
               [1, 1, 0, 0, int(k == 3), 0])
    elif op.op in (ir.OP_WRITE, ir.OP_READ):
        transfers = -(-(words * 4) // 64)       # charge_burst
        dt = f32(burst_time_ns(words * 4, cfg))
        yield ([dt, f32(cfg.e_act), f32(cfg.e_pre), 0.0,
                f32(transfers * cfg.e_burst_per_64b),
                dt * f32(cfg.p_background)],
               [1, 1, 0, 0, 0, 0])
    elif op.op == ir.OP_ISSUE:
        dt = f32(cfg.t_issue)
        yield ([dt, 0.0, 0.0, 0.0, 0.0, dt * f32(cfg.p_background)],
               [0, 0, 0, 0, 0, 0])
    elif op.op == ir.OP_FILL:
        return                                   # setup: meter-free
    else:
        raise ValueError(op.op)


def cost_tables_reference(program: ir.PimProgram,
                          cfg: DDR3Timing = DEFAULT_TIMING):
    """Per-op Python-loop table builder (the pre-columnar implementation).

    Kept as the bit-exactness oracle for the vectorized :func:`cost_tables`
    (differential tests compare the two row-for-row) and as the baseline
    the scheduler benchmark measures the columnar gather against."""
    frows, irows = [], []
    for op in program.ops:
        for f, i in _event_rows(op, program.words, cfg):
            frows.append(f)
            irows.append(i)
    if not frows:
        return (np.zeros((0, 6), np.float32), np.zeros((0, 6), np.int32))
    return (np.asarray(frows, np.float32), np.asarray(irows, np.int32))


# Most events any single op expands to (SHIFT = 4 AAPs).
_MAX_EVENTS = 4

# Representative op per opcode — operand-independent cost templates. COPY
# uses the local (self-slot) form; cross-slot COPYs are refused by
# cost_tables just as the per-op path refused them.
_TEMPLATE_OPS = {
    ir.OP_ISSUE: ir.PimOp(ir.OP_ISSUE),
    ir.OP_ROWCLONE: ir.PimOp(ir.OP_ROWCLONE),
    ir.OP_DRA: ir.PimOp(ir.OP_DRA),
    ir.OP_TRA: ir.PimOp(ir.OP_TRA),
    ir.OP_NOT2DCC: ir.PimOp(ir.OP_NOT2DCC),
    ir.OP_DCC2: ir.PimOp(ir.OP_DCC2),
    ir.OP_SHIFT: ir.PimOp(ir.OP_SHIFT, delta=1),
    ir.OP_WRITE: ir.PimOp(ir.OP_WRITE),
    ir.OP_READ: ir.PimOp(ir.OP_READ),
    ir.OP_FILL: ir.PimOp(ir.OP_FILL),
    ir.OP_COPY: ir.PimOp(ir.OP_COPY, delta=ir.COPY_SELF, c=ir.COPY_SELF),
}


@functools.lru_cache(maxsize=64)
def _opcode_templates(words: int, cfg: DDR3Timing):
    """Per-opcode increment templates: ``(n_codes, _MAX_EVENTS, 6)`` float32
    and int32 event rows plus the per-opcode event count, built once per
    (words, timing) through the same ``_event_rows`` generator — so the
    vectorized gather reproduces the per-op loop float32-for-float32."""
    n_codes = len(ir.OPCODES)
    f_t = np.zeros((n_codes, _MAX_EVENTS, 6), np.float32)
    i_t = np.zeros((n_codes, _MAX_EVENTS, 6), np.int32)
    counts = np.zeros(n_codes, np.int64)
    for name, op in _TEMPLATE_OPS.items():
        code = ir.OP_CODE[name]
        for e, (f, i) in enumerate(_event_rows(op, words, cfg)):
            f_t[code, e] = f
            i_t[code, e] = i
            counts[code] = e + 1
    f_t.setflags(write=False)
    i_t.setflags(write=False)
    counts.setflags(write=False)
    return f_t, i_t, counts


# Cost tables are a pure function of (op-table digest, words, timing) —
# payload data never enters the charge model — so equal streams share one
# pair of (read-only) tables across compiles. Warm multi-phase plans that
# re-compile a recurring stream (or a phase-concat of recurring streams)
# skip the gather entirely. LRU-bounded like the scheduler caches.
_cost_table_cache: dict = {}
_COST_TABLE_CACHE_MAX = 512


def cost_tables(program: ir.PimProgram,
                cfg: DDR3Timing = DEFAULT_TIMING):
    """(m, 6) float32 + (m, 6) int32 increment tables, one row per charge
    event in program order.

    Vectorized over the program's cached columnar encoding: one numpy
    gather from the per-opcode templates instead of a per-op Python loop.
    Bit-exact against :func:`cost_tables_reference` (same rows, same order,
    same float32 values). Cached per (stream digest, words, timing); the
    returned arrays are read-only."""
    cols = program.columns
    key = (cols.digest, program.words, cfg)
    hit = _cost_table_cache.pop(key, None)
    if hit is not None:
        _cost_table_cache[key] = hit    # (re)insert at the MRU end
        return hit
    codes = cols.code
    is_copy = codes.size and codes == ir.OP_CODE[ir.OP_COPY]
    if codes.size and is_copy.any():
        local = (((cols.delta == ir.COPY_SELF) & (cols.c == ir.COPY_SELF))
                 | ((cols.delta == 0) & (cols.c == 0)))
        bad = np.flatnonzero(is_copy & ~local)
        if bad.size:
            i = int(bad[0])
            raise ValueError(
                f"cross-subarray COPY to ({int(cols.delta[i])}, "
                f"{int(cols.c[i])}) cannot be compiled for one subarray — "
                "route it through the device scheduler (schedule.py), "
                "which strips and applies it")
    f_t, i_t, counts = _opcode_templates(program.words, cfg)
    ev = counts[codes] if codes.size else np.zeros(0, np.int64)
    total = int(ev.sum())
    if total == 0:
        out = (np.zeros((0, 6), np.float32), np.zeros((0, 6), np.int32))
    else:
        rep = np.repeat(codes, ev)
        within = np.arange(total) - np.repeat(np.cumsum(ev) - ev, ev)
        out = (f_t[rep, within], i_t[rep, within])
    for a in out:
        a.setflags(write=False)
    if len(_cost_table_cache) >= _COST_TABLE_CACHE_MAX:
        _cost_table_cache.pop(next(iter(_cost_table_cache)))
    _cost_table_cache[key] = out
    return out


# The in-jit fold runs as a lax.scan over BLOCKS of this many event rows,
# each block's additions unrolled in the loop body. Same additions in the
# same order as a row-at-a-time scan (bit-exact — trailing blocks are
# padded with +0.0 rows, an IEEE identity on these non-negative meters),
# but ~64x fewer XLA loop iterations: the per-step cost of a compiled
# runner no longer scales with one loop trip per charge event.
#
# Each float add sits behind jax.lax.optimization_barrier: XLA's CPU
# fast-math would otherwise reassociate the unrolled chain into SIMD
# partial sums and drift from the eager meter by ulps. jax 0.4.x has no
# vmap batching rule for the barrier primitive, but it is an identity
# primitive, so the passthrough rule (the one upstream later added) is
# registered here; without it the fold falls back to row-at-a-time blocks,
# which need no barrier.
_FOLD_BLOCK = 64


def _register_barrier_batching() -> bool:
    try:
        from jax._src.lax.lax import optimization_barrier_p as p
        from jax.interpreters import batching
        if p not in batching.primitive_batchers:
            batching.primitive_batchers[p] = (
                lambda args, dims: (p.bind(*args), dims))
        return True
    except Exception:           # pragma: no cover - future-jax safety net
        return False


_BARRIER_OK = _register_barrier_batching()


@functools.partial(jax.jit, static_argnames=())
def _fold_tables(f_tab, i_tab, f0, i0):
    n = f_tab.shape[0]
    if n == 0:
        return f0, i0
    block = _FOLD_BLOCK if _BARRIER_OK else 1
    pad = (-n) % block
    if pad:
        f_tab = jnp.concatenate(
            [f_tab, jnp.zeros((pad, f_tab.shape[1]), f_tab.dtype)])
        i_tab = jnp.concatenate(
            [i_tab, jnp.zeros((pad, i_tab.shape[1]), i_tab.dtype)])

    def step(carry, blk):
        cf, ci = carry
        bf, bi = blk
        for j in range(block):          # unrolled inside the loop body
            cf = cf + bf[j]
            if _BARRIER_OK:
                cf = jax.lax.optimization_barrier(cf)
            ci = ci + bi[j]
        return (cf, ci), ()

    (ff, fi), _ = jax.lax.scan(
        step, (f0, i0),
        (f_tab.reshape(-1, block, f_tab.shape[1]),
         i_tab.reshape(-1, block, i_tab.shape[1])))
    return ff, fi


def cost_pass(program: ir.PimProgram, cfg: DDR3Timing = DEFAULT_TIMING,
              init: CostMeter | None = None) -> CostMeter:
    """Exact meter for the whole program in one fold (accumulating on top
    of ``init`` when given) — equals the eager path bit-for-bit.

    The fold is a strictly-sequential ``np.add.accumulate`` over the
    columnar increment tables: the same IEEE float32 additions in the same
    order as the eager per-command path (and as the executor's in-jit
    ``lax.scan`` fold), with no XLA compilation on the host path at all."""
    f_tab, i_tab = cost_tables(program, cfg)
    init = CostMeter.zeros() if init is None else init
    f0 = np.asarray([np.float32(getattr(init, k)) for k in _FLOAT_FIELDS],
                    np.float32)
    i0 = np.asarray([np.int32(getattr(init, k)) for k in _INT_FIELDS],
                    np.int32)
    if len(f_tab):
        ff = np.add.accumulate(
            np.concatenate([f0[None, :], f_tab], axis=0),
            axis=0, dtype=np.float32)[-1]
        fi = np.add.accumulate(
            np.concatenate([i0[None, :], i_tab], axis=0),
            axis=0, dtype=np.int32)[-1]
    else:
        ff, fi = f0, i0
    fields = {k: jnp.asarray(ff[j], jnp.float32)
              for j, k in enumerate(_FLOAT_FIELDS)}
    fields.update({k: jnp.asarray(fi[j], jnp.int32)
                   for j, k in enumerate(_INT_FIELDS)})
    return CostMeter(**fields)


def cost_summary(program: ir.PimProgram, cfg: DDR3Timing = DEFAULT_TIMING,
                 refresh: bool = False) -> dict:
    """Closed-form float64 totals (O(ops) table build, O(1) reduction);
    analytical counterpart of ``program.estimate_cost``."""
    f_tab, i_tab = cost_tables(program, cfg)
    t, e_act, e_pre, e_ref, e_burst, e_bg = (
        f_tab.astype(np.float64).sum(axis=0) if len(f_tab) else np.zeros(6))
    counts = dict(zip(_INT_FIELDS,
                      i_tab.sum(axis=0).tolist() if len(i_tab) else [0] * 6))
    n_ref = 0
    if refresh:
        n_ref = refresh_events_scalar(t, cfg)
        t += n_ref * cfg.tRFC
        e_ref += n_ref * cfg.e_ref
        e_bg += n_ref * cfg.tRFC * cfg.p_background
        counts["n_refresh"] = n_ref
    return {
        "time_ns": float(t), "e_act": float(e_act), "e_pre": float(e_pre),
        "e_refresh": float(e_ref), "e_burst": float(e_burst),
        "e_background": float(e_bg),
        "energy_nj": float(e_act + e_pre + e_ref + e_burst + e_bg),
        **counts,
    }


# ---------------------------------------------------------------------------
# Dead-copy elimination
# ---------------------------------------------------------------------------

def dead_copy_elimination(program: ir.PimProgram,
                          live_out: set[int] | None = None) -> ir.PimProgram:
    """Drop pure overwrites (rowclone/dra/write/fill) of rows that are
    rewritten before any later read. ``live_out`` is the set of rows whose
    final contents matter; by default all rows except the Ambit scratch
    (T0..T3)."""
    if live_out is None:
        scratch = {int(t) % program.num_rows
                   for t in (isa.T0, isa.T1, isa.T2, isa.T3)}
        live_out = set(range(program.num_rows)) - scratch
    live = set(live_out)
    keep = [True] * len(program.ops)
    for i in range(len(program.ops) - 1, -1, -1):
        op = program.ops[i]
        if (op.op in (ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_WRITE, ir.OP_FILL)
                and op.b not in live):
            keep[i] = False
            continue
        live -= set(op.writes())
        live |= set(op.reads())
    ops, payloads, remap = [], [], {}
    for flag, op in zip(keep, program.ops):
        if not flag:
            continue
        if op.op == ir.OP_WRITE:
            if op.payload not in remap:
                remap[op.payload] = len(payloads)
                payloads.append(program.payloads[op.payload])
            op = dataclasses.replace(op, payload=remap[op.payload])
        ops.append(op)
    return ir.PimProgram(ops=tuple(ops), num_rows=program.num_rows,
                         words=program.words, payloads=tuple(payloads))


# ---------------------------------------------------------------------------
# Fusion into executor segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegShiftRun:
    """k chained 1-bit shifts src→dst(→dst…), one direction."""
    src: int
    dst: int
    delta: int
    k: int


@dataclasses.dataclass(frozen=True)
class SegMaj:
    """Fused Ambit MAJ idiom (covers AND/OR via control rows)."""
    a: int
    b: int
    c: int
    dst: int


@dataclasses.dataclass(frozen=True)
class SegNot:
    """Fused NOT pair (not_to_dcc + dcc_to)."""
    src: int
    dst: int


@dataclasses.dataclass(frozen=True)
class SegScan:
    """Residual primitive run executed by the lax.scan interpreter."""
    ops: tuple[ir.PimOp, ...]


@dataclasses.dataclass(frozen=True)
class SegHost:
    """Host-visible op executed unrolled (read/write/fill)."""
    op: ir.PimOp


# Residual primitives the scan interpreter understands.
_SCANNABLE = (ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_TRA, ir.OP_NOT2DCC,
              ir.OP_DCC2, ir.OP_SHIFT, ir.OP_COPY)


def _maj_sites(cols: ir.ProgramColumns, num_rows: int) -> np.ndarray:
    """Boolean mask of positions ``i`` where ``ops[i:i+5]`` is the
    ambit_maj expansion in its alias-safe fused form (the vectorized
    5-op window match the old per-position ``_match_maj`` performed):
    three rowclones into T0..T2, the TRA over them, and the rowclone of
    T0 into dst — refused when a later source would have observed an
    earlier scratch write."""
    n = len(cols.table)
    maj_at = np.zeros(n, bool)
    if n < 5:
        return maj_at
    t0, t1, t2 = (int(t) % num_rows for t in (isa.T0, isa.T1, isa.T2))
    code, a, b, c = cols.code, cols.a, cols.b, cols.c
    rc, tra = ir.OP_CODE[ir.OP_ROWCLONE], ir.OP_CODE[ir.OP_TRA]
    m = ((code[:n - 4] == rc) & (b[:n - 4] == t0)
         & (code[1:n - 3] == rc) & (b[1:n - 3] == t1)
         & (code[2:n - 2] == rc) & (b[2:n - 2] == t2)
         & (code[3:n - 1] == tra) & (a[3:n - 1] == t0)
         & (b[3:n - 1] == t1) & (c[3:n - 1] == t2)
         & (code[4:] == rc) & (a[4:] == t0)
         # alias safety: reads of a, b, c precede the scratch writes
         & (a[1:n - 3] != t0) & (a[2:n - 2] != t0) & (a[2:n - 2] != t1))
    maj_at[:n - 4] = m
    return maj_at


def _shift_runs(cols: ir.ProgramColumns) -> tuple[np.ndarray, np.ndarray]:
    """Columnar chain detection: ``(cont, run_end)`` where ``cont[j]`` is
    True when the SHIFT at ``j`` continues the chain started earlier (same
    dst, src == dst, same direction) and ``run_end[s]`` holds, for every
    chain start ``s``, the index one past the chain's last op (-1
    elsewhere)."""
    n = len(cols.table)
    code, a, b, delta = cols.code, cols.a, cols.b, cols.delta
    is_shift = code == ir.OP_CODE[ir.OP_SHIFT]
    cont = np.zeros(n, bool)
    if n > 1:
        cont[1:] = (is_shift[1:] & is_shift[:-1]
                    & (a[1:] == b[1:]) & (b[1:] == b[:-1])
                    & (delta[1:] == delta[:-1]))
    run_end = np.full(n, -1, np.int64)
    starts = np.flatnonzero(is_shift & ~cont)
    if starts.size:
        breaks = np.flatnonzero(~cont)
        pos = np.searchsorted(breaks, starts, side="right")
        run_end[starts] = np.append(breaks, n)[pos]
    return cont, run_end


# Shift chains shorter than this stay residual (scan) ops: a handful of
# 1-bit hops costs less than a dedicated kernel segment, and keeping them in
# the scan table lets neighboring segments coalesce into one loop.
SHIFT_FUSE_MIN = 32


def fuse(program: ir.PimProgram, *,
         shift_fuse_min: int = SHIFT_FUSE_MIN,
         verify_semantics: bool = False) -> tuple:
    """Lower the op stream to a segment list for the executor.

    Pattern detection (MAJ idioms, shift chains) runs vectorized on the
    program's columnar encoding; the walk then just jumps between the
    precomputed match sites instead of re-inspecting ``PimOp`` operands at
    every position.

    ``verify_semantics=True`` runs the symbolic abstract interpreter
    (``sem.py``) over BOTH the op stream and the produced segment list
    and raises :class:`~.sem.EquivalenceError` unless they are proved to
    compute identical state — the opt-in proof that fusion preserved
    semantics (UNKNOWN also raises: a gate must not pass unproved)."""
    ops = program.ops
    n = len(ops)
    if n == 0:
        return ()
    cols = program.columns
    code = cols.code
    maj_at = _maj_sites(cols, program.num_rows)
    cont, run_end = _shift_runs(cols)
    shift_c = ir.OP_CODE[ir.OP_SHIFT]
    not2dcc_c, dcc2_c = ir.OP_CODE[ir.OP_NOT2DCC], ir.OP_CODE[ir.OP_DCC2]
    host_cs = {ir.OP_CODE[o] for o in (ir.OP_WRITE, ir.OP_READ, ir.OP_FILL)}
    issue_c = ir.OP_CODE[ir.OP_ISSUE]
    segments: list = []
    residual: list[ir.PimOp] = []

    def flush_residual():
        if residual:
            segments.append(SegScan(ops=tuple(residual)))
            residual.clear()

    i = 0
    while i < n:
        op = ops[i]
        ci = code[i]
        if maj_at[i]:
            flush_residual()
            segments.append(SegMaj(a=op.a, b=ops[i + 1].a, c=ops[i + 2].a,
                                   dst=ops[i + 4].b))
            i += 5
            continue
        if ci == not2dcc_c and i + 1 < n and code[i + 1] == dcc2_c:
            flush_residual()
            segments.append(SegNot(src=op.a, dst=ops[i + 1].b))
            i += 2
            continue
        if ci == shift_c:
            j = int(run_end[i])
            if j < 0:               # mid-run landing (cannot happen via the
                j = i + 1           # walk itself): extend by continuation
                while j < n and cont[j]:
                    j += 1
            if j - i >= max(2, shift_fuse_min):
                flush_residual()
                segments.append(SegShiftRun(src=op.a, dst=op.b,
                                            delta=op.delta, k=j - i))
                i = j
                continue
            residual.extend(ops[i:j])
            i = j
            continue
        if ci in host_cs:
            flush_residual()
            segments.append(SegHost(op=op))
            i += 1
            continue
        if ci == issue_c:
            i += 1                    # cost-only; no state effect
            continue
        assert op.op in _SCANNABLE, op.op
        residual.append(op)
        i += 1
    flush_residual()
    out = tuple(segments)
    if verify_semantics:
        from . import sem       # lazy: sem imports this module's dataclasses
        sem.verify_fusion(program, out)
    return out


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """A program lowered to segments, with its cost tables prebuilt."""

    program: ir.PimProgram
    segments: tuple
    f_tab: np.ndarray
    i_tab: np.ndarray

    @property
    def num_rows(self) -> int:
        return self.program.num_rows

    @property
    def words(self) -> int:
        return self.program.words


def compile_program(program: ir.PimProgram,
                    cfg: DDR3Timing = DEFAULT_TIMING, *,
                    optimize: bool = False,
                    live_out: set[int] | None = None,
                    shift_fuse_min: int = SHIFT_FUSE_MIN,
                    verify: bool = False,
                    verify_semantics: bool = False) -> CompiledProgram:
    """Full pipeline: (optional lint) → (optional DCE) → fusion → cost
    tables.

    ``optimize=True`` applies dead-copy elimination first; the resulting
    meter reflects the *optimized* stream (cheaper than eager — that is the
    point), so equivalence tests run with the default ``optimize=False``.

    ``verify=True`` runs the static verifier (``lint.lint_program``) over
    the INPUT stream before any transformation and raises
    :class:`~.lint.LintError` on error-severity diagnostics.

    ``verify_semantics=True`` additionally proves (``sem.py``) that the
    fused segment list computes the same state as the op stream it was
    lowered from, raising :class:`~.sem.EquivalenceError` otherwise. The
    proof runs against the post-DCE stream when ``optimize=True`` (DCE
    changes dead state on purpose; the fusion gate checks fusion).
    """
    if verify:
        from . import lint      # lazy: lint imports this module's passes
        report = lint.lint_program(program)
        if not report.ok:
            raise lint.LintError(report)
    if optimize:
        program = dead_copy_elimination(program, live_out)
    f_tab, i_tab = cost_tables(program, cfg)
    return CompiledProgram(
        program=program,
        segments=fuse(program, shift_fuse_min=shift_fuse_min,
                      verify_semantics=verify_semantics),
        f_tab=f_tab, i_tab=i_tab)
