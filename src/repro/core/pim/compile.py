"""Compilation passes over a recorded :class:`~.ir.PimProgram`.

Three passes:

``cost_pass``
    Replaces the eager path's per-command ``charge_*`` threading with a
    single vectorized fold. Per-charge-event float32/int32 increment tables
    are built once (numpy, exact mirrors of ``timing.charge_*``), then one
    ``lax.scan`` with a 12-scalar carry folds them **in program order** —
    bit-exact against the eager meter (same IEEE adds, same order) without
    stepping the (rows × words) state pytree per command.
    ``cost_summary`` is the closed-form O(1) float64 companion for planning
    (analytical, not bit-exact; cross-checked against ``estimate_cost``).

``dead_copy_elimination``
    Backward-liveness pass removing pure row overwrites (AAP/DRA copies,
    host writes, fills) whose destination is rewritten before any read.
    An *optimization*: the optimized program is cheaper by construction, so
    its meter intentionally differs from the unoptimized stream.

``fuse``
    Lowers the stream into executor segments: maximal same-direction shift
    chains become one k-column kernel shift, Ambit MAJ/NOT macro-idioms
    become single bitwise kernel calls, and residual primitives batch into
    ``lax.scan``-able runs. Fusion is semantics-preserving (bit-exact,
    including migration-row and DCC side state); costs always come from the
    unfused stream.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ir, isa
from .state import CostMeter
from .timing import (DDR3Timing, DEFAULT_TIMING, burst_time_ns,
                     refresh_events_scalar)

_FLOAT_FIELDS = ("time_ns", "e_act", "e_pre", "e_refresh", "e_burst",
                 "e_background")
_INT_FIELDS = ("n_act", "n_pre", "n_aap", "n_shift", "n_tra", "n_refresh")


# ---------------------------------------------------------------------------
# Cost pass
# ---------------------------------------------------------------------------

def _event_rows(op: ir.PimOp, words: int, cfg: DDR3Timing):
    """Yield (float6, int6) increment rows for one command — one row per
    charge event, mirroring timing.charge_* float32-for-float32."""
    f32 = np.float32

    def aap(extra_shift=0):
        dt = f32(cfg.t_aap)
        return ([dt, f32(2 * cfg.e_act), f32(cfg.e_pre), 0.0, 0.0,
                 dt * f32(cfg.p_background)],
                [2, 1, 1, extra_shift, 0, 0])

    if op.op in (ir.OP_ROWCLONE, ir.OP_NOT2DCC, ir.OP_DCC2):
        yield aap()
    elif op.op == ir.OP_COPY:
        if not ir.copy_is_local(op):
            raise ValueError(
                f"cross-subarray COPY to ({op.delta}, {op.c}) cannot be "
                "compiled for one subarray — route it through the device "
                "scheduler (schedule.py), which strips and applies it")
        # timing.copy_cost(0) — a distance-0 LISA copy is exactly one AAP.
        yield aap()
    elif op.op == ir.OP_SHIFT:
        for i in range(4):                      # charge_shift = 4 × charge_aap
            yield aap(extra_shift=int(i == 3))
    elif op.op in (ir.OP_DRA, ir.OP_TRA):
        k = 2 if op.op == ir.OP_DRA else 3
        dt = f32(cfg.tRC)
        yield ([dt, f32(cfg.e_act + (k - 1) * cfg.e_act_extra_row),
                f32(cfg.e_pre), 0.0, 0.0, dt * f32(cfg.p_background)],
               [1, 1, 0, 0, int(k == 3), 0])
    elif op.op in (ir.OP_WRITE, ir.OP_READ):
        transfers = -(-(words * 4) // 64)       # charge_burst
        dt = f32(burst_time_ns(words * 4, cfg))
        yield ([dt, f32(cfg.e_act), f32(cfg.e_pre), 0.0,
                f32(transfers * cfg.e_burst_per_64b),
                dt * f32(cfg.p_background)],
               [1, 1, 0, 0, 0, 0])
    elif op.op == ir.OP_ISSUE:
        dt = f32(cfg.t_issue)
        yield ([dt, 0.0, 0.0, 0.0, 0.0, dt * f32(cfg.p_background)],
               [0, 0, 0, 0, 0, 0])
    elif op.op == ir.OP_FILL:
        return                                   # setup: meter-free
    else:
        raise ValueError(op.op)


def cost_tables(program: ir.PimProgram,
                cfg: DDR3Timing = DEFAULT_TIMING):
    """(m, 6) float32 + (m, 6) int32 increment tables, one row per charge
    event in program order."""
    frows, irows = [], []
    for op in program.ops:
        for f, i in _event_rows(op, program.words, cfg):
            frows.append(f)
            irows.append(i)
    if not frows:
        return (np.zeros((0, 6), np.float32), np.zeros((0, 6), np.int32))
    return (np.asarray(frows, np.float32), np.asarray(irows, np.int32))


@functools.partial(jax.jit, static_argnames=())
def _fold_tables(f_tab, i_tab, f0, i0):
    def step(carry, row):
        cf, ci = carry
        rf, ri = row
        return (cf + rf, ci + ri), ()

    (ff, fi), _ = jax.lax.scan(step, (f0, i0), (f_tab, i_tab))
    return ff, fi


def cost_pass(program: ir.PimProgram, cfg: DDR3Timing = DEFAULT_TIMING,
              init: CostMeter | None = None) -> CostMeter:
    """Exact meter for the whole program in one compiled fold (accumulating
    on top of ``init`` when given) — equals the eager path bit-for-bit."""
    f_tab, i_tab = cost_tables(program, cfg)
    init = CostMeter.zeros() if init is None else init
    f0 = jnp.stack([jnp.asarray(getattr(init, k), jnp.float32)
                    for k in _FLOAT_FIELDS])
    i0 = jnp.stack([jnp.asarray(getattr(init, k), jnp.int32)
                    for k in _INT_FIELDS])
    ff, fi = _fold_tables(jnp.asarray(f_tab), jnp.asarray(i_tab), f0, i0)
    fields = {k: ff[j] for j, k in enumerate(_FLOAT_FIELDS)}
    fields.update({k: fi[j] for j, k in enumerate(_INT_FIELDS)})
    return CostMeter(**fields)


def cost_summary(program: ir.PimProgram, cfg: DDR3Timing = DEFAULT_TIMING,
                 refresh: bool = False) -> dict:
    """Closed-form float64 totals (O(ops) table build, O(1) reduction);
    analytical counterpart of ``program.estimate_cost``."""
    f_tab, i_tab = cost_tables(program, cfg)
    t, e_act, e_pre, e_ref, e_burst, e_bg = (
        f_tab.astype(np.float64).sum(axis=0) if len(f_tab) else np.zeros(6))
    counts = dict(zip(_INT_FIELDS,
                      i_tab.sum(axis=0).tolist() if len(i_tab) else [0] * 6))
    n_ref = 0
    if refresh:
        n_ref = refresh_events_scalar(t, cfg)
        t += n_ref * cfg.tRFC
        e_ref += n_ref * cfg.e_ref
        e_bg += n_ref * cfg.tRFC * cfg.p_background
        counts["n_refresh"] = n_ref
    return {
        "time_ns": float(t), "e_act": float(e_act), "e_pre": float(e_pre),
        "e_refresh": float(e_ref), "e_burst": float(e_burst),
        "e_background": float(e_bg),
        "energy_nj": float(e_act + e_pre + e_ref + e_burst + e_bg),
        **counts,
    }


# ---------------------------------------------------------------------------
# Dead-copy elimination
# ---------------------------------------------------------------------------

def dead_copy_elimination(program: ir.PimProgram,
                          live_out: set[int] | None = None) -> ir.PimProgram:
    """Drop pure overwrites (rowclone/dra/write/fill) of rows that are
    rewritten before any later read. ``live_out`` is the set of rows whose
    final contents matter; by default all rows except the Ambit scratch
    (T0..T3)."""
    if live_out is None:
        scratch = {int(t) % program.num_rows
                   for t in (isa.T0, isa.T1, isa.T2, isa.T3)}
        live_out = set(range(program.num_rows)) - scratch
    live = set(live_out)
    keep = [True] * len(program.ops)
    for i in range(len(program.ops) - 1, -1, -1):
        op = program.ops[i]
        if (op.op in (ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_WRITE, ir.OP_FILL)
                and op.b not in live):
            keep[i] = False
            continue
        live -= set(op.writes())
        live |= set(op.reads())
    ops, payloads, remap = [], [], {}
    for flag, op in zip(keep, program.ops):
        if not flag:
            continue
        if op.op == ir.OP_WRITE:
            if op.payload not in remap:
                remap[op.payload] = len(payloads)
                payloads.append(program.payloads[op.payload])
            op = dataclasses.replace(op, payload=remap[op.payload])
        ops.append(op)
    return ir.PimProgram(ops=tuple(ops), num_rows=program.num_rows,
                         words=program.words, payloads=tuple(payloads))


# ---------------------------------------------------------------------------
# Fusion into executor segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegShiftRun:
    """k chained 1-bit shifts src→dst(→dst…), one direction."""
    src: int
    dst: int
    delta: int
    k: int


@dataclasses.dataclass(frozen=True)
class SegMaj:
    """Fused Ambit MAJ idiom (covers AND/OR via control rows)."""
    a: int
    b: int
    c: int
    dst: int


@dataclasses.dataclass(frozen=True)
class SegNot:
    """Fused NOT pair (not_to_dcc + dcc_to)."""
    src: int
    dst: int


@dataclasses.dataclass(frozen=True)
class SegScan:
    """Residual primitive run executed by the lax.scan interpreter."""
    ops: tuple[ir.PimOp, ...]


@dataclasses.dataclass(frozen=True)
class SegHost:
    """Host-visible op executed unrolled (read/write/fill)."""
    op: ir.PimOp


# Residual primitives the scan interpreter understands.
_SCANNABLE = (ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_TRA, ir.OP_NOT2DCC,
              ir.OP_DCC2, ir.OP_SHIFT, ir.OP_COPY)


def _match_maj(ops, i, num_rows):
    """Recognize the 5-op ambit_maj expansion at ops[i:] when the fused
    read-all-then-write form is alias-safe."""
    if i + 5 > len(ops):
        return None
    t0, t1, t2 = (int(t) % num_rows for t in (isa.T0, isa.T1, isa.T2))
    o0, o1, o2, o3, o4 = ops[i:i + 5]
    if not (o0.op == ir.OP_ROWCLONE and o0.b == t0
            and o1.op == ir.OP_ROWCLONE and o1.b == t1
            and o2.op == ir.OP_ROWCLONE and o2.b == t2
            and o3.op == ir.OP_TRA and (o3.a, o3.b, o3.c) == (t0, t1, t2)
            and o4.op == ir.OP_ROWCLONE and o4.a == t0):
        return None
    # Fused form reads a, b, c before writing T0..T2: refuse when a later
    # source would have observed an earlier scratch write.
    if o1.a == t0 or o2.a in (t0, t1):
        return None
    return SegMaj(a=o0.a, b=o1.a, c=o2.a, dst=o4.b)


# Shift chains shorter than this stay residual (scan) ops: a handful of
# 1-bit hops costs less than a dedicated kernel segment, and keeping them in
# the scan table lets neighboring segments coalesce into one loop.
SHIFT_FUSE_MIN = 32


def fuse(program: ir.PimProgram, *,
         shift_fuse_min: int = SHIFT_FUSE_MIN) -> tuple:
    """Lower the op stream to a segment list for the executor."""
    ops = program.ops
    num_rows = program.num_rows
    segments: list = []
    residual: list[ir.PimOp] = []

    def flush_residual():
        if residual:
            segments.append(SegScan(ops=tuple(residual)))
            residual.clear()

    i = 0
    while i < len(ops):
        op = ops[i]
        maj = _match_maj(ops, i, num_rows)
        if maj is not None:
            flush_residual()
            segments.append(maj)
            i += 5
            continue
        if (op.op == ir.OP_NOT2DCC and i + 1 < len(ops)
                and ops[i + 1].op == ir.OP_DCC2):
            flush_residual()
            segments.append(SegNot(src=op.a, dst=ops[i + 1].b))
            i += 2
            continue
        if op.op == ir.OP_SHIFT:
            j, dst, delta = i + 1, op.b, op.delta
            while (j < len(ops) and ops[j].op == ir.OP_SHIFT
                   and ops[j].a == dst and ops[j].b == dst
                   and ops[j].delta == delta):
                j += 1
            if j - i >= max(2, shift_fuse_min):
                flush_residual()
                segments.append(SegShiftRun(src=op.a, dst=dst, delta=delta,
                                            k=j - i))
                i = j
                continue
            residual.extend(ops[i:j])
            i = j
            continue
        if op.op in (ir.OP_WRITE, ir.OP_READ, ir.OP_FILL):
            flush_residual()
            segments.append(SegHost(op=op))
            i += 1
            continue
        if op.op == ir.OP_ISSUE:
            i += 1                    # cost-only; no state effect
            continue
        assert op.op in _SCANNABLE, op.op
        residual.append(op)
        i += 1
    flush_residual()
    return tuple(segments)


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """A program lowered to segments, with its cost tables prebuilt."""

    program: ir.PimProgram
    segments: tuple
    f_tab: np.ndarray
    i_tab: np.ndarray

    @property
    def num_rows(self) -> int:
        return self.program.num_rows

    @property
    def words(self) -> int:
        return self.program.words


def compile_program(program: ir.PimProgram,
                    cfg: DDR3Timing = DEFAULT_TIMING, *,
                    optimize: bool = False,
                    live_out: set[int] | None = None,
                    shift_fuse_min: int = SHIFT_FUSE_MIN) -> CompiledProgram:
    """Full pipeline: (optional DCE) → fusion → cost tables.

    ``optimize=True`` applies dead-copy elimination first; the resulting
    meter reflects the *optimized* stream (cheaper than eager — that is the
    point), so equivalence tests run with the default ``optimize=False``.
    """
    if optimize:
        program = dead_copy_elimination(program, live_out)
    f_tab, i_tab = cost_tables(program, cfg)
    return CompiledProgram(
        program=program,
        segments=fuse(program, shift_fuse_min=shift_fuse_min),
        f_tab=f_tab, i_tab=i_tab)
