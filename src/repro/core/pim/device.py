"""Device-level model: channels × ranks × banks × subarrays over the
subarray runtime.

The paper's §5.1.4 configuration is 2 channels × 2 ranks × 8 banks/rank =
32 independently-operating banks; each bank stacks ``subarrays`` (S)
:class:`~.state.SubarrayState` units (SIMDRAM allocates μPrograms across
subarrays the same way). A ``(bank, sub)`` pair is a *slot*; slots execute
concurrently (separate row buffers and sense amplifiers) but share their
channel's command/data bus, so the device-level wall clock is

    wall = max over channels of serialized bus occupancy
         + max over slots of in-slot execution time
         + link-contended COPY drain                  (see ``schedule.py``)
    energy = sum over slots                (the paper's constant nJ/op)

Bus occupancy charges each slot's per-burst ``ISSUE`` overhead
(``DDR3Timing.t_issue``) AND its off-chip HOSTW/HOSTR burst windows
(``timing.burst_time_ns``) back-to-back *per channel*: a memory controller
can only drive one command burst / data transfer onto a channel at a time,
channels operate independently, and consecutive bursts targeting different
ranks of one channel pay the ``tRTRS`` bus-turnaround penalty. The
activated slots then run their streams in parallel. With one bank of one
subarray this degenerates to exactly the single-subarray meter (issue +
host bursts + execution), which is what keeps device runs bit-comparable
to the PR-1 executor.

Adjacent subarrays of one bank are additionally linked by LISA-style
row-buffer movement: a ``COPY`` IR op moves a row between them at
``timing.copy_cost`` (per-hop link latency/energy), and across banks over
the chip's shared internal bus — never through the host. The scheduler
(``schedule.py``) applies those transfers.

``DeviceState`` is a registered pytree whose leaves carry a leading *slot*
axis of length ``n_banks * subarrays`` (slot ``b*S + s``), so one compiled
program vmaps across any slot subset; heterogeneous per-slot programs are
the scheduler's job.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ir
from .state import NUM_ROWS, ROW_WORDS, SubarrayState, make_subarray
from .timing import DDR3Timing, DEFAULT_TIMING, burst_time_ns


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """A DRAM device: ``channels × ranks × banks_per_rank`` banks of
    ``subarrays`` subarrays each, all sharing one subarray geometry and
    timing model. Frozen/hashable so it can sit in pytree metadata and
    cache keys."""

    channels: int = 2
    ranks: int = 2
    banks_per_rank: int = 8
    subarrays: int = 1
    num_rows: int = NUM_ROWS
    words: int = ROW_WORDS
    timing: DDR3Timing = DEFAULT_TIMING

    @property
    def n_banks(self) -> int:
        return self.channels * self.ranks * self.banks_per_rank

    @property
    def n_slots(self) -> int:
        """Independently-executing units: every (bank, subarray) pair."""
        return self.n_banks * self.subarrays

    def bank_coords(self, bank: int) -> tuple[int, int, int]:
        """Flat bank index → (channel, rank, bank-in-rank)."""
        assert 0 <= bank < self.n_banks, bank
        ch, rest = divmod(bank, self.ranks * self.banks_per_rank)
        rk, bk = divmod(rest, self.banks_per_rank)
        return ch, rk, bk

    def slot_index(self, bank: int, sub: int = 0) -> int:
        """(bank, subarray) → flat slot index into the state's leading axis."""
        if not 0 <= bank < self.n_banks:
            raise ValueError(f"bank {bank} out of range [0, {self.n_banks})")
        if not 0 <= sub < self.subarrays:
            raise ValueError(
                f"subarray {sub} out of range [0, {self.subarrays})")
        return bank * self.subarrays + sub

    def slot_coords(self, slot: int) -> tuple[int, int]:
        """Flat slot index → (bank, subarray)."""
        assert 0 <= slot < self.n_slots, slot
        return divmod(slot, self.subarrays)

    def bank_slots(self, banks) -> tuple[int, ...]:
        """Flat slot indices of every subarray of the given banks, in
        (bank, subarray) order — the serving layer's placement unit."""
        return tuple(self.slot_index(b, s) for b in banks
                     for s in range(self.subarrays))

    def subdevice(self, n_banks: int) -> "DeviceConfig":
        """A private single-channel slice of this device: ``n_banks`` banks
        with the same subarray geometry and timing. Per-slot state and
        meters are layout-independent, so a tenant scheduled alone on its
        subdevice is bit-exact against the same programs running on its
        slots of the shared device (the multi-tenant differential leg)."""
        if not 0 < n_banks <= self.n_banks:
            raise ValueError(
                f"subdevice of {n_banks} banks from {self.n_banks}")
        return dataclasses.replace(self, channels=1, ranks=1,
                                   banks_per_rank=n_banks)


# §5.1.4 device sizes used throughout benchmarks: 1, 8 (one rank), 32 (all).
def paper_device(n_banks: int, num_rows: int = NUM_ROWS,
                 words: int = ROW_WORDS, subarrays: int = 1,
                 timing: DDR3Timing = DEFAULT_TIMING) -> DeviceConfig:
    """The paper's DDR3 topology scaled down to ``n_banks`` total banks."""
    shapes = {1: (1, 1, 1), 2: (1, 1, 2), 4: (1, 1, 4), 8: (1, 1, 8),
              16: (1, 2, 8), 32: (2, 2, 8)}
    if n_banks not in shapes:
        raise ValueError(
            f"n_banks must be one of {sorted(shapes)}, got {n_banks}")
    ch, rk, bk = shapes[n_banks]
    return DeviceConfig(channels=ch, ranks=rk, banks_per_rank=bk,
                        subarrays=subarrays, num_rows=num_rows, words=words,
                        timing=timing)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["banks", "host_credit_ns"],
    meta_fields=["config"],
)
@dataclasses.dataclass
class DeviceState:
    """All subarrays of one device; every ``banks`` leaf has a leading
    ``(n_banks * subarrays,)`` slot axis (slot ``b*S + s``).

    ``host_credit_ns`` is the async-host-engine double-buffer window: the
    previous ``schedule`` step's compute+copy wall time, against which the
    *next* step's off-chip HOSTW/HOSTR bursts may overlap when scheduled
    with ``async_host=True`` (Shared-PIM-style concurrent data flow). It is
    plain bookkeeping — zero on a fresh device, rewritten by every step,
    and only consumed in async mode. A *data* pytree leaf (scalar, possibly
    an on-device value): the scheduler writes the step's lazy compute time
    here without a blocking ``float()`` sync, and the single-dispatch step
    function consumes it as a traced argument."""

    banks: SubarrayState
    config: DeviceConfig
    host_credit_ns: float | jax.Array = 0.0

    @property
    def n_banks(self) -> int:
        return self.config.n_banks

    @property
    def n_slots(self) -> int:
        return self.config.n_slots

    def slot(self, bank: int, sub: int = 0) -> SubarrayState:
        """One subarray's state, unbatched (host-side convenience)."""
        i = self.config.slot_index(bank, sub)
        return jax.tree_util.tree_map(lambda x: x[i], self.banks)

    def bank(self, b: int) -> SubarrayState:
        """One bank's state: unbatched for single-subarray banks (the PR-2
        contract), a stacked ``(subarrays, ...)`` view otherwise."""
        if self.config.subarrays == 1:
            return self.slot(b, 0)
        i = self.config.slot_index(b, 0)
        return jax.tree_util.tree_map(
            lambda x: x[i:i + self.config.subarrays], self.banks)

    @property
    def slot_time_ns(self) -> jax.Array:
        """(n_slots,) cumulative per-slot busy time — lazy (stays on
        device). Meters are cumulative and slots are exclusively owned, so
        a tenant's busy time over any window is the difference of two
        snapshots of this array sliced at its slots."""
        return self.banks.meter.time_ns

    @property
    def slot_energy_nj(self) -> jax.Array:
        """(n_slots,) cumulative per-slot energy — lazy. Summing slices
        over a slot partition reconciles exactly with the device totals
        (the scheduler's ``energy_nj`` is the same array, summed)."""
        return self.banks.meter.total_energy_nj

    def with_banks(self, banks: SubarrayState,
                   host_credit_ns=None) -> "DeviceState":
        """``host_credit_ns`` is stored as-is (float or lazy device scalar);
        no blocking conversion happens here."""
        return DeviceState(banks=banks, config=self.config,
                           host_credit_ns=(self.host_credit_ns
                                           if host_credit_ns is None
                                           else host_credit_ns))


def make_device(config: DeviceConfig, reserve: bool = True) -> DeviceState:
    """Fresh device; ``reserve`` initializes the Ambit C0/C1 control rows in
    every subarray (meter-free, as in ``isa.reserve_control_rows``)."""
    from .isa import reserve_control_rows

    def one(_):
        s = make_subarray(config.num_rows, config.words)
        return reserve_control_rows(s) if reserve else s

    return DeviceState(banks=jax.vmap(one)(jnp.arange(config.n_slots)),
                       config=config)


def issue_bus_ns(program: ir.PimProgram | None,
                 timing: DDR3Timing = DEFAULT_TIMING) -> float:
    """Command-bus occupancy of one slot's ISSUE bursts."""
    if program is None:
        return 0.0
    n_issue = sum(1 for o in program.ops if o.op == ir.OP_ISSUE)
    return n_issue * timing.t_issue


def host_bus_ns(program: ir.PimProgram | None,
                timing: DDR3Timing = DEFAULT_TIMING) -> float:
    """Channel occupancy of one slot's off-chip HOSTW/HOSTR bursts — the
    part of the stream that streams data over the channel and therefore
    cannot overlap with another slot's bursts on the SAME channel."""
    if program is None:
        return 0.0
    row_bytes = program.words * 4
    n_host = sum(1 for o in program.ops
                 if o.op in (ir.OP_WRITE, ir.OP_READ))
    return n_host * burst_time_ns(row_bytes, timing)


def bus_time_ns(program: ir.PimProgram | None,
                timing: DDR3Timing = DEFAULT_TIMING) -> float:
    """Total per-channel bus occupancy of one slot's stream: ISSUE bursts
    plus off-chip HOSTW/HOSTR burst windows. (Before the channel-aware
    model, only ISSUE counted — off-chip bursts were free on the wall
    clock.)"""
    return issue_bus_ns(program, timing) + host_bus_ns(program, timing)


def channel_bus_model(cfg: DeviceConfig, issue_slot, host_slot, *,
                      host_credit_ns: float = 0.0):
    """Serialize per-slot bus occupancy FCFS per channel.

    ``issue_slot`` / ``host_slot`` are length-``n_slots`` arrays of each
    slot's ISSUE / host-burst occupancy. Slots are served in slot order on
    their bank's channel; consecutive bus-active slots on one channel that
    sit in different ranks charge one ``tRTRS`` bus-turnaround penalty.
    ``host_credit_ns`` is the async-host overlap window: up to that much of
    each channel's HOST traffic is hidden under the *previous* step's
    compute (each channel's transfer engine overlaps the same window —
    channels stream independently).

    Returns ``(busy, switch_ns, hidden_ns)``: per-channel serialized
    occupancy (float array, switch penalties included, overlap deducted),
    the total rank-switch penalty, and the total host time hidden.
    """
    issue_ch, host_ch, switch_ch = channel_occupancy(cfg, issue_slot,
                                                     host_slot)
    hidden = np.minimum(host_ch, max(float(host_credit_ns), 0.0))
    busy = issue_ch + host_ch - hidden + switch_ch
    return busy, float(switch_ch.sum()), float(hidden.sum())


def channel_occupancy(cfg: DeviceConfig, issue_slot, host_slot):
    """The per-channel accumulation walk shared by ``channel_bus_model``
    and the scheduler's async-credit fold: serialize bus-active slots FCFS
    in slot order onto their bank's channel. Returns float64
    ``(issue_ch, host_ch, switch_ch)`` arrays of length ``channels`` —
    ISSUE occupancy, HOSTW/HOSTR occupancy (the part an async host engine
    may hide), and accumulated ``tRTRS`` rank-switch penalties."""
    issue_slot = np.asarray(issue_slot, np.float64)
    host_slot = np.asarray(host_slot, np.float64)
    issue_ch = np.zeros(cfg.channels)
    host_ch = np.zeros(cfg.channels)
    switch_ch = np.zeros(cfg.channels)
    last_rank: list = [None] * cfg.channels
    for k in range(cfg.n_slots):
        if issue_slot[k] + host_slot[k] <= 0.0:
            continue
        ch, rk, _ = cfg.bank_coords(k // cfg.subarrays)
        issue_ch[ch] += issue_slot[k]
        host_ch[ch] += host_slot[k]
        if last_rank[ch] is not None and last_rank[ch] != rk:
            switch_ch[ch] += cfg.timing.tRTRS
        last_rank[ch] = rk
    return issue_ch, host_ch, switch_ch


def device_wall_ns(bus_ns, exec_ns) -> jnp.ndarray:
    """Legacy device-wide serialization: wall = Σ bus + max exec. Kept as
    the A/B reference against the channel-aware model (``schedule`` now
    uses ``channel_bus_model``); for one channel with no rank switches the
    two agree — ``tests/test_pim_channels.py`` pins that equivalence."""
    bus_ns = jnp.asarray(bus_ns, jnp.float32)
    exec_ns = jnp.asarray(exec_ns, jnp.float32)
    return jnp.sum(bus_ns) + (jnp.max(exec_ns) if exec_ns.size
                              else jnp.float32(0.0))
