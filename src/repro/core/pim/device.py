"""Device-level model: channels × ranks × banks over the subarray runtime.

The paper's §5.1.4 configuration is 2 channels × 2 ranks × 8 banks/rank =
32 independently-operating banks, each modeled here as one
:class:`~.state.SubarrayState`. Banks execute concurrently (separate row
buffers and sense amplifiers) but share the command bus, so the device-level
wall clock is

    wall = bus serialization + max over banks of in-bank execution time
    energy = sum over banks                      (the paper's constant nJ/op)

Bus serialization charges each bank's per-burst ``ISSUE`` overhead
(``DDR3Timing.t_issue``) back-to-back: the memory controller can only drive
one command burst onto a channel at a time, while the activated banks then
run their streams in parallel. With one bank this degenerates to exactly the
single-subarray meter (issue + execution), which is what keeps device runs
bit-comparable to the PR-1 executor.

``DeviceState`` is a registered pytree whose leaves carry a leading bank
axis, so one compiled program vmaps across any bank subset; heterogeneous
per-bank programs are the scheduler's job (``schedule.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import ir
from .state import NUM_ROWS, ROW_WORDS, SubarrayState, make_subarray
from .timing import DDR3Timing, DEFAULT_TIMING


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """A DRAM device: ``channels × ranks × banks_per_rank`` subarray-banks,
    all sharing one subarray geometry and timing model. Frozen/hashable so
    it can sit in pytree metadata and cache keys."""

    channels: int = 2
    ranks: int = 2
    banks_per_rank: int = 8
    num_rows: int = NUM_ROWS
    words: int = ROW_WORDS
    timing: DDR3Timing = DEFAULT_TIMING

    @property
    def n_banks(self) -> int:
        return self.channels * self.ranks * self.banks_per_rank

    def bank_coords(self, bank: int) -> tuple[int, int, int]:
        """Flat bank index → (channel, rank, bank-in-rank)."""
        assert 0 <= bank < self.n_banks, bank
        ch, rest = divmod(bank, self.ranks * self.banks_per_rank)
        rk, bk = divmod(rest, self.banks_per_rank)
        return ch, rk, bk


# §5.1.4 device sizes used throughout benchmarks: 1, 8 (one rank), 32 (all).
def paper_device(n_banks: int, num_rows: int = NUM_ROWS,
                 words: int = ROW_WORDS,
                 timing: DDR3Timing = DEFAULT_TIMING) -> DeviceConfig:
    """The paper's DDR3 topology scaled down to ``n_banks`` total banks."""
    shapes = {1: (1, 1, 1), 2: (1, 1, 2), 4: (1, 1, 4), 8: (1, 1, 8),
              16: (1, 2, 8), 32: (2, 2, 8)}
    if n_banks not in shapes:
        raise ValueError(
            f"n_banks must be one of {sorted(shapes)}, got {n_banks}")
    ch, rk, bk = shapes[n_banks]
    return DeviceConfig(channels=ch, ranks=rk, banks_per_rank=bk,
                        num_rows=num_rows, words=words, timing=timing)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["banks"],
    meta_fields=["config"],
)
@dataclasses.dataclass
class DeviceState:
    """All banks of one device; every ``banks`` leaf has a leading
    ``(n_banks,)`` axis."""

    banks: SubarrayState
    config: DeviceConfig

    @property
    def n_banks(self) -> int:
        return self.config.n_banks

    def bank(self, b: int) -> SubarrayState:
        """One bank's state, unbatched (host-side convenience)."""
        return jax.tree_util.tree_map(lambda x: x[b], self.banks)

    def with_banks(self, banks: SubarrayState) -> "DeviceState":
        return DeviceState(banks=banks, config=self.config)


def make_device(config: DeviceConfig, reserve: bool = True) -> DeviceState:
    """Fresh device; ``reserve`` initializes the Ambit C0/C1 control rows in
    every bank (meter-free, as in ``isa.reserve_control_rows``)."""
    from .isa import reserve_control_rows

    def one(_):
        s = make_subarray(config.num_rows, config.words)
        return reserve_control_rows(s) if reserve else s

    return DeviceState(banks=jax.vmap(one)(jnp.arange(config.n_banks)),
                       config=config)


def bus_time_ns(program: ir.PimProgram | None,
                timing: DDR3Timing = DEFAULT_TIMING) -> float:
    """Command-bus occupancy of one bank's stream: its ISSUE bursts are the
    only part that serializes device-wide."""
    if program is None:
        return 0.0
    n_issue = sum(1 for o in program.ops if o.op == ir.OP_ISSUE)
    return n_issue * timing.t_issue


def device_wall_ns(bus_ns, exec_ns) -> jnp.ndarray:
    """wall = serialized bus traffic + slowest bank's in-bank execution."""
    bus_ns = jnp.asarray(bus_ns, jnp.float32)
    exec_ns = jnp.asarray(exec_ns, jnp.float32)
    return jnp.sum(bus_ns) + (jnp.max(exec_ns) if exec_ns.size
                              else jnp.float32(0.0))
