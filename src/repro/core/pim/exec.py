"""Compiled executor for recorded PIM programs.

Lowers the fused segments of a :class:`~.compile.CompiledProgram` onto the
Pallas ``kernels/rowops`` kernels (``bitwise``, ``shift_cols``) — a k-long
chain of migration shifts becomes ONE k-column kernel shift, an Ambit MAJ
idiom becomes one bitwise kernel call — with a ``lax.scan`` interpreter for
residual primitives. The meter comes from the compile-time cost pass (one
fold over the increment tables, seeded with the incoming meter), so the
final ``SubarrayState`` is bit-exact against the eager ISA path: same bits,
same migration/DCC side state, same CostMeter to the last ulp.

``use_kernels`` defaults to kernel lowering only on real TPU backends: in
interpret mode (CPU hosts, like the rest of ``kernels/rowops``) the pure-jnp
row math produces identical uint32 results without the per-call interpreter
overhead. Force either path explicitly to compare.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import compile as pim_compile
from . import ir
from .compile import (CompiledProgram, SegHost, SegMaj, SegNot, SegScan,
                      SegShiftRun, compile_program)
from .isa import T0 as isa_T0, T1 as isa_T1, T2 as isa_T2
from .isa import maj3_words, shift_row_words
from .state import EVEN_MASK, ODD_MASK, SubarrayState, make_subarray
from .timing import DDR3Timing, DEFAULT_TIMING, apply_refresh


@dataclasses.dataclass
class ExecResult:
    """Final state plus host-read rows in ``read_row`` slot order."""

    state: SubarrayState
    reads: tuple


# How many times a runner body was (re)traced by jit. Steady-state pipelines
# must not grow this: regression tests assert "1 compile, then 0" across
# recurring schedule steps.
RUNNER_STATS = {"traces": 0}


def _as_compiled(program, cfg) -> CompiledProgram:
    if isinstance(program, CompiledProgram):
        return program
    return compile_program(program, cfg)


def _default_use_kernels() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Row math: kernel and jnp lowering produce identical uint32 results
# ---------------------------------------------------------------------------

def _shift_row(row, k: int, use_kernels: bool, interpret):
    if k == 0:
        return row
    if use_kernels:
        from ...kernels.rowops import ops as kops
        return kops.shift_cols(row[None, :], k, interpret=interpret)[0]
    return shift_row_words(row, k)


def _maj_rows(a, b, c, use_kernels: bool, interpret):
    if use_kernels:
        from ...kernels.rowops import ops as kops
        return kops.bitwise(a[None, :], b[None, :], c[None, :], op="maj",
                            interpret=interpret)[0]
    return maj3_words(a, b, c)


def _not_row(a, use_kernels: bool, interpret):
    if use_kernels:
        from ...kernels.rowops import ops as kops
        return kops.bitwise(a[None, :], op="not", interpret=interpret)[0]
    return ~a


def _shift1(row, delta: int):
    """One 1-bit shift, exactly mirroring ``shift_row_words(row, ±1)``."""
    if delta > 0:
        carry = jnp.concatenate(
            [jnp.zeros(row.shape[:-1] + (1,), jnp.uint32),
             row[..., :-1]], axis=-1) >> jnp.uint32(31)
        return (row << jnp.uint32(1)) | carry
    carry = jnp.concatenate(
        [row[..., 1:], jnp.zeros(row.shape[:-1] + (1,), jnp.uint32)],
        axis=-1) << jnp.uint32(31)
    return (row >> jnp.uint32(1)) | carry


# ---------------------------------------------------------------------------
# Residual-op lax.scan interpreter
# ---------------------------------------------------------------------------

_SCAN_COPY, _SCAN_TRA, _SCAN_NOT2DCC, _SCAN_DCC2 = 0, 1, 2, 3
_SCAN_SHIFT_R, _SCAN_SHIFT_L = 4, 5
_SCAN_MAJ, _SCAN_NOTPAIR = 6, 7          # fused macro rows (SegMaj / SegNot)

_SCAN_CODE = {ir.OP_ROWCLONE: _SCAN_COPY, ir.OP_DRA: _SCAN_COPY,
              ir.OP_COPY: _SCAN_COPY, ir.OP_TRA: _SCAN_TRA,
              ir.OP_NOT2DCC: _SCAN_NOT2DCC, ir.OP_DCC2: _SCAN_DCC2}


@dataclasses.dataclass(frozen=True)
class _SegTable:
    """Coalesced scan table: residual primitives plus fused MAJ/NOT macro
    rows, executed as ONE lax.scan loop (one trace, one XLA loop)."""

    rows: tuple  # of (code, a, b, c, d)


def _op_rows(op: ir.PimOp):
    if op.op == ir.OP_SHIFT:
        code = _SCAN_SHIFT_R if op.delta > 0 else _SCAN_SHIFT_L
    else:
        code = _SCAN_CODE[op.op]
    return (code, op.a, op.b, op.c, 0)


def _coalesce(segments, use_kernels):
    """With kernel lowering off, merge contiguous scan-able segments (incl.
    MAJ/NOT macros) into single _SegTable loops to keep traces tiny."""
    out, rows = [], []

    def flush():
        if rows:
            out.append(_SegTable(rows=tuple(rows)))
            rows.clear()

    for seg in segments:
        if isinstance(seg, SegScan):
            rows.extend(_op_rows(op) for op in seg.ops)
        elif not use_kernels and isinstance(seg, SegMaj):
            rows.append((_SCAN_MAJ, seg.a, seg.b, seg.c, seg.dst))
        elif not use_kernels and isinstance(seg, SegNot):
            rows.append((_SCAN_NOTPAIR, seg.src, seg.dst, 0, 0))
        else:
            flush()
            out.append(seg)
    flush()
    return tuple(out)


def _scan_segment(seg: _SegTable, carry, num_rows: int):
    import numpy as np
    tab = np.asarray(seg.rows, np.int32)
    code, opnd = jnp.asarray(tab[:, 0]), jnp.asarray(tab[:, 1:])
    t0, t1, t2 = (t % num_rows for t in (isa_T0, isa_T1, isa_T2))

    def do_copy(carry, a, b, c, d):
        bits, mt, mb, dcc = carry
        return bits.at[b].set(bits[a]), mt, mb, dcc

    def do_tra(carry, a, b, c, d):
        bits, mt, mb, dcc = carry
        m = maj3_words(bits[a], bits[b], bits[c])
        return bits.at[a].set(m).at[b].set(m).at[c].set(m), mt, mb, dcc

    def do_not2dcc(carry, a, b, c, d):
        bits, mt, mb, _ = carry
        return bits, mt, mb, ~bits[a]

    def do_dcc2(carry, a, b, c, d):
        bits, mt, mb, dcc = carry
        return bits.at[b].set(dcc), mt, mb, dcc

    def do_shift(delta):
        def f(carry, a, b, c, d):
            bits, _, _, dcc = carry
            row = bits[a]
            mt = row & (EVEN_MASK if delta > 0 else ODD_MASK)
            mb = row & (ODD_MASK if delta > 0 else EVEN_MASK)
            merged = _shift1(mt, delta) | _shift1(mb, delta)
            return bits.at[b].set(merged), mt, mb, dcc
        return f

    def do_maj(carry, a, b, c, d):
        bits, mt, mb, dcc = carry
        m = maj3_words(bits[a], bits[b], bits[c])
        bits = bits.at[t0].set(m).at[t1].set(m).at[t2].set(m)
        return bits.at[d].set(m), mt, mb, dcc

    def do_notpair(carry, a, b, c, d):
        bits, mt, mb, _ = carry
        dcc = ~bits[a]
        return bits.at[b].set(dcc), mt, mb, dcc

    branches = [do_copy, do_tra, do_not2dcc, do_dcc2,
                do_shift(+1), do_shift(-1), do_maj, do_notpair]

    def step(carry, x):
        c, o = x
        out = jax.lax.switch(c, branches, carry, o[0], o[1], o[2], o[3])
        return out, ()

    carry, _ = jax.lax.scan(step, carry, (code, opnd))
    return carry


# ---------------------------------------------------------------------------
# Segment walk
# ---------------------------------------------------------------------------

def _run_segments(compiled: CompiledProgram, carry, use_kernels, interpret,
                  payloads=None):
    reads = []
    if payloads is None:
        payloads = [jnp.asarray(p) for p in compiled.program.payloads]
    for seg in _coalesce(compiled.segments, use_kernels):
        bits, mt, mb, dcc = carry
        if isinstance(seg, SegShiftRun):
            # k chained 1-bit shifts: shift (k-1) columns in one kernel call,
            # then replay the last hop so mig_top/mig_bot match eager exactly.
            y = _shift_row(bits[seg.src], seg.delta * (seg.k - 1),
                           use_kernels, interpret)
            mt = y & (EVEN_MASK if seg.delta > 0 else ODD_MASK)
            mb = y & (ODD_MASK if seg.delta > 0 else EVEN_MASK)
            merged = _shift1(mt, seg.delta) | _shift1(mb, seg.delta)
            carry = (bits.at[seg.dst].set(merged), mt, mb, dcc)
        elif isinstance(seg, SegMaj):
            m = _maj_rows(bits[seg.a], bits[seg.b], bits[seg.c],
                          use_kernels, interpret)
            t0, t1, t2 = (t % compiled.num_rows
                          for t in (isa_T0, isa_T1, isa_T2))
            bits = bits.at[t0].set(m).at[t1].set(m).at[t2].set(m)
            carry = (bits.at[seg.dst].set(m), mt, mb, dcc)
        elif isinstance(seg, SegNot):
            dcc = _not_row(bits[seg.src], use_kernels, interpret)
            carry = (bits.at[seg.dst].set(dcc), mt, mb, dcc)
        elif isinstance(seg, _SegTable):
            carry = _scan_segment(seg, carry, compiled.num_rows)
        elif isinstance(seg, SegHost):
            op = seg.op
            if op.op == ir.OP_READ:
                reads.append(bits[op.a])
            elif op.op == ir.OP_WRITE:
                carry = (bits.at[op.b].set(payloads[op.payload]), mt, mb, dcc)
            elif op.op == ir.OP_FILL:
                row = jnp.full((compiled.words,), jnp.uint32(op.payload))
                carry = (bits.at[op.b].set(row), mt, mb, dcc)
        else:
            raise TypeError(seg)
    return carry, tuple(reads)


def make_runner(program, cfg: DDR3Timing = DEFAULT_TIMING, *,
                use_kernels: bool | None = None,
                interpret: bool | None = None,
                refresh: bool = False,
                payload_arg: bool = False,
                verify: bool = False):
    """Build a jitted ``state -> ExecResult`` function for one program.

    The returned runner is cached per (program, flags, cfg-value) and is
    vmap-able, so ``bank_parallel`` maps ONE compiled program across banks
    instead of re-tracing the eager interpreter per bank.

    With ``payload_arg=True`` the runner takes HOSTW payloads as a second
    argument — a ``(n_payloads, words)`` uint32 array — instead of baking
    ``program.payloads`` in as constants. This is how the device scheduler
    (``schedule.py``) runs banks whose command streams are identical but
    whose written data differs: one compiled runner, vmapped over
    ``(states, payload_stacks)``.

    ``verify=True`` statically lints the stream before building the
    runner and raises :class:`~.lint.LintError` on errors (a construction-
    time gate: cached runners are never rebuilt, so warm calls pay zero).
    """
    if verify:
        from . import lint      # lazy: lint is a pure-numpy leaf module
        src = program.program if hasattr(program, "program") else program
        report = lint.lint_program(src)
        if not report.ok:
            raise lint.LintError(report)
    compiled = _as_compiled(program, cfg)
    if use_kernels is None:
        use_kernels = _default_use_kernels()
    cache = getattr(compiled, "_runner_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(compiled, "_runner_cache", cache)
    # Key on the frozen cfg VALUE: id(cfg) could alias a dead cfg's reused id
    # (stale refresh constants) and always missed for equal-but-distinct cfgs.
    key = (use_kernels, interpret, refresh, payload_arg, cfg)
    if key in cache:
        return cache[key]

    f_tab = jnp.asarray(compiled.f_tab)
    i_tab = jnp.asarray(compiled.i_tab)

    @jax.jit
    def run(state: SubarrayState, payloads=None):
        RUNNER_STATS["traces"] += 1      # executes at trace time only
        carry = (state.bits, state.mig_top, state.mig_bot, state.dcc)
        (bits, mt, mb, dcc), reads = _run_segments(
            compiled, carry, use_kernels, interpret, payloads=payloads)
        f0 = jnp.stack([jnp.asarray(getattr(state.meter, k), jnp.float32)
                        for k in pim_compile._FLOAT_FIELDS])
        i0 = jnp.stack([jnp.asarray(getattr(state.meter, k), jnp.int32)
                        for k in pim_compile._INT_FIELDS])
        ff, fi = pim_compile._fold_tables(f_tab, i_tab, f0, i0)
        fields = {k: ff[j]
                  for j, k in enumerate(pim_compile._FLOAT_FIELDS)}
        fields.update({k: fi[j]
                       for j, k in enumerate(pim_compile._INT_FIELDS)})
        meter = type(state.meter)(**fields)
        if refresh:
            meter = apply_refresh(meter, cfg)
        return SubarrayState(bits=bits, mig_top=mt, mig_bot=mb, dcc=dcc,
                             meter=meter), reads

    if payload_arg:
        def runner(state: SubarrayState, payloads) -> ExecResult:
            out_state, reads = run(state, payloads)
            return ExecResult(state=out_state, reads=reads)
        runner.traced = run      # (state, payloads) -> (state, reads)
    else:
        def runner(state: SubarrayState) -> ExecResult:
            out_state, reads = run(state)
            return ExecResult(state=out_state, reads=reads)
        runner.traced = run      # raw (state) -> (state, reads), for vmap
    cache[key] = runner
    return runner


def make_pipeline_runner(program, cfg: DDR3Timing = DEFAULT_TIMING, *,
                         use_kernels: bool | None = None,
                         interpret: bool | None = None,
                         refresh: bool = False):
    """Build a jitted K-step pipeline ``(state, payload_steps) ->
    (state, reads_steps)`` for ONE recurring program.

    ``payload_steps`` is a ``(K, n_payloads, words)`` uint32 array — the
    HOSTW data of each step; the same command stream executes K times under
    one ``jax.lax.scan``, so a recurring single-subarray pipeline (e.g. a
    ``PimVM.run_pipeline`` on an unsharded VM) costs one XLA dispatch total
    instead of one per step. ``reads_steps`` leaves carry a leading step
    axis. Cached per (program, flags, cfg) like :func:`make_runner`."""
    compiled = _as_compiled(program, cfg)
    if use_kernels is None:
        use_kernels = _default_use_kernels()
    base = make_runner(compiled, cfg, use_kernels=use_kernels,
                       interpret=interpret, refresh=refresh,
                       payload_arg=True)
    cache = compiled._runner_cache      # make_runner just ensured it exists
    key = ("pipeline", use_kernels, interpret, refresh, cfg)
    if key in cache:
        return cache[key]

    @jax.jit
    def run_pipe(state: SubarrayState, payload_steps):
        def body(s, p):
            out, reads = base.traced(s, p)
            return out, reads

        return jax.lax.scan(body, state, payload_steps)

    cache[key] = run_pipe
    return run_pipe


def make_workload_runner(programs, cfg: DDR3Timing = DEFAULT_TIMING, *,
                         use_kernels: bool | None = None,
                         interpret: bool | None = None,
                         refresh: bool = False):
    """Build a jitted MULTI-PHASE pipeline ``(state, payload_phases) ->
    (state, reads_phases)`` for a sequence of recurring programs.

    ``programs`` is one recurring program per phase; ``payload_phases``
    is a matching tuple of ``(K_p, n_payloads_p, words)`` uint32 arrays —
    each phase's per-step HOSTW data. The phases run back-to-back as
    chained ``lax.scan``s (one per phase) inside ONE jit, so a whole
    heterogeneous single-subarray workload (e.g. ``PimVM.run_workload``
    on an unsharded VM) costs one XLA dispatch total. ``reads_phases``
    is a tuple of per-phase read pytrees, each with a leading step axis.
    Cached on the first program's compile artifact, keyed by the phase
    digest sequence."""
    compiled = [_as_compiled(p, cfg) for p in programs]
    if not compiled:
        raise ValueError("make_workload_runner needs at least one program")
    if use_kernels is None:
        use_kernels = _default_use_kernels()
    bases = tuple(
        make_runner(c, cfg, use_kernels=use_kernels, interpret=interpret,
                    refresh=refresh, payload_arg=True)
        for c in compiled)
    cache = compiled[0]._runner_cache   # make_runner just ensured it exists
    key = ("workload", tuple(c.program.digest for c in compiled),
           use_kernels, interpret, refresh, cfg)
    if key in cache:
        return cache[key]

    @jax.jit
    def run_workload(state: SubarrayState, payload_phases):
        reads_phases = []
        for base, payload_steps in zip(bases, payload_phases):
            def body(s, p, base=base):
                out, reads = base.traced(s, p)
                return out, reads

            state, reads = jax.lax.scan(body, state, payload_steps)
            reads_phases.append(reads)
        return state, tuple(reads_phases)

    cache[key] = run_workload
    return run_workload


def execute(program, state: SubarrayState | None = None,
            cfg: DDR3Timing = DEFAULT_TIMING, *,
            use_kernels: bool | None = None,
            interpret: bool | None = None, refresh: bool = False,
            verify: bool = False) -> ExecResult:
    """Compile (if needed) and run ``program`` against ``state`` (a fresh
    subarray by default). Meter increments accumulate on the incoming
    ``state.meter``. ``verify=True`` statically lints the stream first
    and raises :class:`~.lint.LintError` on errors."""
    if verify:
        from . import lint
        src = program.program if hasattr(program, "program") else program
        report = lint.lint_program(src)
        if not report.ok:
            raise lint.LintError(report)
    compiled = _as_compiled(program, cfg)
    if state is None:
        state = make_subarray(compiled.num_rows, compiled.words)
    runner = make_runner(compiled, cfg, use_kernels=use_kernels,
                         interpret=interpret, refresh=refresh)
    return runner(state)


def bank_parallel(program, cfg: DDR3Timing = DEFAULT_TIMING, *,
                  use_kernels: bool | None = None,
                  interpret: bool | None = None,
                  refresh: bool = False):
    """§5.1.4 on the compiled path: vmap ONE compiled program across a bank
    batch of states. Returns ``states_batched -> (states, wall_ns,
    energy_nj)`` — wall time is the max over banks, energy the sum."""
    runner = make_runner(program, cfg, use_kernels=use_kernels,
                         interpret=interpret, refresh=refresh)
    vrun = jax.vmap(runner.traced)

    def wrapped(states: SubarrayState):
        out, _ = vrun(states)
        wall_ns = jnp.max(out.meter.time_ns)
        energy_nj = jnp.sum(out.meter.total_energy_nj)
        return out, wall_ns, energy_nj

    return wrapped
