"""Recorded PIM instruction-stream IR.

Instead of executing every ISA command eagerly (one Python-level pytree
transition per command), a :class:`ProgramBuilder` records the command stream
once into a :class:`PimProgram`. The program is then cost-modeled in a single
pass, optimized, fused, and executed as a compiled artifact
(``compile.py`` / ``exec.py``) — the trace-driven architecture of
HBM-PIMulator and SIMDRAM's μProgram abstraction.

The IR stores *primitive* commands only. Composite Ambit ops (AND/OR/XOR/
NOT/MAJ) are macro-expanded at record time into exactly the primitive
sequence ``isa.py`` executes, so a recorded program is command-for-command —
and therefore cost- and bit-identical — to the eager path. The eager ISA in
``isa.py`` is unchanged and remains the shim for old call-sites.

Row operands must be concrete Python ints at record time (negative aliases
like ``isa.T0`` resolve against ``num_rows``, as in the eager path).

Text traces (``to_trace`` / ``from_trace``) use an HBM-PIMulator-style
line-per-command format (see DESIGN.md §6) so external workloads can be
replayed through ``benchmarks/trace_replay.py``. Multi-bank (device-level)
streams serialize as ``pim-trace v2`` — a ``banks=N`` header plus
``BANK <b>`` line prefixes — via ``to_trace_banks``/``from_trace_banks``
(DESIGN.md §7); multi-subarray devices as ``pim-trace v3`` — an extra
``subarrays=S`` header field and ``BANK <b> SUB <s>`` prefixes — via
``to_trace_device``/``from_trace_device`` (DESIGN.md §8). v2/v3 HOSTW
payloads use an RLE zero-page encoding when shorter than plain hex.
Imports validate operands (row ranges, SHIFT delta) with line-numbered
errors instead of letting the executor mis-execute them.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable

import numpy as np

from . import isa
from .state import NUM_ROWS, ROW_WORDS

# Primitive opcodes. DRA copies like ROWCLONE but charges a 2-row MRA.
OP_ISSUE = "issue"
OP_ROWCLONE = "rowclone"
OP_DRA = "dra"
OP_TRA = "tra"
OP_NOT2DCC = "not_to_dcc"
OP_DCC2 = "dcc_to"
OP_SHIFT = "shift"
OP_WRITE = "write_row"
OP_READ = "read_row"
OP_FILL = "fill"          # zero-cost row init (reserve_control_rows)
OP_COPY = "copy"          # LISA row movement; dst may live in another
                          # subarray/bank (device addressing in delta/c)

# COPY's "destination = the slot carrying this stream" sentinel (delta = c =
# COPY_SELF). Programs recorded with it stay local on WHATEVER slot runs
# them — replicating one stream across banks keeps every copy in-bank —
# whereas explicit coordinates (including (0, 0)) always name that device
# slot.
COPY_SELF = -1


def copy_is_local(op: "PimOp") -> bool:
    """True iff a COPY executes inside the single subarray running it:
    self-addressed, or explicitly (0, 0) — which IS the only subarray on
    the eager/compiled paths. The device scheduler additionally treats a
    destination equal to the carrying slot as local (``schedule.py``)."""
    return (op.delta, op.c) in ((COPY_SELF, COPY_SELF), (0, 0))

# Columnar opcode encoding: the fixed integer code of every opcode. Order is
# part of the on-the-wire columnar layout (and of the program digest), so new
# opcodes append — never reorder.
OPCODES = (OP_ISSUE, OP_ROWCLONE, OP_DRA, OP_TRA, OP_NOT2DCC, OP_DCC2,
           OP_SHIFT, OP_WRITE, OP_READ, OP_FILL, OP_COPY)
OP_CODE = {name: i for i, name in enumerate(OPCODES)}

# How many columnar encodings (and digests) were built — regression tests
# assert warm caches never rebuild them.
COLUMN_STATS = {"builds": 0}


@dataclasses.dataclass(frozen=True)
class ProgramColumns:
    """Array-native view of one op stream: an ``(n_ops, 6)`` int64 table
    (columns ``code, a, b, c, delta, payload``; FILL words need the int64
    headroom) plus a 128-bit content digest. Built ONCE per program (at
    ``build``/``concat``/trace-import time, or lazily on first use) so the
    cost pass, fusion, and stream-group hashing all run on arrays instead
    of re-walking Python ``PimOp`` objects."""

    table: np.ndarray
    digest: bytes

    @property
    def code(self) -> np.ndarray:
        return self.table[:, 0]

    @property
    def a(self) -> np.ndarray:
        return self.table[:, 1]

    @property
    def b(self) -> np.ndarray:
        return self.table[:, 2]

    @property
    def c(self) -> np.ndarray:
        return self.table[:, 3]

    @property
    def delta(self) -> np.ndarray:
        return self.table[:, 4]

    @property
    def payload(self) -> np.ndarray:
        return self.table[:, 5]


def _build_columns(ops: tuple) -> ProgramColumns:
    COLUMN_STATS["builds"] += 1
    table = np.empty((len(ops), 6), np.int64)
    for i, o in enumerate(ops):
        table[i, 0] = OP_CODE[o.op]
        table[i, 1] = o.a
        table[i, 2] = o.b
        table[i, 3] = o.c
        table[i, 4] = o.delta
        table[i, 5] = o.payload
    table.setflags(write=False)
    digest = hashlib.blake2b(table.tobytes(), digest_size=16).digest()
    return ProgramColumns(table=table, digest=digest)


# Trace mnemonics (stable on-disk names), one line per command.
_MNEMONIC = {
    OP_ISSUE: "ISSUE", OP_ROWCLONE: "AAP", OP_DRA: "DRA", OP_TRA: "TRA",
    OP_NOT2DCC: "NOT2DCC", OP_DCC2: "DCC2", OP_SHIFT: "SHIFT",
    OP_WRITE: "HOSTW", OP_READ: "HOSTR", OP_FILL: "FILL", OP_COPY: "COPY",
}
_FROM_MNEMONIC = {v: k for k, v in _MNEMONIC.items()}


# -- HOSTW payload encoding (plain hex / RLE zero-page) -----------------------

def rle_encode_payload(row: np.ndarray) -> str:
    """Run-length encode a uint32 row as ``rle:`` + comma-joined tokens:
    ``<hex8>`` for a single word, ``<hex8>x<count>`` for a run. Multi-KB
    HOSTW payloads are mostly zero pages — runs collapse them to one token.
    """
    row = np.asarray(row, dtype=np.uint32)
    toks = []
    i = 0
    while i < row.size:
        j = i + 1
        while j < row.size and row[j] == row[i]:
            j += 1
        word = f"{int(row[i]):08x}"
        toks.append(word if j - i == 1 else f"{word}x{j - i}")
        i = j
    return "rle:" + ",".join(toks)


def decode_payload(tok: str, words: int) -> np.ndarray:
    """Decode a HOSTW payload field: plain little-endian hex or ``rle:``."""
    if not tok.startswith("rle:"):
        payload = np.frombuffer(bytes.fromhex(tok), dtype="<u4")
    else:
        out = []
        for t in tok[4:].split(","):
            word, _, count = t.partition("x")
            w = int(word, 16)
            if not 0 <= w < 2**32:
                raise ValueError(f"RLE word {word!r} is not a 32-bit value")
            out.extend([w] * (int(count) if count else 1))
        payload = np.asarray(out, dtype=np.uint32)
    if payload.shape != (words,):
        raise ValueError(
            f"HOSTW payload is {payload.size} words, "
            f"trace declares {words}")
    return payload.astype(np.uint32)


def _payload_field(row: np.ndarray, rle: bool) -> str:
    plain = np.asarray(row, dtype="<u4").tobytes().hex()
    if not rle:
        return plain
    enc = rle_encode_payload(row)
    return enc if len(enc) < len(plain) else plain


def _parse_operands(op: str, toks: list[str], payloads: "list[np.ndarray]",
                    words: int, num_rows: int, banks: int = 1,
                    subarrays: int = 1) -> "PimOp":
    """Decode one trace line's operands (mnemonic already resolved).

    Operands are validated here so a malformed trace fails at import, not as
    a silent mis-execution downstream: row indices must lie in
    ``[0, num_rows)`` (the executor would otherwise wrap them ``% num_rows``)
    and SHIFT's delta must be exactly ±1 (the migration-cell primitive moves
    one bit; ``_op_rows`` would quietly treat any positive delta as +1).
    """
    def row(tok: str) -> int:
        r = int(tok)
        if not 0 <= r < num_rows:
            raise ValueError(
                f"row index {r} out of range [0, {num_rows})")
        return r

    if op == OP_ISSUE:
        return PimOp(op)
    if op in (OP_ROWCLONE, OP_DRA):
        return PimOp(op, a=row(toks[1]), b=row(toks[2]))
    if op == OP_TRA:
        return PimOp(op, a=row(toks[1]), b=row(toks[2]), c=row(toks[3]))
    if op == OP_NOT2DCC:
        return PimOp(op, a=row(toks[1]))
    if op == OP_DCC2:
        return PimOp(op, b=row(toks[1]))
    if op == OP_SHIFT:
        delta = int(toks[3])
        if delta not in (1, -1):
            raise ValueError(
                f"SHIFT delta must be +1 or -1 (1-bit migration-cell "
                f"primitive), got {delta:+d}")
        return PimOp(op, a=row(toks[1]), b=row(toks[2]), delta=delta)
    if op == OP_COPY:
        dst_bank, dst_sub = int(toks[3]), int(toks[4])
        if (dst_bank, dst_sub) != (COPY_SELF, COPY_SELF) and not (
                0 <= dst_bank < banks and 0 <= dst_sub < subarrays):
            raise ValueError(
                f"COPY destination ({dst_bank}, {dst_sub}) outside the "
                f"device ({banks} banks x {subarrays} subarrays); use "
                f"{COPY_SELF} {COPY_SELF} for a local (self-slot) copy")
        return PimOp(op, a=row(toks[1]), b=row(toks[2]), delta=dst_bank,
                     c=dst_sub)
    if op == OP_WRITE:
        payload = decode_payload(toks[2], words)
        out = PimOp(op, b=row(toks[1]), payload=len(payloads))
        payloads.append(payload)
        return out
    if op == OP_READ:
        return PimOp(op, a=row(toks[1]))
    assert op == OP_FILL, op
    return PimOp(op, b=row(toks[1]), payload=int(toks[2], 16))


@dataclasses.dataclass(frozen=True)
class PimOp:
    """One primitive command. ``a``/``b``/``c`` are absolute row indices
    (src, dst, third TRA row); ``delta`` is the shift direction; ``payload``
    indexes ``PimProgram.payloads`` for WRITE and holds the fill word for
    FILL.

    COPY (LISA row movement) reuses ``delta``/``c`` as the *destination's
    device coordinates* ``(dst_bank, dst_sub)``; the source is always the
    slot whose stream carries the op. ``(COPY_SELF, COPY_SELF)`` addresses
    the carrying slot itself — a local copy on whatever slot runs the
    stream; explicit coordinates (including ``(0, 0)``) always name that
    device slot."""

    op: str
    a: int = 0
    b: int = 0
    c: int = 0
    delta: int = 0
    payload: int = -1

    def reads(self) -> tuple[int, ...]:
        if self.op in (OP_ROWCLONE, OP_DRA, OP_NOT2DCC, OP_SHIFT, OP_READ,
                       OP_COPY):
            return (self.a,)
        if self.op == OP_TRA:
            return (self.a, self.b, self.c)
        return ()

    def writes(self) -> tuple[int, ...]:
        if self.op in (OP_ROWCLONE, OP_DRA, OP_DCC2, OP_SHIFT, OP_WRITE,
                       OP_FILL):
            return (self.b,)
        if self.op == OP_COPY:
            # Cross-slot copies write another subarray's row, not a local one.
            return (self.b,) if copy_is_local(self) else ()
        if self.op == OP_TRA:
            return (self.a, self.b, self.c)
        return ()


@dataclasses.dataclass(frozen=True)
class PimProgram:
    """An immutable recorded command stream for one subarray shape.

    Immutability covers the ``payloads`` data: executor jit constants and
    the scheduler's identity-keyed payload cache key on it never changing.
    ``ProgramBuilder.write_row`` and :meth:`with_payloads` snapshot (copy)
    the rows for you; constructing a ``PimProgram`` directly with arrays
    you keep writing to is a caller bug."""

    ops: tuple[PimOp, ...]
    num_rows: int = NUM_ROWS
    words: int = ROW_WORDS
    payloads: tuple[np.ndarray, ...] = ()

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def columns(self) -> ProgramColumns:
        """Cached columnar encoding (see :class:`ProgramColumns`). Lazily
        built on first access and memoized on the (frozen) instance —
        ``build``/``concat``/trace import warm it eagerly so downstream
        passes never pay the per-op walk twice."""
        cols = getattr(self, "_columns", None)
        if cols is None:
            cols = _build_columns(self.ops)
            object.__setattr__(self, "_columns", cols)
        return cols

    @property
    def digest(self) -> bytes:
        """Stable 128-bit content hash of the op stream (payload *data*
        excluded — that is the stream-group contract). O(1) after the
        columnar encoding is built."""
        return self.columns.digest

    @property
    def payload_digest(self) -> bytes:
        """Stable 128-bit hash of the HOSTW payload *contents* (sizes +
        bits), memoized on the instance. The op-stream :attr:`digest`
        deliberately excludes payload data (the stream-group contract),
        but semantic verdicts (``sem.py``) depend on it — HOSTW bits are
        constants in the truth-table domain — so content-keyed caches
        pair both digests."""
        pd = getattr(self, "_payload_digest", None)
        if pd is None:
            h = hashlib.blake2b(digest_size=16)
            for p in self.payloads:
                h.update(np.int64(p.size).tobytes())
                h.update(np.ascontiguousarray(p, dtype=np.uint32).tobytes())
            pd = h.digest()
            object.__setattr__(self, "_payload_digest", pd)
        return pd

    def with_payloads(self, payloads) -> "PimProgram":
        """Same command stream, different HOSTW payload data (the stream-
        group pattern: one recorded step, per-bank/per-step data). Shares
        this program's cached columnar encoding — no op re-walk, no
        re-hash. The rows are snapshotted (copied), like
        ``ProgramBuilder.write_row``: programs are immutable, and the
        executor's jit constants and the scheduler's identity-keyed
        payload cache rely on recorded data never changing under them."""
        out = PimProgram(
            ops=self.ops, num_rows=self.num_rows, words=self.words,
            payloads=tuple(np.array(p, dtype=np.uint32, copy=True)
                           for p in payloads))
        object.__setattr__(out, "_columns", self.columns)
        return out

    @property
    def trace_lines(self) -> tuple[int, ...] | None:
        """Per-op source line numbers when this program was imported from
        a pim-trace text (``from_trace_*``), else ``None``. Provenance
        only — attached outside the dataclass fields so equality, hashing
        and the columnar digest are unaffected; the lint pass uses it to
        anchor diagnostics to trace lines."""
        return getattr(self, "_trace_lines", None)

    @property
    def n_reads(self) -> int:
        return sum(1 for o in self.ops if o.op == OP_READ)

    def counts(self) -> dict:
        """Static per-opcode histogram (exact, no execution)."""
        out: dict[str, int] = {}
        for o in self.ops:
            out[o.op] = out.get(o.op, 0) + 1
        return out

    @property
    def host_bytes(self) -> int:
        """Off-chip bytes this stream moves: HOSTW payloads + HOSTR rows.
        The number the in-DRAM COPY path drives to zero."""
        n = sum(int(p.size) * 4 for p in self.payloads)
        return n + self.n_reads * self.words * 4

    # -- trace import/export --------------------------------------------------
    def _format_op(self, o: PimOp, rle: bool = False) -> str:
        m = _MNEMONIC[o.op]
        if o.op == OP_ISSUE:
            return m
        if o.op in (OP_ROWCLONE, OP_DRA):
            return f"{m} {o.a} {o.b}"
        if o.op == OP_TRA:
            return f"{m} {o.a} {o.b} {o.c}"
        if o.op == OP_NOT2DCC:
            return f"{m} {o.a}"
        if o.op == OP_DCC2:
            return f"{m} {o.b}"
        if o.op == OP_SHIFT:
            return f"{m} {o.a} {o.b} {o.delta:+d}"
        if o.op == OP_COPY:
            return f"{m} {o.a} {o.b} {o.delta} {o.c}"
        if o.op == OP_WRITE:
            return f"{m} {o.b} {_payload_field(self.payloads[o.payload], rle)}"
        if o.op == OP_READ:
            return f"{m} {o.a}"
        assert o.op == OP_FILL, o.op
        return f"{m} {o.b} {o.payload:08x}"

    def to_trace(self) -> str:
        lines = [f"# pim-trace v1 rows={self.num_rows} words={self.words}"]
        lines.extend(self._format_op(o) for o in self.ops)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_trace(cls, text: str) -> "PimProgram":
        programs = from_trace_banks(text)
        if len(programs) != 1:
            raise ValueError(
                f"trace holds {len(programs)} banks; use "
                "from_trace_banks for multi-bank (pim-trace v2) traces")
        return programs[0]

    def save_trace(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_trace())

    @classmethod
    def load_trace(cls, path) -> "PimProgram":
        with open(path) as f:
            return cls.from_trace(f.read())


def to_trace_banks(programs: "Iterable[PimProgram]") -> str:
    """Export per-bank programs as a ``pim-trace v2`` text.

    Every command line carries a ``BANK <b>`` prefix; the header records the
    bank count. All banks must share one subarray shape (the device model's
    invariant). Single-program exports stay ``to_trace`` (v1) — v2 is the
    superset format for device-level streams. HOSTW payloads use the RLE
    zero-page encoding whenever it is shorter than plain hex.
    """
    programs = list(programs)
    assert programs, "need at least one per-bank program"
    rows, words = programs[0].num_rows, programs[0].words
    for p in programs:
        assert (p.num_rows, p.words) == (rows, words), \
            "banks must share one subarray shape"
    lines = [f"# pim-trace v2 rows={rows} words={words} "
             f"banks={len(programs)}"]
    for b, p in enumerate(programs):
        lines.extend(f"BANK {b} {p._format_op(o, rle=True)}" for o in p.ops)
    return "\n".join(lines) + "\n"


def to_trace_device(programs) -> str:
    """Export per-``(bank, subarray)`` programs as a ``pim-trace v3`` text.

    ``programs`` is a nested ``[bank][subarray]`` sequence (``None`` = idle
    slot); all banks must have the same subarray count and all programs one
    shape. Lines carry ``BANK <b> SUB <s>`` prefixes and the header records
    both axes. HOSTW payloads use the RLE zero-page encoding when shorter.
    """
    programs = [list(bank) for bank in programs]
    assert programs and programs[0], "need at least one bank with subarrays"
    subarrays = len(programs[0])
    assert all(len(bank) == subarrays for bank in programs), \
        "all banks must have the same subarray count"
    shapes = {(p.num_rows, p.words) for bank in programs for p in bank
              if p is not None}
    assert len(shapes) <= 1, "slots must share one subarray shape"
    rows, words = shapes.pop() if shapes else (NUM_ROWS, ROW_WORDS)
    lines = [f"# pim-trace v3 rows={rows} words={words} "
             f"banks={len(programs)} subarrays={subarrays}"]
    for b, bank in enumerate(programs):
        for s, p in enumerate(bank):
            if p is not None:
                lines.extend(f"BANK {b} SUB {s} {p._format_op(o, rle=True)}"
                             for o in p.ops)
    return "\n".join(lines) + "\n"


def _parse_trace(text: str):
    """Shared v1/v2/v3 parser → (per-slot ops/payloads, rows, words, banks,
    subarrays). Slot key = (bank, sub); unprefixed lines fall to (0, 0)."""
    num_rows, words, banks, subarrays = NUM_ROWS, ROW_WORDS, 1, 1
    ops: dict[tuple[int, int], list[PimOp]] = {}
    payloads: dict[tuple[int, int], list[np.ndarray]] = {}
    lines: dict[tuple[int, int], list[int]] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("//")[0].strip()
        if line.startswith("#"):
            if "pim-trace" in line:
                for tok in line.split():
                    if tok.startswith("rows="):
                        num_rows = int(tok[5:])
                    elif tok.startswith("words="):
                        words = int(tok[6:])
                    elif tok.startswith("banks="):
                        banks = int(tok[6:])
                        if banks < 1:
                            raise ValueError(
                                f"trace line {lineno}: banks={banks} "
                                "must be >= 1")
                    elif tok.startswith("subarrays="):
                        subarrays = int(tok[10:])
                        if subarrays < 1:
                            raise ValueError(
                                f"trace line {lineno}: subarrays="
                                f"{subarrays} must be >= 1")
            continue
        if not line:
            continue
        toks = line.split()
        if toks[0] == "PIM":      # HBM-PIMulator-style prefix is accepted
            toks = toks[1:]
        bank = sub = 0
        try:
            if toks and toks[0].upper() == "BANK":
                bank = int(toks[1])
                toks = toks[2:]
                if not 0 <= bank < banks:
                    raise ValueError(
                        f"bank {bank} out of range [0, {banks}) — is the "
                        "header's banks= count right?")
            if toks and toks[0].upper() == "SUB":
                sub = int(toks[1])
                toks = toks[2:]
                if not 0 <= sub < subarrays:
                    raise ValueError(
                        f"subarray {sub} out of range [0, {subarrays}) — "
                        "is the header's subarrays= count right?")
            name = toks[0].upper() if toks else ""
            if name not in _FROM_MNEMONIC:
                raise ValueError(f"unknown trace mnemonic {name!r}")
            op = _FROM_MNEMONIC[name]
            key = (bank, sub)
            ops.setdefault(key, []).append(_parse_operands(
                op, toks, payloads.setdefault(key, []), words, num_rows,
                banks, subarrays))
            lines.setdefault(key, []).append(lineno)
        except (IndexError, ValueError) as e:
            msg = "missing operand(s)" if isinstance(e, IndexError) else e
            raise ValueError(
                f"trace line {lineno} ({raw.strip()!r}): {msg}") from e

    def slot(b, s):
        prog = PimProgram(ops=tuple(ops.get((b, s), ())), num_rows=num_rows,
                          words=words,
                          payloads=tuple(payloads.get((b, s), ())))
        prog.columns            # warm the columnar encoding + digest once
        # Trace-line provenance for diagnostics (lint.py); attribute, not
        # a field, so program equality/digest semantics are untouched.
        object.__setattr__(prog, "_trace_lines",
                           tuple(lines.get((b, s), ())))
        return prog

    return slot, banks, subarrays


def from_trace_banks(text: str) -> tuple[PimProgram, ...]:
    """Parse a ``pim-trace`` text into per-bank programs.

    Accepts v1 (no ``BANK`` prefixes → one program) and v2 (``banks=N``
    header, ``BANK <b>`` prefixed command lines; unprefixed lines fall to
    bank 0). Multi-subarray (v3) traces are refused with a pointer to
    ``from_trace_device``. Malformed lines raise line-numbered errors.
    """
    slot, banks, subarrays = _parse_trace(text)
    if subarrays != 1:
        raise ValueError(
            f"trace declares {subarrays} subarrays per bank; use "
            "from_trace_device for multi-subarray (pim-trace v3) traces")
    return tuple(slot(b, 0) for b in range(banks))


def from_trace_device(text: str) -> tuple[tuple[PimProgram, ...], ...]:
    """Parse any ``pim-trace`` text into nested ``[bank][subarray]``
    programs (v1 → one bank/one subarray; v2 → N banks/one subarray)."""
    slot, banks, subarrays = _parse_trace(text)
    return tuple(tuple(slot(b, s) for s in range(subarrays))
                 for b in range(banks))


class ProgramBuilder:
    """Records the ISA surface into a :class:`PimProgram`.

    Method names and operand orders mirror ``isa.py`` minus the threaded
    state (``rowclone(src, dst)``, ``shift(src, dst, delta)``, ...), and the
    Ambit composites expand to the identical primitive sequences, so swapping
    ``isa.xxx(state, ...)`` for ``builder.xxx(...)`` records exactly the
    commands the eager path would execute.

    Operand validation matches the trace importers (``_parse_operands``)
    with op-index provenance: rows must lie in ``[-num_rows, num_rows)``
    (negative values alias the reserved tail, e.g. ``isa.T0``), SHIFT's
    delta must be exactly ±1, HOSTW payloads must be ``(words,)`` rows.
    ``verify=True`` additionally lints the stream at :meth:`build` and
    raises :class:`~.lint.LintError` on any error-severity diagnostic.
    """

    def __init__(self, num_rows: int = NUM_ROWS, words: int = ROW_WORDS,
                 *, verify: bool = False):
        self.num_rows = int(num_rows)
        self.words = int(words)
        self.verify = bool(verify)
        self._ops: list[PimOp] = []
        self._payloads: list[np.ndarray] = []
        self._n_reads = 0

    def _resolve(self, r) -> int:
        if not isinstance(r, (int, np.integer)):
            raise TypeError(
                f"IR recording needs concrete int row indices, got {type(r)};"
                " use the eager isa.* path for traced row operands")
        r = int(r)
        if not -self.num_rows <= r < self.num_rows:
            # Same contract the trace importer enforces, with op-index
            # provenance; negatives down to -num_rows alias the reserved
            # tail (isa.C0/C1/T0..T3) and resolve modulo num_rows.
            raise ValueError(
                f"op {len(self._ops)}: row index {r} out of range "
                f"[{-self.num_rows}, {self.num_rows}) — negative rows "
                "alias the reserved control/scratch tail")
        return r % self.num_rows

    def __len__(self) -> int:
        return len(self._ops)

    def build(self) -> PimProgram:
        prog = PimProgram(ops=tuple(self._ops), num_rows=self.num_rows,
                          words=self.words, payloads=tuple(self._payloads))
        prog.columns            # warm the columnar encoding + digest once
        if self.verify:
            from . import lint      # lazy: lint imports this module
            report = lint.lint_program(prog)
            if not report.ok:
                raise lint.LintError(report)
        return prog

    # -- primitives -----------------------------------------------------------
    def issue(self) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_ISSUE))
        return self

    def rowclone(self, src, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_ROWCLONE, a=self._resolve(src),
                               b=self._resolve(dst)))
        return self

    def dra(self, src, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_DRA, a=self._resolve(src),
                               b=self._resolve(dst)))
        return self

    def tra(self, r1, r2, r3) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_TRA, a=self._resolve(r1),
                               b=self._resolve(r2), c=self._resolve(r3)))
        return self

    def not_to_dcc(self, src) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_NOT2DCC, a=self._resolve(src)))
        return self

    def dcc_to(self, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_DCC2, b=self._resolve(dst)))
        return self

    def copy_row(self, src, dst, dst_bank: int = COPY_SELF,
                 dst_sub: int = COPY_SELF) -> "ProgramBuilder":
        """LISA row movement: ``dst`` row of slot ``(dst_bank, dst_sub)``
        <- ``src`` row of the slot executing this stream. The default
        destination is the *carrying slot itself* (``COPY_SELF``), so a
        stream replicated across banks keeps its copies local everywhere;
        explicit coordinates name a device slot and are only executable by
        the device scheduler (``schedule.py``), which drains cross-slot
        copies after the step's in-bank compute."""
        dst_bank, dst_sub = int(dst_bank), int(dst_sub)
        if (dst_bank, dst_sub) != (COPY_SELF, COPY_SELF) and (
                dst_bank < 0 or dst_sub < 0):
            raise ValueError(
                f"COPY destination ({dst_bank}, {dst_sub}) must be "
                f"non-negative coordinates, or ({COPY_SELF}, {COPY_SELF}) "
                "for the carrying slot")
        self._ops.append(PimOp(OP_COPY, a=self._resolve(src),
                               b=self._resolve(dst), delta=dst_bank,
                               c=dst_sub))
        return self

    def shift(self, src, dst, delta: int = +1) -> "ProgramBuilder":
        if delta not in (+1, -1):
            raise ValueError(
                f"op {len(self._ops)}: SHIFT delta must be +1 or -1 "
                f"(1-bit migration-cell primitive), got {delta:+d}")
        self._ops.append(PimOp(OP_SHIFT, a=self._resolve(src),
                               b=self._resolve(dst), delta=int(delta)))
        return self

    def write_row(self, dst, row) -> "ProgramBuilder":
        # snapshot (copy) the payload: programs are immutable, and both the
        # executor's jit constants and the scheduler's identity-keyed
        # payload cache rely on the recorded data never changing under them
        row = np.array(row, dtype=np.uint32, copy=True)
        if row.shape != (self.words,):
            raise ValueError(
                f"op {len(self._ops)}: HOSTW payload shape {row.shape} "
                f"!= ({self.words},)")
        self._ops.append(PimOp(OP_WRITE, b=self._resolve(dst),
                               payload=len(self._payloads)))
        self._payloads.append(row)
        return self

    def read_row(self, src) -> int:
        """Record a host read; returns the read slot index into
        ``ExecResult.reads``."""
        self._ops.append(PimOp(OP_READ, a=self._resolve(src)))
        slot = self._n_reads
        self._n_reads += 1
        return slot

    def fill(self, dst, word: int) -> "ProgramBuilder":
        """Zero-cost row init with a repeated 32-bit word (setup, not a DRAM
        command — mirrors ``reserve_control_rows`` mutating bits meter-free)."""
        self._ops.append(PimOp(OP_FILL, b=self._resolve(dst),
                               payload=int(word) & 0xFFFF_FFFF))
        return self

    def reserve_control_rows(self) -> "ProgramBuilder":
        return self.fill(isa.C0, 0).fill(isa.C1, 0xFFFF_FFFF)

    # -- composites (identical expansion to isa.py) ---------------------------
    def ambit_maj(self, a, b, c, dst) -> "ProgramBuilder":
        return (self.rowclone(a, isa.T0).rowclone(b, isa.T1)
                .rowclone(c, isa.T2).tra(isa.T0, isa.T1, isa.T2)
                .rowclone(isa.T0, dst))

    def ambit_and(self, a, b, dst) -> "ProgramBuilder":
        return self.ambit_maj(a, b, isa.C0, dst)

    def ambit_or(self, a, b, dst) -> "ProgramBuilder":
        return self.ambit_maj(a, b, isa.C1, dst)

    def ambit_not(self, src, dst) -> "ProgramBuilder":
        return self.not_to_dcc(src).dcc_to(dst)

    def ambit_xor(self, a, b, dst) -> "ProgramBuilder":
        scratch = {self._resolve(t)
                   for t in (isa.T0, isa.T1, isa.T2, isa.T3)}
        clash = {self._resolve(r) for r in (a, b, dst)} & scratch
        if clash:
            raise ValueError(
                f"ambit_xor operands alias its scratch rows {sorted(clash)}; "
                "the T0..T3 expansion would clobber them mid-sequence")
        return (self.ambit_or(a, b, isa.T3).ambit_and(a, b, dst)
                .ambit_not(dst, dst).ambit_and(isa.T3, dst, dst))

    # -- convenience ----------------------------------------------------------
    def shift_k(self, src, dst, k: int) -> "ProgramBuilder":
        """|k| repeated 1-bit shifts (k=0 degenerates to a copy), mirroring
        ``program.shift_k``."""
        if k == 0:
            return self.rowclone(src, dst)
        delta = 1 if k > 0 else -1
        self.shift(src, dst, delta)
        for _ in range(abs(k) - 1):
            self.shift(dst, dst, delta)
        return self


def record(fn, num_rows: int = NUM_ROWS, words: int = ROW_WORDS, *,
           verify: bool = False) -> PimProgram:
    """Run ``fn(builder)`` and return the recorded program. ``verify=True``
    lints the stream and raises :class:`~.lint.LintError` on errors."""
    b = ProgramBuilder(num_rows, words, verify=verify)
    fn(b)
    return b.build()


def sequence_digest(digests: Iterable[bytes]) -> bytes:
    """Stable 128-bit digest of an ORDERED digest sequence — the O(1)
    identity of a concatenated or multi-phase stream, folded from the
    parts' cached 128-bit digests instead of re-hashing any op table."""
    h = hashlib.blake2b(digest_size=16)
    for d in digests:
        h.update(d)
    return h.digest()


def concat(programs: Iterable[PimProgram]) -> PimProgram:
    """Concatenate same-shape programs into one stream.

    Columnar fast path: the output's op table is stitched from the
    children's CACHED column tables (only WRITE payload indices are
    rebased), so concatenating warm programs never re-walks ops through
    ``_build_columns`` — ``ir.COLUMN_STATS`` stays flat on recurring
    multi-phase plans that fuse compute+gather streams every call."""
    programs = list(programs)
    assert programs, "need at least one program"
    if len(programs) == 1:
        return programs[0]
    rows, words = programs[0].num_rows, programs[0].words
    ops: list[PimOp] = []
    payloads: list[np.ndarray] = []
    tables: list[np.ndarray] = []
    write_code = OP_CODE[OP_WRITE]
    for p in programs:
        assert (p.num_rows, p.words) == (rows, words), "shape mismatch"
        off = len(payloads)
        table = p.columns.table
        if off and len(p.payloads):
            table = table.copy()
            table[table[:, 0] == write_code, 5] += off
            for o in p.ops:
                if o.op == OP_WRITE:
                    o = dataclasses.replace(o, payload=o.payload + off)
                ops.append(o)
        else:
            ops.extend(p.ops)
        tables.append(table)
        payloads.extend(p.payloads)
    table = np.concatenate(tables, axis=0)
    table.setflags(write=False)
    digest = hashlib.blake2b(table.tobytes(), digest_size=16).digest()
    out = PimProgram(ops=tuple(ops), num_rows=rows, words=words,
                     payloads=tuple(payloads))
    object.__setattr__(out, "_columns",
                       ProgramColumns(table=table, digest=digest))
    return out
