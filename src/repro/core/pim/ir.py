"""Recorded PIM instruction-stream IR.

Instead of executing every ISA command eagerly (one Python-level pytree
transition per command), a :class:`ProgramBuilder` records the command stream
once into a :class:`PimProgram`. The program is then cost-modeled in a single
pass, optimized, fused, and executed as a compiled artifact
(``compile.py`` / ``exec.py``) — the trace-driven architecture of
HBM-PIMulator and SIMDRAM's μProgram abstraction.

The IR stores *primitive* commands only. Composite Ambit ops (AND/OR/XOR/
NOT/MAJ) are macro-expanded at record time into exactly the primitive
sequence ``isa.py`` executes, so a recorded program is command-for-command —
and therefore cost- and bit-identical — to the eager path. The eager ISA in
``isa.py`` is unchanged and remains the shim for old call-sites.

Row operands must be concrete Python ints at record time (negative aliases
like ``isa.T0`` resolve against ``num_rows``, as in the eager path).

Text traces (``to_trace`` / ``from_trace``) use an HBM-PIMulator-style
line-per-command format (see DESIGN.md §6) so external workloads can be
replayed through ``benchmarks/trace_replay.py``. Multi-bank (device-level)
streams serialize as ``pim-trace v2`` — a ``banks=N`` header plus
``BANK <b>`` line prefixes — via ``to_trace_banks``/``from_trace_banks``
(DESIGN.md §7). Imports validate operands (row ranges, SHIFT delta) with
line-numbered errors instead of letting the executor mis-execute them.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from . import isa
from .state import NUM_ROWS, ROW_WORDS

# Primitive opcodes. DRA copies like ROWCLONE but charges a 2-row MRA.
OP_ISSUE = "issue"
OP_ROWCLONE = "rowclone"
OP_DRA = "dra"
OP_TRA = "tra"
OP_NOT2DCC = "not_to_dcc"
OP_DCC2 = "dcc_to"
OP_SHIFT = "shift"
OP_WRITE = "write_row"
OP_READ = "read_row"
OP_FILL = "fill"          # zero-cost row init (reserve_control_rows)

# Trace mnemonics (stable on-disk names), one line per command.
_MNEMONIC = {
    OP_ISSUE: "ISSUE", OP_ROWCLONE: "AAP", OP_DRA: "DRA", OP_TRA: "TRA",
    OP_NOT2DCC: "NOT2DCC", OP_DCC2: "DCC2", OP_SHIFT: "SHIFT",
    OP_WRITE: "HOSTW", OP_READ: "HOSTR", OP_FILL: "FILL",
}
_FROM_MNEMONIC = {v: k for k, v in _MNEMONIC.items()}


def _parse_operands(op: str, toks: list[str], payloads: "list[np.ndarray]",
                    words: int, num_rows: int) -> "PimOp":
    """Decode one trace line's operands (mnemonic already resolved).

    Operands are validated here so a malformed trace fails at import, not as
    a silent mis-execution downstream: row indices must lie in
    ``[0, num_rows)`` (the executor would otherwise wrap them ``% num_rows``)
    and SHIFT's delta must be exactly ±1 (the migration-cell primitive moves
    one bit; ``_op_rows`` would quietly treat any positive delta as +1).
    """
    def row(tok: str) -> int:
        r = int(tok)
        if not 0 <= r < num_rows:
            raise ValueError(
                f"row index {r} out of range [0, {num_rows})")
        return r

    if op == OP_ISSUE:
        return PimOp(op)
    if op in (OP_ROWCLONE, OP_DRA):
        return PimOp(op, a=row(toks[1]), b=row(toks[2]))
    if op == OP_TRA:
        return PimOp(op, a=row(toks[1]), b=row(toks[2]), c=row(toks[3]))
    if op == OP_NOT2DCC:
        return PimOp(op, a=row(toks[1]))
    if op == OP_DCC2:
        return PimOp(op, b=row(toks[1]))
    if op == OP_SHIFT:
        delta = int(toks[3])
        if delta not in (1, -1):
            raise ValueError(
                f"SHIFT delta must be +1 or -1 (1-bit migration-cell "
                f"primitive), got {delta:+d}")
        return PimOp(op, a=row(toks[1]), b=row(toks[2]), delta=delta)
    if op == OP_WRITE:
        payload = np.frombuffer(bytes.fromhex(toks[2]), dtype="<u4")
        if payload.shape != (words,):
            raise ValueError(
                f"HOSTW payload is {payload.size} words, "
                f"trace declares {words}")
        out = PimOp(op, b=row(toks[1]), payload=len(payloads))
        payloads.append(payload.astype(np.uint32))
        return out
    if op == OP_READ:
        return PimOp(op, a=row(toks[1]))
    assert op == OP_FILL, op
    return PimOp(op, b=row(toks[1]), payload=int(toks[2], 16))


@dataclasses.dataclass(frozen=True)
class PimOp:
    """One primitive command. ``a``/``b``/``c`` are absolute row indices
    (src, dst, third TRA row); ``delta`` is the shift direction; ``payload``
    indexes ``PimProgram.payloads`` for WRITE and holds the fill word for
    FILL."""

    op: str
    a: int = 0
    b: int = 0
    c: int = 0
    delta: int = 0
    payload: int = -1

    def reads(self) -> tuple[int, ...]:
        if self.op in (OP_ROWCLONE, OP_DRA, OP_NOT2DCC, OP_SHIFT, OP_READ):
            return (self.a,)
        if self.op == OP_TRA:
            return (self.a, self.b, self.c)
        return ()

    def writes(self) -> tuple[int, ...]:
        if self.op in (OP_ROWCLONE, OP_DRA, OP_DCC2, OP_SHIFT, OP_WRITE,
                       OP_FILL):
            return (self.b,)
        if self.op == OP_TRA:
            return (self.a, self.b, self.c)
        return ()


@dataclasses.dataclass(frozen=True)
class PimProgram:
    """An immutable recorded command stream for one subarray shape."""

    ops: tuple[PimOp, ...]
    num_rows: int = NUM_ROWS
    words: int = ROW_WORDS
    payloads: tuple[np.ndarray, ...] = ()

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def n_reads(self) -> int:
        return sum(1 for o in self.ops if o.op == OP_READ)

    def counts(self) -> dict:
        """Static per-opcode histogram (exact, no execution)."""
        out: dict[str, int] = {}
        for o in self.ops:
            out[o.op] = out.get(o.op, 0) + 1
        return out

    # -- trace import/export --------------------------------------------------
    def _format_op(self, o: PimOp) -> str:
        m = _MNEMONIC[o.op]
        if o.op == OP_ISSUE:
            return m
        if o.op in (OP_ROWCLONE, OP_DRA):
            return f"{m} {o.a} {o.b}"
        if o.op == OP_TRA:
            return f"{m} {o.a} {o.b} {o.c}"
        if o.op == OP_NOT2DCC:
            return f"{m} {o.a}"
        if o.op == OP_DCC2:
            return f"{m} {o.b}"
        if o.op == OP_SHIFT:
            return f"{m} {o.a} {o.b} {o.delta:+d}"
        if o.op == OP_WRITE:
            data = self.payloads[o.payload].astype("<u4").tobytes().hex()
            return f"{m} {o.b} {data}"
        if o.op == OP_READ:
            return f"{m} {o.a}"
        assert o.op == OP_FILL, o.op
        return f"{m} {o.b} {o.payload:08x}"

    def to_trace(self) -> str:
        lines = [f"# pim-trace v1 rows={self.num_rows} words={self.words}"]
        lines.extend(self._format_op(o) for o in self.ops)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_trace(cls, text: str) -> "PimProgram":
        programs = from_trace_banks(text)
        if len(programs) != 1:
            raise ValueError(
                f"trace holds {len(programs)} banks; use "
                "from_trace_banks for multi-bank (pim-trace v2) traces")
        return programs[0]

    def save_trace(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_trace())

    @classmethod
    def load_trace(cls, path) -> "PimProgram":
        with open(path) as f:
            return cls.from_trace(f.read())


def to_trace_banks(programs: "Iterable[PimProgram]") -> str:
    """Export per-bank programs as a ``pim-trace v2`` text.

    Every command line carries a ``BANK <b>`` prefix; the header records the
    bank count. All banks must share one subarray shape (the device model's
    invariant). Single-program exports stay ``to_trace`` (v1) — v2 is the
    superset format for device-level streams.
    """
    programs = list(programs)
    assert programs, "need at least one per-bank program"
    rows, words = programs[0].num_rows, programs[0].words
    for p in programs:
        assert (p.num_rows, p.words) == (rows, words), \
            "banks must share one subarray shape"
    lines = [f"# pim-trace v2 rows={rows} words={words} "
             f"banks={len(programs)}"]
    for b, p in enumerate(programs):
        lines.extend(f"BANK {b} {p._format_op(o)}" for o in p.ops)
    return "\n".join(lines) + "\n"


def from_trace_banks(text: str) -> tuple[PimProgram, ...]:
    """Parse a ``pim-trace`` text into per-bank programs.

    Accepts v1 (no ``BANK`` prefixes → one program) and v2 (``banks=N``
    header, ``BANK <b>`` prefixed command lines; unprefixed lines fall to
    bank 0). Malformed lines raise line-numbered ``ValueError``s.
    """
    num_rows, words, banks = NUM_ROWS, ROW_WORDS, 1
    ops: dict[int, list[PimOp]] = {}
    payloads: dict[int, list[np.ndarray]] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("//")[0].strip()
        if line.startswith("#"):
            if "pim-trace" in line:
                for tok in line.split():
                    if tok.startswith("rows="):
                        num_rows = int(tok[5:])
                    elif tok.startswith("words="):
                        words = int(tok[6:])
                    elif tok.startswith("banks="):
                        banks = int(tok[6:])
                        if banks < 1:
                            raise ValueError(
                                f"trace line {lineno}: banks={banks} "
                                "must be >= 1")
            continue
        if not line:
            continue
        toks = line.split()
        if toks[0] == "PIM":      # HBM-PIMulator-style prefix is accepted
            toks = toks[1:]
        bank = 0
        try:
            if toks and toks[0].upper() == "BANK":
                bank = int(toks[1])
                toks = toks[2:]
                if not 0 <= bank < banks:
                    raise ValueError(
                        f"bank {bank} out of range [0, {banks}) — is the "
                        "header's banks= count right?")
            name = toks[0].upper() if toks else ""
            if name not in _FROM_MNEMONIC:
                raise ValueError(f"unknown trace mnemonic {name!r}")
            op = _FROM_MNEMONIC[name]
            ops.setdefault(bank, []).append(_parse_operands(
                op, toks, payloads.setdefault(bank, []), words, num_rows))
        except (IndexError, ValueError) as e:
            msg = "missing operand(s)" if isinstance(e, IndexError) else e
            raise ValueError(
                f"trace line {lineno} ({raw.strip()!r}): {msg}") from e
    return tuple(
        PimProgram(ops=tuple(ops.get(b, ())), num_rows=num_rows, words=words,
                   payloads=tuple(payloads.get(b, ())))
        for b in range(banks))


class ProgramBuilder:
    """Records the ISA surface into a :class:`PimProgram`.

    Method names and operand orders mirror ``isa.py`` minus the threaded
    state (``rowclone(src, dst)``, ``shift(src, dst, delta)``, ...), and the
    Ambit composites expand to the identical primitive sequences, so swapping
    ``isa.xxx(state, ...)`` for ``builder.xxx(...)`` records exactly the
    commands the eager path would execute.
    """

    def __init__(self, num_rows: int = NUM_ROWS, words: int = ROW_WORDS):
        self.num_rows = int(num_rows)
        self.words = int(words)
        self._ops: list[PimOp] = []
        self._payloads: list[np.ndarray] = []
        self._n_reads = 0

    def _resolve(self, r) -> int:
        if not isinstance(r, (int, np.integer)):
            raise TypeError(
                f"IR recording needs concrete int row indices, got {type(r)};"
                " use the eager isa.* path for traced row operands")
        return int(r) % self.num_rows

    def __len__(self) -> int:
        return len(self._ops)

    def build(self) -> PimProgram:
        return PimProgram(ops=tuple(self._ops), num_rows=self.num_rows,
                          words=self.words, payloads=tuple(self._payloads))

    # -- primitives -----------------------------------------------------------
    def issue(self) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_ISSUE))
        return self

    def rowclone(self, src, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_ROWCLONE, a=self._resolve(src),
                               b=self._resolve(dst)))
        return self

    def dra(self, src, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_DRA, a=self._resolve(src),
                               b=self._resolve(dst)))
        return self

    def tra(self, r1, r2, r3) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_TRA, a=self._resolve(r1),
                               b=self._resolve(r2), c=self._resolve(r3)))
        return self

    def not_to_dcc(self, src) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_NOT2DCC, a=self._resolve(src)))
        return self

    def dcc_to(self, dst) -> "ProgramBuilder":
        self._ops.append(PimOp(OP_DCC2, b=self._resolve(dst)))
        return self

    def shift(self, src, dst, delta: int = +1) -> "ProgramBuilder":
        assert delta in (+1, -1), "the migration-cell shift moves exactly 1 bit"
        self._ops.append(PimOp(OP_SHIFT, a=self._resolve(src),
                               b=self._resolve(dst), delta=int(delta)))
        return self

    def write_row(self, dst, row) -> "ProgramBuilder":
        row = np.asarray(row, dtype=np.uint32)
        assert row.shape == (self.words,), (row.shape, self.words)
        self._ops.append(PimOp(OP_WRITE, b=self._resolve(dst),
                               payload=len(self._payloads)))
        self._payloads.append(row)
        return self

    def read_row(self, src) -> int:
        """Record a host read; returns the read slot index into
        ``ExecResult.reads``."""
        self._ops.append(PimOp(OP_READ, a=self._resolve(src)))
        slot = self._n_reads
        self._n_reads += 1
        return slot

    def fill(self, dst, word: int) -> "ProgramBuilder":
        """Zero-cost row init with a repeated 32-bit word (setup, not a DRAM
        command — mirrors ``reserve_control_rows`` mutating bits meter-free)."""
        self._ops.append(PimOp(OP_FILL, b=self._resolve(dst),
                               payload=int(word) & 0xFFFF_FFFF))
        return self

    def reserve_control_rows(self) -> "ProgramBuilder":
        return self.fill(isa.C0, 0).fill(isa.C1, 0xFFFF_FFFF)

    # -- composites (identical expansion to isa.py) ---------------------------
    def ambit_maj(self, a, b, c, dst) -> "ProgramBuilder":
        return (self.rowclone(a, isa.T0).rowclone(b, isa.T1)
                .rowclone(c, isa.T2).tra(isa.T0, isa.T1, isa.T2)
                .rowclone(isa.T0, dst))

    def ambit_and(self, a, b, dst) -> "ProgramBuilder":
        return self.ambit_maj(a, b, isa.C0, dst)

    def ambit_or(self, a, b, dst) -> "ProgramBuilder":
        return self.ambit_maj(a, b, isa.C1, dst)

    def ambit_not(self, src, dst) -> "ProgramBuilder":
        return self.not_to_dcc(src).dcc_to(dst)

    def ambit_xor(self, a, b, dst) -> "ProgramBuilder":
        scratch = {self._resolve(t)
                   for t in (isa.T0, isa.T1, isa.T2, isa.T3)}
        clash = {self._resolve(r) for r in (a, b, dst)} & scratch
        if clash:
            raise ValueError(
                f"ambit_xor operands alias its scratch rows {sorted(clash)}; "
                "the T0..T3 expansion would clobber them mid-sequence")
        return (self.ambit_or(a, b, isa.T3).ambit_and(a, b, dst)
                .ambit_not(dst, dst).ambit_and(isa.T3, dst, dst))

    # -- convenience ----------------------------------------------------------
    def shift_k(self, src, dst, k: int) -> "ProgramBuilder":
        """|k| repeated 1-bit shifts (k=0 degenerates to a copy), mirroring
        ``program.shift_k``."""
        if k == 0:
            return self.rowclone(src, dst)
        delta = 1 if k > 0 else -1
        self.shift(src, dst, delta)
        for _ in range(abs(k) - 1):
            self.shift(dst, dst, delta)
        return self


def record(fn, num_rows: int = NUM_ROWS, words: int = ROW_WORDS) -> PimProgram:
    """Run ``fn(builder)`` and return the recorded program."""
    b = ProgramBuilder(num_rows, words)
    fn(b)
    return b.build()


def concat(programs: Iterable[PimProgram]) -> PimProgram:
    """Concatenate same-shape programs into one stream."""
    programs = list(programs)
    assert programs, "need at least one program"
    rows, words = programs[0].num_rows, programs[0].words
    ops: list[PimOp] = []
    payloads: list[np.ndarray] = []
    for p in programs:
        assert (p.num_rows, p.words) == (rows, words), "shape mismatch"
        off = len(payloads)
        for o in p.ops:
            if o.op == OP_WRITE:
                o = dataclasses.replace(o, payload=o.payload + off)
            ops.append(o)
        payloads.extend(p.payloads)
    return PimProgram(ops=tuple(ops), num_rows=rows, words=words,
                      payloads=tuple(payloads))
