"""The in-DRAM PIM command ISA.

Primitive commands (each advances the DDR3 cost meter):

    rowclone(src, dst)            AAP — intra-subarray copy (RowClone-FPM)
    tra(r1, r2, r3)               triple-row activation → MAJ3, destructive
    dra(src, dst)                 dual-row activation (RowClone variant)
    not_to_dcc(src) / dcc_to(dst) Ambit NOT via the dual-contact-cell row
    shift(src, dst, delta=±1)     THE PAPER'S PRIMITIVE — 4 AAPs through the
                                  two migration rows
    write_row / read_row          host <-> row buffer (burst energy)

Composite Ambit ops built from primitives (costs emerge from the sequence):

    ambit_and / ambit_or / ambit_maj / ambit_xor / ambit_not

Row index arguments may be Python ints or traced int32 scalars; all commands
are functional (state in, state out) and jit/vmap/shard-compatible.

Row-address map (matching the paper's Figure 1): data rows 0..R-1 are
``state.bits``; the migration rows and the DCC row are held out-of-band in
dedicated fields. Two reserved data rows serve as Ambit control rows:
row R-1 = C0 (all zeros), row R-2 = C1 (all ones); ``reserve_control_rows``
initializes them. Rows R-3, R-4, R-5 are the Ambit scratch (T0, T1, T2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .state import EVEN_MASK, ODD_MASK, SubarrayState, make_subarray
from .timing import (DDR3Timing, DEFAULT_TIMING, charge_aap, charge_burst,
                     charge_copy, charge_issue, charge_mra, charge_shift)

# Reserved row aliases (relative to num_rows R).
C0 = -1   # constant zeros
C1 = -2   # constant ones
T0 = -3   # scratch
T1 = -4
T2 = -5
T3 = -6   # extra scratch (survives ambit_maj, which clobbers T0..T2)


def resolve(state: SubarrayState, r) -> jax.Array:
    """Resolve possibly-negative row aliases to absolute indices."""
    return jnp.asarray(r) % state.num_rows


def reserve_control_rows(state: SubarrayState) -> SubarrayState:
    bits = state.bits
    bits = bits.at[-1].set(jnp.zeros((state.words,), jnp.uint32))
    bits = bits.at[-2].set(jnp.full((state.words,), 0xFFFF_FFFF, jnp.uint32))
    return SubarrayState(bits=bits, mig_top=state.mig_top,
                         mig_bot=state.mig_bot, dcc=state.dcc,
                         meter=state.meter)


# ---------------------------------------------------------------------------
# Row-level helpers (pure bit math on packed uint32 rows)
# ---------------------------------------------------------------------------

def shift_row_words(row: jax.Array, delta: int) -> jax.Array:
    """Shift a packed row by ``delta`` columns (+1 = toward higher column).

    Little-endian bit order: +1 column == left shift within each word with the
    carry bit (bit 31) propagated into bit 0 of the *next* word. Edge bits
    fall off (the last migration cell has no partner bitline — fill 0).
    """
    row = row.astype(jnp.uint32)
    if delta == 0:
        return row
    k = abs(int(delta))
    kw, kb = divmod(k, 32)

    def word_shift(x, up):  # shift whole words along the row axis, 0 fill
        if up == 0:
            return x
        if abs(up) >= x.shape[-1]:   # whole row shifted out (e.g. fused k≥32W)
            return jnp.zeros_like(x)
        pad = jnp.zeros(x.shape[:-1] + (abs(up),), jnp.uint32)
        if up > 0:
            return jnp.concatenate([pad, x[..., :-up]], axis=-1)
        return jnp.concatenate([x[..., -up:], pad], axis=-1)

    if delta > 0:
        x = word_shift(row, kw)
        if kb:
            carry = word_shift(x, 1) >> jnp.uint32(32 - kb)
            x = (x << jnp.uint32(kb)) | carry
        return x
    x = word_shift(row, -kw)
    if kb:
        carry = word_shift(x, -1) << jnp.uint32(32 - kb)
        x = (x >> jnp.uint32(kb)) | carry
    return x


def maj3_words(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    return (a & b) | (b & c) | (a & c)


# ---------------------------------------------------------------------------
# Primitive commands
# ---------------------------------------------------------------------------

def _with(state: SubarrayState, *, bits=None, mig_top=None, mig_bot=None,
          dcc=None, meter=None) -> SubarrayState:
    return SubarrayState(
        bits=state.bits if bits is None else bits,
        mig_top=state.mig_top if mig_top is None else mig_top,
        mig_bot=state.mig_bot if mig_bot is None else mig_bot,
        dcc=state.dcc if dcc is None else dcc,
        meter=state.meter if meter is None else meter,
    )


def rowclone(state: SubarrayState, src, dst,
             cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """AAP: dst <- src (src restored by the sense amps)."""
    src_i, dst_i = resolve(state, src), resolve(state, dst)
    row = state.bits[src_i]
    return _with(state, bits=state.bits.at[dst_i].set(row),
                 meter=charge_aap(state.meter, cfg))


def dra(state: SubarrayState, src, dst,
        cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Dual-row activation copy variant (both rows end equal to src)."""
    src_i, dst_i = resolve(state, src), resolve(state, dst)
    row = state.bits[src_i]
    return _with(state, bits=state.bits.at[dst_i].set(row),
                 meter=charge_mra(state.meter, 2, cfg))


def tra(state: SubarrayState, r1, r2, r3,
        cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Triple-row activation: all three rows <- MAJ(r1, r2, r3). Destructive."""
    i1, i2, i3 = (resolve(state, r) for r in (r1, r2, r3))
    m = maj3_words(state.bits[i1], state.bits[i2], state.bits[i3])
    bits = state.bits.at[i1].set(m).at[i2].set(m).at[i3].set(m)
    return _with(state, bits=bits, meter=charge_mra(state.meter, 3, cfg))


def not_to_dcc(state: SubarrayState, src,
               cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Ambit NOT, phase 1: dcc <- ~src (charge crosses the DCC's n-port)."""
    row = state.bits[resolve(state, src)]
    return _with(state, dcc=~row, meter=charge_aap(state.meter, cfg))


def dcc_to(state: SubarrayState, dst,
           cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Ambit NOT, phase 2: dst <- dcc."""
    dst_i = resolve(state, dst)
    return _with(state, bits=state.bits.at[dst_i].set(state.dcc),
                 meter=charge_aap(state.meter, cfg))


def shift(state: SubarrayState, src, dst, delta: int = +1,
          cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """THE PAPER'S PRIMITIVE: full-row 1-bit shift via the migration rows.

    Right shift (delta=+1), mirroring Fig. 3's 4-AAP sequence:
      AAP1  src -> mig_top  : top row captures the EVEN-column bits
      AAP2  src -> mig_bot  : bottom row captures the ODD-column bits
      AAP3  mig_top -> dst  : even bits re-emerge at their pair's odd bitline
      AAP4  mig_bot -> dst  : odd bits re-emerge one pair over; rows merge

    Left shift swaps which parity each migration row captures. Edge bits fall
    off (fill 0). ``delta`` must be ±1 — multi-bit shifts are repeated ops
    (paper §8.0.3); use ``program.shift_k`` for the loop.
    """
    assert delta in (+1, -1), "the migration-cell shift moves exactly 1 bit"
    src_i, dst_i = resolve(state, src), resolve(state, dst)
    row = state.bits[src_i]
    if delta == +1:
        mig_top = row & EVEN_MASK            # AAP1: capture even columns
        mig_bot = row & ODD_MASK             # AAP2: capture odd columns
    else:
        mig_top = row & ODD_MASK             # AAP1: capture odd columns
        mig_bot = row & EVEN_MASK            # AAP2: capture even columns
    out_top = shift_row_words(mig_top, delta)  # AAP3: emerge via other port
    out_bot = shift_row_words(mig_bot, delta)  # AAP4: emerge + merge
    merged = out_top | out_bot
    return _with(state, mig_top=mig_top, mig_bot=mig_bot,
                 bits=state.bits.at[dst_i].set(merged),
                 meter=charge_shift(state.meter, cfg))


def lisa_copy(state: SubarrayState, src, dst,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """LISA row movement within this subarray: dst <- src at COPY timing.

    A distance-0 LISA copy costs exactly one AAP (``timing.copy_cost``); the
    interesting cross-subarray/cross-bank cases carry hop and internal-bus
    charges and are applied by the device scheduler, which owns both
    endpoints' state (``schedule.py``).
    """
    src_i, dst_i = resolve(state, src), resolve(state, dst)
    return _with(state, bits=state.bits.at[dst_i].set(state.bits[src_i]),
                 meter=charge_copy(state.meter, 0, False, cfg))


def write_row(state: SubarrayState, dst, row: jax.Array,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Host write: burst data onto the chip then restore into the row."""
    dst_i = resolve(state, dst)
    meter = charge_burst(state.meter, state.words * 4, cfg)
    return _with(state, bits=state.bits.at[dst_i].set(row.astype(jnp.uint32)),
                 meter=meter)


def read_row(state: SubarrayState, src,
             cfg: DDR3Timing = DEFAULT_TIMING):
    """Host read: returns (state', row) and charges burst energy."""
    src_i = resolve(state, src)
    meter = charge_burst(state.meter, state.words * 4, cfg)
    return _with(state, meter=meter), state.bits[src_i]


def issue(state: SubarrayState,
          cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Command-burst issue overhead (once per host-triggered burst)."""
    return _with(state, meter=charge_issue(state.meter, cfg))


# ---------------------------------------------------------------------------
# Composite Ambit ops (costs emerge from the primitive sequence)
# ---------------------------------------------------------------------------

def ambit_maj(state: SubarrayState, a, b, c, dst,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """dst <- MAJ(a, b, c): 3 copies into scratch, TRA, copy out = 4 AAP + TRA."""
    s = rowclone(state, a, T0, cfg)
    s = rowclone(s, b, T1, cfg)
    s = rowclone(s, c, T2, cfg)
    s = tra(s, T0, T1, T2, cfg)
    return rowclone(s, T0, dst, cfg)


def ambit_and(state: SubarrayState, a, b, dst,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """dst <- a & b = MAJ(a, b, 0)."""
    return ambit_maj(state, a, b, C0, dst, cfg)


def ambit_or(state: SubarrayState, a, b, dst,
             cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """dst <- a | b = MAJ(a, b, 1)."""
    return ambit_maj(state, a, b, C1, dst, cfg)


def ambit_not(state: SubarrayState, src, dst,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """dst <- ~src via the dual-contact-cell row (2 AAPs)."""
    s = not_to_dcc(state, src, cfg)
    return dcc_to(s, dst, cfg)


def run_program(state: SubarrayState, program,
                cfg: DDR3Timing = DEFAULT_TIMING, *,
                verify: bool = False):
    """Replay a recorded :class:`~.ir.PimProgram` command-at-a-time through
    this eager ISA. Returns ``(state, reads)``.

    This is the differential-testing reference path (tests/
    test_pim_differential.py): one Python-level pytree transition per
    command, no compilation — the compiled executor must match it bit for
    bit. Cross-slot COPYs have no meaning on one subarray and raise.
    ``verify=True`` statically lints the stream first (see ``lint.py``)
    and raises :class:`~.lint.LintError` on errors.
    """
    from . import ir

    if verify:
        from . import lint
        report = lint.lint_program(program)
        if not report.ok:
            raise lint.LintError(report)
    reads = []
    for op in program.ops:
        if op.op == ir.OP_ISSUE:
            state = issue(state, cfg)
        elif op.op == ir.OP_ROWCLONE:
            state = rowclone(state, op.a, op.b, cfg)
        elif op.op == ir.OP_DRA:
            state = dra(state, op.a, op.b, cfg)
        elif op.op == ir.OP_TRA:
            state = tra(state, op.a, op.b, op.c, cfg)
        elif op.op == ir.OP_NOT2DCC:
            state = not_to_dcc(state, op.a, cfg)
        elif op.op == ir.OP_DCC2:
            state = dcc_to(state, op.b, cfg)
        elif op.op == ir.OP_SHIFT:
            state = shift(state, op.a, op.b, op.delta, cfg)
        elif op.op == ir.OP_COPY:
            if not ir.copy_is_local(op):
                raise ValueError(
                    f"cross-subarray COPY to ({op.delta}, {op.c}) needs the "
                    "device scheduler; the eager path runs one subarray")
            state = lisa_copy(state, op.a, op.b, cfg)
        elif op.op == ir.OP_WRITE:
            state = write_row(state, op.b,
                              jnp.asarray(program.payloads[op.payload]), cfg)
        elif op.op == ir.OP_READ:
            state, row = read_row(state, op.a, cfg)
            reads.append(row)
        elif op.op == ir.OP_FILL:
            row = jnp.full((state.words,), jnp.uint32(op.payload))
            state = _with(state, bits=state.bits.at[op.b].set(row))
        else:
            raise ValueError(op.op)
    return state, tuple(reads)


def run_on_bits(program, bits=None, *, control: bool = True,
                cfg: DDR3Timing = DEFAULT_TIMING):
    """Run a recorded program eagerly on a fresh subarray initialized with
    ``bits`` (``(num_rows, words)`` uint32, default all-zero). Returns
    ``(state, reads)``. ``control=True`` seeds C0/C1 via
    ``reserve_control_rows`` first — the convention ``sem.py`` witnesses
    assume, so a DIFFERENT verdict replays with one call per program."""
    state = make_subarray(program.num_rows, program.words, bits)
    if control:
        state = reserve_control_rows(state)
    return run_program(state, program, cfg)


def ambit_xor(state: SubarrayState, a, b, dst,
              cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """dst <- a ^ b = (a | b) & ~(a & b). Uses T0..T3 as intermediates.

    ``dst`` may alias ``a`` or ``b`` (every MAJ step reads its operands into
    scratch before writing), but none of the operands may resolve onto the
    T0..T3 scratch rows themselves — the expansion would clobber them
    mid-sequence and silently compute the wrong row, so concrete operands
    are checked up front.

    Note: XOR is the workhorse of GF(2) arithmetic (AES / Reed-Solomon), which
    is why the paper pairs shifting with Ambit ops for crypto workloads.
    """
    scratch = {t % state.num_rows for t in (T0, T1, T2, T3)}
    for name, r in (("a", a), ("b", b), ("dst", dst)):
        if (isinstance(r, (int, np.integer))
                and int(r) % state.num_rows in scratch):
            raise ValueError(
                f"ambit_xor operand {name}={r} resolves onto scratch row "
                f"{int(r) % state.num_rows} (T0..T3) and would be clobbered "
                "mid-sequence")
    s = ambit_or(state, a, b, T3, cfg)       # T3 = a | b (T0..T2 are scratch)
    s = ambit_and(s, a, b, dst, cfg)         # dst = a & b
    s = ambit_not(s, dst, dst, cfg)          # dst = ~(a & b)
    return ambit_and(s, T3, dst, dst, cfg)   # dst = (a|b) & ~(a&b)
