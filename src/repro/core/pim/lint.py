"""pimlint: static verifier + hazard analyzer for PIM programs and plans.

Every analysis here runs over the cached columnar table
(:class:`~.ir.ProgramColumns`) with a constant number of numpy passes —
O(n_ops) total, no execution, no tracing, no per-op Python loop — so a
100k-command stream lints in milliseconds and the result can be cached
per program digest and per schedule plan.

Three entry points:

``lint_program(program)``
    Single-stream hazards: operand ranges, SHIFT geometry, TRA operand
    aliasing, scratch-row clobber hazards (the PR-1 ``ambit_xor`` bug
    class), control-row clobbers, uninitialized reads, dead writes,
    host-order races, payload shape/reference errors.

``lint_schedule(cfg, programs)``
    Everything above per slot, plus cross-slot COPY hazards: destination
    coordinates outside the :class:`~.device.DeviceConfig`, two drained
    copies racing on one destination row, compute reading a row that is a
    pending copy destination, and (async plans) host-burst windows too
    large for the compute window to hide.

``lint_trace(text)`` / ``python -m repro.core.pim.lint <trace>``
    The same checks over on-disk pim-trace v1/v2/v3 files, with
    line-numbered diagnostics and CI-friendly exit codes.

Diagnostics are structured (:class:`Diagnostic`) and cataloged
(:data:`CATALOG`); severities split hard contract violations (``error`` —
the executor would wrap, clobber, or race) from smells (``warning`` —
legal but almost certainly not what the program meant). ``verify=True``
gates on :class:`~.ir.ProgramBuilder`, ``compile_program``, ``execute``/
``make_runner``, and ``schedule*`` raise :class:`LintError` on errors.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

from . import ir
from . import isa
from .device import (DeviceConfig, channel_occupancy, host_bus_ns,
                     issue_bus_ns)

__all__ = [
    "CATALOG", "Diagnostic", "LintError", "LintReport", "lint_program",
    "lint_schedule", "lint_trace", "lint_trace_file", "main",
]

ERROR = "error"
WARNING = "warning"

# code -> (default severity, title, rationale). The rationale records WHY
# the paper's geometry or the runtime's contract makes the pattern a
# hazard — DESIGN.md section 12 renders this catalog verbatim.
CATALOG: dict[str, tuple[str, str, str]] = {
    "PIM101": (ERROR, "row index out of range",
               "row operands must lie in [0, num_rows): the executor "
               "indexes the bitplane array and would silently wrap "
               "(% num_rows) onto the reserved control/scratch tail"),
    "PIM102": (ERROR, "SHIFT delta not ±1",
               "the migration cells sit at the subarray edge and move "
               "exactly one bit per activation (paper section 3); any "
               "|delta| != 1 has no hardware meaning and the cost model "
               "would mischarge it"),
    "PIM103": (ERROR, "TRA operands not distinct",
               "triple-row activation charge-shares three DISTINCT rows; "
               "duplicate operands short the same bitline twice and the "
               "majority value is undefined"),
    "PIM104": (ERROR, "scratch-row alias hazard",
               "the Ambit composites expand through T0..T3; an operand "
               "aliasing the scratch rows is clobbered mid-expansion "
               "(the PR-1 ambit_xor bug, caught at runtime then — a lint "
               "code now)"),
    "PIM105": (ERROR, "HOSTW payload mismatch",
               "a HOSTW must reference an existing payload row of shape "
               "(words,); anything else fails (or truncates) only at "
               "dispatch time"),
    "PIM106": (ERROR, "control row clobbered",
               "C0 (all-zeros) and C1 (all-ones) are the constant rows "
               "AND/OR are built from; a non-FILL write breaks every "
               "later composite that charges against them"),
    "PIM201": (WARNING, "read of uninitialized row",
               "the row is read before any HOSTW/FILL/compute write in "
               "this stream; unless device state was seeded by an "
               "earlier step the value is undefined"),
    "PIM202": (WARNING, "dead write",
               "a pure-overwrite write (AAP/DRA/HOSTW/FILL) whose row is "
               "overwritten before any read — charged DRAM activations "
               "for a value nothing observes"),
    "PIM203": (WARNING, "unread scratch row",
               "the last touch of a T0..T3 scratch row is a pure "
               "overwrite that nothing reads — usually a truncated "
               "composite expansion"),
    "PIM204": (WARNING, "host read before later compute write",
               "a HOSTR of a row that in-DRAM compute overwrites later "
               "in the same stream: the host observes an intermediate "
               "value, which is rarely the intent of a read-back"),
    "PIM205": (WARNING, "unused HOSTW payload",
               "payload rows no HOSTW references still travel with the "
               "program and inflate the identity-keyed payload caches"),
    "PIM301": (ERROR, "COPY destination outside device",
               "a cross-slot COPY names (dst_bank, dst_sub) that the "
               "DeviceConfig does not have; schedule() would reject the "
               "whole layout at dispatch time"),
    "PIM302": (ERROR, "COPY destination race",
               "two deferred copies drain into the same (slot, row) in "
               "one step; FCFS drain order decides the winner, so the "
               "result depends on stream assembly order"),
    "PIM303": (WARNING, "read of pending COPY destination",
               "a slot's compute (or HOSTR) reads a row that a cross-"
               "slot COPY writes this same step; copies drain AFTER the "
               "in-bank compute, so the read observes the pre-copy "
               "value"),
    "PIM304": (WARNING, "async host window not hidden",
               "async_host double-buffers host bursts under the previous "
               "step's compute; a per-channel burst window larger than "
               "the compute window stays on the critical path and the "
               "pipeline degenerates toward sync timing"),
    "PIM305": (ERROR, "program/device shape mismatch",
               "every slot program must share the device's "
               "(num_rows, words) subarray shape; the vmapped runners "
               "cannot batch mismatched bitplanes"),
    # PIM4xx: semantic diagnostics — findings of the symbolic abstract
    # interpreter (sem.py), proved over packed truth tables rather than
    # pattern-matched. Only emitted when the fact is PROVED (never from
    # an approximation), so every PIM4xx is a true positive.
    "PIM401": (WARNING, "op computes a constant",
               "the op's result is provably the same constant row for "
               "EVERY input (a TRA whose majority cancels its symbolic "
               "operands, or a SHIFT chain that pushes the data entirely "
               "past the subarray boundary): charged DRAM activations "
               "for a value a FILL produces free"),
    "PIM402": (WARNING, "MAJ with symbolically equal operands",
               "two TRA operand rows provably hold the same boolean "
               "function of the inputs, so MAJ degenerates to the "
               "duplicated operand — the 5-op expansion is a copy"),
    "PIM403": (WARNING, "cancelling NOT/SHIFT chain",
               "back-to-back NOTs (or a SHIFT chain returning to net "
               "displacement 0 with provably-zero edge lanes) reproduce "
               "the original value exactly; the whole chain is dead "
               "work"),
    "PIM404": (WARNING, "semantically no-op write",
               "the destination row provably already holds exactly the "
               "value being written — the activation changes nothing "
               "any program could observe"),
    "PIM405": (ERROR, "pimverify equivalence directive failed",
               "the trace carries a `# pimverify: equiv=<trace>` "
               "contract and the prover found the two programs "
               "DIFFERENT (a concrete distinguishing input exists) or "
               "could not discharge the proof"),
}

# Cap per-code emissions so a degenerate stream (every op bad) cannot
# turn the O(n) array pass into an O(n) diagnostic build.
_MAX_PER_CODE = 64

_RC = ir.OP_CODE[ir.OP_ROWCLONE]
_DRA = ir.OP_CODE[ir.OP_DRA]
_TRA = ir.OP_CODE[ir.OP_TRA]
_N2D = ir.OP_CODE[ir.OP_NOT2DCC]
_DCC2 = ir.OP_CODE[ir.OP_DCC2]
_SHIFT = ir.OP_CODE[ir.OP_SHIFT]
_WRITE = ir.OP_CODE[ir.OP_WRITE]
_READ = ir.OP_CODE[ir.OP_READ]
_FILL = ir.OP_CODE[ir.OP_FILL]
_COPY = ir.OP_CODE[ir.OP_COPY]

_READS_A = (_RC, _DRA, _N2D, _SHIFT, _READ, _COPY)
_WRITES_B = (_RC, _DRA, _DCC2, _SHIFT, _WRITE, _FILL)
# Writes that replace the row without reading it first (the
# dead_copy_elimination overwrite set): candidates for PIM202/PIM203.
_PURE_OVERWRITE = (_RC, _DRA, _WRITE, _FILL)
# In-DRAM writes (everything but HOSTW/FILL): what makes a HOSTR stale.
_COMPUTE_WRITES = (_RC, _DRA, _TRA, _DCC2, _SHIFT, _COPY)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a catalog ``code``, its ``severity``, and the anchor —
    ``op_index`` into the stream, ``trace_line`` when the program came
    from a pim-trace file, ``slot`` = (bank, sub) device coordinates when
    found by a schedule-level pass."""

    code: str
    severity: str
    message: str
    op_index: int | None = None
    trace_line: int | None = None
    slot: tuple[int, int] | None = None

    def render(self) -> str:
        where = []
        if self.slot is not None:
            where.append(f"slot {self.slot}")
        if self.op_index is not None:
            where.append(f"op {self.op_index}")
        if self.trace_line is not None:
            where.append(f"line {self.trace_line}")
        at = f" [{', '.join(where)}]" if where else ""
        return f"{self.code} {self.severity}{at}: {self.message}"


@dataclasses.dataclass(frozen=True)
class LintReport:
    """All diagnostics of one lint pass, error-first ordering."""

    diagnostics: tuple[Diagnostic, ...] = ()

    @property
    def errors(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == WARNING)

    @property
    def ok(self) -> bool:
        """No errors (warnings do not fail a lint)."""
        return not self.errors

    def codes(self) -> tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    def render(self) -> str:
        if not self.diagnostics:
            return "clean"
        return "\n".join(d.render() for d in self.diagnostics)

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "diagnostics": [dataclasses.asdict(d) for d in self.diagnostics],
        }


class LintError(ValueError):
    """Raised by the ``verify=True`` gates when a lint finds errors."""

    def __init__(self, report: LintReport, what: str = "program"):
        self.report = report
        errs = report.errors
        head = "; ".join(d.render() for d in errs[:4])
        more = f" (+{len(errs) - 4} more)" if len(errs) > 4 else ""
        super().__init__(f"pimlint: {what} failed verification: {head}{more}")


class _Emit:
    """Diagnostic accumulator with a per-code emission cap."""

    def __init__(self):
        self.diags: list[Diagnostic] = []
        self._counts: dict[str, int] = {}

    def __call__(self, code: str, message: str, *, op_index=None,
                 severity: str | None = None) -> None:
        n = self._counts.get(code, 0)
        self._counts[code] = n + 1
        if n == _MAX_PER_CODE:
            self.diags.append(Diagnostic(
                code=code, severity=severity or CATALOG[code][0],
                message=f"further {code} diagnostics suppressed "
                        f"(> {_MAX_PER_CODE})"))
            return
        if n > _MAX_PER_CODE:
            return
        self.diags.append(Diagnostic(
            code=code, severity=severity or CATALOG[code][0],
            message=message,
            op_index=None if op_index is None else int(op_index)))


@dataclasses.dataclass(frozen=True)
class _Events:
    """Row-access events of one stream, in columnar form.

    Positions are scaled op indices — reads at ``2*i``, writes at
    ``2*i + 1`` — so an op that reads and writes the same row (e.g.
    ``AAP r r``) orders its own read before its own write."""

    r_row: np.ndarray           # read rows
    r_idx: np.ndarray           # read op indices
    r_code: np.ndarray
    w_row: np.ndarray           # write rows
    w_idx: np.ndarray
    w_code: np.ndarray
    x_row: np.ndarray           # cross-slot COPY destination rows (remote)
    x_idx: np.ndarray


def _events(cols: ir.ProgramColumns) -> _Events:
    code, a, b, c, d = cols.code, cols.a, cols.b, cols.c, cols.delta
    n = code.shape[0]
    idx = np.arange(n)
    m_tra = code == _TRA
    m_copy = code == _COPY
    local = m_copy & (((d == ir.COPY_SELF) & (c == ir.COPY_SELF))
                      | ((d == 0) & (c == 0)))
    m_ra = np.isin(code, _READS_A)
    m_wb = np.isin(code, _WRITES_B) | local
    ti = idx[m_tra]
    r_idx = np.concatenate([idx[m_ra], ti, ti, ti])
    w_idx = np.concatenate([idx[m_wb], ti, ti, ti])
    return _Events(
        r_row=np.concatenate([a[m_ra], a[m_tra], b[m_tra], c[m_tra]]),
        r_idx=r_idx, r_code=code[r_idx],
        w_row=np.concatenate([b[m_wb], a[m_tra], b[m_tra], c[m_tra]]),
        w_idx=w_idx, w_code=code[w_idx],
        x_row=b[m_copy & ~local], x_idx=idx[m_copy & ~local])


def _first_per_row(rows: np.ndarray, idxs: np.ndarray):
    """(unique rows, min op index per row) of a flagged event subset."""
    uniq, inv = np.unique(rows, return_inverse=True)
    first = np.full(uniq.shape[0], np.iinfo(np.int64).max, np.int64)
    np.minimum.at(first, inv, idxs)
    return uniq, first


def _scratch_rows(num_rows: int) -> tuple[int, ...]:
    return tuple(int(t) % num_rows for t in (isa.T0, isa.T1, isa.T2, isa.T3))


def _control_rows(num_rows: int) -> tuple[int, ...]:
    return tuple(int(t) % num_rows for t in (isa.C0, isa.C1))


def _scratch_name(r: int, num_rows: int) -> str:
    names = dict(zip(_scratch_rows(num_rows), ("T0", "T1", "T2", "T3")))
    names.update(zip(_control_rows(num_rows), ("C0", "C1")))
    return f"{names[r]} (row {r})" if r in names else f"row {r}"


def _lint_columns(cols: ir.ProgramColumns, num_rows: int, words: int,
                  payload_shapes: tuple, assume) -> tuple[Diagnostic, ...]:
    """The program-level pass: a constant number of vectorized sweeps over
    the columnar table. ``assume`` is a frozenset of rows taken as
    initialized (or the string "all")."""
    emit = _Emit()
    code, a, b, c, d = cols.code, cols.a, cols.b, cols.c, cols.delta
    p = cols.payload
    n = code.shape[0]
    ev = _events(cols)

    # --- PIM101: operand rows outside [0, num_rows) --------------------------
    all_row = np.concatenate([ev.r_row, ev.w_row, ev.x_row])
    all_idx = np.concatenate([ev.r_idx, ev.w_idx, ev.x_idx])
    bad = (all_row < 0) | (all_row >= num_rows)
    if bad.any():
        for i in np.unique(all_idx[bad])[:_MAX_PER_CODE + 1]:
            r = all_row[bad & (all_idx == i)][0]
            emit("PIM101",
                 f"row index {int(r)} out of range [0, {num_rows})",
                 op_index=i)
    r_ok = (ev.r_row >= 0) & (ev.r_row < num_rows)
    w_ok = (ev.w_row >= 0) & (ev.w_row < num_rows)
    r_row, r_idx, r_code = ev.r_row[r_ok], ev.r_idx[r_ok], ev.r_code[r_ok]
    w_row, w_idx, w_code = ev.w_row[w_ok], ev.w_idx[w_ok], ev.w_code[w_ok]

    # --- PIM102: SHIFT delta must be exactly +-1 -----------------------------
    bad = (code == _SHIFT) & ~np.isin(d, (1, -1))
    for i in np.flatnonzero(bad)[:_MAX_PER_CODE + 1]:
        emit("PIM102",
             f"SHIFT delta {int(d[i]):+d}: the migration-cell primitive "
             "moves exactly 1 bit per activation", op_index=i)

    # --- PIM103: TRA operands must be three distinct rows --------------------
    bad = (code == _TRA) & ((a == b) | (a == c) | (b == c))
    for i in np.flatnonzero(bad)[:_MAX_PER_CODE + 1]:
        emit("PIM103",
             f"TRA rows ({int(a[i])}, {int(b[i])}, {int(c[i])}) are not "
             "distinct", op_index=i)

    # --- PIM104a: MAJ-shaped window failing its alias-safety terms -----------
    # Mirrors compile._maj_sites: same 5-op structural match, but flags
    # windows where a LATER rowclone source aliases an EARLIER scratch
    # write (the conjuncts _maj_sites requires, negated).
    if n >= 5:
        t0, t1, t2, _ = _scratch_rows(num_rows)
        shape = ((code[:n - 4] == _RC) & (b[:n - 4] == t0)
                 & (code[1:n - 3] == _RC) & (b[1:n - 3] == t1)
                 & (code[2:n - 2] == _RC) & (b[2:n - 2] == t2)
                 & (code[3:n - 1] == _TRA) & (a[3:n - 1] == t0)
                 & (b[3:n - 1] == t1) & (c[3:n - 1] == t2)
                 & (code[4:] == _RC) & (a[4:] == t0))
        aliased = ((a[1:n - 3] == t0) | (a[2:n - 2] == t0)
                   | (a[2:n - 2] == t1))
        for i in np.flatnonzero(shape & aliased)[:_MAX_PER_CODE + 1]:
            emit("PIM104",
                 "MAJ expansion whose later source reads an already-"
                 "clobbered scratch row (operand aliases T0/T1)",
                 op_index=i)

    # --- PIM104b: stale scratch read (the PR-1 ambit_xor hazard) -------------
    # A read of T0/T1/T2 whose last writer is a TRA further back than the
    # immediately following op: the one legitimate consumer of a TRA
    # result is the very next rowclone-out of the MAJ expansion; anything
    # later means the caller handed scratch rows to a composite that
    # already destroyed them.
    t_rows = _scratch_rows(num_rows)[:3]
    for r in t_rows:
        wp = w_idx[w_row == r]
        if not wp.size:
            continue
        order = np.argsort(wp, kind="stable")
        wp = wp[order]
        wc = w_code[w_row == r][order]
        rp = r_idx[r_row == r]
        j = np.searchsorted(wp, rp, side="left") - 1
        ok = j >= 0
        stale = ok & (wc[np.maximum(j, 0)] == _TRA) \
            & (rp > wp[np.maximum(j, 0)] + 1)
        for i in np.unique(rp[stale])[:_MAX_PER_CODE + 1]:
            emit("PIM104",
                 f"reads scratch {_scratch_name(r, num_rows)} last "
                 "written by a TRA more than one op earlier — the "
                 "operand aliased a composite's T0..T3 scratch and was "
                 "clobbered mid-expansion", op_index=i)

    # --- PIM105 / PIM205: HOSTW payload references ---------------------------
    m_w = code == _WRITE
    pay = p[m_w]
    w_ops = np.flatnonzero(m_w)
    n_pay = len(payload_shapes)
    bad = (pay < 0) | (pay >= n_pay)
    for i, k in zip(w_ops[bad][:_MAX_PER_CODE + 1], pay[bad]):
        emit("PIM105",
             f"HOSTW references payload {int(k)} but the program has "
             f"{n_pay}", op_index=i)
    for k, shape in enumerate(payload_shapes):
        if shape != (words,):
            hits = w_ops[pay == k]
            emit("PIM105",
                 f"payload {k} has shape {tuple(shape)}, subarray rows "
                 f"are ({words},)",
                 op_index=hits[0] if hits.size else None)
    if n_pay:
        unused = sorted(set(range(n_pay)) - set(pay[~bad].tolist()))
        if unused:
            head = ", ".join(map(str, unused[:8]))
            more = "..." if len(unused) > 8 else ""
            emit("PIM205",
                 f"{len(unused)} payload row(s) never referenced by any "
                 f"HOSTW: [{head}{more}]")

    # --- PIM106: non-FILL write to a control row -----------------------------
    for r in _control_rows(num_rows):
        clob = np.flatnonzero((w_row == r) & (w_code != _FILL))
        if not clob.size:
            continue
        fills = np.sort(w_idx[(w_row == r) & (w_code == _FILL)])
        reads = np.sort(r_idx[r_row == r])
        for i in np.unique(w_idx[clob])[:_MAX_PER_CODE + 1]:
            nf = np.searchsorted(fills, i, side="right")
            until = fills[nf] if nf < fills.size else np.iinfo(np.int64).max
            k = np.searchsorted(reads, i, side="right")
            read_back = k < reads.size and reads[k] < until
            emit("PIM106",
                 f"{_scratch_name(r, num_rows)} clobbered by a non-FILL "
                 "write" + (" and read again before any re-FILL"
                            if read_back else
                            " (never read after — downgrade to warning)"),
                 op_index=i,
                 severity=ERROR if read_back else WARNING)

    # --- PIM201: reads before any write ("all" skips: prior-step state) ------
    if assume != "all":
        first_w = np.full(num_rows, np.iinfo(np.int64).max, np.int64)
        np.minimum.at(first_w, w_row, 2 * w_idx + 1)
        keep = np.ones(num_rows, bool)
        for r in assume:
            if 0 <= r < num_rows:
                keep[r] = False
        un = keep[r_row] & (2 * r_idx < first_w[r_row])
        if un.any():
            rows_u, first_u = _first_per_row(r_row[un], r_idx[un])
            for r, i in zip(rows_u[:_MAX_PER_CODE + 1], first_u):
                emit("PIM201",
                     f"row {int(r)} read before any write in this stream",
                     op_index=i)
        # The DCC register variant: DCC2 copies the dual-contact cell out,
        # which only NOT2DCC loads.
        d2 = np.flatnonzero(code == _DCC2)
        n2 = np.flatnonzero(code == _N2D)
        first_n2 = n2[0] if n2.size else np.iinfo(np.int64).max
        if d2.size and d2[0] < first_n2:
            emit("PIM201",
                 "DCC2 before any NOT2DCC: the dual-contact cell was "
                 "never loaded", op_index=d2[0])

    # --- PIM202/PIM203: dead writes and unread scratch -----------------------
    ev_row = np.concatenate([r_row, w_row])
    ev_pos = np.concatenate([2 * r_idx, 2 * w_idx + 1])
    ev_isw = np.concatenate([np.zeros(r_row.shape[0], bool),
                             np.ones(w_row.shape[0], bool)])
    ev_code = np.concatenate([r_code, w_code])
    ev_opi = np.concatenate([r_idx, w_idx])
    order = np.lexsort((ev_pos, ev_row))
    row_s = ev_row[order]
    isw_s = ev_isw[order]
    code_s = ev_code[order]
    opi_s = ev_opi[order]
    pure_s = isw_s & np.isin(code_s, _PURE_OVERWRITE)
    if row_s.size:
        same_next = np.zeros(row_s.shape[0], bool)
        same_next[:-1] = row_s[1:] == row_s[:-1]
        dead = pure_s & same_next
        dead[:-1] &= isw_s[1:]
        for k in np.flatnonzero(dead)[:_MAX_PER_CODE + 1]:
            emit("PIM202",
                 f"write to row {int(row_s[k])} is overwritten (op "
                 f"{int(opi_s[k + 1])}) before any read",
                 op_index=opi_s[k])
        last = ~same_next   # last event of each row group
        scr = np.isin(row_s, _scratch_rows(num_rows))
        for k in np.flatnonzero(last & pure_s & scr)[:_MAX_PER_CODE + 1]:
            emit("PIM203",
                 f"scratch {_scratch_name(int(row_s[k]), num_rows)} "
                 "written but never read afterwards — truncated composite "
                 "expansion?", op_index=opi_s[k])

    # --- PIM204: HOSTR of a row that compute later overwrites ----------------
    hostr = r_code == _READ
    if hostr.any():
        cw = np.isin(w_code, _COMPUTE_WRITES)
        last_cw = np.full(num_rows, -1, np.int64)
        np.maximum.at(last_cw, w_row[cw], 2 * w_idx[cw] + 1)
        stale = hostr & (last_cw[r_row] > 2 * r_idx)
        if stale.any():
            rows_u, first_u = _first_per_row(r_row[stale], r_idx[stale])
            for r, i in zip(rows_u[:_MAX_PER_CODE + 1], first_u):
                emit("PIM204",
                     f"HOSTR of row {int(r)} precedes an in-DRAM write of "
                     "the same row: the host reads an intermediate value",
                     op_index=i)

    return tuple(emit.diags)


# ---------------------------------------------------------------------------
# Program-level entry point (digest-keyed cache)
# ---------------------------------------------------------------------------

_lint_cache: dict = {}
_LINT_CACHE_MAX = 512


def _assume_key(assume_initialized, num_rows: int):
    if assume_initialized == "all":
        return "all"
    if assume_initialized is None:
        return frozenset(_control_rows(num_rows))
    return frozenset(int(r) % num_rows for r in assume_initialized)


def _semantic_diags(program: ir.PimProgram) -> tuple[Diagnostic, ...]:
    """The PIM401-404 tier: findings of the symbolic abstract interpreter
    (``sem.semantic_findings``, content-digest-cached there). Best-effort
    — a stream the interpreter cannot model yields no semantic findings;
    the structural tier above owns malformed programs."""
    from . import sem      # lazy: keep non-semantic lints numpy-light
    try:
        findings = sem.semantic_findings(program)
    except Exception:
        return ()
    return tuple(Diagnostic(code=code, severity=CATALOG[code][0],
                            message=msg, op_index=opi)
                 for code, opi, msg in findings)


def lint_program(program: ir.PimProgram, *, assume_initialized=None,
                 semantic: bool = False) -> LintReport:
    """Statically verify one command stream. Pure columnar analysis: no
    execution, no tracing, cached per (digest, shape, payload shapes).

    ``assume_initialized`` — rows exempt from the PIM201 uninitialized-
    read check: ``None`` (default) exempts only C0/C1 (pre-seeded by
    ``make_device``/``reserve_control_rows`` outside the stream), a row
    iterable exempts those rows, and ``"all"`` disables the check (the
    right setting when device state persists from earlier steps, e.g.
    inside a schedule plan).

    ``semantic=True`` additionally runs the PIM4xx tier (``sem.py``):
    proved constant results, degenerate MAJs, cancelling NOT/SHIFT
    chains, no-op writes. Off by default — the verify gates and hot
    schedule paths stay structural-only; ``lint_trace``/the CLI turn it
    on."""
    assume = _assume_key(assume_initialized, program.num_rows)
    shapes = tuple(tuple(p.shape) for p in program.payloads)
    key = (program.digest, program.num_rows, program.words, shapes, assume)
    diags = _lint_cache.pop(key, None)
    if diags is None:
        diags = _lint_columns(program.columns, program.num_rows,
                              program.words, shapes, assume)
        diags = tuple(sorted(
            diags, key=lambda d: (d.severity != ERROR,
                                  d.op_index if d.op_index is not None
                                  else 1 << 60, d.code)))
        if len(_lint_cache) >= _LINT_CACHE_MAX:
            _lint_cache.pop(next(iter(_lint_cache)))
    _lint_cache[key] = diags
    if semantic:
        # Semantic findings ride sem.py's own payload-CONTENT-keyed cache
        # (HOSTW bits are constants in the truth-table domain, so the
        # shapes-keyed structural cache above must not hold them).
        diags = tuple(sorted(
            diags + _semantic_diags(program),
            key=lambda d: (d.severity != ERROR,
                           d.op_index if d.op_index is not None
                           else 1 << 60, d.code)))
    lines = program.trace_lines
    if lines:
        diags = tuple(
            dataclasses.replace(d, trace_line=lines[d.op_index])
            if d.op_index is not None and d.op_index < len(lines) else d
            for d in diags)
    return LintReport(diagnostics=diags)


# ---------------------------------------------------------------------------
# Schedule-level analyses
# ---------------------------------------------------------------------------

def _copy_hazard_diags(cfg: DeviceConfig, slot_programs,
                       deferred) -> list[Diagnostic]:
    """PIM302/PIM303 over a resolved deferred-copy list
    ``[(src_slot, dst_slot, op), ...]`` (the scheduler's own shape); a
    4th/5th tuple element (op index, trace line) adds source provenance
    when the caller has it."""
    diags: list[Diagnostic] = []
    seen: dict[tuple[int, int], int] = {}
    for item in deferred:
        s, dd, op = item[0], item[1], item[2]
        opi = item[3] if len(item) > 3 else None
        tline = item[4] if len(item) > 4 else None
        dst = (dd, op.b)
        if dst in seen:
            diags.append(Diagnostic(
                code="PIM302", severity=ERROR,
                slot=cfg.slot_coords(s), op_index=opi, trace_line=tline,
                message=f"COPY into slot {cfg.slot_coords(dd)} row "
                        f"{op.b} races an earlier copy from slot "
                        f"{cfg.slot_coords(seen[dst])} this step"))
        else:
            seen[dst] = s
    if not seen:
        return diags
    reads: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for (dd, row), s in seen.items():
        prog = slot_programs[dd] if dd < len(slot_programs) else None
        if prog is None or not len(prog.ops):
            continue
        if dd not in reads:
            ev = _events(prog.columns)
            reads[dd] = (ev.r_row, ev.r_idx)
        r_row, r_idx = reads[dd]
        hits = r_idx[r_row == row]
        if hits.size:
            first = int(hits.min())
            diags.append(Diagnostic(
                code="PIM303", severity=WARNING,
                slot=cfg.slot_coords(dd), op_index=first,
                trace_line=(prog.trace_lines[first]
                            if prog.trace_lines else None),
                message=f"slot {cfg.slot_coords(dd)} reads row {row} "
                        f"which a COPY from slot {cfg.slot_coords(s)} "
                        "overwrites this step; copies drain after "
                        "compute, so the read sees the pre-copy value"))
    return diags


def _async_hide_diags(cfg: DeviceConfig, slot_programs) -> list[Diagnostic]:
    """PIM304: per-channel host-burst occupancy vs the compute window the
    async credit could at best hide it under."""
    from .compile import cost_summary    # lazy: compile is heavier
    t = cfg.timing
    issue = np.zeros(cfg.n_slots, np.float32)
    host = np.zeros(cfg.n_slots, np.float32)
    compute = 0.0
    summaries: dict[bytes, tuple] = {}
    for k, prog in enumerate(slot_programs):
        if prog is None or not len(prog.ops):
            continue
        hit = summaries.get(prog.digest)
        if hit is None:
            ib = issue_bus_ns(prog, t)
            hb = host_bus_ns(prog, t)
            cs = cost_summary(prog, t)["time_ns"]
            hit = summaries[prog.digest] = (ib, hb, cs)
        ib, hb, cs = hit
        issue[k] = ib
        host[k] = hb
        compute = max(compute, cs - ib - hb)
    if not host.any():
        return []
    _, host_ch, _ = channel_occupancy(cfg, issue, host)
    worst = int(np.argmax(host_ch))
    if float(host_ch[worst]) <= compute:
        return []
    return [Diagnostic(
        code="PIM304", severity=WARNING,
        message=f"channel {worst}'s host bursts occupy "
                f"{float(host_ch[worst]):.0f} ns but the step computes "
                f"for ~{compute:.0f} ns: async_host cannot fully hide "
                "the transfers and the excess stays on the wall clock")]


def _plan_diagnostics(cfg: DeviceConfig, stripped, groups, deferred,
                      async_host: bool) -> tuple[Diagnostic, ...]:
    """Diagnostics of one lowered schedule layout — called ONCE per
    step-plan build (``schedule._plan_for``) and stored on the cached
    ``_StepPlan``, so warm paths pay nothing. Uninitialized-read checks
    are disabled (device state persists across steps)."""
    diags: list[Diagnostic] = []
    for key, slot_ids in groups.items():
        rep = stripped[slot_ids[0]]
        rep_report = lint_program(rep, assume_initialized="all")
        coords = cfg.slot_coords(slot_ids[0])
        diags.extend(dataclasses.replace(d, slot=coords)
                     for d in rep_report.diagnostics)
    diags.extend(_copy_hazard_diags(cfg, stripped, deferred))
    if async_host:
        diags.extend(_async_hide_diags(cfg, stripped))
    return tuple(diags)


def lint_schedule(cfg: DeviceConfig, programs, *,
                  async_host: bool = False,
                  semantic: bool = False) -> LintReport:
    """Statically verify a whole schedule layout against ``cfg``: the
    program-level pass per distinct stream plus the cross-slot COPY and
    async-host analyses. Accepts every layout ``schedule()`` accepts, and
    DIAGNOSES (rather than raises on) shape mismatches and out-of-device
    COPY destinations. ``semantic=True`` adds the PIM4xx tier per
    distinct stream (distinct by payload CONTENT, not just shape — HOSTW
    bits are constants in the semantic domain)."""
    from .schedule import _normalize_programs    # lazy: avoid cycle
    emit: list[Diagnostic] = []
    try:
        flat = _normalize_programs(cfg, programs)
    except (ValueError, AssertionError) as e:
        return LintReport((Diagnostic(code="PIM305", severity=ERROR,
                                      message=str(e)),))

    seen: set = set()
    deferred: list = []
    for k, prog in enumerate(flat):
        if prog is None:
            continue
        coords = cfg.slot_coords(k)
        if (prog.num_rows, prog.words) != (cfg.num_rows, cfg.words):
            emit.append(Diagnostic(
                code="PIM305", severity=ERROR, slot=coords,
                message=f"program shape {(prog.num_rows, prog.words)} != "
                        f"device shape {(cfg.num_rows, cfg.words)}"))
            continue
        key = (prog.digest, tuple(tuple(p.shape) for p in prog.payloads))
        if semantic:
            key = key + (prog.payload_digest,)
        if key not in seen:
            seen.add(key)
            emit.extend(dataclasses.replace(d, slot=coords)
                        for d in lint_program(
                            prog, semantic=semantic).diagnostics)
        # Resolve cross-slot copies, diagnosing bad coordinates (PIM301)
        # where the scheduler's _split_copies would raise.
        for i, op in enumerate(prog.ops):
            if op.op != ir.OP_COPY or ir.copy_is_local(op):
                continue
            try:
                dst_slot = cfg.slot_index(op.delta, op.c)
            except ValueError:
                emit.append(Diagnostic(
                    code="PIM301", severity=ERROR, slot=coords, op_index=i,
                    trace_line=(prog.trace_lines[i]
                                if prog.trace_lines else None),
                    message=f"COPY destination ({op.delta}, {op.c}) "
                            f"outside the device ({cfg.n_banks} banks x "
                            f"{cfg.subarrays} subarrays)"))
                continue
            if dst_slot != k:
                deferred.append((k, dst_slot, op, i,
                                 prog.trace_lines[i]
                                 if prog.trace_lines else None))
    emit.extend(_copy_hazard_diags(cfg, flat, deferred))
    if async_host:
        emit.extend(_async_hide_diags(cfg, flat))
    emit.sort(key=lambda dg: (dg.severity != ERROR,
                              dg.slot if dg.slot is not None else (-1, -1),
                              dg.op_index if dg.op_index is not None
                              else 1 << 60, dg.code))
    return LintReport(tuple(emit))


def lint_trace(text: str, *, banks: int | None = None,
               subarrays: int | None = None,
               async_host: bool = False,
               semantic: bool = True) -> LintReport:
    """Lint a pim-trace v1/v2/v3 text. The device defaults to the trace
    header's geometry on one channel/rank; ``banks``/``subarrays``
    override it, so a trace can be checked against a SMALLER device than
    it was captured on (out-of-device COPY destinations become PIM301).
    The PIM4xx semantic tier is ON by default for traces (files are the
    audit path; pass ``semantic=False`` to stay structural-only)."""
    progs = ir.from_trace_device(text)
    hdr_banks, hdr_subs = len(progs), len(progs[0])
    shapes = {(p.num_rows, p.words) for bank in progs for p in bank}
    rows, words = shapes.pop()
    cfg = DeviceConfig(channels=1, ranks=1, banks_per_rank=hdr_banks,
                       subarrays=hdr_subs, num_rows=rows, words=words)
    report = lint_schedule(cfg, [list(bank) for bank in progs],
                           async_host=async_host, semantic=semantic)
    diags = list(report.diagnostics)
    want_b = hdr_banks if banks is None else int(banks)
    want_s = hdr_subs if subarrays is None else int(subarrays)
    if (want_b, want_s) != (hdr_banks, hdr_subs):
        for bk, bank in enumerate(progs):
            for sb, prog in enumerate(bank):
                cols = prog.columns
                m = ((cols.code == _COPY)
                     & ~(((cols.delta == ir.COPY_SELF)
                          & (cols.c == ir.COPY_SELF))
                         | ((cols.delta == 0) & (cols.c == 0)))
                     & ((cols.delta >= want_b) | (cols.c >= want_s)))
                for i in np.flatnonzero(m):
                    diags.append(Diagnostic(
                        code="PIM301", severity=ERROR, slot=(bk, sb),
                        op_index=int(i),
                        trace_line=(prog.trace_lines[i]
                                    if prog.trace_lines else None),
                        message=f"COPY destination ({int(cols.delta[i])}, "
                                f"{int(cols.c[i])}) outside the linted "
                                f"device ({want_b} banks x {want_s} "
                                "subarrays)"))
    return LintReport(tuple(diags))


# ---------------------------------------------------------------------------
# CLI: python -m repro.core.pim.lint <trace>... [--json out.json]
# ---------------------------------------------------------------------------

def _trace_directives(text: str) -> dict:
    """Parse ``# pimlint: key=value ...`` and ``# pimverify: key=value``
    comment directives (fixture self-description: expected code, device
    overrides, reference trace for equivalence proof)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("#"):
            continue
        for marker in ("pimlint:", "pimverify:"):
            if marker in line:
                for tok in line.split(marker, 1)[1].split():
                    k, _, v = tok.partition("=")
                    out[k] = v
    return out


def _pimverify_diags(path: str, text: str, ref: str) -> list[Diagnostic]:
    """PIM405: prove this trace equivalent to the reference trace named
    by its ``# pimverify: equiv=<file>`` directive (resolved relative to
    the trace's own directory). DIFFERENT is an ERROR carrying the
    distinguishing component + witness lane; UNKNOWN degrades to a
    WARNING (the proof did not go through — not a proved bug)."""
    from . import sem
    ref_path = os.path.join(os.path.dirname(os.path.abspath(path)), ref)
    try:
        with open(ref_path) as f:
            ref_text = f.read()
        progs = ir.from_trace_device(text)
        ref_progs = ir.from_trace_device(ref_text)
        flat = [p for bank in progs for p in bank]
        ref_flat = [p for bank in ref_progs for p in bank]
        if len(flat) != 1 or len(ref_flat) != 1:
            raise ValueError("pimverify: equiv= requires single-slot "
                             "traces on both sides")
        report = sem.prove_equivalent(flat[0], ref_flat[0])
    except (OSError, ValueError) as e:
        return [Diagnostic(code="PIM405", severity=ERROR,
                           message=f"pimverify equiv={ref}: {e}")]
    if report.verdict == sem.EQUIVALENT:
        return []
    if report.verdict == sem.DIFFERENT:
        w = report.witness
        where = (f" (component {report.component}, lane {w.lane})"
                 if w is not None else "")
        return [Diagnostic(code="PIM405", severity=ERROR,
                           message=f"trace is NOT equivalent to {ref}"
                                   f"{where}")]
    return [Diagnostic(code="PIM405", severity=WARNING,
                       message=f"equivalence to {ref} could not be "
                               f"proved (unknown: "
                               f"{', '.join(report.unknown) or '?'})")]


def lint_trace_file(path: str, *, banks: int | None = None,
                    subarrays: int | None = None,
                    async_host: bool = False,
                    semantic: bool = True) -> LintReport:
    """Lint a pim-trace FILE: ``lint_trace`` plus the file-scoped extras
    — in-file ``# pimlint: banks=/subarrays=`` device overrides (explicit
    arguments win), parse failures wrapped as a PARSE diagnostic, and the
    ``# pimverify: equiv=<file>`` equivalence proof (PIM405), whose
    relative reference resolves against the trace's directory."""
    with open(path) as f:
        text = f.read()
    directives = _trace_directives(text)
    if banks is None and "banks" in directives:
        banks = int(directives["banks"])
    if subarrays is None and "subarrays" in directives:
        subarrays = int(directives["subarrays"])
    try:
        report = lint_trace(text, banks=banks, subarrays=subarrays,
                            async_host=async_host, semantic=semantic)
    except ValueError as e:
        return LintReport((Diagnostic(code="PARSE", severity=ERROR,
                                      message=str(e)),))
    diags = report.diagnostics
    if semantic and "equiv" in directives:
        diags = tuple(sorted(
            diags + tuple(_pimverify_diags(path, text, directives["equiv"])),
            key=lambda d: (d.severity != ERROR,
                           d.op_index if d.op_index is not None
                           else 1 << 60, d.code)))
    return LintReport(diags)


def _lint_one_file(path: str, args) -> tuple[str, LintReport, str | None]:
    """(name, report, expected-code-or-None); parse failures become a
    single PARSE error diagnostic so the CLI never tracebacks on input."""
    with open(path) as f:
        text = f.read()
    expect = args.expect or _trace_directives(text).get("expect")
    report = lint_trace_file(path, banks=args.banks,
                             subarrays=args.subarrays,
                             async_host=args.async_host,
                             semantic=not args.no_semantic)
    return path, report, expect


def main(argv=None) -> int:
    """Exit codes: 0 clean (or every ``--expect`` matched), 1 diagnostics
    (errors; warnings too under ``--strict``), 2 usage errors."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.pim.lint",
        description="Static verifier for pim-trace files, PIM programs "
                    "and schedules (see DESIGN.md section 12 for the "
                    "diagnostic catalog).")
    ap.add_argument("traces", nargs="*", help="pim-trace files to lint")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full JSON report to PATH")
    ap.add_argument("--banks", type=int, default=None,
                    help="lint against this many banks (default: header)")
    ap.add_argument("--subarrays", type=int, default=None,
                    help="lint against this many subarrays per bank")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail (exit 1)")
    ap.add_argument("--expect", metavar="CODE",
                    help="golden-fixture mode: succeed iff CODE is among "
                         "the diagnostics (overrides in-file directives)")
    ap.add_argument("--async-host", action="store_true",
                    help="also run the async-host hiding analysis")
    ap.add_argument("--no-semantic", action="store_true",
                    help="skip the PIM4xx semantic tier and the "
                         "pimverify/workload equivalence proofs")
    ap.add_argument("--workloads", action="store_true",
                    help="lint the repo's canonical in-memory workloads "
                         "(shift pipeline, XOR reduce, sharded layouts) "
                         "instead of trace files")
    args = ap.parse_args(argv)
    if not args.traces and not args.workloads:
        ap.print_usage(sys.stderr)
        print("error: no traces given (or use --workloads)",
              file=sys.stderr)
        return 2

    results: list[tuple[str, LintReport, str | None]] = []
    if args.workloads:
        for name, report in _workload_reports():
            results.append((name, report, None))
        if not args.no_semantic:
            for name, report in _semantic_reports():
                results.append((name, report, None))
    for path in args.traces:
        try:
            results.append(_lint_one_file(path, args))
        except OSError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    failed = False
    for name, report, expect in results:
        if expect:
            hit = expect in report.codes()
            status = ("ok" if hit else
                      f"MISSING {expect} (got {sorted(set(report.codes()))})")
            print(f"{name}: expect {expect}: {status}")
            failed |= not hit
        else:
            bad = (not report.ok) or (args.strict and report.warnings)
            print(f"{name}: {report.render()}")
            failed |= bool(bad)
    if args.json:
        payload = {name: report.to_json() for name, report, _ in results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return 1 if failed else 0


def _workload_reports():
    """Lint the benchmark-backing workload generators (the 'benchmark-
    generated traces' leg of `make pimlint`): every one must be
    error-free."""
    from .program import shift_workload_program
    from .schedule import (gather_rows, shard_rows, xor_reduce_program)
    from .device import paper_device

    out = []
    prog = shift_workload_program(256)
    out.append(("workload:shift_workload(256)", lint_program(prog)))

    cfg = paper_device(4, num_rows=32, words=8)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 2**32, (12, 8), dtype=np.uint32)
    layout = shard_rows(data, cfg.n_banks, cfg.num_rows, read_back=True)
    out.append(("workload:shard_rows[4 banks]",
                lint_schedule(cfg, layout)))

    xr = xor_reduce_program(32, 8, rows=[0, 1, 2], dst=3)
    out.append(("workload:xor_reduce", lint_program(xr)))

    cfg2 = paper_device(2, num_rows=32, words=8, subarrays=2)
    moves = [((0, 0, 0), (1, 0, 4)), ((0, 1, 0), (1, 1, 4))]
    fused = gather_rows(cfg2, moves, shard_rows(
        data[:8], cfg2.n_banks, cfg2.num_rows, subarrays=2))
    out.append(("workload:gather_rows+shard[2x2]",
                lint_schedule(cfg2, fused)))
    return out


def _recorded_xtime() -> ir.PimProgram:
    """Record (never execute) one GF(2^8) xtime over a symbolic input
    register — the deepest real kernel in the repo at 16 symbolic inputs,
    right at the analyzer's default budget."""
    from ..bitplane import gf
    from ..bitplane.vm import PimVM
    vm = PimVM(8, num_rows=64, words=1)
    a = vm.alloc()
    gf.xtime(vm, a)
    return vm.take_recorded()


def _recorded_rs_encode() -> ir.PimProgram:
    """Record an RS(n, n-2) encode of a concrete 3-symbol message (4 byte
    lanes at words=1); loads are constants in the semantic domain, so the
    whole LFSR folds and the fusion proof is exercised end to end."""
    from ..bitplane import rs
    from ..bitplane.vm import PimVM
    vm = PimVM(8, num_rows=128, words=1)
    msg = [vm.load([i + 1, 2 * i + 3, 7 * i + 5, i * i + 1])
           for i in range(3)]
    rs.rs_encode(vm, msg, 2)
    return vm.take_recorded()


def _semantic_reports():
    """The proof leg of ``--workloads``: every canonical kernel must pass
    its own fused-vs-unfused equivalence gate, and the flagship streams
    must summarize to the closed forms the paper promises. Failures show
    up as ``SEM`` error diagnostics so they fold into the same report/
    exit-code machinery as the lint checks."""
    from . import sem
    from .program import ambit_xor_program, shift_workload_program
    from .schedule import xor_reduce_program

    def check(name, fn):
        try:
            msg = fn()
        except Exception as e:          # a crash IS a failed proof here
            msg = f"{type(e).__name__}: {e}"
        diags = () if msg is None else (
            Diagnostic(code="SEM", severity=ERROR, message=str(msg)),)
        return (f"sem:{name}", LintReport(diags))

    def xor_proved():
        prog = ambit_xor_program()
        got = sem.summarize(prog).get(2)
        if got != "r0 ^ r1":
            return f"ambit_xor row 2 summarizes to {got!r}, not 'r0 ^ r1'"
        rep = sem.fusion_report(prog)
        if rep.verdict != sem.EQUIVALENT:
            return f"ambit_xor fusion verdict {rep.verdict}"
        return None

    def fusion_of(prog):
        def fn():
            rep = sem.fusion_report(prog)
            if rep.verdict != sem.EQUIVALENT:
                return f"fusion verdict {rep.verdict}" + (
                    f" (unknown: {', '.join(rep.unknown)})"
                    if rep.unknown else "")
            return None
        return fn

    return [
        check("ambit_xor", xor_proved),
        check("shift_workload(256)",
              fusion_of(shift_workload_program(256, num_rows=64,
                                               words=32))),
        check("xor_reduce",
              fusion_of(xor_reduce_program(32, 8, rows=[0, 1, 2], dst=3))),
        check("gf.xtime", fusion_of(_recorded_xtime())),
        check("rs.encode", fusion_of(_recorded_rs_encode())),
    ]


if __name__ == "__main__":
    sys.exit(main())
