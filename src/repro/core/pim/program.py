"""PIM program construction & execution helpers.

A "program" is a Python-built straight-line sequence of ISA commands traced
into a single jitted computation. For the paper's workloads we provide:

    run_shift_workload(n_shifts)  — the NVMain experiment (Tables 2 & 3)
    shift_k                       — multi-bit shift by repetition (§8.0.3)
    bank_parallel(fn, n_banks)    — §5.1.4: vmap a PIM program across banks

plus a static cost estimator mirroring the timing model without tracing.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import isa
from .state import SubarrayState, make_subarray
from .timing import DDR3Timing, DEFAULT_TIMING, apply_refresh


def shift_k(state: SubarrayState, src, dst, k: int,
            cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Shift by |k| columns = |k| repeated 1-bit migration shifts.

    First shift goes src->dst, the rest dst->dst (the paper's primitive is
    strictly 1 bit per 4-AAP sequence).
    """
    if k == 0:
        return isa.rowclone(state, src, dst, cfg)
    delta = 1 if k > 0 else -1
    s = isa.shift(state, src, dst, delta, cfg)
    for _ in range(abs(k) - 1):
        s = isa.shift(s, dst, dst, delta, cfg)
    return s


@functools.partial(jax.jit, static_argnames=("n_shifts", "num_rows", "words"))
def run_shift_workload(row: jax.Array, n_shifts: int,
                       num_rows: int = 512, words: int = 2048) -> SubarrayState:
    """The paper's NVMain workload: N full-row 1-bit right shifts in Bank 0
    Subarray 0, sequentially, with periodic refresh folded in at the end.

    src row = 0, dst row = 1; shifts chain dst->dst so N shifts move the data
    N columns (matching "each shift operation shifts all bits ... by one
    position" executed back-to-back).
    """
    state = make_subarray(num_rows, words)
    state = isa.reserve_control_rows(state)
    state = SubarrayState(bits=state.bits.at[0].set(row.astype(jnp.uint32)),
                          mig_top=state.mig_top, mig_bot=state.mig_bot,
                          dcc=state.dcc, meter=state.meter)
    state = isa.issue(state)

    def body(s, _):
        return isa.shift(s, 1, 1, +1), ()

    # First shift reads the source row; the rest chain in place.
    state = isa.shift(state, 0, 1, +1)
    if n_shifts > 1:
        state, _ = jax.lax.scan(body, state, None, length=n_shifts - 1)
    meter = apply_refresh(state.meter)
    return SubarrayState(bits=state.bits, mig_top=state.mig_top,
                         mig_bot=state.mig_bot, dcc=state.dcc, meter=meter)


def bank_parallel(fn: Callable, n_banks: int):
    """§5.1.4: run the same PIM program concurrently in ``n_banks`` banks.

    Banks are independent (separate row buffers & subarrays) so wall time is
    max over banks while energy sums — exactly the paper's claim that
    throughput scales linearly at constant energy/op.
    """
    vfn = jax.vmap(fn)

    def wrapped(*batched_args):
        states = vfn(*batched_args)
        wall_ns = jnp.max(states.meter.time_ns)
        energy_nj = jnp.sum(states.meter.total_energy_nj)
        return states, wall_ns, energy_nj

    return wrapped


def estimate_cost(n_shifts: int = 0, n_aaps: int = 0, n_tras: int = 0,
                  cfg: DDR3Timing = DEFAULT_TIMING) -> dict:
    """Static (no-trace) cost model for planning PIM programs."""
    t = (n_shifts * cfg.t_shift + n_aaps * cfg.t_aap + n_tras * cfg.tRC
         + cfg.t_issue)
    n_ref = int(t // cfg.tREFI)
    n_ref = int((t + n_ref * cfg.tRFC) // cfg.tREFI)
    t += n_ref * cfg.tRFC
    e_act = (n_shifts * 8 + n_aaps * 2 + n_tras) * cfg.e_act \
        + n_tras * 2 * cfg.e_act_extra_row
    e_pre = (n_shifts * 4 + n_aaps + n_tras) * cfg.e_pre
    e_ref = n_ref * cfg.e_ref
    e_bg = t * cfg.p_background
    return {
        "time_ns": t,
        "energy_nj": e_act + e_pre + e_ref + e_bg,
        "e_act": e_act, "e_pre": e_pre, "e_refresh": e_ref,
        "n_refresh": n_ref,
    }
