"""PIM program construction & execution helpers.

A "program" is a recorded :class:`~.ir.PimProgram` instruction stream run
through the compiling executor (``compile.py`` / ``exec.py``): cost-modeled
in one pass and kernel-fused, instead of interpreted command-at-a-time. For
the paper's workloads we provide:

    run_shift_workload(n_shifts)  — the NVMain experiment (Tables 2 & 3)
    shift_k                       — multi-bit shift by repetition (§8.0.3)
    bank_parallel(prog, n_banks)  — §5.1.4: one compiled program, all banks

plus a static cost estimator mirroring the timing model without tracing.
Both paths are bit-exact against the eager ISA (tests/test_pim_ir.py); the
eager command-at-a-time shim remains available as ``isa.*``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .compile import CompiledProgram, compile_program
from .ir import PimProgram, ProgramBuilder
from .state import SubarrayState, make_subarray
from .timing import DDR3Timing, DEFAULT_TIMING, refresh_events_scalar


def shift_k(state: SubarrayState, src, dst, k: int,
            cfg: DDR3Timing = DEFAULT_TIMING) -> SubarrayState:
    """Shift by |k| columns = |k| repeated 1-bit migration shifts.

    First shift goes src->dst, the rest dst->dst (the paper's primitive is
    strictly 1 bit per 4-AAP sequence). With concrete row indices the
    sequence is recorded as IR and run fused (one k-column kernel shift);
    traced indices fall back to the eager shim.
    """
    from . import exec as pim_exec

    concrete = all(isinstance(r, (int, np.integer)) for r in (src, dst))
    if not concrete:
        if k == 0:
            return isa.rowclone(state, src, dst, cfg)
        delta = 1 if k > 0 else -1
        s = isa.shift(state, src, dst, delta, cfg)
        for _ in range(abs(k) - 1):
            s = isa.shift(s, dst, dst, delta, cfg)
        return s
    compiled = _shift_k_compiled(state.num_rows, state.words,
                                 src % state.num_rows, dst % state.num_rows,
                                 k, cfg)
    return pim_exec.execute(compiled, state, cfg).state


@functools.lru_cache(maxsize=256)
def _shift_k_compiled(num_rows: int, words: int, src: int, dst: int, k: int,
                      cfg: DDR3Timing) -> CompiledProgram:
    b = ProgramBuilder(num_rows, words)
    b.shift_k(src, dst, k)
    return compile_program(b.build(), cfg)


@functools.lru_cache(maxsize=256)
def shift_workload_program(n_shifts: int, num_rows: int = 512,
                           words: int = 2048,
                           verify: bool = False) -> PimProgram:
    """The recorded Table 2/3 instruction stream: one issue burst, then N
    chained 1-bit right shifts (row 0 → row 1 → row 1 …). ``verify=True``
    runs the static verifier on the recorded stream (builder-side gate;
    errors raise :class:`~.lint.LintError`)."""
    assert n_shifts >= 1, "the workload is defined for at least one shift"
    b = ProgramBuilder(num_rows, words, verify=verify)
    b.issue()
    b.shift_k(0, 1, n_shifts)
    return b.build()


@functools.lru_cache(maxsize=256)
def ambit_xor_program(num_rows: int = 16, words: int = 2, *, a: int = 0,
                      b: int = 1, dst: int = 2,
                      read_back: bool = True) -> PimProgram:
    """The canonical recorded ``ambit_xor`` kernel: reserve control rows,
    expand ``dst <- a ^ b`` into its MAJ/NOT primitive sequence, and
    (optionally) read ``dst`` back. The small default shape keeps the
    stream cheap to execute AND to analyze — ``sem.summarize`` proves
    row ``dst`` computes ``r{a} ^ r{b}`` on it, the repo's one-line
    "proved by analysis" demo."""
    builder = ProgramBuilder(num_rows, words)
    builder.reserve_control_rows()
    builder.ambit_xor(a, b, dst)
    if read_back:
        builder.read_row(dst)
    return builder.build()


@functools.lru_cache(maxsize=256)
def _shift_workload_compiled(n_shifts: int, num_rows: int,
                             words: int) -> CompiledProgram:
    return compile_program(shift_workload_program(n_shifts, num_rows, words))


def run_shift_workload(row: jax.Array, n_shifts: int,
                       num_rows: int = 512, words: int = 2048) -> SubarrayState:
    """The paper's NVMain workload: N full-row 1-bit right shifts in Bank 0
    Subarray 0, sequentially, with periodic refresh folded in at the end.

    src row = 0, dst row = 1; shifts chain dst->dst so N shifts move the data
    N columns (matching "each shift operation shifts all bits ... by one
    position" executed back-to-back). The stream is recorded once per
    ``n_shifts`` and executed compiled: the N-shift chain fuses to a single
    N-column kernel shift and the meter comes from the one-fold cost pass.
    """
    from . import exec as pim_exec

    state = make_subarray(num_rows, words)
    state = isa.reserve_control_rows(state)
    state = SubarrayState(bits=state.bits.at[0].set(row.astype(jnp.uint32)),
                          mig_top=state.mig_top, mig_bot=state.mig_bot,
                          dcc=state.dcc, meter=state.meter)
    compiled = _shift_workload_compiled(n_shifts, num_rows, words)
    return pim_exec.execute(compiled, state, refresh=True).state


def bank_parallel(fn: Callable | PimProgram | CompiledProgram, n_banks: int,
                  cfg: DDR3Timing = DEFAULT_TIMING):
    """§5.1.4: run the same PIM program concurrently in ``n_banks`` banks.

    Banks are independent (separate row buffers & subarrays) so wall time is
    max over banks while energy sums — exactly the paper's claim that
    throughput scales linearly at constant energy/op.

    Given a recorded/compiled program, ONE compiled artifact is vmapped
    across a bank batch of states (states in, (states, wall, energy) out).
    A plain callable keeps the legacy row-in, state-out contract.

    This is the homogeneous fast path (no command-bus model, identical
    payloads). For heterogeneous per-bank programs, per-bank HOSTW data,
    and bus-serialized device timing, use ``device.make_device`` +
    ``schedule.schedule`` (DESIGN.md §7).
    """
    if isinstance(fn, (PimProgram, CompiledProgram)):
        from . import exec as pim_exec
        return pim_exec.bank_parallel(fn, cfg)
    vfn = jax.vmap(fn)

    def wrapped(*batched_args):
        states = vfn(*batched_args)
        wall_ns = jnp.max(states.meter.time_ns)
        energy_nj = jnp.sum(states.meter.total_energy_nj)
        return states, wall_ns, energy_nj

    return wrapped


def estimate_cost(n_shifts: int = 0, n_aaps: int = 0, n_tras: int = 0,
                  cfg: DDR3Timing = DEFAULT_TIMING) -> dict:
    """Static (no-trace) cost model for planning PIM programs."""
    t = (n_shifts * cfg.t_shift + n_aaps * cfg.t_aap + n_tras * cfg.tRC
         + cfg.t_issue)
    n_ref = refresh_events_scalar(t, cfg)
    t += n_ref * cfg.tRFC
    e_act = (n_shifts * 8 + n_aaps * 2 + n_tras) * cfg.e_act \
        + n_tras * 2 * cfg.e_act_extra_row
    e_pre = (n_shifts * 4 + n_aaps + n_tras) * cfg.e_pre
    e_ref = n_ref * cfg.e_ref
    e_bg = t * cfg.p_background
    return {
        "time_ns": t,
        "energy_nj": e_act + e_pre + e_ref + e_bg,
        "e_act": e_act, "e_pre": e_pre, "e_refresh": e_ref,
        "n_refresh": n_ref,
    }
