"""Workload scheduler for device-level (multi-bank, multi-subarray) PIM
execution.

Takes *heterogeneous* per-slot :class:`~.ir.PimProgram`s (slot = one
``(bank, subarray)`` pair) and executes them against a
:class:`~.device.DeviceState` with as few compiled artifacts as possible:
slots whose command streams are identical (same ops, shape and payload
count — payload *data* may differ) form one group, and each group runs as
ONE compiled runner vmapped over the group's slot states with the HOSTW
payloads passed as a batched argument (``exec.make_runner``'s
``payload_arg`` mode). This is SIMDRAM's framework split — program →
allocation → execution — with Shared-PIM-style concurrent bank scheduling.

In-DRAM row movement (``COPY``, LISA-style): a slot's stream may carry
``COPY`` ops whose destination is *another* slot — an adjacent subarray
(row-buffer-movement hops) or another bank (the chip's shared internal
bus). The scheduler strips those ops out of the compiled streams and
drains them **after the step's in-bank compute**, DMA-engine style: a
cross-slot COPY reads its source row's *post-compute* value, copies apply
in (slot, stream-position) order (later copies observe earlier ones), and
the moved rows are visible to the *next* ``schedule`` step. Each copy
charges ``timing.copy_cost`` onto the **source** slot's meter — no HOSTR/
HOSTW, no off-chip burst energy. Same-slot COPYs stay in-stream (they are
ordinary distance-0 LISA copies the executor runs directly).

The drain itself is *link-contended*: every inter-subarray RBM link
(``(bank, i)`` joins subarrays ``i``/``i+1``) and every channel's shared
internal bus is a FCFS resource. Copies are served in drain order; a copy
holds every link it crosses (plus the internal bus(es) for inter-bank
moves) for its full duration, so massive gathers queue instead of
draining for free. An inter-bank copy pays real RBM hops too: source
subarray → bank edge (subarray 0, where the internal bus taps the bank)
and edge → destination subarray.

Device accounting (see ``device.py``): per-slot meters accumulate each
slot's own busy time; the schedule-level wall clock is channel-aware:

    wall = max_ch chan_busy_ch + max_k (Δt_k − bus_k) + copy drain makespan
    energy = Σ_k Δenergy_k

where ``bus_k`` is slot k's bus occupancy (ISSUE bursts AND off-chip
HOSTW/HOSTR burst windows) and ``chan_busy_ch`` serializes the occupancy
of channel ``ch``'s slots FCFS, charging ``tRTRS`` between bursts that
switch rank. With ``async_host=True`` (Shared-PIM-style double buffering)
each channel's HOST traffic first overlaps the *previous* step's
compute+copy window (``DeviceState.host_credit_ns``), so multi-step
pipelines pay ``max(transfer, compute)`` instead of the sum — bits,
reads, and energy are identical to the sync schedule.

``shard_rows`` / ``shard_lanes`` partition one large host buffer into
per-slot programs (row-wise or lane-wise, optionally across the subarray
axis), and ``gather_rows`` / ``xor_reduce_program`` are the in-DRAM
movement/reduction building blocks the benchmarks use to exchange rows
between slots without host round-trips (RS syndrome sums across banks,
cross-lane reductions).

Host-side performance model (DESIGN.md §10): one ``schedule`` call is ONE
XLA dispatch. Grouping hashes the programs' cached columnar digests (O(1)
per slot), the per-call layout resolves to a cached :class:`_StepPlan`
whose jitted step function folds every stream group, the COPY drain, and
the channel-bus model into a single compiled computation, and all returned
timing values stay lazy (device/numpy) until read. ``schedule_pipeline``
runs K recurring steps under one ``jax.lax.scan`` — steady-state per-step
cost is one scan iteration, not a Python round-trip.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import exec as pim_exec
from . import ir
from .compile import CompiledProgram, compile_program
from .device import (DeviceConfig, DeviceState, channel_occupancy,
                     host_bus_ns, issue_bus_ns)
from .ir import PimProgram, ProgramBuilder
from .state import NUM_ROWS
from .timing import DDR3Timing, DEFAULT_TIMING, copy_cost


def _unbatch_reads(group_reads, read_layout, n_steps=None):
    """Shared lazy read unbatching: ONE device->host transfer per group
    read array, then plain numpy slicing into the per-slot layout. With
    ``n_steps`` the arrays carry a leading step axis and a per-step list
    is returned."""
    n_slots, group_slots = read_layout
    host = [tuple(np.asarray(r) for r in g) for g in group_reads]

    def one_step(pick):
        out: list = [()] * n_slots
        for g, slots in enumerate(group_slots):
            for j, k in enumerate(slots):
                out[k] = tuple(pick(r, j) for r in host[g])
        return tuple(out)

    if n_steps is None:
        return one_step(lambda r, j: r[j])
    return [one_step(lambda r, j, k=k: r[k, j]) for k in range(n_steps)]


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one device-level schedule step.

    Timing metrics that may live on-device (async mode makes the channel
    occupancy depend on the previous step's lazy compute window) are stored
    raw in underscored fields and converted on *access* — reading
    ``host_overlap_ns`` etc. yields plain floats exactly as before, but
    constructing the result never blocks on the device, so back-to-back
    ``schedule`` calls dispatch asynchronously."""

    state: DeviceState
    wall_ns: jax.Array          # max-channel bus + max in-slot exec + copies
    bus_ns: float               # total bus occupancy, summed over slots
    energy_nj: jax.Array        # summed across slots (this step only)
    copy_ns: float = 0.0        # COPY drain *makespan* (link-contended wall)
    host_bytes: int = 0         # off-chip bytes this step's streams moved
    rank_switch_ns: float = 0.0  # total tRTRS penalty charged this step
    copy_total_ns: float = 0.0  # Σ per-copy duration (old copy_ns meaning)
    copy_queue_ns: float = 0.0  # Σ FCFS waiting behind busy links/buses
    link_busy_ns: dict = dataclasses.field(default_factory=dict)
    # per-resource occupancy: ("link", bank, i) RBM link between subarrays
    # i/i+1, ("ibus", channel) the channel's shared internal bus.
    _host_bus_ns: float = 0.0   # HOSTW/HOSTR burst occupancy, Σ over slots
    _channel_bus_ns: object = ()  # per-channel occupancy (may be on-device)
    _host_overlap_ns: object = 0.0  # host time hidden under prev step
    _group_reads: tuple = ()    # per group: per-read (n_group, words) arrays
    _read_layout: tuple = (0, ())  # (n_slots, group slot-id tuples)

    @property
    def reads(self) -> tuple:
        """Per slot: host-read rows in ``read_row`` slot order. The jitted
        step returns reads batched per stream group; the per-slot view is
        sliced out lazily here (and memoized) so the hot scheduling path
        never pays per-slot unbatching."""
        cached = getattr(self, "_reads_cache", None)
        if cached is None:
            cached = _unbatch_reads(self._group_reads, self._read_layout)
            self._reads_cache = cached
        return cached

    @property
    def host_bus_ns(self) -> float:
        return float(self._host_bus_ns)

    @property
    def host_overlap_ns_lazy(self):
        """The raw (possibly on-device) hidden-host-time value — for
        accumulators that must not block (``host_overlap_ns`` converts)."""
        return self._host_overlap_ns

    @property
    def channel_bus_ns(self) -> tuple:
        """Per-channel serialized occupancy (+tRTRS), as floats."""
        return tuple(float(x) for x in self._channel_bus_ns)

    @property
    def host_overlap_ns(self) -> float:
        return float(self._host_overlap_ns)


def stream_key(p: PimProgram):
    """Slots with equal keys share one compiled vmapped runner: identical
    command stream and shape; HOSTW payload *data* is excluded (it is passed
    per-slot at run time). O(1): the stream itself is represented by the
    program's cached 128-bit columnar digest, not re-hashed per call."""
    return (p.digest, p.num_rows, p.words, len(p.payloads))


# Host-orchestration counters, reset-able by tests/benchmarks:
#   dispatches     — XLA dispatches issued by schedule()/schedule_pipeline()
#                    (the acceptance bar is <= 1 per steady-state step)
#   plan_misses    — step-plan cache misses (a new schedule layout)
#   compile_misses — _compiled_for cache misses (a new program stream)
SCHED_STATS = {"dispatches": 0, "plan_misses": 0, "compile_misses": 0}


# One compiled artifact per distinct (stream, timing): groups recur across
# schedule() calls (e.g. PimVM flushes), so keep the jitted runners warm.
# LRU-bounded — long sessions stream many one-off programs through here,
# and insertion-order (FIFO) eviction would let them push out hot
# recurring streams.
_compile_cache: dict = {}
_COMPILE_CACHE_MAX = 512


def _compiled_for(program: PimProgram, timing: DDR3Timing) -> CompiledProgram:
    key = (stream_key(program), timing)
    hit = _compile_cache.pop(key, None)
    if hit is None:
        SCHED_STATS["compile_misses"] += 1
        if len(_compile_cache) >= _COMPILE_CACHE_MAX:
            _compile_cache.pop(next(iter(_compile_cache)))
        hit = compile_program(program, timing)
    _compile_cache[key] = hit           # (re)insert at the MRU end
    return hit


def compiled_for(program: PimProgram,
                 timing: DDR3Timing = DEFAULT_TIMING) -> CompiledProgram:
    """Public entry to the scheduler's LRU compile cache: equal streams
    (by columnar digest) share one :class:`CompiledProgram` — and thereby
    one set of jitted runners — across calls. Use this instead of
    ``compile_program`` for recurring streams (``PimVM`` does)."""
    return _compiled_for(program, timing)


# Stacked payload batches keyed on the *identity* of the payload arrays:
# recurring flushes (PimVM pipelines) schedule the same PimProgram objects
# over and over, and re-np.stack-ing identical host data plus re-uploading
# it to the device every step was pure waste. Cache values hold references
# to the source arrays, pinning their ids for the lifetime of the entry
# (so a recycled id can never alias a dead key). Bounded by entry count
# AND by pinned bytes: the "multi" pipeline entries hold K-times-stacked
# device arrays, and a long-running serving loop with churning payloads
# would otherwise grow device memory without bound.
_payload_cache: dict = {}
_PAYLOAD_CACHE_MAX = 256
_PAYLOAD_CACHE_MAX_BYTES = 256 << 20        # pinned stacked-array budget
_payload_cache_bytes = 0


def _entry_nbytes(hit) -> int:
    """Bytes one cache entry pins: the stacked device array plus the host
    source arrays it keeps alive for id stability."""
    stacked, refs = hit
    n = int(stacked.nbytes)
    for group in refs:
        arrays = group if isinstance(group, (tuple, list)) else (group,)
        n += sum(int(a.nbytes) for a in arrays)  # no host sync: attr only
    return n


def _payload_cache_get(key):
    """LRU hit: pop + reinsert at the MRU end (byte total unchanged)."""
    hit = _payload_cache.pop(key, None)
    if hit is not None:
        _payload_cache[key] = hit
    return hit


def _payload_cache_put(key, hit) -> None:
    """Insert at the MRU end, then evict LRU entries until both the entry
    count and the pinned-byte budget hold. The newest entry itself is never
    evicted — one oversized batch must still be cacheable or recurring
    pipelines would re-upload it every call."""
    global _payload_cache_bytes
    _payload_cache[key] = hit
    _payload_cache_bytes += _entry_nbytes(hit)
    while (len(_payload_cache) > _PAYLOAD_CACHE_MAX
           or _payload_cache_bytes > _PAYLOAD_CACHE_MAX_BYTES):
        if len(_payload_cache) <= 1:
            break
        old = _payload_cache.pop(next(iter(_payload_cache)))
        _payload_cache_bytes -= _entry_nbytes(old)


def _payload_cache_clear() -> None:
    """Drop every pinned payload batch (test hygiene)."""
    global _payload_cache_bytes
    _payload_cache.clear()
    _payload_cache_bytes = 0


def _payload_stack(programs: Sequence[PimProgram], words: int) -> jnp.ndarray:
    """(n_slots_in_group, n_payloads, words) uint32 HOSTW payload batch."""
    n_pay = len(programs[0].payloads)
    if n_pay == 0:
        key = ("zeros", len(programs), words)
    else:
        # shape prefix disambiguates the partitioning: the same id sequence
        # could otherwise alias e.g. 2 programs x 2 payloads vs 4 x 1
        key = (len(programs), n_pay, words) + tuple(
            id(a) for p in programs for a in p.payloads)
    hit = _payload_cache_get(key)
    if hit is None:
        if n_pay == 0:
            stacked = jnp.zeros((len(programs), 0, words), jnp.uint32)
            refs = ()
        else:
            stacked = jnp.asarray(np.stack(
                [np.stack(p.payloads) for p in programs]).astype(np.uint32))
            refs = tuple(p.payloads for p in programs)
        _payload_cache_put(key, (stacked, refs))
        return stacked
    return hit[0]


def _normalize_programs(cfg: DeviceConfig, programs) -> list:
    """Accept per-bank (len ``n_banks``, entries optionally nested per
    subarray) or flat per-slot (len ``n_slots``) program sequences and
    return a flat per-slot list (``None`` = idle)."""
    programs = list(programs)
    flat: list = [None] * cfg.n_slots
    S = cfg.subarrays

    def put(slot, p):
        flat[slot] = p

    if len(programs) == cfg.n_slots and not any(
            isinstance(p, (list, tuple)) for p in programs):
        for k, p in enumerate(programs):
            put(k, p)
        return flat
    if len(programs) != cfg.n_banks:
        raise ValueError(
            f"got {len(programs)} programs for {cfg.n_banks} banks "
            f"({cfg.n_slots} slots)")
    for b, entry in enumerate(programs):
        if isinstance(entry, (list, tuple)):
            if len(entry) != S:
                raise ValueError(
                    f"bank {b}: {len(entry)} subarray programs for "
                    f"{S} subarrays")
            for s, p in enumerate(entry):
                put(b * S + s, p)
        else:
            put(b * S, entry)       # bare program → the bank's subarray 0
    return flat


def _split_copies(cfg: DeviceConfig, slot: int, program: PimProgram):
    """Partition one slot's stream into (compiled-stream program, deferred
    cross-slot copies). Same-slot COPYs are normalized to the executor's
    local ``COPY_SELF`` encoding and stay in-stream.

    The no-copy common case is detected vectorized on the columnar
    encoding (no per-op Python walk); only streams that actually carry
    cross-slot or explicitly-self-addressed COPYs take the op loop."""
    cols = program.columns
    is_copy = cols.code == ir.OP_CODE[ir.OP_COPY]
    b, s = cfg.slot_coords(slot)
    if not is_copy.any():
        return program, []              # no COPYs at all: nothing to strip
    self_like = (cols.delta == ir.COPY_SELF) & (cols.c == ir.COPY_SELF)
    if not (is_copy & ~self_like).any():
        return program, []              # every COPY already local-encoded
    self_dst = (ir.COPY_SELF, ir.COPY_SELF)
    kept, deferred = [], []
    changed = False
    for op in program.ops:
        # On the device, local means self-addressed or "destination IS the
        # carrying slot" — explicit (0, 0) on any other carrier is a real
        # transfer to bank 0, so ir.copy_is_local only applies at (0, 0).
        is_local = (op.op == ir.OP_COPY
                    and ((op.delta, op.c) == self_dst
                         or (op.delta, op.c) == (b, s)))
        if op.op != ir.OP_COPY or is_local:
            if is_local and (op.delta, op.c) != self_dst:
                op = dataclasses.replace(op, delta=ir.COPY_SELF,
                                         c=ir.COPY_SELF)
                changed = True
            kept.append(op)
            continue
        dst_slot = cfg.slot_index(op.delta, op.c)   # validates coordinates
        if not (0 <= op.a < cfg.num_rows and 0 <= op.b < cfg.num_rows):
            raise ValueError(
                f"slot {(b, s)}: COPY rows {(op.a, op.b)} out of range "
                f"[0, {cfg.num_rows})")
        deferred.append((slot, dst_slot, op))
        changed = True
    if not changed:
        return program, deferred
    return PimProgram(ops=tuple(kept), num_rows=program.num_rows,
                      words=program.words,
                      payloads=program.payloads), deferred


@dataclasses.dataclass
class CopyDrainStats:
    """Link-contention accounting of one step's COPY drain phase."""

    makespan_ns: float = 0.0    # FCFS queue-model wall of the drain
    total_ns: float = 0.0       # Σ per-copy duration (contention-free sum)
    queue_ns: float = 0.0       # Σ time copies waited behind busy resources
    link_busy_ns: dict = dataclasses.field(default_factory=dict)


def _copy_route(cfg: DeviceConfig, src_slot: int, dst_slot: int):
    """(hops, inter_bank, resources) of one cross-slot copy.

    Intra-bank: RBM hops between the two subarrays, crossing links
    ``(bank, i)`` for i in [min, max). Inter-bank: the row rides RBM links
    from the source subarray to the bank edge (subarray 0, where the
    chip's internal bus taps the bank), crosses the channel's shared
    internal bus, and rides links from the destination's edge inward —
    so an S-1 → S-1 move costs 2(S-1) hops on top of ``t_copy_bank``.
    """
    S = cfg.subarrays
    sb, ss = divmod(src_slot, S)
    db, ds = divmod(dst_slot, S)
    if sb == db:
        hops = abs(ds - ss)
        res = [("link", sb, i) for i in range(min(ss, ds), max(ss, ds))]
        return hops, False, res
    hops = ss + ds
    res = [("link", sb, i) for i in range(ss)]
    res += [("link", db, i) for i in range(ds)]
    s_ch = cfg.bank_coords(sb)[0]
    d_ch = cfg.bank_coords(db)[0]
    res.append(("ibus", s_ch))
    if d_ch != s_ch:
        res.append(("ibus", d_ch))
    return hops, True, res


@dataclasses.dataclass(frozen=True)
class _CopyDrainPlan:
    """Route-table + FCFS outcome of one copy *pattern* (the (src, dst)
    slot pairs, in drain order). Rows are not part of the pattern — the
    same gather shape recurs step after step with different rows, and
    everything here depends only on the slots, so it is computed once and
    cached."""

    dt_slot: np.ndarray         # (n_slots,) float32 Σ copy time per source
    e_act_slot: np.ndarray      # (n_slots,) float32
    e_pre_slot: np.ndarray      # (n_slots,) float32
    n_act_slot: np.ndarray      # (n_slots,) int32
    n_pre_slot: np.ndarray      # (n_slots,) int32
    n_aap_slot: np.ndarray      # (n_slots,) int32
    stats: CopyDrainStats


@functools.lru_cache(maxsize=256)
def _copy_drain_plan(cfg: DeviceConfig, pairs: tuple) -> _CopyDrainPlan:
    """Per-copy route tables and ``timing.copy_cost`` charges (computed
    once per pair in the FCFS walk), per-source meter increments (one
    ``np.add.at`` scatter per field), and the FCFS link/bus serialization
    — all keyed on (device, copy pattern) so recurring steps skip the
    whole computation."""
    t = cfg.timing
    n = cfg.n_slots
    src = np.fromiter((p[0] for p in pairs), np.int64, len(pairs))
    dt = np.zeros(len(pairs))
    e_act = np.zeros(len(pairs))
    stats = CopyDrainStats()
    ready: dict = {}                    # resource -> busy-until (drain clock)
    for i, (src_slot, dst_slot) in enumerate(pairs):
        hops, inter_bank, resources = _copy_route(cfg, src_slot, dst_slot)
        c_dt, c_ea, _, _, _, _ = copy_cost(hops, inter_bank, t)
        dt[i] = c_dt
        e_act[i] = c_ea
        start = max((ready.get(r, 0.0) for r in resources), default=0.0)
        end = start + c_dt
        for r in resources:
            ready[r] = end
            stats.link_busy_ns[r] = stats.link_busy_ns.get(r, 0.0) + c_dt
        stats.queue_ns += start
        stats.total_ns += c_dt
        stats.makespan_ns = max(stats.makespan_ns, end)
    dt_slot = np.zeros(n, np.float32)
    e_act_slot = np.zeros(n, np.float32)
    e_pre_slot = np.zeros(n, np.float32)
    n_act_slot = np.zeros(n, np.int32)
    n_pre_slot = np.zeros(n, np.int32)
    n_aap_slot = np.zeros(n, np.int32)
    np.add.at(dt_slot, src, dt.astype(np.float32))
    np.add.at(e_act_slot, src, e_act.astype(np.float32))
    np.add.at(e_pre_slot, src, np.float32(t.e_pre))
    np.add.at(n_act_slot, src, np.int32(2))
    np.add.at(n_pre_slot, src, np.int32(1))
    np.add.at(n_aap_slot, src, np.int32(1))
    return _CopyDrainPlan(dt_slot=dt_slot, e_act_slot=e_act_slot,
                          e_pre_slot=e_pre_slot, n_act_slot=n_act_slot,
                          n_pre_slot=n_pre_slot, n_aap_slot=n_aap_slot,
                          stats=stats)


@dataclasses.dataclass
class _StepPlan:
    """One schedule layout, fully lowered: the jitted single-dispatch step
    function plus every static (trace-time) quantity of the step. Cached
    per (device config, flags, group signature, copy signature) so a
    recurring step pays ONE dict lookup + one XLA dispatch."""

    fn: object                  # jitted (banks, credit, payloads) -> ...
    raw_fn: object              # same, unjitted (inlined into pipelines)
    group_slots: tuple          # tuple of slot-id tuples, plan group order
    bus_total: float            # Σ per-slot bus occupancy
    host_bus_total: float       # Σ per-slot host-burst occupancy
    chan_busy: tuple            # per-channel occupancy at credit=0 (+tRTRS)
    switch_ns: float
    host_bytes: int
    copy: "_CopyDrainPlan | None"
    group_n_reads: tuple = ()   # per group: HOSTR count of the rep stream
    group_n_payloads: tuple = ()  # per group: HOSTW payload count
    # Static diagnostics of this layout (lint._plan_diagnostics), computed
    # ONCE at plan build: the verify=True gates of schedule()/
    # schedule_pipeline()/schedule_workload() only scan this cached tuple,
    # so warm paths pay zero extra work.
    lint: tuple = ()


_plan_cache: dict = {}
_PLAN_CACHE_MAX = 256


def _plan_key(cfg: DeviceConfig, groups, deferred, *,
              use_kernels, interpret, refresh, async_host):
    """The step-plan cache key: everything trace-relevant about one
    schedule layout (streams via digests, grouping, copy pattern, flags).
    Shared by ``_plan_for`` and the multi-phase workload signature."""
    return (cfg, use_kernels, interpret, refresh, async_host,
            tuple((key, tuple(slots)) for key, slots in groups.items()),
            tuple((s, d, op.a, op.b) for s, d, op in deferred))


def _make_step_fn(cfg: DeviceConfig, runners, group_slots, bus_j,
                  chan_busy0, host_ch, copy_plan, copy_moves,
                  copy_independent, async_host):
    """Build the single-dispatch jitted step: every stream group's vmapped
    run, the COPY drain (bits scatter + meter bump), and the channel-bus
    fold — one traced computation, one XLA dispatch per call."""
    n_slots = cfg.n_slots
    bus_j_c = jnp.asarray(bus_j)
    busy0_c = jnp.asarray(chan_busy0, jnp.float32)
    host_ch_c = jnp.asarray(host_ch, jnp.float32)
    p_bg = jnp.float32(cfg.timing.p_background)
    idx_arrays = [jnp.asarray(np.asarray(slots)) for slots in group_slots]
    makespan = jnp.float32(copy_plan.stats.makespan_ns if copy_plan else 0.0)

    def step(banks, credit, payloads):
        t0 = jnp.asarray(banks.meter.time_ns)
        e0 = jnp.asarray(banks.meter.total_energy_nj)
        new_banks = banks
        reads = []
        for g, runner in enumerate(runners):
            if group_slots[g] == tuple(range(n_slots)):
                # group covers every slot: no gather/scatter round-trip
                # (the homogeneous fast path — one vmap over the banks)
                out, group_reads = jax.vmap(runner.traced)(banks,
                                                           payloads[g])
                new_banks = out
            else:
                idx = idx_arrays[g]
                sub = jax.tree_util.tree_map(lambda x: x[idx], banks)
                out, group_reads = jax.vmap(runner.traced)(sub, payloads[g])
                new_banks = jax.tree_util.tree_map(
                    lambda full, upd: full.at[idx].set(upd), new_banks, out)
            reads.append(group_reads)   # batched: per-slot view sliced lazily
        # In-slot execution excludes each slot's own bus occupancy and the
        # drained copies (accounted by the contention model below).
        exec_ns = jnp.asarray(new_banks.meter.time_ns) - t0 - bus_j_c
        if copy_plan is not None:
            bits = new_banks.bits
            si, sr, di, dr = copy_moves
            if copy_independent:
                # Independent copies (the common gather pattern: distinct
                # destinations, none feeding a later copy) — ONE batched
                # scatter instead of a row-at-a-time chain.
                bits = bits.at[jnp.asarray(di), jnp.asarray(dr)].set(
                    bits[jnp.asarray(si), jnp.asarray(sr)])
            else:
                for s_slot, s_row, d_slot, d_row in zip(si, sr, di, dr):
                    bits = bits.at[d_slot, d_row].set(bits[s_slot, s_row])
            m = new_banks.meter
            meter = dataclasses.replace(
                m,
                time_ns=m.time_ns + jnp.asarray(copy_plan.dt_slot),
                e_act=m.e_act + jnp.asarray(copy_plan.e_act_slot),
                e_pre=m.e_pre + jnp.asarray(copy_plan.e_pre_slot),
                e_background=m.e_background
                + jnp.asarray(copy_plan.dt_slot) * p_bg,
                n_act=m.n_act + jnp.asarray(copy_plan.n_act_slot),
                n_pre=m.n_pre + jnp.asarray(copy_plan.n_pre_slot),
                n_aap=m.n_aap + jnp.asarray(copy_plan.n_aap_slot))
            new_banks = dataclasses.replace(new_banks, bits=bits,
                                            meter=meter)
        e1 = jnp.asarray(new_banks.meter.total_energy_nj)
        compute_ns = jnp.max(exec_ns) + makespan
        if async_host:
            hidden = jnp.minimum(
                host_ch_c,
                jnp.maximum(jnp.asarray(credit, jnp.float32), 0.0))
        else:
            hidden = jnp.zeros_like(host_ch_c)
        busy = busy0_c - hidden
        wall = jnp.max(busy) + compute_ns
        energy = jnp.sum(e1 - e0)
        # The outgoing double-buffer credit: only an ASYNC step prefetches
        # the next step's transfers under its compute window. A sync step
        # resets the leaf to zero — its host engine ran synchronously, so
        # there is nothing buffered for a later async step to hide behind.
        credit_out = compute_ns if async_host else jnp.float32(0.0)
        return (new_banks, tuple(reads), wall, energy, credit_out, busy,
                jnp.sum(hidden))

    return jax.jit(step), step


def _plan_for(cfg: DeviceConfig, stripped, groups, deferred, *,
              use_kernels, interpret, refresh, async_host) -> _StepPlan:
    """Resolve (and cache) the step plan of one schedule layout."""
    plan_key = _plan_key(cfg, groups, deferred, use_kernels=use_kernels,
                         interpret=interpret, refresh=refresh,
                         async_host=async_host)
    plan = _plan_cache.pop(plan_key, None)
    if plan is not None:
        _plan_cache[plan_key] = plan    # (re)insert at the MRU end
        return plan
    SCHED_STATS["plan_misses"] += 1

    runners, group_slots = [], []
    group_n_reads, group_n_pay = [], []
    issue_bus = np.zeros(cfg.n_slots, np.float32)
    host_bus = np.zeros(cfg.n_slots, np.float32)
    for key, slot_ids in groups.items():
        rep = stripped[slot_ids[0]]
        compiled = _compiled_for(rep, cfg.timing)
        runners.append(pim_exec.make_runner(
            compiled, cfg.timing, use_kernels=use_kernels,
            interpret=interpret, refresh=refresh, payload_arg=True))
        group_slots.append(tuple(slot_ids))
        group_n_reads.append(rep.n_reads)
        group_n_pay.append(len(rep.payloads))
        g_issue = issue_bus_ns(rep, cfg.timing)
        g_host = host_bus_ns(rep, cfg.timing)
        for k in slot_ids:
            issue_bus[k] = g_issue
            host_bus[k] = g_host

    issue_ch, host_ch, switch_ch = channel_occupancy(cfg, issue_bus,
                                                     host_bus)
    chan_busy0 = issue_ch + host_ch + switch_ch
    switch_ns = float(switch_ch.sum())

    copy_plan = None
    copy_moves = None
    copy_independent = False
    if deferred:
        copy_plan = _copy_drain_plan(
            cfg, tuple((s, d) for s, d, _ in deferred))
        srcs = [(k, op.a) for k, _, op in deferred]
        dsts = [(d, op.b) for _, d, op in deferred]
        copy_independent = (len(set(dsts)) == len(dsts)
                            and not set(dsts) & set(srcs))
        copy_moves = (tuple(x[0] for x in srcs), tuple(x[1] for x in srcs),
                      tuple(x[0] for x in dsts), tuple(x[1] for x in dsts))

    host_bytes = sum(
        len(slots) * stripped[slots[0]].host_bytes
        for slots in group_slots)

    fn, raw_fn = _make_step_fn(cfg, tuple(runners), tuple(group_slots),
                               issue_bus + host_bus, chan_busy0, host_ch,
                               copy_plan, copy_moves, copy_independent,
                               async_host)
    from . import lint as pim_lint      # lazy: lint imports this module
    plan_lint = pim_lint._plan_diagnostics(cfg, stripped, groups, deferred,
                                           async_host)
    plan = _StepPlan(
        fn=fn,
        raw_fn=raw_fn,
        group_slots=tuple(group_slots),
        bus_total=float((issue_bus + host_bus).sum(dtype=np.float64)),
        host_bus_total=float(host_bus.sum(dtype=np.float64)),
        chan_busy=tuple(float(x) for x in chan_busy0),
        switch_ns=switch_ns,
        host_bytes=host_bytes,
        copy=copy_plan,
        group_n_reads=tuple(group_n_reads),
        group_n_payloads=tuple(group_n_pay),
        lint=plan_lint)
    if len(_plan_cache) >= _PLAN_CACHE_MAX:
        _plan_cache.pop(next(iter(_plan_cache)))
    _plan_cache[plan_key] = plan
    return plan


def _verify_plans(plans, what: str) -> None:
    """The ``verify=True`` gate: raise LintError when any plan in ``plans``
    carries error-severity diagnostics. Scans cached tuples only — no
    analysis runs here."""
    if all(not plan.lint for plan in plans):
        return
    from . import lint as pim_lint
    diags = tuple(d for plan in plans for d in plan.lint)
    if any(d.severity == pim_lint.ERROR for d in diags):
        raise pim_lint.LintError(pim_lint.LintReport(diags), what)


def _lower_step(cfg: DeviceConfig, programs):
    """Shared front half of schedule()/schedule_pipeline(): normalize the
    layout, strip cross-slot copies, group by stream digest. Returns
    ``(flat, stripped, groups, deferred)``."""
    flat = _normalize_programs(cfg, programs)
    for k, p in enumerate(flat):
        if p is not None and (p.num_rows, p.words) != (cfg.num_rows,
                                                       cfg.words):
            raise ValueError(
                f"slot {cfg.slot_coords(k)}: program shape "
                f"{(p.num_rows, p.words)} != device "
                f"shape {(cfg.num_rows, cfg.words)}")

    deferred: list = []
    stripped: list = [None] * cfg.n_slots
    for k, p in enumerate(flat):
        if p is None:
            continue
        stripped[k], slot_copies = _split_copies(cfg, k, p)
        deferred.extend(slot_copies)

    groups: dict = {}
    for k, p in enumerate(stripped):
        if p is not None and len(p.ops):
            groups.setdefault(stream_key(p), []).append(k)
    return flat, stripped, groups, deferred


def _lower_recurring(cfg: DeviceConfig, step_list, *, what: str, hint: str):
    """Lower a K-step RECURRING layout: step 0 fully, later steps only an
    O(slots) digest check — identical command streams imply identical copy
    stripping and grouping, and stripping preserves HOSTW payloads, so the
    original (pre-strip) programs serve for per-step payload extraction.
    Returns ``(flats, stripped0, groups0, deferred0)``."""
    flat0, stripped0, groups0, deferred0 = _lower_step(cfg, step_list[0])
    flats = [flat0]
    for k, programs in enumerate(step_list[1:], 1):
        if programs is step_list[0]:
            flats.append(flat0)         # replicated layout: nothing to check
            continue
        flat_k = _normalize_programs(cfg, programs)
        for s in range(cfg.n_slots):
            a, b = flat0[s], flat_k[s]
            if ((a is None) != (b is None)
                    or (a is not None and stream_key(a) != stream_key(b))):
                raise ValueError(
                    f"{what} step {k} does not recur: slot "
                    f"{cfg.slot_coords(s)}'s command stream differs from "
                    f"step 0 — {hint}")
        flats.append(flat_k)
    return flats, stripped0, groups0, deferred0


def schedule(device: DeviceState,
             programs, *,
             use_kernels: bool | None = None,
             interpret: bool | None = None,
             refresh: bool = False,
             async_host: bool = False,
             verify: bool = False) -> ScheduleResult:
    """Run one program per slot (``None`` = idle slot) and fold the device
    timing model over the per-slot meters.

    ``programs`` may be per-bank (len ``n_banks``; entries are a program for
    the bank's subarray 0 or a nested per-subarray sequence) or flat
    per-slot (len ``n_slots``). Cross-slot ``COPY`` ops are stripped from
    the compiled streams and drained after the in-bank compute (see module
    docstring).

    ``refresh`` folds periodic-refresh stalls/energy into each slot's meter
    (``timing.apply_refresh``); the fold is incremental against the meter's
    ``n_refresh`` history, so repeated refreshed schedules on one device
    charge every event exactly once.

    ``async_host=True`` models a Shared-PIM-style asynchronous host-transfer
    engine: this step's HOSTW/HOSTR bursts overlap the *previous* step's
    compute+copy window (``device.host_credit_ns``), double-buffered, so a
    multi-step pipeline pays ``max(transfer, compute)`` per step instead of
    the sum. Only the wall clock changes — states, reads, and energy are
    identical to the synchronous schedule.

    The whole step — every stream group, the COPY drain, and the
    channel-bus fold — executes as ONE jitted dispatch (the step plan is
    cached per layout), and the result's timing values stay lazy; no
    blocking device sync happens inside this call.
    """
    cfg = device.config
    _, stripped, groups, deferred = _lower_step(cfg, programs)
    plan = _plan_for(cfg, stripped, groups, deferred,
                     use_kernels=use_kernels, interpret=interpret,
                     refresh=refresh, async_host=async_host)
    if verify:
        _verify_plans((plan,), "schedule layout")
    payloads = tuple(
        _payload_stack([stripped[k] for k in slots], cfg.words)
        for slots in plan.group_slots)
    credit = device.host_credit_ns
    if not isinstance(credit, jax.Array):
        credit = jnp.float32(credit)
    new_banks, greads, wall, energy, credit_out, busy, hidden_sum = plan.fn(
        device.banks, credit, payloads)
    SCHED_STATS["dispatches"] += 1
    stats = plan.copy.stats if plan.copy is not None else CopyDrainStats()
    return ScheduleResult(
        state=device.with_banks(new_banks, host_credit_ns=credit_out),
        wall_ns=wall,
        bus_ns=plan.bus_total,
        energy_nj=energy,
        _group_reads=greads,
        _read_layout=(cfg.n_slots, plan.group_slots),
        copy_ns=stats.makespan_ns,
        host_bytes=plan.host_bytes,
        rank_switch_ns=plan.switch_ns,
        copy_total_ns=stats.total_ns,
        copy_queue_ns=stats.queue_ns,
        link_busy_ns=dict(stats.link_busy_ns),
        _host_bus_ns=plan.host_bus_total,
        _channel_bus_ns=busy if async_host else plan.chan_busy,
        _host_overlap_ns=hidden_sum if async_host else 0.0)


# ---------------------------------------------------------------------------
# Multi-step pipelines: K recurring steps under one lax.scan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineResult:
    """Outcome of ``schedule_pipeline``: K steps of one recurring layout.

    Per-step timing arrays carry a leading step axis and stay lazy until
    read; the static per-step quantities (bus occupancy, copy drain stats,
    host bytes) are identical every step — the layout recurs by
    construction."""

    state: DeviceState          # final device (credit = last step's compute)
    wall_ns: jax.Array          # (K,) per-step wall clock
    energy_nj: jax.Array        # (K,) per-step energy
    n_steps: int
    bus_ns: float               # per-step bus occupancy (Σ slots)
    host_bytes: int             # per-step off-chip bytes
    copy_ns: float = 0.0        # per-step COPY drain makespan
    copy_total_ns: float = 0.0
    copy_queue_ns: float = 0.0
    rank_switch_ns: float = 0.0
    link_busy_ns: dict = dataclasses.field(default_factory=dict)
    _group_reads: tuple = ()    # per group: per-read (K, n_group, words)
    _read_layout: tuple = (0, ())  # (n_slots, group slot-id tuples)
    _host_overlap_ns: object = 0.0  # (K,) in async mode, else 0.0

    @property
    def reads(self) -> list:
        """Per-step reads, same nesting as ``ScheduleResult.reads``:
        ``reads[k][slot]`` is the slot's host-read rows of step ``k``.
        Sliced out of the group-batched scan output lazily (memoized)."""
        cached = getattr(self, "_reads_cache", None)
        if cached is None:
            cached = _unbatch_reads(self._group_reads, self._read_layout,
                                    self.n_steps)
            self._reads_cache = cached
        return cached

    @property
    def host_overlap_ns_lazy(self):
        """Raw per-step hidden-host-time values (see
        ``ScheduleResult.host_overlap_ns_lazy``)."""
        return self._host_overlap_ns

    @property
    def total_wall_ns(self) -> float:
        return float(jnp.sum(self.wall_ns))

    @property
    def host_overlap_ns(self) -> float:
        """Total host-transfer time hidden across the pipeline (async)."""
        return float(jnp.sum(jnp.asarray(self._host_overlap_ns)))


def _stack_step_payloads(pay_list):
    """Stack per-step payload batches into the scan's ``(K, ...)`` xs. A
    fully-replicated pipeline (every step the same cached batch) reuses one
    stacked device array via the payload cache instead of re-uploading K
    copies of identical host data per call."""
    if any(p is not pay_list[0] for p in pay_list):
        key = ("multi",) + tuple(id(p) for p in pay_list)
        hit = _payload_cache_get(key)
        if hit is None:
            # the cache entry holds the batches, pinning their ids
            hit = (jnp.stack(pay_list), tuple(pay_list))
            _payload_cache_put(key, hit)
        return hit[0]
    key = ("steps", len(pay_list), id(pay_list[0]))
    hit = _payload_cache_get(key)
    if hit is None:
        # the cache entry holds the source batch, pinning its id
        hit = (jnp.stack([pay_list[0]] * len(pay_list)), pay_list[0])
        _payload_cache_put(key, hit)
    return hit[0]


_pipeline_cache: dict = {}
_PIPELINE_CACHE_MAX = 64


def _pipeline_fn(plan: _StepPlan, n_steps: int, donate: bool):
    """One jitted scan over the plan's step function. With ``donate`` the
    input device buffers are donated to the scan (the caller's state is
    consumed in place); CPU ignores donation, so it is skipped there to
    avoid warnings."""
    key = (id(plan), n_steps, donate)
    hit = _pipeline_cache.pop(key, None)
    if hit is None:
        def pipe(banks, credit, xs):
            def body(carry, x):
                b, c = carry
                nb, reads, wall, energy, credit_out, _busy, hidden = \
                    plan.raw_fn(b, c, x)
                return (nb, credit_out), (reads, wall, energy, hidden)

            # explicit length: a copy-only step layout has no stream
            # groups, so its xs pytree carries no leaves to infer K from
            (nb, credit_out), ys = jax.lax.scan(body, (banks, credit), xs,
                                                length=n_steps)
            return nb, credit_out, ys

        argnums = ((0, 1) if donate and jax.default_backend() != "cpu"
                   else ())
        # the cache entry holds the plan too, pinning id(plan) to this plan
        hit = (jax.jit(pipe, donate_argnums=argnums), plan)
        if len(_pipeline_cache) >= _PIPELINE_CACHE_MAX:
            _pipeline_cache.pop(next(iter(_pipeline_cache)))
    _pipeline_cache[key] = hit
    return hit[0]


def schedule_pipeline(device: DeviceState, steps, *,
                      n_steps: int | None = None,
                      use_kernels: bool | None = None,
                      interpret: bool | None = None,
                      refresh: bool = False,
                      async_host: bool = False,
                      donate: bool = False,
                      verify: bool = False) -> PipelineResult:
    """Run K recurring schedule steps as ONE ``jax.lax.scan`` dispatch.

    ``steps`` is either a sequence of K per-step program layouts (anything
    ``schedule`` accepts — all steps must lower to the SAME layout:
    identical command streams per slot and copy pattern; HOSTW payload
    *data* may differ per step), or — with ``n_steps=K`` — a single layout
    replayed K times. Equivalent to calling ``schedule`` K times in a
    Python loop (bit-exact states, reads, and meters; the async host
    credit chains identically), but the steady-state per-step cost is one
    scan iteration instead of a full host round-trip.

    ``donate=True`` donates the input device's buffers to the scan on
    accelerator backends — fastest for long-lived pipelines, but the
    passed-in ``device`` is CONSUMED (using it afterwards raises a
    donated-buffer error); leave the default to keep ``schedule``'s
    input-preserving contract.
    """
    cfg = device.config
    if n_steps is not None:
        step_list = [steps] * int(n_steps)
    else:
        step_list = list(steps)
    if not step_list:
        raise ValueError("schedule_pipeline needs at least one step")

    flats, stripped0, groups0, deferred0 = _lower_recurring(
        cfg, step_list, what="pipeline",
        hint="schedule_pipeline runs ONE recurring step; use "
             "schedule_workload() for multi-phase sequences or schedule() "
             "for fully heterogeneous ones")

    plan = _plan_for(cfg, stripped0, groups0, deferred0,
                     use_kernels=use_kernels, interpret=interpret,
                     refresh=refresh, async_host=async_host)
    if verify:
        _verify_plans((plan,), "pipeline layout")
    xs = tuple(
        _stack_step_payloads(
            [_payload_stack([flats[k][s] for s in slots], cfg.words)
             for k in range(len(step_list))])
        for slots in plan.group_slots)
    credit = device.host_credit_ns
    if not isinstance(credit, jax.Array):
        credit = jnp.float32(credit)
    fn = _pipeline_fn(plan, len(step_list), donate)
    new_banks, credit_out, (reads, walls, energies, hidden) = fn(
        device.banks, credit, xs)
    SCHED_STATS["dispatches"] += 1
    stats = plan.copy.stats if plan.copy is not None else CopyDrainStats()
    return PipelineResult(
        state=device.with_banks(new_banks, host_credit_ns=credit_out),
        wall_ns=walls,
        energy_nj=energies,
        n_steps=len(step_list),
        bus_ns=plan.bus_total,
        host_bytes=plan.host_bytes,
        copy_ns=stats.makespan_ns,
        copy_total_ns=stats.total_ns,
        copy_queue_ns=stats.queue_ns,
        rank_switch_ns=plan.switch_ns,
        link_busy_ns=dict(stats.link_busy_ns),
        _group_reads=reads,
        _read_layout=(cfg.n_slots, plan.group_slots),
        _host_overlap_ns=hidden if async_host else 0.0)


# ---------------------------------------------------------------------------
# Multi-phase workloads: heterogeneous phase sequences under ONE dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Phase:
    """One phase of a multi-phase workload: a RECURRING step layout (the
    ``schedule_pipeline`` contract) replayed once per entry of ``steps``.
    Payload data may differ per step; the command streams may not.

    ``async_host=None`` inherits the workload-level flag; an explicit
    ``True``/``False`` overrides it per phase (e.g. an async HOSTW load
    phase feeding a sync compute phase)."""

    steps: tuple
    async_host: bool | None = None

    @classmethod
    def repeat(cls, layout, n_steps: int, **kw) -> "Phase":
        """A phase that replays ONE layout ``n_steps`` times (payloads
        included — use explicit ``steps`` for per-step data)."""
        return cls(steps=(layout,) * int(n_steps), **kw)


def _as_phase(d) -> Phase:
    """Phase descriptors: a :class:`Phase`, a ``(layout, n_steps)`` pair,
    or a sequence of per-step layouts."""
    if isinstance(d, Phase):
        return d
    if (isinstance(d, tuple) and len(d) == 2
            and isinstance(d[1], (int, np.integer))):
        return Phase.repeat(d[0], int(d[1]))
    return Phase(steps=tuple(d))


@dataclasses.dataclass(frozen=True, eq=False)
class PipelinePlan:
    """A fully-lowered multi-phase workload: one cached :class:`_StepPlan`
    per phase plus the sequence signature the plan cache is keyed on.
    Identity-stable across warm ``schedule_workload`` calls, so the jitted
    segmented/switch drivers (keyed on ``id(plan)``) stay warm too."""

    phases: tuple               # per-phase _StepPlan
    n_steps: tuple              # per-phase step count
    async_host: tuple           # per-phase resolved async-host flag
    signature: bytes            # 128-bit digest of the phase sequence


@dataclasses.dataclass
class PhaseResult:
    """One phase's slice of a :class:`WorkloadResult` — the
    :class:`PipelineResult` metrics minus the device state (state is only
    meaningful at the end of the whole workload) plus the async credit
    observed at the phase boundary."""

    wall_ns: jax.Array          # (K,) per-step wall clock
    energy_nj: jax.Array        # (K,) per-step energy
    n_steps: int
    bus_ns: float               # per-step bus occupancy (Σ slots)
    host_bytes: int             # per-step off-chip bytes
    copy_ns: float = 0.0
    copy_total_ns: float = 0.0
    copy_queue_ns: float = 0.0
    rank_switch_ns: float = 0.0
    link_busy_ns: dict = dataclasses.field(default_factory=dict)
    _boundary_credit_ns: object = 0.0   # credit leaving the phase's last step
    _group_reads: tuple = ()
    _read_layout: tuple = (0, ())
    _host_overlap_ns: object = 0.0      # (K,) in async mode, else 0.0

    @property
    def reads(self) -> list:
        """Per-step reads: ``reads[k][slot]``, as in
        :attr:`PipelineResult.reads` (lazy, memoized)."""
        cached = getattr(self, "_reads_cache", None)
        if cached is None:
            cached = _unbatch_reads(self._group_reads, self._read_layout,
                                    self.n_steps)
            self._reads_cache = cached
        return cached

    @property
    def boundary_credit_ns(self) -> float:
        """The ``host_credit_ns`` leaf as it left this phase's last step:
        the next phase's first step overlaps (at most) this much host
        traffic. Zero after a sync phase — see the credit-reset contract.
        Stored as a lazy ``(per-boundary array, phase index)`` pair so a
        warm ``schedule_workload`` call issues no per-phase host
        dispatches; the slice happens here, on first read."""
        b = self._boundary_credit_ns
        if isinstance(b, tuple):
            arr, i = b
            return float(arr[i])
        return float(b)

    @property
    def host_overlap_ns_lazy(self):
        """Raw per-step hidden-host-time values (see
        ``ScheduleResult.host_overlap_ns_lazy``)."""
        return self._host_overlap_ns

    @property
    def total_wall_ns(self) -> float:
        return float(jnp.sum(self.wall_ns))

    @property
    def host_overlap_ns(self) -> float:
        return float(jnp.sum(jnp.asarray(self._host_overlap_ns)))


@dataclasses.dataclass
class WorkloadResult:
    """Outcome of ``schedule_workload``: the final device state plus one
    :class:`PhaseResult` per phase. ``order`` echoes the switch-mode step
    order (``None`` for the segmented lowering)."""

    state: DeviceState
    phases: tuple
    order: tuple | None = None

    @property
    def n_steps(self) -> int:
        return sum(p.n_steps for p in self.phases)

    @property
    def total_wall_ns(self) -> float:
        return sum(p.total_wall_ns for p in self.phases)

    @property
    def total_energy_nj(self) -> float:
        return float(sum(float(jnp.sum(p.energy_nj)) for p in self.phases))

    @property
    def host_overlap_ns(self) -> float:
        return sum(p.host_overlap_ns for p in self.phases)


_workload_plan_cache: dict = {}
_WORKLOAD_PLAN_CACHE_MAX = 64

_workload_fn_cache: dict = {}
_WORKLOAD_FN_CACHE_MAX = 64

# Per-phase lowering memo: a warm re-dispatch of a workload whose phase
# objects are unchanged (the steady-state shape — fresh payloads arrive as
# NEW with_payloads programs and therefore miss) skips the O(steps x
# slots) recurrence re-check and the plan-key tuple rebuild entirely.
_phase_lower_cache: dict = {}
_PHASE_LOWER_CACHE_MAX = 256

# Whole-workload identity memo: re-submitting the SAME Phase objects (the
# steady-state loop shape — state threads through, descriptors don't
# change) skips even the O(phases x steps) id walks and goes straight to
# the cached driver + xs. Entries pin the steps tuples they key on, so a
# recycled id can never alias a dead layout.
_workload_fast_cache: dict = {}
_WORKLOAD_FAST_CACHE_MAX = 32


def _layout_ids(step):
    """Identity fingerprint of one step layout (programs by id, nesting
    preserved). Mutating a layout in place swaps the contained program
    ids, so the fingerprint-keyed cache can never serve stale lowerings.
    Returns None for containers it does not recognize (uncacheable)."""
    if step is None or isinstance(step, PimProgram):
        return id(step)
    if isinstance(step, (list, tuple)):
        parts = tuple(_layout_ids(x) for x in step)
        return None if any(p is None for p in parts) else parts
    return None


def _workload_fn(wplan: PipelinePlan, donate: bool):
    """The segmented-scan driver: one ``lax.scan`` per phase, chained
    under ONE jit with the banks pytree and the async credit threaded
    through — a whole multi-phase workload is one XLA dispatch."""
    key = ("seg", id(wplan), donate)
    hit = _workload_fn_cache.pop(key, None)
    if hit is None:
        plans = wplan.phases

        def drive(banks, credit, xs_phases):
            outs, boundary = [], []
            b, c = banks, credit
            for plan, n, xs in zip(plans, wplan.n_steps, xs_phases):
                def body(carry, x, plan=plan):
                    bb, cc = carry
                    nb, reads, wall, energy, credit_out, _busy, hidden = \
                        plan.raw_fn(bb, cc, x)
                    return (nb, credit_out), (reads, wall, energy, hidden)

                # explicit length: a copy-only phase has no stream groups,
                # so its xs pytree carries no leaves to infer K from
                (b, c), ys = jax.lax.scan(body, (b, c), xs, length=n)
                outs.append(ys)
                boundary.append(c)
            return b, c, tuple(outs), jnp.stack(boundary)

        argnums = ((0, 1) if donate and jax.default_backend() != "cpu"
                   else ())
        # the cache entry holds the wplan too, pinning id(wplan)
        hit = (jax.jit(drive, donate_argnums=argnums), wplan)
        if len(_workload_fn_cache) >= _WORKLOAD_FN_CACHE_MAX:
            _workload_fn_cache.pop(next(iter(_workload_fn_cache)))
    _workload_fn_cache[key] = hit
    return hit[0]


def _switch_fn(wplan: PipelinePlan, words: int, donate: bool):
    """The plan-switching driver: one ``lax.scan`` over a phase-index
    sequence, ``lax.switch``-ing across the per-phase step fns. Branches
    must return identical pytrees, so each branch flattens its reads to a
    zero-padded ``(R_max, words)`` block and slices its payloads out of a
    common ``(G_max, S_max, P_max, words)`` xs leaf; the per-phase views
    are recovered statically by the caller."""
    key = ("switch", id(wplan), donate)
    hit = _workload_fn_cache.pop(key, None)
    if hit is None:
        plans = wplan.phases
        r_tot = [sum(nr * len(slots) for nr, slots in
                     zip(p.group_n_reads, p.group_slots)) for p in plans]
        r_max = max(r_tot)
        branches = []
        for plan, r_p in zip(plans, r_tot):
            def branch(banks, credit, pay, plan=plan, r_p=r_p):
                payloads = tuple(
                    pay[g, :len(slots), :n_pay]
                    for g, (slots, n_pay) in enumerate(
                        zip(plan.group_slots, plan.group_n_payloads)))
                nb, reads, wall, energy, credit_out, _busy, hidden = \
                    plan.raw_fn(banks, credit, payloads)
                if r_p:
                    fr = jnp.concatenate(
                        [r for group in reads for r in group], axis=0)
                    fr = jnp.zeros((r_max, words),
                                   jnp.uint32).at[:r_p].set(fr)
                else:
                    fr = jnp.zeros((r_max, words), jnp.uint32)
                return nb, credit_out, (fr, wall, energy, hidden,
                                        credit_out)

            branches.append(branch)

        def drive(banks, credit, idx, pay):
            def body(carry, x):
                b, c = carry
                i, p = x
                nb, cc, ys = jax.lax.switch(i, branches, b, c, p)
                return (nb, cc), ys

            (nb, cc), ys = jax.lax.scan(body, (banks, credit), (idx, pay))
            return nb, cc, ys

        argnums = ((0, 1) if donate and jax.default_backend() != "cpu"
                   else ())
        hit = (jax.jit(drive, donate_argnums=argnums), wplan)
        if len(_workload_fn_cache) >= _WORKLOAD_FN_CACHE_MAX:
            _workload_fn_cache.pop(next(iter(_workload_fn_cache)))
    _workload_fn_cache[key] = hit
    return hit[0]


def _phase_result(cfg, plan: _StepPlan, n_steps: int, walls, energies,
                  greads, hidden, boundary) -> PhaseResult:
    stats = plan.copy.stats if plan.copy is not None else CopyDrainStats()
    return PhaseResult(
        wall_ns=walls,
        energy_nj=energies,
        n_steps=n_steps,
        bus_ns=plan.bus_total,
        host_bytes=plan.host_bytes,
        copy_ns=stats.makespan_ns,
        copy_total_ns=stats.total_ns,
        copy_queue_ns=stats.queue_ns,
        rank_switch_ns=plan.switch_ns,
        link_busy_ns=dict(stats.link_busy_ns),
        _boundary_credit_ns=boundary,
        _group_reads=greads,
        _read_layout=(cfg.n_slots, plan.group_slots),
        _host_overlap_ns=hidden)


def _run_segmented(device: DeviceState, wplan: PipelinePlan, xs_phases,
                   fn) -> WorkloadResult:
    """Dispatch a prepared segmented-scan workload and wrap the outputs.
    Shared by the cold path and the whole-workload identity fast path."""
    cfg = device.config
    credit = device.host_credit_ns
    if not isinstance(credit, jax.Array):
        credit = jnp.float32(credit)
    new_banks, credit_out, outs, boundary = fn(
        device.banks, credit, xs_phases)
    SCHED_STATS["dispatches"] += 1
    phase_results = tuple(
        _phase_result(cfg, plan, wplan.n_steps[p], walls, energies,
                      greads,
                      hidden if wplan.async_host[p] else 0.0,
                      (boundary, p))
        for p, (plan, (greads, walls, energies, hidden)) in enumerate(
            zip(wplan.phases, outs)))
    return WorkloadResult(
        state=device.with_banks(new_banks, host_credit_ns=credit_out),
        phases=phase_results,
        order=None)


def schedule_workload(device: DeviceState, phases, *,
                      order: Sequence[int] | None = None,
                      use_kernels: bool | None = None,
                      interpret: bool | None = None,
                      refresh: bool = False,
                      async_host: bool = False,
                      donate: bool = False,
                      verify: bool = False) -> WorkloadResult:
    """Run a HETEROGENEOUS multi-phase workload as ONE XLA dispatch.

    ``phases`` is a sequence of phase descriptors (:class:`Phase`, a
    ``(layout, n_steps)`` pair, or a sequence of per-step layouts); each
    phase is one recurring step layout in the ``schedule_pipeline`` sense
    — per-step HOSTW data may differ, command streams may not. Phases may
    differ arbitrarily from each other (different streams, grouping, copy
    patterns, async flags).

    With ``order=None`` (the static, hot path) the phases execute
    back-to-back — one ``lax.scan`` per contiguous phase segment, chained
    under a single jitted driver. With ``order=[phase_idx, ...]`` (the
    data-dependent path) the steps execute in exactly that interleaved
    order under one ``lax.scan`` over the phase index, ``lax.switch``-ing
    across the per-phase step fns; each phase's steps are consumed FIFO,
    so ``order`` must name phase ``p`` exactly ``len(phases[p].steps)``
    times. Switch mode pads every step's reads/payloads to the workload
    maximum — prefer the segmented lowering when the order is static.

    Equivalent to per-phase ``schedule_pipeline`` / per-step ``schedule``
    loops: bit-exact states, reads, and meters, with the async host credit
    and the refresh-history meter threaded through the scan carry across
    every phase boundary (a sync phase RESETS the credit — see the step-fn
    contract). Timing/energy outputs stay lazy per phase.
    """
    cfg = device.config
    phase_list = [_as_phase(d) for d in phases]
    if not phase_list:
        raise ValueError("schedule_workload needs at least one phase")

    fkey = (cfg, use_kernels, interpret, refresh, async_host, donate)
    if order is None:
        entry = _workload_fast_cache.pop(fkey, None)
        if entry is not None:
            _workload_fast_cache[fkey] = entry   # MRU touch
            steps_refs, wplan_c, xs_c, fn_c = entry
            if len(phase_list) == len(steps_refs) and all(
                    ph.steps is st and
                    (async_host if ph.async_host is None
                     else bool(ph.async_host)) == ah
                    for ph, (st, ah) in zip(phase_list, steps_refs)):
                if verify:
                    _verify_plans(wplan_c.phases, "workload layout")
                return _run_segmented(device, wplan_c, xs_c, fn_c)

    plans, flats_p, keys, a_hs = [], [], [], []
    for p, ph in enumerate(phase_list):
        step_list = list(ph.steps)
        if not step_list:
            raise ValueError(f"workload phase {p} has no steps")
        a_h = async_host if ph.async_host is None else bool(ph.async_host)
        ids = _layout_ids(tuple(step_list))
        lkey = (None if ids is None else
                (cfg, use_kernels, interpret, refresh, a_h, ids))
        hit = _phase_lower_cache.pop(lkey, None) if lkey else None
        if hit is None:
            flats, stripped0, groups0, deferred0 = _lower_recurring(
                cfg, step_list, what=f"workload phase {p}",
                hint="each phase of schedule_workload is ONE recurring "
                     "step layout; split heterogeneous steps into "
                     "separate phases")
            plan = _plan_for(cfg, stripped0, groups0, deferred0,
                             use_kernels=use_kernels, interpret=interpret,
                             refresh=refresh, async_host=a_h)
            pk = _plan_key(cfg, groups0, deferred0,
                           use_kernels=use_kernels, interpret=interpret,
                           refresh=refresh, async_host=a_h)
            # flats hold every layout program, pinning the ids in lkey
            hit = (flats, plan, pk)
        if lkey:
            if len(_phase_lower_cache) >= _PHASE_LOWER_CACHE_MAX:
                _phase_lower_cache.pop(next(iter(_phase_lower_cache)))
            _phase_lower_cache[lkey] = hit
        flats, plan, pk = hit
        plans.append(plan)
        flats_p.append(flats)
        keys.append((pk, len(step_list)))
        a_hs.append(a_h)

    # The phase-sequence signature keys the workload plan cache, keeping
    # PipelinePlan identity (and thereby the jitted drivers) stable across
    # warm calls with fresh payload data.
    wkey = tuple(keys)
    wplan = _workload_plan_cache.pop(wkey, None)
    if wplan is None:
        if len(_workload_plan_cache) >= _WORKLOAD_PLAN_CACHE_MAX:
            _workload_plan_cache.pop(next(iter(_workload_plan_cache)))
        wplan = PipelinePlan(
            phases=tuple(plans),
            n_steps=tuple(len(ph.steps) for ph in phase_list),
            async_host=tuple(a_hs),
            signature=ir.sequence_digest(
                hashlib.blake2b(repr(k).encode(), digest_size=16).digest()
                for k in keys))
    _workload_plan_cache[wkey] = wplan
    if verify:
        _verify_plans(wplan.phases, "workload layout")

    if order is None:
        xs_phases = tuple(
            tuple(_stack_step_payloads(
                [_payload_stack([flats[k][s] for s in slots], cfg.words)
                 for k in range(n)])
                for slots in plan.group_slots)
            for plan, flats, n in zip(wplan.phases, flats_p, wplan.n_steps))
        fn = _workload_fn(wplan, donate)
        if len(_workload_fast_cache) >= _WORKLOAD_FAST_CACHE_MAX:
            _workload_fast_cache.pop(next(iter(_workload_fast_cache)))
        _workload_fast_cache[fkey] = (
            tuple((ph.steps, ah) for ph, ah in zip(phase_list, a_hs)),
            wplan, xs_phases, fn)
        return _run_segmented(device, wplan, xs_phases, fn)

    credit = device.host_credit_ns
    if not isinstance(credit, jax.Array):
        credit = jnp.float32(credit)

    order = tuple(int(i) for i in order)
    n_ph = len(wplan.phases)
    counts = [0] * n_ph
    for i in order:
        if not 0 <= i < n_ph:
            raise ValueError(
                f"order index {i} out of range for {n_ph} phases")
        counts[i] += 1
    for p, (got, want) in enumerate(zip(counts, wplan.n_steps)):
        if got != want:
            raise ValueError(
                f"order names phase {p} {got} times but the phase has "
                f"{want} steps — each phase's steps are consumed FIFO")

    g_max = max(len(p.group_slots) for p in wplan.phases)
    s_max = max((len(s) for p in wplan.phases for s in p.group_slots),
                default=0)
    p_max = max((n for p in wplan.phases for n in p.group_n_payloads),
                default=0)
    pay = np.zeros((len(order), g_max, s_max, p_max, cfg.words),
                   np.uint32)
    cursor = [0] * n_ph
    for t, pi in enumerate(order):
        plan = wplan.phases[pi]
        flat = flats_p[pi][cursor[pi]]
        cursor[pi] += 1
        for g, slots in enumerate(plan.group_slots):
            for j, s in enumerate(slots):
                for q, arr in enumerate(flat[s].payloads):
                    pay[t, g, j, q] = np.asarray(arr, np.uint32)

    fn = _switch_fn(wplan, cfg.words, donate)
    new_banks, credit_out, (fr, walls, energies, hidden, credits) = fn(
        device.banks, credit,
        jnp.asarray(np.asarray(order, np.int32)), jnp.asarray(pay))
    SCHED_STATS["dispatches"] += 1
    phase_results = []
    for p, plan in enumerate(wplan.phases):
        ks = [t for t, o in enumerate(order) if o == p]
        sel = jnp.asarray(np.asarray(ks, np.int32))
        fr_p = fr[sel]
        greads, off = [], 0
        for g, slots in enumerate(plan.group_slots):
            n_g = len(slots)
            rds = []
            for _ in range(plan.group_n_reads[g]):
                rds.append(fr_p[:, off:off + n_g])
                off += n_g
            greads.append(tuple(rds))
        phase_results.append(_phase_result(
            cfg, plan, wplan.n_steps[p], walls[sel], energies[sel],
            tuple(greads),
            hidden[sel] if wplan.async_host[p] else 0.0,
            (credits, ks[-1])))
    phase_results = tuple(phase_results)
    order_out = order

    return WorkloadResult(
        state=device.with_banks(new_banks, host_credit_ns=credit_out),
        phases=phase_results,
        order=order_out)


# ---------------------------------------------------------------------------
# In-DRAM movement / reduction primitives
# ---------------------------------------------------------------------------

def gather_rows(cfg: DeviceConfig, moves, programs=None) -> list:
    """Per-slot COPY streams for in-DRAM row movement (zero host bytes).

    ``moves``: iterable of ``((src_bank, src_sub, src_row),
    (dst_bank, dst_sub, dst_row))``. Each move records one ``COPY`` in the
    *source* slot's stream; the scheduler drains them after the step's
    compute, so gathered rows hold post-compute values and are readable by
    the next step. ``programs`` (optional, any layout ``schedule`` accepts)
    is appended to — pass the step's compute programs to fuse compute +
    gather into one ``schedule`` call. Returns a flat per-slot list.
    """
    base = (_normalize_programs(cfg, programs) if programs is not None
            else [None] * cfg.n_slots)
    builders: dict[int, ProgramBuilder] = {}
    for (sb, ss, sr), (db, ds, dr) in moves:
        slot = cfg.slot_index(sb, ss)
        cfg.slot_index(db, ds)          # validate destination coordinates
        builders.setdefault(
            slot, ProgramBuilder(cfg.num_rows, cfg.words)).copy_row(
                sr, dr, db, ds)
    out = list(base)
    for slot, b in builders.items():
        copies = b.build()
        out[slot] = (copies if out[slot] is None
                     else ir.concat([out[slot], copies]))
    return out


def xor_reduce_program(num_rows: int, words: int, rows: Sequence[int],
                       dst: int) -> PimProgram:
    """One slot's in-place XOR fold: ``dst <- rows[0] ^ rows[1] ^ ...`` via
    Ambit XOR (rows must avoid the T0..T3 scratch). The reduction half of a
    gather/reduce step — all row traffic stays inside the subarray."""
    b = ProgramBuilder(num_rows, words)
    rows = list(rows)
    assert rows, "need at least one row to reduce"
    if rows[0] != dst:
        b.rowclone(rows[0], dst)
    for r in rows[1:]:
        b.ambit_xor(dst, r, dst)
    return b.build()


# ---------------------------------------------------------------------------
# Host-buffer partitioners: one large buffer → per-slot programs
# ---------------------------------------------------------------------------

BuildFn = Callable[[ProgramBuilder, list[int]], None]


def _chunk_program(chunk: np.ndarray, num_rows: int, words: int,
                   build: BuildFn | None, read_back: bool) -> PimProgram:
    b = ProgramBuilder(num_rows, words)
    b.issue()
    rows = list(range(chunk.shape[0]))
    for r in rows:
        b.write_row(r, chunk[r])
    if build is not None:
        build(b, rows)
    if read_back:
        for r in rows:
            b.read_row(r)
    return b.build()


def _regroup(programs: list, subarrays: int):
    """Flat chunk list → nested [bank][sub] when placing across the
    subarray axis; flat per-bank list otherwise (back-compat)."""
    if subarrays == 1:
        return programs
    return [programs[b * subarrays:(b + 1) * subarrays]
            for b in range(len(programs) // subarrays)]


def shard_rows(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
               subarrays: int = 1, build: BuildFn | None = None,
               read_back: bool = False) -> list:
    """Split a ``(R, words)`` row buffer row-wise across ``n_banks`` banks
    (× ``subarrays`` slots per bank).

    Each slot receives a contiguous chunk of rows, HOSTW-written to its rows
    ``0..k-1`` after one ISSUE burst; ``build(builder, local_rows)`` then
    appends the per-slot compute. Chunks are ``np.array_split``-balanced, so
    R need not divide evenly (trailing slots may be one row short or idle).
    Returns a flat per-bank list, or nested ``[bank][sub]`` when
    ``subarrays > 1`` — both layouts feed ``schedule`` directly.
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    chunks = np.array_split(data, n_banks * subarrays, axis=0)
    return _regroup(
        [_chunk_program(c, num_rows, data.shape[1], build, read_back)
         for c in chunks], subarrays)


def shard_lanes(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
                subarrays: int = 1, build: BuildFn | None = None,
                read_back: bool = False) -> list:
    """Split a ``(R, words)`` row buffer lane-wise across ``n_banks`` banks
    (× ``subarrays`` slots per bank).

    Slot ``k`` receives the word-slice ``[:, k*w:(k+1)*w]`` of every row
    (``w = words // n_slots``) — all slots then run the SAME command stream
    over different columns, the natural SIMD split for element-parallel
    workloads (element width must divide 32 so lanes never straddle the
    word-slice boundary). Layout as in ``shard_rows``.
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    words = data.shape[1]
    n_slots = n_banks * subarrays
    if words % n_slots:
        raise ValueError(f"words={words} not divisible by n_banks*subarrays="
                         f"{n_slots}")
    w = words // n_slots
    chunks = [data[:, k * w:(k + 1) * w] for k in range(n_slots)]
    return _regroup(
        [_chunk_program(c, num_rows, w, build, read_back) for c in chunks],
        subarrays)
