"""Workload scheduler for device-level (multi-bank, multi-subarray) PIM
execution.

Takes *heterogeneous* per-slot :class:`~.ir.PimProgram`s (slot = one
``(bank, subarray)`` pair) and executes them against a
:class:`~.device.DeviceState` with as few compiled artifacts as possible:
slots whose command streams are identical (same ops, shape and payload
count — payload *data* may differ) form one group, and each group runs as
ONE compiled runner vmapped over the group's slot states with the HOSTW
payloads passed as a batched argument (``exec.make_runner``'s
``payload_arg`` mode). This is SIMDRAM's framework split — program →
allocation → execution — with Shared-PIM-style concurrent bank scheduling.

In-DRAM row movement (``COPY``, LISA-style): a slot's stream may carry
``COPY`` ops whose destination is *another* slot — an adjacent subarray
(row-buffer-movement hops) or another bank (the chip's shared internal
bus). The scheduler strips those ops out of the compiled streams and
drains them **after the step's in-bank compute**, DMA-engine style: a
cross-slot COPY reads its source row's *post-compute* value, copies apply
in (slot, stream-position) order (later copies observe earlier ones), and
the moved rows are visible to the *next* ``schedule`` step. Each copy
charges ``timing.copy_cost`` onto the **source** slot's meter — no HOSTR/
HOSTW, no off-chip burst energy. Same-slot COPYs stay in-stream (they are
ordinary distance-0 LISA copies the executor runs directly).

The drain itself is *link-contended*: every inter-subarray RBM link
(``(bank, i)`` joins subarrays ``i``/``i+1``) and every channel's shared
internal bus is a FCFS resource. Copies are served in drain order; a copy
holds every link it crosses (plus the internal bus(es) for inter-bank
moves) for its full duration, so massive gathers queue instead of
draining for free. An inter-bank copy pays real RBM hops too: source
subarray → bank edge (subarray 0, where the internal bus taps the bank)
and edge → destination subarray.

Device accounting (see ``device.py``): per-slot meters accumulate each
slot's own busy time; the schedule-level wall clock is channel-aware:

    wall = max_ch chan_busy_ch + max_k (Δt_k − bus_k) + copy drain makespan
    energy = Σ_k Δenergy_k

where ``bus_k`` is slot k's bus occupancy (ISSUE bursts AND off-chip
HOSTW/HOSTR burst windows) and ``chan_busy_ch`` serializes the occupancy
of channel ``ch``'s slots FCFS, charging ``tRTRS`` between bursts that
switch rank. With ``async_host=True`` (Shared-PIM-style double buffering)
each channel's HOST traffic first overlaps the *previous* step's
compute+copy window (``DeviceState.host_credit_ns``), so multi-step
pipelines pay ``max(transfer, compute)`` instead of the sum — bits,
reads, and energy are identical to the sync schedule.

``shard_rows`` / ``shard_lanes`` partition one large host buffer into
per-slot programs (row-wise or lane-wise, optionally across the subarray
axis), and ``gather_rows`` / ``xor_reduce_program`` are the in-DRAM
movement/reduction building blocks the benchmarks use to exchange rows
between slots without host round-trips (RS syndrome sums across banks,
cross-lane reductions).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import exec as pim_exec
from . import ir
from .compile import CompiledProgram, compile_program
from .device import (DeviceConfig, DeviceState, channel_bus_model,
                     host_bus_ns, issue_bus_ns)
from .ir import PimProgram, ProgramBuilder
from .state import NUM_ROWS
from .timing import DDR3Timing, copy_cost


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one device-level schedule step."""

    state: DeviceState
    wall_ns: jax.Array          # max-channel bus + max in-slot exec + copies
    bus_ns: jax.Array           # total bus occupancy, summed over slots
    energy_nj: jax.Array        # summed across slots (this step only)
    reads: tuple                # per slot: host-read rows in slot order
    copy_ns: float = 0.0        # COPY drain *makespan* (link-contended wall)
    host_bytes: int = 0         # off-chip bytes this step's streams moved
    host_bus_ns: float = 0.0    # HOSTW/HOSTR burst occupancy, Σ over slots
    channel_bus_ns: tuple = ()  # per-channel serialized occupancy (+tRTRS)
    rank_switch_ns: float = 0.0  # total tRTRS penalty charged this step
    host_overlap_ns: float = 0.0  # host time hidden under prev step (async)
    copy_total_ns: float = 0.0  # Σ per-copy duration (old copy_ns meaning)
    copy_queue_ns: float = 0.0  # Σ FCFS waiting behind busy links/buses
    link_busy_ns: dict = dataclasses.field(default_factory=dict)
    # per-resource occupancy: ("link", bank, i) RBM link between subarrays
    # i/i+1, ("ibus", channel) the channel's shared internal bus.


def stream_key(p: PimProgram):
    """Slots with equal keys share one compiled vmapped runner: identical
    command stream and shape; HOSTW payload *data* is excluded (it is passed
    per-slot at run time)."""
    return (p.ops, p.num_rows, p.words, len(p.payloads))


# One compiled artifact per distinct (stream, timing): groups recur across
# schedule() calls (e.g. PimVM flushes), so keep the jitted runners warm.
# LRU-bounded — long sessions stream many one-off programs through here,
# and insertion-order (FIFO) eviction would let them push out hot
# recurring streams.
_compile_cache: dict = {}
_COMPILE_CACHE_MAX = 512


def _compiled_for(program: PimProgram, timing: DDR3Timing) -> CompiledProgram:
    key = (stream_key(program), timing)
    hit = _compile_cache.pop(key, None)
    if hit is None:
        if len(_compile_cache) >= _COMPILE_CACHE_MAX:
            _compile_cache.pop(next(iter(_compile_cache)))
        hit = compile_program(program, timing)
    _compile_cache[key] = hit           # (re)insert at the MRU end
    return hit


def _payload_stack(programs: Sequence[PimProgram], words: int) -> jnp.ndarray:
    """(n_slots_in_group, n_payloads, words) uint32 HOSTW payload batch."""
    n_pay = len(programs[0].payloads)
    if n_pay == 0:
        return jnp.zeros((len(programs), 0, words), jnp.uint32)
    return jnp.asarray(np.stack(
        [np.stack(p.payloads) for p in programs]).astype(np.uint32))


def _normalize_programs(cfg: DeviceConfig, programs) -> list:
    """Accept per-bank (len ``n_banks``, entries optionally nested per
    subarray) or flat per-slot (len ``n_slots``) program sequences and
    return a flat per-slot list (``None`` = idle)."""
    programs = list(programs)
    flat: list = [None] * cfg.n_slots
    S = cfg.subarrays

    def put(slot, p):
        flat[slot] = p

    if len(programs) == cfg.n_slots and not any(
            isinstance(p, (list, tuple)) for p in programs):
        for k, p in enumerate(programs):
            put(k, p)
        return flat
    if len(programs) != cfg.n_banks:
        raise ValueError(
            f"got {len(programs)} programs for {cfg.n_banks} banks "
            f"({cfg.n_slots} slots)")
    for b, entry in enumerate(programs):
        if isinstance(entry, (list, tuple)):
            if len(entry) != S:
                raise ValueError(
                    f"bank {b}: {len(entry)} subarray programs for "
                    f"{S} subarrays")
            for s, p in enumerate(entry):
                put(b * S + s, p)
        else:
            put(b * S, entry)       # bare program → the bank's subarray 0
    return flat


def _split_copies(cfg: DeviceConfig, slot: int, program: PimProgram):
    """Partition one slot's stream into (compiled-stream program, deferred
    cross-slot copies). Same-slot COPYs are normalized to the executor's
    local ``COPY_SELF`` encoding and stay in-stream."""
    b, s = cfg.slot_coords(slot)
    self_dst = (ir.COPY_SELF, ir.COPY_SELF)
    kept, deferred = [], []
    changed = False
    for op in program.ops:
        # On the device, local means self-addressed or "destination IS the
        # carrying slot" — explicit (0, 0) on any other carrier is a real
        # transfer to bank 0, so ir.copy_is_local only applies at (0, 0).
        is_local = (op.op == ir.OP_COPY
                    and ((op.delta, op.c) == self_dst
                         or (op.delta, op.c) == (b, s)))
        if op.op != ir.OP_COPY or is_local:
            if is_local and (op.delta, op.c) != self_dst:
                op = dataclasses.replace(op, delta=ir.COPY_SELF,
                                         c=ir.COPY_SELF)
                changed = True
            kept.append(op)
            continue
        dst_slot = cfg.slot_index(op.delta, op.c)   # validates coordinates
        if not (0 <= op.a < cfg.num_rows and 0 <= op.b < cfg.num_rows):
            raise ValueError(
                f"slot {(b, s)}: COPY rows {(op.a, op.b)} out of range "
                f"[0, {cfg.num_rows})")
        deferred.append((slot, dst_slot, op))
        changed = True
    if not changed:
        return program, deferred
    return PimProgram(ops=tuple(kept), num_rows=program.num_rows,
                      words=program.words,
                      payloads=program.payloads), deferred


@dataclasses.dataclass
class CopyDrainStats:
    """Link-contention accounting of one step's COPY drain phase."""

    makespan_ns: float = 0.0    # FCFS queue-model wall of the drain
    total_ns: float = 0.0       # Σ per-copy duration (contention-free sum)
    queue_ns: float = 0.0       # Σ time copies waited behind busy resources
    link_busy_ns: dict = dataclasses.field(default_factory=dict)


def _copy_route(cfg: DeviceConfig, src_slot: int, dst_slot: int):
    """(hops, inter_bank, resources) of one cross-slot copy.

    Intra-bank: RBM hops between the two subarrays, crossing links
    ``(bank, i)`` for i in [min, max). Inter-bank: the row rides RBM links
    from the source subarray to the bank edge (subarray 0, where the
    chip's internal bus taps the bank), crosses the channel's shared
    internal bus, and rides links from the destination's edge inward —
    so an S-1 → S-1 move costs 2(S-1) hops on top of ``t_copy_bank``.
    """
    S = cfg.subarrays
    sb, ss = divmod(src_slot, S)
    db, ds = divmod(dst_slot, S)
    if sb == db:
        hops = abs(ds - ss)
        res = [("link", sb, i) for i in range(min(ss, ds), max(ss, ds))]
        return hops, False, res
    hops = ss + ds
    res = [("link", sb, i) for i in range(ss)]
    res += [("link", db, i) for i in range(ds)]
    s_ch = cfg.bank_coords(sb)[0]
    d_ch = cfg.bank_coords(db)[0]
    res.append(("ibus", s_ch))
    if d_ch != s_ch:
        res.append(("ibus", d_ch))
    return hops, True, res


def _apply_copies(cfg: DeviceConfig, banks, deferred):
    """Drain deferred cross-slot copies on the post-compute state: move the
    rows in (slot, stream-position) order, charge ``copy_cost`` onto each
    source slot's meter, and serialize contended links/buses FCFS in the
    same order. Returns (banks', CopyDrainStats)."""
    t = cfg.timing
    n = cfg.n_slots
    dt = np.zeros(n, np.float32)
    e_act = np.zeros(n, np.float32)
    e_pre = np.zeros(n, np.float32)
    n_act = np.zeros(n, np.int32)
    n_pre = np.zeros(n, np.int32)
    n_aap = np.zeros(n, np.int32)
    srcs = [(k, op.a) for k, _, op in deferred]
    dsts = [(d, op.b) for _, d, op in deferred]
    bits = banks.bits
    if len(set(dsts)) == len(dsts) and not set(dsts) & set(srcs):
        # Independent copies (the common gather pattern: distinct
        # destinations, none feeding a later copy) — ONE batched scatter
        # instead of a dispatch per row.
        si, sr = (jnp.asarray([x[j] for x in srcs]) for j in (0, 1))
        di, dr = (jnp.asarray([x[j] for x in dsts]) for j in (0, 1))
        bits = bits.at[di, dr].set(bits[si, sr])
    else:
        for src_slot, dst_slot, op in deferred:
            bits = bits.at[dst_slot, op.b].set(bits[src_slot, op.a])
    stats = CopyDrainStats()
    ready: dict = {}                    # resource -> busy-until (drain clock)
    for src_slot, dst_slot, op in deferred:
        hops, inter_bank, resources = _copy_route(cfg, src_slot, dst_slot)
        c_dt, c_ea, c_ep, c_na, c_np, c_naap = copy_cost(hops, inter_bank, t)
        dt[src_slot] += np.float32(c_dt)
        e_act[src_slot] += np.float32(c_ea)
        e_pre[src_slot] += np.float32(c_ep)
        n_act[src_slot] += c_na
        n_pre[src_slot] += c_np
        n_aap[src_slot] += c_naap
        start = max((ready.get(r, 0.0) for r in resources), default=0.0)
        end = start + c_dt
        for r in resources:
            ready[r] = end
            stats.link_busy_ns[r] = stats.link_busy_ns.get(r, 0.0) + c_dt
        stats.queue_ns += start
        stats.total_ns += c_dt
        stats.makespan_ns = max(stats.makespan_ns, end)
    m = banks.meter
    meter = dataclasses.replace(
        m,
        time_ns=m.time_ns + jnp.asarray(dt),
        e_act=m.e_act + jnp.asarray(e_act),
        e_pre=m.e_pre + jnp.asarray(e_pre),
        e_background=m.e_background
        + jnp.asarray(dt) * jnp.float32(t.p_background),
        n_act=m.n_act + jnp.asarray(n_act),
        n_pre=m.n_pre + jnp.asarray(n_pre),
        n_aap=m.n_aap + jnp.asarray(n_aap))
    return dataclasses.replace(banks, bits=bits, meter=meter), stats


def schedule(device: DeviceState,
             programs, *,
             use_kernels: bool | None = None,
             interpret: bool | None = None,
             refresh: bool = False,
             async_host: bool = False) -> ScheduleResult:
    """Run one program per slot (``None`` = idle slot) and fold the device
    timing model over the per-slot meters.

    ``programs`` may be per-bank (len ``n_banks``; entries are a program for
    the bank's subarray 0 or a nested per-subarray sequence) or flat
    per-slot (len ``n_slots``). Cross-slot ``COPY`` ops are stripped from
    the compiled streams and drained after the in-bank compute (see module
    docstring).

    ``refresh`` folds periodic-refresh stalls/energy into each slot's meter
    (``timing.apply_refresh``); the fold is incremental against the meter's
    ``n_refresh`` history, so repeated refreshed schedules on one device
    charge every event exactly once.

    ``async_host=True`` models a Shared-PIM-style asynchronous host-transfer
    engine: this step's HOSTW/HOSTR bursts overlap the *previous* step's
    compute+copy window (``device.host_credit_ns``), double-buffered, so a
    multi-step pipeline pays ``max(transfer, compute)`` per step instead of
    the sum. Only the wall clock changes — states, reads, and energy are
    identical to the synchronous schedule.
    """
    cfg = device.config
    flat = _normalize_programs(cfg, programs)
    for k, p in enumerate(flat):
        if p is not None and (p.num_rows, p.words) != (cfg.num_rows,
                                                       cfg.words):
            raise ValueError(
                f"slot {cfg.slot_coords(k)}: program shape "
                f"{(p.num_rows, p.words)} != device "
                f"shape {(cfg.num_rows, cfg.words)}")

    deferred: list = []
    stripped: list = [None] * cfg.n_slots
    for k, p in enumerate(flat):
        if p is None:
            continue
        stripped[k], slot_copies = _split_copies(cfg, k, p)
        deferred.extend(slot_copies)

    groups: dict = {}
    for k, p in enumerate(stripped):
        if p is not None and len(p.ops):
            groups.setdefault(stream_key(p), []).append(k)

    banks = device.banks
    t0 = jnp.asarray(banks.meter.time_ns)
    e0 = jnp.asarray(banks.meter.total_energy_nj)
    new_banks = banks
    reads: list[tuple] = [() for _ in range(cfg.n_slots)]
    issue_bus = np.zeros(cfg.n_slots, np.float32)
    host_bus = np.zeros(cfg.n_slots, np.float32)

    for key, slot_ids in groups.items():
        group_progs = [stripped[k] for k in slot_ids]
        compiled = _compiled_for(group_progs[0], cfg.timing)
        runner = pim_exec.make_runner(
            compiled, cfg.timing, use_kernels=use_kernels,
            interpret=interpret, refresh=refresh, payload_arg=True)
        idx = jnp.asarray(slot_ids)
        sub = jax.tree_util.tree_map(lambda x: x[idx], banks)
        out, group_reads = jax.vmap(runner.traced)(
            sub, _payload_stack(group_progs, cfg.words))
        new_banks = jax.tree_util.tree_map(
            lambda full, upd: full.at[idx].set(upd), new_banks, out)
        group_issue = issue_bus_ns(group_progs[0], cfg.timing)
        group_host = host_bus_ns(group_progs[0], cfg.timing)
        for j, k in enumerate(slot_ids):
            reads[k] = tuple(r[j] for r in group_reads)
            issue_bus[k] = group_issue
            host_bus[k] = group_host

    # In-slot execution excludes each slot's own bus occupancy and the
    # drained copies (accounted by the contention model below).
    bus_j = jnp.asarray(issue_bus + host_bus)
    exec_ns = jnp.asarray(new_banks.meter.time_ns) - t0 - bus_j

    copies = CopyDrainStats()
    if deferred:
        new_banks, copies = _apply_copies(cfg, new_banks, deferred)

    e1 = jnp.asarray(new_banks.meter.total_energy_nj)
    chan_busy, switch_ns, hidden_ns = channel_bus_model(
        cfg, issue_bus, host_bus,
        host_credit_ns=device.host_credit_ns if async_host else 0.0)
    compute_ns = (jnp.max(exec_ns) if exec_ns.size else jnp.float32(0.0)) \
        + jnp.float32(copies.makespan_ns)
    wall = jnp.float32(chan_busy.max()) + compute_ns
    return ScheduleResult(
        state=device.with_banks(new_banks,
                                host_credit_ns=float(compute_ns)),
        wall_ns=wall,
        bus_ns=jnp.sum(bus_j),
        energy_nj=jnp.sum(e1 - e0),
        reads=tuple(reads),
        copy_ns=copies.makespan_ns,
        host_bytes=sum(p.host_bytes for p in flat if p is not None),
        host_bus_ns=float(host_bus.sum()),
        channel_bus_ns=tuple(float(x) for x in chan_busy),
        rank_switch_ns=switch_ns,
        host_overlap_ns=hidden_ns,
        copy_total_ns=copies.total_ns,
        copy_queue_ns=copies.queue_ns,
        link_busy_ns=dict(copies.link_busy_ns))


# ---------------------------------------------------------------------------
# In-DRAM movement / reduction primitives
# ---------------------------------------------------------------------------

def gather_rows(cfg: DeviceConfig, moves, programs=None) -> list:
    """Per-slot COPY streams for in-DRAM row movement (zero host bytes).

    ``moves``: iterable of ``((src_bank, src_sub, src_row),
    (dst_bank, dst_sub, dst_row))``. Each move records one ``COPY`` in the
    *source* slot's stream; the scheduler drains them after the step's
    compute, so gathered rows hold post-compute values and are readable by
    the next step. ``programs`` (optional, any layout ``schedule`` accepts)
    is appended to — pass the step's compute programs to fuse compute +
    gather into one ``schedule`` call. Returns a flat per-slot list.
    """
    base = (_normalize_programs(cfg, programs) if programs is not None
            else [None] * cfg.n_slots)
    builders: dict[int, ProgramBuilder] = {}
    for (sb, ss, sr), (db, ds, dr) in moves:
        slot = cfg.slot_index(sb, ss)
        cfg.slot_index(db, ds)          # validate destination coordinates
        builders.setdefault(
            slot, ProgramBuilder(cfg.num_rows, cfg.words)).copy_row(
                sr, dr, db, ds)
    out = list(base)
    for slot, b in builders.items():
        copies = b.build()
        out[slot] = (copies if out[slot] is None
                     else ir.concat([out[slot], copies]))
    return out


def xor_reduce_program(num_rows: int, words: int, rows: Sequence[int],
                       dst: int) -> PimProgram:
    """One slot's in-place XOR fold: ``dst <- rows[0] ^ rows[1] ^ ...`` via
    Ambit XOR (rows must avoid the T0..T3 scratch). The reduction half of a
    gather/reduce step — all row traffic stays inside the subarray."""
    b = ProgramBuilder(num_rows, words)
    rows = list(rows)
    assert rows, "need at least one row to reduce"
    if rows[0] != dst:
        b.rowclone(rows[0], dst)
    for r in rows[1:]:
        b.ambit_xor(dst, r, dst)
    return b.build()


# ---------------------------------------------------------------------------
# Host-buffer partitioners: one large buffer → per-slot programs
# ---------------------------------------------------------------------------

BuildFn = Callable[[ProgramBuilder, list[int]], None]


def _chunk_program(chunk: np.ndarray, num_rows: int, words: int,
                   build: BuildFn | None, read_back: bool) -> PimProgram:
    b = ProgramBuilder(num_rows, words)
    b.issue()
    rows = list(range(chunk.shape[0]))
    for r in rows:
        b.write_row(r, chunk[r])
    if build is not None:
        build(b, rows)
    if read_back:
        for r in rows:
            b.read_row(r)
    return b.build()


def _regroup(programs: list, subarrays: int):
    """Flat chunk list → nested [bank][sub] when placing across the
    subarray axis; flat per-bank list otherwise (back-compat)."""
    if subarrays == 1:
        return programs
    return [programs[b * subarrays:(b + 1) * subarrays]
            for b in range(len(programs) // subarrays)]


def shard_rows(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
               subarrays: int = 1, build: BuildFn | None = None,
               read_back: bool = False) -> list:
    """Split a ``(R, words)`` row buffer row-wise across ``n_banks`` banks
    (× ``subarrays`` slots per bank).

    Each slot receives a contiguous chunk of rows, HOSTW-written to its rows
    ``0..k-1`` after one ISSUE burst; ``build(builder, local_rows)`` then
    appends the per-slot compute. Chunks are ``np.array_split``-balanced, so
    R need not divide evenly (trailing slots may be one row short or idle).
    Returns a flat per-bank list, or nested ``[bank][sub]`` when
    ``subarrays > 1`` — both layouts feed ``schedule`` directly.
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    chunks = np.array_split(data, n_banks * subarrays, axis=0)
    return _regroup(
        [_chunk_program(c, num_rows, data.shape[1], build, read_back)
         for c in chunks], subarrays)


def shard_lanes(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
                subarrays: int = 1, build: BuildFn | None = None,
                read_back: bool = False) -> list:
    """Split a ``(R, words)`` row buffer lane-wise across ``n_banks`` banks
    (× ``subarrays`` slots per bank).

    Slot ``k`` receives the word-slice ``[:, k*w:(k+1)*w]`` of every row
    (``w = words // n_slots``) — all slots then run the SAME command stream
    over different columns, the natural SIMD split for element-parallel
    workloads (element width must divide 32 so lanes never straddle the
    word-slice boundary). Layout as in ``shard_rows``.
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    words = data.shape[1]
    n_slots = n_banks * subarrays
    if words % n_slots:
        raise ValueError(f"words={words} not divisible by n_banks*subarrays="
                         f"{n_slots}")
    w = words // n_slots
    chunks = [data[:, k * w:(k + 1) * w] for k in range(n_slots)]
    return _regroup(
        [_chunk_program(c, num_rows, w, build, read_back) for c in chunks],
        subarrays)
