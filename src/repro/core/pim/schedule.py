"""Workload scheduler for device-level (multi-bank) PIM execution.

Takes *heterogeneous* per-bank :class:`~.ir.PimProgram`s and executes them
against a :class:`~.device.DeviceState` with as few compiled artifacts as
possible: banks whose command streams are identical (same ops, shape and
payload count — payload *data* may differ) form one group, and each group
runs as ONE compiled runner vmapped over the group's bank states with the
HOSTW payloads passed as a batched argument (``exec.make_runner``'s
``payload_arg`` mode). This is SIMDRAM's framework split — program →
allocation → execution — with Shared-PIM-style concurrent bank scheduling.

Device accounting (see ``device.py``): per-bank meters accumulate each
bank's own busy time; the schedule-level wall clock is

    wall = Σ_b bus_b  +  max_b (Δtime_b − bus_b)        energy = Σ_b Δenergy_b

where ``bus_b`` is bank b's serialized per-burst ``ISSUE`` occupancy.

``shard_rows`` / ``shard_lanes`` partition one large host buffer into
per-bank programs (row-wise or lane-wise), the building blocks the
benchmarks and ``bitplane.PimVM``'s ``n_banks`` mode use to scatter a
multi-KB workload over the paper's 32 banks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import exec as pim_exec
from . import ir
from .compile import CompiledProgram, compile_program
from .device import DeviceState, bus_time_ns, device_wall_ns
from .ir import PimProgram, ProgramBuilder
from .state import NUM_ROWS
from .timing import DDR3Timing


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one device-level schedule step."""

    state: DeviceState
    wall_ns: jax.Array          # bus serialization + max in-bank exec
    bus_ns: jax.Array           # serialized command-bus occupancy
    energy_nj: jax.Array        # summed across banks (this step only)
    reads: tuple                # per bank: host-read rows in slot order


def stream_key(p: PimProgram):
    """Banks with equal keys share one compiled vmapped runner: identical
    command stream and shape; HOSTW payload *data* is excluded (it is passed
    per-bank at run time)."""
    return (p.ops, p.num_rows, p.words, len(p.payloads))


# One compiled artifact per distinct (stream, timing): groups recur across
# schedule() calls (e.g. PimVM flushes), so keep the jitted runners warm.
# FIFO-bounded — long sessions stream many one-off programs through here.
_compile_cache: dict = {}
_COMPILE_CACHE_MAX = 512


def _compiled_for(program: PimProgram, timing: DDR3Timing) -> CompiledProgram:
    key = (stream_key(program), timing)
    if key not in _compile_cache:
        if len(_compile_cache) >= _COMPILE_CACHE_MAX:
            _compile_cache.pop(next(iter(_compile_cache)))
        _compile_cache[key] = compile_program(program, timing)
    return _compile_cache[key]


def _payload_stack(programs: Sequence[PimProgram], words: int) -> jnp.ndarray:
    """(n_banks_in_group, n_payloads, words) uint32 HOSTW payload batch."""
    n_pay = len(programs[0].payloads)
    if n_pay == 0:
        return jnp.zeros((len(programs), 0, words), jnp.uint32)
    return jnp.asarray(np.stack(
        [np.stack(p.payloads) for p in programs]).astype(np.uint32))


def schedule(device: DeviceState,
             programs: Sequence[PimProgram | None], *,
             use_kernels: bool | None = None,
             interpret: bool | None = None,
             refresh: bool = False) -> ScheduleResult:
    """Run one program per bank (``None`` = idle bank) and fold the device
    timing model over the per-bank meters.

    ``refresh`` folds periodic-refresh stalls/energy into each bank's meter
    (``timing.apply_refresh``). It recounts from the bank's *cumulative*
    busy time, so only use it on single-shot runs against fresh devices —
    repeated refreshed schedules on one device would double-count events.
    """
    cfg = device.config
    if len(programs) != cfg.n_banks:
        raise ValueError(
            f"got {len(programs)} programs for {cfg.n_banks} banks")
    for b, p in enumerate(programs):
        if p is not None and (p.num_rows, p.words) != (cfg.num_rows,
                                                       cfg.words):
            raise ValueError(
                f"bank {b}: program shape {(p.num_rows, p.words)} != device "
                f"shape {(cfg.num_rows, cfg.words)}")

    groups: dict = {}
    for b, p in enumerate(programs):
        if p is not None and len(p.ops):
            groups.setdefault(stream_key(p), []).append(b)

    banks = device.banks
    t0 = jnp.asarray(banks.meter.time_ns)
    e0 = jnp.asarray(banks.meter.total_energy_nj)
    new_banks = banks
    reads: list[tuple] = [() for _ in range(cfg.n_banks)]
    bus = np.zeros(cfg.n_banks, np.float32)

    for key, bank_ids in groups.items():
        group_progs = [programs[b] for b in bank_ids]
        compiled = _compiled_for(group_progs[0], cfg.timing)
        runner = pim_exec.make_runner(
            compiled, cfg.timing, use_kernels=use_kernels,
            interpret=interpret, refresh=refresh, payload_arg=True)
        idx = jnp.asarray(bank_ids)
        sub = jax.tree_util.tree_map(lambda x: x[idx], banks)
        out, group_reads = jax.vmap(runner.traced)(
            sub, _payload_stack(group_progs, cfg.words))
        new_banks = jax.tree_util.tree_map(
            lambda full, upd: full.at[idx].set(upd), new_banks, out)
        group_bus = bus_time_ns(group_progs[0], cfg.timing)
        for j, b in enumerate(bank_ids):
            reads[b] = tuple(r[j] for r in group_reads)
            bus[b] = group_bus

    t1 = jnp.asarray(new_banks.meter.time_ns)
    e1 = jnp.asarray(new_banks.meter.total_energy_nj)
    bus_j = jnp.asarray(bus)
    exec_ns = t1 - t0 - bus_j
    return ScheduleResult(
        state=device.with_banks(new_banks),
        wall_ns=device_wall_ns(bus_j, exec_ns),
        bus_ns=jnp.sum(bus_j),
        energy_nj=jnp.sum(e1 - e0),
        reads=tuple(reads))


# ---------------------------------------------------------------------------
# Host-buffer partitioners: one large buffer → per-bank programs
# ---------------------------------------------------------------------------

BuildFn = Callable[[ProgramBuilder, list[int]], None]


def _chunk_program(chunk: np.ndarray, num_rows: int, words: int,
                   build: BuildFn | None, read_back: bool) -> PimProgram:
    b = ProgramBuilder(num_rows, words)
    b.issue()
    rows = list(range(chunk.shape[0]))
    for r in rows:
        b.write_row(r, chunk[r])
    if build is not None:
        build(b, rows)
    if read_back:
        for r in rows:
            b.read_row(r)
    return b.build()


def shard_rows(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
               build: BuildFn | None = None,
               read_back: bool = False) -> list[PimProgram]:
    """Split a ``(R, words)`` row buffer row-wise across ``n_banks``.

    Bank ``b`` receives a contiguous chunk of rows, HOSTW-written to its rows
    ``0..k-1`` after one ISSUE burst; ``build(builder, local_rows)`` then
    appends the per-bank compute. Chunks are ``np.array_split``-balanced, so
    R need not divide evenly (trailing banks may be one row short or idle).
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    chunks = np.array_split(data, n_banks, axis=0)
    return [_chunk_program(c, num_rows, data.shape[1], build, read_back)
            for c in chunks]


def shard_lanes(data: np.ndarray, n_banks: int, num_rows: int = NUM_ROWS, *,
                build: BuildFn | None = None,
                read_back: bool = False) -> list[PimProgram]:
    """Split a ``(R, words)`` row buffer lane-wise across ``n_banks``.

    Bank ``b`` receives the word-slice ``[:, b*w:(b+1)*w]`` of every row
    (``w = words // n_banks``) — all banks then run the SAME command stream
    over different columns, the natural SIMD split for element-parallel
    workloads (element width must divide 32 so lanes never straddle the
    word-slice boundary).
    """
    data = np.asarray(data, dtype=np.uint32)
    assert data.ndim == 2, data.shape
    words = data.shape[1]
    if words % n_banks:
        raise ValueError(f"words={words} not divisible by n_banks={n_banks}")
    w = words // n_banks
    chunks = [data[:, b * w:(b + 1) * w] for b in range(n_banks)]
    return [_chunk_program(c, num_rows, w, build, read_back) for c in chunks]
