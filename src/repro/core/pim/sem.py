"""pimsem: symbolic semantic analyzer — prove what PIM programs compute.

A vectorized abstract interpreter over the cached columnar IR
(:class:`~.ir.ProgramColumns`): no execution, no tracing, no jax. The
abstract domain is *boolean functions of named symbolic input rows*,
represented as packed-uint64 truth tables over at most ``max_inputs``
variables — numpy bit-parallel across both the 2^k truth-table axis and
the subarray's bit lanes — with a lattice top (``TOP``) fallback when a
value's support outgrows the budget.

Variables are ``(row, disp)`` pairs: the variable ``(r, d)`` evaluated at
lane ``L`` denotes input bit ``L - d`` of row ``r``'s *initial* contents.
A 1-bit migration-cell SHIFT is then exact and cheap: the truth tables
roll one lane (the boundary lane becomes constant 0 — the paper's "edge
bit falls off, fill 0" semantics), and every support variable's
displacement moves by the shift delta. Because the support is kept
lexicographically sorted and a shift displaces every variable uniformly,
no truth-table column permutation is ever needed.

Soundness invariant (by induction over the transfer functions): at every
lane ``L``, a value's truth table has zero dependence on any support
variable ``(r, d)`` whose referenced input bit ``L - d`` lies outside
``[0, lanes)``. Fresh inputs have ``d = 0``; a shift zeroes exactly the
lanes where newly out-of-range references appear; bitwise ops cannot
introduce dependence their operands lack. Consequently truth-table
equality over the union support is *exact* equality of the concrete
functions, and any truth-table difference yields a concrete witness
assignment touching only in-range input bits.

Built on top:

``summarize(program)``
    Per-row closed-form boolean expression of every written row.

``prove_equivalent(a, b, *, inputs, outputs)``
    Sound verdict contract: ``EQUIVALENT`` (exact), ``DIFFERENT`` plus a
    concrete :class:`Witness` assignment that provably distinguishes the
    two programs under the eager ISA, or ``UNKNOWN`` (a compared value
    hit ``TOP`` or the truth-table budget). Never a false EQUIVALENT.

``semantic_findings(program)``
    The PIM4xx diagnostic tier consumed by ``lint.py``: PIM401 (op
    computes a constant), PIM402 (MAJ with symbolically equal operands),
    PIM403 (cancelling NOT/NOT or net-zero SHIFT chains), PIM404
    (semantically no-op write).

``fusion_report(program, segments)`` / ``verify_fusion``
    Abstractly interprets the *fused* segment list (``compile.fuse``)
    against the unfused op stream and proves them equivalent — the
    ``verify_semantics=True`` gate on ``compile.fuse``/``compile_program``.

The initial abstract state matches ``state.make_subarray``: migration
rows and the DCC row start as constant 0; ``assume_control=True``
(default) additionally seeds C0/C1 with their ``reserve_control_rows``
constants. Witnesses replay through ``isa.run_on_bits`` under the same
convention, so every DIFFERENT verdict is executable by construction.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import ir, isa
from .timing import DDR3Timing, DEFAULT_TIMING

__all__ = [
    "Analysis", "EQUIVALENT", "DIFFERENT", "UNKNOWN", "DEFAULT_MAX_INPUTS",
    "EquivReport", "EquivalenceError", "SEM_STATS", "SymVal", "TOP",
    "Witness", "analyze", "check_witness", "fusion_report", "is_const",
    "lane_const", "prove_equivalent", "semantic_findings", "summarize",
    "verify_fusion",
]

EQUIVALENT = "EQUIVALENT"
DIFFERENT = "DIFFERENT"
UNKNOWN = "UNKNOWN"

# Default symbolic-input budget: a value may depend on at most this many
# (row, disp) variables before collapsing to TOP.
DEFAULT_MAX_INPUTS = 16

# Resource guard on expanded truth tables: lanes * 2^k single-bit elements.
# Strictly-greater comparison so the differential harness's largest case
# (128 lanes x 2^16 assignments == 1 << 23) still analyzes exactly.
_MAX_TT_ELEMS = 1 << 23

_MAX_FINDINGS = 64

SEM_STATS = {"analyses": 0, "analysis_hits": 0, "proofs": 0,
             "proof_hits": 0, "top_values": 0}

_U1 = np.uint64(1)
_U6 = np.uint64(6)
_U63 = np.uint64(63)
_ONES = np.uint64(0xFFFF_FFFF_FFFF_FFFF)

# Truth-table bit pattern of variable p (p < 6) within one uint64 word:
# assignment j has variable p set iff bit p of j is set, so the pattern
# alternates in blocks of 2^p. Tables with k < 6 variables replicate
# their 2^k-bit table to fill the word (stable under all bitwise ops),
# which makes these patterns exact for every k.
_VAR_WORDS = (
    np.uint64(0xAAAA_AAAA_AAAA_AAAA), np.uint64(0xCCCC_CCCC_CCCC_CCCC),
    np.uint64(0xF0F0_F0F0_F0F0_F0F0), np.uint64(0xFF00_FF00_FF00_FF00),
    np.uint64(0xFFFF_0000_FFFF_0000), np.uint64(0xFFFF_FFFF_0000_0000))


class _Top:
    """Lattice top: value exceeded the symbolic budget. Singleton."""

    __slots__ = ()

    def __repr__(self) -> str:
        return "TOP"


TOP = _Top()


class SymVal:
    """One abstract row value: ``sup`` is the lex-sorted tuple of
    ``(row, disp)`` variables, ``tt`` the packed truth tables — uint64
    array of shape ``(lanes, max(1, 2^k >> 6))`` with ``k = len(sup)``;
    for ``k < 6`` the 2^k-bit table is replicated across the word."""

    __slots__ = ("sup", "tt", "neg_of", "cancels", "shift_base",
                 "shift_net", "_shrunk")

    def __init__(self, sup: tuple, tt: np.ndarray):
        self.sup = sup
        self.tt = tt
        # Findings-pass provenance (PIM403): what this value is a NOT of,
        # whether it closes a NOT/NOT pair, and the shift-chain origin.
        self.neg_of = None
        self.cancels = False
        self.shift_base = None
        self.shift_net = 0
        self._shrunk = None

    def __repr__(self) -> str:
        return f"SymVal(sup={self.sup}, lanes={self.tt.shape[0]})"


def _n_words(k: int) -> int:
    return 1 if k < 6 else 1 << (k - 6)


def _const_val(lanes: int, on: bool) -> SymVal:
    tt = np.full((lanes, 1), _ONES if on else np.uint64(0), np.uint64)
    return SymVal((), tt)


def _var(row: int, lanes: int) -> SymVal:
    tt = np.full((lanes, 1), _VAR_WORDS[0], np.uint64)
    return SymVal(((int(row), 0),), tt)


def _row_to_lane_bits(row: np.ndarray) -> np.ndarray:
    """(words,) uint32 -> (lanes,) bool: little-endian lane bits."""
    w = np.asarray(row, np.uint32)
    bits = (w[:, None] >> np.arange(32, dtype=np.uint32)) & np.uint32(1)
    return bits.reshape(-1).astype(bool)


def _const_lanes(mask: np.ndarray) -> SymVal:
    """Per-lane constant from a (lanes,) bool mask."""
    tt = np.where(mask, _ONES, np.uint64(0)).astype(np.uint64)
    return SymVal((), tt.reshape(-1, 1))


@functools.lru_cache(maxsize=1024)
def _gather_arrays(k_to: int, moves: tuple):
    """Gather indices remapping a truth table between variable layouts.

    ``moves`` is a tuple of ``(src_pos, dst_pos)``: source variable at
    bit position ``src_pos`` appears at position ``dst_pos`` of the
    target support. For every target assignment ``j`` the source
    assignment reads the moved bits (dropped source variables stay 0 —
    only valid when the table does not depend on them, which both
    callers guarantee). Returns ``(word_idx, bit_shift)`` arrays of
    length ``2^k_to``."""
    j = np.arange(1 << k_to, dtype=np.uint64)
    src = np.zeros(1 << k_to, np.uint64)
    for sp, dp in moves:
        src |= ((j >> np.uint64(dp)) & _U1) << np.uint64(sp)
    return (src >> _U6).astype(np.intp), (src & _U63)


def _pack_bits(bits: np.ndarray, k: int) -> np.ndarray:
    """(lanes, 2^k) 0/1 uint64 -> packed (lanes, n_words(k)) table."""
    lanes = bits.shape[0]
    if k >= 6:
        b = bits.reshape(lanes, -1, 64)
        return np.bitwise_or.reduce(
            b << np.arange(64, dtype=np.uint64), axis=-1)
    w = np.bitwise_or.reduce(
        bits << np.arange(1 << k, dtype=np.uint64), axis=-1)
    for p in range(k, 6):          # replicate the 2^k-bit table wordwide
        w = w | (w << np.uint64(1 << p))
    return w.reshape(lanes, 1)


def _remap(tt: np.ndarray, k_to: int, moves: tuple) -> np.ndarray:
    """Re-express ``tt`` over a ``k_to``-variable layout via ``moves``."""
    widx, bshift = _gather_arrays(k_to, moves)
    bits = (tt[:, widx] >> bshift) & _U1
    return _pack_bits(bits, k_to)


def _to_sup(val: SymVal, sup: tuple) -> np.ndarray:
    """``val``'s truth table expanded to the (superset) support ``sup``."""
    if val.sup == sup:
        return val.tt
    pos = {v: i for i, v in enumerate(sup)}
    moves = tuple((i, pos[v]) for i, v in enumerate(val.sup))
    return _remap(val.tt, len(sup), moves)


def _depends(tt: np.ndarray, p: int) -> bool:
    """Does the table depend on the variable at bit position ``p``?"""
    if p < 6:
        d = (tt >> np.uint64(1 << p)) ^ tt
        return bool(np.any(d & ~_VAR_WORDS[p]))
    step = 1 << (p - 6)
    lanes, w = tt.shape
    blocks = tt.reshape(lanes, w // (2 * step), 2, step)
    return bool(np.any(blocks[:, :, 0, :] ^ blocks[:, :, 1, :]))


def _shrink(v):
    """Canonical form: drop support variables the table never depends on
    (cached on the value). TOP shrinks to TOP."""
    if v is TOP:
        return TOP
    if v._shrunk is not None:
        return v._shrunk
    k = len(v.sup)
    dep = [p for p in range(k) if _depends(v.tt, p)]
    if len(dep) == k:
        out = v
    else:
        sup = tuple(v.sup[p] for p in dep)
        out = SymVal(sup, _remap(v.tt, len(dep),
                                 tuple((old, new)
                                       for new, old in enumerate(dep))))
        out._shrunk = out
    v._shrunk = out
    return out


def is_const(v) -> bool:
    """True iff the value is a per-lane constant (no symbolic support)."""
    if v is TOP:
        return False
    return not _shrink(v).sup


def lane_const(v, lane: int):
    """The provable constant bit of ``v`` at ``lane`` (0 or 1), else
    ``None`` when the lane depends on symbolic inputs (or ``v`` is TOP)."""
    if v is TOP:
        return None
    row = v.tt[lane]
    if not row.any():
        return 0
    if bool(np.all(row == _ONES)):
        return 1
    return None


def _cheap_eq(x, y) -> bool:
    """Sufficient (sound, incomplete) equality: same object, or same
    support with identical tables."""
    if x is TOP or y is TOP:
        return False
    if x is y:
        return True
    return x.sup == y.sup and np.array_equal(x.tt, y.tt)


def _union_sup(*vals) -> tuple:
    s: set = set()
    for v in vals:
        s.update(v.sup)
    return tuple(sorted(s))


def _diff(va, vb, lanes: int, max_inputs: int):
    """Exact comparison of two values.

    Returns ``("eq", ...)``, ``("ne", lane, assignment, sup)`` with the
    first differing lane and truth-table assignment index over the union
    support, or ``("unknown", ...)`` when either value is TOP or the
    union table exceeds the budget."""
    if va is TOP or vb is TOP:
        return ("unknown", None, None, None)
    if va is vb:
        return ("eq", None, None, None)
    sup = _union_sup(va, vb)
    k = len(sup)
    if k > max_inputs or lanes * (1 << k) > _MAX_TT_ELEMS:
        return ("unknown", None, None, None)
    d = _to_sup(va, sup) ^ _to_sup(vb, sup)
    nz = np.nonzero(d)
    if nz[0].size == 0:
        return ("eq", None, None, None)
    lane, w = int(nz[0][0]), int(nz[1][0])
    word = int(d[lane, w])
    j = w * 64 + ((word & -word).bit_length() - 1)
    if k < 6:
        j %= 1 << k                # table replicated with period 2^k
    return ("ne", lane, j, sup)


def _eq_opt(x, y, lanes: int, max_inputs: int):
    """True / False / None(unknown) equality used by the findings pass."""
    if _cheap_eq(x, y):
        return True
    verdict = _diff(x, y, lanes, max_inputs)[0]
    return {"eq": True, "ne": False}.get(verdict)


# ---------------------------------------------------------------------------
# The abstract machine
# ---------------------------------------------------------------------------

class Analysis:
    """Abstract state of one interpreted stream: ``env`` maps row ->
    value (SymVal or TOP), ``reads`` are the host-read values in slot
    order, ``dcc``/``mig_top``/``mig_bot`` the side-state rows, and
    ``written`` the rows the stream wrote."""

    def __init__(self, num_rows: int, words: int, *,
                 max_inputs: int = DEFAULT_MAX_INPUTS,
                 assume_control: bool = True, inputs=None):
        self.num_rows = int(num_rows)
        self.words = int(words)
        self.lanes = self.words * 32
        self.max_inputs = int(max_inputs)
        self.assume_control = bool(assume_control)
        self.inputs = (None if inputs is None else
                       frozenset(int(r) % self.num_rows for r in inputs))
        self.const0 = _const_val(self.lanes, False)
        self.const1 = _const_val(self.lanes, True)
        self._control = frozenset(
            int(r) % self.num_rows for r in (isa.C0, isa.C1))
        self._even = (np.arange(self.lanes) & 1) == 0
        self.env: dict = {}
        if self.assume_control:
            self.env[int(isa.C0) % self.num_rows] = self.const0
            self.env[int(isa.C1) % self.num_rows] = self.const1
        # make_subarray zeroes the migration rows and the DCC row.
        self.dcc = self.const0
        self.mig_top = self.const0
        self.mig_bot = self.const0
        self.reads: list = []
        self.written: set = set()
        self.n_top = 0

    # -- reads / writes -------------------------------------------------------
    def value(self, r: int):
        """Current abstract value of row ``r`` (lazily a fresh symbolic
        input — or constant 0 outside the declared ``inputs`` set)."""
        v = self.env.get(r)
        if v is None:
            if self.inputs is not None and r not in self.inputs:
                v = self.const0
            else:
                v = _var(r, self.lanes)
            self.env[r] = v
        return v

    def _top(self):
        self.n_top += 1
        SEM_STATS["top_values"] += 1
        return TOP

    def _write(self, b: int, v, op_index, emit) -> None:
        if (emit is not None
                and not (self.assume_control and b in self._control)):
            old = self.value(b)
            if _eq_opt(old, v, self.lanes, self.max_inputs) is True:
                emit("PIM404", op_index,
                     f"write to row {b} is a semantic no-op: the row "
                     "already holds exactly this value")
        self.env[b] = v
        self.written.add(b)

    # -- transfer functions ---------------------------------------------------
    def maj(self, va, vb, vc):
        # maj(x, x, z) == x for ANY z (even TOP) — but only when the two
        # equal operands are the same known value, never the TOP object.
        if va is not TOP and (va is vb or _cheap_eq(va, vb)
                              or va is vc or _cheap_eq(va, vc)):
            return va
        if vb is not TOP and (vb is vc or _cheap_eq(vb, vc)):
            return vb
        if va is TOP or vb is TOP or vc is TOP:
            return self._top()
        sup = _union_sup(va, vb, vc)
        k = len(sup)
        if k > self.max_inputs or self.lanes * (1 << k) > _MAX_TT_ELEMS:
            return self._top()
        ta, tb, tc = (_to_sup(v, sup) for v in (va, vb, vc))
        return SymVal(sup, (ta & tb) | (ta & tc) | (tb & tc))

    def not_(self, v):
        if v is TOP:
            return self._top()
        out = SymVal(v.sup, ~v.tt)
        out.neg_of = v
        out.cancels = v.neg_of is not None
        return out

    def _displace(self, v, m: int):
        """Value shifted ``m`` lanes with boundary zero fill; support
        displacements move uniformly by ``m`` (order-preserving)."""
        if v is TOP:
            return TOP
        if m == 0:
            return v
        if abs(m) >= self.lanes:
            # Fresh constant, not the shared const0: shift_chain annotates
            # provenance fields on its result.
            return _const_val(self.lanes, False)
        tt = np.roll(v.tt, m, axis=0)
        if m > 0:
            tt[:m] = 0
        else:
            tt[m:] = 0
        return SymVal(tuple((r, d + m) for (r, d) in v.sup), tt)

    def _mask_parity(self, v, even: bool):
        if v is TOP:
            return TOP
        tt = v.tt.copy()
        tt[~self._even if even else self._even] = 0
        return SymVal(v.sup, tt)

    def shift_chain(self, src: int, dst: int, delta: int, k: int, *,
                    op_index=None, emit=None) -> None:
        """``k`` chained 1-bit shifts src->dst(->dst...), one direction —
        exactly the eager loop and ``compile.SegShiftRun``: the result is
        the source displaced ``delta*k`` lanes, the migration rows hold
        the parity masks of the ``delta*(k-1)``-displaced value (the last
        hop's captures)."""
        v = self.value(src)
        res = self._displace(v, delta * k)
        pre = self._displace(v, delta * (k - 1))
        self.mig_top = self._mask_parity(pre, even=delta > 0)
        self.mig_bot = self._mask_parity(pre, even=delta < 0)
        if res is not TOP and v is not TOP:
            base, net = ((v.shift_base, v.shift_net)
                         if v.shift_base is not None else (v, 0))
            res.shift_base, res.shift_net = base, net + delta * k
            if emit is not None:
                if is_const(res) and not is_const(v):
                    emit("PIM401", op_index,
                         f"SHIFT chain (|k|={k}) shifts row {src} "
                         "entirely past the subarray boundary: the "
                         "result is constant 0")
                elif (res.shift_net == 0 and base is not TOP
                      and _eq_opt(res, base, self.lanes,
                                  self.max_inputs) is True):
                    emit("PIM403", op_index,
                         "SHIFT chain returns to net displacement 0 and "
                         "provably cancels (every displaced-off edge "
                         "lane was already 0)")
        self.env[dst] = res
        self.written.add(dst)

    def tra(self, a: int, b: int, c: int, *, op_index=None,
            emit=None) -> None:
        va, vb, vc = self.value(a), self.value(b), self.value(c)
        if emit is not None:
            for r1, r2, x, y in ((a, b, va, vb), (a, c, va, vc),
                                 (b, c, vb, vc)):
                if x is not TOP and _eq_opt(
                        x, y, self.lanes, self.max_inputs) is True:
                    emit("PIM402", op_index,
                         f"TRA operand rows {r1} and {r2} hold "
                         "symbolically equal values: MAJ degenerates to "
                         "the duplicated operand")
                    break
        m = self.maj(va, vb, vc)
        if (emit is not None and m is not TOP and is_const(m)
                and any(v is not TOP and not is_const(v)
                        for v in (va, vb, vc))):
            emit("PIM401", op_index,
                 "TRA computes a per-lane constant from non-constant "
                 "operands: the majority cancels its symbolic inputs")
        for r in (a, b, c):
            self.env[r] = m
            self.written.add(r)

    # -- op dispatch ----------------------------------------------------------
    def apply(self, op: ir.PimOp, payloads, *, op_index=None, emit=None,
              allow_remote: bool = False) -> None:
        kind = op.op
        if kind == ir.OP_ISSUE:
            return
        if kind in (ir.OP_ROWCLONE, ir.OP_DRA):
            self._write(op.b, self.value(op.a), op_index, emit)
        elif kind == ir.OP_COPY:
            if not ir.copy_is_local(op):
                if allow_remote:
                    self.value(op.a)     # local effect is the read only
                    return
                raise ValueError(
                    f"cross-subarray COPY to ({op.delta}, {op.c}) has no "
                    "single-subarray semantics; analyze per-slot streams "
                    "or route through the device scheduler")
            self._write(op.b, self.value(op.a), op_index, emit)
        elif kind == ir.OP_TRA:
            self.tra(op.a, op.b, op.c, op_index=op_index, emit=emit)
        elif kind == ir.OP_NOT2DCC:
            self.dcc = self.not_(self.value(op.a))
        elif kind == ir.OP_DCC2:
            v = self.dcc
            if emit is not None and v is not TOP and v.cancels:
                emit("PIM403", op_index,
                     "NOT of a NOT: this DCC2 materializes a value "
                     "identical to the one two NOTs ago")
            self._write(op.b, v, op_index, emit)
        elif kind == ir.OP_SHIFT:
            self.shift_chain(op.a, op.b, int(op.delta), 1,
                             op_index=op_index, emit=emit)
        elif kind == ir.OP_WRITE:
            v = _const_lanes(_row_to_lane_bits(payloads[op.payload]))
            self._write(op.b, v, op_index, emit)
        elif kind == ir.OP_READ:
            self.reads.append(self.value(op.a))
        elif kind == ir.OP_FILL:
            word = np.full((self.words,), op.payload & 0xFFFF_FFFF,
                           np.uint32)
            self._write(op.b, _const_lanes(_row_to_lane_bits(word)),
                        op_index, emit)
        else:
            raise ValueError(kind)


_SHIFT_C = ir.OP_CODE[ir.OP_SHIFT]


def _shift_run_ends(cols: ir.ProgramColumns) -> np.ndarray:
    """Columnar shift-chain detection (the ``compile._shift_runs``
    contract, duplicated so sem stays a numpy leaf): ``run_end[s]`` is
    one past the last op of the chain starting at ``s`` (-1 elsewhere)."""
    n = len(cols.table)
    code, a, b, delta = cols.code, cols.a, cols.b, cols.delta
    is_shift = code == _SHIFT_C
    cont = np.zeros(n, bool)
    if n > 1:
        cont[1:] = (is_shift[1:] & is_shift[:-1] & (a[1:] == b[1:])
                    & (b[1:] == b[:-1]) & (delta[1:] == delta[:-1]))
    run_end = np.full(n, -1, np.int64)
    starts = np.flatnonzero(is_shift & ~cont)
    if starts.size:
        breaks = np.flatnonzero(~cont)
        run_end[starts] = np.append(breaks, n)[
            np.searchsorted(breaks, starts, side="right")]
    return run_end


def _interpret(m: Analysis, program: ir.PimProgram, *, emit=None,
               allow_remote: bool = False) -> Analysis:
    """Drive the machine over the op stream. Maximal same-direction
    shift chains collapse to ONE abstract shift (a 100k-hop stream is a
    single ``np.roll``), so analysis stays sub-second at lint scale."""
    ops = program.ops
    n = len(ops)
    if n == 0:
        return m
    cols = program.columns
    run_end = _shift_run_ends(cols) if (cols.code == _SHIFT_C).any() \
        else None
    i = 0
    while i < n:
        op = ops[i]
        if op.op == ir.OP_SHIFT:
            j = int(run_end[i]) if run_end is not None else -1
            if j < 0:
                j = i + 1
            m.shift_chain(op.a, op.b, int(op.delta), j - i,
                          op_index=j - 1, emit=emit)
            i = j
            continue
        m.apply(op, program.payloads, op_index=i, emit=emit,
                allow_remote=allow_remote)
        i += 1
    return m


# ---------------------------------------------------------------------------
# analyze / summarize / findings (payload-CONTENT-keyed caches)
# ---------------------------------------------------------------------------

_SEM_CACHE: dict = {}
_SEM_CACHE_MAX = 256


def _cache_key(tag: str, program: ir.PimProgram, *extra):
    # Payload CONTENT digest, not shapes: HOSTW bits are constants in
    # this domain, so same-shape different-bits payloads must miss.
    return (tag, program.digest, program.payload_digest, program.num_rows,
            program.words) + extra


def _cache_put(key, val):
    if len(_SEM_CACHE) >= _SEM_CACHE_MAX:
        _SEM_CACHE.pop(next(iter(_SEM_CACHE)))
    _SEM_CACHE[key] = val
    return val


def _inputs_key(inputs, num_rows: int):
    return (None if inputs is None
            else frozenset(int(r) % num_rows for r in inputs))


def analyze(program: ir.PimProgram, *,
            max_inputs: int = DEFAULT_MAX_INPUTS,
            assume_control: bool = True, inputs=None) -> Analysis:
    """Abstractly interpret one stream; cached on the program digest plus
    the payload *content* digest (zero column-table rebuilds on warm
    hits). Cross-slot COPYs raise — analyze per-slot streams."""
    ik = _inputs_key(inputs, program.num_rows)
    key = _cache_key("analysis", program, max_inputs, assume_control, ik)
    hit = _SEM_CACHE.get(key)
    if hit is not None:
        SEM_STATS["analysis_hits"] += 1
        return hit
    SEM_STATS["analyses"] += 1
    m = Analysis(program.num_rows, program.words, max_inputs=max_inputs,
                 assume_control=assume_control, inputs=ik)
    _interpret(m, program)
    return _cache_put(key, m)


def semantic_findings(program: ir.PimProgram, *,
                      max_inputs: int = DEFAULT_MAX_INPUTS,
                      assume_control: bool = True) -> tuple:
    """The PIM4xx findings of one stream as ``(code, op_index, message)``
    tuples (per-code capped). Best-effort: a stream the machine cannot
    interpret (malformed payload references, out-of-range operands)
    yields no findings — the structural lint tier owns those errors.
    Cross-slot COPYs are skipped (their write lands in another slot)."""
    key = _cache_key("findings", program, max_inputs, assume_control)
    hit = _SEM_CACHE.get(key)
    if hit is not None:
        SEM_STATS["analysis_hits"] += 1
        return hit
    SEM_STATS["analyses"] += 1
    found: list = []
    counts: dict = {}

    def emit(code, op_index, message):
        n = counts.get(code, 0)
        counts[code] = n + 1
        if n < _MAX_FINDINGS:
            found.append((code, None if op_index is None else int(op_index),
                          message))

    m = Analysis(program.num_rows, program.words, max_inputs=max_inputs,
                 assume_control=assume_control)
    try:
        _interpret(m, program, emit=emit, allow_remote=True)
    except Exception:
        return _cache_put(key, ())
    return _cache_put(key, tuple(found))


# ---------------------------------------------------------------------------
# Closed-form rendering (summarize)
# ---------------------------------------------------------------------------

def _atom(pair: tuple, parens: bool = True) -> str:
    r, d = pair
    if d == 0:
        return f"r{r}"
    body = f"r{r} << {d}" if d > 0 else f"r{r} >> {-d}"
    return f"({body})" if parens else body


@functools.lru_cache(maxsize=256)
def _var_tt(p: int, k: int) -> np.ndarray:
    w = _n_words(k)
    if p < 6:
        return np.full(w, _VAR_WORDS[p], np.uint64)
    on = ((np.arange(w) >> (p - 6)) & 1) == 1
    return np.where(on, _ONES, np.uint64(0)).astype(np.uint64)


def _popcount_period(row: np.ndarray, k: int) -> tuple[int, int]:
    """(#ON assignments, index of the first ON) within one 2^k period."""
    if k < 6:
        word = int(row[0]) & ((1 << (1 << k)) - 1)
        return word.bit_count(), ((word & -word).bit_length() - 1
                                  if word else -1)
    total, first = 0, -1
    for wi, w in enumerate(row):
        w = int(w)
        total += w.bit_count()
        if first < 0 and w:
            first = wi * 64 + (w & -w).bit_length() - 1
    return total, first


def _render_row(row: np.ndarray, sup: tuple) -> str:
    k = len(sup)
    if k == 0:
        return "1" if row.any() else "0"
    names = [_atom(v) for v in sup]
    if k > 8:
        return f"fn({', '.join(names)})"
    if k == 1:
        if np.array_equal(row, _var_tt(0, 1)):
            return names[0]
        return f"~{names[0]}"
    parity = _var_tt(0, k).copy()
    for p in range(1, k):
        parity ^= _var_tt(p, k)
    if np.array_equal(row, parity):
        return " ^ ".join(names)
    if np.array_equal(row, ~parity):
        return f"~({' ^ '.join(names)})"
    if k == 3:
        v0, v1, v2 = (_var_tt(p, 3) for p in range(3))
        if np.array_equal(row, (v0 & v1) | (v0 & v2) | (v1 & v2)):
            return f"maj({', '.join(names)})"
    on, first_on = _popcount_period(row, k)
    period = 1 << k
    if on == 1:                                     # AND of literals
        lits = [names[i] if (first_on >> i) & 1 else f"~{names[i]}"
                for i in range(k)]
        return " & ".join(lits)
    if on == period - 1:                            # OR of literals
        _, first_off = _popcount_period(~row, k)
        lits = [f"~{names[i]}" if (first_off >> i) & 1 else names[i]
                for i in range(k)]
        return " | ".join(lits)
    if k <= 4 and on <= 8:                          # small DNF
        terms = []
        for j in range(period):
            if k < 6:
                bit = (int(row[0]) >> j) & 1
            else:
                bit = (int(row[j // 64]) >> (j % 64)) & 1
            if bit:
                lits = [names[i] if (j >> i) & 1 else f"~{names[i]}"
                        for i in range(k)]
                terms.append("(" + " & ".join(lits) + ")")
        return " | ".join(terms)
    return f"fn({', '.join(names)})"


def render_value(v) -> str:
    """Closed-form boolean expression of one abstract value. Lanes that
    disagree with the dominant pattern (boundary fill) are counted in a
    trailing annotation."""
    if v is TOP:
        return "TOP"
    sv = _shrink(v)
    patterns, counts = np.unique(sv.tt, axis=0, return_counts=True)
    main = patterns[int(np.argmax(counts))]
    expr = _render_row(main, sv.sup)
    n_edge = sv.tt.shape[0] - int(counts.max())
    if n_edge:
        expr += f" [{n_edge} boundary lane(s) differ]"
    return expr


def summarize(program: ir.PimProgram, *, rows=None,
              max_inputs: int = DEFAULT_MAX_INPUTS,
              assume_control: bool = True, inputs=None) -> dict:
    """Per-row closed-form expression of every written row (or of the
    explicit ``rows``) in terms of the named symbolic input rows."""
    m = analyze(program, max_inputs=max_inputs,
                assume_control=assume_control, inputs=inputs)
    targets = sorted(m.written) if rows is None else \
        [int(r) % m.num_rows for r in rows]
    return {r: render_value(m.value(r)) for r in targets}


# ---------------------------------------------------------------------------
# Equivalence proving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Witness:
    """A concrete distinguishing input assignment: set each row of
    ``rows`` into a fresh subarray (C1 seeded when ``assume_control``),
    run both programs eagerly, and the ``kind``/``index`` component
    differs. ``lane`` is the bit lane the static proof found."""

    kind: str                    # row | read | reads_len | dcc | mig_top |
    index: int | None            # mig_bot; row index / read slot
    lane: int | None
    rows: dict
    num_rows: int
    words: int
    assume_control: bool

    def as_bits(self) -> np.ndarray:
        bits = np.zeros((self.num_rows, self.words), np.uint32)
        for r, row in self.rows.items():
            bits[r] = row
        if self.assume_control:
            bits[int(isa.C1) % self.num_rows] = 0xFFFF_FFFF
            bits[int(isa.C0) % self.num_rows] = 0
        return bits


@dataclasses.dataclass(frozen=True)
class EquivReport:
    """Outcome of one equivalence proof. ``verdict`` is EQUIVALENT /
    DIFFERENT / UNKNOWN; DIFFERENT carries the ``witness`` and the
    ``component`` it distinguishes; UNKNOWN lists the components whose
    values hit TOP or the truth-table budget."""

    verdict: str
    witness: Witness | None = None
    component: str | None = None
    unknown: tuple = ()

    @property
    def ok(self) -> bool:
        return self.verdict == EQUIVALENT


class EquivalenceError(ValueError):
    """Raised by ``verify_fusion`` when fused != unfused (or unprovable)."""

    def __init__(self, report: EquivReport, what: str = "fusion"):
        self.report = report
        detail = (f"differs at {report.component}"
                  if report.verdict == DIFFERENT else
                  f"unprovable ({', '.join(report.unknown)} exceeded the "
                  "symbolic budget)")
        super().__init__(f"pimsem: {what} equivalence failed: "
                         f"{report.verdict} — {detail}")


def _witness_from(ma: Analysis, kind: str, index, lane, j, sup) -> Witness:
    rows: dict = {}
    if sup:
        for i, (r, dsp) in enumerate(sup):
            if (j >> i) & 1:
                pos = lane - dsp
                # Out-of-range references carry zero dependence at this
                # lane (the soundness invariant), so dropping the bit
                # preserves the difference.
                if 0 <= pos < ma.lanes:
                    row = rows.setdefault(r, np.zeros(ma.words, np.uint32))
                    row[pos // 32] |= np.uint32(1 << (pos % 32))
    return Witness(kind=kind, index=index, lane=lane, rows=rows,
                   num_rows=ma.num_rows, words=ma.words,
                   assume_control=ma.assume_control)


def _compare_analyses(ma: Analysis, mb: Analysis, outputs,
                      max_inputs: int) -> EquivReport:
    if len(ma.reads) != len(mb.reads):
        return EquivReport(
            verdict=DIFFERENT, component="number of host reads",
            witness=Witness(kind="reads_len", index=None, lane=None,
                            rows={}, num_rows=ma.num_rows, words=ma.words,
                            assume_control=ma.assume_control))
    comps: list = []
    if outputs is None:
        rows = sorted(ma.written | mb.written)
    else:
        rows = sorted({int(r) % ma.num_rows for r in outputs})
    comps += [("row", r, ma.value(r), mb.value(r)) for r in rows]
    comps += [("read", i, va, vb)
              for i, (va, vb) in enumerate(zip(ma.reads, mb.reads))]
    if outputs is None:
        comps += [("dcc", None, ma.dcc, mb.dcc),
                  ("mig_top", None, ma.mig_top, mb.mig_top),
                  ("mig_bot", None, ma.mig_bot, mb.mig_bot)]
    unknown: list = []
    for kind, index, va, vb in comps:
        name = kind if index is None else f"{kind} {index}"
        verdict, lane, j, sup = _diff(va, vb, ma.lanes, max_inputs)
        if verdict == "ne":
            return EquivReport(
                verdict=DIFFERENT, component=name,
                witness=_witness_from(ma, kind, index, lane, j, sup))
        if verdict == "unknown":
            unknown.append(name)
    if unknown:
        return EquivReport(verdict=UNKNOWN, unknown=tuple(unknown))
    return EquivReport(verdict=EQUIVALENT)


def prove_equivalent(a: ir.PimProgram, b: ir.PimProgram, *, inputs=None,
                     outputs=None, max_inputs: int = DEFAULT_MAX_INPUTS,
                     assume_control: bool = True) -> EquivReport:
    """Statically prove two same-shape programs equivalent.

    The contract is sound by construction: EQUIVALENT is only returned
    when every compared component's truth tables match exactly over the
    union support (never from an approximation), and every DIFFERENT
    verdict ships a :class:`Witness` whose assignment provably
    distinguishes the programs under ``isa.run_program`` (replay it with
    :func:`check_witness`). Anything the domain cannot decide — a value
    past the ``max_inputs``/table budget — is UNKNOWN, never EQUIVALENT.

    ``inputs`` restricts which rows are symbolic (others start constant
    0, matching a fresh subarray); ``outputs`` restricts the compared
    rows (default: every written row, the host-read values, and the
    DCC/migration side state)."""
    if (a.num_rows, a.words) != (b.num_rows, b.words):
        raise ValueError(
            f"cannot compare programs of different subarray shapes "
            f"{(a.num_rows, a.words)} vs {(b.num_rows, b.words)}")
    ik = _inputs_key(inputs, a.num_rows)
    ok = None if outputs is None else \
        tuple(sorted(int(r) % a.num_rows for r in outputs))
    key = ("prove", a.digest, a.payload_digest, b.digest, b.payload_digest,
           a.num_rows, a.words, ik, ok, max_inputs, assume_control)
    hit = _SEM_CACHE.get(key)
    if hit is not None:
        SEM_STATS["proof_hits"] += 1
        return hit
    SEM_STATS["proofs"] += 1
    ma = analyze(a, max_inputs=max_inputs, assume_control=assume_control,
                 inputs=ik)
    mb = analyze(b, max_inputs=max_inputs, assume_control=assume_control,
                 inputs=ik)
    return _cache_put(key, _compare_analyses(ma, mb, ok, max_inputs))


def check_witness(a: ir.PimProgram, b: ir.PimProgram, witness: Witness,
                  cfg: DDR3Timing = DEFAULT_TIMING) -> bool:
    """Execute both programs eagerly on the witness assignment and return
    True iff the claimed component really differs (the DIFFERENT
    contract's replay check)."""
    sa, reads_a = isa.run_on_bits(a, witness.as_bits(),
                                  control=witness.assume_control, cfg=cfg)
    sb, reads_b = isa.run_on_bits(b, witness.as_bits(),
                                  control=witness.assume_control, cfg=cfg)
    if witness.kind == "reads_len":
        return len(reads_a) != len(reads_b)
    if witness.kind == "read":
        return not np.array_equal(np.asarray(reads_a[witness.index]),
                                  np.asarray(reads_b[witness.index]))
    if witness.kind == "row":
        return not np.array_equal(np.asarray(sa.bits[witness.index]),
                                  np.asarray(sb.bits[witness.index]))
    assert witness.kind in ("dcc", "mig_top", "mig_bot"), witness.kind
    return not np.array_equal(np.asarray(getattr(sa, witness.kind)),
                              np.asarray(getattr(sb, witness.kind)))


# ---------------------------------------------------------------------------
# Fusion verification (the compile.fuse verify_semantics gate)
# ---------------------------------------------------------------------------

def _interpret_segments(m: Analysis, program: ir.PimProgram,
                        segments) -> Analysis:
    """Abstractly execute a fused segment list with the exact semantics
    of ``exec._run_segments`` (incl. SegMaj's scratch writes and
    SegShiftRun's migration-row side state)."""
    from . import compile as pim_compile
    t0, t1, t2 = (int(t) % m.num_rows for t in (isa.T0, isa.T1, isa.T2))
    for seg in segments:
        if isinstance(seg, pim_compile.SegShiftRun):
            m.shift_chain(seg.src, seg.dst, int(seg.delta), int(seg.k))
        elif isinstance(seg, pim_compile.SegMaj):
            mj = m.maj(m.value(seg.a), m.value(seg.b), m.value(seg.c))
            for r in (t0, t1, t2, seg.dst):
                m.env[r] = mj
                m.written.add(r)
        elif isinstance(seg, pim_compile.SegNot):
            nv = m.not_(m.value(seg.src))
            m.dcc = nv
            m.env[seg.dst] = nv
            m.written.add(seg.dst)
        elif isinstance(seg, pim_compile.SegScan):
            for op in seg.ops:
                m.apply(op, program.payloads)
        elif isinstance(seg, pim_compile.SegHost):
            m.apply(seg.op, program.payloads)
        else:
            raise TypeError(seg)
    return m


def fusion_report(program: ir.PimProgram, segments=None, *,
                  max_inputs: int = DEFAULT_MAX_INPUTS,
                  assume_control: bool = True) -> EquivReport:
    """Prove the fused segment list (``compile.fuse(program)`` when not
    given) abstractly equivalent to the unfused op stream — full state:
    written rows, host reads, DCC and migration rows."""
    from . import compile as pim_compile
    if segments is None:
        segments = pim_compile.fuse(program)
    segments = tuple(segments)
    key = _cache_key("fusion", program, max_inputs, assume_control,
                     segments)
    hit = _SEM_CACHE.get(key)
    if hit is not None:
        SEM_STATS["proof_hits"] += 1
        return hit
    SEM_STATS["proofs"] += 1
    ma = analyze(program, max_inputs=max_inputs,
                 assume_control=assume_control)
    mb = Analysis(program.num_rows, program.words, max_inputs=max_inputs,
                  assume_control=assume_control)
    _interpret_segments(mb, program, segments)
    return _cache_put(key, _compare_analyses(ma, mb, None, max_inputs))


def verify_fusion(program: ir.PimProgram, segments=None, *,
                  max_inputs: int = DEFAULT_MAX_INPUTS,
                  assume_control: bool = True) -> EquivReport:
    """``fusion_report`` that RAISES :class:`EquivalenceError` unless the
    fused form is *provably* equivalent (UNKNOWN also raises: the gate
    promises a proof, not an absence of counterexamples)."""
    report = fusion_report(program, segments, max_inputs=max_inputs,
                           assume_control=assume_control)
    if report.verdict != EQUIVALENT:
        raise EquivalenceError(report)
    return report
