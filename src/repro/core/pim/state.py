"""Subarray / bank state for the in-DRAM PIM runtime.

The paper's subarray is modeled functionally:

- ``bits``    : (num_rows, words) uint32 — the data rows. Column ``c`` of the
  8KB row (65,536 bitlines) lives at bit ``c % 32`` (little-endian) of word
  ``c // 32``. Horizontal layout is preserved — this is the paper's key
  property (no transposition).
- ``mig_top`` : (words,) uint32 — migration-cell row at the top of the
  subarray. Each migration cell is shared between bitline pair ``(2k, 2k+1)``.
- ``mig_bot`` : (words,) uint32 — migration-cell row at the bottom, staggered
  pairing ``(2k+1, 2k+2)``.
- ``dcc``     : (words,) uint32 — dual-contact-cell row (Ambit NOT).
- ``meter``   : cost meter advanced by every command (DDR3-1333 model).

Everything is a registered dataclass pytree so whole PIM programs jit, vmap
(banks) and shard (channels/ranks) like any other JAX computation.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# Paper/NVMain configuration: 8KB row buffer = 65,536 bitlines; 512 rows.
ROW_BITS = 65_536
WORD_BITS = 32
ROW_WORDS = ROW_BITS // WORD_BITS  # 2048
NUM_ROWS = 512

# Parity masks in little-endian bit order: even columns sit at bits 0,2,4,...
EVEN_MASK = jnp.uint32(0x5555_5555)
ODD_MASK = jnp.uint32(0xAAAA_AAAA)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "time_ns", "e_act", "e_pre", "e_refresh", "e_burst", "e_background",
        "n_act", "n_pre", "n_aap", "n_shift", "n_tra", "n_refresh",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class CostMeter:
    """DDR3-1333 time/energy accounting (ns / nJ), advanced per command."""

    time_ns: jax.Array
    e_act: jax.Array
    e_pre: jax.Array
    e_refresh: jax.Array
    e_burst: jax.Array
    e_background: jax.Array
    n_act: jax.Array
    n_pre: jax.Array
    n_aap: jax.Array
    n_shift: jax.Array
    n_tra: jax.Array
    n_refresh: jax.Array

    @staticmethod
    def zeros() -> "CostMeter":
        z = jnp.zeros((), jnp.float32)
        zi = jnp.zeros((), jnp.int32)
        return CostMeter(
            time_ns=z, e_act=z, e_pre=z, e_refresh=z, e_burst=z,
            e_background=z, n_act=zi, n_pre=zi, n_aap=zi, n_shift=zi,
            n_tra=zi, n_refresh=zi,
        )

    @property
    def total_energy_nj(self) -> jax.Array:
        return (self.e_act + self.e_pre + self.e_refresh + self.e_burst
                + self.e_background)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bits", "mig_top", "mig_bot", "dcc", "meter"],
    meta_fields=[],
)
@dataclasses.dataclass
class SubarrayState:
    """One open-bitline subarray with the paper's two migration rows."""

    bits: jax.Array      # (num_rows, words) uint32
    mig_top: jax.Array   # (words,) uint32
    mig_bot: jax.Array   # (words,) uint32
    dcc: jax.Array       # (words,) uint32
    meter: CostMeter

    @property
    def num_rows(self) -> int:
        return self.bits.shape[-2]

    @property
    def words(self) -> int:
        return self.bits.shape[-1]


def make_subarray(num_rows: int = NUM_ROWS, words: int = ROW_WORDS,
                  bits: jax.Array | None = None) -> SubarrayState:
    if bits is None:
        bits = jnp.zeros((num_rows, words), jnp.uint32)
    else:
        bits = jnp.asarray(bits, jnp.uint32)
        assert bits.shape == (num_rows, words), (bits.shape, num_rows, words)
    zrow = jnp.zeros((words,), jnp.uint32)
    return SubarrayState(bits=bits, mig_top=zrow, mig_bot=zrow, dcc=zrow,
                         meter=CostMeter.zeros())


def make_bank(num_subarrays: int, num_rows: int = NUM_ROWS,
              words: int = ROW_WORDS) -> SubarrayState:
    """A bank is a stacked (vmap-able) batch of subarrays."""
    return jax.vmap(lambda _: make_subarray(num_rows, words))(
        jnp.arange(num_subarrays))
