"""DDR3-1333 timing & energy model (NVMain-equivalent, calibrated to paper).

The paper configures NVMain as Micron DDR3-1333 4Gb, 8 banks/rank,
2 ranks/channel, 2 channels, 512-row subarrays, 8KB row buffer, and reports
(Tables 2-3):

    single shift  : 208.7 ns, 31.321 nJ (30.24 nJ active)
    energy / ACT  : 30.24 / 8 = 3.78 nJ  (4 AAP = 8 ACTs per shift)
    AAP latency   : ~49.5 ns  (tRAS + tRP, matches Ambit's ~49 ns)
    refresh       : tREFI = 7.8 us, ~80 nJ + tRFC stall per event

We model each command's time/energy from first principles with DDR3-1333
datasheet constants, calibrated so the paper's Tables 2/3 reproduce within a
few percent (benchmarks print model-vs-paper errors; tests gate at 5%).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .state import CostMeter


@dataclasses.dataclass(frozen=True)
class DDR3Timing:
    """All times ns, energies nJ, powers mW (nJ/ns = W; mW = 1e-6 nJ/ns)."""

    tCK: float = 1.5            # DDR3-1333 clock (667 MHz)
    tRCD: float = 13.5
    tRP: float = 13.5
    tRAS: float = 36.0
    tRC: float = 49.5           # tRAS + tRP
    tREFI: float = 7_800.0      # refresh interval
    tRFC: float = 260.0         # refresh cycle, 4Gb DDR3
    tRTRS: float = 3.0          # rank-to-rank switch (2 tCK bus turnaround)
    t_issue: float = 10.5       # command-bus issue overhead per op burst (7 tCK)

    # Energy. E_ACT covers one full-row (8KB) activation + restore.
    e_act: float = 3.78         # nJ / ACT   (paper: 30.24 nJ / 8 ACTs)
    e_pre: float = 0.25         # nJ / PRE
    e_ref: float = 80.0         # nJ / refresh event (paper: 77.1-96.4)
    e_burst_per_64b: float = 12.5   # nJ / 64B off-chip transfer (paper ~10-15)
    p_background: float = 0.39e-6   # nJ/ns standby power within the bank
    # Multi-row activation: k simultaneously-raised rows share one bitline
    # swing but restore k cells. Extra restore energy per extra row:
    e_act_extra_row: float = 1.2    # nJ / additional row in DRA/TRA
    # LISA-style in-DRAM row movement. An inter-subarray COPY activates the
    # source row, links neighboring row buffers (RBM) one hop at a time, and
    # restores into the destination; each hop adds link latency/energy. An
    # inter-bank COPY instead crosses the chip's shared internal I/O bus
    # (RowClone's inter-bank mode): a fixed extra latency/energy, still far
    # below the two off-chip bursts a host round-trip would cost.
    t_rbm: float = 8.0              # ns / inter-subarray link hop (LISA RBM)
    e_rbm: float = 0.2              # nJ / link hop
    t_copy_bank: float = 99.0       # ns inter-bank internal-bus transfer (2 tRC)
    e_copy_bank: float = 11.0       # nJ / inter-bank row transfer

    @property
    def t_aap(self) -> float:
        return self.tRAS + self.tRP  # ACT-ACT-PRE: second ACT overlaps restore

    @property
    def t_shift(self) -> float:
        return 4.0 * self.t_aap      # the paper's 4-AAP shift


DEFAULT_TIMING = DDR3Timing()


def _bump(meter: CostMeter, *, dt: float, e_act: float = 0.0,
          e_pre: float = 0.0, n_act: int = 0, n_pre: int = 0,
          n_aap: int = 0, n_shift: int = 0, n_tra: int = 0,
          cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """Advance the meter by one command, folding in background power."""
    dt = jnp.float32(dt)
    return CostMeter(
        time_ns=meter.time_ns + dt,
        e_act=meter.e_act + jnp.float32(e_act),
        e_pre=meter.e_pre + jnp.float32(e_pre),
        e_refresh=meter.e_refresh,
        e_burst=meter.e_burst,
        e_background=meter.e_background + dt * jnp.float32(cfg.p_background),
        n_act=meter.n_act + n_act,
        n_pre=meter.n_pre + n_pre,
        n_aap=meter.n_aap + n_aap,
        n_shift=meter.n_shift + n_shift,
        n_tra=meter.n_tra + n_tra,
        n_refresh=meter.n_refresh,
    )


def charge_aap(meter: CostMeter, cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """ACT-ACT-PRE (RowClone intra-subarray copy): 2 activations, 1 precharge."""
    return _bump(meter, dt=cfg.t_aap, e_act=2 * cfg.e_act, e_pre=cfg.e_pre,
                 n_act=2, n_pre=1, n_aap=1, cfg=cfg)


def charge_mra(meter: CostMeter, k_rows: int,
               cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """Multi-row activation (DRA k=2 / TRA k=3) + PRE."""
    e = cfg.e_act + (k_rows - 1) * cfg.e_act_extra_row
    return _bump(meter, dt=cfg.tRC, e_act=e, e_pre=cfg.e_pre,
                 n_act=1, n_pre=1, n_tra=int(k_rows == 3), cfg=cfg)


def charge_shift(meter: CostMeter,
                 cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """One full-row 1-bit shift = 4 AAPs (the paper's primitive)."""
    m = meter
    for _ in range(4):
        m = charge_aap(m, cfg)
    return CostMeter(
        time_ns=m.time_ns, e_act=m.e_act, e_pre=m.e_pre,
        e_refresh=m.e_refresh, e_burst=m.e_burst,
        e_background=m.e_background, n_act=m.n_act, n_pre=m.n_pre,
        n_aap=m.n_aap, n_shift=m.n_shift + 1, n_tra=m.n_tra,
        n_refresh=m.n_refresh,
    )


def copy_cost(hops: int = 0, inter_bank: bool = False,
              cfg: DDR3Timing = DEFAULT_TIMING):
    """(dt_ns, e_act, e_pre, n_act, n_pre, n_aap) of one LISA COPY.

    ``hops`` inter-subarray link hops inside one bank; ``inter_bank`` routes
    over the shared internal bus instead. ``hops=0`` without ``inter_bank``
    degenerates to exactly one AAP — a distance-0 LISA copy *is* RowClone.
    """
    dt = cfg.t_aap + hops * cfg.t_rbm + (cfg.t_copy_bank if inter_bank
                                         else 0.0)
    e_act = 2 * cfg.e_act + hops * cfg.e_rbm + (cfg.e_copy_bank if inter_bank
                                                else 0.0)
    return dt, e_act, cfg.e_pre, 2, 1, 1


def charge_copy(meter: CostMeter, hops: int = 0, inter_bank: bool = False,
                cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """LISA row movement: source activation + RBM hops (+ internal bus)."""
    dt, e_act, e_pre, n_act, n_pre, n_aap = copy_cost(hops, inter_bank, cfg)
    return _bump(meter, dt=dt, e_act=e_act, e_pre=e_pre, n_act=n_act,
                 n_pre=n_pre, n_aap=n_aap, cfg=cfg)


def charge_issue(meter: CostMeter,
                 cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """One-time command-bus issue overhead for a burst of PIM commands."""
    return _bump(meter, dt=cfg.t_issue, cfg=cfg)


def refresh_events(busy, cfg: DDR3Timing = DEFAULT_TIMING):
    """Refresh events owed for ``busy`` ns of stall-free work: the true
    fixed point of  n = floor((busy + n·tRFC) / tREFI).

    Each event's tRFC stall extends the wall clock, which can cross further
    tREFI boundaries — on multi-millisecond streams the cascade crosses more
    than one, so a single re-count undercounts. The count is iterated to
    convergence (monotone, so the loop reaches the least fixed point — the
    same n a step-by-step tREFI walk produces); element-wise on arrays.
    """
    busy = jnp.asarray(busy, jnp.float32)

    def recount(k):
        return jnp.floor((busy + k.astype(jnp.float32) * cfg.tRFC)
                         / cfg.tREFI).astype(jnp.int32)

    n0 = jnp.floor(busy / cfg.tREFI).astype(jnp.int32)
    _, n = jax.lax.while_loop(
        lambda c: jnp.any(c[1] > c[0]),
        lambda c: (c[1], recount(c[1])),
        (jnp.full_like(n0, -1), n0))
    return n


def refresh_events_scalar(busy_ns: float,
                          cfg: DDR3Timing = DEFAULT_TIMING) -> int:
    """Python-scalar counterpart of :func:`refresh_events` for the
    closed-form float64 planners (``compile.cost_summary``,
    ``program.estimate_cost``): same least fixed point, no tracing."""
    n = int(busy_ns // cfg.tREFI)
    while int((busy_ns + n * cfg.tRFC) // cfg.tREFI) > n:
        n = int((busy_ns + n * cfg.tRFC) // cfg.tREFI)
    return n


def apply_refresh(meter: CostMeter,
                  cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """Fold in periodic refresh for the elapsed busy time — incrementally.

    NVMain interleaves REF every tREFI; we post-process: the meter owes
    n refresh events in total (the ``refresh_events`` fixed point: stalls
    extend wall time past further tREFI boundaries), each adding tRFC stall
    and e_ref energy. ``busy`` is the meter's wall time with previously
    charged refresh stalls stripped (``n_refresh`` events × tRFC), and only
    the events *not yet charged* are added — so repeated application on an
    accumulating meter (e.g. back-to-back refreshed ``schedule()`` calls on
    one device) counts every event exactly once instead of re-charging the
    whole history per call. On a never-refreshed meter whose stalls cross at
    most one extra boundary this reduces to the old single-re-count formula
    bit-for-bit.
    """
    prior = meter.n_refresh.astype(jnp.float32)
    busy = meter.time_ns - prior * cfg.tRFC
    n = refresh_events(busy, cfg)
    new = jnp.maximum(n - meter.n_refresh, 0)
    return CostMeter(
        time_ns=meter.time_ns + new * cfg.tRFC,
        e_act=meter.e_act, e_pre=meter.e_pre,
        e_refresh=meter.e_refresh + new.astype(jnp.float32) * cfg.e_ref,
        e_burst=meter.e_burst,
        e_background=meter.e_background
        + new.astype(jnp.float32) * cfg.tRFC * jnp.float32(cfg.p_background),
        n_act=meter.n_act, n_pre=meter.n_pre, n_aap=meter.n_aap,
        n_shift=meter.n_shift, n_tra=meter.n_tra,
        n_refresh=meter.n_refresh + new,
    )


def burst_time_ns(num_bytes: int, cfg: DDR3Timing = DEFAULT_TIMING) -> float:
    """Wall time of one off-chip HOSTW/HOSTR transfer: an ACT+PRE row access
    plus the data beats. DDR3-1333: 64B burst = 8 beats of 8B at 0.75
    ns/beat. This whole window occupies the slot's channel (command +
    data bus), so the device model serializes it per channel."""
    transfers = -(-num_bytes // 64)
    return cfg.tRC + transfers * 6.0


def charge_burst(meter: CostMeter, num_bytes: int,
                 cfg: DDR3Timing = DEFAULT_TIMING) -> CostMeter:
    """Off-chip data transfer: one ACT+PRE plus burst energy+time."""
    transfers = -(-num_bytes // 64)
    dt = burst_time_ns(num_bytes, cfg)
    m = _bump(meter, dt=dt, e_act=cfg.e_act, e_pre=cfg.e_pre,
              n_act=1, n_pre=1, cfg=cfg)
    return CostMeter(
        time_ns=m.time_ns, e_act=m.e_act, e_pre=m.e_pre,
        e_refresh=m.e_refresh,
        e_burst=m.e_burst + jnp.float32(transfers * cfg.e_burst_per_64b),
        e_background=m.e_background, n_act=m.n_act, n_pre=m.n_pre,
        n_aap=m.n_aap, n_shift=m.n_shift, n_tra=m.n_tra,
        n_refresh=m.n_refresh,
    )


def cpu_movement_energy_nj(num_bytes: int,
                           cfg: DDR3Timing = DEFAULT_TIMING) -> float:
    """Conventional path (paper §5.1.5): read row to CPU + write back."""
    transfers = -(-num_bytes // 64)
    return 2.0 * transfers * cfg.e_burst_per_64b
