"""Monte-Carlo process-variation reliability model (paper §5.2, Table 4).

The paper's LTSPICE study perturbs cell capacitance, transistor W/L, and
bitline/wordline RC by a uniform ±p% and reports the fraction of 100,000
trials in which the 4-AAP shift fails. We model the same physics analytically
(and vectorize the Monte Carlo in JAX):

Charge sharing at each activation develops a bitline swing

    dV = (Vdd/2) * Cc / (Cc + Cbl) * f_transfer

where f_transfer = 1 - exp(-t_share / (Ron * Cser)) captures incomplete
transfer through the access transistor within the allotted tRCD window (a
migration cell drives its *partner* bitline through the second port, so its
series resistance matters twice). The sense amplifier resolves correctly when
dV exceeds its input offset, modeled as N(0, sigma_sa) plus a fixed margin.
One shift = 4 AAPs = 8 sensing events; the shift fails if ANY event fails.

Constants are 22nm values from the paper's Table 1 (Vdd=1.2 V, Cc=25 fF,
BL C/cell=0.24 fF, 512 cells/bitline) with the sense-margin/transfer constants
calibrated once so the model reproduces Table 4 at the paper's variation
levels; the benchmark prints model vs paper side by side.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Tech22nm:
    vdd: float = 1.2
    wl_boost: float = 2.5
    c_cell_f: float = 25e-15
    cells_per_bl: int = 512
    c_bl_per_cell_f: float = 0.24e-15
    r_bl_per_cell: float = 0.120          # ohm (120 mohm)
    access_w: float = 44e-9
    access_l: float = 22e-9
    t_share_s: float = 13.5e-9            # tRCD window for charge sharing
    # Calibrated sensing constants (see module docstring):
    sa_sigma_v: float = 0.02              # sense-amp offset spread scale
    sa_sigma_sat: float = 0.06            # mismatch saturation level (tanh)
    sa_margin_v: float = 0.055            # deterministic margin requirement
    param_sigma_frac: float = 0.5         # +-p% read as a 2-sigma bound
    r_on_nominal: float = 8.0e3           # access-transistor on resistance


TECH22 = Tech22nm()
SENSE_EVENTS_PER_SHIFT = 8  # 4 AAPs x 2 activations


def _sense_margin(u: jax.Array, tech: Tech22nm) -> jax.Array:
    """Per-event margin given uniform(-1,1) parameter draws u[..., 0:5].

    u slots: 0=cell cap, 1=bitline cap, 2=transistor W (conductance),
             3=transistor L (conductance, inverse), 4=threshold/overdrive.
    Scaled outside by the variation level p.
    """
    cc = tech.c_cell_f * (1.0 + u[..., 0])
    cbl = tech.cells_per_bl * tech.c_bl_per_cell_f * (1.0 + u[..., 1])
    # Conductance g ~ W/L * overdrive; Ron = 1/g.
    g_rel = (1.0 + u[..., 2]) / (1.0 + u[..., 3]) * (1.0 + 0.8 * u[..., 4])
    r_on = tech.r_on_nominal / jnp.maximum(g_rel, 1e-3)
    # Migration cell drives through TWO access ports in series.
    tau = 2.0 * r_on * (cc * cbl / (cc + cbl))
    f_transfer = 1.0 - jnp.exp(-tech.t_share_s / tau)
    dv = 0.5 * tech.vdd * cc / (cc + cbl) * f_transfer
    return dv - tech.sa_margin_v


@functools.partial(jax.jit, static_argnames=("n_trials", "tech"))
def shift_failure_rate(key: jax.Array, variation_pct: float,
                       n_trials: int = 100_000,
                       tech: Tech22nm = TECH22) -> jax.Array:
    """Fraction of Monte-Carlo trials in which a full shift fails.

    Each trial draws independent parameter sets for the 8 sensing events of
    one 4-AAP shift plus a per-event sense-amp offset; the shift fails if any
    event's margin falls below its offset.
    """
    p = variation_pct / 100.0
    ku, ko = jax.random.split(key)
    # +-p% is read as a k-sigma bound (industry convention for corner specs).
    u = (p * tech.param_sigma_frac) * jax.random.normal(
        ku, (n_trials, SENSE_EVENTS_PER_SHIFT, 5))
    margin = _sense_margin(u, tech)
    # Offset spread grows with local mismatch but saturates: beyond a point
    # the dominant mismatch sources (Vth pairs in the SA) are fully expressed.
    sigma = tech.sa_sigma_v * jnp.tanh(p / tech.sa_sigma_sat)
    offset = sigma * jax.random.normal(ko, (n_trials, SENSE_EVENTS_PER_SHIFT))
    event_fail = margin < jnp.abs(offset)
    return jnp.mean(jnp.any(event_fail, axis=-1))


PAPER_TABLE4 = {0.0: 0.0, 5.0: 0.005, 10.0: 0.14, 20.0: 0.30}
