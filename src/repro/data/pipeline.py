"""Batch pipeline: packing, label shifting, modality stubs, host prefetch.

``make_batch(cfg, shape, step)`` is a pure function of the step index, so the
pipeline is trivially resumable after restart (fault tolerance: the loader
has no state to checkpoint beyond the step counter) and identical across
hosts — each host materializes only its shard when ``lo/hi`` are given.

``Prefetcher`` overlaps host batch construction with device compute by one
step (double buffering).
"""
from __future__ import annotations

import threading
from queue import Queue

import numpy as np

from .synthetic import SyntheticTokens


def make_batch(cfg, *, batch: int, seq: int, step: int, seed: int = 0,
               lo: int = 0, hi: int | None = None):
    """Global batch [lo, hi) rows for one step (hi=None → full batch)."""
    hi = batch if hi is None else hi
    rows = hi - lo
    stream = SyntheticTokens(cfg.vocab_size, seed=seed)
    out_tokens = np.zeros((rows, seq + 1), np.int32)
    for r in range(rows):
        gidx = step * batch + lo + r
        out_tokens[r] = stream.block(gidx * (seq + 1), seq + 1)
    tokens = out_tokens[:, :-1]
    labels = out_tokens[:, 1:]
    mask = (labels != 0).astype(np.float32)      # don't train on separators

    if cfg.frontend == "audio_frames":
        rng = np.random.default_rng(seed * 1_000_003 + step)
        return {
            "frame_embeds": rng.standard_normal(
                (rows, seq, cfg.d_model)).astype(np.float32),
            "labels": np.stack(
                [labels % cfg.vocab_size] * cfg.n_codebooks, axis=-1
            ).astype(np.int32),
            "mask": mask,
        }
    if cfg.frontend == "vision_patches":
        P = cfg.n_patches
        text = max(seq - P, 1)
        rng = np.random.default_rng(seed * 1_000_003 + step)
        return {
            "tokens": tokens[:, :text],
            "patch_embeds": rng.standard_normal(
                (rows, P, cfg.d_model)).astype(np.float32),
            "labels": labels[:, :text],
            "mask": mask[:, :text],
        }
    return {"tokens": tokens, "labels": labels, "mask": mask}


class Prefetcher:
    """One-step-ahead host prefetch (double buffering)."""

    def __init__(self, make_fn, start_step: int = 0, depth: int = 2):
        self._make = make_fn
        self._q: Queue = Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            self._q.put((step, self._make(step)))
            step += 1

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except Exception:
            pass
