"""Deterministic synthetic LM data.

A keyed, stateless token stream: token[i] = h(seed, i) with a learnable
structure (n-gram-ish correlations) so tiny models show a falling loss — the
end-to-end example trains against this. Document boundaries every
``doc_len`` tokens exercise the packing/masking path.
"""
from __future__ import annotations

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab_size: int, seed: int = 0, doc_len: int = 512,
                 correlation: int = 8):
        self.vocab = vocab_size
        self.seed = seed
        self.doc_len = doc_len
        self.correlation = max(1, correlation)

    def block(self, start: int, length: int) -> np.ndarray:
        """Tokens [start, start+length) — pure function of (seed, index)."""
        idx = np.arange(start, start + length, dtype=np.uint64)
        base = idx // self.correlation      # repeat-ish structure
        mixed = (base * np.uint64(2654435761) + np.uint64(self.seed)) \
            % np.uint64(0xFFFFFFFB)
        toks = (mixed % np.uint64(max(self.vocab - 2, 1))).astype(np.int64) + 1
        # document separators (token 0) at fixed period
        toks = np.where(idx % np.uint64(self.doc_len) == 0, 0, toks)
        return toks.astype(np.int32)
