"""Pallas TPU kernels (validated in interpret mode on CPU hosts)."""
from __future__ import annotations


def pallas_compiler_params(**kwargs):
    """Build Pallas TPU compiler params across the JAX rename
    (TPUCompilerParams -> CompilerParams); raises clearly when neither
    exists instead of failing with a NoneType call."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported JAX version")
    return cls(**kwargs)
