from .ops import flash_attention, ref_flash_attention
from . import ref
