"""Pallas TPU flash-attention forward kernel.

The §Perf analysis identified streamed f32 score tiles as the dominant HBM
term of every training/prefill cell — the scan-based flash implementation
(models/flash.py) writes each (qc × kc) tile's p-matrix to HBM between XLA
ops. This kernel keeps the whole online-softmax state (m, l, acc) in VMEM
scratch across the kv grid axis, so score tiles never leave the core:

  grid = (H, nq, nk), kv innermost ("arbitrary");
  q block (1, bq, dh) VMEM · k/v block (1, bk, dh) VMEM (kv head = h // G)
  scratch: m,l (bq,128-padded) f32 · acc (bq, dh) f32, persisted across nk;
  @pl.when(k == 0) init, @pl.when(k == nk − 1) finalize into the out block.

GQA mapping is done by the k/v BlockSpec index maps (no repeated k/v in
HBM). Causal/window/validity masking from position vectors, same semantics
as models/attention.chunked_attention. Forward only — the training backward
stays on the custom-VJP recompute path (models/flash.py); this kernel is
the serving/prefill fast path and the TPU target for the fwd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import pallas_compiler_params

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 512


def _kernel(pq_ref, pk_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, window, nk: int):
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                    # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                    # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    pq = pq_ref[...].astype(jnp.float32)                # (bq,)
    pk = pk_ref[...].astype(jnp.float32)                # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = (pk[None, :] >= 0) & (pk[None, :] <= pq[:, None])
    if window is not None:
        ok &= (pq[:, None] - pk[None, :]) < float(window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(kidx == nk - 1)
    def _finalize():
        o_ref[0] = (acc_s[...]
                    / jnp.maximum(l_s[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, pos_q, pos_k, *, window=None,
                        scale: float | None = None, bq: int = DEFAULT_BQ,
                        bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (H, Sq, dh); k/v: (KV, Sk, dh); pos_*: int32. → (H, Sq, dh)."""
    H, Sq, dh = q.shape
    KV, Sk, _ = k.shape
    assert H % KV == 0
    G = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    while Sq % bq:
        bq //= 2
    while Sk % bk:
        bk //= 2
    nq, nk = Sq // bq, Sk // bk
    grid = (H, nq, nk)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda h, i, kc: (i,)),          # pos_q
            pl.BlockSpec((bk,), lambda h, i, kc: (kc,)),         # pos_k
            pl.BlockSpec((1, bq, dh), lambda h, i, kc: (h, i, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda h, i, kc, G=G: (h // G, kc, 0)),  # GQA map
            pl.BlockSpec((1, bk, dh),
                         lambda h, i, kc, G=G: (h // G, kc, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, kc: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, dh), jnp.float32),    # running accumulator
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_q, pos_k, q, k, v)
