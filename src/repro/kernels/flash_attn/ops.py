"""Jit'd wrapper for the Pallas flash-attention forward kernel."""
from __future__ import annotations

import functools

import jax

from . import flash_attn as _k
from . import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, pos_q, pos_k, *, window=None, scale=None,
                    bq: int = _k.DEFAULT_BQ, bk: int = _k.DEFAULT_BK,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _k.flash_attention_fwd(q, k, v, pos_q, pos_k, window=window,
                                  scale=scale, bq=bq, bk=bk,
                                  interpret=interpret)


ref_flash_attention = _ref.ref_flash_attention
