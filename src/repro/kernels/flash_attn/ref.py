"""Oracle for the Pallas flash-attention kernel: plain masked softmax."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ref_flash_attention(q, k, v, pos_q, pos_k, *, window=None, scale=None):
    """q: (H, Sq, dh) query heads; k/v: (KV, Sk, dh); H = KV·G with head h
    reading kv head h // G. pos_*: int32 positions (−1 = invalid key)."""
    H, Sq, dh = q.shape
    KV, Sk, _ = k.shape
    G = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    ok = (pos_k[None, None, :] >= 0) \
        & (pos_k[None, None, :] <= pos_q[None, :, None])
    if window is not None:
        ok &= (pos_q[None, :, None] - pos_k[None, None, :]) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
