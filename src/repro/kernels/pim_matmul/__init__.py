from .ops import pim_matmul, pim_linear, quantize
from . import ref
