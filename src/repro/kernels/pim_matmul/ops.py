"""Public API: quantize + pim_matmul + a drop-in linear layer.

`pim_linear` is how the paper's technique enters the LM stack: any linear in
``repro.models`` can run as a bit-plane quantized matmul
(config.quant = "pim_w4" / "pim_w8", mode = "shift_add" | "dequant").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pim_matmul as _k
from . import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize(w, bits: int):
    """Symmetric per-output-channel int quantization → (int8 codes, scales)."""
    return _ref.ref_quantize(w, bits)


@functools.partial(jax.jit,
                   static_argnames=("mode", "bits", "bm", "bn", "bk",
                                    "interpret"))
def pim_matmul(x, w_int, scales, *, mode: str = "shift_add", bits: int = 4,
               bm: int = 128, bn: int = 128, bk: int = 512,
               interpret: bool | None = None):
    """Y = X @ (W_int · scale) via bit planes. x: (M,K), w_int: (K,N) int8."""
    interpret = _default_interpret() if interpret is None else interpret
    raw = _k.pim_matmul_raw(x, w_int, mode=mode, bits=bits,
                            bm=bm, bn=bn, bk=bk, interpret=interpret)
    return raw * scales[None, :].astype(jnp.float32)


def pim_linear(x, w_int, scales, *, mode: str = "shift_add", bits: int = 4,
               out_dtype=jnp.bfloat16, interpret: bool | None = None):
    """Linear layer over arbitrary leading dims: (..., K) @ (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = pim_matmul(x2, w_int, scales, mode=mode, bits=bits,
                   interpret=interpret)
    return y.reshape(*lead, -1).astype(out_dtype)


# Re-exported oracles.
ref_pim_matmul = _ref.ref_pim_matmul
ref_pim_matmul_planes = _ref.ref_pim_matmul_planes
ref_quantize = _ref.ref_quantize
