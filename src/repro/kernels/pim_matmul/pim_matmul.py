"""Bit-plane shift-and-add quantized matmul — the paper's algorithm on the MXU.

The paper motivates in-DRAM shifting with shift-and-add multiplication:
partial products are aligned by shifts and accumulated (§1). On TPU the
"shift" of a partial product by 2^b is a power-of-two scalar folded into the
MXU accumulation, and a "row" of the computation is a weight *bit plane*:

    Y = X @ W_int * scale = sum_b  c_b * (X @ plane_b) * scale,
    c = [1, 2, 4, ..., -(2^(bits-1))]   (two's complement planes)

Modes:
  * ``shift_add`` — paper-faithful: one MXU pass per bit plane (`bits` dots
    per block). This is the BASELINE recorded in EXPERIMENTS.md §Perf.
  * ``dequant``   — beyond-paper optimization: dequantize the int block in
    VMEM and run ONE MXU pass (bits× fewer MXU FLOPs, same result).

VMEM tiling (TPU v5e: 128-lane MXU, ~16 MiB VMEM):
  X block (bm, bk) bf16, W block (bk, bn) int8, acc (bm, bn) f32 in the
  output ref (revisited across the K grid axis). Defaults bm=bn=128 bk=512:
  128·512·2 + 512·128·1 + 128·128·4 ≈ 0.25 MiB per step — deep pipelining
  headroom. All dims MXU-aligned (multiples of 128... 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .. import pallas_compiler_params

from .ref import plane_coeffs


def _matmul_kernel(x_ref, w_ref, o_ref, *, mode: str, bits: int, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (arbitrary) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]

    if mode == "dequant":
        wf = w.astype(x.dtype)
        o_ref[...] += jnp.dot(x, wf, preferred_element_type=jnp.float32)
    elif mode == "shift_add":
        wu = w.astype(jnp.int32) & ((1 << bits) - 1)
        acc = jnp.zeros_like(o_ref)
        for i, coeff in enumerate(plane_coeffs(bits)):
            plane = ((wu >> i) & 1).astype(x.dtype)   # the bit plane
            acc += coeff * jnp.dot(x, plane,
                                   preferred_element_type=jnp.float32)
        o_ref[...] += acc
    else:
        raise ValueError(mode)


def pim_matmul_raw(x, w_int, *, mode: str, bits: int,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool = False):
    """Unscaled integer-plane matmul: returns f32 (M, N) = X @ W_int."""
    m, kdim = x.shape
    k2, n = w_int.shape
    assert kdim == k2, (x.shape, w_int.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (
        f"shape ({m},{kdim},{n}) not divisible by blocks ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, mode=mode, bits=bits, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_int)
