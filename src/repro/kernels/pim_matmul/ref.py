"""Pure-jnp oracles for the bit-plane shift-and-add matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def plane_coeffs(bits: int):
    """Two's-complement plane weights: [1, 2, ..., -(2^(bits-1))]."""
    c = [float(1 << i) for i in range(bits - 1)]
    c.append(-float(1 << (bits - 1)))
    return c


def ref_planes(w_int: jnp.ndarray, bits: int):
    """Decompose signed int8 weights into 0/1 bit planes (list of arrays)."""
    wu = w_int.astype(jnp.int32) & ((1 << bits) - 1)
    return [((wu >> i) & 1).astype(jnp.float32) for i in range(bits)]


def ref_dequant(w_int: jnp.ndarray, scales: jnp.ndarray,
                bits: int) -> jnp.ndarray:
    """Reference dequantize: w_int * scale (per output channel)."""
    del bits
    return w_int.astype(jnp.float32) * scales[None, :].astype(jnp.float32)


def ref_pim_matmul(x: jnp.ndarray, w_int: jnp.ndarray, scales: jnp.ndarray,
                   bits: int) -> jnp.ndarray:
    """Y = X @ dequant(W). Mathematically identical for both kernel modes:
    sum_b c_b (X @ plane_b) * scale == X @ (W_int * scale)."""
    xf = x.astype(jnp.float32)
    wf = ref_dequant(w_int, scales, bits)
    return xf @ wf


def ref_pim_matmul_planes(x: jnp.ndarray, w_int: jnp.ndarray,
                          scales: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Plane-by-plane evaluation (tests the shift-add decomposition itself)."""
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], w_int.shape[1]), jnp.float32)
    for coeff, plane in zip(plane_coeffs(bits), ref_planes(w_int, bits)):
        acc = acc + coeff * (xf @ plane)
    return acc * scales[None, :].astype(jnp.float32)


def ref_quantize(w: jnp.ndarray, bits: int):
    """Symmetric per-output-channel quantization to signed ``bits`` ints."""
    qmax = float((1 << (bits - 1)) - 1)
    absmax = jnp.max(jnp.abs(w), axis=0)
    scales = jnp.maximum(absmax, 1e-8) / qmax
    w_int = jnp.clip(jnp.round(w / scales[None, :]), -qmax - 1, qmax)
    return w_int.astype(jnp.int8), scales.astype(jnp.float32)
