from .ops import bitwise, shift_cols, ripple_add
from . import ref
