"""Jit'd public wrappers for the rowops kernels.

``interpret`` defaults to True on CPU hosts (this container) and False when a
real TPU backend is present; callers can force either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import rowops as _k
from . import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def bitwise(a, b=None, c=None, *, op: str, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _k.bitwise(a.astype(jnp.uint32),
                      None if b is None else b.astype(jnp.uint32),
                      None if c is None else c.astype(jnp.uint32),
                      op=op, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def shift_cols(x, k: int, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _k.shift_cols(x.astype(jnp.uint32), k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def ripple_add(a, b, *, width: int, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _k.ripple_add(a.astype(jnp.uint32), b.astype(jnp.uint32),
                         width=width, interpret=interpret)


# Re-exported oracles (benchmarks compare kernel vs ref on identical inputs).
ref_bitwise = _ref.ref_bitwise
ref_shift_cols = _ref.ref_shift_cols
ref_ripple_add = _ref.ref_ripple_add
