"""Pure-jnp oracles for the rowops Pallas kernel.

Rows are (N, W) uint32: N independent DRAM rows of W packed words; column c
of a row = bit c%32 (little-endian) of word c//32 — same convention as
``repro.core.pim.state``.
"""
from __future__ import annotations

import jax.numpy as jnp


def ref_bitwise(a, b=None, c=None, *, op: str):
    a = a.astype(jnp.uint32)
    if op == "not":
        return ~a
    b = b.astype(jnp.uint32)
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "maj":
        c = c.astype(jnp.uint32)
        return (a & b) | (b & c) | (a & c)
    raise ValueError(op)


def ref_shift_cols(x, k: int):
    """Shift every row by k columns (+ = toward higher column), 0 fill."""
    x = x.astype(jnp.uint32)
    if k == 0:
        return x
    kw, kb = divmod(abs(int(k)), 32)

    def word_shift(v, up):
        if up == 0:
            return v
        pad = jnp.zeros(v.shape[:-1] + (abs(up),), jnp.uint32)
        if up > 0:
            return jnp.concatenate([pad, v[..., :-up]], axis=-1)
        return jnp.concatenate([v[..., -up:], pad], axis=-1)

    if k > 0:
        v = word_shift(x, kw)
        if kb:
            v = (v << jnp.uint32(kb)) | (word_shift(v, 1) >> jnp.uint32(32 - kb))
        return v
    v = word_shift(x, -kw)
    if kb:
        v = (v >> jnp.uint32(kb)) | (word_shift(v, -1) << jnp.uint32(32 - kb))
    return v


def ref_ripple_add(a, b, width: int, elem_mask_pattern: int | None = None):
    """Bulk element-wise add over horizontally packed w-bit elements,
    implemented with the same S/C iteration the PIM machine runs (but as one
    fused jnp computation). Oracle for the fused adder kernel."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    interior = jnp.uint32(_interior_mask(width))
    s = a ^ b
    c = a & b
    for _ in range(width - 1):
        cs = ref_shift_cols(c, +1) & interior
        c = s & cs
        s = s ^ cs
    return s


def _interior_mask(width: int) -> int:
    """32-bit tile of the 'all element bits except bit 0' pattern, as a plain
    int (usable both under jit and as a static kernel parameter).

    Valid whenever width divides 32 (1,2,4,8,16,32)."""
    assert 32 % width == 0, "interior mask tiles only for width | 32"
    pat = 0
    for e in range(32 // width):
        pat |= (((1 << width) - 1) & ~1) << (e * width)
    return pat
