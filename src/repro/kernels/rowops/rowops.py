"""Pallas TPU kernels for bulk PIM row operations.

The TPU-native re-tiling of the paper's subarray (DESIGN.md §2): a DRAM row's
65,536 bitlines become 2,048 packed uint32 lanes; the sense-amp-parallel
bitwise ops become VPU ops over (8, 128)-lane vregs; the migration-cell
staggered pairing becomes the inter-word carry network of ``shift_cols``.

Two execution styles:

  * per-op kernels (`bitwise`, `shift_cols`) — the paper-faithful
    command-by-command path: every ISA command round-trips rows HBM→VMEM→HBM,
    exactly like every AAP round-trips the row buffer.
  * the fused `ripple_add` kernel — the beyond-paper path: the whole w-round
    carry iteration runs on a VMEM-resident block, eliminating 3·(w-1)
    intermediate row round-trips (quantified in EXPERIMENTS.md §Perf).

Block shapes: rows are tiled (block_rows, W) — a full row of W words stays
contiguous in the block so the carry network never crosses a block boundary;
block_rows × W × 4 B must fit VMEM (default 8 × 2048 × 4 = 64 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _word_shift_up(x, n):
    """Shift whole words toward higher index along the minor axis, 0 fill."""
    if n == 0:
        return x
    if n >= x.shape[-1]:             # whole block shifted out (fused k≥32W)
        return jnp.zeros_like(x)
    pad = jnp.zeros(x.shape[:-1] + (n,), x.dtype)
    return jnp.concatenate([pad, x[..., :-n]], axis=-1)


def _word_shift_down(x, n):
    if n == 0:
        return x
    if n >= x.shape[-1]:
        return jnp.zeros_like(x)
    pad = jnp.zeros(x.shape[:-1] + (n,), x.dtype)
    return jnp.concatenate([x[..., n:], pad], axis=-1)


def _shift_cols_block(x, k: int):
    """Column shift with inter-word carry, entirely within the block."""
    kw, kb = divmod(abs(int(k)), 32)
    if k > 0:
        v = _word_shift_up(x, kw)
        if kb:
            v = (v << jnp.uint32(kb)) | (_word_shift_up(v, 1)
                                         >> jnp.uint32(32 - kb))
        return v
    if k < 0:
        v = _word_shift_down(x, kw)
        if kb:
            v = (v >> jnp.uint32(kb)) | (_word_shift_down(v, 1)
                                         << jnp.uint32(32 - kb))
        return v
    return x


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _bitwise_kernel(*refs, op: str):
    o_ref = refs[-1]
    a = refs[0][...]
    if op == "not":
        o_ref[...] = ~a
    elif op == "and":
        o_ref[...] = a & refs[1][...]
    elif op == "or":
        o_ref[...] = a | refs[1][...]
    elif op == "xor":
        o_ref[...] = a ^ refs[1][...]
    elif op == "maj":
        b, c = refs[1][...], refs[2][...]
        o_ref[...] = (a & b) | (b & c) | (a & c)
    else:
        raise ValueError(op)


def _shift_kernel(x_ref, o_ref, *, k: int):
    o_ref[...] = _shift_cols_block(x_ref[...], k)


def _ripple_add_kernel(a_ref, b_ref, o_ref, *, width: int, interior: int):
    """Fused w-round carry iteration — one HBM round-trip total."""
    a = a_ref[...]
    b = b_ref[...]
    interior_mask = jnp.uint32(interior)
    s = a ^ b
    c = a & b
    for _ in range(width - 1):
        cs = _shift_cols_block(c, +1) & interior_mask
        c = s & cs
        s = s ^ cs
    o_ref[...] = s


# ---------------------------------------------------------------------------
# pallas_call wrappers (grid/BlockSpec plumbing; jit wrappers live in ops.py)
# ---------------------------------------------------------------------------

def _row_grid(x, block_rows):
    n, w = x.shape
    br = min(block_rows, n)
    assert n % br == 0, f"rows {n} not divisible by block {br}"
    return (n // br,), br, w


def bitwise(a, b=None, c=None, *, op: str,
            block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False):
    grid, br, w = _row_grid(a, block_rows)
    spec = pl.BlockSpec((br, w), lambda i: (i, 0))
    nargs = {"not": 1, "and": 2, "or": 2, "xor": 2, "maj": 3}[op]
    args = [a, b, c][:nargs]
    assert all(x is not None for x in args), f"{op} needs {nargs} operands"
    return pl.pallas_call(
        functools.partial(_bitwise_kernel, op=op),
        grid=grid,
        in_specs=[spec] * nargs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.uint32),
        interpret=interpret,
    )(*args)


def shift_cols(x, k: int, *, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False):
    grid, br, w = _row_grid(x, block_rows)
    spec = pl.BlockSpec((br, w), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_shift_kernel, k=k),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint32),
        interpret=interpret,
    )(x)


def ripple_add(a, b, *, width: int, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False):
    from .ref import _interior_mask  # single source of truth for the pattern
    grid, br, w = _row_grid(a, block_rows)
    spec = pl.BlockSpec((br, w), lambda i: (i, 0))
    interior = int(_interior_mask(width))
    return pl.pallas_call(
        functools.partial(_ripple_add_kernel, width=width, interior=interior),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.uint32),
        interpret=interpret,
    )(a, b)
