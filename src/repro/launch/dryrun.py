import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# For each cell this builds the real step function (train_step / prefill /
# serve_step), the full sharding trees from the rule engine, lowers with
# ShapeDtypeStruct inputs (no allocation), compiles under the production
# mesh, and records memory/cost/collective analysis → experiments/dryrun/*.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
#         --shape train_4k --mesh single
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

import argparse
import re
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, skip_reason
from repro.launch import roofline as rl
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_shardings, param_shardings)
from repro.models import decode_step, init_caches, init_params, prefill
from repro.optim import adamw
from repro.train.step import init_train_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def batch_struct(cfg, batch: int, seq: int):
    """ShapeDtypeStruct stand-ins for every model input (shardable,
    weak-type-correct, no device allocation)."""
    i32 = jnp.int32
    f32 = jnp.float32
    if cfg.frontend == "audio_frames":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                                 f32),
            "labels": jax.ShapeDtypeStruct((batch, seq, cfg.n_codebooks),
                                           i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32),
        }
    if cfg.frontend == "vision_patches":
        text = seq - cfg.n_patches
        return {
            "tokens": jax.ShapeDtypeStruct((batch, text), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.n_patches, cfg.d_model), f32),
            "labels": jax.ShapeDtypeStruct((batch, text), i32),
            "mask": jax.ShapeDtypeStruct((batch, text), f32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32)}


def decode_token_struct(cfg, batch: int):
    if cfg.frontend == "audio_frames":
        return {"frame_embeds": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                                     jnp.float32)}
    return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}


def _replicated_bytes(tree) -> float:
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def _sharded_bytes_per_device(tree, shardings, mesh) -> float:
    """Analytic per-device bytes given sharding specs."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        n_shards = 1
        for ax in sh.spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, (tuple, list)) else (ax,)
            for a in axes:
                n_shards *= mesh.shape[a]
        total += np.prod(leaf.shape) * leaf.dtype.itemsize / n_shards
    return total


def build_cell(cfg, shape, mesh):
    """Returns (jitted fn, arg structs, ShardingReport, byte accounting)."""
    key = jax.random.PRNGKey(0)
    params_s = _sds(jax.eval_shape(lambda: init_params(cfg, key)))
    p_shard, report = param_shardings(cfg, mesh, params_s)
    bytes_acct = {"params_per_device":
                  _sharded_bytes_per_device(params_s, p_shard, mesh)}

    if shape.kind == "train":
        train_s, frozen_s, opt_s = jax.eval_shape(
            lambda p: init_train_state(cfg, p), params_s)
        train_s, frozen_s, opt_s = map(_sds, (train_s, frozen_s, opt_s))
        t_shard, _ = param_shardings(cfg, mesh, train_s)
        f_shard, _ = param_shardings(cfg, mesh, frozen_s)
        o_shard = opt_shardings(mesh, opt_s, t_shard)
        batch_s = batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_shard = batch_shardings(mesh, batch_s, shape.global_batch)
        bytes_acct["opt_per_device"] = _sharded_bytes_per_device(
            opt_s, o_shard, mesh)
        # min traffic: params fwd+bwd reads + grad write + moments r/w
        bytes_acct["ideal_step_bytes"] = (
            3 * bytes_acct["params_per_device"]
            + 2 * bytes_acct["opt_per_device"])
        step = make_train_step(cfg, adamw.AdamWConfig(), lambda s: 1.0)
        fn = jax.jit(step,
                     in_shardings=(t_shard, f_shard, o_shard, b_shard),
                     out_shardings=(t_shard, o_shard, None),
                     donate_argnums=(0, 2))
        return fn, (train_s, frozen_s, opt_s, batch_s), report, bytes_acct

    caches_s = _sds(jax.eval_shape(
        lambda _: init_caches(cfg, shape.global_batch, shape.seq_len),
        jnp.zeros(())))
    c_shard = cache_shardings(mesh, caches_s, shape.global_batch)
    bytes_acct["cache_per_device"] = _sharded_bytes_per_device(
        caches_s, c_shard, mesh)

    if shape.kind == "prefill":
        batch_s = batch_struct(cfg, shape.global_batch, shape.seq_len)
        batch_s.pop("labels", None)
        batch_s.pop("mask", None)
        b_shard = batch_shardings(mesh, batch_s, shape.global_batch)
        bytes_acct["ideal_step_bytes"] = (
            bytes_acct["params_per_device"] + bytes_acct["cache_per_device"])
        fn = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_cache_len=shape.seq_len),
            in_shardings=(p_shard, b_shard))
        return fn, (params_s, batch_s), report, bytes_acct

    # decode: one new token against a seq_len-deep cache
    tok_s = decode_token_struct(cfg, shape.global_batch)
    t_shard_tok = batch_shardings(mesh, tok_s, shape.global_batch)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    bytes_acct["ideal_step_bytes"] = (
        bytes_acct["params_per_device"] + bytes_acct["cache_per_device"])
    fn = jax.jit(
        lambda p, tok, pos, c: decode_step(cfg, p, tok, pos, c),
        in_shardings=(p_shard, t_shard_tok, None, c_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(3,))
    return fn, (params_s, tok_s, pos_s, caches_s), report, bytes_acct


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, tag: str = "",
             out_dir: str = OUT_DIR) -> dict:
    shape = SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, stem + ".json")
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": reason}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    flat = {k: v for k, v in (overrides or {}).items() if "." not in k}
    nested = {k: v for k, v in (overrides or {}).items() if "." in k}
    cfg = get_config(arch, **flat)
    for k, v in nested.items():           # e.g. --override moe.impl=gather
        head, leaf = k.split(".", 1)
        sub = getattr(cfg, head)
        cfg = dataclasses.replace(
            cfg, **{head: dataclasses.replace(sub, **{leaf: v})})
    custom = re.match(r"^(\d+)x(\d+)$", mesh_kind)
    if custom:                            # e.g. --mesh 64x4 (layout study)
        mesh = jax.make_mesh((int(custom.group(1)), int(custom.group(2))),
                             ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "devices": n_dev, "overrides": {k: str(v) for k, v in
                                           (overrides or {}).items()}}
    t0 = time.time()
    try:
        with mesh:
            fn, args, report, bytes_acct = build_cell(cfg, shape, mesh)
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            hlo = compiled.as_text()
            model_flops = rl.analytic_model_flops(
                cfg, shape.kind, shape.seq_len, shape.global_batch)
            roof, coll = rl.from_compiled(compiled, n_dev, model_flops,
                                          hlo_text=hlo)
            try:
                mem = compiled.memory_analysis()
                mem_rec = {k: int(getattr(mem, k)) for k in
                           ("argument_size_in_bytes",
                            "output_size_in_bytes",
                            "temp_size_in_bytes",
                            "generated_code_size_in_bytes")
                           if hasattr(mem, k)}
            except Exception as e:                       # noqa: BLE001
                mem_rec = {"error": str(e)}
            rec.update({
                "status": "ok",
                "lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2),
                "flops_per_device": roof.flops,
                "hbm_bytes_per_device": roof.hbm_bytes,
                "link_bytes_per_device": roof.link_bytes,
                "collectives": {k: v for k, v in coll.items()},
                "model_flops": model_flops,
                "t_compute": roof.t_compute,
                "t_memory": roof.t_memory,
                "t_collective": roof.t_collective,
                "bottleneck": roof.bottleneck,
                "roofline_fraction": roof.roofline_fraction,
                "flops_utilization": roof.flops_utilization,
                "bytes_accounting": {k: float(v)
                                     for k, v in bytes_acct.items()},
                "memory_fraction": float(
                    bytes_acct["ideal_step_bytes"]
                    / max(roof.hbm_bytes, 1.0)),
                # score-carrying fraction: ideal time (compute OR unavoidable
                # memory, whichever binds) over the achieved bound
                "roofline_fraction_cell": float(
                    max(model_flops / n_dev / rl.PEAK_FLOPS,
                        bytes_acct["ideal_step_bytes"] / rl.HBM_BW)
                    / max(roof.bound_time, 1e-30)),
                "memory_analysis": mem_rec,
                "sharding_report": {
                    "matched": report.matched,
                    "fallback_replicated": report.fallback_replicated[:20],
                    "degraded_dims": [list(map(str, d))
                                      for d in report.degraded_dims[:20]],
                },
            })
    except Exception as e:                                # noqa: BLE001
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:]})
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    help="single | multi | both | <data>x<model> (e.g. 64x4)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--override", action="append",
                    help="cfg field override, e.g. --override remat=false")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = _parse_overrides(args.override)

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                stem = f"{arch}__{shape}__{mesh_kind}" \
                    + (f"__{args.tag}" if args.tag else "")
                path = os.path.join(args.out, stem + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {stem}")
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind, overrides, args.tag,
                               args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"bottleneck={rec['bottleneck']} "
                             f"frac={rec['roofline_fraction_cell']:.3f} "
                             f"compile={rec['compile_s']}s")
                elif status == "error":
                    extra = rec["error"][:160]
                else:
                    extra = rec["reason"][:60]
                print(f"[{status:7s}] {stem} ({time.time()-t0:.0f}s) {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
