"""Loop-aware cost analysis over compiled (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` counts a ``while`` body ONCE, but our layer
stacks are lax.scan loops — flops/bytes/collectives would be low by a factor
of ~n_layers. This module re-derives per-device costs by walking the HLO
call graph and scaling loop bodies by their trip counts (taken from XLA's
``known_trip_count`` backend config, falling back to the loop condition's
comparison constant):

  flops       : 2·|result|·|contracted| for every dot (incl. inside fusions)
  hbm bytes   : operands+results of *top-level* instructions only (fusion
                internals don't touch HBM). A fusion operand that is only
                dynamic-sliced inside counts as the slice, not the full
                array (scan weight indexing would otherwise overcount ×L).
  collectives : ring-model link bytes per chip (factors in roofline.py)

This is an analysis model, not a simulator — XLA:CPU layout copies are
counted as written, and EXPERIMENTS.md reports the analytic config-level
model alongside as a cross-check.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
_GROUPS_SIZE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SKIP_HBM = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call-start", "iota",
}


def shape_dims(type_str: str):
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPE_RE.findall(type_str)
            if dt in _DTYPE_BYTES]


def shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str

    def operands(self):
        if "(" not in self.line:
            return []
        # operands live between the opcode's '(' and the matching ')'
        tail = self.line.split(self.opcode + "(", 1)
        if len(tail) < 2:
            return []
        return _OPERAND_RE.findall(tail[1].split("), ")[0])


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm: float = 0.0
    link: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.hbm += other.hbm * scale
        self.link += other.link * scale
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * scale


class HloModule:
    def __init__(self, text: str, n_devices: int):
        self.n_devices = n_devices
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}
        self._ptraffic_cache: dict[str, dict[int, float]] = {}

    @staticmethod
    def _parse_instr(line: str):
        """Balanced-paren instruction parse: handles tuple result types with
        layout braces and /*index=N*/ comments that defeat regexes."""
        stripped = line.strip()
        if stripped.startswith("ROOT "):
            stripped = stripped[5:]
        eq = stripped.find(" = ")
        if eq < 0:
            return None
        name = stripped[:eq].strip().lstrip("%")
        if not re.fullmatch(r"[\w.\-]+", name):
            return None
        rest = stripped[eq + 3:]
        if rest.startswith("("):                      # tuple type
            depth = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        type_str = rest[:i + 1]
                        tail = rest[i + 1:]
                        break
            else:
                return None
        else:
            m = re.match(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", rest)
            if not m:
                return None
            type_str = m.group(1)
            tail = rest[m.end():]
        m = _OPCODE_RE.match(tail)
        if not m:
            return None
        return Instr(name, type_str, m.group(1), stripped)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line.startswith(" "):
                m = _HDR_RE.match(line)
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if cur is None or line.strip() == "}":
                continue
            instr = self._parse_instr(line)
            if instr is not None:
                self.comps[cur].append(instr)

    def _types_of(self, comp: str):
        return {i.name: i.type_str for i in self.comps.get(comp, [])}

    def _trip_count(self, line: str) -> float:
        m = _TRIP_RE.search(line)
        if m:
            return float(m.group(1))
        mc = _COND_RE.search(line)
        best = 1
        if mc:
            for i in self.comps.get(mc.group(1), []):
                c = _CONST_INT_RE.search(i.line)
                if c:
                    best = max(best, int(c.group(1)))
        return float(best)

    def _group_size(self, line: str) -> int:
        m = _GROUPS_SIZE_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(line)
        if m and m.group(1).strip():
            first = m.group(1).split("}")[0].strip("{ ")
            n = len([t for t in first.split(",") if t.strip() != ""])
            if n:
                return n
        return self.n_devices

    def _dot_flops(self, instr: Instr, types: dict) -> float:
        result = shape_dims(instr.type_str)
        if not result:
            return 0.0
        rn = 1
        for d in result[0][1]:
            rn *= d
        contracted = 1
        m = _CONTRACT_RE.search(instr.line)
        ops = instr.operands()
        if m and ops:
            lhs = shape_dims(types.get(ops[0], ""))
            if lhs:
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(lhs[0][1]):
                        contracted *= lhs[0][1][int(idx)]
        return 2.0 * rn * contracted

    def _collective(self, instr: Instr):
        for k in COLLECTIVES:
            if instr.opcode == k or instr.opcode.startswith(k + "-"):
                if instr.opcode.endswith("-done"):
                    return None
                n = self._group_size(instr.line)
                if n <= 1:
                    return None
                b = shape_bytes(instr.type_str)
                ring = (n - 1) / n
                if k == "all-reduce":
                    return k, 2.0 * b * ring
                if k == "reduce-scatter":
                    return k, b * (n - 1)
                if k == "collective-permute":
                    return k, b
                return k, b * ring
        return None

    def _param_traffic(self, comp: str) -> dict[int, float]:
        """Per-parameter HBM traffic of a fused computation:
        - consumed only by dynamic-slice   → the slice bytes (gather read)
        - consumed only as the dynamic-update-slice TARGET → 0 (in-place
          aliased buffer; the update itself is counted at the result)
        - otherwise → full parameter bytes."""
        if comp in self._ptraffic_cache:
            return self._ptraffic_cache[comp]
        instrs = self.comps.get(comp, [])
        params = {}       # name -> (idx, bytes)
        for i in instrs:
            if i.opcode == "parameter":
                m = _PARAM_IDX_RE.search(i.line)
                if m:
                    params[i.name] = (int(m.group(1)),
                                      shape_bytes(i.type_str))
        traffic = {idx: b for idx, b in params.values()}
        consumers: dict[str, list[Instr]] = {}
        for i in instrs:
            for o in i.operands():
                consumers.setdefault(o, []).append(i)
        def effective_consumers(name, depth=0):
            """Consumers with bitcast/copy/reshape treated as pass-through."""
            out = []
            for c in consumers.get(name, []):
                if c.opcode in ("bitcast", "reshape", "copy", "convert") \
                        and depth < 8:
                    out.extend(effective_consumers(c.name, depth + 1))
                else:
                    out.append(c)
            return out

        for name, (idx, b) in params.items():
            cons = effective_consumers(name)
            if not cons:
                continue
            if all(c.opcode == "dynamic-slice" for c in cons):
                traffic[idx] = sum(shape_bytes(c.type_str) for c in cons)
            elif all(c.opcode == "dynamic-update-slice"
                     and c.operands() and self._resolves_to(
                         comp, c.operands()[0], name) for c in cons):
                traffic[idx] = 0.0
        self._ptraffic_cache[comp] = traffic
        return traffic

    def _resolves_to(self, comp: str, name: str, target: str,
                     depth: int = 0) -> bool:
        """True if ``name`` is ``target`` through bitcast/copy/reshape."""
        if name == target:
            return True
        if depth > 8:
            return False
        by_name = {i.name: i for i in self.comps.get(comp, [])}
        i = by_name.get(name)
        if i is not None and i.opcode in ("bitcast", "reshape", "copy",
                                          "convert"):
            ops = i.operands()
            if ops:
                return self._resolves_to(comp, ops[0], target, depth + 1)
        return False

    def _result_traffic(self, comp: str, full_bytes: float) -> float:
        """Result-side HBM bytes of a fused computation: if the ROOT is a
        dynamic-update-slice (in-place buffer update, possibly behind
        bitcast/copy), only the update slice is written, not the buffer."""
        instrs = self.comps.get(comp, [])
        if not instrs:
            return full_bytes
        by_name = {i.name: i for i in instrs}
        root = instrs[-1]
        hops = 0
        while root.opcode in ("bitcast", "reshape", "copy", "convert") \
                and hops < 8:
            ops = root.operands()
            if not ops or ops[0] not in by_name:
                break
            root = by_name[ops[0]]
            hops += 1
        if root.opcode == "dynamic-update-slice":
            ops = root.operands()
            types = self._types_of(comp)
            if len(ops) >= 2 and ops[1] in types:
                return 2.0 * shape_bytes(types[ops[1]])   # slice r+w
        return full_bytes

    def comp_cost(self, comp: str, _depth=0) -> Cost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total = Cost()
        self._cost_cache[comp] = total
        if _depth > 64:
            return total
        types = self._types_of(comp)
        for instr in self.comps.get(comp, []):
            if instr.opcode == "while":
                mb = _WHILE_RE.search(instr.line)
                trips = self._trip_count(instr.line)
                if mb:
                    total.add(self.comp_cost(mb.group(1), _depth + 1), trips)
                continue
            if instr.opcode == "conditional":
                m = _BRANCHES_RE.search(instr.line)
                if m:
                    costs = [self.comp_cost(b.strip().lstrip("%"), _depth + 1)
                             for b in m.group(1).split(",")]
                    if costs:
                        total.add(max(costs, key=lambda c: c.flops + c.hbm))
                continue
            if instr.opcode in ("fusion", "call"):
                m = _CALLS_RE.search(instr.line)
                if m:
                    sub = self.comp_cost(m.group(1), _depth + 1)
                    total.flops += sub.flops
                    total.link += sub.link
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                    ptraffic = self._param_traffic(m.group(1))
                    for i_op, _ in enumerate(instr.operands()):
                        total.hbm += ptraffic.get(i_op, 0.0)
                    total.hbm += self._result_traffic(
                        m.group(1), shape_bytes(instr.type_str))
                else:
                    total.hbm += shape_bytes(instr.type_str)
                continue
            if instr.opcode == "dynamic-update-slice":
                ops = instr.operands()
                if len(ops) >= 2 and ops[1] in types:
                    total.hbm += 2.0 * shape_bytes(types[ops[1]])
                else:
                    total.hbm += shape_bytes(instr.type_str)
                continue
            if instr.opcode == "dynamic-slice":
                total.hbm += 2.0 * shape_bytes(instr.type_str)
                continue
            if instr.opcode in ("dot", "convolution"):
                total.flops += self._dot_flops(instr, types)
                total.hbm += shape_bytes(instr.type_str)
                for o in instr.operands():
                    if o in types:
                        total.hbm += shape_bytes(types[o])
                continue
            c = self._collective(instr)
            if c is not None:
                k, b = c
                total.coll[k] = total.coll.get(k, 0.0) + b
                total.link += b
                total.hbm += shape_bytes(instr.type_str)
                continue
            if instr.opcode in _SKIP_HBM:
                continue
            total.hbm += shape_bytes(instr.type_str)
            for o in instr.operands():
                if o in types:
                    total.hbm += shape_bytes(types[o])
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry) if self.entry else Cost()


def analyze(hlo_text: str, n_devices: int) -> Cost:
    return HloModule(hlo_text, n_devices).entry_cost()
