"""Production mesh builders (TPU v5e pods: 16×16 = 256 chips per pod).

A FUNCTION, not a module constant — importing this module never touches jax
device state (required so tests/benches see 1 CPU device while the dry-run
sees 512 placeholder devices it configures itself).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers, as a 1×N (data, model) mesh — used by
    small-scale integration tests."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The batch (data-parallel) axes of a production mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
