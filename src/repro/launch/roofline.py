"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    T_compute    = HLO_FLOPs / (chips · 197e12)          [bf16 peak]
    T_memory     = HLO_bytes / (chips · 819e9)           [HBM BW]
    T_collective = link_bytes / (chips · 50e9)           [ICI per-link BW]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed out of the post-SPMD HLO text: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we resolve the
result (and, via a symbol table, operand) shapes and convert to *per-chip
link traffic* with ring-algorithm factors:

    all-reduce       2 · bytes · (n−1)/n      (reduce-scatter + all-gather)
    all-gather       bytes · (n−1)/n          (bytes = full result)
    reduce-scatter   bytes · (n−1)/n          (bytes = full operand)
    all-to-all       bytes · (n−1)/n
    collective-permute  bytes

Since cost_analysis on the CPU backend reflects XLA:CPU fusion choices, an
*analytic* FLOP model per cell (from the config) is reported alongside —
MODEL_FLOPS = 6·N·D for training, 2·N·D for inference (N = active params).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+"
                     r"([\w\-]+)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_SIZE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_SIZE_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m and m.group(1).strip():
        first = m.group(1).split("}")[0].strip("{ ")
        n = len([t for t in first.split(",") if t.strip() != ""])
        if n > 0:
            return n
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-chip link-traffic bytes by collective kind (ring model)."""
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for k in COLLECTIVES:
            if op == k or op.startswith(k + "-"):   # e.g. all-reduce-start
                kind = k
                break
        if kind is None or op.endswith("-done"):
            continue
        n = _group_size(stripped, n_devices)
        if n <= 1:
            continue
        result_bytes = shape_bytes(m.group(2))
        ring = (n - 1) / n
        if kind == "all-reduce":
            traffic = 2.0 * result_bytes * ring
        elif kind == "all-gather":
            traffic = result_bytes * ring          # result = gathered size
        elif kind == "reduce-scatter":
            traffic = result_bytes * (n - 1)       # operand = result × n
        elif kind == "all-to-all":
            traffic = result_bytes * ring
        else:                                      # collective-permute
            traffic = result_bytes
        out[kind] += traffic
        counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    link_bytes: float            # per-chip collective link traffic
    chips: int
    model_flops: float           # analytic global model flops

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the bound: (MODEL_FLOPS/chips/peak) /
        max-term — the score-carrying number (1.0 = perfect)."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / max(self.bound_time, 1e-30)

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — remat/redundancy waste detector."""
        return self.model_flops / max(self.flops * self.chips, 1e-30)


def from_compiled(compiled, n_devices: int, model_flops: float,
                  hlo_text: str | None = None) -> tuple[Roofline, dict]:
    """Terms via the loop-aware HLO analyzer (hlo_analysis.py). The SPMD
    module is already per-device, so no /n_devices normalization is applied
    to flops/bytes; only model_flops (global) is divided where needed."""
    from . import hlo_analysis
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_analysis.analyze(text, n_devices)
    coll = dict(cost.coll)
    coll["total"] = cost.link
    # raw XLA numbers as a cross-check column (loops counted once there)
    try:
        xla = compiled.cost_analysis()
        if isinstance(xla, (list, tuple)):
            xla = xla[0]
        coll["xla_flops_raw"] = float(xla.get("flops", 0.0))
        coll["xla_bytes_raw"] = float(xla.get("bytes accessed", 0.0))
    except Exception:                                     # noqa: BLE001
        pass
    rl = Roofline(flops=cost.flops, hbm_bytes=cost.hbm,
                  link_bytes=cost.link, chips=n_devices,
                  model_flops=model_flops)
    return rl, coll


def analytic_model_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    tokens = batch * (seq if shape_kind in ("train", "prefill") else 1)
    n = cfg.n_active_params()
    per_tok = 6 * n if shape_kind == "train" else 2 * n
    return float(per_tok) * tokens
