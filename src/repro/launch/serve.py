"""Serving CLI: ``PYTHONPATH=src python -m repro.launch.serve --arch <id>``."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    out = greedy_generate(cfg, params, prompts, max_new_tokens=args.max_new,
                          temperature=args.temperature)
    for i in range(args.batch):
        print(f"req{i}: {np.asarray(out[i])}")


if __name__ == "__main__":
    main()
