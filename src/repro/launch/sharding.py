"""Path-regex sharding rule engine.

``param_shardings(cfg, mesh, params_tree)`` maps every parameter leaf to a
NamedSharding by matching its tree path against ordered rules. Rules specify
the PartitionSpec of the *trailing* dims; leading stacked-layer axes are
padded with None automatically. Before use, every sharded dim is checked for
divisibility by its mesh axes — non-divisible dims degrade to replicated
(collected in ``ShardingReport`` instead of failing the compile; a real
cluster run reviews the report).

Scheme (DESIGN.md §4): vocab/embef + attention heads + FFN hidden + MoE
expert axis + SSM/RG-LRU channel axis on "model"; batch on ("pod","data");
decode KV caches context-sharded (sequence dim on "model").
"""
from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes

# (path regex, trailing-dims spec). First match wins; matched right-to-left
# against the "/"-joined tree path.
_RULES: list[tuple[str, tuple]] = [
    # norms & scalar-ish leaves — replicated
    (r"(ln1|ln2|ln|final_norm|kv_norm|q_norm|k_norm|dt_norm|b_norm|c_norm)"
     r"(/[wb])?$", ()),
    (r"(dt_bias|conv_b|b_a|b_x|lam|D)$", ()),
    # embeddings / output heads
    (r"embed$", ("model", None)),
    (r"lm_head$", (None, "model")),
    (r"heads$", (None, "model")),
    # attention (GQA)
    (r"attn/w[qkv]$", (None, "model")),
    (r"attn/b[qkv]$", ("model",)),
    (r"attn/wo$", ("model", None)),
    # attention (MLA)
    (r"attn/w_dkv$", (None, None)),
    (r"attn/w_uk$", (None, "model", None)),
    (r"attn/w_uv$", (None, "model", None)),
    (r"attn/w_q$", (None, "model")),
    (r"attn/w_o$", ("model", None)),
    # MoE: expert-parallel stacks, replicated router
    (r"ffn/router$", (None, None)),
    (r"ffn/w[13]$", ("model", None, None)),
    (r"ffn/w2$", ("model", None, None)),
    # dense FFN / shared experts / hybrid MLP (incl. PIM-quantized forms)
    (r"w[13]/(w|w_int)$", (None, "model")),
    (r"w[13]/scales$", ("model",)),
    (r"w[13]/b$", ("model",)),
    (r"w2/(w|w_int)$", ("model", None)),
    (r"w2/scales$", ()),
    (r"w2/b$", ()),
    # mamba
    (r"mix/in_proj$", (None, "model")),
    (r"mix/conv_w$", (None, "model")),
    (r"mix/x_proj$", ("model", None)),
    (r"mix/dt_proj$", (None, "model")),
    (r"mix/A_log$", ("model", None)),
    (r"mix/out_proj$", ("model", None)),
    # attention living inside hybrid blocks (…/mix/ instead of …/attn/)
    (r"mix/w[qkv]$", (None, "model")),
    (r"mix/b[qkv]$", ("model",)),
    (r"mix/wo$", ("model", None)),
    # rg-lru
    (r"mix/w_in$", (None, "model")),
    (r"mix/w_gate_branch$", (None, "model")),
    (r"mix/w_[ax]$", (None, "model")),
    (r"mix/w_out$", ("model", None)),
    # attention inside hybrid blocks reuses attn/* names via sub paths
]


@dataclasses.dataclass
class ShardingReport:
    matched: int = 0
    fallback_replicated: list = dataclasses.field(default_factory=list)
    degraded_dims: list = dataclasses.field(default_factory=list)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(path: str, ndim: int, mesh, report: ShardingReport,
             shape=None) -> P:
    for pattern, trailing in _RULES:
        if re.search(pattern, path):
            report.matched += 1
            spec = [None] * (ndim - len(trailing)) + list(trailing)
            if shape is not None:
                for i, ax in enumerate(spec):
                    if ax is not None and shape[i] % _axis_size(mesh, ax):
                        report.degraded_dims.append((path, i, ax, shape[i]))
                        spec[i] = None
            return P(*spec)
    report.fallback_replicated.append(path)
    return P(*([None] * ndim))


_SP_ATTN_RE = re.compile(r"attn/(w[qkvo]|b[qkv]|w_o|w_q|w_uk|w_uv|w_dkv)$")


def param_shardings(cfg, mesh, params_tree):
    """→ (shardings pytree of NamedSharding, ShardingReport)."""
    sp_attn = bool(getattr(cfg, "sp_attn", False))
    report = ShardingReport()

    def one(path, leaf):
        ps = _path_str(path)
        if sp_attn and _SP_ATTN_RE.search(ps):
            report.matched += 1
            return NamedSharding(mesh, P(*([None] * np.ndim(leaf))))
        spec = spec_for(ps, np.ndim(leaf), mesh, report,
                        shape=np.shape(leaf))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree), report


def batch_shardings(mesh, batch_tree, global_batch: int):
    """Input batches: shard the batch dim over ("pod","data")."""
    dp = dp_axes(mesh)

    def one(leaf):
        shape = np.shape(leaf)
        spec = [None] * len(shape)
        if shape and shape[0] == global_batch \
                and shape[0] % _axis_size(mesh, dp) == 0:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh, cache_tree, batch: int):
    """Decode caches (stacked (L, B, ...)): batch dim on ("pod","data"),
    then the largest divisible remaining dim on "model" — for attention
    caches that is the sequence dim (context parallelism), for SSM states
    the channel dim."""
    dp = dp_axes(mesh)
    model_size = mesh.shape["model"]
    dp_size = _axis_size(mesh, dp)

    def one(leaf):
        shape = np.shape(leaf)
        spec = [None] * len(shape)
        b_idx = next((i for i, s in enumerate(shape[:2]) if s == batch), None)
        if b_idx is not None and batch % dp_size == 0:
            spec[b_idx] = dp
        rest = [(s, i) for i, s in enumerate(shape)
                if spec[i] is None and i != 0 and s % model_size == 0
                and s >= model_size]
        if rest:
            _, i = max(rest)
            spec[i] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_tree)


def opt_shardings(mesh, opt_tree, param_shardings_tree, zero1: bool = True):
    """Optimizer moments follow their parameter's spec; with zero1=True the
    leading (stacked-layer) dim additionally shards over "data" when
    divisible (ZeRO-1-style state partitioning)."""
    flat_ps = {}

    def record(path, sh):
        flat_ps[_path_str(path)] = sh

    jax.tree_util.tree_map_with_path(record, param_shardings_tree)

    def one(path, leaf):
        ps = _path_str(path)
        for prefix in ("mu/", "nu/", "residual/"):
            if ps.startswith(prefix):
                base = flat_ps.get(ps[len(prefix):])
                if base is None:
                    return NamedSharding(mesh, P())
                spec = list(base.spec) + [None] * (np.ndim(leaf)
                                                   - len(base.spec))
                if zero1 and spec and spec[0] is None and np.ndim(leaf) \
                        and np.shape(leaf)[0] % mesh.shape["data"] == 0:
                    spec[0] = "data"
                return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())            # step counter etc.

    return jax.tree_util.tree_map_with_path(one, opt_tree)
