"""Training CLI: ``PYTHONPATH=src python -m repro.launch.train --arch <id>``.

Single-host execution at reduced scale (this container); the same loop +
sharding machinery the dry-run proves out at 512 devices.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train.loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--quant", default=None, choices=[None, "pim_w4",
                                                      "pim_w8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=args.quant)
    sched = lambda s: warmup_cosine(s, warmup_steps=max(args.steps // 10, 1),
                                    total_steps=args.steps)
    _, hist = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        opt_cfg=AdamWConfig(lr=args.lr), schedule_fn=sched,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        microbatches=args.microbatches, compress=args.compress_grads)
    print(f"done: {len(hist['loss'])} steps, "
          f"final loss {hist['loss'][-1]:.4f}, "
          f"skipped {hist['skipped']}, stragglers {hist['stragglers']}")


if __name__ == "__main__":
    main()
