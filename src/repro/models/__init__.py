"""Model zoo: functional decoder stacks for all assigned architectures."""
from .transformer import (decode_step, init_caches, init_params, loss_fn,
                          prefill)
