"""Attention variants: GQA/MQA (+RoPE, qk-norm, bias, sliding window) and
DeepSeek-V2 MLA (with the absorbed-projection decode path).

All softmax paths go through ``chunked_attention`` — a flash-style
online-softmax over query/key chunks (pure JAX scans) so activations never
materialize the (S, S) score matrix; this is what keeps the 4k-train and
32k-prefill dry-run memory honest.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .common import (apply_norm, apply_rope, dense_init, dtype_of,
                     make_norm_params, rmsnorm)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash-style chunked attention
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, pos_q, pos_k, *, window=None,
                      q_chunk: int = 512, k_chunk: int = 1024,
                      scale: float | None = None, impl: str = "flash"):
    """Online-softmax attention.

    q: (B, Sq, KV, G, dh) — query heads grouped by kv head
    k: (B, Sk, KV, dh)
    v: (B, Sk, KV, dv)
    pos_q: (Sq,) int32; pos_k: (Sk,) or (B, Sk) int32 (−1 = invalid slot)
    Causal: attend iff 0 <= pos_k <= pos_q (and pos_q − pos_k < window).
    Returns (B, Sq, KV, G, dv).

    impl="flash" uses the custom-VJP flash path (models/flash.py): backward
    recomputes tiles instead of saving O(nq·nk) residuals — the §Perf
    memory-bound optimization. impl="naive" keeps the plainly-differentiated
    scan (the paper-faithful baseline for §Perf and the test oracle).
    """
    if impl == "flash":
        from .flash import flash_attention
        B, Sq, KV, G, dh = q.shape
        sc = (1.0 / math.sqrt(dh)) if scale is None else scale
        pq = pos_q.astype(jnp.float32)
        pk = (pos_k if pos_k.ndim == 2 else pos_k[None, :]).astype(
            jnp.float32)
        return flash_attention(q, k, v, pq, pk, window, sc,
                               q_chunk, k_chunk)
    B, Sq, KV, G, dh = q.shape
    Sk, dv = k.shape[1], v.shape[-1]
    scale = (1.0 / math.sqrt(dh)) if scale is None else scale
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    while Sq % qc:
        qc //= 2
    while Sk % kc:
        kc //= 2
    nq, nk = Sq // qc, Sk // kc

    if pos_k.ndim == 1:
        pos_k = pos_k[None, :]                                   # (1, Sk)
    pos_k = pos_k.astype(jnp.int32)
    pos_q = pos_q.astype(jnp.int32)

    # Pre-chunk along sequence axes; scan over chunk indices.
    q_ch = q.reshape(B, nq, qc, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
    k_ch = k.reshape(B, nk, kc, KV, dh).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, nk, kc, KV, dv).transpose(1, 0, 2, 3, 4)
    pq_ch = pos_q.reshape(nq, qc)
    pk_ch = pos_k.reshape(pos_k.shape[0], nk, kc).transpose(1, 0, 2)

    def q_step(_, qx):
        qb, pq = qx                                              # (B,qc,KV,G,dh)

        def k_step(carry, kx):
            m, l, acc = carry
            kb, vb, pk = kx
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            ok = (pk[:, None, None, None, :] >= 0)
            ok &= pk[:, None, None, None, :] <= pq[None, None, None, :, None]
            if window is not None:
                ok &= (pq[None, None, None, :, None]
                       - pk[:, None, None, None, :]) < window
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (k_ch, v_ch, pk_ch))
        out = acc / jnp.maximum(l[..., None], 1e-30)             # (B,KV,G,qc,dv)
        return None, out.transpose(0, 3, 1, 2, 4)                # (B,qc,KV,G,dv)

    _, outs = jax.lax.scan(q_step, None, (q_ch, pq_ch))          # (nq,B,qc,...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], D, H * dh, dt),
        "wk": dense_init(ks[1], D, KV * dh, dt),
        "wv": dense_init(ks[2], D, KV * dh, dt),
        "wo": dense_init(ks[3], H * dh, D, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dt)
        p["bk"] = jnp.zeros((KV * dh,), dt)
        p["bv"] = jnp.zeros((KV * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _gqa_qkv(cfg, p, x, positions):
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(cfg, p, x, positions, window=None):
    """Full causal attention; returns (out, (k, v) for cache building)."""
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    if cfg.sp_attn and S > 1:
        # Sequence-parallel attention: queries sharded on S over "model",
        # (small GQA) k/v gathered — avoids per-layer head resharding when
        # n_heads is not divisible by the model axis.
        from jax.sharding import PartitionSpec as _P
        q = jax.lax.with_sharding_constraint(
            q, _P(None, "model", None, None))
        k = jax.lax.with_sharding_constraint(k, _P(None, None, None, None))
        v = jax.lax.with_sharding_constraint(v, _P(None, None, None, None))
    qg = q.reshape(B, S, KV, H // KV, dh)
    out = chunked_attention(qg, k, v, positions, positions, window=window,
                            q_chunk=cfg.attn_q_chunk,
                            k_chunk=cfg.attn_k_chunk, impl=cfg.attn_impl)
    out = out.reshape(B, S, H * dh)
    if cfg.sp_attn and S > 1:
        from jax.sharding import PartitionSpec as _P
        out = jax.lax.with_sharding_constraint(out, _P(None, "model", None))
    return out @ p["wo"], (k, v)


def gqa_decode(cfg, p, x, pos, cache, window=None):
    """One-token decode. cache: {k:(B,Sc,KV,dh), v:..., kpos:(B,Sc)}."""
    B, S, D = x.shape
    assert S == 1
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    slot = pos % cache["k"].shape[1]                 # ring for SWA, id for full
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(
        cache["kpos"], jnp.full((B, 1), pos, jnp.int32), (0, slot))
    qg = q.reshape(B, 1, KV, H // KV, dh)
    out = chunked_attention(qg, ck, cv, positions, kpos, window=window,
                            q_chunk=1, k_chunk=cfg.attn_k_chunk,
                            impl=cfg.attn_impl)
    out = out.reshape(B, 1, H * dh)
    return out @ p["wo"], {"k": ck, "v": cv, "kpos": kpos}


def gqa_init_cache(cfg, batch: int, max_len: int):
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    cache_len = min(max_len, cfg.sliding_window or max_len)
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, cache_len, KV, dh), dt),
        "v": jnp.zeros((batch, cache_len, KV, dh), dt),
        "kpos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    return {
        "w_dkv": dense_init(ks[0], D, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "w_uk": (dense_init(ks[1], m.kv_lora_rank, H * m.qk_nope_head_dim, dt)
                 .reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)),
        "w_uv": (dense_init(ks[2], m.kv_lora_rank, H * m.v_head_dim, dt)
                 .reshape(m.kv_lora_rank, H, m.v_head_dim)),
        "w_q": dense_init(ks[3], D,
                          H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dt),
        "w_o": dense_init(ks[4], H * m.v_head_dim, D, dt),
    }


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = (x @ p["w_q"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(cfg, p, x, positions):
    m = cfg.mla
    dkv = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_train(cfg, p, x, positions):
    """Non-absorbed path: materialize per-head k/v (best for long matmuls)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_ckv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"])
    # Concatenate nope+rope feature dims: one softmax attention.
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    qc = qc.transpose(0, 1, 2, 3, 4)                    # (B,S,H,1,dh+rope)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = chunked_attention(qc, kc, v, positions, positions, scale=scale,
                            q_chunk=cfg.attn_q_chunk,
                            k_chunk=cfg.attn_k_chunk, impl=cfg.attn_impl)
    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ p["w_o"], (c_kv, k_rope)


def mla_decode(cfg, p, x, pos, cache):
    """Absorbed path: score against the rank-512 latent cache directly —
    the MLA serving trick that makes the KV cache 576 B/token-equivalent."""
    m = cfg.mla
    B, S, _ = x.shape
    assert S == 1
    H = cfg.n_heads
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_ckv(cfg, p, x, positions)
    ck = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, pos, 0))
    kpos = jax.lax.dynamic_update_slice(
        cache["kpos"], jnp.full((B, 1), pos, jnp.int32), (0, pos))
    # Absorb W_uk into q; treat [latent ⊕ rope] as the key/value stream.
    q_abs = jnp.einsum("bthd,rhd->bthr", q_nope, p["w_uk"])
    qc = jnp.concatenate([q_abs, q_rope], axis=-1)[:, :, None, :, :]
    qc = qc.transpose(0, 1, 2, 3, 4)                    # (B,1,1,H,rank+rope)
    kc = jnp.concatenate([ck, cr], axis=-1)[:, :, None, :]   # (B,Sc,1,r+rope)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    ctx = chunked_attention(qc, kc, ck[:, :, None, :], positions, kpos,
                            q_chunk=1, k_chunk=cfg.attn_k_chunk, scale=scale,
                            impl=cfg.attn_impl)          # (B,1,1,H,rank)
    ctx = ctx.reshape(B, 1, H, m.kv_lora_rank)
    v_ctx = jnp.einsum("bthr,rhd->bthd", ctx, p["w_uv"])
    out = v_ctx.reshape(B, 1, H * m.v_head_dim)
    return out @ p["w_o"], {"ckv": ck, "krope": cr, "kpos": kpos}


def mla_init_cache(cfg, batch: int, max_len: int):
    m = cfg.mla
    dt = dtype_of(cfg)
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
        "kpos": jnp.full((batch, max_len), -1, jnp.int32),
    }
