"""Shared building blocks: norms, RoPE, initializers, linear (incl. PIM-quant).

All modules are pure functions over explicit parameter pytrees (nested dicts)
— no framework objects — so the whole stack jits, scans, shards and
checkpoints uniformly.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(dt) * weight + bias


def make_norm_params(cfg, d: int):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), dtype_of(cfg))}
    return {"w": jnp.ones((d,), dtype_of(cfg)),
            "b": jnp.zeros((d,), dtype_of(cfg))}


def apply_norm(cfg, p, x):
    if "b" in p:
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Linear — dense bf16 or the paper's bit-plane PIM-quantized path
# ---------------------------------------------------------------------------

def make_linear_params(key, cfg, d_in: int, d_out: int, bias: bool = False,
                       quantize: bool = False):
    p = {"w": dense_init(key, d_in, d_out, dtype_of(cfg))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype_of(cfg))
    if quantize and cfg.quant:
        from repro.kernels.pim_matmul import ops as pm
        w_int, scales = pm.quantize(p["w"].astype(jnp.float32),
                                    cfg.quant_bits)
        p = {"w_int": w_int, "scales": scales}
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype_of(cfg))
    return p


def linear(cfg, p, x):
    """Apply a linear layer; dispatches to the PIM bit-plane path when the
    params are quantized. The XLA bit-plane formulation is used under jit so
    the op shards/lowers everywhere; the Pallas kernel is the TPU execution
    path for the same math (see kernels/pim_matmul)."""
    if "w_int" in p:
        y = pim_matmul_xla(x, p["w_int"], p["scales"],
                           mode=cfg.quant_mode, bits=cfg.quant_bits,
                           out_dtype=x.dtype)
    else:
        y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def pim_matmul_xla(x, w_int, scales, *, mode: str, bits: int, out_dtype):
    """Shardable XLA formulation of the bit-plane matmul (same math as the
    Pallas kernel; used for distributed lowering / dry-run cost analysis)."""
    from repro.kernels.pim_matmul.ref import plane_coeffs
    xf = x.astype(jnp.bfloat16)
    if mode == "dequant":
        w = (w_int.astype(jnp.float32) * scales[None, :]).astype(jnp.bfloat16)
        return (xf @ w).astype(out_dtype)
    wu = w_int.astype(jnp.int32) & ((1 << bits) - 1)
    acc = None
    for i, c in enumerate(plane_coeffs(bits)):
        plane = ((wu >> i) & 1).astype(jnp.bfloat16)
        term = c * jnp.einsum("...k,kn->...n", xf, plane,
                              preferred_element_type=jnp.float32)
        acc = term if acc is None else acc + term
    return (acc * scales).astype(out_dtype)
