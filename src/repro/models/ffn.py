"""Feed-forward variants: dense (SwiGLU / GELU / GeGLU) and Mixture-of-Experts.

MoE is the GShard-style capacity dispatch, expressed as einsums so the expert
axis shards cleanly on the ``model`` mesh axis (EP). Tokens are processed in
chunks (lax.scan) so the (tokens, experts, capacity) dispatch tensor stays
bounded regardless of global batch; over-capacity tokens are dropped
(standard capacity semantics), with the capacity factor a config knob.

Dense FFNs route through ``common.linear`` so the paper's PIM bit-plane
quantized path (cfg.quant) applies transparently.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, linear, make_linear_params


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_dense_ffn(key, cfg, d_ff: int, quantize: bool = True):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    bias = cfg.mlp_bias
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w1": make_linear_params(ks[0], cfg, D, d_ff, bias, quantize),
            "w3": make_linear_params(ks[1], cfg, D, d_ff, bias, quantize),
            "w2": make_linear_params(ks[2], cfg, d_ff, D, bias, quantize),
        }
    return {
        "w1": make_linear_params(ks[0], cfg, D, d_ff, bias, quantize),
        "w2": make_linear_params(ks[2], cfg, d_ff, D, bias, quantize),
    }


def dense_ffn(cfg, p, x):
    if cfg.act == "swiglu":
        return linear(cfg, p["w2"],
                      jax.nn.silu(linear(cfg, p["w1"], x))
                      * linear(cfg, p["w3"], x))
    if cfg.act == "geglu":
        return linear(cfg, p["w2"],
                      jax.nn.gelu(linear(cfg, p["w1"], x))
                      * linear(cfg, p["w3"], x))
    return linear(cfg, p["w2"], jax.nn.gelu(linear(cfg, p["w1"], x)))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(key, cfg):
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
               * scale).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
               * scale).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
               * (1.0 / math.sqrt(F))).astype(dt),
    }
    if m.n_shared_experts:
        p["shared"] = init_dense_ffn(ks[4], cfg,
                                     m.d_ff_expert * m.n_shared_experts)
    return p


def _capacity(chunk: int, cfg) -> int:
    m = cfg.moe
    c = int(math.ceil(chunk * m.top_k * m.capacity_factor / m.n_experts))
    return max(4, -(-c // 4) * 4)                      # pad to multiple of 4


def _router(cfg, p, xc):
    m = cfg.moe
    logits = (xc.astype(jnp.float32) @ p["router"])              # (c, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)                   # (c, K)
    if m.norm_topk_prob:
        gates = gates / jnp.maximum(
            jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss terms.
    f = jnp.mean(jax.nn.one_hot(idx[:, 0], m.n_experts,
                                dtype=jnp.float32), axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * pbar)
    return gates, idx, aux


def _expert_ffn(p, xe):
    """xe: (E, C, D) → (E, C, D), stacked-expert SwiGLU."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"]))
         * jnp.einsum("ecd,edf->ecf", xe, p["w3"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w2"])


def _gather_chunk(cfg, p, xc, C):
    """Scatter/gather dispatch (§Perf): replaces the O(T·E·C·D) one-hot
    einsums with O(T·E) routing bookkeeping + pure gather/scatter-add data
    movement. Same capacity-drop semantics, slot-major priority."""
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    c = xc.shape[0]
    gates, idx, aux = _router(cfg, p, xc)
    # slot-major flattening (all tokens' slot 0 first — GShard priority)
    e_sm = idx.T.reshape(-1)                                     # (Kc,)
    g_sm = gates.T.reshape(-1)
    tok_sm = jnp.tile(jnp.arange(c, dtype=jnp.int32), K)
    oh = jax.nn.one_hot(e_sm, E, dtype=jnp.int32)                # (Kc, E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              e_sm[:, None], axis=1)[:, 0]       # (Kc,)
    keep = pos < C
    pos_w = jnp.where(keep, pos, C)                              # C = dump col
    # slot tables (E, C+1): token index and gate per expert slot
    slot_tok = jnp.full((E, C + 1), -1, jnp.int32).at[
        e_sm, pos_w].set(tok_sm)[:, :C]
    slot_gate = jnp.zeros((E, C + 1), jnp.float32).at[
        e_sm, pos_w].set(g_sm)[:, :C]
    valid = slot_tok >= 0
    xe = xc[jnp.clip(slot_tok, 0, c - 1)] \
        * valid[..., None].astype(xc.dtype)                      # (E, C, D)
    ye = _expert_ffn(p, xe)                                      # (E, C, D)
    contrib = ye.astype(jnp.float32) * slot_gate[..., None]
    y = jnp.zeros((c, xc.shape[1]), jnp.float32).at[
        jnp.clip(slot_tok, 0, c - 1).reshape(-1)].add(
        contrib.reshape(E * C, -1) * valid.reshape(E * C, 1))
    return y.astype(xc.dtype), aux


def moe_ffn(cfg, p, x):
    """x: (B, S, D) → (y, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    chunk = min(m.dispatch_chunk, T)
    assert T % chunk == 0, (T, chunk)
    nch = T // chunk
    C = _capacity(chunk, cfg)
    E, K = m.n_experts, m.top_k

    def one_chunk_gather(carry, xc):
        y, aux = _gather_chunk(cfg, p, xc, C)
        return carry, (y, aux)

    def one_chunk(carry, xc):
        logits = (xc.astype(jnp.float32) @ p["router"])          # (c, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, K)                     # (c, K)
        if m.norm_topk_prob:
            gates = gates / jnp.maximum(
                jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
        counts = jnp.zeros((E,), jnp.float32)
        dispatch = jnp.zeros((chunk, E, C), jnp.bfloat16)
        combine = jnp.zeros((chunk, E, C), jnp.float32)
        for slot in range(K):                                    # priority
            oh = jax.nn.one_hot(idx[:, slot], E, dtype=jnp.float32)
            pos = jnp.cumsum(oh, axis=0) - 1.0 + counts[None, :]
            counts = counts + jnp.sum(oh, axis=0)
            keep = (pos < C) * oh                                # (c, E)
            pos_c = jnp.clip(pos, 0, C - 1).astype(jnp.int32)
            oh_c = jax.nn.one_hot(pos_c, C, dtype=jnp.float32) \
                * keep[..., None]                                # (c, E, C)
            dispatch = dispatch + oh_c.astype(jnp.bfloat16)
            combine = combine + oh_c * gates[:, slot, None, None]
        xe = jnp.einsum("td,tec->ecd", xc.astype(jnp.bfloat16), dispatch)
        ye = _expert_ffn(p, xe)                                  # (E, C, D)
        yc = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)
        # Switch-style load-balance loss.
        f = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
        pbar = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f * pbar)
        return carry, (yc.astype(x.dtype), aux)

    xs = xf.reshape(nch, chunk, D)
    body = one_chunk_gather if m.impl == "gather" else one_chunk
    _, (ys, auxs) = jax.lax.scan(body, None, xs)
    y = ys.reshape(B, S, D)
    if m.n_shared_experts:
        y = y + dense_ffn(cfg, p["shared"], x)
    return y, m.router_aux_weight * jnp.mean(auxs)
