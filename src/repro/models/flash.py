"""Flash attention with a memory-correct custom VJP (pure JAX scans).

The naive differentiable online-softmax scan saves every (q-chunk × k-chunk)
intermediate for the backward pass — O(nq·nk·qc·kc) f32 residuals, hundreds
of GB/device at 4k–32k sequence lengths. This custom_vjp saves only
(q, k, v, out, lse) and recomputes each tile in the backward, the standard
FlashAttention-2 recurrence:

  fwd : per kv-chunk online softmax (m, l, acc) → out, lse = m + log l
  bwd : delta = Σ dO∘O; per kv-chunk j, per q-chunk i:
            p  = exp(qk^T·scale − lse)
            dv_j += pᵀ dO ;  dp = dO vᵀ ;  ds = p∘(dp − delta)·scale
            dk_j += dsᵀ q ;  dq_i += ds k

Positions are passed as f32 (cast by the caller) so cotangents are plain
zeros. Shapes follow attention.py: q (B,Sq,KV,G,dh), k/v (B,Sk,KV,dh|dv).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunks(x, axis_len, c, batch_first_dims):
    del axis_len, batch_first_dims
    return x, c


def _mask(pq, pk, window):
    """pq: (qc,), pk: (B?, kc) f32 → (B,1,1,qc,kc) bool."""
    ok = pk[:, None, None, None, :] >= 0
    ok &= pk[:, None, None, None, :] <= pq[None, None, None, :, None]
    if window is not None:
        ok &= (pq[None, None, None, :, None]
               - pk[:, None, None, None, :]) < window
    return ok


def _fwd_impl(q, k, v, pos_q, pos_k, window, scale, q_chunk, k_chunk):
    B, Sq, KV, G, dh = q.shape
    Sk, dv = k.shape[1], v.shape[-1]
    qc, kc = min(q_chunk, Sq), min(k_chunk, Sk)
    while Sq % qc:
        qc //= 2
    while Sk % kc:
        kc //= 2
    nq, nk = Sq // qc, Sk // kc

    pk = (pos_k if pos_k.ndim == 2 else pos_k[None, :])
    q_ch = q.reshape(B, nq, qc, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
    k_ch = k.reshape(B, nk, kc, KV, dh).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, nk, kc, KV, dv).transpose(1, 0, 2, 3, 4)
    pq_ch = pos_q.reshape(nq, qc)
    pk_ch = pk.reshape(pk.shape[0], nk, kc).transpose(1, 0, 2)

    def q_step(_, qx):
        qb, pq = qx

        def k_step(carry, kx):
            m, l, acc = carry
            kb, vb, pkc = kx
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = jnp.where(_mask(pq, pkc, window), s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (k_ch, v_ch, pk_ch))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (q_ch, pq_ch))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Sq)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention(q, k, v, pos_q, pos_k, window, scale, q_chunk, k_chunk):
    out, _ = _fwd_impl(q, k, v, pos_q, pos_k, window, scale, q_chunk,
                       k_chunk)
    return out


def _flash_fwd(q, k, v, pos_q, pos_k, window, scale, q_chunk, k_chunk):
    out, lse = _fwd_impl(q, k, v, pos_q, pos_k, window, scale, q_chunk,
                         k_chunk)
    return out, (q, k, v, pos_q, pos_k, out, lse)


def _flash_bwd(window, scale, q_chunk, k_chunk, res, dout):
    q, k, v, pos_q, pos_k, out, lse = res
    B, Sq, KV, G, dh = q.shape
    Sk, dv = k.shape[1], v.shape[-1]
    qc, kc = min(q_chunk, Sq), min(k_chunk, Sk)
    while Sq % qc:
        qc //= 2
    while Sk % kc:
        kc //= 2
    nq, nk = Sq // qc, Sk // kc

    doutf = dout.astype(jnp.float32)
    outf = out.astype(jnp.float32)
    delta = jnp.sum(doutf * outf, axis=-1)               # (B,Sq,KV,G)
    delta = delta.transpose(0, 2, 3, 1)                  # (B,KV,G,Sq)

    pk = (pos_k if pos_k.ndim == 2 else pos_k[None, :])
    q_ch = q.reshape(B, nq, qc, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
    do_ch = doutf.reshape(B, nq, qc, KV, G, dv).transpose(1, 0, 2, 3, 4, 5)
    k_ch = k.reshape(B, nk, kc, KV, dh).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, nk, kc, KV, dv).transpose(1, 0, 2, 3, 4)
    pq_ch = pos_q.reshape(nq, qc)
    pk_ch = pk.reshape(pk.shape[0], nk, kc).transpose(1, 0, 2)
    lse_ch = lse.reshape(B, KV, G, nq, qc).transpose(3, 0, 1, 2, 4)
    dl_ch = delta.reshape(B, KV, G, nq, qc).transpose(3, 0, 1, 2, 4)

    def kv_step(dq_acc, kx):
        kb, vb, pkc = kx

        def q_step(carry, qx):
            dk_j, dv_j = carry
            qb, dob, pq, lse_i, dl_i = qx
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            s = jnp.where(_mask(pq, pkc, window), s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])             # (B,KV,G,qc,kc)
            dv_c = jnp.einsum("bkgqs,bqkgd->bskd", p,
                              dob)                        # (B,kc,KV,dv)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None]) * scale
            dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                              qb.astype(jnp.float32))
            dq_c = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                              kb.astype(jnp.float32))     # (B,qc,KV,G,dh)
            return (dk_j + dk_c, dv_j + dv_c), dq_c

        dk0 = jnp.zeros((B, kc, KV, dh), jnp.float32)
        dv0 = jnp.zeros((B, kc, KV, dv), jnp.float32)
        (dk_j, dv_j), dq_parts = jax.lax.scan(
            q_step, (dk0, dv0), (q_ch, do_ch, pq_ch, lse_ch, dl_ch))
        dq_full = dq_parts.transpose(1, 0, 2, 3, 4, 5).reshape(
            B, Sq, KV, G, dh)
        return dq_acc + dq_full, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, KV, G, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (k_ch, v_ch, pk_ch))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, dh)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, dv)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(pos_q), jnp.zeros_like(pos_k))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
