"""RG-LRU recurrent block (RecurrentGemma / Griffin family).

    r_t = sigmoid(x_t @ W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t @ W_x + b_x)            (input gate)
    a_t = exp(c * log(sigmoid(Λ)) * r_t)      (data-dependent decay)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

wrapped in the Griffin temporal block: dual in-projection (value branch →
conv1d → RG-LRU; gate branch → GeLU), multiplicative merge, out-projection.
Training uses the same chunked associative scan as ``ssm.py`` (element-wise
state — no d_state axis). Decode carries (h, conv) — O(1) state, which is
what qualifies the arch for the 500k long-context cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of


def init_rglru(key, cfg):
    r = cfg.rglru
    D, W = cfg.d_model, r.lru_width
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], D, W, dt),
        "w_gate_branch": dense_init(ks[1], D, W, dt),
        "conv_w": (jax.random.normal(ks[2], (r.d_conv, W), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((W,), dt),
        "w_a": dense_init(ks[3], W, W, dt),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_x": dense_init(ks[4], W, W, dt),
        "b_x": jnp.zeros((W,), jnp.float32),
        # Λ init so a ≈ 0.9..0.999 at r=1 (standard LRU init range).
        "lam": jnp.linspace(2.0, 6.0, W).astype(jnp.float32),
        "w_out": dense_init(ks[5], W, D, dt),
    }


def _conv_causal(p, x, d_conv):
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["conv_w"][i]
              for i in range(d_conv))
    return out + p["conv_b"]


def _gates(cfg, p, u):
    """u: (B,*,W) value branch → a_t, beta_t·x_t terms (f32)."""
    uf = u.astype(jnp.float32)
    rg = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    ig = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a0 = jax.nn.log_sigmoid(p["lam"])                 # (W,) ≤ 0
    log_a = cfg.rglru.c_exponent * log_a0 * rg
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * ig * uf


def rglru_train(cfg, p, x):
    """x: (B, S, D) → (B, S, D)."""
    r = cfg.rglru
    B, S, D = x.shape
    W = r.lru_width
    u_pre = x @ p["w_in"]
    u = _conv_causal(p, u_pre, r.d_conv)
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    a, bx = _gates(cfg, p, u)                             # (B,S,W) f32

    chunk = min(r.scan_chunk, S)
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        # Identity-padded recurrence steps (a=1, b=0): exact final state.
        a = jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, Sp - S), (0, 0)))
    nch = Sp // chunk

    def chunk_step(h0, xs):
        a_c, b_c = xs

        def combine(l, rr):
            al, bl = l
            ar, br = rr
            return al * ar, bl * ar + br

        a_s, b_s = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h = a_s * h0[:, None] + b_s
        return h[:, -1], h

    a_ch = a.reshape(B, nch, chunk, W).transpose(1, 0, 2, 3)
    b_ch = bx.reshape(B, nch, chunk, W).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((B, W), jnp.float32)
    h_last, hs = jax.lax.scan(chunk_step, h0, (a_ch, b_ch))
    h = hs.transpose(1, 0, 2, 3).reshape(B, Sp, W)[:, :S]
    y = h.astype(x.dtype) * gate
    state = {"h": h_last, "conv": u_pre[:, S - (r.d_conv - 1):, :]}
    return y @ p["w_out"], state


def rglru_decode(cfg, p, x, state):
    """x: (B,1,D); state: {"h": (B,W) f32, "conv": (B, d_conv-1, W)}."""
    r = cfg.rglru
    u_pre = x @ p["w_in"]                                  # (B,1,W)
    window = jnp.concatenate([state["conv"], u_pre], axis=1)
    conv = jnp.einsum("bcw,cw->bw", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(
        jnp.float32)
    u = conv[:, None, :].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    a, bx = _gates(cfg, p, u)                              # (B,1,W)
    h = a[:, 0] * state["h"] + bx[:, 0]
    y = h[:, None, :].astype(x.dtype) * gate
    return y @ p["w_out"], {"h": h, "conv": window[:, 1:]}


def rglru_init_state(cfg, batch: int):
    r = cfg.rglru
    return {
        "h": jnp.zeros((batch, r.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, r.d_conv - 1, r.lru_width), dtype_of(cfg)),
    }
