"""Mamba-1 selective SSM block (falcon-mamba-7b family).

Training/prefill uses a chunked parallel scan: lax.scan over sequence chunks
carrying the (B, d_inner, d_state) state, with an associative scan inside
each chunk — the (B, chunk, d_inner, d_state) intermediate is the only large
activation and its size is a config knob (ssm.scan_chunk).

Decode is the O(1)-state recurrence with a ring conv state — this is what
makes the arch eligible for the 500k-token long-context cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, rmsnorm


def init_mamba(key, cfg):
    s = cfg.ssm
    D = cfg.d_model
    I = s.expand * D
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": dense_init(ks[0], D, 2 * I, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, I), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((I,), dt),
        "x_proj": dense_init(ks[2], I, s.dt_rank + 2 * s.d_state, dt),
        "dt_proj": dense_init(ks[3], s.dt_rank, I, dt),
        "dt_bias": jnp.full((I,), -4.6, jnp.float32),   # softplus ≈ 0.01
        "A_log": jnp.log(jnp.tile(jnp.arange(1, s.d_state + 1,
                                             dtype=jnp.float32), (I, 1))),
        "D": jnp.ones((I,), jnp.float32),
        "out_proj": dense_init(ks[4], I, D, dt),
    }
    if s.extra_norms:
        p["dt_norm"] = jnp.ones((s.dt_rank,), dt)
        p["b_norm"] = jnp.ones((s.d_state,), dt)
        p["c_norm"] = jnp.ones((s.d_state,), dt)
    return p


def _conv_train(p, x, d_conv):
    """Causal depthwise conv over (B, S, I)."""
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["conv_w"][i]
              for i in range(d_conv))
    return out + p["conv_b"]


def mamba_train(cfg, p, x):
    """x: (B, S, D) → (B, S, D). Chunked parallel selective scan."""
    s = cfg.ssm
    B, S, D = x.shape
    I = s.expand * D
    N = s.d_state
    xz = x @ p["in_proj"]
    u_pre, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_conv_train(p, u_pre, s.d_conv))
    dbc = u @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(dbc, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    if s.extra_norms:
        dt_r = rmsnorm(dt_r, p["dt_norm"])
        Bm = rmsnorm(Bm, p["b_norm"])
        Cm = rmsnorm(Cm, p["c_norm"])
    dt = jax.nn.softplus(dt_r.astype(jnp.float32)
                         @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                     # (I, N)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    chunk = min(s.scan_chunk, S)
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        # Pad with identity recurrence steps (dt=0 → a=1, b=0): the final
        # state is exact; padded outputs are sliced off below.
        pad = ((0, 0), (0, Sp - S), (0, 0))
        dt = jnp.pad(dt, pad)
        Bm = jnp.pad(Bm, pad)
        Cm = jnp.pad(Cm, pad)
        uf_s = jnp.pad(uf, pad)
    else:
        uf_s = uf
    nch = Sp // chunk

    def chunk_step(h0, xs):
        dt_c, b_c, c_c, u_c = xs          # (B,chunk,I) / (B,chunk,N) ...
        a = jnp.exp(dt_c[..., None] * A)                        # (B,c,I,N)
        bx = (dt_c * u_c)[..., None] * b_c[:, :, None, :]       # (B,c,I,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_s, b_s = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h = a_s * h0[:, None] + b_s                              # (B,c,I,N)
        y = jnp.einsum("bcin,bcn->bci", h, c_c)
        return h[:, -1], y

    dt_ch = dt.reshape(B, nch, chunk, I).transpose(1, 0, 2, 3)
    b_ch = Bm.reshape(B, nch, chunk, N).transpose(1, 0, 2, 3)
    c_ch = Cm.reshape(B, nch, chunk, N).transpose(1, 0, 2, 3)
    u_ch = uf_s.reshape(B, nch, chunk, I).transpose(1, 0, 2, 3)
    h_init = jnp.zeros((B, I, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h_init, (dt_ch, b_ch, c_ch, u_ch))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, I)[:, :S]
    y = y + uf * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    state = {"h": h_last,
             "conv": u_pre[:, S - (s.d_conv - 1):, :]}  # ring tail for decode
    return y @ p["out_proj"], state


def mamba_decode(cfg, p, x, state):
    """x: (B, 1, D); state: {"h": (B,I,N) f32, "conv": (B, d_conv-1, I)}."""
    s = cfg.ssm
    B = x.shape[0]
    xz = x @ p["in_proj"]
    u_pre, z = jnp.split(xz, 2, axis=-1)                         # (B,1,I)
    window = jnp.concatenate([state["conv"], u_pre], axis=1)     # (B,dc,I)
    conv = jnp.einsum("bci,ci->bi", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(
        jnp.float32)
    u = jax.nn.silu(conv)[:, None, :].astype(x.dtype)            # (B,1,I)
    dbc = u @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(dbc, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    if s.extra_norms:
        dt_r = rmsnorm(dt_r, p["dt_norm"])
        Bm = rmsnorm(Bm, p["b_norm"])
        Cm = rmsnorm(Cm, p["c_norm"])
    dt = jax.nn.softplus(dt_r.astype(jnp.float32)
                         @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                           # (B,I,N)
    bx = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
        * Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = a * state["h"] + bx
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0].astype(jnp.float32))
    y = y + u[:, 0].astype(jnp.float32) * p["D"]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}


def mamba_init_state(cfg, batch: int):
    s = cfg.ssm
    I = s.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, I, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, I), dtype_of(cfg)),
    }
