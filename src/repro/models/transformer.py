"""Decoder assembly: embeds → scanned layer stacks → chunked LM loss / decode.

Layer stacks are homogeneous scan units with weights stacked along a leading
axis, so HLO size is depth-independent (critical for the 512-device dry-run
compiles). Heterogeneous archs decompose into a few homogeneous stacks:

  dense / moe / vlm / audio : one stack of (attn + ffn|moe) layers
  deepseek (first_k_dense)  : unstacked dense layer 0 + stacked MoE layers
  ssm                       : one stack of mamba layers
  hybrid (recurrentgemma)   : stacked (rec, rec, attn) super-blocks + a
                              stacked tail of leftover rec layers

Each family provides (init / train / decode / init_cache) per scan unit; the
generic drivers below thread residuals, MoE aux losses, and cache pytrees
through ``lax.scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import rglru as rg
from . import ssm as ssm_mod
from .common import (apply_norm, dtype_of, embed_init, make_norm_params,
                     sinusoidal_pos_emb)


# ---------------------------------------------------------------------------
# Per-family scan units
# ---------------------------------------------------------------------------

def _init_attn(key, cfg):
    return attn.init_mla(key, cfg) if cfg.attn_type == "mla" \
        else attn.init_gqa(key, cfg)


def _attn_train(cfg, p, x, positions):
    if cfg.attn_type == "mla":
        out, kv = attn.mla_train(cfg, p, x, positions)
    else:
        out, kv = attn.gqa_train(cfg, p, x, positions,
                                 window=cfg.sliding_window)
    return out, kv


def _attn_decode(cfg, p, x, pos, cache):
    if cfg.attn_type == "mla":
        return attn.mla_decode(cfg, p, x, pos, cache)
    return attn.gqa_decode(cfg, p, x, pos, cache, window=cfg.sliding_window)


def _attn_init_cache(cfg, batch, max_len):
    if cfg.attn_type == "mla":
        return attn.mla_init_cache(cfg, batch, max_len)
    return attn.gqa_init_cache(cfg, batch, max_len)


def _attn_cache_from_prefill(cfg, kv, max_len):
    """Build a decode cache from prefill-produced full-sequence k/v."""
    if cfg.attn_type == "mla":
        c_kv, k_rope = kv
        B, S = c_kv.shape[:2]
        cache = attn.mla_init_cache(cfg, B, max_len)
        cache["ckv"] = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv, (0, 0, 0))
        cache["krope"] = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope, (0, 0, 0))
        cache["kpos"] = jax.lax.dynamic_update_slice(
            cache["kpos"], jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                            (B, S)), (0, 0))
        return cache
    k, v = kv
    B, S = k.shape[:2]
    cache = attn.gqa_init_cache(cfg, B, max_len)
    Sc = cache["k"].shape[1]
    if S >= Sc:                      # keep the last window at ring slots
        pos = jnp.arange(S - Sc, S, dtype=jnp.int32)
        slots = pos % Sc
        ck = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -Sc:])
        cv = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -Sc:])
        kpos = jnp.zeros((B, Sc), jnp.int32).at[:, slots].set(
            jnp.broadcast_to(pos, (B, Sc)))
        return {"k": ck, "v": cv, "kpos": kpos}
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    cache["kpos"] = jax.lax.dynamic_update_slice(
        cache["kpos"], jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                        (B, S)), (0, 0))
    return cache


# -- standard transformer layer (attn + ffn/moe) ----------------------------

def init_tf_layer(key, cfg, moe: bool):
    ks = jax.random.split(key, 4)
    d_ff = cfg.d_ff
    if cfg.moe is not None and not moe and cfg.moe.d_ff_dense:
        d_ff = cfg.moe.d_ff_dense
    return {
        "ln1": make_norm_params(cfg, cfg.d_model),
        "attn": _init_attn(ks[0], cfg),
        "ln2": make_norm_params(cfg, cfg.d_model),
        "ffn": (ffn_mod.init_moe(ks[1], cfg) if moe
                else ffn_mod.init_dense_ffn(ks[2], cfg, d_ff)),
    }


def _sp_constraint(cfg, x):
    if cfg.sp_attn and x.ndim == 3 and x.shape[1] > 1:
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(x, _P(None, "model", None))
    return x


def tf_layer_train(cfg, p, x, positions, moe: bool):
    x = _sp_constraint(cfg, x)
    a, kv = _attn_train(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                        positions)
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    if moe:
        f, aux = ffn_mod.moe_ffn(cfg, p["ffn"], h)
    else:
        f, aux = ffn_mod.dense_ffn(cfg, p["ffn"], h), 0.0
    return x + f, aux, kv


def tf_layer_decode(cfg, p, x, pos, cache, moe: bool):
    a, cache = _attn_decode(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                            pos, cache)
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    if moe:
        f, _ = ffn_mod.moe_ffn(cfg, p["ffn"], h)
    else:
        f = ffn_mod.dense_ffn(cfg, p["ffn"], h)
    return x + f, cache


# -- mamba layer -------------------------------------------------------------

def init_mamba_layer(key, cfg):
    return {"ln": make_norm_params(cfg, cfg.d_model),
            "mix": ssm_mod.init_mamba(key, cfg)}


def mamba_layer_train(cfg, p, x, positions):
    del positions
    y, state = ssm_mod.mamba_train(cfg, p["mix"], apply_norm(cfg, p["ln"], x))
    return x + y, 0.0, state


def mamba_layer_decode(cfg, p, x, pos, state):
    del pos
    y, state = ssm_mod.mamba_decode(cfg, p["mix"], apply_norm(cfg, p["ln"], x),
                                    state)
    return x + y, state


# -- hybrid (Griffin) super-block: rec, rec, attn, each + MLP ----------------

def init_hybrid_sub(key, cfg, kind: str):
    ks = jax.random.split(key, 2)
    mix = rg.init_rglru(ks[0], cfg) if kind == "rec" else _init_attn(ks[0], cfg)
    return {
        "ln1": make_norm_params(cfg, cfg.d_model),
        "mix": mix,
        "ln2": make_norm_params(cfg, cfg.d_model),
        "mlp": ffn_mod.init_dense_ffn(ks[1], cfg, cfg.d_ff),
    }


def hybrid_sub_train(cfg, p, x, positions, kind: str):
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rec":
        y, kv = rg.rglru_train(cfg, p["mix"], h)
    else:
        y, kv = _attn_train(cfg, p["mix"], h, positions)
    x = x + y
    x = x + ffn_mod.dense_ffn(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x, kv


def hybrid_sub_decode(cfg, p, x, pos, cache, kind: str):
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "rec":
        y, cache = rg.rglru_decode(cfg, p["mix"], h, cache)
    else:
        y, cache = _attn_decode(cfg, p["mix"], h, pos, cache)
    x = x + y
    x = x + ffn_mod.dense_ffn(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
    return x, cache


# ---------------------------------------------------------------------------
# Stack drivers
# ---------------------------------------------------------------------------

def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def scan_stack_train(cfg, stack, x, positions, unit_train):
    """unit_train(lp, x) -> (x, aux, cache_entry); caches returned stacked."""
    body = unit_train
    if cfg.remat:
        body = jax.checkpoint(body)

    def step(carry, lp):
        x, aux = carry
        x, aux_i, kv = body(lp, x)
        return (x, aux + aux_i), kv

    (x, aux), kvs = jax.lax.scan(step, (x, 0.0), stack)
    return x, aux, kvs


def scan_stack_decode(stack, caches, x, unit_decode):
    def step(x, xs):
        lp, cache = xs
        x, cache = unit_decode(lp, x, cache)
        return x, cache

    x, caches = jax.lax.scan(step, x, (stack, caches))
    return x, caches


# ---------------------------------------------------------------------------
# Model: init / train / prefill / decode
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg):
    pat = cfg.rglru.pattern
    n_blocks = cfg.n_layers // len(pat)
    tail = cfg.n_layers % len(pat)
    assert all(k == "rec" for k in cfg.rglru.pattern[:tail]), \
        "tail layers must be the leading (rec) prefix of the pattern"
    return n_blocks, tail


def init_params(cfg, key):
    keys = jax.random.split(key, 8)
    params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                  dtype_of(cfg)),
              "final_norm": make_norm_params(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        if cfg.frontend == "audio_frames":
            params["heads"] = jax.vmap(
                lambda k: embed_init(k, cfg.vocab_size, cfg.d_model,
                                     dtype_of(cfg)).T)(
                jax.random.split(keys[1], cfg.n_codebooks))
        else:
            params["lm_head"] = embed_init(keys[1], cfg.vocab_size,
                                           cfg.d_model, dtype_of(cfg)).T
    fam = cfg.family
    if fam == "ssm":
        params["stack"] = _stacked_init(
            lambda k: init_mamba_layer(k, cfg), keys[2], cfg.n_layers)
    elif cfg.rglru is not None:
        n_blocks, tail = _hybrid_layout(cfg)
        pat = cfg.rglru.pattern

        def init_block(k):
            sub = jax.random.split(k, len(pat))
            return {f"sub{i}": init_hybrid_sub(sub[i], cfg, kind)
                    for i, kind in enumerate(pat)}

        params["blocks"] = _stacked_init(init_block, keys[2], n_blocks)
        if tail:
            params["tail"] = _stacked_init(
                lambda k: init_hybrid_sub(k, cfg, "rec"), keys[3], tail)
    elif cfg.moe is not None:
        fk = cfg.moe.first_k_dense
        if fk:
            params["dense_head_layers"] = _stacked_init(
                lambda k: init_tf_layer(k, cfg, moe=False), keys[3], fk)
        params["stack"] = _stacked_init(
            lambda k: init_tf_layer(k, cfg, moe=True), keys[2],
            cfg.n_layers - fk)
    else:
        params["stack"] = _stacked_init(
            lambda k: init_tf_layer(k, cfg, moe=False), keys[2], cfg.n_layers)
    return params


def embed_inputs(cfg, params, batch):
    """Returns (x, positions, n_prefix) — n_prefix = non-text prefix length."""
    if cfg.frontend == "audio_frames":
        x = batch["frame_embeds"].astype(dtype_of(cfg))
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        x = x + sinusoidal_pos_emb(pos, cfg.d_model).astype(x.dtype)
        return x, pos, 0
    tok_emb = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision_patches":
        patches = batch["patch_embeds"].astype(dtype_of(cfg))
        x = jnp.concatenate([patches, tok_emb], axis=1)
        n_prefix = patches.shape[1]
    else:
        x = tok_emb
        n_prefix = 0
    if cfg.pos_emb == "sinusoidal":
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + sinusoidal_pos_emb(pos, cfg.d_model).astype(x.dtype)
    return x, jnp.arange(x.shape[1], dtype=jnp.int32), n_prefix


def backbone_train(cfg, params, x, positions):
    """Run all layer stacks; returns (hidden, aux_loss, caches-pytree)."""
    caches = {}
    aux = 0.0
    if cfg.family == "ssm":
        unit = lambda lp, h: mamba_layer_train(cfg, lp, h, positions)
        x, aux, states = scan_stack_train(cfg, params["stack"], x, positions,
                                          unit)
        caches["stack"] = states
    elif cfg.rglru is not None:
        pat = cfg.rglru.pattern

        def block_train(lp, h):
            entries = {}
            for i, kind in enumerate(pat):
                h, kv = hybrid_sub_train(cfg, lp[f"sub{i}"], h, positions,
                                         kind)
                entries[f"sub{i}"] = kv
            return h, 0.0, entries

        x, _, kvs = scan_stack_train(cfg, params["blocks"], x, positions,
                                     block_train)
        caches["blocks"] = kvs
        if "tail" in params:
            def tail_unit(lp, h):
                h, st = hybrid_sub_train(cfg, lp, h, positions, "rec")
                return h, 0.0, st

            x, _, tails = scan_stack_train(cfg, params["tail"], x, positions,
                                           tail_unit)
            caches["tail"] = tails
    elif cfg.moe is not None:
        if "dense_head_layers" in params:
            unit = lambda lp, h: tf_layer_train(cfg, lp, h, positions,
                                                moe=False)
            x, aux0, kv0 = scan_stack_train(cfg, params["dense_head_layers"],
                                            x, positions, unit)
            aux += aux0
            caches["dense_head"] = kv0
        unit = lambda lp, h: tf_layer_train(cfg, lp, h, positions, moe=True)
        x, aux1, kvs = scan_stack_train(cfg, params["stack"], x, positions,
                                        unit)
        aux += aux1
        caches["stack"] = kvs
    else:
        unit = lambda lp, h: tf_layer_train(cfg, lp, h, positions, moe=False)
        x, aux, kvs = scan_stack_train(cfg, params["stack"], x, positions,
                                       unit)
        caches["stack"] = kvs
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Loss (sequence-chunked cross entropy)
# ---------------------------------------------------------------------------

def _logits_chunk(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        w.astype(jnp.float32))
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def lm_loss(cfg, params, h, labels, mask):
    """h: (B,S,D); labels: (B,S) int32; mask: (B,S) {0,1}. Chunked over S."""
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    while S % chunk:                     # e.g. vlm text span after the prefix
        chunk //= 2
    nch = S // chunk

    def step(carry, xs):
        hc, lc, mc = xs
        logits = _logits_chunk(cfg, params, hc)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    hs = h.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nch, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, nch, chunk).transpose(1, 0, 2).astype(jnp.float32)
    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def musicgen_loss(cfg, params, h, labels, mask):
    """labels: (B,S,n_codebooks)."""
    losses = []
    for c in range(cfg.n_codebooks):
        w = params["heads"][c]
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            w.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., c:c + 1].astype(
            jnp.int32), axis=-1)[..., 0]
        losses.append(jnp.sum((lse - gold) * mask) / jnp.maximum(
            jnp.sum(mask), 1.0))
    return sum(losses) / len(losses)


def loss_fn(cfg, params, batch):
    """Full training objective. batch: tokens/labels/mask (+ modality)."""
    x, positions, n_prefix = embed_inputs(cfg, params, batch)
    h, aux, _ = backbone_train(cfg, params, x, positions)
    if cfg.frontend == "audio_frames":
        mask = batch.get("mask", jnp.ones(batch["labels"].shape[:2],
                                          jnp.float32))
        loss = musicgen_loss(cfg, params, h, batch["labels"], mask)
    else:
        if n_prefix:
            h = h[:, n_prefix:]
        labels = batch["labels"]
        mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
        loss = lm_loss(cfg, params, h, labels, mask)
    return loss + aux, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: caches, prefill, single-token decode
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    """Zero-initialized decode caches for every layer stack."""
    caches = {}
    if cfg.family == "ssm":
        caches["stack"] = jax.vmap(
            lambda _: ssm_mod.mamba_init_state(cfg, batch))(
            jnp.arange(cfg.n_layers))
    elif cfg.rglru is not None:
        n_blocks, tail = _hybrid_layout(cfg)
        pat = cfg.rglru.pattern

        def one_block(_):
            return {f"sub{i}": (rg.rglru_init_state(cfg, batch)
                                if kind == "rec"
                                else _attn_init_cache(cfg, batch, max_len))
                    for i, kind in enumerate(pat)}

        caches["blocks"] = jax.vmap(one_block)(jnp.arange(n_blocks))
        if tail:
            caches["tail"] = jax.vmap(
                lambda _: rg.rglru_init_state(cfg, batch))(jnp.arange(tail))
    elif cfg.moe is not None and cfg.moe.first_k_dense:
        caches["dense_head"] = jax.vmap(
            lambda _: _attn_init_cache(cfg, batch, max_len))(
            jnp.arange(cfg.moe.first_k_dense))
        caches["stack"] = jax.vmap(
            lambda _: _attn_init_cache(cfg, batch, max_len))(
            jnp.arange(cfg.n_layers - cfg.moe.first_k_dense))
    else:
        caches["stack"] = jax.vmap(
            lambda _: _attn_init_cache(cfg, batch, max_len))(
            jnp.arange(cfg.n_layers))
    return caches


def prefill(cfg, params, batch, max_cache_len: int):
    """Process a prompt batch; returns (last-position logits, decode caches).

    Recurrent states pass through as-is; attention k/v convert to (possibly
    ring-windowed) decode caches, vmapped over the stacked layer axis.
    """
    x, positions, n_prefix = embed_inputs(cfg, params, batch)
    h, _, raw = backbone_train(cfg, params, x, positions)

    conv = jax.vmap(lambda kv: _attn_cache_from_prefill(cfg, kv,
                                                        max_cache_len))
    caches = {}
    if cfg.family == "ssm":
        caches["stack"] = raw["stack"]
    elif cfg.rglru is not None:
        pat = cfg.rglru.pattern
        caches["blocks"] = {
            f"sub{i}": (raw["blocks"][f"sub{i}"] if kind == "rec"
                        else conv(raw["blocks"][f"sub{i}"]))
            for i, kind in enumerate(pat)}
        if "tail" in raw:
            caches["tail"] = raw["tail"]
    else:
        if "dense_head" in raw:
            caches["dense_head"] = conv(raw["dense_head"])
        caches["stack"] = conv(raw["stack"])
    if cfg.frontend == "audio_frames":
        logits = jnp.einsum("bsd,cdv->bscv", h[:, -1:, :].astype(jnp.float32),
                            params["heads"].astype(jnp.float32))
    else:
        logits = _logits_chunk(cfg, params, h[:, -1:, :])
    return logits, caches


def decode_step(cfg, params, token_inputs, pos, caches):
    """One decode step at absolute position ``pos``.

    token_inputs: {"tokens": (B,1)} or {"frame_embeds": (B,1,D)}.
    Returns (logits (B,1,V or n_codebooks×V), new caches).
    """
    if cfg.frontend == "audio_frames":
        x = token_inputs["frame_embeds"].astype(dtype_of(cfg))
        x = x + sinusoidal_pos_emb(
            jnp.full((1,), pos, jnp.int32), cfg.d_model).astype(x.dtype)
    else:
        x = params["embed"][token_inputs["tokens"]]
        if cfg.pos_emb == "sinusoidal":
            x = x + sinusoidal_pos_emb(
                jnp.full((1,), pos, jnp.int32), cfg.d_model).astype(x.dtype)

    new_caches = {}
    if cfg.family == "ssm":
        unit = lambda lp, h, c: mamba_layer_decode(cfg, lp, h, pos, c)
        x, new_caches["stack"] = scan_stack_decode(
            params["stack"], caches["stack"], x, unit)
    elif cfg.rglru is not None:
        pat = cfg.rglru.pattern

        def block_decode(lp, h, c):
            out_c = {}
            for i, kind in enumerate(pat):
                h, out_c[f"sub{i}"] = hybrid_sub_decode(
                    cfg, lp[f"sub{i}"], h, pos, c[f"sub{i}"], kind)
            return h, out_c

        x, new_caches["blocks"] = scan_stack_decode(
            params["blocks"], caches["blocks"], x, block_decode)
        if "tail" in params:
            unit = lambda lp, h, c: hybrid_sub_decode(cfg, lp, h, pos, c,
                                                      "rec")
            x, new_caches["tail"] = scan_stack_decode(
                params["tail"], caches["tail"], x, unit)
    else:
        moe = cfg.moe is not None
        if "dense_head" in caches:
            unit = lambda lp, h, c: tf_layer_decode(cfg, lp, h, pos, c,
                                                    moe=False)
            x, new_caches["dense_head"] = scan_stack_decode(
                params["dense_head_layers"], caches["dense_head"], x, unit)
        unit = lambda lp, h, c: tf_layer_decode(cfg, lp, h, pos, c, moe=moe)
        x, new_caches["stack"] = scan_stack_decode(
            params["stack"], caches["stack"], x, unit)

    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.frontend == "audio_frames":
        logits = jnp.einsum("bsd,cdv->bscv", x.astype(jnp.float32),
                            params["heads"].astype(jnp.float32))
    else:
        logits = _logits_chunk(cfg, params, x)
    return logits, new_caches
