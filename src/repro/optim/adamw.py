"""AdamW with decoupled weight decay, global-norm clipping and f32 moments.

Non-float leaves (the PIM bit-plane int8 codes) are frozen: ``partition``
splits the param tree into a trainable tree (None at frozen positions — an
empty pytree, invisible to jax.grad) and a frozen tree; ``merge`` recombines.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def _is_trainable(leaf) -> bool:
    dtype = getattr(leaf, "dtype", None) or jnp.asarray(leaf).dtype
    return jnp.issubdtype(dtype, jnp.floating)


def partition(params):
    """→ (trainable_tree, frozen_tree); frozen positions are None in the
    trainable tree and vice versa."""
    train = jax.tree.map(lambda x: x if _is_trainable(x) else None, params)
    frozen = jax.tree.map(lambda x: None if _is_trainable(x) else x, params)
    return train, frozen


def merge(train, frozen):
    return jax.tree.map(
        lambda t, f: t if f is None else f,
        train, frozen,
        is_leaf=lambda x: x is None)


def init_state(train_params):
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           train_params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           train_params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(train_params, grads, state, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0):
    """Returns (new_train_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        delta = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(train_params)
    flat = [upd(p, g, m, n) for p, g, m, n in
            zip(flat_p, jax.tree.leaves(grads),
                jax.tree.leaves(state["mu"]), jax.tree.leaves(state["nu"]))]
    new_params = treedef.unflatten([f[0] for f in flat])
    new_state = {"mu": treedef.unflatten([f[1] for f in flat]),
                 "nu": treedef.unflatten([f[2] for f in flat]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm}
