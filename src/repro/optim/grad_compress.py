"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At multi-pod scale the pod-to-pod (DCN / optical) hop is the narrow link, so
gradients crossing it are quantized to int8 with per-tensor scales and an
error-feedback residual (Seide et al. / EF-SGD style):

    q = round(g / s) clipped to int8,  s = max|g| / 127
    residual' = g - q * s    (carried to the next step — unbiased over time)

The compressed payload is 4x smaller than f32 (2x vs bf16). ``psum_compressed``
wires this into a shard_map collective; with plain pjit the same trick applies
at the gradient-tree level via compress/decompress around the reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g, residual=None):
    """→ (codes int8, scale f32 scalar, new_residual)."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - codes.astype(jnp.float32) * scale
    return codes, scale, new_residual


def decompress(codes, scale):
    return codes.astype(jnp.float32) * scale


def compress_tree(grads, residuals=None):
    """Tree-wise compression; returns (codes_tree, scales_tree, residuals)."""
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                 grads)
    out = jax.tree.map(compress, grads, residuals)
    codes = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return codes, scales, resid


def decompress_tree(codes, scales):
    return jax.tree.map(decompress, codes, scales)


def psum_compressed(g, axis_name: str, residual=None):
    """shard_map building block: int8-quantize, sum codes in int32 across the
    axis, rescale. Scales are per-participant, so codes are pre-scaled to a
    shared max before the reduction."""
    codes, scale, new_residual = compress(g, residual)
    # Use the max scale across the axis so summed codes share one scale.
    smax = jax.lax.pmax(scale, axis_name)
    rescaled = jnp.round(codes.astype(jnp.float32) * (scale / smax))
    total = jax.lax.psum(rescaled.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax, new_residual
