"""Batched serving engine: prefill + jitted single-token decode loop.

Greedy or temperature sampling over a batch of equal-length prompts (a
production engine adds continuous batching on top; the step function here is
exactly the unit the dry-run lowers as ``serve_step``).

The whole decode loop — token sampling, key splitting, and the per-token
``decode_step`` — runs as ONE jitted ``lax.scan``: generating N tokens
costs one host dispatch after prefill, not one per token plus host-side
``jax.random.split``/argmax round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill

# Host→device dispatches issued by the decode loop (excludes prefill):
# one jitted scan per generate call. Reset-able by tests, which assert the
# whole loop stays a single dispatch regardless of max_new_tokens.
DECODE_STATS = {"dispatches": 0}


def greedy_generate(cfg, params, batch, *, max_new_tokens: int,
                    max_cache_len: int | None = None, temperature: float = 0.0,
                    key=None):
    """batch: prompt inputs (see data.pipeline). Returns (B, max_new) tokens."""
    if max_new_tokens < 0:
        raise ValueError(
            f"max_new_tokens must be >= 0, got {max_new_tokens}")
    if max_new_tokens == 0:
        # the scan below would get length=-1, which XLA rejects with an
        # opaque "invalid tensor dimension size" — zero tokens is just an
        # empty result, no prefill or decode needed
        b = (batch["frame_embeds"] if cfg.frontend == "audio_frames"
             else batch["tokens"]).shape[0]
        return jnp.zeros((b, 0), jnp.int32)
    prompt_len = (batch["frame_embeds"].shape[1]
                  if cfg.frontend == "audio_frames"
                  else batch["tokens"].shape[1]
                  + (cfg.n_patches if cfg.frontend == "vision_patches" else 0))
    max_cache_len = max_cache_len or (prompt_len + max_new_tokens)

    logits, caches = prefill(cfg, params, batch, max_cache_len)

    def sample(lg, k):
        lg = lg.reshape(lg.shape[0], -1)[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    @functools.partial(jax.jit, static_argnums=())
    def decode_tokens(lg0, caches, key, pos0):
        k0, key = jax.random.split(key)
        tok0 = sample(lg0, k0)[:, None]

        def body(carry, _):
            tok, pos, caches, key = carry
            lg, caches = decode_step(cfg, params, {"tokens": tok}, pos,
                                     caches)
            k0, key = jax.random.split(key)
            nxt = sample(lg, k0)[:, None]
            return (nxt, pos + 1, caches, key), nxt

        _, rest = jax.lax.scan(body, (tok0, pos0, caches, key), None,
                               length=max_new_tokens - 1)
        # tok0 (B, 1) + rest (T-1, B, 1) -> (B, T)
        return jnp.concatenate([tok0[None], rest], axis=0)[..., 0].T

    key = key if key is not None else jax.random.PRNGKey(0)
    DECODE_STATS["dispatches"] += 1
    return decode_tokens(logits, caches, key, jnp.int32(prompt_len))
