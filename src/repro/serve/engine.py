"""Batched serving engine: prefill + jitted single-token decode loop.

Greedy or temperature sampling over a batch of equal-length prompts (a
production engine adds continuous batching on top; the step function here is
exactly the unit the dry-run lowers as ``serve_step``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill


def greedy_generate(cfg, params, batch, *, max_new_tokens: int,
                    max_cache_len: int | None = None, temperature: float = 0.0,
                    key=None):
    """batch: prompt inputs (see data.pipeline). Returns (B, max_new) tokens."""
    prompt_len = (batch["frame_embeds"].shape[1]
                  if cfg.frontend == "audio_frames"
                  else batch["tokens"].shape[1]
                  + (cfg.n_patches if cfg.frontend == "vision_patches" else 0))
    max_cache_len = max_cache_len or (prompt_len + max_new_tokens)

    logits, caches = prefill(cfg, params, batch, max_cache_len)

    @functools.partial(jax.jit, static_argnums=())
    def one_step(tok, pos, caches):
        lg, caches = decode_step(cfg, params, {"tokens": tok}, pos, caches)
        return lg, caches

    def sample(lg, k):
        lg = lg.reshape(lg.shape[0], -1)[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    k0, key = jax.random.split(key)
    tok = sample(logits, k0)[:, None]
    toks.append(tok)
    pos = prompt_len
    for _ in range(max_new_tokens - 1):
        logits, caches = one_step(tok, pos, caches)
        k0, key = jax.random.split(key)
        tok = sample(logits, k0)[:, None]
        toks.append(tok)
        pos += 1
    return jnp.concatenate(toks, axis=1)
