"""Multi-tenant PIM serving front end: one device, N client workloads.

The scheduler core (``core/pim/schedule.py``) runs one *layout* — a flat
per-slot program list — as a single dispatch. This module is the
request-level front end on top of it: N tenants submit
:class:`~repro.core.pim.PimProgram` workloads against one
:class:`~repro.core.pim.DeviceConfig`, and the front end

* **places** each tenant on an explicit set of banks (every subarray of
  an owned bank belongs to the tenant; the placement map is public),
  rejecting over-subscription at admission time;
* **verifies** submitted programs at admission with the static verifier
  (``lint_schedule`` over the tenant's private subdevice slice), so a
  hostile tenant is rejected with diagnostics at ``submit()`` and can
  never crash the shared step plan — cross-slot ``COPY`` destinations
  outside the tenant's own allocation surface as PIM301 errors on the
  subdevice and are rejected too (tenant isolation);
* **coalesces** identical command streams across tenants: tenant
  programs are written in *tenant-local* bank coordinates, relocated to
  device coordinates at placement, and merged into one layout — slots
  owned by different tenants whose streams share a columnar digest land
  in one ``stream_key`` group and run under ONE vmapped runner
  (the scheduler's existing grouping does the heavy lifting; the front
  end just places everyone into the same ``schedule`` call);
* runs a **continuous-batching loop**: admission and preemption happen
  only at step boundaries, a departing tenant's slots simply become idle
  ``None`` entries (the surviving layout's warm ``_StepPlan`` stays
  cached — nothing is invalidated), and windows where every tenant's
  stream recurs are dispatched as ONE ``schedule_pipeline`` scan instead
  of per-step round-trips;
* **accounts** per tenant by slicing the lazy per-slot meters
  (``DeviceState.slot_time_ns`` / ``slot_energy_nj``): meters are
  cumulative and slots are exclusively owned, so a tenant's busy time and
  energy are differences of two snapshots, and tenant sums reconcile with
  the device-level totals (exactly, when computed from the same per-slot
  diffs — see :meth:`PimServeFront.reconcile`).

DESIGN.md §13 documents the placement / coalescing / preemption /
accounting contracts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pim import ir
from repro.core.pim.device import DeviceConfig, DeviceState, make_device
from repro.core.pim.ir import PimProgram
from repro.core.pim.lint import LintReport, lint_schedule
from repro.core.pim.schedule import (PipelineResult, ScheduleResult,
                                     _normalize_programs, schedule,
                                     schedule_pipeline, stream_key)

__all__ = ["AdmissionError", "FrontStepResult", "PimServeFront",
           "Placement", "TenantReport"]


class AdmissionError(ValueError):
    """A tenant submission was rejected at admission: over-subscription,
    malformed programs, or static-verifier errors. Carries the lint
    ``report`` when the verifier found the problem."""

    def __init__(self, tenant: str, reason: str,
                 report: LintReport | None = None):
        self.tenant = tenant
        self.report = report
        detail = ""
        if report is not None and report.errors:
            head = "; ".join(d.render() for d in report.errors[:3])
            more = (f" (+{len(report.errors) - 3} more)"
                    if len(report.errors) > 3 else "")
            detail = f": {head}{more}"
        super().__init__(f"tenant {tenant!r} rejected: {reason}{detail}")


@dataclasses.dataclass(frozen=True)
class Placement:
    """One tenant's allocation: device bank ids (every subarray of an
    owned bank belongs to the tenant) and the flat slot ids they imply."""

    tenant: str
    banks: tuple[int, ...]
    slots: tuple[int, ...]


@dataclasses.dataclass
class TenantReport:
    """Per-tenant accounting over the tenant's whole residency, sliced
    from the lazy per-slot meters: busy time and energy are snapshot
    differences at the tenant's slots, ``host_bytes`` counts its own
    streams' off-chip traffic, and ``wall_ns`` holds the device step
    latency of every step the tenant was active in."""

    tenant: str
    banks: tuple[int, ...]
    slots: tuple[int, ...]
    n_steps: int
    busy_ns: float
    energy_nj: float
    host_bytes: int
    wall_ns: np.ndarray

    def wall_percentile(self, q: float) -> float:
        """Step-latency percentile (q in [0, 100]) over the tenant's
        active steps — the p50/p99 the serving bench reports."""
        if self.wall_ns.size == 0:
            return 0.0
        return float(np.percentile(self.wall_ns, q))

    @property
    def p50_wall_ns(self) -> float:
        return self.wall_percentile(50.0)

    @property
    def p99_wall_ns(self) -> float:
        return self.wall_percentile(99.0)


@dataclasses.dataclass
class FrontStepResult:
    """One front-end dispatch: a single device step (``result`` is a
    :class:`ScheduleResult`) or a recurring window of ``n_steps`` steps
    (``result`` is a :class:`PipelineResult`). ``placements`` maps the
    tenants active in this dispatch to their slots."""

    result: "ScheduleResult | PipelineResult"
    placements: dict
    n_steps: int
    n_groups: int               # coalesced stream groups in the layout
    n_active_slots: int         # slots that ran a program

    @property
    def coalescing(self) -> float:
        """Active slots per compiled stream group — N identical-digest
        tenants coalesce to factor ~N."""
        return (self.n_active_slots / self.n_groups if self.n_groups
                else 0.0)

    def tenant_reads(self, tenant: str):
        """The tenant's host-read rows, sliced from the lazy batched
        reads: per-slot tuples for a single step, a per-step list of them
        for a pipeline window."""
        slots = self.placements[tenant]
        if isinstance(self.result, ScheduleResult):
            return tuple(self.result.reads[s] for s in slots)
        return [tuple(step[s] for s in slots) for step in self.result.reads]


@dataclasses.dataclass
class _Tenant:
    """Internal per-tenant record: relocated per-step slot fragments plus
    the meter snapshots taken at admission."""

    tid: str
    banks: tuple[int, ...]
    slots: tuple[int, ...]
    steps: list                 # per step: per-owned-slot program list
    t0_time: jax.Array
    t0_energy: jax.Array
    cursor: int = 0
    host_bytes: int = 0
    walls: list = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return len(self.steps) - self.cursor


@dataclasses.dataclass
class _Pending:
    """A queued submission: admission-linted tenant-local steps waiting
    for enough free banks."""

    tid: str
    n_banks: int
    local_steps: list           # per step: tenant-local flat slot list


def _as_steps(steps):
    """Submission sugar: ``(layout, n)`` replays one layout n times
    (identical objects — the pipeline fast path recurs by identity);
    otherwise ``steps`` is a sequence of per-step layouts."""
    if (isinstance(steps, tuple) and len(steps) == 2
            and isinstance(steps[1], (int, np.integer))):
        return [steps[0]] * int(steps[1])
    return list(steps)


class PimServeFront:
    """Request-level multi-tenant front end over one shared PIM device.

    ``refresh`` / ``async_host`` are the scheduler flags applied to every
    shared step. ``admission_lint=False`` disables the static-verifier
    admission gate (benchmarking the gate itself; production keeps it on).
    """

    def __init__(self, config: DeviceConfig, *, refresh: bool = False,
                 async_host: bool = False, admission_lint: bool = True):
        self.cfg = config
        self.device: DeviceState = make_device(config)
        self.refresh = refresh
        self.async_host = async_host
        self.admission_lint = admission_lint
        self._free: list[int] = list(range(config.n_banks))
        self._active: dict[str, _Tenant] = {}
        self._pending: list[_Pending] = []
        self._done: dict[str, TenantReport] = {}
        self._lint_ok: set = set()      # (n_banks, per-slot digest sig)
        self._t0 = np.asarray(self.device.slot_time_ns, np.float64)
        self._e0 = np.asarray(self.device.slot_energy_nj, np.float64)
        self._host_bytes_total = 0
        self._n_steps_total = 0

    # -- introspection ----------------------------------------------------

    @property
    def active(self) -> tuple[str, ...]:
        return tuple(self._active)

    @property
    def pending(self) -> tuple[str, ...]:
        return tuple(p.tid for p in self._pending)

    @property
    def free_banks(self) -> tuple[int, ...]:
        return tuple(self._free)

    def placement(self, tenant: str | None = None):
        """The explicit placement map: ``{tenant: Placement}``, or one
        tenant's :class:`Placement`."""
        out = {tid: Placement(tid, t.banks, t.slots)
               for tid, t in self._active.items()}
        return out if tenant is None else out[tenant]

    # -- admission --------------------------------------------------------

    def _normalize_local(self, tid: str, steps, n_banks: int) -> list:
        """Validate + normalize every submitted step to a tenant-local
        flat slot list over the tenant's private subdevice slice."""
        sub = self.cfg.subdevice(n_banks)
        out = []
        for k, layout in enumerate(steps):
            if isinstance(layout, PimProgram):
                # a bare program replicates across every tenant bank
                # (subarray 0) — one stream, maximal coalescing
                layout = [layout] * sub.n_banks
            try:
                flat = _normalize_programs(sub, layout)
            except (ValueError, AssertionError) as e:
                raise AdmissionError(tid, f"step {k}: {e}") from e
            for p in flat:
                if p is not None and not isinstance(p, PimProgram):
                    raise AdmissionError(
                        tid, f"step {k}: {type(p).__name__} is not a "
                             "PimProgram")
                if p is not None and (p.num_rows, p.words) != (
                        self.cfg.num_rows, self.cfg.words):
                    raise AdmissionError(
                        tid, f"step {k}: program shape "
                             f"{(p.num_rows, p.words)} != device shape "
                             f"{(self.cfg.num_rows, self.cfg.words)}")
            out.append(flat)
        return out

    def _lint_gate(self, tid: str, local_steps: list, n_banks: int) -> None:
        """The admission-time ``verify=True`` gate: run the static
        verifier over every distinct step signature on the tenant's
        private subdevice. PIM301 on the subdevice doubles as the
        isolation check — a COPY addressed outside the tenant's own banks
        is outside its subdevice. Errors reject the submission BEFORE any
        allocation; the shared step plan never sees the program."""
        if not self.admission_lint:
            return
        sub = self.cfg.subdevice(n_banks)
        for flat in local_steps:
            sig = (n_banks, tuple(None if p is None else p.digest
                                  for p in flat),
                   tuple(() if p is None else
                         tuple(tuple(q.shape) for q in p.payloads)
                         for p in flat))
            if sig in self._lint_ok:
                continue
            report = lint_schedule(sub, flat, async_host=self.async_host)
            if not report.ok:
                raise AdmissionError(tid, "static verification failed",
                                     report)
            self._lint_ok.add(sig)

    @staticmethod
    def _relocate(program: PimProgram, banks: tuple[int, ...]) -> PimProgram:
        """Tenant-local → device coordinates: rewrite cross-slot COPY
        destination banks through the placement map. Programs without
        cross-slot COPYs are returned UNCHANGED (same object) so their
        digests — and therefore cross-tenant stream-group coalescing and
        the identity-keyed payload cache — are placement-independent."""
        cols = program.columns
        is_copy = cols.code == ir.OP_CODE[ir.OP_COPY]
        if not is_copy.any():
            return program
        cross = is_copy & ~((cols.delta == ir.COPY_SELF)
                            & (cols.c == ir.COPY_SELF))
        if not cross.any():
            return program
        ops = []
        for op in program.ops:
            if (op.op == ir.OP_COPY
                    and (op.delta, op.c) != (ir.COPY_SELF, ir.COPY_SELF)):
                ops.append(dataclasses.replace(op, delta=banks[op.delta]))
            else:
                ops.append(op)
        return PimProgram(ops=tuple(ops), num_rows=program.num_rows,
                          words=program.words, payloads=program.payloads)

    def _admit(self, tid: str, n_banks: int, local_steps: list) -> Placement:
        banks = tuple(self._free[:n_banks])
        del self._free[:n_banks]
        slots = self.cfg.bank_slots(banks)
        reloc: dict[int, PimProgram] = {}
        pins: list = []                 # keep source programs alive: the
        steps = []                      # reloc memo is id-keyed
        for flat in local_steps:
            step = []
            for p in flat:
                if p is None:
                    step.append(None)
                else:
                    r = reloc.get(id(p))
                    if r is None:
                        r = self._relocate(p, banks)
                        reloc[id(p)] = r
                        pins.append(p)
                    step.append(r)
            steps.append(step)
        idx = jnp.asarray(np.asarray(slots))
        self._active[tid] = _Tenant(
            tid=tid, banks=banks, slots=slots, steps=steps,
            t0_time=self.device.slot_time_ns[idx],
            t0_energy=self.device.slot_energy_nj[idx])
        return Placement(tid, banks, slots)

    def submit(self, tenant: str, steps, *, banks: int = 1,
               queue: bool = False) -> Placement | None:
        """Admit a tenant workload: ``steps`` is a sequence of per-step
        layouts over the tenant's ``banks``-bank slice (anything
        ``schedule`` accepts for that slice, in TENANT-LOCAL bank
        coordinates), or ``(layout, n)`` to replay one layout n times.

        Admission validates shapes, runs the static verifier over the
        tenant's private subdevice (rejecting hostile programs with their
        diagnostics), and allocates ``banks`` free device banks. With
        ``queue=True`` a submission that does not fit *right now* waits in
        the FIFO pending queue and is admitted at a later step boundary;
        otherwise over-subscription raises :class:`AdmissionError`.
        Returns the :class:`Placement` (``None`` when queued)."""
        if tenant in self._active or tenant in {p.tid for p in self._pending}:
            raise AdmissionError(tenant, "tenant id already submitted")
        if banks < 1:
            raise AdmissionError(tenant, f"needs at least 1 bank, got "
                                         f"{banks}")
        if banks > self.cfg.n_banks:
            raise AdmissionError(
                tenant, f"requested {banks} banks; the device has "
                        f"{self.cfg.n_banks} — cannot ever fit")
        step_list = _as_steps(steps)
        if not step_list:
            raise AdmissionError(tenant, "workload has no steps")
        local_steps = self._normalize_local(tenant, step_list, banks)
        self._lint_gate(tenant, local_steps, banks)
        if len(self._free) < banks:
            if queue:
                self._pending.append(_Pending(tenant, banks, local_steps))
                return None
            raise AdmissionError(
                tenant, f"over-subscribed: needs {banks} banks, "
                        f"{len(self._free)} free (queue=True to wait)")
        return self._admit(tenant, banks, local_steps)

    # -- departure / preemption ------------------------------------------

    def _report_for(self, t: _Tenant) -> TenantReport:
        idx = jnp.asarray(np.asarray(t.slots))
        busy = (np.asarray(self.device.slot_time_ns[idx], np.float64)
                - np.asarray(t.t0_time, np.float64))
        energy = (np.asarray(self.device.slot_energy_nj[idx], np.float64)
                  - np.asarray(t.t0_energy, np.float64))
        walls = (np.concatenate([np.atleast_1d(np.asarray(w, np.float64))
                                 for w in t.walls])
                 if t.walls else np.zeros(0))
        return TenantReport(
            tenant=t.tid, banks=t.banks, slots=t.slots, n_steps=t.cursor,
            busy_ns=float(busy.sum()), energy_nj=float(energy.sum()),
            host_bytes=t.host_bytes, wall_ns=walls)

    def depart(self, tenant: str) -> TenantReport:
        """Remove a tenant at the current step boundary (preemption:
        unconsumed steps are discarded). Its slots become idle ``None``
        entries in subsequent layouts — the surviving tenants' warm step
        plan is untouched — and its banks return to the free list."""
        t = self._active.pop(tenant, None)
        if t is None:
            for i, p in enumerate(self._pending):
                if p.tid == tenant:
                    del self._pending[i]
                    return self._done.setdefault(
                        tenant, TenantReport(tenant, (), (), 0, 0.0, 0.0,
                                             0, np.zeros(0)))
            raise KeyError(f"unknown tenant {tenant!r}")
        report = self._report_for(t)
        self._done[tenant] = report
        self._free.extend(t.banks)
        self._free.sort()
        return report

    def report(self, tenant: str) -> TenantReport:
        """Accounting snapshot: live tenants are measured up to the last
        completed step, departed tenants return their final report."""
        t = self._active.get(tenant)
        if t is not None:
            return self._report_for(t)
        return self._done[tenant]

    def reports(self) -> dict:
        return {**{tid: self._report_for(t)
                   for tid, t in self._active.items()},
                **dict(self._done)}

    # -- the serving loop -------------------------------------------------

    def _boundary(self) -> None:
        """Step-boundary bookkeeping: retire tenants whose steps are
        exhausted, then admit pending submissions FIFO while they fit."""
        for tid in [tid for tid, t in self._active.items()
                    if t.cursor >= len(t.steps)]:
            self.depart(tid)
        while self._pending and self._pending[0].n_banks <= len(self._free):
            p = self._pending.pop(0)
            self._admit(p.tid, p.n_banks, p.local_steps)

    def _merged(self, offset: int = 0) -> list:
        flat: list = [None] * self.cfg.n_slots
        for t in self._active.values():
            step = t.steps[t.cursor + offset]
            for i, s in enumerate(t.slots):
                flat[s] = step[i]
        return flat

    def _account(self, result, n_steps: int) -> FrontStepResult:
        placements = {}
        walls = (result.wall_ns if isinstance(result, PipelineResult)
                 else jnp.reshape(result.wall_ns, (1,)))
        for t in self._active.values():
            placements[t.tid] = t.slots
            t.walls.append(walls)
            for j in range(n_steps):
                t.host_bytes += sum(
                    t.steps[t.cursor + j][i].host_bytes
                    for i, _ in enumerate(t.slots)
                    if t.steps[t.cursor + j][i] is not None)
            t.cursor += n_steps
        self._host_bytes_total += result.host_bytes * n_steps
        self._n_steps_total += n_steps
        group_slots = result._read_layout[1]
        n_active = sum(len(g) for g in group_slots)
        out = FrontStepResult(result=result, placements=placements,
                              n_steps=n_steps, n_groups=len(group_slots),
                              n_active_slots=n_active)
        self._boundary()
        return out

    def step(self) -> FrontStepResult:
        """Run ONE shared device step over every active tenant's current
        step programs (one ``schedule`` dispatch; slots of identical
        digests coalesce into shared vmapped groups)."""
        if not self._active:
            raise RuntimeError("no active tenants (queue admission happens "
                               "at step boundaries — call step()/run() "
                               "with at least one admitted tenant)")
        result = schedule(self.device, self._merged(),
                          refresh=self.refresh, async_host=self.async_host)
        self.device = result.state
        return self._account(result, 1)

    def _window_recurs(self, k: int) -> bool:
        """Do the next k steps of every active tenant carry identical
        command streams (payload data free)? Identity short-circuits the
        common replayed-layout case."""
        for t in self._active.values():
            s0 = t.steps[t.cursor]
            for j in range(1, k):
                sj = t.steps[t.cursor + j]
                if sj is s0:
                    continue
                for a, b in zip(s0, sj):
                    if ((a is None) != (b is None)
                            or (a is not None
                                and stream_key(a) != stream_key(b))):
                        return False
        return True

    def run(self, max_steps: int | None = None, *, chunk: int = 64,
            pipeline: bool = True) -> list[FrontStepResult]:
        """The continuous-batching loop: repeatedly dispatch the merged
        layout until every tenant (active AND queued) is served, or
        ``max_steps`` device steps have run. Windows of up to ``chunk``
        steps in which every tenant's streams recur — and no tenant
        finishes mid-window — run as ONE ``schedule_pipeline`` scan;
        membership changes (completion, admission from the queue) happen
        only between dispatches."""
        out: list[FrontStepResult] = []
        done = 0
        self._boundary()
        while self._active and (max_steps is None or done < max_steps):
            k = min(t.remaining for t in self._active.values())
            if max_steps is not None:
                k = min(k, max_steps - done)
            k = min(k, chunk)
            if pipeline and k > 1 and self._window_recurs(k):
                flats = [self._merged(j) for j in range(k)]
                result = schedule_pipeline(
                    self.device, flats, refresh=self.refresh,
                    async_host=self.async_host)
                self.device = result.state
                out.append(self._account(result, k))
            else:
                out.append(self.step())
            done += out[-1].n_steps
        return out

    # -- reconciliation ---------------------------------------------------

    def reconcile(self) -> dict:
        """Device-level totals vs per-tenant sums, from the SAME per-slot
        meter diffs: ``device_*`` sums every slot's cumulative delta since
        construction, ``tenant_*`` sums the per-tenant reports. With each
        slot owned by one tenant at a time and idle slots never metered,
        the two agree (exactly when slots are not re-used across tenants;
        to float64 rounding of the snapshot telescope otherwise)."""
        t_now = np.asarray(self.device.slot_time_ns, np.float64)
        e_now = np.asarray(self.device.slot_energy_nj, np.float64)
        reports = self.reports().values()
        return {
            "device_busy_ns": float((t_now - self._t0).sum()),
            "device_energy_nj": float((e_now - self._e0).sum()),
            "device_host_bytes": self._host_bytes_total,
            "device_steps": self._n_steps_total,
            "tenant_busy_ns": float(sum(r.busy_ns for r in reports)),
            "tenant_energy_nj": float(sum(r.energy_nj for r in reports)),
            "tenant_host_bytes": int(sum(r.host_bytes for r in reports)),
        }
