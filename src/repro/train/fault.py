"""Fault-tolerance plumbing: preemption capture, step timing / straggler
detection, bounded-retry recovery."""
from __future__ import annotations

import signal
import time


class PreemptionGuard:
    """Latches SIGTERM/SIGINT so the loop can checkpoint-and-exit cleanly
    (TPU pod preemptions deliver SIGTERM with a grace window)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except (ValueError, OSError):   # non-main thread / unsupported
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StepTimer:
    """EWMA step timing; flags straggler steps (>ratio × EWMA). On a real
    cluster the flag feeds the controller's slice-replacement logic; here it
    is surfaced in metrics and logs."""

    def __init__(self, alpha: float = 0.1, straggler_ratio: float = 3.0):
        self.alpha = alpha
        self.ratio = straggler_ratio
        self.ewma = None
        self.stragglers = 0
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> tuple[float, bool]:
        dt = time.perf_counter() - self._t0
        is_straggler = self.ewma is not None and dt > self.ratio * self.ewma
        if is_straggler:
            self.stragglers += 1
        else:                      # don't pollute the EWMA with outliers
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt, is_straggler


def with_retries(fn, recover, max_retries: int = 3, log=print):
    """Run ``fn()``; on exception call ``recover(attempt)`` and retry.
    Models node-failure recovery: reload the last checkpoint and continue."""
    attempt = 0
    while True:
        try:
            return fn()
        except KeyboardInterrupt:
            raise
        except Exception as e:                      # noqa: BLE001
            attempt += 1
            if attempt > max_retries:
                raise
            log(f"[fault] step failed ({type(e).__name__}: {e}); "
                f"recovery attempt {attempt}/{max_retries}")
            recover(attempt)
