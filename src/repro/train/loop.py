"""The fault-tolerant training loop.

Responsibilities: restore-or-init, host prefetch, jitted step, periodic +
preemption checkpointing, NaN-skip accounting, straggler flagging, bounded
retry on step failure. Pure orchestration — all math lives in step.py.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.data.pipeline import Prefetcher, make_batch
from repro.models import init_params
from repro.optim import adamw
from repro.train import fault
from repro.train.step import init_train_state, make_train_step


def train_loop(cfg, *, steps: int, batch: int, seq: int,
               opt_cfg=None, schedule_fn=None, ckpt_dir: str | None = None,
               ckpt_every: int = 50, microbatches: int = 1,
               compress: bool = False, seed: int = 0, log=print,
               max_retries: int = 2):
    """Returns (params, history dict)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    schedule_fn = schedule_fn or (lambda s: 1.0)

    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    train, frozen, opt = init_train_state(cfg, params, compress)
    start = 0

    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        last = checkpoint.latest_step(ckpt_dir)
        if last is not None:
            restored, manifest = checkpoint.restore(
                ckpt_dir, last, {"train": train, "opt": opt})
            train, opt = restored["train"], restored["opt"]
            start = manifest["step"]
            log(f"[ckpt] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, schedule_fn,
                                      microbatches, compress),
                      donate_argnums=(0, 2))

    prefetch = Prefetcher(
        lambda s: make_batch(cfg, batch=batch, seq=seq, step=s, seed=seed),
        start_step=start)
    guard = fault.PreemptionGuard()
    timer = fault.StepTimer()
    history = {"loss": [], "step_time": [], "skipped": 0, "stragglers": 0,
               "retries": 0}

    def save(step):
        if ckpt_dir:
            checkpoint.save(ckpt_dir, step, {"train": train, "opt": opt},
                            meta={"arch": cfg.arch_id, "seq": seq,
                                  "batch": batch})

    step = start
    try:
        while step < steps:
            got_step, np_batch = prefetch.get()
            assert got_step == step, (got_step, step)
            batch_dev = jax.tree.map(jax.numpy.asarray, np_batch)

            def run_one():
                nonlocal train, opt
                timer.start()
                train, opt, metrics = step_fn(train, frozen, opt, batch_dev)
                metrics = jax.device_get(metrics)
                dt, straggler = timer.stop()
                return metrics, dt, straggler

            def recover(attempt):
                nonlocal train, opt
                history["retries"] += 1
                if ckpt_dir and checkpoint.latest_step(ckpt_dir) is not None:
                    last = checkpoint.latest_step(ckpt_dir)
                    restored, _ = checkpoint.restore(
                        ckpt_dir, last, {"train": train, "opt": opt})
                    train, opt = restored["train"], restored["opt"]

            metrics, dt, straggler = fault.with_retries(
                run_one, recover, max_retries=max_retries, log=log)
            history["loss"].append(float(metrics["loss"]))
            history["step_time"].append(dt)
            history["skipped"] += int(metrics["skipped"])
            history["stragglers"] += int(straggler)
            if straggler:
                log(f"[straggler] step {step} took {dt:.2f}s "
                    f"(ewma {timer.ewma:.2f}s)")
            if step % 10 == 0:
                log(f"step {step:5d} loss {metrics['loss']:.4f} "
                    f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f} ms")
            step += 1
            if ckpt_dir and (step % ckpt_every == 0 or guard.requested):
                save(step)
            if guard.requested:
                log(f"[preempt] SIGTERM at step {step}: saved and exiting")
                break
    finally:
        prefetch.close()
        guard.restore()
    save(step)
    params = adamw.merge(train, frozen)
    return params, history
