"""The jitted training step: microbatched grads, AdamW, NaN guard,
optional int8 error-feedback gradient compression.

Semantics:
  * grad accumulation — the global batch is split into ``microbatches``
    equal slices scanned sequentially (activation memory / batch trade-off).
  * NaN/Inf guard — a step with non-finite loss or grad-norm applies NO
    update (params/opt-state pass through; the loop logs and continues).
    At cluster scale this is the first line of defense against data poison
    and transient numerics (fault tolerance requirement).
  * compression — grads pass through int8 quantize/dequantize with an
    error-feedback residual carried in the optimizer state, matching the
    cross-pod int8 all-reduce payload (optim/grad_compress.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.optim import adamw, grad_compress


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, schedule_fn,
                    microbatches: int = 1, compress: bool = False):
    def step_fn(train_params, frozen_params, opt_state, batch):
        def loss_of(tp, b):
            return loss_fn(cfg, adamw.merge(tp, frozen_params), b)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(train_params, batch)
        else:
            def slice_mb(b, i):
                return jax.tree.map(
                    lambda x: x.reshape(microbatches, -1, *x.shape[1:])[i], b)

            def mb_step(carry, i):
                acc, loss_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    train_params, slice_mb(batch, i))
                acc = jax.tree.map(lambda a, b_: a + b_, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 train_params)
            (gsum, lsum), _ = jax.lax.scan(
                mb_step, (zeros, jnp.float32(0.0)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"ce_loss": loss, "aux_loss": jnp.float32(0.0)}

        if compress:
            codes, scales, resid = grad_compress.compress_tree(
                grads, opt_state.get("residual"))
            grads = grad_compress.decompress_tree(codes, scales)
            opt_state = dict(opt_state, residual=resid)

        lr_scale = schedule_fn(opt_state["step"])
        new_params, new_opt, om = adamw.apply_updates(
            train_params, grads, {k: opt_state[k] for k in
                                  ("mu", "nu", "step")},
            opt_cfg, lr_scale)
        if compress:
            new_opt = dict(new_opt, residual=opt_state["residual"])

        good = jnp.isfinite(loss) & jnp.isfinite(om["grad_norm"])
        pick = lambda n, o: jax.tree.map(
            lambda a, b: jnp.where(good, a, b), n, o)
        new_params = pick(new_params, train_params)
        new_opt = pick(new_opt, opt_state)
        metrics = dict(metrics, loss=loss, grad_norm=om["grad_norm"],
                       lr_scale=lr_scale,
                       skipped=(~good).astype(jnp.float32))
        return new_params, new_opt, metrics

    return step_fn


def init_train_state(cfg, params, compress: bool = False):
    """Split params and build the optimizer state (+ compression residual)."""
    train, frozen = adamw.partition(params)
    opt = adamw.init_state(train)
    if compress:
        opt["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), train)
    return train, frozen, opt
