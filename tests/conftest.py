import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # hypothesis is optional: clean environments still run the example tests
    from hypothesis import settings, HealthCheck
except ImportError:
    pass
else:
    settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
