import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_pim_stats():
    """Zero the pim instrumentation counters (COLUMN_STATS / SCHED_STATS /
    RUNNER_STATS) before every test so stats-asserting tests are
    order-independent — any test may touch the cached schedule paths."""
    import repro.core.pim as pim

    pim.reset_stats()
    yield

try:  # hypothesis is optional: clean environments still run the example tests
    from hypothesis import settings, HealthCheck
except ImportError:
    pass
else:
    _suppress = [HealthCheck.too_slow, HealthCheck.data_too_large]
    # derandomize: CI failures must reproduce from the fixed profile seed.
    settings.register_profile(
        "ci", deadline=None, max_examples=25, derandomize=True,
        suppress_health_check=_suppress)
    # Heavier sweep for the differential harness (CI runs it explicitly:
    # HYPOTHESIS_PROFILE=differential pytest tests/test_pim_differential.py).
    settings.register_profile(
        "differential", deadline=None, max_examples=200, derandomize=True,
        suppress_health_check=_suppress)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
