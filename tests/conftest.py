import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # hypothesis is optional: clean environments still run the example tests
    from hypothesis import settings, HealthCheck
except ImportError:
    pass
else:
    _suppress = [HealthCheck.too_slow, HealthCheck.data_too_large]
    # derandomize: CI failures must reproduce from the fixed profile seed.
    settings.register_profile(
        "ci", deadline=None, max_examples=25, derandomize=True,
        suppress_health_check=_suppress)
    # Heavier sweep for the differential harness (CI runs it explicitly:
    # HYPOTHESIS_PROFILE=differential pytest tests/test_pim_differential.py).
    settings.register_profile(
        "differential", deadline=None, max_examples=200, derandomize=True,
        suppress_health_check=_suppress)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
