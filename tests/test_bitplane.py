"""In-DRAM SIMD arithmetic on horizontal data (adders, multiplier, GF, RS)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic example loops below
    HAVE_HYPOTHESIS = False

from repro.core.bitplane import PimVM, arith, gf, layout, rs


def make_vm(width=8, words=2, rows=96):
    return PimVM(width=width, num_rows=rows, words=words)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 256, 16)
    row = layout.pack_elements(vals, 8, 4)
    back = layout.unpack_elements(row, 8, 16)
    assert np.array_equal(back, vals.astype(np.uint64))


@pytest.mark.parametrize("adder", [arith.add_ripple, arith.add_kogge_stone])
def test_adders(adder):
    rng = np.random.default_rng(1)
    vm = make_vm()
    a = rng.integers(0, 256, vm.lanes)
    b = rng.integers(0, 256, vm.lanes)
    out = adder(vm, vm.load(a), vm.load(b))
    assert np.array_equal(vm.read(out), arith.ref_add(a, b, 8))


def test_kogge_stone_fewer_logic_rounds_more_shift_cost():
    """§8.0.1: KS trades TRA depth for longer shifts; both must be exact."""
    rng = np.random.default_rng(2)
    vm1, vm2 = make_vm(), make_vm()
    a = rng.integers(0, 256, vm1.lanes)
    b = rng.integers(0, 256, vm1.lanes)
    r1 = arith.add_ripple(vm1, vm1.load(a), vm1.load(b))
    r2 = arith.add_kogge_stone(vm2, vm2.load(a), vm2.load(b))
    assert np.array_equal(vm1.read(r1), vm2.read(r2))
    assert vm1.counts()["n_shift"] != vm2.counts()["n_shift"]


def _check_mul_shift_add(avals, bvals):
    vm = make_vm(words=2)
    a = np.array(avals, dtype=np.uint64)
    b = np.array(bvals, dtype=np.uint64)
    out = arith.mul_shift_add(vm, vm.load(a), vm.load(b))
    assert np.array_equal(vm.read(out), arith.ref_mul(a, b, 8))


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 255), min_size=8, max_size=8),
           st.lists(st.integers(0, 255), min_size=8, max_size=8))
    @settings(max_examples=5)
    def test_mul_shift_add_property(avals, bvals):
        _check_mul_shift_add(avals, bvals)
else:
    @pytest.mark.parametrize("seed", range(5))
    def test_mul_shift_add_property(seed):
        rng = np.random.default_rng(seed)
        _check_mul_shift_add(rng.integers(0, 256, 8), rng.integers(0, 256, 8))


def test_width4_arithmetic():
    rng = np.random.default_rng(3)
    vm = make_vm(width=4, words=2)
    a = rng.integers(0, 16, vm.lanes)
    b = rng.integers(0, 16, vm.lanes)
    out = arith.add_ripple(vm, vm.load(a), vm.load(b))
    assert np.array_equal(vm.read(out), arith.ref_add(a, b, 4))


def test_xtime_and_gf_mul():
    rng = np.random.default_rng(4)
    vm = make_vm(words=2)
    a = rng.integers(0, 256, vm.lanes)
    b = rng.integers(0, 256, vm.lanes)
    ra, rb = vm.load(a), vm.load(b)
    assert np.array_equal(vm.read(gf.xtime(vm, ra)), gf.ref_xtime(a))
    assert np.array_equal(vm.read(gf.gf_mul(vm, ra, rb)),
                          gf.ref_gf_mul(a, b))


def test_gf_mul_const_rs_field():
    rng = np.random.default_rng(5)
    vm = make_vm(words=2)
    a = rng.integers(0, 256, vm.lanes)
    got = vm.read(gf.gf_mul_const(vm, vm.load(a), 0x1D, poly=gf.RS_POLY))
    ref = gf.ref_gf_mul(a, np.full_like(a, 0x1D), poly=gf.RS_POLY)
    assert np.array_equal(got, ref)


def test_aes_xtime_known_vectors():
    vm = make_vm(words=2)
    vals = np.array([0x57, 0x80, 0x01, 0xFF] * (vm.lanes // 4),
                    dtype=np.uint64)
    got = vm.read(gf.xtime(vm, vm.load(vals)))
    assert got[0] == 0xAE          # FIPS-197 example: xtime(0x57)=0xAE
    assert got[1] == 0x1B          # 0x80 → reduce
    assert got[2] == 0x02


def test_reed_solomon_encode_and_syndromes():
    rng = np.random.default_rng(6)
    k, npar = 5, 4
    vm = PimVM(width=8, num_rows=120, words=1)
    msg = rng.integers(0, 256, size=(k, vm.lanes))
    regs = [vm.load(msg[i]) for i in range(k)]
    par = rs.rs_encode(vm, regs, npar)
    got = np.stack([vm.read(r) for r in par])
    ref = rs.ref_rs_encode(msg, npar)
    assert np.array_equal(got, ref)
    cw = np.concatenate([msg.astype(np.uint64), ref[::-1]], axis=0)
    assert not rs.ref_rs_syndromes(cw, npar).any()


def test_rs_detects_corruption():
    rng = np.random.default_rng(7)
    k, npar = 5, 4
    msg = rng.integers(0, 256, size=(k, 4)).astype(np.uint64)
    par = rs.ref_rs_encode(msg, npar)
    cw = np.concatenate([msg, par[::-1]], axis=0)
    cw[2, 1] ^= 0x40
    assert rs.ref_rs_syndromes(cw, npar).any()


def test_costs_accumulate():
    vm = make_vm(words=2)
    rng = np.random.default_rng(8)
    a = vm.load(rng.integers(0, 256, vm.lanes))
    t0 = vm.time_ns
    gf.xtime(vm, a)
    assert vm.time_ns > t0
    assert vm.counts()["n_shift"] >= 1        # xtime uses migration shifts


def test_aes_mixcolumns_full_in_dram():
    """FIPS-197 MixColumns on byte-lane columns — rotations via chained
    migration shifts, scaling via xtime: the paper's §1/§8 AES pitch."""
    rng = np.random.default_rng(9)
    vm = make_vm(words=2, rows=96)
    state = rng.integers(0, 256, (vm.lanes // 4, 4))
    reg = vm.load(state.reshape(-1))
    out = gf.mixcolumns(vm, reg)
    got = vm.read(out).reshape(-1, 4)
    assert np.array_equal(got, gf.ref_mixcolumns(state))
    assert vm.counts()["n_shift"] > 0


def test_aes_mixcolumns_fips_vector():
    vm = make_vm(words=1, rows=96)
    kv = np.array([[0xDB, 0x13, 0x53, 0x45]])
    reg = vm.load(kv.reshape(-1))
    got = vm.read(gf.mixcolumns(vm, reg)).reshape(-1, 4)
    assert np.array_equal(got[0], [0x8E, 0x4D, 0xA1, 0xBC])
