"""Decode-vs-prefill logits consistency for every architecture.

prefill(S−1 tokens) + decode_step(token S−1) must reproduce the logits of
prefill(S tokens) — this exercises KV/ring/latent/SSM caches end to end.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, init_params, prefill

from util import make_inputs, split_last

B, S = 2, 32

# f32-state paths (SSM/RG-LRU/MLA-absorbed) legitimately differ in op order.
TOL = {
    "deepseek-v2-lite-16b": 3e-2,
    "falcon-mamba-7b": 3e-2,
    "recurrentgemma-2b": 3e-2,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_inputs(cfg, B, S, labels=False)
    pre, last = split_last(batch, cfg)

    logits_full, _ = prefill(cfg, params, batch, max_cache_len=S)
    _, caches = prefill(cfg, params, pre, max_cache_len=S)
    logits_dec, _ = decode_step(cfg, params, last, S - 1, caches)

    a = logits_full.reshape(-1)
    b = logits_dec.reshape(-1)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
    assert rel < TOL.get(arch, 1e-4), rel


@pytest.mark.parametrize("arch", ["starcoder2-7b", "recurrentgemma-2b"])
def test_sliding_window_ring_cache_wraps(arch):
    """Decode far past the window: ring slots must overwrite correctly."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(2))
    W = cfg.sliding_window
    total = W * 2 + 5                      # force multiple wraps
    batch = make_inputs(cfg, B, total, labels=False)
    pre = {"tokens": batch["tokens"][:, :-1]}
    last = {"tokens": batch["tokens"][:, -1:]}
    logits_full, _ = prefill(cfg, params, batch, max_cache_len=total)
    _, caches = prefill(cfg, params, {"tokens": batch["tokens"][:, :W]},
                        max_cache_len=total)
    # decode the rest token by token
    logits = None
    for t in range(W, total):
        logits, caches = decode_step(
            cfg, params, {"tokens": batch["tokens"][:, t:t + 1]}, t, caches)
    rel = float(jnp.max(jnp.abs(logits_full.reshape(-1) - logits.reshape(-1)))
                / (jnp.max(jnp.abs(logits_full)) + 1e-9))
    # bf16 gate recurrences drift over ~2W sequential steps; the hybrid arch
    # (RG-LRU) compounds more than pure attention.
    tol = 8e-2 if arch == "recurrentgemma-2b" else 3e-2
    assert rel < tol, rel
