"""Flash custom-VJP attention vs the naively-differentiated oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic example loops below
    HAVE_HYPOTHESIS = False

from repro.models.attention import chunked_attention


def make(B, Sq, Sk, KV, G, dh, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, dh)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("chunks", [(16, 32), (64, 64), (8, 8)])
def test_forward_matches_naive(window, chunks):
    q, k, v = make(2, 64, 64, 2, 3, 16)
    pos = jnp.arange(64, dtype=jnp.int32)
    qc, kc = chunks
    o1 = chunked_attention(q, k, v, pos, pos, window=window, q_chunk=qc,
                           k_chunk=kc, impl="flash")
    o2 = chunked_attention(q, k, v, pos, pos, window=window, q_chunk=qc,
                           k_chunk=kc, impl="naive")
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


@pytest.mark.parametrize("window", [None, 16])
def test_gradients_match_naive(window):
    q, k, v = make(2, 32, 32, 2, 2, 8, seed=1)
    pos = jnp.arange(32, dtype=jnp.int32)

    def loss(impl):
        def f(q, k, v):
            o = chunked_attention(q, k, v, pos, pos, window=window,
                                  q_chunk=8, k_chunk=16, impl=impl)
            return jnp.sum(o * o)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    gf, gn = loss("flash"), loss("naive")
    for a, b in zip(gf, gn):
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        assert float(jnp.max(jnp.abs(a - b))) / scale < 1e-4


def test_decode_single_query_with_ring_positions():
    """Decode path: kpos carries absolute positions with -1 invalid slots."""
    q, k, v = make(2, 1, 16, 2, 2, 8, seed=2)
    pos_q = jnp.asarray([20], jnp.int32)
    kpos = jnp.tile(jnp.asarray([[5, 21, -1, 7, 20, 9, 10, 11,
                                  12, 13, 14, 15, 16, 17, 18, 19]],
                                jnp.int32), (2, 1))
    out_f = chunked_attention(q, k, v, pos_q, kpos, q_chunk=1, k_chunk=8,
                              impl="flash")
    out_n = chunked_attention(q, k, v, pos_q, kpos, q_chunk=1, k_chunk=8,
                              impl="naive")
    assert float(jnp.max(jnp.abs(out_f - out_n))) < 1e-5
    # future (21) and invalid (-1) keys must not contribute:
    v_masked = v.at[:, 1].set(1e4).at[:, 2].set(1e4)
    out_masked = chunked_attention(q, k, v_masked, pos_q, kpos, q_chunk=1,
                                   k_chunk=8, impl="flash")
    assert float(jnp.max(jnp.abs(out_masked - out_f))) < 1e-5


def _check_property_shapes(b, s, kv, g):
    q, k, v = make(b, s, s, kv, g, 8, seed=s)
    pos = jnp.arange(s, dtype=jnp.int32)
    o1 = chunked_attention(q, k, v, pos, pos, q_chunk=8, k_chunk=8,
                           impl="flash")
    o2 = chunked_attention(q, k, v, pos, pos, q_chunk=8, k_chunk=8,
                           impl="naive")
    assert o1.shape == (b, s, kv, g, 8)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 3), st.sampled_from([16, 32]),
           st.sampled_from([1, 2]), st.sampled_from([1, 4]))
    @settings(max_examples=8)
    def test_property_shapes(b, s, kv, g):
        _check_property_shapes(b, s, kv, g)
else:
    @pytest.mark.parametrize("b,s,kv,g",
                             [(1, 16, 1, 1), (2, 32, 2, 4), (3, 16, 2, 1),
                              (1, 32, 1, 4)])
    def test_property_shapes(b, s, kv, g):
        _check_property_shapes(b, s, kv, g)


def test_first_token_attends_only_itself():
    q, k, v = make(1, 4, 4, 1, 1, 8, seed=3)
    pos = jnp.arange(4, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, q_chunk=4, k_chunk=4,
                            impl="flash")
    assert float(jnp.max(jnp.abs(out[0, 0, 0, 0] - v[0, 0, 0]))) < 1e-5
