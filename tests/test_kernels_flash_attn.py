"""Pallas flash-attention forward kernel vs oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, ref_flash_attention


def make(H, KV, Sq, Sk, dh, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(H, Sq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(KV, Sk, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(KV, Sk, dh)), jnp.float32)
    pq = jnp.arange(Sk - Sq, Sk, dtype=jnp.int32)
    pk = jnp.arange(Sk, dtype=jnp.int32)
    return q, k, v, pq, pk


@pytest.mark.parametrize("H,KV,Sq,Sk,dh", [
    (4, 2, 64, 64, 16),
    (8, 8, 64, 64, 16),      # MHA
    (4, 1, 32, 96, 16),      # MQA + longer keys than queries
    (6, 2, 128, 128, 32),
])
@pytest.mark.parametrize("window", [None, 32])
def test_matches_oracle(H, KV, Sq, Sk, dh, window):
    q, k, v, pq, pk = make(H, KV, Sq, Sk, dh, seed=H)
    got = flash_attention(q, k, v, pq, pk, window=window, bq=16, bk=32)
    exp = ref_flash_attention(q, k, v, pq, pk, window=window)
    assert float(jnp.max(jnp.abs(got - exp))) < 2e-5


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 64), (64, 16), (64, 64)])
def test_block_shape_invariance(bq, bk):
    q, k, v, pq, pk = make(4, 2, 64, 64, 16, seed=9)
    base = ref_flash_attention(q, k, v, pq, pk)
    got = flash_attention(q, k, v, pq, pk, bq=bq, bk=bk)
    assert float(jnp.max(jnp.abs(got - base))) < 2e-5


def test_bf16_inputs():
    q, k, v, pq, pk = make(4, 2, 64, 64, 16, seed=3)
    got = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), pq, pk)
    exp = ref_flash_attention(q, k, v, pq, pk)
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - exp))) < 0.05


def test_invalid_slots_ignored():
    q, k, v, pq, pk = make(2, 2, 16, 32, 16, seed=4)
    pk = pk.at[5].set(-1)
    v_poison = v.at[:, 5].set(1e4)
    a = flash_attention(q, k, v, pq, pk, bq=8, bk=16)
    b = flash_attention(q, k, v_poison, pq, pk, bq=8, bk=16)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
