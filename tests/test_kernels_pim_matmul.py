"""Bit-plane shift-and-add matmul kernel vs oracle: shape/dtype/mode sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pim_matmul import pim_matmul, quantize, ref


def make(mkn, seed=0, x_dtype=jnp.bfloat16):
    m, k, n = mkn
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), x_dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    return x, w


SHAPES = [(8, 128, 128), (16, 256, 128), (64, 512, 256), (128, 1024, 128)]


@pytest.mark.parametrize("mkn", SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("mode", ["shift_add", "dequant"])
def test_matmul_close_to_oracle(mkn, bits, mode):
    x, w = make(mkn)
    wi, sc = quantize(w, bits)
    y = pim_matmul(x, wi, sc, mode=mode, bits=bits, bk=min(512, mkn[1]))
    yref = ref.ref_pim_matmul(x, wi, sc, bits)
    rel = float(jnp.max(jnp.abs(y - yref))
                / (jnp.max(jnp.abs(yref)) + 1e-9))
    assert rel < 2e-2, rel


@pytest.mark.parametrize("x_dtype", [jnp.bfloat16, jnp.float32])
def test_dtypes(x_dtype):
    x, w = make((16, 256, 128), x_dtype=x_dtype)
    wi, sc = quantize(w, 4)
    y = pim_matmul(x, wi, sc, mode="shift_add", bits=4, bk=256)
    yref = ref.ref_pim_matmul(x, wi, sc, 4)
    assert float(jnp.max(jnp.abs(y - yref))) < 0.05 * float(
        jnp.max(jnp.abs(yref)) + 1e-9)


@pytest.mark.parametrize("bits", [4, 8])
def test_modes_agree(bits):
    """shift_add and dequant are the same math — must agree tightly."""
    x, w = make((32, 256, 128), seed=3)
    wi, sc = quantize(w, bits)
    y1 = pim_matmul(x, wi, sc, mode="shift_add", bits=bits, bk=256)
    y2 = pim_matmul(x, wi, sc, mode="dequant", bits=bits, bk=256)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-2 * float(
        jnp.max(jnp.abs(y2)) + 1e-9)


@pytest.mark.parametrize("bits", [4, 8])
def test_plane_decomposition_exact(bits):
    """sum_b c_b·plane_b == w exactly (two's complement identity)."""
    rng = np.random.default_rng(4)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    w = jnp.asarray(rng.integers(lo, hi + 1, (64, 32)), jnp.int8)
    acc = jnp.zeros((64, 32), jnp.float32)
    for coeff, plane in zip(ref.plane_coeffs(bits), ref.ref_planes(w, bits)):
        acc = acc + coeff * plane
    assert jnp.array_equal(acc.astype(jnp.int32), w.astype(jnp.int32))


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    wi, sc = quantize(w, 8)
    wback = wi.astype(jnp.float32) * sc[None, :]
    # max quantization error ≤ scale/2 per channel
    err = jnp.max(jnp.abs(w - wback), axis=0)
    assert bool(jnp.all(err <= sc * 0.5 + 1e-7))


def test_block_shape_sweep():
    x, w = make((128, 512, 256), seed=6)
    wi, sc = quantize(w, 4)
    base = pim_matmul(x, wi, sc, mode="dequant", bits=4)
    for bm, bn, bk in [(64, 128, 256), (128, 64, 128), (32, 256, 512)]:
        y = pim_matmul(x, wi, sc, mode="dequant", bits=4, bm=bm, bn=bn, bk=bk)
        assert float(jnp.max(jnp.abs(y - base))) < 1e-3
