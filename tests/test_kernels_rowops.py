"""Pallas rowops kernel vs pure-jnp oracle (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rowops import bitwise, ripple_add, shift_cols
from repro.kernels.rowops import ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))


SHAPES = [(8, 64), (16, 128), (32, 256)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("op", ["and", "or", "xor", "not", "maj"])
def test_bitwise_ops(shape, op):
    a, b, c = rand(shape, 1), rand(shape, 2), rand(shape, 3)
    got = bitwise(a, b, c, op=op)
    exp = ref.ref_bitwise(a, b, c, op=op)
    assert jnp.array_equal(got, exp)


@pytest.mark.parametrize("shape", SHAPES[:2])
@pytest.mark.parametrize("k", [1, -1, 3, 31, 32, -32, 33, 100, -100])
def test_shift_cols(shape, k):
    x = rand(shape, k & 0xFF)
    assert jnp.array_equal(shift_cols(x, k), ref.ref_shift_cols(x, k))


@pytest.mark.parametrize("width", [4, 8, 16])
def test_ripple_add_matches_lane_math(width):
    rng = np.random.default_rng(width)
    rows, words = 8, 64
    lanes = words * 32 // width
    av = rng.integers(0, 1 << width, (rows, lanes), dtype=np.uint64)
    bv = rng.integers(0, 1 << width, (rows, lanes), dtype=np.uint64)

    def pack(vals):
        out = np.zeros((rows, words), dtype=np.uint32)
        for r in range(rows):
            big = 0
            for v in vals[r][::-1]:
                big = (big << width) | int(v)
            for i in range(words):
                out[r, i] = (big >> (32 * i)) & 0xFFFFFFFF
        return jnp.asarray(out)

    got = ripple_add(pack(av), pack(bv), width=width)
    exp = pack((av + bv) % (1 << width))
    assert jnp.array_equal(got, exp)
    assert jnp.array_equal(got, ref.ref_ripple_add(pack(av), pack(bv), width))


def test_fused_adder_equals_composed_primitives():
    """The fused kernel must equal the op-by-op (paper-faithful) sequence."""
    width = 8
    a, b = rand((8, 64), 10), rand((8, 64), 11)
    interior = jnp.uint32(ref._interior_mask(width))
    s = bitwise(a, b, op="xor")
    c = bitwise(a, b, op="and")
    for _ in range(width - 1):
        cs = bitwise(shift_cols(c, 1), jnp.broadcast_to(interior, c.shape),
                     op="and")
        c = bitwise(s, cs, op="and")
        s = bitwise(s, cs, op="xor")
    fused = ripple_add(a, b, width=width)
    assert jnp.array_equal(fused, s)
