"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, loss_fn
from repro.optim import adamw

from util import make_inputs

B, S = 2, 64


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_inputs(cfg, B, S)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(metrics["ce_loss"]))


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "deepseek-v2-lite-16b"])
def test_one_grad_step_finite(arch):
    """Covers the exotic backward paths (MoE dispatch, selective scan,
    RG-LRU, MLA)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    train, frozen = adamw.partition(params)
    batch = make_inputs(cfg, B, S)

    def loss_of(tp):
        return loss_fn(cfg, adamw.merge(tp, frozen), batch)[0]

    grads = jax.jit(jax.grad(loss_of))(train)
    gnorm = adamw.global_norm(grads)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_close_to_actual(arch):
    """The roofline's 6·N·D uses the analytic count — keep it honest."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(2))
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.n_params()
    assert abs(actual - analytic) / actual < 0.06, (actual, analytic)


def test_pim_quantized_config_runs():
    """The paper's technique as a first-class feature: pim_w4 linears."""
    cfg = get_config("qwen3-4b", smoke=True, quant="pim_w4",
                     quant_mode="shift_add")
    params = init_params(cfg, jax.random.PRNGKey(3))
    leaves = jax.tree_util.tree_leaves_with_path(params)
    assert any("w_int" in "/".join(str(p) for p in path)
               for path, _ in leaves)
    batch = make_inputs(cfg, B, S)
    loss, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert bool(jnp.isfinite(loss))


def test_pim_quant_modes_agree():
    cfg_s = get_config("qwen3-4b", smoke=True, quant="pim_w4",
                       quant_mode="shift_add")
    cfg_d = get_config("qwen3-4b", smoke=True, quant="pim_w4",
                       quant_mode="dequant")
    params = init_params(cfg_s, jax.random.PRNGKey(4))
    batch = make_inputs(cfg_s, B, S)
    l1, _ = jax.jit(lambda p, b: loss_fn(cfg_s, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: loss_fn(cfg_d, p, b))(params, batch)
    assert float(jnp.abs(l1 - l2)) < 0.05
