"""MoE dispatch implementations: einsum (GShard baseline) vs gather (§Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.models.ffn import _capacity, moe_ffn
from repro.optim import adamw

from util import make_inputs


def cfgs():
    e = get_config("qwen3-moe-30b-a3b", smoke=True)
    g = dataclasses.replace(e, moe=dataclasses.replace(e.moe, impl="gather"))
    return e, g


def test_gather_matches_einsum_loss_and_grads():
    cfg_e, cfg_g = cfgs()
    params = init_params(cfg_e, jax.random.PRNGKey(0))
    batch = make_inputs(cfg_e, 2, 64, seed=3)
    l1, _ = jax.jit(lambda p, b: loss_fn(cfg_e, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: loss_fn(cfg_g, p, b))(params, batch)
    assert float(jnp.abs(l1 - l2)) < 1e-5

    t, f = adamw.partition(params)
    g1 = jax.jit(jax.grad(
        lambda tp: loss_fn(cfg_e, adamw.merge(tp, f), batch)[0]))(t)
    g2 = jax.jit(jax.grad(
        lambda tp: loss_fn(cfg_g, adamw.merge(tp, f), batch)[0]))(t)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32)))) < 1e-6


@pytest.mark.parametrize("impl", ["einsum", "gather"])
def test_capacity_drops_are_bounded(impl):
    """With capacity_factor ≥ 1 and perfect balance no tokens drop; with a
    tiny factor the layer still runs and outputs stay finite."""
    cfg, cfg_g = cfgs()
    cfg = cfg if impl == "einsum" else cfg_g
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_inputs(cfg, 2, 64, seed=4)
    loss, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert bool(jnp.isfinite(loss))


def test_moe_aux_loss_encourages_balance():
    cfg_e, _ = cfgs()
    params = init_params(cfg_e, jax.random.PRNGKey(2))
    batch = make_inputs(cfg_e, 2, 64, seed=5)
    _, metrics = jax.jit(lambda p, b: loss_fn(cfg_e, p, b))(params, batch)
    # switch LB loss is E·Σ f·p ≥ 1 with equality at perfect balance
    aux = float(metrics["aux_loss"]) / cfg_e.moe.router_aux_weight
    assert aux >= 0.9


def test_capacity_rounding():
    cfg_e, _ = cfgs()
    c = _capacity(64, cfg_e)
    assert c % 4 == 0 and c >= 64 * cfg_e.moe.top_k / cfg_e.moe.n_experts
