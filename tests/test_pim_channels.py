"""Channel-aware bus model, async host engine, COPY link contention.

Locks down the device timing model rework: per-channel FCFS serialization
of ISSUE + HOSTW/HOSTR burst windows (channels overlap, rank switches pay
tRTRS), the Shared-PIM-style async host-transfer engine (double-buffered
against the previous step's compute window), the FCFS link/internal-bus
queue model for drained COPYs, the LRU compile cache, and the true
fixed-point refresh re-count.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

import importlib

from repro.core import pim
from repro.core.pim import exec as pim_exec

# the package re-exports schedule() the function, shadowing the module
pim_schedule = importlib.import_module("repro.core.pim.schedule")

WORDS = 8
ROWS = 32
T = pim.DEFAULT_TIMING


def _rand_row(rng, words=WORDS):
    return rng.integers(0, 2**32, (words,), dtype=np.uint32)


def _host_shift_prog(data, k, rows=ROWS, words=WORDS):
    b = pim.ProgramBuilder(rows, words)
    b.issue()
    b.write_row(0, data)
    b.shift_k(0, 1, k)
    b.read_row(1)
    return b.build()


def _cfg(channels, ranks, banks_per_rank, subarrays=1):
    return pim.DeviceConfig(channels=channels, ranks=ranks,
                            banks_per_rank=banks_per_rank,
                            subarrays=subarrays, num_rows=ROWS, words=WORDS)


# ---------------------------------------------------------------------------
# Per-channel bus serialization
# ---------------------------------------------------------------------------

def test_bus_time_counts_issue_and_host_bursts():
    rng = np.random.default_rng(0)
    p = _host_shift_prog(_rand_row(rng), 3)
    burst = pim.burst_time_ns(WORDS * 4, T)
    assert pim.issue_bus_ns(p, T) == pytest.approx(T.t_issue)
    assert pim.host_bus_ns(p, T) == pytest.approx(2 * burst)  # HOSTW + HOSTR
    assert pim.bus_time_ns(p, T) == pytest.approx(T.t_issue + 2 * burst)
    assert pim.bus_time_ns(None, T) == 0.0


def test_single_slot_wall_is_the_subarray_meter():
    """1-channel, 1-slot: bus + exec telescopes back to the meter exactly —
    the PR-3 degenerate contract survives host bursts entering bus time."""
    rng = np.random.default_rng(1)
    prog = _host_shift_prog(_rand_row(rng), 9)
    res = pim.schedule(pim.make_device(_cfg(1, 1, 1)), [prog])
    ref = pim_exec.execute(prog, pim.reserve_control_rows(
        pim.make_subarray(ROWS, WORDS)))
    assert float(res.wall_ns) == pytest.approx(
        float(ref.state.meter.time_ns), rel=1e-6)


def test_two_channels_overlap_bursts():
    """Work on both channels: the channel-aware wall sits strictly below
    the device-wide-serialized (PR-3) wall; states and reads bit-exact."""
    rng = np.random.default_rng(2)
    progs = [_host_shift_prog(_rand_row(rng), 4) for _ in range(4)]
    r1 = pim.schedule(pim.make_device(_cfg(1, 1, 4)), progs)
    r2 = pim.schedule(pim.make_device(_cfg(2, 1, 2)), progs)
    # 1 channel, 1 rank == the legacy device-wide serialization
    buses = [pim.bus_time_ns(p, T) for p in progs]
    exec_ns = np.asarray(r1.state.banks.meter.time_ns) - np.asarray(buses)
    legacy = pim.device_wall_ns(buses, exec_ns)
    assert float(r1.wall_ns) == pytest.approx(float(legacy), rel=1e-6)
    assert float(r2.wall_ns) < float(r1.wall_ns)
    assert len(r2.channel_bus_ns) == 2
    assert sum(r2.channel_bus_ns) == pytest.approx(sum(buses), rel=1e-6)
    assert np.array_equal(np.asarray(r1.state.banks.bits),
                          np.asarray(r2.state.banks.bits))
    for a, b in zip(r1.reads, r2.reads):
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_two_channels_equal_when_one_channel_idle():
    """All work placed on channel 0 of a 2-channel device == the same work
    on a 1-channel device of that shape."""
    rng = np.random.default_rng(3)
    progs = [_host_shift_prog(_rand_row(rng), 4) for _ in range(2)]
    r1 = pim.schedule(pim.make_device(_cfg(1, 1, 2)), progs)
    r2 = pim.schedule(pim.make_device(_cfg(2, 1, 2)), progs + [None, None])
    assert float(r2.wall_ns) == pytest.approx(float(r1.wall_ns), rel=1e-6)
    assert r2.channel_bus_ns[1] == 0.0


def test_rank_switch_penalty_counted_per_transition():
    rng = np.random.default_rng(4)
    prog = _host_shift_prog(_rand_row(rng), 2)
    # 1 channel x 2 ranks x 2 banks/rank; bank order 0,1 (rank 0), 2,3
    # (rank 1): active banks (0, 2) switch rank once
    r = pim.schedule(pim.make_device(_cfg(1, 2, 2)),
                     [prog, None, prog, None])
    assert r.rank_switch_ns == pytest.approx(T.tRTRS)
    # same-rank banks: no switch
    r0 = pim.schedule(pim.make_device(_cfg(1, 2, 2)),
                      [prog, prog, None, None])
    assert r0.rank_switch_ns == 0.0
    assert float(r.wall_ns) - float(r0.wall_ns) == pytest.approx(
        T.tRTRS, abs=1e-3)
    # four active banks in slot order 0,1,2,3 -> one rank transition
    r4 = pim.schedule(pim.make_device(_cfg(1, 2, 2)), [prog] * 4)
    assert r4.rank_switch_ns == pytest.approx(T.tRTRS)


def test_wall_invariant_two_channels_never_worse():
    """For ANY placement, splitting the same banks across 2 channels never
    increases the wall (channels only add overlap)."""
    rng = np.random.default_rng(5)
    for seed in range(8):
        r = np.random.default_rng(seed)
        progs = [_host_shift_prog(_rand_row(rng), int(r.integers(1, 6)))
                 if r.random() < 0.7 else None for _ in range(4)]
        if all(p is None for p in progs):
            continue
        w1 = pim.schedule(pim.make_device(_cfg(1, 1, 4)), progs)
        w2 = pim.schedule(pim.make_device(_cfg(2, 1, 2)), progs)
        assert float(w2.wall_ns) <= float(w1.wall_ns) + 1e-3, seed


# ---------------------------------------------------------------------------
# Async host engine
# ---------------------------------------------------------------------------

def _pipeline(async_host, steps, cfg=None):
    cfg = cfg or _cfg(1, 1, 2)
    dev = pim.make_device(cfg)
    walls, results = [], []
    for progs in steps:
        res = pim.schedule(dev, progs, async_host=async_host)
        dev = res.state
        walls.append(float(res.wall_ns))
        results.append(res)
    return walls, results, dev


def test_async_host_overlaps_previous_compute():
    rng = np.random.default_rng(6)
    steps = [[_host_shift_prog(_rand_row(rng), 8) for _ in range(2)]
             for _ in range(3)]
    sw, sres, sdev = _pipeline(False, steps)
    aw, ares, adev = _pipeline(True, steps)
    # step 0 has no prior compute to hide behind: identical walls
    assert aw[0] == pytest.approx(sw[0], rel=1e-6)
    # later steps hide their host bursts under the previous compute window
    for k in (1, 2):
        assert aw[k] < sw[k]
        assert ares[k].host_overlap_ns > 0.0
        assert aw[k] == pytest.approx(
            sw[k] - ares[k].host_overlap_ns, rel=1e-6)
    # bits, reads, energy identical — only the wall accounting moves
    assert np.array_equal(np.asarray(sdev.banks.bits),
                          np.asarray(adev.banks.bits))
    for rs, ra in zip(sres, ares):
        assert float(rs.energy_nj) == pytest.approx(
            float(ra.energy_nj), rel=1e-6)
        for a, b in zip(rs.reads, ra.reads):
            for x, y in zip(a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y))


def test_async_host_never_worse_than_sync():
    rng = np.random.default_rng(7)
    for seed in range(6):
        r = np.random.default_rng(100 + seed)
        steps = []
        for _ in range(int(r.integers(2, 4))):
            steps.append([
                _host_shift_prog(_rand_row(rng), int(r.integers(1, 10)))
                if r.random() < 0.8 else None for _ in range(2)])
        sw, _, _ = _pipeline(False, steps)
        aw, _, _ = _pipeline(True, steps)
        for k, (s, a) in enumerate(zip(sw, aw)):
            assert a <= s + 1e-3, (seed, k)


def test_async_credit_is_the_previous_compute_window():
    """The double buffer hides at most the previous step's compute+copy
    time: a transfer-heavy step after a tiny compute step stays exposed."""
    rng = np.random.default_rng(8)
    tiny = [_host_shift_prog(_rand_row(rng), 1), None]
    heavy = [_host_shift_prog(_rand_row(rng), 1) for _ in range(2)]
    dev = pim.make_device(_cfg(1, 1, 2))
    r0 = pim.schedule(dev, tiny, async_host=True)
    credit = r0.state.host_credit_ns
    r1 = pim.schedule(r0.state, heavy, async_host=True)
    assert r1.host_overlap_ns == pytest.approx(credit, rel=1e-6)
    assert r1.host_overlap_ns < r1.host_bus_ns


def test_sync_phase_resets_async_credit():
    """async -> sync -> async: the sync step RESETS the double-buffer
    credit (its host engine ran synchronously — nothing is prefetched),
    so the async step right after it hides NOTHING, and only the one
    after that overlaps again, by exactly min(host bus, previous compute
    window) — all hand-computed."""
    rng = np.random.default_rng(14)
    heavy = [_host_shift_prog(_rand_row(rng), 12) for _ in range(2)]
    light = [_host_shift_prog(_rand_row(rng), 1), None]

    # async step banks a positive credit...
    r0 = pim.schedule(pim.make_device(_cfg(1, 1, 2)), heavy,
                      async_host=True)
    assert float(r0.state.host_credit_ns) > 0.0
    # ...the sync step consumes nothing and must RESET the leaf to zero
    # (the old behaviour silently carried its compute window instead)
    r1 = pim.schedule(r0.state, light, async_host=False)
    assert r1.host_overlap_ns == 0.0
    assert float(r1.state.host_credit_ns) == 0.0

    # async again: nothing was prefetched during the sync step, so this
    # step stays fully exposed — its wall equals the sync wall exactly
    r2 = pim.schedule(r1.state, heavy, async_host=True)
    assert r2.host_overlap_ns == 0.0
    sync_wall = pim.schedule(pim.make_device(_cfg(1, 1, 2)), heavy).wall_ns
    assert float(r2.wall_ns) == pytest.approx(float(sync_wall), rel=1e-6)

    # and the NEXT async step overlaps again: min(host bus, r2's compute)
    credit = float(r2.state.host_credit_ns)
    assert credit > 0.0
    r3 = pim.schedule(r2.state, heavy, async_host=True)
    host_total = 2 * pim.host_bus_ns(heavy[0], T)   # one channel, 2 banks
    assert r3.host_overlap_ns == pytest.approx(min(host_total, credit),
                                               rel=1e-6)


# ---------------------------------------------------------------------------
# COPY drain contention
# ---------------------------------------------------------------------------

def test_gather_serializes_on_internal_bus():
    """N-1 inter-bank copies into bank 0 share one internal bus: makespan =
    N-1 transfers back-to-back, FCFS queueing = 0 + dt + 2dt + ..."""
    rng = np.random.default_rng(9)
    n = 4
    cfg = _cfg(1, 1, n)
    load = [pim.ProgramBuilder(ROWS, WORDS).write_row(1, _rand_row(rng))
            .build() for _ in range(n)]
    state = pim.schedule(pim.make_device(cfg), load).state
    moves = [((b, 0, 1), (0, 0, 1 + b)) for b in range(1, n)]
    res = pim.schedule(state, pim.gather_rows(cfg, moves))
    dt = T.t_aap + T.t_copy_bank
    assert res.copy_ns == pytest.approx(3 * dt)
    assert res.copy_total_ns == pytest.approx(3 * dt)
    assert res.copy_queue_ns == pytest.approx((1 + 2) * dt)
    assert res.link_busy_ns[("ibus", 0)] == pytest.approx(3 * dt)


def test_intra_bank_copies_in_different_banks_overlap():
    rng = np.random.default_rng(10)
    cfg = _cfg(1, 1, 2, subarrays=2)
    progs = []
    for b in range(2):
        pb = pim.ProgramBuilder(ROWS, WORDS)
        pb.write_row(0, _rand_row(rng))
        pb.copy_row(0, 1, dst_bank=b, dst_sub=1)
        progs.append([pb.build(), None])
    res = pim.schedule(pim.make_device(cfg), progs)
    dt = T.t_aap + T.t_rbm
    assert res.copy_total_ns == pytest.approx(2 * dt)
    assert res.copy_ns == pytest.approx(dt)          # disjoint bank links
    assert res.copy_queue_ns == 0.0


def test_disjoint_links_within_one_bank_overlap():
    """S=4: a sub0->sub1 copy (link 0) and a sub2->sub3 copy (link 2) use
    different RBM links of the same bank and drain concurrently."""
    rng = np.random.default_rng(11)
    cfg = _cfg(1, 1, 1, subarrays=4)
    p01 = pim.ProgramBuilder(ROWS, WORDS)
    p01.write_row(0, _rand_row(rng))
    p01.copy_row(0, 1, dst_bank=0, dst_sub=1)
    p23 = pim.ProgramBuilder(ROWS, WORDS)
    p23.write_row(0, _rand_row(rng))
    p23.copy_row(0, 1, dst_bank=0, dst_sub=3)
    res = pim.schedule(pim.make_device(cfg),
                       [[p01.build(), None, p23.build(), None]])
    dt = T.t_aap + T.t_rbm
    assert res.copy_ns == pytest.approx(dt)
    assert res.copy_queue_ns == 0.0
    # overlapping spans (sub0->sub2 and sub1->sub3) DO contend on link 1
    p02 = pim.ProgramBuilder(ROWS, WORDS)
    p02.write_row(0, _rand_row(rng))
    p02.copy_row(0, 1, dst_bank=0, dst_sub=2)
    p13 = pim.ProgramBuilder(ROWS, WORDS)
    p13.write_row(0, _rand_row(rng))
    p13.copy_row(0, 1, dst_bank=0, dst_sub=3)
    res2 = pim.schedule(pim.make_device(cfg),
                        [[p02.build(), p13.build(), None, None]])
    dt2 = T.t_aap + 2 * T.t_rbm
    assert res2.copy_ns == pytest.approx(2 * dt2)
    assert res2.copy_queue_ns == pytest.approx(dt2)


def test_32_bank_gather_has_nonzero_queueing():
    """Acceptance: a 32-bank gather shows nonzero COPY queueing delay."""
    rng = np.random.default_rng(12)
    cfg = pim.paper_device(32, num_rows=ROWS, words=WORDS)
    load = [pim.ProgramBuilder(ROWS, WORDS).write_row(1, _rand_row(rng))
            .build() for _ in range(32)]
    state = pim.schedule(pim.make_device(cfg), load).state
    moves = [((b, 0, 1), (0, 0, 2 + (b - 1) % 12)) for b in range(1, 32)]
    res = pim.schedule(state, pim.gather_rows(cfg, moves))
    assert res.copy_queue_ns > 0.0
    assert res.copy_ns > T.t_aap + T.t_copy_bank          # not a single hop
    # every copy lands on bank 0, so its channel's internal bus serializes
    # the whole gather: makespan == contention-free sum
    assert res.copy_ns == pytest.approx(res.copy_total_ns)
    assert ("ibus", 0) in res.link_busy_ns
    assert ("ibus", 1) in res.link_busy_ns
    # split the gather across the two channels' hub banks (0 and 16) and
    # the buses drain concurrently: makespan strictly below the sum
    state2 = pim.schedule(pim.make_device(cfg), load).state
    moves2 = [((b, 0, 1), (0, 0, 2 + (b - 1) % 12))
              for b in range(1, 16)]
    moves2 += [((b, 0, 1), (16, 0, 2 + (b - 17) % 12))
               for b in range(17, 32)]
    res2 = pim.schedule(state2, pim.gather_rows(cfg, moves2))
    assert res2.copy_ns < res2.copy_total_ns
    assert res2.copy_queue_ns > 0.0


# ---------------------------------------------------------------------------
# LRU compile cache
# ---------------------------------------------------------------------------

def test_compile_cache_is_lru_not_fifo(monkeypatch):
    """A hot recurring stream must survive _COMPILE_CACHE_MAX distinct
    one-off streams as long as it keeps being touched."""
    monkeypatch.setattr(pim_schedule, "_COMPILE_CACHE_MAX", 8)
    monkeypatch.setattr(pim_schedule, "_compile_cache", {})
    cache = pim_schedule._compile_cache

    def prog(k):
        b = pim.ProgramBuilder(ROWS, WORDS)
        for _ in range(k + 1):
            b.rowclone(0, 1)
        return b.build()

    hot = prog(0)
    hot_compiled = pim_schedule._compiled_for(hot, T)
    hot_key = (pim.stream_key(hot), T)
    for k in range(1, 9):                     # MAX distinct one-offs
        pim_schedule._compiled_for(prog(k), T)
        # the hot stream recurs between one-offs (PimVM-flush pattern)
        assert pim_schedule._compiled_for(hot, T) is hot_compiled
    assert hot_key in cache
    assert len(cache) <= 8
    # and a hit refreshes recency: the oldest untouched one-off is the
    # eviction victim, not the hot key
    assert (pim.stream_key(prog(1)), T) not in cache


# ---------------------------------------------------------------------------
# Refresh fixed point
# ---------------------------------------------------------------------------

def _ref_refresh_events(busy_ns: float, cfg) -> int:
    """Step-by-step reference: walk tREFI boundaries one event at a time,
    each event's tRFC stall extending the wall clock (float32, matching
    the meter arithmetic)."""
    busy = np.float32(busy_ns)
    n = 0
    while busy + np.float32(n) * np.float32(cfg.tRFC) \
            >= np.float32(n + 1) * np.float32(cfg.tREFI):
        n += 1
    return n


@pytest.mark.parametrize("busy_ms", [0.005, 0.9, 2.0, 7.7, 31.0, 123.4])
def test_refresh_events_match_step_by_step_reference(busy_ms):
    busy = busy_ms * 1e6
    got = int(pim.refresh_events(jnp.float32(busy)))
    assert got == _ref_refresh_events(busy, T)


def test_refresh_events_property_sweep():
    rng = np.random.default_rng(13)
    for _ in range(50):
        busy = float(rng.uniform(0.0, 5e7))
        got = int(pim.refresh_events(jnp.float32(busy)))
        assert got == _ref_refresh_events(busy, T), busy


def test_old_single_recount_undercounts_on_long_streams():
    """The regression this fixes: one re-count loses events once the
    accumulated tRFC stalls cross more than one extra tREFI boundary."""
    busy = np.float32(50e6)                    # 50 ms
    n0 = int(np.floor(busy / np.float32(T.tREFI)))
    old = int(np.floor((busy + np.float32(n0) * np.float32(T.tRFC))
                       / np.float32(T.tREFI)))
    new = int(pim.refresh_events(jnp.float32(busy)))
    assert new > old                           # the cascade matters
    assert new == _ref_refresh_events(float(busy), T)


def test_apply_refresh_long_meter_incremental_consistency():
    """Applying refresh to one 20ms meter == applying it across two 10ms
    installments (every event charged exactly once, fixed point included)."""
    half = 10e6
    m = dataclasses.replace(pim.CostMeter.zeros(),
                            time_ns=jnp.float32(2 * half))
    once = pim.apply_refresh(m)

    m2 = dataclasses.replace(pim.CostMeter.zeros(),
                             time_ns=jnp.float32(half))
    first = pim.apply_refresh(m2)
    stepped = dataclasses.replace(
        first, time_ns=first.time_ns + jnp.float32(half))
    twice = pim.apply_refresh(stepped)
    assert int(once.n_refresh) == int(twice.n_refresh)
    assert float(once.time_ns) == pytest.approx(float(twice.time_ns),
                                                rel=1e-6)
    assert float(once.e_refresh) == pytest.approx(float(twice.e_refresh),
                                                  rel=1e-6)
