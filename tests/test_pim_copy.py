"""In-DRAM row movement (LISA COPY) + multi-subarray banks.

Covers: the subarray axis on DeviceConfig/DeviceState, local COPY semantics
across all three execution paths, scheduler-drained cross-subarray and
cross-bank copies (timing/energy charged to the source slot, zero host
bytes), the gather/reduce primitives, subarray-aware sharding, and the
incremental-refresh regression (apply_refresh used to re-charge the whole
history on every refreshed ``schedule`` call).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import pim
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir

WORDS = 4
ROWS = 16


def _rand_row(rng):
    return rng.integers(0, 2**32, (WORDS,), dtype=np.uint32)


def _device(n_banks, subarrays=1, rows=ROWS, words=WORDS):
    return pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=n_banks, subarrays=subarrays,
        num_rows=rows, words=words))


# ---------------------------------------------------------------------------
# Device geometry
# ---------------------------------------------------------------------------

def test_subarray_axis_shapes_and_accessors():
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=3,
                           subarrays=2, num_rows=ROWS, words=WORDS)
    assert cfg.n_banks == 3 and cfg.n_slots == 6
    assert cfg.slot_index(2, 1) == 5
    assert cfg.slot_coords(5) == (2, 1)
    with pytest.raises(ValueError, match="subarray"):
        cfg.slot_index(0, 2)
    with pytest.raises(ValueError, match="bank"):
        cfg.slot_index(3, 0)
    dev = pim.make_device(cfg)
    assert dev.banks.bits.shape == (6, ROWS, WORDS)
    assert dev.slot(2, 1).bits.shape == (ROWS, WORDS)
    assert dev.bank(1).bits.shape == (2, ROWS, WORDS)   # stacked subarrays
    # single-subarray banks keep the PR-2 unbatched contract
    assert _device(2).bank(1).bits.shape == (ROWS, WORDS)


def test_paper_device_takes_subarrays():
    cfg = pim.paper_device(8, subarrays=4)
    assert cfg.n_banks == 8 and cfg.n_slots == 32


# ---------------------------------------------------------------------------
# Local COPY: one op, three execution paths
# ---------------------------------------------------------------------------

def test_local_copy_agrees_and_costs_one_aap():
    rng = np.random.default_rng(0)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, _rand_row(rng))
    b.copy_row(0, 2)
    b.read_row(2)
    prog = b.build()
    st = pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS))
    s_e, reads_e = pim.run_program(st, prog)
    res = pim_exec.execute(
        prog, pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS)))
    assert np.array_equal(np.asarray(s_e.bits), np.asarray(res.state.bits))
    assert np.array_equal(np.asarray(reads_e[0]), np.asarray(res.reads[0]))
    for f in ("time_ns", "e_act", "e_pre"):
        assert float(getattr(s_e.meter, f)) == float(
            getattr(res.state.meter, f)), f
    # distance-0 LISA copy == exactly one AAP
    ref = pim.lisa_copy(pim.make_subarray(ROWS, WORDS), 0, 2)
    assert int(ref.meter.n_aap) == 1 and int(ref.meter.n_act) == 2
    assert float(ref.meter.time_ns) == pytest.approx(
        pim.DEFAULT_TIMING.t_aap)


def test_cross_subarray_copy_refused_off_device():
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.copy_row(0, 1, dst_bank=0, dst_sub=1)
    prog = b.build()
    with pytest.raises(ValueError, match="scheduler"):
        pim_exec.execute(prog)
    with pytest.raises(ValueError, match="scheduler"):
        pim.run_program(pim.make_subarray(ROWS, WORDS), prog)


# ---------------------------------------------------------------------------
# Scheduler-drained copies
# ---------------------------------------------------------------------------

def test_cross_subarray_copy_moves_row_and_charges_source():
    rng = np.random.default_rng(1)
    data = _rand_row(rng)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, data)
    b.copy_row(0, 5, dst_bank=0, dst_sub=2)
    dev = _device(1, subarrays=3)
    res = pim.schedule(dev, [[b.build(), None, None]])
    assert np.array_equal(np.asarray(res.state.slot(0, 2).bits[5]), data)
    t = pim.DEFAULT_TIMING
    dt, e_act, e_pre, n_act, n_pre, n_aap = pim.copy_cost(2, False, t)
    assert dt == pytest.approx(t.t_aap + 2 * t.t_rbm)
    m_src = res.state.slot(0, 0).meter
    m_dst = res.state.slot(0, 2).meter
    # the source slot pays (write burst + copy); the destination stays idle
    assert float(m_dst.time_ns) == 0.0
    assert res.copy_ns == pytest.approx(dt)
    assert int(m_src.n_aap) == 1 and int(m_src.n_act) == 1 + n_act
    assert float(res.energy_nj) > 0


def test_cross_bank_copy_and_next_step_visibility():
    rng = np.random.default_rng(2)
    data = _rand_row(rng)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, data)
    b.copy_row(0, 7, dst_bank=2, dst_sub=0)
    dev = _device(3)
    r1 = pim.schedule(dev, [b.build(), None, None])
    assert np.array_equal(np.asarray(r1.state.bank(2).bits[7]), data)
    t = pim.DEFAULT_TIMING
    assert r1.copy_ns == pytest.approx(t.t_aap + t.t_copy_bank)
    # the moved row is readable by the NEXT schedule step
    rb = pim.ProgramBuilder(ROWS, WORDS)
    rb.read_row(7)
    r2 = pim.schedule(r1.state, [None, None, rb.build()])
    assert np.array_equal(np.asarray(r2.reads[2][0]), data)


def test_inter_bank_copy_charges_edge_hops():
    """Bugfix regression: an inter-bank COPY used to charge hops = 0
    regardless of the subarrays involved, so S-1 → S-1 cost the same as
    edge-to-edge. The row must ride RBM links to the source bank's edge
    (subarray 0) and from the destination's edge inward: hand-computed,
    src sub 2 → dst sub 1 is 3 hops on top of the internal-bus transfer."""
    rng = np.random.default_rng(20)
    data = _rand_row(rng)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, data)
    b.copy_row(0, 5, dst_bank=1, dst_sub=1)
    dev = _device(2, subarrays=3)
    # carrier slot = (bank 0, sub 2): 2 hops to the edge + 1 hop inward
    res = pim.schedule(dev, [[None, None, b.build()], [None, None, None]])
    assert np.array_equal(np.asarray(res.state.slot(1, 1).bits[5]), data)
    t = pim.DEFAULT_TIMING
    expect_dt = t.t_aap + 3 * t.t_rbm + t.t_copy_bank
    assert res.copy_ns == pytest.approx(expect_dt)
    assert res.copy_total_ns == pytest.approx(expect_dt)
    m_src = res.state.slot(0, 2).meter
    # meter: one HOSTW burst + the copy (2 ACT, 1 PRE, 1 AAP)
    assert int(m_src.n_aap) == 1
    assert int(m_src.n_act) == 1 + 2 and int(m_src.n_pre) == 1 + 1
    e_copy = 2 * t.e_act + 3 * t.e_rbm + t.e_copy_bank
    assert float(m_src.e_act) == pytest.approx(t.e_act + e_copy)
    burst_dt = pim.burst_time_ns(WORDS * 4, t)
    assert float(m_src.time_ns) == pytest.approx(burst_dt + expect_dt)


def test_edge_to_edge_inter_bank_copy_still_bus_only():
    """S-1 → S-1 vs 0 → 0 inter-bank copies must now differ by exactly
    2·(S-1) RBM hops."""
    rng = np.random.default_rng(21)
    data = _rand_row(rng)
    t = pim.DEFAULT_TIMING
    walls = []
    for sub in (0, 2):
        b = pim.ProgramBuilder(ROWS, WORDS)
        b.write_row(0, data)
        b.copy_row(0, 5, dst_bank=1, dst_sub=sub)
        dev = _device(2, subarrays=3)
        progs = [[None, None, None], [None, None, None]]
        progs[0][sub] = b.build()
        res = pim.schedule(dev, progs)
        assert np.array_equal(np.asarray(res.state.slot(1, sub).bits[5]),
                              data)
        walls.append(res.copy_ns)
    assert walls[0] == pytest.approx(t.t_aap + t.t_copy_bank)
    assert walls[1] - walls[0] == pytest.approx(4 * t.t_rbm)


def test_copy_drains_after_compute_and_in_stream_order():
    """A COPY reads its source row's post-compute value, and later copies
    observe earlier ones (chained gather within one step)."""
    rng = np.random.default_rng(3)
    data = _rand_row(rng)
    b0 = pim.ProgramBuilder(ROWS, WORDS)
    b0.write_row(0, data)
    b0.copy_row(0, 4, dst_bank=1, dst_sub=0)  # reads row 0 AFTER the shift
    b0.shift(0, 0, +1)                        # compute happens first
    b1 = pim.ProgramBuilder(ROWS, WORDS)
    b1.copy_row(4, 5, dst_bank=2, dst_sub=0)  # later slot: sees row 4
    dev = _device(3)
    res = pim.schedule(dev, [b0.build(), b1.build(), None])
    shifted = np.asarray(pim.shift_row_words(jnp.asarray(data), 1))
    assert np.array_equal(np.asarray(res.state.bank(1).bits[4]), shifted)
    assert np.array_equal(np.asarray(res.state.bank(2).bits[5]), shifted)


def test_copy_to_own_slot_is_local_on_any_carrier():
    """COPY whose destination IS the carrying slot executes in-stream, even
    for carriers other than bank 0 — and a (0,0)-addressed COPY on another
    carrier is a genuine transfer to bank 0 (the regression that bit the
    first implementation)."""
    rng = np.random.default_rng(4)
    data = _rand_row(rng)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, data)
    b.copy_row(0, 3, dst_bank=1, dst_sub=0)   # local: carrier is (1, 0)
    b.copy_row(3, 6, dst_bank=0, dst_sub=0)   # cross-bank to bank 0
    dev = _device(2)
    res = pim.schedule(dev, [None, b.build()])
    assert np.array_equal(np.asarray(res.state.bank(1).bits[3]), data)
    assert np.array_equal(np.asarray(res.state.bank(0).bits[6]), data)


def test_default_copy_stays_local_when_replicated_across_banks():
    """Regression: a stream recorded with the default (self) COPY
    destination must behave identically on EVERY slot — it used to be
    silently retargeted to bank 0 when scheduled on banks 1+."""
    rng = np.random.default_rng(11)
    rows = rng.integers(0, 2**32, (4, WORDS), dtype=np.uint32)
    progs = []
    for b in range(4):
        pb = pim.ProgramBuilder(ROWS, WORDS)
        pb.write_row(1, rows[b])
        pb.copy_row(1, 2)                 # default destination = self
        progs.append(pb.build())
    res = pim.schedule(_device(4), progs)
    assert res.copy_ns == 0.0             # all local, nothing drained
    for b in range(4):
        ref, _ = pim.run_program(
            pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS)),
            progs[b])
        assert np.array_equal(np.asarray(res.state.bank(b).bits),
                              np.asarray(ref.bits)), b


def test_schedule_accepts_flat_slot_programs():
    rng = np.random.default_rng(5)
    d0, d1 = _rand_row(rng), _rand_row(rng)
    mk = lambda d: pim.ProgramBuilder(ROWS, WORDS).write_row(0, d).build()
    dev = _device(2, subarrays=2)
    res = pim.schedule(dev, [mk(d0), None, None, mk(d1)])
    assert np.array_equal(np.asarray(res.state.slot(0, 0).bits[0]), d0)
    assert np.array_equal(np.asarray(res.state.slot(1, 1).bits[0]), d1)
    with pytest.raises(ValueError, match="programs for"):
        pim.schedule(dev, [None, None, None])
    with pytest.raises(ValueError, match="subarray programs"):
        pim.schedule(dev, [[None], [None]])


# ---------------------------------------------------------------------------
# gather_rows / xor_reduce_program
# ---------------------------------------------------------------------------

def test_gather_reduce_zero_host_bytes_bit_exact():
    """Binary-tree XOR reduction of one row across 4 banks: every byte moves
    via COPY (host_bytes == 0) and the result equals the numpy fold."""
    rng = np.random.default_rng(6)
    n = 4
    rows = rng.integers(0, 2**32, (n, WORDS), dtype=np.uint32)
    dev = _device(n)
    load = [pim.ProgramBuilder(ROWS, WORDS).write_row(1, rows[b]).build()
            for b in range(n)]
    state = pim.schedule(dev, load).state
    cfg = state.config
    moves = [((b, 0, 1), (0, 0, 2 + b - 1)) for b in range(1, n)]
    r1 = pim.schedule(state, pim.gather_rows(cfg, moves))
    assert r1.host_bytes == 0
    fold = pim.xor_reduce_program(ROWS, WORDS, [1, 2, 3, 4], 5)
    r2 = pim.schedule(r1.state, [fold, None, None, None])
    assert r2.host_bytes == 0
    got = np.asarray(r2.state.bank(0).bits[5])
    assert np.array_equal(got, np.bitwise_xor.reduce(rows))


def test_gather_rows_appends_to_compute_programs():
    rng = np.random.default_rng(7)
    data = _rand_row(rng)
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=2,
                           num_rows=ROWS, words=WORDS)
    compute = [pim.ProgramBuilder(ROWS, WORDS).write_row(0, data).build(),
               None]
    progs = pim.gather_rows(cfg, [((0, 0, 0), (1, 0, 9))], compute)
    res = pim.schedule(pim.make_device(cfg), progs)
    assert np.array_equal(np.asarray(res.state.bank(1).bits[9]), data)


def test_shard_rows_across_subarrays():
    rng = np.random.default_rng(8)
    data = rng.integers(0, 2**32, (8, WORDS), dtype=np.uint32)
    progs = pim.shard_rows(data, 2, num_rows=ROWS, subarrays=2,
                           read_back=True)
    assert len(progs) == 2 and len(progs[0]) == 2     # nested [bank][sub]
    res = pim.schedule(_device(2, subarrays=2), progs)
    got = np.concatenate(
        [np.stack([np.asarray(r) for r in res.reads[k]])
         for k in range(4) if res.reads[k]])
    assert np.array_equal(got, data)


def test_shard_lanes_across_subarrays():
    rng = np.random.default_rng(9)
    data = rng.integers(0, 2**32, (2, WORDS * 4), dtype=np.uint32)

    def build(b, rows):
        b.ambit_xor(rows[0], rows[1], 2)
        b.read_row(2)

    progs = pim.shard_lanes(data, 2, num_rows=ROWS, subarrays=2, build=build)
    res = pim.schedule(_device(2, subarrays=2), progs)
    got = np.concatenate([np.asarray(res.reads[k][0]) for k in range(4)])
    assert np.array_equal(got, data[0] ^ data[1])


# ---------------------------------------------------------------------------
# Trace v3
# ---------------------------------------------------------------------------

def test_trace_v3_round_trip_and_replay():
    rng = np.random.default_rng(10)
    data = _rand_row(rng)
    b00 = pim.ProgramBuilder(ROWS, WORDS)
    b00.issue()
    b00.write_row(0, data)
    b00.copy_row(0, 2, dst_bank=1, dst_sub=1)
    b11 = pim.ProgramBuilder(ROWS, WORDS)
    b11.shift(2, 3, +1)
    nested = [[b00.build(), None], [None, b11.build()]]
    text = pim.to_trace_device(nested)
    assert text.splitlines()[0].startswith("# pim-trace v3")
    assert "subarrays=2" in text.splitlines()[0]
    rt = pim.from_trace_device(text)
    assert rt[0][0].ops == nested[0][0].ops
    assert rt[1][1].ops == nested[1][1].ops
    assert rt[0][1].ops == () and rt[1][0].ops == ()
    with pytest.raises(ValueError, match="from_trace_device"):
        pim.from_trace_banks(text)
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=2,
                           subarrays=2, num_rows=ROWS, words=WORDS)
    res = pim.schedule(pim.make_device(cfg), [list(b) for b in rt])
    assert np.array_equal(np.asarray(res.state.slot(1, 1).bits[2]), data)


def test_trace_copy_line_validation():
    with pytest.raises(ValueError, match="outside the device"):
        pim.PimProgram.from_trace(
            "# pim-trace v1 rows=16 words=4\nCOPY 0 1 -1 0\n")
    with pytest.raises(ValueError, match="out of range"):
        pim.PimProgram.from_trace(
            "# pim-trace v1 rows=16 words=4\nCOPY 99 1 0 0\n")
    with pytest.raises(ValueError, match="missing operand"):
        pim.PimProgram.from_trace(
            "# pim-trace v1 rows=16 words=4\nCOPY 0 1\n")
    # destination bank/sub must fit the header's device shape at import
    with pytest.raises(ValueError, match="outside the device"):
        pim.from_trace_banks("# pim-trace v2 rows=16 words=4 banks=2\n"
                             "BANK 0 COPY 1 2 7 0\n")
    with pytest.raises(ValueError, match="outside the device"):
        pim.from_trace_device(
            "# pim-trace v3 rows=16 words=4 banks=2 subarrays=2\n"
            "BANK 0 SUB 0 COPY 1 2 0 2\n")
    # the self sentinel is valid in any shape
    (p,) = pim.from_trace_banks(
        "# pim-trace v1 rows=16 words=4\nCOPY 0 1 -1 -1\n")
    assert (p.ops[0].delta, p.ops[0].c) == (-1, -1)


def test_copy_builder_validation():
    b = pim.ProgramBuilder(ROWS, WORDS)
    with pytest.raises(ValueError, match="non-negative"):
        b.copy_row(0, 1, dst_bank=-2)
    with pytest.raises(ValueError, match="non-negative"):
        b.copy_row(0, 1, dst_bank=-1, dst_sub=0)   # half-sentinel is invalid
    # scheduler refuses destinations outside the device
    b2 = pim.ProgramBuilder(ROWS, WORDS)
    b2.copy_row(0, 1, dst_bank=7, dst_sub=0)
    with pytest.raises(ValueError, match="bank"):
        pim.schedule(_device(2), [b2.build(), None])


# ---------------------------------------------------------------------------
# RLE payload encoding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", [
    np.zeros(64, np.uint32),                                   # all-zero page
    np.r_[np.zeros(60, np.uint32), np.arange(4, dtype=np.uint32)],  # sparse
    np.arange(64, dtype=np.uint32),                            # dense
    np.array([0xFFFFFFFF] * 3 + [0], np.uint32),               # short run
])
def test_rle_payload_round_trip(row):
    enc = pim.rle_encode_payload(row)
    assert enc.startswith("rle:")
    assert np.array_equal(pim.decode_payload(enc, row.size), row)
    plain = row.astype("<u4").tobytes().hex()
    assert np.array_equal(pim.decode_payload(plain, row.size), row)


def test_trace_v2_rle_payload_round_trips_and_shrinks():
    b = pim.ProgramBuilder(64, 64)
    b.write_row(0, np.zeros(64, np.uint32))
    prog = b.build()
    text = pim.to_trace_banks([prog])
    assert "rle:00000000x64" in text
    (rt,) = pim.from_trace_banks(text)
    assert np.array_equal(rt.payloads[0], prog.payloads[0])
    # plain v1 export unchanged (golden fixtures stay stable)
    assert "rle:" not in prog.to_trace()


def test_decode_payload_rejects_wrong_length():
    with pytest.raises(ValueError, match="words"):
        pim.decode_payload("rle:00000000x3", 4)


# ---------------------------------------------------------------------------
# Refresh accounting across schedule calls (regression)
# ---------------------------------------------------------------------------

def test_refresh_counts_once_across_sequential_schedules():
    """Two refreshed schedule() calls on one device must account exactly the
    events a single refreshed run of the concatenated stream accounts —
    apply_refresh used to re-charge the whole history on every call."""
    prog = pim.shift_workload_program(41, ROWS, WORDS)     # ~8.2 us > tREFI
    dev = _device(1)
    r1 = pim.schedule(dev, [prog], refresh=True)
    r2 = pim.schedule(r1.state, [prog], refresh=True)
    m = r2.state.bank(0).meter
    assert int(r1.state.bank(0).meter.n_refresh) == 1
    both = ir.concat([prog, prog])
    ref = pim_exec.execute(
        both, pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS)),
        refresh=True)
    assert int(m.n_refresh) == int(ref.state.meter.n_refresh) == 2
    assert float(m.time_ns) == pytest.approx(
        float(ref.state.meter.time_ns), rel=1e-6)
    assert float(m.e_refresh) == pytest.approx(
        float(ref.state.meter.e_refresh), rel=1e-6)


def test_apply_refresh_idempotent_when_no_new_busy_time():
    m = pim.CostMeter.zeros()
    m = pim.charge_copy(m)          # tiny busy time, no refresh due
    r1 = pim.apply_refresh(m)
    r2 = pim.apply_refresh(r1)
    assert int(r2.n_refresh) == int(r1.n_refresh) == 0
    assert float(r2.time_ns) == float(r1.time_ns)
    # and with events due: re-applying without new busy time adds none
    prog = pim.shift_workload_program(41, ROWS, WORDS)
    meter = pim.cost_pass(prog)
    a1 = pim.apply_refresh(meter)
    a2 = pim.apply_refresh(a1)
    assert int(a1.n_refresh) == 1
    assert int(a2.n_refresh) == int(a1.n_refresh)
    assert float(a2.time_ns) == float(a1.time_ns)
