"""Timing/energy model vs the paper's NVMain Tables 2 & 3 (5% gate)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pim

PAPER = {  # n_shifts: (total_ns, total_nj, active_nj)
    1: (208.7, 31.321, 30.24),
    50: (10_291.0, 1_592.52, 1_515.4),
    100: (20_733.0, 3_223.6, 3_030.81),
    512: (106_272.0, 16_554.6, 15_513.5),
}


@pytest.fixture(scope="module")
def rows():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 2**32, (2048,), dtype=np.uint32))


@pytest.mark.parametrize("n", sorted(PAPER))
def test_latency_within_5pct(rows, n):
    s = pim.run_shift_workload(rows, n)
    t_paper = PAPER[n][0]
    assert float(s.meter.time_ns) == pytest.approx(t_paper, rel=0.05)


@pytest.mark.parametrize("n", sorted(PAPER))
def test_energy_within_5pct(rows, n):
    s = pim.run_shift_workload(rows, n)
    e_paper = PAPER[n][1]
    assert float(s.meter.total_energy_nj) == pytest.approx(e_paper, rel=0.05)


@pytest.mark.parametrize("n", sorted(PAPER))
def test_active_energy_exact_model(rows, n):
    """Active energy = 8 ACTs/shift × 3.78 nJ — the paper's dominant term."""
    s = pim.run_shift_workload(rows, n)
    assert float(s.meter.e_act) == pytest.approx(n * 30.24, rel=0.005)


def test_burst_energy_zero_for_pim_workload(rows):
    """Table 2: burst energy is zero — no data leaves the chip."""
    s = pim.run_shift_workload(rows, 50)
    assert float(s.meter.e_burst) == 0.0


def test_energy_per_kb_about_4nj(rows):
    s = pim.run_shift_workload(rows, 100)
    per_kb = float(s.meter.total_energy_nj) / 100 / 8.0
    assert 3.5 <= per_kb <= 4.5                      # paper: 3.915–4.041


def test_refresh_overhead_grows_with_duration(rows):
    fracs = []
    for n in (1, 50, 512):
        s = pim.run_shift_workload(rows, n)
        fracs.append(float(s.meter.e_refresh)
                     / float(s.meter.total_energy_nj))
    assert fracs[0] == 0.0
    assert fracs[0] < fracs[1] < fracs[2]
    assert fracs[2] < 0.10                           # paper: 6.3%


def test_static_estimate_matches_traced_run(rows):
    est = pim.estimate_cost(n_shifts=100)
    s = pim.run_shift_workload(rows, 100)
    assert est["time_ns"] == pytest.approx(float(s.meter.time_ns), rel=0.01)
    assert est["energy_nj"] == pytest.approx(
        float(s.meter.total_energy_nj), rel=0.01)


def test_cpu_movement_comparison():
    """§5.1.5: conventional read+write of 8KB ≫ one in-DRAM shift."""
    conventional = pim.cpu_movement_energy_nj(8192)
    assert conventional >= 2_560.0                   # ≥ 2×128×10 nJ
    assert conventional / 32.0 > 40                  # ≥40× reduction claim
