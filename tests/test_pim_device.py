"""Device-level invariants: scheduler wall/energy algebra, heterogeneous
grouping, pim-trace v2 round-trips, trace import validation, the
value-keyed runner cache, and PimVM lane sharding.

The acceptance bar mirrors test_pim_ir.py: device runs must be *bit-exact*
against per-bank single-subarray executions — same bits, same reads — while
the device wall clock follows  wall = Σ bus + max(Δt − bus)  and energy sums
across banks.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import pim
from repro.core.bitplane import PimVM, gf, rs
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir

WORDS = 8
ROWS = 32


def _rand_row(rng, words=WORDS):
    return rng.integers(0, 2**32, (words,), dtype=np.uint32)


def _shift_prog(data, k, rows=ROWS, words=WORDS):
    b = pim.ProgramBuilder(rows, words)
    b.issue()
    b.write_row(0, data)
    b.shift_k(0, 1, k)
    b.read_row(1)
    return b.build()


def _xor_prog(d1, d2, rows=ROWS, words=WORDS):
    b = pim.ProgramBuilder(rows, words)
    b.issue()
    b.write_row(0, d1)
    b.write_row(1, d2)
    b.ambit_xor(0, 1, 2)
    b.read_row(2)
    return b.build()


def _single_ref(prog):
    """Per-bank reference: the same program on one fresh subarray."""
    st = pim.reserve_control_rows(pim.make_subarray(prog.num_rows,
                                                    prog.words))
    return pim_exec.execute(prog, st)


def _device(n_banks, rows=ROWS, words=WORDS):
    return pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=n_banks,
        num_rows=rows, words=words))


# ---------------------------------------------------------------------------
# Scheduler algebra
# ---------------------------------------------------------------------------

def test_schedule_heterogeneous_matches_per_bank_reference():
    """wall = Σ bus + max(exec), energy = Σ, bits/reads bit-exact — for
    programs with different streams AND same-stream/different-payload
    banks (which share one vmapped runner)."""
    rng = np.random.default_rng(0)
    d = [_rand_row(rng) for _ in range(4)]
    progs = [_shift_prog(d[0], 5), _shift_prog(d[1], 5),
             _xor_prog(d[2], d[3]), None]
    res = pim.schedule(_device(4), progs)

    walls, buses, energy = [], [], 0.0
    for b, p in enumerate(progs):
        if p is None:
            assert res.reads[b] == ()
            continue
        ref = _single_ref(p)
        assert np.array_equal(np.asarray(ref.state.bits),
                              np.asarray(res.state.bank(b).bits)), b
        assert len(ref.reads) == len(res.reads[b])
        for x, y in zip(ref.reads, res.reads[b]):
            assert np.array_equal(np.asarray(x), np.asarray(y)), b
        walls.append(float(ref.state.meter.time_ns))
        buses.append(pim.bus_time_ns(p))
        energy += float(ref.state.meter.total_energy_nj)

    expect_wall = sum(buses) + max(w - bu for w, bu in zip(walls, buses))
    assert float(res.wall_ns) == pytest.approx(expect_wall, rel=1e-6)
    assert float(res.bus_ns) == pytest.approx(sum(buses), rel=1e-6)
    assert float(res.energy_nj) == pytest.approx(energy, rel=1e-5)


def test_schedule_single_bank_degenerates_to_subarray_meter():
    rng = np.random.default_rng(1)
    prog = _shift_prog(_rand_row(rng), 7)
    res = pim.schedule(_device(1), [prog])
    ref = _single_ref(prog)
    assert float(res.wall_ns) == pytest.approx(
        float(ref.state.meter.time_ns), rel=1e-6)
    assert float(res.energy_nj) == pytest.approx(
        float(ref.state.meter.total_energy_nj), rel=1e-5)


def test_schedule_same_stream_banks_group_into_one_runner():
    """Same ops + different payloads must share one compiled artifact."""
    rng = np.random.default_rng(2)
    progs = [_shift_prog(_rand_row(rng), 3) for _ in range(3)]
    keys = {pim.stream_key(p) for p in progs}
    assert len(keys) == 1
    res = pim.schedule(_device(3), progs)
    for b, p in enumerate(progs):
        ref = _single_ref(p)
        assert np.array_equal(np.asarray(ref.reads[0]),
                              np.asarray(res.reads[b][0]))


def test_schedule_validates_shapes_and_count():
    dev = _device(2)
    with pytest.raises(ValueError, match="programs for"):
        pim.schedule(dev, [None])
    bad = pim.ProgramBuilder(ROWS, WORDS * 2).issue().build()
    with pytest.raises(ValueError, match="shape"):
        pim.schedule(dev, [bad, None])


def test_schedule_meters_accumulate_across_calls():
    rng = np.random.default_rng(3)
    dev = _device(2)
    prog = _shift_prog(_rand_row(rng), 4)
    r1 = pim.schedule(dev, [prog, prog])
    r2 = pim.schedule(r1.state, [prog, prog])
    t = np.asarray(r2.state.banks.meter.time_ns)
    ref = _single_ref(prog)
    assert np.allclose(t, 2 * float(ref.state.meter.time_ns), rtol=1e-6)
    # per-call wall/energy are deltas, not cumulative
    assert float(r2.wall_ns) == pytest.approx(float(r1.wall_ns), rel=1e-6)
    assert float(r2.energy_nj) == pytest.approx(float(r1.energy_nj),
                                                rel=1e-5)


def test_paper_device_topologies():
    assert pim.paper_device(1).n_banks == 1
    assert pim.paper_device(8).n_banks == 8
    d32 = pim.paper_device(32)
    assert (d32.channels, d32.ranks, d32.banks_per_rank) == (2, 2, 8)
    assert d32.bank_coords(0) == (0, 0, 0)
    assert d32.bank_coords(31) == (1, 1, 7)
    with pytest.raises(ValueError, match="n_banks"):
        pim.paper_device(3)


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

def test_shard_rows_round_trips_buffer():
    rng = np.random.default_rng(4)
    data = rng.integers(0, 2**32, (10, WORDS), dtype=np.uint32)
    progs = pim.shard_rows(data, 4, num_rows=ROWS, read_back=True)
    assert len(progs) == 4
    res = pim.schedule(_device(4), progs)
    got = np.concatenate(
        [np.stack([np.asarray(r) for r in res.reads[b]])
         for b in range(4) if res.reads[b]])
    assert np.array_equal(got, data)


def test_shard_lanes_matches_full_width_compute():
    """A lane-sharded xor equals the same xor on the unsharded buffer."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 2**32, (2, WORDS * 4), dtype=np.uint32)

    def build(b, rows):
        b.ambit_xor(rows[0], rows[1], 2)
        b.read_row(2)

    progs = pim.shard_lanes(data, 4, num_rows=ROWS, build=build)
    assert all(p.words == WORDS for p in progs)
    res = pim.schedule(_device(4, words=WORDS), progs)
    got = np.concatenate([np.asarray(res.reads[b][0]) for b in range(4)])
    assert np.array_equal(got, data[0] ^ data[1])
    with pytest.raises(ValueError, match="divisible"):
        pim.shard_lanes(data, 3)


# ---------------------------------------------------------------------------
# pim-trace v2
# ---------------------------------------------------------------------------

def test_trace_v2_round_trip_bit_exact():
    """BANK-prefixed round-trip preserves ops AND payloads; the re-imported
    device run matches per-bank single-subarray executions bit-exactly."""
    rng = np.random.default_rng(6)
    d = [_rand_row(rng) for _ in range(3)]
    progs = [_shift_prog(d[0], 4), _shift_prog(d[1], 9),
             _xor_prog(d[1], d[2])]
    text = pim.to_trace_banks(progs)
    assert text.splitlines()[0].startswith("# pim-trace v2")
    rt = pim.from_trace_banks(text)
    assert len(rt) == 3
    for p, q in zip(progs, rt):
        assert p.ops == q.ops
        assert all(np.array_equal(x, y)
                   for x, y in zip(p.payloads, q.payloads))
    res = pim.schedule(_device(3), list(rt))
    for b, p in enumerate(progs):
        ref = _single_ref(p)
        assert np.array_equal(np.asarray(ref.state.bits),
                              np.asarray(res.state.bank(b).bits)), b
        for x, y in zip(ref.reads, res.reads[b]):
            assert np.array_equal(np.asarray(x), np.asarray(y)), b


def test_trace_v1_accepts_v2_rejects_multibank():
    rng = np.random.default_rng(7)
    progs = [_shift_prog(_rand_row(rng), 2)] * 2
    with pytest.raises(ValueError, match="from_trace_banks"):
        pim.PimProgram.from_trace(pim.to_trace_banks(progs))
    # v1 text through from_trace_banks → one bank
    (one,) = pim.from_trace_banks(progs[0].to_trace())
    assert one.ops == progs[0].ops


def test_trace_v2_empty_bank_round_trips():
    progs = [pim.ProgramBuilder(ROWS, WORDS).issue().build(),
             pim.ProgramBuilder(ROWS, WORDS).build()]     # bank 1 idle
    rt = pim.from_trace_banks(pim.to_trace_banks(progs))
    assert len(rt) == 2 and rt[1].ops == ()


# ---------------------------------------------------------------------------
# Trace import validation (bugfix: invalid operands used to mis-execute)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("line,match", [
    ("SHIFT 0 1 +3", r"delta must be \+1 or -1"),
    ("SHIFT 0 1 0", r"delta must be \+1 or -1"),
    ("AAP 16 0", "out of range"),
    ("AAP 0 -1", "out of range"),
    ("TRA 0 1 99", "out of range"),
    ("HOSTR 16", "out of range"),
    ("TRA 0 1", "missing operand"),
    ("FROB 0 1", "unknown trace mnemonic"),
])
def test_from_trace_rejects_malformed_lines(line, match):
    text = f"# pim-trace v1 rows=16 words=8\nISSUE\n{line}\n"
    with pytest.raises(ValueError, match=match):
        pim.PimProgram.from_trace(text)
    with pytest.raises(ValueError, match="trace line 3"):
        pim.PimProgram.from_trace(text)


def test_from_trace_rejects_bad_bank_and_payload():
    with pytest.raises(ValueError, match=r"bank 5 out of range"):
        pim.from_trace_banks(
            "# pim-trace v2 rows=16 words=8 banks=2\nBANK 5 ISSUE\n")
    with pytest.raises(ValueError, match="payload"):
        pim.PimProgram.from_trace(
            "# pim-trace v1 rows=16 words=8\nHOSTW 0 00000000\n")


def test_from_trace_still_accepts_valid_edge_rows():
    text = "# pim-trace v1 rows=16 words=8\nAAP 0 15\nSHIFT 15 0 -1\n"
    prog = pim.PimProgram.from_trace(text)
    assert prog.ops[0].b == 15 and prog.ops[1].delta == -1


# ---------------------------------------------------------------------------
# Runner cache keying (bugfix: id(cfg) aliasing)
# ---------------------------------------------------------------------------

def test_runner_cache_keys_on_timing_value_not_id():
    """Equal-but-distinct cfgs must share a cache entry; a cfg with
    different constants must NOT reuse a stale runner (the old id(cfg) key
    could alias after garbage collection)."""
    prog = pim.shift_workload_program(40, 16, WORDS)   # > tREFI: refreshes
    cfg_a = pim.DDR3Timing()
    compiled = pim.compile_program(prog, cfg_a)
    r_a = pim_exec.make_runner(compiled, cfg_a, refresh=True)
    # equal value, distinct instance → cache hit
    cfg_a2 = pim.DDR3Timing()
    assert cfg_a2 is not cfg_a
    assert pim_exec.make_runner(compiled, cfg_a2, refresh=True) is r_a
    # different refresh constants → different runner AND different meter
    cfg_b = dataclasses.replace(cfg_a, tRFC=2600.0, e_ref=800.0)
    r_b = pim_exec.make_runner(compiled, cfg_b, refresh=True)
    assert r_b is not r_a
    st = pim.make_subarray(16, WORDS)
    m_a = r_a(st).state.meter
    m_b = r_b(st).state.meter
    assert int(m_a.n_refresh) >= 1
    assert float(m_b.time_ns) > float(m_a.time_ns)
    assert float(m_b.e_refresh) > float(m_a.e_refresh)


# ---------------------------------------------------------------------------
# PimVM lane sharding
# ---------------------------------------------------------------------------

def test_pimvm_sharded_gf_mul_bit_exact():
    rng = np.random.default_rng(8)
    vm1 = PimVM(width=8, num_rows=96, words=16)
    vm4 = PimVM(width=8, num_rows=96, words=16, n_banks=4)
    a = rng.integers(0, 256, vm1.lanes)
    b = rng.integers(0, 256, vm1.lanes)
    got1 = vm1.read(gf.gf_mul(vm1, vm1.load(a), vm1.load(b)))
    got4 = vm4.read(gf.gf_mul(vm4, vm4.load(a), vm4.load(b)))
    assert np.array_equal(got1, got4)
    assert np.array_equal(got1, gf.ref_gf_mul(a, b))
    # homogeneous streams: every bank's meter advances identically, and the
    # device wall adds the other banks' serialized HOSTW/HOSTR bus windows
    # on top of one bank's meter time (no ISSUE bursts in VM streams)
    t = np.asarray(vm4._device.banks.meter.time_ns)
    assert np.allclose(t, t[0])
    # wall = one bank's meter time + the OTHER banks' serialized host-burst
    # windows: strictly between one bank's time and all four banks' total
    assert float(t[0]) < vm4.time_ns < float(t.sum())
    assert vm4.energy_nj == pytest.approx(
        float(jnp.sum(vm4._device.banks.meter.total_energy_nj)), rel=1e-6)


def test_pimvm_sharded_rs_encode_bit_exact():
    rng = np.random.default_rng(9)
    k, npar = 4, 2
    vm1 = PimVM(width=8, num_rows=120, words=8)
    vm2 = PimVM(width=8, num_rows=120, words=8, n_banks=2)
    msg = rng.integers(0, 256, size=(k, vm1.lanes))
    p1 = rs.rs_encode(vm1, [vm1.load(msg[i]) for i in range(k)], npar)
    p2 = rs.rs_encode(vm2, [vm2.load(msg[i]) for i in range(k)], npar)
    got1 = np.stack([vm1.read(r) for r in p1])
    got2 = np.stack([vm2.read(r) for r in p2])
    assert np.array_equal(got1, got2)
    assert np.array_equal(got1, rs.ref_rs_encode(msg, npar))


def test_pimvm_sharded_rejects_bad_config():
    with pytest.raises(AssertionError):
        PimVM(width=8, words=16, n_banks=3)      # 16 % 3 != 0
    with pytest.raises(AssertionError):
        PimVM(width=8, words=16, n_banks=2, eager=True)
