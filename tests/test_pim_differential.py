"""Differential property harness: three executions of one random program.

Every hypothesis-generated :class:`~repro.core.pim.PimProgram` is executed

  1. eagerly     — ``pim.run_program``: one ISA pytree transition per command,
  2. compiled    — ``pim.execute``: fused segments + one-fold cost pass,
  3. scheduled   — ``pim.schedule`` on a single-bank device.

All three must agree *bit-exactly* on the final ``bits``/migration/DCC state
and the host-read rows, and within float32 tolerance on every cost-meter
field (the compiled fold replays the eager path's IEEE additions, so in
practice the meters are equal to the last ulp too). This is the safety net
that keeps IR → compile → exec → device → schedule refactors honest.

Every program additionally checks the vectorized columnar cost tables
against the per-op reference loop (identical float32 bit patterns), and a
pipeline leg runs K recurring steps through ``schedule_pipeline``'s single
``lax.scan`` dispatch against K per-step ``schedule`` calls (bit-exact
states/reads/meters, identical chained async credit).

The scheduled leg also runs on a 2-channel device (channel layout must not
touch per-slot state), a refresh strategy covers ``refresh=True`` end to
end, and a multi-step invariant suite checks the channel-aware wall clock:
identical bits/reads/energy across 1-/2-channel layouts and sync/async
host scheduling, wall(2ch) <= wall(1ch) for any placement (== when one
channel holds all the work), and async wall <= sync wall per step.

Hypothesis is optional (conftest registers the profiles); without it a
deterministic seed sweep runs the same generator. CI runs this file a
second time under the ``differential`` profile (200 examples, fixed seed).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic seed sweep below
    HAVE_HYPOTHESIS = False

from repro.core import pim
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir, isa, sem

ROWS = 16
WORDS = 4
USER_ROWS = ROWS - 8          # keep clear of C0/C1/T0..T3 (+ margin)

FLOAT_FIELDS = ("time_ns", "e_act", "e_pre", "e_refresh", "e_burst",
                "e_background")
INT_FIELDS = ("n_act", "n_pre", "n_aap", "n_shift", "n_tra", "n_refresh")

KINDS = ("rowclone", "dra", "tra", "shift", "chain", "copy", "and", "or",
         "xor", "not", "maj", "write", "read", "fill", "issue")


def _build_program(rng, n_ops):
    """One random mixed program over the user rows (np.random generator)."""
    b = ir.ProgramBuilder(ROWS, WORDS)
    pick = lambda n: [int(r) for r in rng.choice(USER_ROWS, n, replace=False)]
    for kind in rng.choice(KINDS, n_ops):
        if kind == "rowclone":
            b.rowclone(*pick(2))
        elif kind == "dra":
            b.dra(*pick(2))
        elif kind == "tra":
            b.tra(*pick(3))
        elif kind == "shift":
            b.shift(*pick(2), int(rng.choice([-1, 1])))
        elif kind == "chain":
            src, dst = pick(2)
            b.shift_k(src, dst, int(rng.integers(2, 8))
                      * int(rng.choice([-1, 1])))
        elif kind == "copy":
            b.copy_row(*pick(2))
        elif kind in ("and", "or", "xor"):
            getattr(b, f"ambit_{kind}")(*pick(3))
        elif kind == "not":
            b.ambit_not(*pick(2))
        elif kind == "maj":
            b.ambit_maj(*pick(4))
        elif kind == "write":
            b.write_row(pick(1)[0],
                        rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))
        elif kind == "read":
            b.read_row(pick(1)[0])
        elif kind == "fill":
            b.fill(pick(1)[0], int(rng.integers(0, 2**32)))
        else:
            assert kind == "issue", kind
            b.issue()
    return b.build()


def _fresh():
    return pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS))


def _assert_agree(prog, refresh=False):
    # static-verifier leg: generated programs draw operands from the user
    # range with TRA operands distinct, so the linter must find no ERRORs
    # (uninitialized-read warnings are expected — streams may read rows
    # the host never wrote)
    assert pim.lint_program(prog).ok, pim.lint_program(prog).render()

    # columnar cost pass leg: the vectorized template gather must equal the
    # per-op reference loop row-for-row (same float32 bit patterns)
    f_vec, i_vec = pim.cost_tables(prog)
    f_ref, i_ref = pim.cost_tables_reference(prog)
    assert f_vec.shape == f_ref.shape
    assert np.array_equal(f_vec.view(np.uint32), f_ref.view(np.uint32))
    assert np.array_equal(i_vec, i_ref)

    s_e, reads_e = pim.run_program(_fresh(), prog)
    if refresh:
        s_e = pim.SubarrayState(
            bits=s_e.bits, mig_top=s_e.mig_top, mig_bot=s_e.mig_bot,
            dcc=s_e.dcc, meter=pim.apply_refresh(s_e.meter))
    res_c = pim_exec.execute(prog, _fresh(), refresh=refresh)
    dev = pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=1, num_rows=ROWS, words=WORDS))
    res_s = pim.schedule(dev, [prog], refresh=refresh)
    # multi-channel device: the program on bank 1 of a 2ch x 1rank x 1bank
    # config — per-slot state/meters must not depend on the channel layout
    dev_mc = pim.make_device(pim.DeviceConfig(
        channels=2, ranks=1, banks_per_rank=1, num_rows=ROWS, words=WORDS))
    res_mc = pim.schedule(dev_mc, [None, prog], refresh=refresh)

    for name, state, reads in (("compiled", res_c.state, res_c.reads),
                               ("scheduled", res_s.state.bank(0),
                                res_s.reads[0]),
                               ("multi-channel", res_mc.state.bank(1),
                                res_mc.reads[1])):
        for f in ("bits", "mig_top", "mig_bot", "dcc"):
            assert np.array_equal(np.asarray(getattr(s_e, f)),
                                  np.asarray(getattr(state, f))), \
                f"{name}: {f} diverges from eager"
        assert len(reads) == len(reads_e), name
        for i, (x, y) in enumerate(zip(reads_e, reads)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                f"{name}: read {i} diverges from eager"
        for f in INT_FIELDS:
            assert int(getattr(s_e.meter, f)) == int(
                getattr(state.meter, f)), f"{name}: meter.{f}"
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(
                float(getattr(state.meter, f)),
                float(getattr(s_e.meter, f)), rtol=1e-6,
                err_msg=f"{name}: meter.{f}")


def _mutate(prog, rng):
    """A nearby program: identical rebuild, fresh payload contents, or one
    appended op — so the static-semantics leg exercises EQUIVALENT and
    DIFFERENT verdicts (and the occasional appended no-op)."""
    c = rng.random()
    if c < 0.25 and prog.payloads:
        return prog.with_payloads(
            rng.integers(0, 2**32, (len(prog.payloads), WORDS),
                         dtype=np.uint32))
    if c < 0.5:
        return ir.PimProgram(ops=prog.ops, num_rows=ROWS, words=WORDS,
                             payloads=prog.payloads)
    r1, r2 = (int(x) for x in rng.choice(USER_ROWS, 2, replace=False))
    k = int(rng.integers(0, 4))
    if k == 0:
        op = ir.PimOp(ir.OP_ROWCLONE, a=r1, b=r2)
    elif k == 1:
        op = ir.PimOp(ir.OP_SHIFT, a=r1, b=r2,
                      delta=int(rng.choice([-1, 1])))
    elif k == 2:
        op = ir.PimOp(ir.OP_FILL, b=r1,
                      payload=int(rng.integers(0, 2**32)))
    else:
        op = ir.PimOp(ir.OP_READ, a=r1)
    return ir.PimProgram(ops=prog.ops + (op,), num_rows=ROWS,
                         words=WORDS, payloads=prog.payloads)


def _assert_sem_agrees(seed: int, n_ops: int):
    """Static-semantics leg: the symbolic analyzer's verdicts must agree
    with bit-exact execution.

      * fusion is semantics-preserving by construction, so the static
        fused-vs-unfused proof may abstain (UNKNOWN past the symbolic
        budget) but must NEVER return DIFFERENT;
      * ``prove_equivalent(prog, prog)`` likewise never DIFFERENT;
      * on a mutated pair: EQUIVALENT implies executed full states and
        reads match on random inputs, and DIFFERENT implies the shipped
        witness actually distinguishes the programs when replayed —
        i.e. zero false EQUIVALENTs and no vacuous witnesses.
    """
    rng = np.random.default_rng(seed)
    prog = _build_program(rng, n_ops)

    assert sem.fusion_report(prog).verdict != sem.DIFFERENT, seed
    assert sem.prove_equivalent(prog, prog).verdict != sem.DIFFERENT, seed

    mut = _mutate(prog, rng)
    rep = sem.prove_equivalent(prog, mut)
    if rep.verdict == sem.DIFFERENT:
        assert rep.witness is not None, seed
        assert sem.check_witness(prog, mut, rep.witness), \
            (seed, rep.component)
    elif rep.verdict == sem.EQUIVALENT:
        for _ in range(2):
            bits = rng.integers(0, 2**32, (ROWS, WORDS), dtype=np.uint32)
            sa, ra = isa.run_on_bits(prog, bits)
            sb, rb = isa.run_on_bits(mut, bits)
            for f in ("bits", "mig_top", "mig_bot", "dcc"):
                assert np.array_equal(np.asarray(getattr(sa, f)),
                                      np.asarray(getattr(sb, f))), (seed, f)
            assert len(ra) == len(rb), seed
            for x, y in zip(ra, rb):
                assert np.array_equal(np.asarray(x), np.asarray(y)), seed


def _assert_channel_and_async_invariants(seed: int, n_steps: int,
                                         refresh=False):
    """Wall-clock invariants of the channel-aware model over random
    multi-step placements on a 4-bank device:

      * identical bits/reads/energy across 1-channel and 2-channel layouts
        and across sync/async host scheduling;
      * wall(2ch) <= wall(1ch) for ANY placement, == when all the work sits
        on one channel;
      * async wall <= sync wall per step.
    """
    rng = np.random.default_rng(seed)
    cfg1 = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=4,
                            num_rows=ROWS, words=WORDS)
    cfg2 = pim.DeviceConfig(channels=2, ranks=1, banks_per_rank=2,
                            num_rows=ROWS, words=WORDS)
    steps = []
    for _ in range(n_steps):
        steps.append([
            _build_program(rng, int(rng.integers(1, 10)))
            if rng.random() < 0.75 else None for _ in range(4)])
    one_channel_only = all(p is None for s in steps for p in s[2:])

    def run(cfg, async_host):
        dev = pim.make_device(cfg)
        walls, energies, reads, overlaps = [], [], [], 0.0
        for progs in steps:
            r = pim.schedule(dev, progs, refresh=refresh,
                             async_host=async_host)
            dev = r.state
            walls.append(float(r.wall_ns))
            energies.append(float(r.energy_nj))
            reads.append(r.reads)
            overlaps += r.host_overlap_ns
        return dev, walls, energies, reads, overlaps

    d1, w1, e1, r1, _ = run(cfg1, False)
    d2, w2, e2, r2, _ = run(cfg2, False)
    da, wa, ea, ra, _ = run(cfg1, True)
    assert np.array_equal(np.asarray(d1.banks.bits),
                          np.asarray(d2.banks.bits))
    assert np.array_equal(np.asarray(d1.banks.bits),
                          np.asarray(da.banks.bits))
    for a, b, c in zip(e1, e2, ea):
        np.testing.assert_allclose(a, b, rtol=1e-6)
        np.testing.assert_allclose(a, c, rtol=1e-6)
    for sa, sb, sc in zip(r1, r2, ra):
        for ka, kb, kc in zip(sa, sb, sc):
            for x, y, z in zip(ka, kb, kc):
                assert np.array_equal(np.asarray(x), np.asarray(y))
                assert np.array_equal(np.asarray(x), np.asarray(z))
    for k, (a, b) in enumerate(zip(w1, w2)):
        assert b <= a + 1e-3, (seed, k)
        if one_channel_only:
            np.testing.assert_allclose(b, a, rtol=1e-6)
    for k, (s, a) in enumerate(zip(w1, wa)):
        assert a <= s + 1e-3, (seed, k)


def _assert_pipeline_agrees(seed: int, n_steps: int, async_host=False):
    """schedule_pipeline leg: K recurring steps under one lax.scan must be
    bit-exact against K per-step schedule() calls — states, reads, meters,
    and the chained async credit."""
    rng = np.random.default_rng(seed)
    cfg = pim.DeviceConfig(channels=2, ranks=1, banks_per_rank=2,
                           num_rows=ROWS, words=WORDS)
    layout = [_build_program(rng, int(rng.integers(1, 12)))
              if rng.random() < 0.75 else None for _ in range(4)]
    if all(p is None for p in layout):
        layout[0] = _build_program(rng, 4)
    steps = []
    for _ in range(n_steps):        # same streams, fresh payload data
        steps.append([
            p.with_payloads(
                rng.integers(0, 2**32, (len(p.payloads), WORDS),
                             dtype=np.uint32))
            if p is not None else None for p in layout])

    dev = pim.make_device(cfg)
    walls, energies, reads = [], [], []
    for s in steps:
        r = pim.schedule(dev, s, async_host=async_host)
        dev = r.state
        walls.append(float(r.wall_ns))
        energies.append(float(r.energy_nj))
        reads.append(r.reads)

    pr = pim.schedule_pipeline(pim.make_device(cfg), steps,
                               async_host=async_host)
    assert pr.n_steps == n_steps
    assert np.array_equal(np.asarray(dev.banks.bits),
                          np.asarray(pr.state.banks.bits))
    for f in INT_FIELDS:
        assert np.array_equal(np.asarray(getattr(dev.banks.meter, f)),
                              np.asarray(getattr(pr.state.banks.meter, f))), f
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(pr.state.banks.meter, f)),
            np.asarray(getattr(dev.banks.meter, f)), rtol=1e-6,
            err_msg=f"pipeline meter.{f}")
    np.testing.assert_allclose(walls, np.asarray(pr.wall_ns), rtol=1e-6)
    np.testing.assert_allclose(energies, np.asarray(pr.energy_nj),
                               rtol=1e-6)
    preads = pr.reads
    for k in range(n_steps):
        for slot in range(4):
            assert len(reads[k][slot]) == len(preads[k][slot])
            for x, y in zip(reads[k][slot], preads[k][slot]):
                assert np.array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(float(dev.host_credit_ns),
                               float(pr.state.host_credit_ns), rtol=1e-6)


def _random_workload(rng):
    """Random 2-3-phase workload on the 4-bank device: a recurring compute
    phase with fresh per-step payloads, a gather+COPY in-DRAM movement
    phase, and (sometimes) a readback phase."""
    cfg = pim.DeviceConfig(channels=2, ranks=1, banks_per_rank=2,
                           num_rows=ROWS, words=WORDS)
    layout = [_build_program(rng, int(rng.integers(1, 10)))
              if rng.random() < 0.75 else None for _ in range(4)]
    if all(p is None for p in layout):
        layout[0] = _build_program(rng, 4)
    k0 = int(rng.integers(1, 4))
    compute_steps = tuple(
        [p.with_payloads(rng.integers(0, 2**32, (len(p.payloads), WORDS),
                                      dtype=np.uint32))
         if p is not None else None for p in layout]
        for _ in range(k0))

    moves = []
    for _ in range(int(rng.integers(1, 4))):
        sb, db = (int(x) for x in rng.choice(4, 2, replace=False))
        moves.append(((sb, 0, int(rng.integers(0, USER_ROWS))),
                      (db, 0, int(rng.integers(0, USER_ROWS)))))
    gather = pim.gather_rows(cfg, moves)
    k1 = int(rng.integers(1, 3))

    phases = [pim.Phase(steps=compute_steps),
              pim.Phase.repeat(gather, k1)]
    if rng.random() < 0.7:
        rb = []
        for _ in range(4):
            if rng.random() < 0.5:
                bb = ir.ProgramBuilder(ROWS, WORDS)
                for r in rng.choice(USER_ROWS, 2, replace=False):
                    bb.read_row(int(r))
                rb.append(bb.build())
            else:
                rb.append(None)
        if all(p is None for p in rb):
            bb = ir.ProgramBuilder(ROWS, WORDS)
            bb.read_row(0)
            rb[0] = bb.build()
        phases.append(pim.Phase.repeat(rb, 1))
    return cfg, phases


def _assert_workload_agrees(seed: int, async_host=False, use_order=False):
    """schedule_workload leg: a heterogeneous multi-phase workload under
    one dispatch (segmented scan, or lax.switch with an interleaved order)
    must be bit-exact against per-step schedule() calls — states, reads,
    meters, per-phase walls/energies, and the async credit at every phase
    boundary."""
    rng = np.random.default_rng(seed)
    cfg, phases = _random_workload(rng)

    order = None
    if use_order:
        order = [p for p, ph in enumerate(phases)
                 for _ in range(len(ph.steps))]
        rng.shuffle(order)

    # per-step reference, consuming each phase's steps FIFO in `order`
    seq = ([(p, step) for p, ph in enumerate(phases) for step in ph.steps]
           if order is None else None)
    if seq is None:
        cursors = [list(ph.steps) for ph in phases]
        seq = [(p, cursors[p].pop(0)) for p in order]
    dev = pim.make_device(cfg)
    walls = [[] for _ in phases]
    energies = [[] for _ in phases]
    reads = [[] for _ in phases]
    boundary = [0.0] * len(phases)
    for p, step in seq:
        r = pim.schedule(dev, step, async_host=async_host)
        dev = r.state
        walls[p].append(float(r.wall_ns))
        energies[p].append(float(r.energy_nj))
        reads[p].append(r.reads)
        boundary[p] = float(dev.host_credit_ns)

    res = pim.schedule_workload(pim.make_device(cfg), phases, order=order,
                                async_host=async_host)
    assert np.array_equal(np.asarray(dev.banks.bits),
                          np.asarray(res.state.banks.bits))
    for f in INT_FIELDS:
        assert np.array_equal(
            np.asarray(getattr(dev.banks.meter, f)),
            np.asarray(getattr(res.state.banks.meter, f))), f
    for f in FLOAT_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(res.state.banks.meter, f)),
            np.asarray(getattr(dev.banks.meter, f)), rtol=1e-6,
            err_msg=f"workload meter.{f}")
    for p, pr in enumerate(res.phases):
        np.testing.assert_allclose(walls[p], np.asarray(pr.wall_ns),
                                   rtol=1e-6)
        np.testing.assert_allclose(energies[p], np.asarray(pr.energy_nj),
                                   rtol=1e-6)
        np.testing.assert_allclose(boundary[p], pr.boundary_credit_ns,
                                   rtol=1e-6, atol=1e-6)
        preads = pr.reads
        for k in range(pr.n_steps):
            for slot in range(cfg.n_slots):
                assert len(reads[p][k][slot]) == len(preads[k][slot])
                for x, y in zip(reads[p][k][slot], preads[k][slot]):
                    assert np.array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(float(dev.host_credit_ns),
                               float(res.state.host_credit_ns),
                               rtol=1e-6, atol=1e-6)


def _assert_multitenant_agrees(seed: int, n_steps: int):
    """Multi-tenant serving leg: N tenants coalesced on one shared device
    through :class:`PimServeFront` must be bit-exact — per-slot states,
    host reads, and cost meters — against each tenant running ALONE on a
    private device slice of the same width; and the per-tenant accounting
    must sum to the device-level totals."""
    from repro.serve.pim_front import PimServeFront

    rng = np.random.default_rng(seed)
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=4,
                           num_rows=ROWS, words=WORDS)
    sizes = [1, int(rng.integers(1, 3))]
    if sum(sizes) < cfg.n_banks and rng.random() < 0.5:
        sizes.append(int(rng.integers(1, cfg.n_banks - sum(sizes) + 1)))

    tenants = {}
    for i, nb in enumerate(sizes):
        layout = [_build_program(rng, int(rng.integers(1, 10)))
                  if rng.random() < 0.8 else None for _ in range(nb)]
        if all(p is None for p in layout):
            layout[0] = _build_program(rng, 3)
        steps = [[p.with_payloads(
                      rng.integers(0, 2**32, (len(p.payloads), WORDS),
                                   dtype=np.uint32))
                  if p is not None else None for p in layout]
                 for _ in range(n_steps)]
        tenants[f"t{i}"] = (nb, steps)

    front = PimServeFront(cfg)
    placements = {tid: front.submit(tid, steps, banks=nb)
                  for tid, (nb, steps) in tenants.items()}
    reads_front = {tid: [] for tid in tenants}
    reports = {}
    for res in front.run():
        for tid in res.placements:
            got = res.tenant_reads(tid)
            reads_front[tid].extend(got if res.n_steps > 1 else [got])
    rec = front.reconcile()
    for tid in tenants:
        reports[tid] = front.report(tid)
    shared = front.device

    for tid, (nb, steps) in tenants.items():
        dev = pim.make_device(cfg.subdevice(nb))
        reads_iso = []
        for s in steps:
            r = pim.schedule(dev, s)
            dev = r.state
            reads_iso.append(r.reads)
        banks = placements[tid].banks
        # states: the tenant's banks on the shared device == its private run
        np.testing.assert_array_equal(
            np.asarray(shared.banks.bits)[list(banks)],
            np.asarray(dev.banks.bits), err_msg=f"{tid}: bits")
        # meters: per-slot cost is layout-independent, bit-exact
        for f in INT_FIELDS + FLOAT_FIELDS:
            assert np.array_equal(
                np.asarray(getattr(shared.banks.meter, f))[list(banks)],
                np.asarray(getattr(dev.banks.meter, f))), f"{tid}: {f}"
        # reads: every host-read row of every step
        assert len(reads_front[tid]) == n_steps, tid
        for k in range(n_steps):
            for sl in range(nb):
                assert len(reads_front[tid][k][sl]) == len(reads_iso[k][sl])
                for x, y in zip(reads_front[tid][k][sl], reads_iso[k][sl]):
                    assert np.array_equal(np.asarray(x), np.asarray(y)), \
                        f"{tid}: step {k} slot {sl}"
        # accounting: the tenant's metered share equals its isolated cost
        np.testing.assert_allclose(
            reports[tid].energy_nj,
            float(np.asarray(dev.slot_energy_nj, np.float64).sum()),
            rtol=1e-6, err_msg=f"{tid}: energy")
        np.testing.assert_allclose(
            reports[tid].busy_ns,
            float(np.asarray(dev.slot_time_ns, np.float64).sum()),
            rtol=1e-6, err_msg=f"{tid}: busy")
        assert reports[tid].host_bytes == sum(
            p.host_bytes for s in steps for p in s if p is not None)

    # ... and the per-tenant sums reconcile with the device-level totals
    np.testing.assert_allclose(rec["tenant_energy_nj"],
                               rec["device_energy_nj"], rtol=1e-9)
    np.testing.assert_allclose(rec["tenant_busy_ns"],
                               rec["device_busy_ns"], rtol=1e-9)
    assert rec["tenant_host_bytes"] == rec["device_host_bytes"]


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 24))
    def test_differential_eager_compiled_scheduled(seed, n_ops):
        _assert_agree(_build_program(np.random.default_rng(seed), n_ops))

    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 24),
           refresh=st.booleans())
    def test_differential_refresh_modes(seed, n_ops, refresh):
        _assert_agree(_build_program(np.random.default_rng(seed), n_ops),
                      refresh=refresh)

    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 24))
    def test_differential_static_semantics(seed, n_ops):
        _assert_sem_agrees(seed, n_ops)

    @given(seed=st.integers(0, 2**32 - 1), n_steps=st.integers(1, 3))
    def test_differential_channel_async_invariants(seed, n_steps):
        _assert_channel_and_async_invariants(seed, n_steps)

    # capped: every example compiles two fresh XLA programs (step plan +
    # pipeline scan) for brand-new random streams — 200 would dominate CI
    @settings(max_examples=40)
    @given(seed=st.integers(0, 2**32 - 1), n_steps=st.integers(1, 3),
           async_host=st.booleans())
    def test_differential_pipeline_vs_per_step(seed, n_steps, async_host):
        _assert_pipeline_agrees(seed, n_steps, async_host)

    # capped harder: every example lowers 2-3 fresh phase plans PLUS a
    # multi-phase driver (segmented chain or lax.switch over all branches)
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), async_host=st.booleans(),
           use_order=st.booleans())
    def test_differential_workload_vs_per_step(seed, async_host, use_order):
        _assert_workload_agrees(seed, async_host, use_order)

    # capped like the workload leg: each example compiles the coalesced
    # front-end plan PLUS one private-device plan per tenant
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), n_steps=st.integers(1, 3))
    def test_differential_multitenant_vs_isolated(seed, n_steps):
        _assert_multitenant_agrees(seed, n_steps)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_differential_eager_compiled_scheduled(seed):
        rng = np.random.default_rng(seed)
        _assert_agree(_build_program(rng, int(rng.integers(1, 25))))

    @pytest.mark.parametrize("seed", range(6))
    def test_differential_refresh_modes(seed):
        rng = np.random.default_rng(1000 + seed)
        _assert_agree(_build_program(rng, int(rng.integers(1, 25))),
                      refresh=bool(seed % 2))

    @pytest.mark.parametrize("seed", range(30))
    def test_differential_static_semantics(seed):
        _assert_sem_agrees(seed, 1 + seed % 24)

    @pytest.mark.parametrize("seed", range(8))
    def test_differential_channel_async_invariants(seed):
        _assert_channel_and_async_invariants(seed, 1 + seed % 3)

    @pytest.mark.parametrize("seed", range(8))
    def test_differential_pipeline_vs_per_step(seed):
        _assert_pipeline_agrees(seed, 1 + seed % 3,
                                async_host=bool(seed % 2))

    @pytest.mark.parametrize("seed", range(6))
    def test_differential_workload_vs_per_step(seed):
        _assert_workload_agrees(seed, async_host=bool(seed % 2),
                                use_order=bool(seed % 3 == 0))

    @pytest.mark.parametrize("seed", range(6))
    def test_differential_multitenant_vs_isolated(seed):
        _assert_multitenant_agrees(seed, 1 + seed % 3)


@pytest.mark.parametrize("seed", range(3))
def test_differential_with_refresh(seed):
    """Shift-heavy stream past tREFI: the post-pass refresh fold must agree
    across eager, compiled, and scheduled paths too."""
    rng = np.random.default_rng(100 + seed)
    b = ir.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))
    b.shift_k(0, 1, 40 + seed)          # ~8 us busy > tREFI
    b.read_row(1)
    _assert_agree(b.build(), refresh=True)


def test_differential_generator_covers_all_kinds():
    """The generator must keep emitting every op kind, or the harness
    silently loses coverage."""
    seen = set()
    for seed in range(40):
        prog = _build_program(np.random.default_rng(seed), 24)
        seen.update(o.op for o in prog.ops)
    assert seen == {ir.OP_ISSUE, ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_TRA,
                    ir.OP_NOT2DCC, ir.OP_DCC2, ir.OP_SHIFT, ir.OP_WRITE,
                    ir.OP_READ, ir.OP_FILL, ir.OP_COPY}
