"""Differential property harness: three executions of one random program.

Every hypothesis-generated :class:`~repro.core.pim.PimProgram` is executed

  1. eagerly     — ``pim.run_program``: one ISA pytree transition per command,
  2. compiled    — ``pim.execute``: fused segments + one-fold cost pass,
  3. scheduled   — ``pim.schedule`` on a single-bank device.

All three must agree *bit-exactly* on the final ``bits``/migration/DCC state
and the host-read rows, and within float32 tolerance on every cost-meter
field (the compiled fold replays the eager path's IEEE additions, so in
practice the meters are equal to the last ulp too). This is the safety net
that keeps IR → compile → exec → device → schedule refactors honest.

Hypothesis is optional (conftest registers the profiles); without it a
deterministic seed sweep runs the same generator. CI runs this file a
second time under the ``differential`` profile (200 examples, fixed seed).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic seed sweep below
    HAVE_HYPOTHESIS = False

from repro.core import pim
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir

ROWS = 16
WORDS = 4
USER_ROWS = ROWS - 8          # keep clear of C0/C1/T0..T3 (+ margin)

FLOAT_FIELDS = ("time_ns", "e_act", "e_pre", "e_refresh", "e_burst",
                "e_background")
INT_FIELDS = ("n_act", "n_pre", "n_aap", "n_shift", "n_tra", "n_refresh")

KINDS = ("rowclone", "dra", "tra", "shift", "chain", "copy", "and", "or",
         "xor", "not", "maj", "write", "read", "fill", "issue")


def _build_program(rng, n_ops):
    """One random mixed program over the user rows (np.random generator)."""
    b = ir.ProgramBuilder(ROWS, WORDS)
    pick = lambda n: [int(r) for r in rng.choice(USER_ROWS, n, replace=False)]
    for kind in rng.choice(KINDS, n_ops):
        if kind == "rowclone":
            b.rowclone(*pick(2))
        elif kind == "dra":
            b.dra(*pick(2))
        elif kind == "tra":
            b.tra(*pick(3))
        elif kind == "shift":
            b.shift(*pick(2), int(rng.choice([-1, 1])))
        elif kind == "chain":
            src, dst = pick(2)
            b.shift_k(src, dst, int(rng.integers(2, 8))
                      * int(rng.choice([-1, 1])))
        elif kind == "copy":
            b.copy_row(*pick(2))
        elif kind in ("and", "or", "xor"):
            getattr(b, f"ambit_{kind}")(*pick(3))
        elif kind == "not":
            b.ambit_not(*pick(2))
        elif kind == "maj":
            b.ambit_maj(*pick(4))
        elif kind == "write":
            b.write_row(pick(1)[0],
                        rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))
        elif kind == "read":
            b.read_row(pick(1)[0])
        elif kind == "fill":
            b.fill(pick(1)[0], int(rng.integers(0, 2**32)))
        else:
            assert kind == "issue", kind
            b.issue()
    return b.build()


def _fresh():
    return pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS))


def _assert_agree(prog, refresh=False):
    s_e, reads_e = pim.run_program(_fresh(), prog)
    if refresh:
        s_e = pim.SubarrayState(
            bits=s_e.bits, mig_top=s_e.mig_top, mig_bot=s_e.mig_bot,
            dcc=s_e.dcc, meter=pim.apply_refresh(s_e.meter))
    res_c = pim_exec.execute(prog, _fresh(), refresh=refresh)
    dev = pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=1, num_rows=ROWS, words=WORDS))
    res_s = pim.schedule(dev, [prog], refresh=refresh)

    for name, state, reads in (("compiled", res_c.state, res_c.reads),
                               ("scheduled", res_s.state.bank(0),
                                res_s.reads[0])):
        for f in ("bits", "mig_top", "mig_bot", "dcc"):
            assert np.array_equal(np.asarray(getattr(s_e, f)),
                                  np.asarray(getattr(state, f))), \
                f"{name}: {f} diverges from eager"
        assert len(reads) == len(reads_e), name
        for i, (x, y) in enumerate(zip(reads_e, reads)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                f"{name}: read {i} diverges from eager"
        for f in INT_FIELDS:
            assert int(getattr(s_e.meter, f)) == int(
                getattr(state.meter, f)), f"{name}: meter.{f}"
        for f in FLOAT_FIELDS:
            np.testing.assert_allclose(
                float(getattr(state.meter, f)),
                float(getattr(s_e.meter, f)), rtol=1e-6,
                err_msg=f"{name}: meter.{f}")


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 24))
    def test_differential_eager_compiled_scheduled(seed, n_ops):
        _assert_agree(_build_program(np.random.default_rng(seed), n_ops))
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_differential_eager_compiled_scheduled(seed):
        rng = np.random.default_rng(seed)
        _assert_agree(_build_program(rng, int(rng.integers(1, 25))))


@pytest.mark.parametrize("seed", range(3))
def test_differential_with_refresh(seed):
    """Shift-heavy stream past tREFI: the post-pass refresh fold must agree
    across eager, compiled, and scheduled paths too."""
    rng = np.random.default_rng(100 + seed)
    b = ir.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))
    b.shift_k(0, 1, 40 + seed)          # ~8 us busy > tREFI
    b.read_row(1)
    _assert_agree(b.build(), refresh=True)


def test_differential_generator_covers_all_kinds():
    """The generator must keep emitting every op kind, or the harness
    silently loses coverage."""
    seen = set()
    for seed in range(40):
        prog = _build_program(np.random.default_rng(seed), 24)
        seen.update(o.op for o in prog.ops)
    assert seen == {ir.OP_ISSUE, ir.OP_ROWCLONE, ir.OP_DRA, ir.OP_TRA,
                    ir.OP_NOT2DCC, ir.OP_DCC2, ir.OP_SHIFT, ir.OP_WRITE,
                    ir.OP_READ, ir.OP_FILL, ir.OP_COPY}
