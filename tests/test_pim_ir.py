"""IR → passes → executor pipeline vs the eager ISA: bit- and meter-exact.

The acceptance bar for the compiling executor is strict equality with the
eager command-at-a-time path: same ``bits``, same migration/DCC side state,
same ``CostMeter`` in every field (float32 to the last ulp — the cost pass
replays the identical IEEE additions in one fold).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import pim
from repro.core.pim import compile as pim_compile
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir, isa

WORDS = 8
ROWS = 32

METER_FIELDS = ("time_ns", "e_act", "e_pre", "e_refresh", "e_burst",
                "e_background", "n_act", "n_pre", "n_aap", "n_shift",
                "n_tra", "n_refresh")


def _rand_row(rng):
    return rng.integers(0, 2**32, (WORDS,), dtype=np.uint32)


def _fresh_state():
    return pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS))


def assert_states_equal(s_eager, s_ir, reads_eager=None, reads_ir=None):
    for field in ("bits", "mig_top", "mig_bot", "dcc"):
        a = np.asarray(getattr(s_eager, field))
        b = np.asarray(getattr(s_ir, field))
        assert np.array_equal(a, b), f"{field} mismatch"
    for k in METER_FIELDS:
        a = np.asarray(getattr(s_eager.meter, k))
        b = np.asarray(getattr(s_ir.meter, k))
        assert np.array_equal(a, b), f"meter.{k}: eager={a} ir={b}"
    if reads_eager is not None:
        assert len(reads_eager) == len(reads_ir)
        for i, (x, y) in enumerate(zip(reads_eager, reads_ir)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), f"read {i}"


def _random_mixed_program(seed, n_ops=40):
    """Issue the same random command stream eagerly and into a builder."""
    rng = np.random.default_rng(seed)
    s = _fresh_state()
    b = ir.ProgramBuilder(ROWS, WORDS)
    b.reserve_control_rows()
    reads = []
    user = list(range(ROWS - PimVMReserved))
    for _ in range(n_ops):
        kind = rng.choice(["write", "rowclone", "dra", "tra", "shift",
                           "chain", "and", "or", "xor", "not", "maj",
                           "issue", "read"])
        pick = lambda n: [int(r) for r in rng.choice(user, n, replace=False)]
        if kind == "write":
            (dst,) = pick(1)
            row = _rand_row(rng)
            s = pim.write_row(s, dst, jnp.asarray(row))
            b.write_row(dst, row)
        elif kind == "rowclone":
            src, dst = pick(2)
            s = pim.rowclone(s, src, dst)
            b.rowclone(src, dst)
        elif kind == "dra":
            src, dst = pick(2)
            s = pim.dra(s, src, dst)
            b.dra(src, dst)
        elif kind == "tra":
            r1, r2, r3 = pick(3)
            s = pim.tra(s, r1, r2, r3)
            b.tra(r1, r2, r3)
        elif kind == "shift":
            src, dst = pick(2)
            delta = int(rng.choice([-1, 1]))
            s = pim.shift(s, src, dst, delta)
            b.shift(src, dst, delta)
        elif kind == "chain":           # contiguous run → SegShiftRun fusion
            src, dst = pick(2)
            delta = int(rng.choice([-1, 1]))
            k = int(rng.integers(2, 40))
            s = pim.shift(s, src, dst, delta)
            b.shift(src, dst, delta)
            for _ in range(k - 1):
                s = pim.shift(s, dst, dst, delta)
                b.shift(dst, dst, delta)
        elif kind in ("and", "or", "xor"):
            a, bb, dst = pick(3)
            fn = {"and": pim.ambit_and, "or": pim.ambit_or,
                  "xor": pim.ambit_xor}[kind]
            s = fn(s, a, bb, dst)
            getattr(b, f"ambit_{kind}")(a, bb, dst)
        elif kind == "not":
            src, dst = pick(2)
            s = pim.ambit_not(s, src, dst)
            b.ambit_not(src, dst)
        elif kind == "maj":
            a, bb, c, dst = pick(4)
            s = pim.ambit_maj(s, a, bb, c, dst)
            b.ambit_maj(a, bb, c, dst)
        elif kind == "issue":
            s = pim.issue(s)
            b.issue()
        elif kind == "read":
            (src,) = pick(1)
            s, row = pim.read_row(s, src)
            reads.append(row)
            b.read_row(src)
    return s, reads, b.build()


PimVMReserved = 8  # keep random rows clear of C0/C1/T0..T3


@pytest.mark.parametrize("seed", range(8))
def test_random_program_equivalence(seed):
    s_eager, reads_eager, prog = _random_mixed_program(seed)
    res = pim_exec.execute(prog)
    assert_states_equal(s_eager, res.state, reads_eager, res.reads)


@pytest.mark.parametrize("seed", range(3))
def test_random_program_equivalence_jnp_lowering(seed):
    s_eager, reads_eager, prog = _random_mixed_program(seed, n_ops=20)
    res = pim_exec.execute(prog, use_kernels=False)
    assert_states_equal(s_eager, res.state, reads_eager, res.reads)
    res_k = pim_exec.execute(prog, use_kernels=True)
    assert_states_equal(s_eager, res_k.state, reads_eager, res_k.reads)


def test_table23_workload_n1000_exact_and_fused():
    """Acceptance: the N=1000 Table 2/3 stream through the compiled executor
    is bit-exact vs the eager loop, the chain fuses to one kernel segment,
    and the cost pass produces the meter without stepping the state."""
    rng = np.random.default_rng(0)
    row = jnp.asarray(rng.integers(0, 2**32, (WORDS,), dtype=np.uint32))

    # eager reference, command at a time
    s = pim.reserve_control_rows(pim.make_subarray(16, WORDS))
    s = pim.SubarrayState(bits=s.bits.at[0].set(row), mig_top=s.mig_top,
                          mig_bot=s.mig_bot, dcc=s.dcc, meter=s.meter)
    s = pim.issue(s)
    s = pim.shift(s, 0, 1, +1)
    for _ in range(999):
        s = pim.shift(s, 1, 1, +1)
    meter = pim.apply_refresh(s.meter)
    s = pim.SubarrayState(bits=s.bits, mig_top=s.mig_top, mig_bot=s.mig_bot,
                          dcc=s.dcc, meter=meter)

    got = pim.run_shift_workload(row, 1000, num_rows=16, words=WORDS)
    assert_states_equal(s, got)

    compiled = pim.compile_program(pim.shift_workload_program(1000, 16, WORDS))
    n_runs = sum(1 for seg in compiled.segments
                 if isinstance(seg, pim_compile.SegShiftRun))
    assert n_runs == 1 and compiled.segments[n_runs - 1].k == 1000


def test_trace_round_trip_preserves_results():
    s_eager, reads_eager, prog = _random_mixed_program(1)
    prog2 = ir.PimProgram.from_trace(prog.to_trace())
    assert prog2.ops == prog.ops
    res = pim_exec.execute(prog2)
    assert_states_equal(s_eager, res.state, reads_eager, res.reads)


def test_trace_accepts_pimulator_style_lines():
    text = """# pim-trace v1 rows=16 words=8
# comment line
PIM AAP 0 1  // HBM-PIMulator-style PIM prefix + trailing comment
SHIFT 1 2 +1
ISSUE
"""
    prog = ir.PimProgram.from_trace(text)
    assert [o.op for o in prog.ops] == [ir.OP_ROWCLONE, ir.OP_SHIFT,
                                        ir.OP_ISSUE]


def test_cost_pass_seeded_and_zero():
    _, _, prog = _random_mixed_program(2)
    m0 = pim.cost_pass(prog)
    s = _fresh_state()
    m1 = pim.cost_pass(prog, init=s.meter)
    assert float(m0.time_ns) == float(m1.time_ns)  # fresh meter is zero
    assert int(m0.n_aap) == int(m1.n_aap)


def test_cost_pass_matches_eager_meter():
    s_eager, _, prog = _random_mixed_program(3)
    meter = pim.cost_pass(prog)
    for k in METER_FIELDS:
        assert np.array_equal(np.asarray(getattr(s_eager.meter, k)),
                              np.asarray(getattr(meter, k))), k


def test_cost_summary_cross_checks_estimate_cost():
    """shift_k/estimate_cost vs recorded-program cost: the closed-form
    summary of the N-shift stream must agree with the static estimator."""
    n = 100
    prog = pim.shift_workload_program(n, 16, WORDS)
    est = pim.estimate_cost(n_shifts=n)
    summ = pim.cost_summary(prog, refresh=True)
    assert summ["time_ns"] == pytest.approx(est["time_ns"], rel=1e-6)
    assert summ["energy_nj"] == pytest.approx(est["energy_nj"], rel=1e-4)
    assert summ["n_shift"] == n
    # and the exact pass agrees with the traced meter (within f32 rounding)
    meter = pim.cost_pass(prog)
    assert float(meter.time_ns) == pytest.approx(
        summ["time_ns"] - summ["n_refresh"] * pim.DEFAULT_TIMING.tRFC,
        rel=1e-5)


def test_shift_k_ir_matches_eager():
    rng = np.random.default_rng(7)
    row = jnp.asarray(_rand_row(rng))
    for k in (0, 1, 3, 40, -5):
        s_new = pim.shift_k(pim.write_row(_fresh_state(), 0, row), 0, 1, k)
        s_ref = pim.write_row(_fresh_state(), 0, row)
        if k == 0:
            s_ref = pim.rowclone(s_ref, 0, 1)
        else:
            d = 1 if k > 0 else -1
            s_ref = pim.shift(s_ref, 0, 1, d)
            for _ in range(abs(k) - 1):
                s_ref = pim.shift(s_ref, 1, 1, d)
        assert_states_equal(s_ref, s_new)


def test_dead_copy_elimination_drops_overwritten_copy():
    b = ir.ProgramBuilder(ROWS, WORDS)
    row = np.arange(WORDS, dtype=np.uint32)
    b.write_row(0, row)
    b.rowclone(0, 2)          # dead: row 2 is overwritten before any read
    b.rowclone(0, 3)
    b.rowclone(3, 2)          # final value of row 2
    prog = b.build()
    opt = pim.dead_copy_elimination(prog)
    assert len(opt) == len(prog) - 1
    res = pim_exec.execute(prog)
    res_opt = pim_exec.execute(opt)
    assert np.array_equal(np.asarray(res.state.bits[2]),
                          np.asarray(res_opt.state.bits[2]))
    # the optimized stream is cheaper — that is the point of the pass
    assert float(res_opt.state.meter.time_ns) < float(res.state.meter.time_ns)


def test_dead_copy_elimination_keeps_read_copies():
    b = ir.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, np.arange(WORDS, dtype=np.uint32))
    b.rowclone(0, 2)
    b.tra(2, 0, 1)            # reads row 2 → the copy is live
    b.rowclone(0, 2)
    prog = b.build()
    assert pim.dead_copy_elimination(prog).ops == prog.ops


def test_ambit_xor_rejects_scratch_aliasing():
    """Regression: xor operands that resolve onto T0..T3 used to be silently
    clobbered mid-sequence; now they raise."""
    s = _fresh_state()
    t3 = isa.T3 % ROWS
    for args in ((t3, 1, 2), (0, t3, 2), (0, 1, t3), (0, 1, isa.T0)):
        with pytest.raises(ValueError, match="scratch"):
            pim.ambit_xor(s, *args)
    b = ir.ProgramBuilder(ROWS, WORDS)
    with pytest.raises(ValueError, match="scratch"):
        b.ambit_xor(0, 1, t3)


def test_ambit_xor_dst_aliasing_is_safe():
    """dst may alias a or b (reads go through scratch first)."""
    rng = np.random.default_rng(11)
    a, b = _rand_row(rng), _rand_row(rng)
    for dst in (0, 1):
        s = pim.write_row(_fresh_state(), 0, jnp.asarray(a))
        s = pim.write_row(s, 1, jnp.asarray(b))
        s = pim.ambit_xor(s, 0, 1, dst)
        assert np.array_equal(np.asarray(s.bits[dst]), a ^ b)


def test_bank_parallel_compiled_program():
    """§5.1.4 via ONE compiled program vmapped across banks."""
    rng = np.random.default_rng(9)
    n_banks = 4
    prog = pim.shift_workload_program(8, 16, WORDS)

    states = []
    rows = rng.integers(0, 2**32, (n_banks, WORDS), dtype=np.uint32)
    import jax
    base = jax.vmap(lambda _: pim.reserve_control_rows(
        pim.make_subarray(16, WORDS)))(jnp.arange(n_banks))
    base = pim.SubarrayState(
        bits=base.bits.at[:, 0].set(jnp.asarray(rows)),
        mig_top=base.mig_top, mig_bot=base.mig_bot, dcc=base.dcc,
        meter=base.meter)
    out, wall, energy = pim.bank_parallel(prog, n_banks)(base)

    single = pim.run_shift_workload(jnp.asarray(rows[0]), 8, num_rows=16,
                                    words=WORDS)
    # refresh is a post-pass, not part of the recorded stream
    assert wall == pytest.approx(
        float(single.meter.time_ns), rel=1e-6)
    assert energy == pytest.approx(
        n_banks * float(single.meter.total_energy_nj), rel=1e-5)
    assert np.array_equal(np.asarray(out.bits[0, 1]),
                          np.asarray(single.bits[1]))


def test_builder_rejects_traced_rows():
    b = ir.ProgramBuilder(ROWS, WORDS)
    with pytest.raises(TypeError, match="concrete int"):
        b.rowclone(jnp.int32(0), 1)
