"""PIM ISA correctness: the paper's migration-cell shift + Ambit ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic example loops below
    HAVE_HYPOTHESIS = False

from repro.core import pim

WORDS = 8  # 256-bit rows keep python-int cross-checks fast


def _rand_row(rng):
    return jnp.asarray(rng.integers(0, 2**32, size=(WORDS,), dtype=np.uint32))


def _row_to_int(row):
    out = 0
    for i, w in enumerate(np.asarray(row, dtype=np.uint32)):
        out |= int(w) << (32 * i)
    return out


def _int_to_row(v):
    return jnp.asarray([(v >> (32 * i)) & 0xFFFFFFFF for i in range(WORDS)],
                       dtype=jnp.uint32)


@pytest.fixture
def state():
    st_ = pim.make_subarray(32, WORDS)
    return pim.reserve_control_rows(st_)


def test_shift_right_matches_bigint(state):
    rng = np.random.default_rng(0)
    row = _rand_row(rng)
    s = pim.write_row(state, 0, row)
    s = pim.shift(s, 0, 1, +1)
    expect = (_row_to_int(row) << 1) & ((1 << (32 * WORDS)) - 1)
    assert _row_to_int(s.bits[1]) == expect


def test_shift_left_matches_bigint(state):
    rng = np.random.default_rng(1)
    row = _rand_row(rng)
    s = pim.write_row(state, 0, row)
    s = pim.shift(s, 0, 1, -1)
    assert _row_to_int(s.bits[1]) == _row_to_int(row) >> 1


def test_shift_is_4_aaps(state):
    s = pim.write_row(state, 0, jnp.ones((WORDS,), jnp.uint32))
    n_aap0 = int(s.meter.n_aap)
    s = pim.shift(s, 0, 1, +1)
    assert int(s.meter.n_aap) - n_aap0 == 4          # paper §3.3
    assert int(s.meter.n_shift) == 1


def test_migration_rows_capture_parity(state):
    """Fig. 3 mechanism: even columns go to mig_top, odd to mig_bot."""
    rng = np.random.default_rng(2)
    row = _rand_row(rng)
    s = pim.write_row(state, 0, row)
    s = pim.shift(s, 0, 1, +1)
    even = np.asarray(row & pim.EVEN_MASK)
    odd = np.asarray(row & pim.ODD_MASK)
    assert np.array_equal(np.asarray(s.mig_top), even)
    assert np.array_equal(np.asarray(s.mig_bot), odd)


def test_rowclone_copies_and_preserves_src(state):
    rng = np.random.default_rng(3)
    row = _rand_row(rng)
    s = pim.write_row(state, 3, row)
    s = pim.rowclone(s, 3, 7)
    assert np.array_equal(np.asarray(s.bits[7]), np.asarray(row))
    assert np.array_equal(np.asarray(s.bits[3]), np.asarray(row))


def test_tra_is_destructive_majority(state):
    rng = np.random.default_rng(4)
    a, b, c = (_rand_row(rng) for _ in range(3))
    s = state
    for i, r in enumerate((a, b, c)):
        s = pim.write_row(s, i, r)
    s = pim.tra(s, 0, 1, 2)
    maj = np.asarray((a & b) | (b & c) | (a & c))
    for i in range(3):                                # all three overwritten
        assert np.array_equal(np.asarray(s.bits[i]), maj)


def test_ambit_logic_ops(state):
    rng = np.random.default_rng(5)
    a, b = _rand_row(rng), _rand_row(rng)
    s = pim.write_row(pim.write_row(state, 0, a), 1, b)
    s = pim.ambit_and(s, 0, 1, 10)
    s = pim.ambit_or(s, 0, 1, 11)
    s = pim.ambit_xor(s, 0, 1, 12)
    s = pim.ambit_not(s, 0, 13)
    assert np.array_equal(np.asarray(s.bits[10]), np.asarray(a & b))
    assert np.array_equal(np.asarray(s.bits[11]), np.asarray(a | b))
    assert np.array_equal(np.asarray(s.bits[12]), np.asarray(a ^ b))
    assert np.array_equal(np.asarray(s.bits[13]), np.asarray(~a))


def test_surrounding_rows_preserved(state):
    """Paper's LTSPICE criterion: rows not involved keep their values."""
    rng = np.random.default_rng(6)
    rows = [_rand_row(rng) for _ in range(4)]
    s = state
    for i, r in enumerate(rows):
        s = pim.write_row(s, i, r)
    s = pim.shift(s, 1, 2, +1)
    assert np.array_equal(np.asarray(s.bits[0]), np.asarray(rows[0]))
    assert np.array_equal(np.asarray(s.bits[1]), np.asarray(rows[1]))
    assert np.array_equal(np.asarray(s.bits[3]), np.asarray(rows[3]))


def _check_shift_k(value, k):
    """k right shifts == one k-column big-int shift (edge bits drop)."""
    s = pim.reserve_control_rows(pim.make_subarray(16, WORDS))
    s = pim.write_row(s, 0, _int_to_row(value))
    s = pim.shift_k(s, 0, 1, k)
    expect = (value << k) & ((1 << (32 * WORDS)) - 1)
    assert _row_to_int(s.bits[1]) == expect


def _check_shift_round_trip(value):
    s = pim.reserve_control_rows(pim.make_subarray(16, WORDS))
    s = pim.write_row(s, 0, _int_to_row(value))
    s = pim.shift(s, 0, 1, +1)
    s = pim.shift(s, 1, 2, -1)
    top_bit_cleared = value & ((1 << (32 * WORDS - 1)) - 1)
    assert _row_to_int(s.bits[2]) == top_bit_cleared


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=(1 << (32 * WORDS)) - 1),
           st.integers(min_value=1, max_value=5))
    def test_shift_k_property(value, k):
        _check_shift_k(value, k)

    @given(st.integers(min_value=0, max_value=(1 << (32 * WORDS)) - 1))
    def test_shift_round_trip_loses_only_edge(value):
        _check_shift_round_trip(value)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_shift_k_property(seed):
        rng = np.random.default_rng(seed)
        value = int(rng.integers(0, 1 << 63)) | (seed << (32 * WORDS - 8))
        _check_shift_k(value & ((1 << (32 * WORDS)) - 1),
                       int(rng.integers(1, 6)))

    @pytest.mark.parametrize("value", [0, 1, (1 << (32 * WORDS)) - 1,
                                       0xDEADBEEF << 64, 1 << (32 * WORDS - 1)])
    def test_shift_round_trip_loses_only_edge(value):
        _check_shift_round_trip(value)


def test_bank_parallel_energy_and_wall_time():
    """§5.1.4: N banks → same wall time, N× energy, N× throughput."""
    def prog(row):
        return pim.run_shift_workload(row, 4, num_rows=16, words=WORDS)

    rng = np.random.default_rng(7)
    rows = jnp.asarray(rng.integers(0, 2**32, size=(8, WORDS),
                                    dtype=np.uint32))
    states, wall_ns, energy = pim.bank_parallel(prog, 8)(rows)
    single = prog(rows[0])
    assert wall_ns == pytest.approx(float(single.meter.time_ns), rel=1e-6)
    assert energy == pytest.approx(
        8 * float(single.meter.total_energy_nj), rel=1e-5)
