"""pimlint: the static verifier + hazard analyzer (DESIGN.md §12).

Covers the three entry points (``lint_program`` / ``lint_schedule`` /
``lint_trace``), the golden known-bad fixtures under
``tests/fixtures/lint/``, the opt-in ``verify=True`` gates across the
builder/compiler/executor/scheduler, the unified builder-vs-importer
operand validation, and the cost contracts: vectorized O(n_ops) speed and
ZERO extra work on warm schedule paths.

Hypothesis is optional (conftest registers the profiles); without it a
deterministic seed sweep drives the same generators.
"""
import glob
import importlib
import json
import os
import time

import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: deterministic seed sweep below
    HAVE_HYPOTHESIS = False

from repro.core import pim
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir
from repro.core.pim import lint
from test_pim_differential import _build_program

# the package re-exports a `schedule` FUNCTION; the module needs importlib
pim_schedule = importlib.import_module("repro.core.pim.schedule")

ROWS = 16
WORDS = 2
FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "lint")


def _b(rows=ROWS, words=WORDS, **kw):
    return pim.ProgramBuilder(rows, words, **kw)


def _raw(ops, rows=ROWS, words=WORDS, payloads=()):
    """Hand-assembled program bypassing the builder's validation — the only
    way to express PIM101/102/105-class streams (builder and trace importer
    both reject them at construction)."""
    return ir.PimProgram(ops=tuple(ops), num_rows=rows, words=words,
                         payloads=tuple(payloads))


# ---------------------------------------------------------------------------
# Golden fixtures: every seeded-hazard trace flags its code, clean is clean
# ---------------------------------------------------------------------------

FIXTURES = sorted(glob.glob(os.path.join(FIXDIR, "*.trace")))
# fixture name -> op index the diagnostic must anchor to (trace op order);
# None = a whole-trace diagnostic (the pim405 equivalence proof has no op)
EXPECT_OP = {"pim103": 0, "pim104": 5, "pim106": 1, "pim201": 0,
             "pim202": 0, "pim203": 1, "pim204": 1, "pim301": 1,
             "pim302": 3, "pim303": 0, "pim401": 4, "pim402": 3,
             "pim403": 3, "pim404": 1, "pim405": None}


def test_fixture_dir_is_populated():
    names = {os.path.basename(p) for p in FIXTURES}
    assert {f"pim{c}.trace" for c in
            (103, 104, 106, 201, 202, 203, 204, 301, 302, 303,
             401, 402, 403, 404, 405)} <= names
    assert "clean_maj.trace" in names


@pytest.mark.parametrize("path", FIXTURES,
                         ids=[os.path.basename(p) for p in FIXTURES])
def test_fixture_flags_expected_code_at_expected_op(path):
    with open(path) as f:
        text = f.read()
    directives = lint._trace_directives(text)
    # lint via the file entry point: it self-applies device directives and
    # resolves pimverify references relative to the fixture directory
    report = lint.lint_trace_file(path)
    name = os.path.basename(path).removesuffix(".trace")
    if "expect" not in directives:
        assert report.diagnostics == (), report.render()
        return
    code = directives["expect"]
    hits = [d for d in report.diagnostics if d.code == code]
    assert hits, f"{name}: {code} not in {report.codes()}"
    assert any(d.op_index == EXPECT_OP[name] for d in hits), \
        (name, [(d.code, d.op_index) for d in hits])
    # severity agrees with the catalog
    for d in hits:
        assert d.severity == lint.CATALOG[code][0]


def test_fixture_diagnostics_carry_trace_line_provenance():
    path = os.path.join(FIXDIR, "pim104.trace")
    with open(path) as f:
        text = f.read()
    report = lint.lint_trace(text)
    hit = next(d for d in report.diagnostics if d.code == "PIM104")
    # the flagged op (op 5) sits on the trace's 11th physical line
    assert hit.trace_line == 11
    assert f"line {hit.trace_line}" in hit.render()


def test_pimverify_directive_parsing_and_missing_ref(tmp_path):
    text = ("# pim-trace v2 rows=16 words=2 banks=1\n"
            "# pimlint: expect=PIM405\n"
            "# pimverify: equiv=nowhere.trace\n"
            "BANK 0 HOSTR 2\n")
    assert lint._trace_directives(text) == {"expect": "PIM405",
                                            "equiv": "nowhere.trace"}
    # an unreadable reference is an ERROR diagnostic, not a traceback
    t = tmp_path / "t.trace"
    t.write_text(text)
    report = lint.lint_trace_file(str(t))
    hit = next(d for d in report.diagnostics if d.code == "PIM405")
    assert hit.severity == lint.ERROR and "nowhere.trace" in hit.message


def test_pim405_witness_names_the_difference():
    report = lint.lint_trace_file(os.path.join(FIXDIR, "pim405.trace"))
    hit = next(d for d in report.diagnostics if d.code == "PIM405")
    assert hit.severity == lint.ERROR
    assert "NOT equivalent" in hit.message and "lane" in hit.message


def test_no_semantic_suppresses_pim4xx(capsys):
    path = os.path.join(FIXDIR, "pim404.trace")
    assert "PIM404" not in lint.lint_trace_file(path,
                                                semantic=False).codes()
    # CLI parity: without the semantic tier the expect directive misses
    assert lint.main([path, "--no-semantic"]) == 1
    assert lint.main([path]) == 0
    capsys.readouterr()


# ---------------------------------------------------------------------------
# Program-level codes not expressible as traces (importer/builder reject)
# ---------------------------------------------------------------------------

def test_pim101_row_out_of_range():
    prog = _raw([ir.PimOp(ir.OP_ROWCLONE, a=3, b=ROWS + 2)])
    report = lint.lint_program(prog)
    assert "PIM101" in report.codes()
    d = next(d for d in report.diagnostics if d.code == "PIM101")
    assert d.op_index == 0 and d.severity == lint.ERROR


def test_pim102_shift_delta():
    prog = _raw([ir.PimOp(ir.OP_SHIFT, a=0, b=1, delta=3)])
    report = lint.lint_program(prog)
    assert "PIM102" in report.codes()
    assert not report.ok


def test_pim105_payload_out_of_range_and_bad_shape():
    missing = _raw([ir.PimOp(ir.OP_WRITE, b=0, payload=4)])
    assert "PIM105" in lint.lint_program(missing).codes()
    bad_shape = _raw(
        [ir.PimOp(ir.OP_WRITE, b=0, payload=0)],
        payloads=[np.zeros(WORDS + 1, dtype=np.uint32)])
    assert "PIM105" in lint.lint_program(bad_shape).codes()


def test_pim205_unused_payload_is_warning_only():
    prog = _raw([ir.PimOp(ir.OP_WRITE, b=0, payload=0)],
                payloads=[np.zeros(WORDS, dtype=np.uint32),
                          np.ones(WORDS, dtype=np.uint32)])
    report = lint.lint_program(prog)
    assert "PIM205" in report.codes()
    assert report.ok          # warnings never fail verification


def test_pim106_clobber_without_read_is_warning():
    b = _b()
    b.fill(pim.C0, 0)
    b.rowclone(0, pim.C0)     # dirty C0, but nothing reads it afterwards
    report = lint.lint_program(b.build())
    d = next(d for d in report.diagnostics if d.code == "PIM106")
    assert d.severity == lint.WARNING and report.ok


def test_pim305_shape_mismatch_schedule():
    cfg = pim.paper_device(2, num_rows=32, words=8)
    wrong = _b(rows=16, words=8)
    wrong.issue()
    report = pim.lint_schedule(cfg, [wrong.build(), None])
    assert "PIM305" in report.codes()
    assert not report.ok


def test_pim304_async_host_window(monkeypatch):
    cfg = pim.paper_device(2, num_rows=32, words=8)
    heavy = _b(rows=32, words=8)        # host-dominated: writes, no compute
    rng = np.random.default_rng(0)
    for r in range(8):
        heavy.write_row(r, rng.integers(0, 2**32, 8, dtype=np.uint32))
    light = _b(rows=32, words=8)
    light.issue()
    report = pim.lint_schedule(cfg, [heavy.build(), light.build()],
                               async_host=True)
    assert "PIM304" in report.codes()
    # same layout without async host analysis: no PIM304
    quiet = pim.lint_schedule(cfg, [heavy.build(), light.build()])
    assert "PIM304" not in quiet.codes()


# ---------------------------------------------------------------------------
# Satellite 1: builder and trace importer share validation + provenance
# ---------------------------------------------------------------------------

def test_builder_rejects_out_of_range_row_with_op_index():
    b = _b()
    b.rowclone(0, 1)
    with pytest.raises(ValueError, match=r"op 1: row index 40"):
        b.rowclone(40, 1)
    with pytest.raises(TypeError):
        b.rowclone(None, 1)


def test_builder_negative_rows_still_alias_the_tail():
    b = _b()
    b.rowclone(0, pim.T0)
    assert b._ops[-1].b == ROWS - 3
    with pytest.raises(ValueError, match="out of range"):
        b.rowclone(-(ROWS + 1), 0)


def test_builder_rejects_bad_shift_delta_and_payload_shape():
    b = _b()
    with pytest.raises(ValueError, match=r"op 0: SHIFT delta"):
        b.shift(0, 1, 2)
    with pytest.raises(ValueError, match=r"op 0: HOSTW payload shape"):
        b.write_row(0, np.zeros(WORDS + 3, dtype=np.uint32))


def test_importer_errors_carry_line_numbers():
    text = ("# pim-trace v2 rows=16 words=2 banks=1\n"
            "BANK 0 AAP 0 1\n"
            "BANK 0 SHIFT 0 1 +2\n")
    with pytest.raises(ValueError, match="trace line 3"):
        ir.from_trace_device(text)


def test_importer_attaches_trace_lines():
    text = ("# pim-trace v2 rows=16 words=2 banks=1\n"
            "\n"
            "BANK 0 AAP 0 1\n"
            "BANK 0 HOSTR 1\n")
    (prog,), = ir.from_trace_device(text)
    assert prog.trace_lines == (3, 4)
    # builder-made programs have no trace provenance
    b = _b()
    b.issue()
    assert b.build().trace_lines is None


# ---------------------------------------------------------------------------
# verify=True gates across the stack
# ---------------------------------------------------------------------------

def _bad_tra_prog(rows=ROWS, words=WORDS):
    return _raw([ir.PimOp(ir.OP_TRA, a=3, b=3, c=5)], rows, words)


def test_builder_verify_gate():
    b = _b(verify=True)
    b.fill(0, 7)
    b.tra(0, 0, 2)            # PIM103 at build() time
    with pytest.raises(lint.LintError, match="PIM103"):
        b.build()
    ok = _b(verify=True)
    ok.fill(0, 7)
    ok.read_row(0)
    ok.build()                # warnings-only streams pass


def test_compile_execute_and_eager_verify_gates():
    bad = _bad_tra_prog()
    with pytest.raises(lint.LintError):
        pim.compile_program(bad, verify=True)
    with pytest.raises(lint.LintError):
        pim_exec.execute(bad, pim.make_subarray(ROWS, WORDS), verify=True)
    with pytest.raises(lint.LintError):
        pim.run_program(pim.make_subarray(ROWS, WORDS), bad, verify=True)
    # unverified paths still run the stream (legacy behaviour untouched)
    pim.run_program(pim.make_subarray(ROWS, WORDS), bad)


def test_record_and_vm_thread_verify():
    with pytest.raises(lint.LintError):
        ir.record(lambda b: b.tra(0, 0, 2), ROWS, WORDS, verify=True)
    vm = pytest.importorskip("repro.core.bitplane.vm")
    v = vm.PimVM(32, num_rows=64, words=4, verify=True)
    assert v._builder.verify is True


def test_schedule_verify_gate_and_clean_pass():
    cfg = pim.paper_device(2, num_rows=32, words=8)
    race = _b(rows=32, words=8)
    race.fill(0, 1)
    race.copy_row(0, 5, 1, 0)
    race.copy_row(0, 5, 1, 0)            # PIM302
    other = _b(rows=32, words=8)
    other.issue()
    with pytest.raises(lint.LintError, match="PIM302"):
        pim.schedule(pim.make_device(cfg), [race.build(), other.build()],
                     verify=True)
    clean = _b(rows=32, words=8)
    clean.fill(0, 1)
    clean.copy_row(0, 5, 1, 0)
    res = pim.schedule(pim.make_device(cfg),
                       [clean.build(), other.build()], verify=True)
    assert float(res.wall_ns) > 0


def test_schedule_workload_verify_gate_covers_fast_path():
    cfg = pim.paper_device(2, num_rows=32, words=8)
    race = _b(rows=32, words=8)
    race.fill(0, 1)
    race.copy_row(0, 5, 1, 0)
    race.copy_row(0, 5, 1, 0)
    other = _b(rows=32, words=8)
    other.issue()
    phases = [pim.Phase.repeat([race.build(), other.build()], 2)]
    with pytest.raises(lint.LintError):
        pim.schedule_workload(pim.make_device(cfg), phases, verify=True)
    # warm the cache unverified, then hit the fast path verified: the plan
    # lint is cached on the plan, so the gate must STILL raise
    pim.schedule_workload(pim.make_device(cfg), phases)
    with pytest.raises(lint.LintError):
        pim.schedule_workload(pim.make_device(cfg), phases, verify=True)


# ---------------------------------------------------------------------------
# Zero warm-path cost: plan lint rides the plan cache
# ---------------------------------------------------------------------------

def test_verified_warm_schedule_adds_no_work():
    cfg = pim.paper_device(2, num_rows=32, words=8)
    rng = np.random.default_rng(3)
    progs = []
    for _ in range(cfg.n_slots):
        b = _b(rows=32, words=8)
        b.issue()
        b.write_row(0, rng.integers(0, 2**32, 8, dtype=np.uint32))
        b.shift(0, 1, +1)
        b.read_row(1)
        progs.append(b.build())
    res = pim.schedule(pim.make_device(cfg), progs, verify=True)  # warm
    pim.reset_stats()
    for _ in range(3):
        res = pim.schedule(res.state, progs, verify=True)
    assert pim_schedule.SCHED_STATS["dispatches"] == 3
    assert pim_schedule.SCHED_STATS["plan_misses"] == 0
    assert pim_schedule.SCHED_STATS["compile_misses"] == 0
    assert ir.COLUMN_STATS["builds"] == 0
    assert pim_exec.RUNNER_STATS["traces"] == 0


def test_lint_program_results_are_cached(monkeypatch):
    b = _b()
    b.fill(0, 7)
    b.read_row(0)
    prog = b.build()
    r1 = lint.lint_program(prog)
    monkeypatch.setattr(lint, "_lint_columns",
                        lambda *a, **k: pytest.fail("cache miss: "
                                                    "_lint_columns re-ran"))
    r2 = lint.lint_program(prog)
    assert r2.diagnostics == r1.diagnostics
    # an identical stream rebuilt from scratch hits the digest-keyed cache
    b2 = _b()
    b2.fill(0, 7)
    b2.read_row(0)
    assert lint.lint_program(b2.build()).diagnostics == r1.diagnostics


# ---------------------------------------------------------------------------
# Generated streams: valid programs lint error-free, injected hazards don't
# ---------------------------------------------------------------------------

def _assert_clean(seed, n_ops):
    prog = _build_program(np.random.default_rng(seed), n_ops)
    report = lint.lint_program(prog)
    assert report.ok, report.render()


def _assert_injected_hazard_flagged(seed, n_ops):
    prog = _build_program(np.random.default_rng(seed), n_ops)
    rows = prog.num_rows
    bad = ir.PimProgram(
        ops=prog.ops + (ir.PimOp(ir.OP_TRA, a=1, b=1, c=2),),
        num_rows=rows, words=prog.words, payloads=prog.payloads)
    report = lint.lint_program(bad)
    assert "PIM103" in report.codes()
    d = next(x for x in report.diagnostics if x.code == "PIM103")
    assert d.op_index == len(prog.ops)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 24))
    def test_generated_programs_lint_clean(seed, n_ops):
        _assert_clean(seed, n_ops)

    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(1, 16))
    def test_injected_hazard_always_flagged(seed, n_ops):
        _assert_injected_hazard_flagged(seed, n_ops)
else:
    @pytest.mark.parametrize("seed", range(0, 40))
    def test_generated_programs_lint_clean(seed):
        _assert_clean(seed, 1 + seed % 24)

    @pytest.mark.parametrize("seed", range(40, 60))
    def test_injected_hazard_always_flagged(seed):
        _assert_injected_hazard_flagged(seed, 1 + seed % 16)


def test_buggy_pr1_xor_expansion_is_flagged_but_current_isnt():
    # the current (fixed) ambit_xor composite must be clean...
    b = _b()
    b.fill(pim.C0, 0)
    b.fill(pim.C1, 0xFFFFFFFF)
    b.write_row(0, np.zeros(WORDS, dtype=np.uint32))
    b.write_row(1, np.ones(WORDS, dtype=np.uint32))
    b.ambit_xor(0, 1, 2)
    b.read_row(2)
    assert lint.lint_program(b.build()).ok
    # ...and the builder itself refuses scratch operands (PR-1's bug)
    with pytest.raises(ValueError, match="scratch"):
        b2 = _b()
        b2.ambit_xor(pim.T0, 1, 2)


def test_benchmark_workloads_lint_clean():
    for name, report in lint._workload_reports():
        assert report.ok, (name, report.render())


def test_workload_semantic_proof_legs_all_pass():
    # the --workloads proof tier: fused == unfused for every canonical
    # kernel, and ambit_xor summarizes to its closed form
    for name, report in lint._semantic_reports():
        assert report.diagnostics == (), (name, report.render())


# ---------------------------------------------------------------------------
# Performance: vectorized O(n_ops), fast enough for CI gating
# ---------------------------------------------------------------------------

def test_lint_100k_ops_under_a_second():
    n = 100_000
    rng = np.random.default_rng(0)
    b = pim.ProgramBuilder(64, 4)
    b.fill(pim.C0, 0)
    srcs = rng.integers(0, 32, n)
    dsts = rng.integers(0, 32, n)
    for s, d in zip(srcs, dsts):
        b.rowclone(int(s), int(d))
    b.tra(1, 1, 2)                       # one seeded hazard at the tail
    prog = b.build()                     # build outside the timed region
    prog.columns                         # columnar encode also untimed
    t0 = time.perf_counter()
    report = lint.lint_program(prog)
    dt = time.perf_counter() - t0
    assert "PIM103" in report.codes()
    assert dt < 1.0, f"lint took {dt:.2f}s for {n} ops"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_fixtures_and_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = lint.main(FIXTURES + ["--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert any(k.endswith("clean_maj.trace") for k in payload)
    bad = next(v for k, v in payload.items() if k.endswith("pim103.trace"))
    assert any(d["code"] == "PIM103" for d in bad["diagnostics"])
    capsys.readouterr()


def test_cli_exit_codes(tmp_path, capsys):
    # a failing trace without an expect directive -> exit 1
    t = tmp_path / "bad.trace"
    t.write_text("# pim-trace v2 rows=16 words=2 banks=1\n"
                 "BANK 0 TRA 3 3 5\n")
    assert lint.main([str(t)]) == 1
    # clean trace, but --strict turns warnings into failures
    w = tmp_path / "warn.trace"
    w.write_text("# pim-trace v2 rows=16 words=2 banks=1\n"
                 "BANK 0 HOSTR 2\n")
    assert lint.main([str(w)]) == 0
    assert lint.main([str(w), "--strict"]) == 1
    # unparseable trace is a PARSE diagnostic, not a traceback
    p = tmp_path / "parse.trace"
    p.write_text("# pim-trace v2 rows=16 words=2 banks=1\n"
                 "BANK 0 FROB 1 2\n")
    assert lint.main([str(p)]) == 1
    # no inputs -> usage error
    assert lint.main([]) == 2
    capsys.readouterr()


def test_cli_workloads_leg(tmp_path, capsys):
    out = tmp_path / "wl.json"
    assert lint.main(["--workloads", "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert {"sem:ambit_xor", "sem:shift_workload(256)", "sem:xor_reduce",
            "sem:gf.xtime", "sem:rs.encode"} <= set(payload)
    assert lint.main(["--workloads", "--no-semantic"]) == 0
    capsys.readouterr()


def test_catalog_is_consistent():
    for code, (sev, title, why) in lint.CATALOG.items():
        assert sev in (lint.ERROR, lint.WARNING)
        assert code.startswith("PIM") and title and why
