"""Columnar IR, single-dispatch scheduler, and pipeline regression guards.

Locks down the host-side performance model (DESIGN.md §10): the cached
columnar encoding + O(1) stream digests (no re-hash on warm cache hits),
the vectorized cost-table gather (bit-exact vs the per-op reference), the
single-dispatch ``schedule()`` step (1 compile, then 0 — and exactly one
XLA dispatch per step), the payload-stack cache, and the ``lax.scan``
pipeline APIs (``schedule_pipeline`` / ``PimVM.run_pipeline``) being
bit-exact against the per-step path.
"""
import importlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pim
from repro.core.bitplane import PimVM
from repro.core.pim import compile as pim_compile
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir

# the package re-exports schedule() the function, shadowing the module
pim_schedule = importlib.import_module("repro.core.pim.schedule")

WORDS = 8
ROWS = 32
T = pim.DEFAULT_TIMING


def _rand_row(rng, words=WORDS):
    return rng.integers(0, 2**32, (words,), dtype=np.uint32)


def _step_prog(data, k=4, rows=ROWS, words=WORDS):
    b = pim.ProgramBuilder(rows, words)
    b.issue()
    b.write_row(0, data)
    b.shift_k(0, 1, k)
    b.ambit_xor(0, 1, 2)
    b.read_row(2)
    return b.build()


def _cfg(channels=1, ranks=1, banks_per_rank=4):
    return pim.DeviceConfig(channels=channels, ranks=ranks,
                            banks_per_rank=banks_per_rank,
                            num_rows=ROWS, words=WORDS)


# Mid-test counter resets (post-warm) go through the shared helper; the
# autouse conftest fixture already zeroes everything per-test.
_reset_stats = pim.reset_stats


# ---------------------------------------------------------------------------
# Columnar encoding & digests
# ---------------------------------------------------------------------------

def test_columns_built_once_and_digest_cached():
    """build() warms the columnar encoding; stream_key/digest/cost passes
    never rebuild it (the no-re-hash-on-warm-hit regression)."""
    rng = np.random.default_rng(0)
    prog = _step_prog(_rand_row(rng))
    n0 = ir.COLUMN_STATS["builds"]
    for _ in range(5):
        pim.stream_key(prog)
        prog.digest
        prog.columns
    pim.cost_tables(prog)
    pim.cost_pass(prog)
    assert ir.COLUMN_STATS["builds"] == n0


def test_compiled_for_warm_hit_does_not_rehash():
    """_compiled_for on a warm cache entry is pure dict traffic: no new
    columnar builds, no compile misses."""
    rng = np.random.default_rng(1)
    prog = _step_prog(_rand_row(rng))
    first = pim_schedule._compiled_for(prog, T)
    _reset_stats()
    n0 = ir.COLUMN_STATS["builds"]
    for _ in range(10):
        assert pim_schedule._compiled_for(prog, T) is first
    assert ir.COLUMN_STATS["builds"] == n0
    assert pim_schedule.SCHED_STATS["compile_misses"] == 0


def test_with_payloads_shares_columns():
    rng = np.random.default_rng(2)
    prog = _step_prog(_rand_row(rng))
    n0 = ir.COLUMN_STATS["builds"]
    clone = prog.with_payloads([_rand_row(rng)])
    assert clone.columns is prog.columns
    assert clone.digest == prog.digest
    assert ir.COLUMN_STATS["builds"] == n0
    # payload DATA is excluded from the stream key (same count -> same key)
    assert pim.stream_key(clone) == pim.stream_key(prog)
    # ...but a different payload COUNT does change it
    extra = prog.with_payloads(list(prog.payloads) + [_rand_row(rng)])
    assert pim.stream_key(extra) != pim.stream_key(prog)


def test_digest_distinguishes_streams():
    b1 = pim.ProgramBuilder(ROWS, WORDS).rowclone(0, 1).build()
    b2 = pim.ProgramBuilder(ROWS, WORDS).rowclone(0, 2).build()
    b3 = pim.ProgramBuilder(ROWS, WORDS).rowclone(0, 1).build()
    assert b1.digest != b2.digest
    assert b1.digest == b3.digest           # content-addressed, not id


# ---------------------------------------------------------------------------
# Vectorized cost tables
# ---------------------------------------------------------------------------

def _mixed_program(rng, n_ops=24):
    user = ROWS - 8
    b = pim.ProgramBuilder(ROWS, WORDS)
    pick = lambda n: [int(r) for r in rng.choice(user, n, replace=False)]
    for kind in rng.choice(
            ["rowclone", "dra", "tra", "shift", "chain", "copy", "xor",
             "not", "maj", "write", "read", "fill", "issue"], n_ops):
        if kind == "rowclone":
            b.rowclone(*pick(2))
        elif kind == "dra":
            b.dra(*pick(2))
        elif kind == "tra":
            b.tra(*pick(3))
        elif kind == "shift":
            b.shift(*pick(2), int(rng.choice([-1, 1])))
        elif kind == "chain":
            src, dst = pick(2)
            b.shift_k(src, dst, int(rng.integers(2, 8)))
        elif kind == "copy":
            b.copy_row(*pick(2))
        elif kind == "xor":
            b.ambit_xor(*pick(3))
        elif kind == "not":
            b.ambit_not(*pick(2))
        elif kind == "maj":
            b.ambit_maj(*pick(4))
        elif kind == "write":
            b.write_row(pick(1)[0], _rand_row(rng))
        elif kind == "read":
            b.read_row(pick(1)[0])
        elif kind == "fill":
            b.fill(pick(1)[0], int(rng.integers(0, 2**32)))
        else:
            b.issue()
    return b.build()


@pytest.mark.parametrize("seed", range(10))
def test_cost_tables_bit_exact_vs_reference(seed):
    """The columnar template gather reproduces the per-op loop row-for-row:
    same rows, same order, same float32 bit patterns."""
    prog = _mixed_program(np.random.default_rng(seed))
    f_vec, i_vec = pim.cost_tables(prog)
    f_ref, i_ref = pim.cost_tables_reference(prog)
    assert f_vec.shape == f_ref.shape
    assert np.array_equal(f_vec.view(np.uint32), f_ref.view(np.uint32))
    assert np.array_equal(i_vec, i_ref)


def test_cost_tables_rejects_cross_slot_copy():
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.copy_row(0, 1, dst_bank=1, dst_sub=0)
    with pytest.raises(ValueError, match="cross-subarray COPY"):
        pim.cost_tables(b.build())


def test_fold_block_matches_row_at_a_time():
    """The block-unrolled in-jit fold equals a strictly-sequential numpy
    accumulate bit-for-bit, including the zero-row padding tail."""
    rng = np.random.default_rng(3)
    for n in (0, 1, 63, 64, 65, 163, 400):
        f_tab = rng.uniform(0, 100, (n, 6)).astype(np.float32)
        i_tab = rng.integers(0, 3, (n, 6), dtype=np.int32)
        f0 = rng.uniform(0, 10, 6).astype(np.float32)
        i0 = rng.integers(0, 5, 6, dtype=np.int32)
        ff, fi = pim_compile._fold_tables(
            jnp.asarray(f_tab), jnp.asarray(i_tab),
            jnp.asarray(f0), jnp.asarray(i0))
        ref_f = np.add.accumulate(
            np.concatenate([f0[None], f_tab]), axis=0,
            dtype=np.float32)[-1]
        ref_i = np.add.accumulate(
            np.concatenate([i0[None], i_tab]), axis=0, dtype=np.int32)[-1]
        assert np.array_equal(np.asarray(ff).view(np.uint32),
                              ref_f.view(np.uint32)), n
        assert np.array_equal(np.asarray(fi), ref_i), n


# ---------------------------------------------------------------------------
# Single-dispatch schedule: compile/dispatch count guards
# ---------------------------------------------------------------------------

def test_recurring_schedule_is_one_compile_then_zero():
    """3-step recurring pipeline via per-step schedule(): the first step
    pays 1 plan build / 1 compile / 1 runner trace; steps 2..3 pay ZERO of
    each and exactly one XLA dispatch per step."""
    rng = np.random.default_rng(4)
    base = _step_prog(_rand_row(rng), k=9)    # stream unique to this test:
    progs = [base] + [base.with_payloads([_rand_row(rng)])   # cold caches
                      for _ in range(3)]
    dev = pim.make_device(_cfg())
    _reset_stats()
    res = pim.schedule(dev, progs)
    assert pim_schedule.SCHED_STATS["plan_misses"] == 1
    assert pim_schedule.SCHED_STATS["compile_misses"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 1
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    for _ in range(2):
        res = pim.schedule(res.state, progs)
    assert pim_schedule.SCHED_STATS["plan_misses"] == 1
    assert pim_schedule.SCHED_STATS["compile_misses"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 1
    assert pim_schedule.SCHED_STATS["dispatches"] == 3


def test_schedule_pipeline_is_one_dispatch_for_k_steps():
    rng = np.random.default_rng(5)
    base = _step_prog(_rand_row(rng))
    progs = [base.with_payloads([_rand_row(rng)]) for _ in range(4)]
    dev = pim.make_device(_cfg())
    pr = pim.schedule_pipeline(dev, progs, n_steps=3)     # warm the compile
    _reset_stats()
    pr = pim.schedule_pipeline(pr.state, progs, n_steps=3)
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_schedule.SCHED_STATS["plan_misses"] == 0
    assert pim_schedule.SCHED_STATS["compile_misses"] == 0
    assert pim_exec.RUNNER_STATS["traces"] == 0
    assert pr.n_steps == 3


def test_payload_stack_cached_for_recurring_programs():
    """Scheduling the SAME program objects twice must not re-stack (or
    re-upload) their HOSTW payload data."""
    rng = np.random.default_rng(6)
    progs = [_step_prog(_rand_row(rng)).with_payloads([_rand_row(rng)])
             for _ in range(2)]
    # same objects -> identical cached device batch
    s1 = pim_schedule._payload_stack(progs, WORDS)
    s2 = pim_schedule._payload_stack(progs, WORDS)
    assert s1 is s2
    # different payload arrays -> a different batch
    other = [p.with_payloads([_rand_row(rng)]) for p in progs]
    s3 = pim_schedule._payload_stack(other, WORDS)
    assert s3 is not s1


def test_payload_cache_byte_budget_evicts_pinned_arrays(monkeypatch):
    """Regression: the payload cache capped entry COUNT but not bytes — a
    serving loop churning payload batches pinned device memory without
    bound. Eviction by byte budget must actually drop the pinned stacked
    arrays (verified by weakref death), not just the dict entries."""
    import gc
    import weakref

    pim_schedule._payload_cache_clear()
    rng = np.random.default_rng(20)

    def batch():
        return [_step_prog(_rand_row(rng)).with_payloads([_rand_row(rng)])
                for _ in range(2)]

    probe = batch()
    per_entry = pim_schedule._entry_nbytes(
        (pim_schedule._payload_stack(probe, WORDS),
         tuple(p.payloads for p in probe)))
    pim_schedule._payload_cache_clear()
    monkeypatch.setattr(pim_schedule, "_PAYLOAD_CACHE_MAX_BYTES",
                        3 * per_entry)

    first = batch()
    dead = weakref.ref(pim_schedule._payload_stack(first, WORDS))
    for _ in range(4):                  # 5 entries vs a 3-entry byte budget
        pim_schedule._payload_stack(batch(), WORDS)
    assert len(pim_schedule._payload_cache) <= 3
    assert pim_schedule._payload_cache_bytes <= 3 * per_entry
    gc.collect()
    assert dead() is None, "evicted entry still pins its device batch"
    # ... and the evicted programs now re-stack to a fresh batch
    fresh = pim_schedule._payload_stack(first, WORDS)
    np.testing.assert_array_equal(
        np.asarray(fresh[0, 0]), np.asarray(first[0].payloads[0]))


def test_payload_cache_keeps_one_oversized_entry(monkeypatch):
    """The newest entry is never evicted: one batch larger than the whole
    budget must still cache (recurring pipelines would otherwise re-upload
    it every call)."""
    pim_schedule._payload_cache_clear()
    monkeypatch.setattr(pim_schedule, "_PAYLOAD_CACHE_MAX_BYTES", 1)
    rng = np.random.default_rng(21)
    progs = [_step_prog(_rand_row(rng)).with_payloads([_rand_row(rng)])
             for _ in range(2)]
    s1 = pim_schedule._payload_stack(progs, WORDS)
    assert pim_schedule._payload_stack(progs, WORDS) is s1
    assert len(pim_schedule._payload_cache) == 1


def test_payload_cache_id_recycling_never_aliases(monkeypatch):
    """The id()-keyed cache relies on entries pinning their key arrays.
    After byte-budget eviction releases the pins, a recycled id must MISS
    and restack — never serve the dead entry's data."""
    import gc

    pim_schedule._payload_cache_clear()
    monkeypatch.setattr(pim_schedule, "_PAYLOAD_CACHE_MAX_BYTES", 1)
    rng = np.random.default_rng(22)
    stream = _step_prog(_rand_row(rng))

    old_prog = stream.with_payloads([_rand_row(rng)])
    evicted_id = id(old_prog.payloads[0])
    old_data = old_prog.payloads[0].copy()
    pim_schedule._payload_stack([old_prog], WORDS)
    # while cached the key array is pinned: its id cannot be recycled
    assert any(isinstance(k, tuple) and evicted_id in k
               for k in pim_schedule._payload_cache)
    # a second entry evicts the first (byte budget = 1), dropping the pin
    pim_schedule._payload_stack(
        [stream.with_payloads([_rand_row(rng)])], WORDS)
    assert not any(isinstance(k, tuple) and evicted_id in k
                   for k in pim_schedule._payload_cache)
    del old_prog
    gc.collect()
    # allocate until CPython hands back the evicted id (usually instant);
    # correctness must hold either way, the loop just makes the collision
    # scenario real rather than hypothetical
    recycled = None
    for _ in range(512):
        cand = stream.with_payloads([_rand_row(rng)])
        if id(cand.payloads[0]) == evicted_id:
            recycled = cand
            break
        del cand
    if recycled is None:
        pytest.skip("allocator never recycled the id")
    assert not np.array_equal(recycled.payloads[0], old_data)
    out = pim_schedule._payload_stack([recycled], WORDS)
    np.testing.assert_array_equal(np.asarray(out[0, 0]),
                                  recycled.payloads[0])


def test_workload_fast_cache_pins_key_steps():
    """_workload_fast_cache keys on Phase.steps identity; the entry must
    pin the steps' programs while cached (no stale hit for a recycled id)
    and release them when evicted."""
    import gc
    import weakref

    rng = np.random.default_rng(23)
    cfg = _cfg(banks_per_rank=2)
    dev = pim.make_device(cfg)
    base = _step_prog(_rand_row(rng))
    layout = [base.with_payloads([_rand_row(rng)]) for _ in range(2)]
    phases = [pim_schedule.Phase.repeat(layout, 2)]
    pim.schedule_workload(dev, phases)
    ref = weakref.ref(layout[0])
    del layout, phases, base
    gc.collect()
    assert ref() is not None, "cached workload entry dropped its key pin"
    # both id-keyed layout caches pin the programs; once evicted from both,
    # nothing else holds them (the payload/compile caches key on payload
    # arrays and digests, not program objects)
    pim_schedule._workload_fast_cache.clear()
    pim_schedule._phase_lower_cache.clear()
    gc.collect()
    assert ref() is None, "programs leak after workload-cache eviction"


def test_schedule_result_metrics_are_plain_floats():
    """The lazily-converted metrics still read as plain host values."""
    rng = np.random.default_rng(7)
    dev = pim.make_device(_cfg(channels=2, banks_per_rank=2))
    progs = [_step_prog(_rand_row(rng)) for _ in range(4)]
    r0 = pim.schedule(dev, progs, async_host=True)
    r1 = pim.schedule(r0.state, progs, async_host=True)
    assert isinstance(r1.host_bus_ns, float)
    assert isinstance(r1.host_overlap_ns, float)
    assert isinstance(r1.channel_bus_ns, tuple)
    assert all(isinstance(x, float) for x in r1.channel_bus_ns)
    assert r1.host_overlap_ns > 0.0
    # the async credit chains lazily (a device value, not a blocking float)
    assert isinstance(r1.state.host_credit_ns, jax.Array)


# ---------------------------------------------------------------------------
# schedule_pipeline vs per-step path: bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("async_host", [False, True])
def test_pipeline_bit_exact_vs_per_step(async_host):
    rng = np.random.default_rng(8)
    cfg = _cfg(channels=2, banks_per_rank=2)
    steps = []
    base = _step_prog(_rand_row(rng))
    for _ in range(4):
        steps.append([base.with_payloads([_rand_row(rng)])
                      for _ in range(4)])

    dev = pim.make_device(cfg)
    walls, energies, reads = [], [], []
    for s in steps:
        r = pim.schedule(dev, s, async_host=async_host)
        dev = r.state
        walls.append(float(r.wall_ns))
        energies.append(float(r.energy_nj))
        reads.append(r.reads)

    pr = pim.schedule_pipeline(pim.make_device(cfg), steps,
                               async_host=async_host)
    assert np.array_equal(np.asarray(dev.banks.bits),
                          np.asarray(pr.state.banks.bits))
    for f in ("time_ns", "e_act", "e_pre", "e_burst", "e_background",
              "n_act", "n_pre", "n_aap", "n_shift", "n_tra"):
        assert np.array_equal(np.asarray(getattr(dev.banks.meter, f)),
                              np.asarray(getattr(pr.state.banks.meter, f))), f
    np.testing.assert_allclose(walls, np.asarray(pr.wall_ns), rtol=1e-6)
    np.testing.assert_allclose(energies, np.asarray(pr.energy_nj),
                               rtol=1e-6)
    preads = pr.reads
    for k in range(4):
        for slot in range(4):
            assert len(reads[k][slot]) == len(preads[k][slot])
            for x, y in zip(reads[k][slot], preads[k][slot]):
                assert np.array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(float(dev.host_credit_ns),
                               float(pr.state.host_credit_ns), rtol=1e-6)


def test_pipeline_with_copy_drain_matches_per_step():
    """A recurring gather step (cross-slot COPYs) drains identically under
    the scan."""
    rng = np.random.default_rng(9)
    cfg = _cfg(banks_per_rank=4)
    load = pim.ProgramBuilder(ROWS, WORDS)
    load.write_row(1, _rand_row(rng))
    moves = [((b, 0, 1), (0, 0, 2 + b)) for b in range(1, 4)]
    progs = pim.gather_rows(cfg, moves,
                            [load.build().with_payloads([_rand_row(rng)])
                             for _ in range(4)])
    dev = pim.make_device(cfg)
    r = pim.schedule(dev, progs)
    r = pim.schedule(r.state, progs)
    pr = pim.schedule_pipeline(pim.make_device(cfg), progs, n_steps=2)
    assert np.array_equal(np.asarray(r.state.banks.bits),
                          np.asarray(pr.state.banks.bits))
    assert pr.copy_ns == pytest.approx(r.copy_ns)
    assert pr.copy_queue_ns == pytest.approx(r.copy_queue_ns)
    np.testing.assert_allclose(float(r.wall_ns),
                               np.asarray(pr.wall_ns)[1], rtol=1e-6)


def test_pipeline_rejects_non_recurring_steps():
    rng = np.random.default_rng(10)
    s1 = [_step_prog(_rand_row(rng)) for _ in range(4)]
    s2 = [_step_prog(_rand_row(rng), k=7) for _ in range(4)]   # other chain
    with pytest.raises(ValueError, match="does not recur"):
        pim.schedule_pipeline(pim.make_device(_cfg()), [s1, s2])


# ---------------------------------------------------------------------------
# PimVM.run_pipeline
# ---------------------------------------------------------------------------

def _vm_step(vm, x):
    a = vm.load(x[0])
    b = vm.load(x[1])
    r = vm.xor(a, b)
    s = vm.shift_elem(r, 1)
    vm.free(a, b, r)
    return s


@pytest.mark.parametrize("n_banks", [1, 4])
def test_vm_run_pipeline_matches_reference(n_banks):
    rng = np.random.default_rng(11)
    vm = PimVM(width=8, num_rows=96, words=16, n_banks=n_banks,
               async_host=n_banks > 1)
    vm.mask(0xFE)                       # pre-create the shift mask
    xs = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
          for _ in range(3)]
    got = vm.run_pipeline(_vm_step, xs)
    for k, (a, b) in enumerate(xs):
        assert np.array_equal(got[k], ((a ^ b) << 1) & 0xFF), k


def test_vm_run_pipeline_is_one_dispatch_when_sharded():
    rng = np.random.default_rng(12)
    vm = PimVM(width=8, num_rows=96, words=16, n_banks=2)
    vm.mask(0xFE)
    xs = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
          for _ in range(3)]
    vm.run_pipeline(_vm_step, xs)       # warm compile
    _reset_stats()
    vm.run_pipeline(_vm_step, xs)
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 0


def test_vmapped_fold_ulp_exact_on_nonzero_meter():
    """Regression: the block-unrolled meter fold must replay eager's f32
    additions exactly even under vmap and with a NONZERO incoming meter —
    XLA CPU fast-math reassociation of the unrolled chain drifted e_act by
    an ulp before the fold's optimization barriers."""
    rng = np.random.default_rng(14)
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.write_row(0, _rand_row(rng))
    b.shift_k(0, 1, 3)
    prog = b.build()

    s = pim.reserve_control_rows(pim.make_subarray(ROWS, WORDS))
    s, _ = pim.run_program(s, prog)
    s, _ = pim.run_program(s, prog)      # eager: strict sequential adds

    dev = pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=2, num_rows=ROWS, words=WORDS))
    r = pim.schedule(dev, [prog, prog])          # vmapped, meter zero
    r = pim.schedule(r.state, [prog, prog])      # vmapped, meter NONZERO
    for f in ("time_ns", "e_act", "e_pre", "e_burst", "e_background"):
        want = np.asarray(getattr(s.meter, f))
        got = np.asarray(getattr(r.state.banks.meter, f))
        assert np.array_equal(np.broadcast_to(want, got.shape), got), f


def test_make_pipeline_runner_cached():
    rng = np.random.default_rng(13)
    prog = _step_prog(_rand_row(rng))
    compiled = pim_schedule._compiled_for(prog, T)
    p1 = pim.make_pipeline_runner(compiled, T)
    p2 = pim.make_pipeline_runner(compiled, T)
    assert p1 is p2
