"""pimsem: the symbolic semantic analyzer (DESIGN.md §14).

What the abstract interpreter must get right, by contract:

- closed forms: the flagship kernels summarize to their paper equations
  (ambit_xor -> ``r0 ^ r1``; shift_k -> the source displaced k lanes with
  PROVED zero boundary fill, the migration-cell edge behaviour);
- soundness: ``prove_equivalent`` never returns a false EQUIVALENT — the
  undecidable collapses to UNKNOWN — and every DIFFERENT verdict carries
  a witness that actually distinguishes the programs when executed;
- the ``verify_semantics=True`` compile gate passes on every real kernel
  and catches corrupted segment lists;
- performance: a 100k-op stream analyzes in under a second, and warm
  digest-keyed hits rebuild zero column tables.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core import pim
from repro.core.pim import compile as pim_compile
from repro.core.pim import ir, sem
from repro.core.pim.program import ambit_xor_program, shift_workload_program

ROWS = 16
WORDS = 2
LANES = WORDS * 32


def _b(rows=ROWS, words=WORDS):
    return pim.ProgramBuilder(rows, words)


# ---------------------------------------------------------------------------
# Closed forms: the paper's kernels, proved
# ---------------------------------------------------------------------------

def test_ambit_xor_summarizes_to_xor():
    assert sem.summarize(ambit_xor_program())[2] == "r0 ^ r1"


def test_ambit_and_or_not_closed_forms():
    b = _b()
    b.reserve_control_rows()
    b.ambit_and(0, 1, 2)
    b.ambit_or(0, 1, 3)
    b.ambit_not(0, 4)
    out = sem.summarize(b.build())
    assert out[2] == "r0 & r1"
    assert out[3] == "r0 | r1"
    assert out[4] == "~r0"


def test_tra_renders_majority():
    b = _b()
    b.tra(0, 1, 2)
    out = sem.summarize(b.build())
    assert out[0] == out[1] == out[2] == "maj(r0, r1, r2)"


def test_shift_k_is_exact_displacement_with_boundary_fill():
    k = 5
    b = _b()
    b.shift_k(0, 1, k)
    m = sem.analyze(b.build())
    v = m.value(1)
    # the value IS the source displaced k lanes: single support variable
    assert v.sup == ((0, k),)
    # the paper's migration-cell edge: lanes entering from the subarray
    # boundary are PROVED zero, every other lane is symbolic
    for lane in range(k):
        assert sem.lane_const(v, lane) == 0
    for lane in range(k, LANES):
        assert sem.lane_const(v, lane) is None
    rendered = sem.summarize(b.build())[1]
    assert "(r0 << 5)" in rendered and "5 boundary lane(s)" in rendered


def test_shift_left_mirrors_the_fill_to_the_top_edge():
    b = _b()
    b.shift_k(0, 1, -3)
    v = sem.analyze(b.build()).value(1)
    assert v.sup == ((0, -3),)
    for lane in range(LANES - 3, LANES):
        assert sem.lane_const(v, lane) == 0
    assert sem.lane_const(v, 0) is None


# ---------------------------------------------------------------------------
# Equivalence proving: the sound-verdict contract
# ---------------------------------------------------------------------------

def test_maj_commutes_proved_on_result_row():
    b1 = _b()
    b1.reserve_control_rows()
    b1.ambit_and(0, 1, 2)
    b2 = _b()
    b2.reserve_control_rows()
    b2.ambit_and(1, 0, 2)
    # scratch rows hold swapped operands, so restrict to the result
    rep = sem.prove_equivalent(b1.build(), b2.build(), outputs=[2])
    assert rep.verdict == sem.EQUIVALENT and rep.ok


def test_shift_round_trip_differs_from_rowclone():
    # +3 then -3 loses the top 3 lanes to boundary fill; a rowclone keeps
    # them — DIFFERENT, and the witness must really distinguish them
    a = _b()
    a.shift_k(0, 1, 3)
    a.shift_k(1, 1, -3)
    bb = _b()
    bb.rowclone(0, 1)
    rep = sem.prove_equivalent(a.build(), bb.build(), outputs=[1])
    assert rep.verdict == sem.DIFFERENT
    assert rep.component == "row 1"
    assert rep.witness is not None
    assert rep.witness.lane >= LANES - 3        # a trimmed top lane
    assert sem.check_witness(a.build(), bb.build(), rep.witness)


def test_or_vs_and_witness_replays():
    b1 = _b()
    b1.reserve_control_rows()
    b1.ambit_and(0, 1, 2)
    b2 = _b()
    b2.reserve_control_rows()
    b2.ambit_or(0, 1, 2)
    rep = sem.prove_equivalent(b1.build(), b2.build(), outputs=[2])
    assert rep.verdict == sem.DIFFERENT
    assert sem.check_witness(b1.build(), b2.build(), rep.witness)


def test_reads_length_mismatch_is_different():
    a = _b()
    a.fill(0, 1)
    a.read_row(0)
    a.read_row(0)
    bb = _b()
    bb.fill(0, 1)
    bb.read_row(0)
    rep = sem.prove_equivalent(a.build(), bb.build())
    assert rep.verdict == sem.DIFFERENT
    assert rep.component == "number of host reads"
    assert rep.witness.kind == "reads_len"
    assert sem.check_witness(a.build(), bb.build(), rep.witness)


def test_side_state_only_difference_is_caught():
    # identical written rows (row 1 ends up 0 both ways) but the shift
    # leaves its migration-cell captures behind — full-state comparison
    # must refuse equivalence and the witness must replay
    a = _b()
    a.shift(0, 1, +1)
    a.fill(1, 0)
    bb = _b()
    bb.fill(1, 0)
    rep = sem.prove_equivalent(a.build(), bb.build())
    assert rep.verdict == sem.DIFFERENT
    assert rep.witness.kind in ("mig_top", "mig_bot")
    assert sem.check_witness(a.build(), bb.build(), rep.witness)


def test_budget_exhaustion_is_unknown_never_equivalent():
    b = _b()
    b.tra(0, 1, 2)                        # 3 symbolic inputs
    prog = b.build()
    rep = sem.prove_equivalent(prog, prog, max_inputs=2)
    assert rep.verdict == sem.UNKNOWN
    assert not rep.ok
    assert rep.unknown                    # names the undecided components


def test_shape_mismatch_raises():
    a = _b()
    a.issue()
    bb = _b(words=WORDS * 2)
    bb.issue()
    with pytest.raises(ValueError, match="shapes"):
        sem.prove_equivalent(a.build(), bb.build())


# ---------------------------------------------------------------------------
# Satellite 1: verdicts keyed on payload CONTENT, not structure
# ---------------------------------------------------------------------------

def test_payload_content_changes_flip_the_verdict():
    b = _b()
    b.write_row(0, np.zeros(WORDS, np.uint32))
    b.read_row(0)
    p1 = b.build()
    p2 = p1.with_payloads((np.full(WORDS, 0xFFFF_FFFF, np.uint32),))
    # same structure, same digest — different payload content digest
    assert p1.digest == p2.digest
    assert p1.payload_digest != p2.payload_digest
    assert sem.prove_equivalent(p1, p1).verdict == sem.EQUIVALENT
    rep = sem.prove_equivalent(p1, p2)
    assert rep.verdict == sem.DIFFERENT
    assert sem.check_witness(p1, p2, rep.witness)


def test_analysis_cache_hits_same_content_misses_new_content():
    b = _b()
    b.write_row(0, np.zeros(WORDS, np.uint32))
    b.read_row(0)
    p1 = b.build()
    p2 = p1.with_payloads((np.ones(WORDS, np.uint32),))
    sem.analyze(p1)                       # warm
    pim.reset_stats()
    sem.analyze(p1)
    assert sem.SEM_STATS["analysis_hits"] == 1
    assert sem.SEM_STATS["analyses"] == 0
    sem.analyze(p2)                       # same digest, new content: MISS
    assert sem.SEM_STATS["analyses"] == 1


# ---------------------------------------------------------------------------
# The compile-gate: fused == unfused, proved
# ---------------------------------------------------------------------------

def test_verify_semantics_gate_passes_real_kernels():
    pim.compile_program(ambit_xor_program(), verify_semantics=True)
    pim.compile_program(shift_workload_program(64, num_rows=64, words=32),
                        verify_semantics=True)
    from repro.core.pim.schedule import xor_reduce_program
    pim.compile_program(xor_reduce_program(32, 8, rows=[0, 1, 2], dst=3),
                        verify_semantics=True)
    from repro.core.pim.lint import _recorded_rs_encode, _recorded_xtime
    pim.compile_program(_recorded_xtime(), verify_semantics=True)
    pim.compile_program(_recorded_rs_encode(), verify_semantics=True)


def test_corrupted_segments_fail_the_gate_with_witness():
    prog = shift_workload_program(40, num_rows=32, words=4)
    good = pim_compile.fuse(prog)
    runs = [i for i, s in enumerate(good)
            if isinstance(s, pim_compile.SegShiftRun)]
    assert runs, "expected a fused shift run"
    bad = list(good)
    bad[runs[0]] = dataclasses.replace(bad[runs[0]], k=bad[runs[0]].k - 1)
    with pytest.raises(sem.EquivalenceError) as ei:
        sem.verify_fusion(prog, tuple(bad))
    rep = ei.value.report
    assert rep.verdict == sem.DIFFERENT
    assert sem.check_witness(prog, prog, rep.witness) is False  # same prog
    # the fusion report agrees with the raising gate
    assert sem.fusion_report(prog, tuple(bad)).verdict == sem.DIFFERENT
    assert sem.fusion_report(prog, good).verdict == sem.EQUIVALENT


def test_dropped_host_read_fails_the_gate():
    prog = ambit_xor_program()
    good = pim_compile.fuse(prog)
    bad = tuple(s for s in good
                if not isinstance(s, pim_compile.SegHost)
                or s.op.op != ir.OP_READ)
    assert len(bad) == len(good) - 1
    with pytest.raises(sem.EquivalenceError):
        sem.verify_fusion(prog, bad)


# ---------------------------------------------------------------------------
# PIM4xx findings through lint (default OFF, opt-in ON)
# ---------------------------------------------------------------------------

def test_lint_semantic_tier_is_opt_in():
    b = _b()
    b.rowclone(0, 1)
    b.rowclone(1, 0)                      # provably rewrites r0 with r0
    prog = b.build()
    assert "PIM404" not in pim.lint_program(prog).codes()
    report = pim.lint_program(prog, semantic=True)
    hit = next(d for d in report.diagnostics if d.code == "PIM404")
    assert hit.op_index == 1
    assert report.ok                      # PIM404 is warning severity


def test_findings_cover_constant_and_cancelling_chains():
    b = _b()
    b.rowclone(0, pim.T0)
    b.not_to_dcc(0)
    b.dcc_to(pim.T1)
    b.rowclone(pim.C0, pim.T2)
    b.tra(pim.T0, pim.T1, pim.T2)         # maj(x, ~x, 0) == 0
    codes = [c for c, _, _ in sem.semantic_findings(b.build())]
    assert "PIM401" in codes
    b2 = _b()
    b2.not_to_dcc(0)
    b2.dcc_to(1)
    b2.not_to_dcc(1)
    b2.dcc_to(2)                          # double negation
    codes2 = [c for c, _, _ in sem.semantic_findings(b2.build())]
    assert "PIM403" in codes2


# ---------------------------------------------------------------------------
# Satellite 6: perf guard — vectorized analysis, zero warm rebuilds
# ---------------------------------------------------------------------------

def test_100k_op_stream_analyzes_under_a_second():
    n = 100_000
    b = pim.ProgramBuilder(64, 4)
    b.shift(0, 1, +1)
    for _ in range(n - 1):
        b.shift(1, 1, +1)
    prog = b.build()
    prog.columns                          # columnar encode untimed
    t0 = time.perf_counter()
    m = sem.analyze(prog)
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"analysis took {dt:.2f}s for {n} ops"
    # 100k displacements wrap far past the subarray edge: provably zero
    assert sem.is_const(m.value(1))
    assert sem.lane_const(m.value(1), 0) == 0


def test_warm_hits_rebuild_zero_column_tables():
    b = _b()
    b.reserve_control_rows()
    b.ambit_xor(0, 1, 2)
    prog = b.build()
    sem.analyze(prog)
    sem.semantic_findings(prog)
    sem.prove_equivalent(prog, prog)
    sem.fusion_report(prog)
    pim.reset_stats()
    sem.analyze(prog)
    sem.semantic_findings(prog)
    sem.prove_equivalent(prog, prog)
    sem.fusion_report(prog)
    assert ir.COLUMN_STATS["builds"] == 0
    assert sem.SEM_STATS["analyses"] == 0
    assert sem.SEM_STATS["proofs"] == 0
    assert sem.SEM_STATS["analysis_hits"] >= 2
    assert sem.SEM_STATS["proof_hits"] >= 2
