"""Multi-tenant serving front end (DESIGN.md §13).

Locks down the serving contracts: explicit placement with admission-time
over-subscription rejection, the static-verifier admission gate (hostile
tenants are rejected with diagnostics, never scheduled), tenant isolation
(COPY destinations outside the tenant's banks are PIM301 at admission;
legal copies are relocated through the placement map), cross-tenant
stream coalescing into shared vmapped groups, warm-``_StepPlan``
preemption (a departing tenant never invalidates the survivors' plan),
continuous batching via single-dispatch ``schedule_pipeline`` windows,
and per-tenant accounting that reconciles with device-level totals.
"""
import numpy as np
import pytest

from repro.core import pim
from repro.core.pim.schedule import SCHED_STATS
from repro.serve.pim_front import (AdmissionError, PimServeFront,
                                   Placement)

ROWS = 16
WORDS = 4


def _cfg(banks=4, subarrays=1):
    return pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=banks,
                            subarrays=subarrays, num_rows=ROWS,
                            words=WORDS)


def _prog(seed=0, *, copy_to=None, words=WORDS):
    """A small verified stream: two host writes, an AND, a host read.
    ``copy_to`` adds a cross-bank COPY to tenant-local bank ``copy_to``."""
    b = pim.ProgramBuilder(ROWS, words)
    rng = np.random.default_rng(seed)
    b.write_row(2, rng.integers(0, 2**32, (words,), dtype=np.uint32))
    b.write_row(3, rng.integers(0, 2**32, (words,), dtype=np.uint32))
    b.ambit_and(2, 3, 4)
    if copy_to is not None:
        b.copy_row(4, 5, dst_bank=copy_to, dst_sub=0)
    b.read_row(4)
    return b.build()


# ---------------------------------------------------------------------------
# Placement & admission
# ---------------------------------------------------------------------------

def test_placement_map_is_explicit_and_exclusive():
    front = PimServeFront(_cfg(banks=4, subarrays=2))
    pa = front.submit("A", (_prog(0), 2), banks=2)
    pb = front.submit("B", (_prog(1), 2), banks=1)
    assert isinstance(pa, Placement)
    assert pa.banks == (0, 1) and pb.banks == (2,)
    # every subarray of an owned bank belongs to the tenant
    assert pa.slots == (0, 1, 2, 3) and pb.slots == (4, 5)
    assert set(pa.slots) & set(pb.slots) == set()
    assert front.free_banks == (3,)
    assert front.placement("A") == pa
    assert set(front.placement()) == {"A", "B"}


def test_oversubscription_rejected_at_admission():
    front = PimServeFront(_cfg(banks=4))
    front.submit("A", (_prog(), 2), banks=3)
    with pytest.raises(AdmissionError, match="over-subscribed"):
        front.submit("B", (_prog(), 2), banks=2)
    # a request larger than the whole device can never fit, even queued
    with pytest.raises(AdmissionError, match="cannot ever fit"):
        front.submit("C", (_prog(), 2), banks=5, queue=True)
    with pytest.raises(AdmissionError, match="already submitted"):
        front.submit("A", (_prog(), 1), banks=1)


def test_queued_tenant_admitted_at_step_boundary():
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 2), banks=2)
    assert front.submit("B", (_prog(1), 3), banks=1, queue=True) is None
    assert front.pending == ("B",)
    results = front.run()
    assert front.pending == () and front.active == ()
    assert front.report("A").n_steps == 2
    assert front.report("B").n_steps == 3
    assert sum(r.n_steps for r in results) == 2 + 3


# ---------------------------------------------------------------------------
# The admission-time verifier gate
# ---------------------------------------------------------------------------

def test_hostile_tenant_rejected_with_diagnostics(tmp_path):
    """The pim104 fixture (scratch-alias hazard) must be rejected at
    submit() with its lint report — not admitted, not a crash."""
    fixture = "tests/fixtures/lint/pim104.trace"
    bad = pim.PimProgram.from_trace(open(fixture).read())
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=2,
                           num_rows=16, words=2)
    front = PimServeFront(cfg)
    with pytest.raises(AdmissionError) as ei:
        front.submit("evil", (bad, 2), banks=1)
    assert ei.value.report is not None
    assert "PIM104" in ei.value.report.codes()
    # nothing was allocated; well-behaved tenants are unaffected
    assert front.free_banks == (0, 1)
    good = pim.ProgramBuilder(16, 2)
    good.write_row(2, np.zeros(2, np.uint32))
    good.read_row(2)
    front.submit("good", (good.build(), 1), banks=1)
    front.run()
    assert front.report("good").n_steps == 1


def test_shape_mismatch_rejected():
    front = PimServeFront(_cfg())
    with pytest.raises(AdmissionError, match="shape"):
        front.submit("A", (_prog(words=2, seed=0), 1), banks=1)


def test_non_program_rejected():
    front = PimServeFront(_cfg())
    with pytest.raises(AdmissionError):
        front.submit("A", [["not a program"]], banks=1)


def test_copy_escape_rejected_as_pim301():
    """A COPY addressed outside the tenant's own banks is outside its
    subdevice — the admission lint rejects it (tenant isolation)."""
    front = PimServeFront(_cfg(banks=4))
    with pytest.raises(AdmissionError) as ei:
        front.submit("A", ([_prog(copy_to=1)], 1), banks=1)
    assert ei.value.report is not None
    assert "PIM301" in ei.value.report.codes()


def test_confined_copy_relocated_through_placement():
    """A legal tenant-local cross-bank COPY is rewritten to device
    coordinates at admission and lands in the right device bank."""
    front = PimServeFront(_cfg(banks=4))
    front.submit("filler", (_prog(9), 1), banks=2)
    p = front.submit("C", ([_prog(0, copy_to=1), None], 1), banks=2)
    assert p.banks == (2, 3)
    reloc = front._active["C"].steps[0][0]
    copies = [op for op in reloc.ops if op.op == pim.ir.OP_COPY]
    assert copies and copies[0].delta == 3     # local bank 1 -> device 3
    res = front.step()
    # the copied row actually landed in device bank 3, row 5
    expect = np.asarray(res.tenant_reads("C")[0])[0]
    got = np.asarray(res.result.state.banks.bits[3][5], np.uint32)
    np.testing.assert_array_equal(got, expect)


def test_copy_free_programs_not_rewritten():
    """Programs without cross-slot COPYs keep their identity through
    placement — digests (and so coalescing and the id-keyed payload
    cache) are placement-independent."""
    front = PimServeFront(_cfg(banks=4))
    p = _prog(0)
    front.submit("filler", (_prog(9), 1), banks=1)
    front.submit("A", (p, 2), banks=1)
    assert front._active["A"].steps[0][0] is p


# ---------------------------------------------------------------------------
# Coalescing & the serving loop
# ---------------------------------------------------------------------------

def test_identical_streams_coalesce_across_tenants():
    front = PimServeFront(_cfg(banks=4))
    shared = _prog(7)
    for tid, banks in (("A", 2), ("B", 1), ("C", 1)):
        front.submit(tid, (shared, 2), banks=banks)
    res = front.step()
    assert res.n_active_slots == 4
    assert res.n_groups == 1
    assert res.coalescing == 4.0


def test_same_stream_different_payloads_still_coalesce():
    """Digests cover the command stream, not payload data — tenants
    running the same program shape over different data share one group
    (the payloads are the vmapped axis)."""
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 1), banks=1)
    front.submit("B", (_prog(1), 1), banks=1)
    res = front.step()
    assert res.n_groups == 1 and res.coalescing == 2.0


def test_distinct_streams_do_not_coalesce():
    other = pim.ProgramBuilder(ROWS, WORDS)
    other.write_row(2, np.zeros(WORDS, np.uint32))
    other.shift_k(2, 6, 2)             # different op stream -> new digest
    other.read_row(6)
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 1), banks=1)
    front.submit("B", (other.build(), 1), banks=1)
    res = front.step()
    assert res.n_groups == 2 and res.coalescing == 1.0


def test_departure_keeps_surviving_plan_warm():
    """Preemption contract: a departing tenant's slots become idle None
    entries; the surviving layout's ``_StepPlan`` stays warm (no new
    plan miss when the survivors' layout recurs)."""
    front = PimServeFront(_cfg(banks=4))
    front.submit("A", (_prog(1), 10), banks=2)
    front.step()
    front.step()
    assert SCHED_STATS["plan_misses"] == 1
    front.submit("B", (_prog(2), 2), banks=1)
    front.step()                       # A+B layout: one new plan
    assert SCHED_STATS["plan_misses"] == 2
    front.step()                       # B's last step; departs at boundary
    assert front.active == ("A",)
    front.step()                       # A-alone layout again: warm
    assert SCHED_STATS["plan_misses"] == 2


def test_run_pipelines_recurring_windows_single_dispatch():
    """A recurring window runs as ONE schedule_pipeline dispatch, not one
    dispatch per step."""
    front = PimServeFront(_cfg(banks=4))
    front.submit("A", (_prog(0), 8), banks=2)
    front.submit("B", (_prog(1), 8), banks=2)
    d0 = SCHED_STATS["dispatches"]
    results = front.run(chunk=8)
    assert sum(r.n_steps for r in results) == 8
    assert SCHED_STATS["dispatches"] - d0 == 1
    assert all(front.report(t).n_steps == 8 for t in ("A", "B"))


def test_run_windows_break_at_membership_changes():
    """Tenants of different lengths: the window never spans a departure,
    and the queue admits between dispatches."""
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 6), banks=1)
    front.submit("B", (_prog(1), 2), banks=1)
    front.submit("C", (_prog(2), 3), banks=1, queue=True)
    results = front.run(chunk=64)
    # windows: [A+B x2] [A+C x3] [A x1] (C admitted when B departs)
    assert [r.n_steps for r in results] == [2, 3, 1]
    assert front.report("C").n_steps == 3


def test_same_digest_steps_pipeline_even_as_distinct_objects():
    """Recurrence is by stream_key, not identity: per-step program objects
    with the same stream (different payload data) still pipeline."""
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", [_prog(0), _prog(1), _prog(2)], banks=1)
    d0 = SCHED_STATS["dispatches"]
    results = front.run()
    assert sum(r.n_steps for r in results) == 3
    assert SCHED_STATS["dispatches"] - d0 == 1


def test_non_recurring_steps_fall_back_to_per_step():
    def variant(k):
        b = pim.ProgramBuilder(ROWS, WORDS)
        b.write_row(2, np.zeros(WORDS, np.uint32))
        b.shift_k(2, 6, k)             # k shifts: k distinct op streams
        b.read_row(6)
        return b.build()

    front = PimServeFront(_cfg(banks=2))
    front.submit("A", [variant(1), variant(2), variant(3)], banks=1)
    d0 = SCHED_STATS["dispatches"]
    results = front.run()
    assert sum(r.n_steps for r in results) == 3
    assert SCHED_STATS["dispatches"] - d0 == 3


def test_depart_preempts_and_frees_banks():
    front = PimServeFront(_cfg(banks=4))
    front.submit("A", (_prog(0), 100), banks=3)
    front.step()
    rep = front.depart("A")
    assert rep.n_steps == 1            # unconsumed steps discarded
    assert front.free_banks == (0, 1, 2, 3)
    assert front.report("A").n_steps == 1
    with pytest.raises(KeyError):
        front.depart("A")


def test_step_with_no_tenants_raises():
    front = PimServeFront(_cfg())
    with pytest.raises(RuntimeError, match="no active tenants"):
        front.step()


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def test_tenant_accounting_reconciles_with_device():
    front = PimServeFront(_cfg(banks=4))
    front.submit("A", (_prog(0), 5), banks=2)
    front.submit("B", (_prog(1), 3), banks=1)
    front.run()
    front.submit("C", (_prog(2), 4), banks=3)   # reuses freed banks
    front.run()
    rec = front.reconcile()
    assert rec["tenant_busy_ns"] == pytest.approx(
        rec["device_busy_ns"], rel=1e-9)
    assert rec["tenant_energy_nj"] == pytest.approx(
        rec["device_energy_nj"], rel=1e-9)
    assert rec["tenant_host_bytes"] == rec["device_host_bytes"]
    assert rec["device_steps"] == 5 + 4         # shared steps, not per-tenant


def test_tenant_report_walls_and_percentiles():
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 4), banks=1)
    front.run(chunk=2)
    rep = front.report("A")
    assert rep.wall_ns.shape == (4,)
    assert rep.p50_wall_ns > 0
    assert rep.p99_wall_ns >= rep.p50_wall_ns
    assert rep.busy_ns > 0 and rep.energy_nj > 0
    # host bytes: per-step stream traffic x steps
    assert rep.host_bytes == 4 * _prog(0).host_bytes


def test_live_report_tracks_progress():
    front = PimServeFront(_cfg(banks=2))
    front.submit("A", (_prog(0), 3), banks=1)
    front.step()
    r1 = front.report("A")
    front.step()
    r2 = front.report("A")
    assert r1.n_steps == 1 and r2.n_steps == 2
    assert r2.energy_nj > r1.energy_nj
