"""Golden pim-trace fixtures: the on-disk formats v1/v2/v3 are frozen.

Each fixture under ``tests/fixtures/`` must (a) parse, (b) re-export to the
*identical byte string* — so any change to mnemonics, operand order, header
fields, or the RLE payload encoding fails loudly here instead of silently
orphaning every previously shared trace — and (c) replay to the same state
and reads as the equivalent freshly-recorded execution.
"""
import os

import numpy as np
import pytest

from repro.core import pim
from repro.core.pim import exec as pim_exec

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _load(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# (a)+(b): parse → re-export must be byte-identical
# ---------------------------------------------------------------------------

def test_golden_v1_reexports_identically():
    text = _load("golden_v1.trace")
    prog = pim.PimProgram.from_trace(text)
    assert prog.to_trace() == text


def test_golden_v2_reexports_identically():
    text = _load("golden_v2.trace")
    banks = pim.from_trace_banks(text)
    assert len(banks) == 2
    assert pim.to_trace_banks(banks) == text


def test_golden_v3_reexports_identically():
    text = _load("golden_v3.trace")
    nested = pim.from_trace_device(text)
    assert len(nested) == 2 and len(nested[0]) == 2
    assert pim.to_trace_device(nested) == text


def test_golden_v2_payload_encodings_are_as_committed():
    """The fixture pins one all-zero (RLE), one dense (plain hex) and one
    sparse (RLE run) payload — changing the encoder's choice rule breaks
    byte-stability and must surface here."""
    text = _load("golden_v2.trace")
    assert "rle:00000000x4" in text                  # all-zero page
    assert "efbeadde67452301efcdab8942424242" in text  # dense stays plain
    assert "rle:deadbeefx3,00000001" in text         # sparse run


# ---------------------------------------------------------------------------
# (c): replay equivalence
# ---------------------------------------------------------------------------

def test_golden_v1_replays_like_eager():
    prog = pim.PimProgram.from_trace(_load("golden_v1.trace"))
    st = pim.reserve_control_rows(pim.make_subarray(16, 4))
    s_e, reads_e = pim.run_program(st, prog)
    res = pim_exec.execute(
        prog, pim.reserve_control_rows(pim.make_subarray(16, 4)))
    assert np.array_equal(np.asarray(s_e.bits), np.asarray(res.state.bits))
    assert len(reads_e) == len(res.reads) == 2
    for x, y in zip(reads_e, res.reads):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert float(res.state.meter.time_ns) == pytest.approx(
        float(s_e.meter.time_ns))


def test_golden_v2_replays_like_per_bank_execution():
    banks = pim.from_trace_banks(_load("golden_v2.trace"))
    dev = pim.make_device(pim.DeviceConfig(
        channels=1, ranks=1, banks_per_rank=2, num_rows=16, words=4))
    res = pim.schedule(dev, list(banks))
    for b, p in enumerate(banks):
        ref = pim_exec.execute(
            p, pim.reserve_control_rows(pim.make_subarray(16, 4)))
        assert np.array_equal(np.asarray(ref.state.bits),
                              np.asarray(res.state.bank(b).bits)), b
        for x, y in zip(ref.reads, res.reads[b]):
            assert np.array_equal(np.asarray(x), np.asarray(y)), b


def test_golden_v3_replays_copies_through_scheduler():
    nested = pim.from_trace_device(_load("golden_v3.trace"))
    cfg = pim.DeviceConfig(channels=1, ranks=1, banks_per_rank=2,
                           subarrays=2, num_rows=16, words=4)
    res = pim.schedule(pim.make_device(cfg), [list(b) for b in nested])
    st = res.state
    # bank 0 sub 0 wrote [7,0,0,7] to row 0 and COPYed it to bank 0 sub 1
    # row 1; bank 1 sub 0 COPYed its row 0 to bank 0 sub 0 row 3.
    assert np.array_equal(np.asarray(st.slot(0, 0).bits[0]),
                          np.array([7, 0, 0, 7], np.uint32))
    assert np.array_equal(np.asarray(st.slot(0, 1).bits[1]),
                          np.array([7, 0, 0, 7], np.uint32))
    assert np.array_equal(np.asarray(st.slot(0, 0).bits[3]),
                          np.array([0, 0xFFFFFFFF, 0, 0], np.uint32))
    # sub 1 of bank 0: FILL + AAP ran in-slot
    assert np.array_equal(np.asarray(st.slot(0, 1).bits[3]),
                          np.full(4, 0x0F0F0F0F, np.uint32))
    # one inter-subarray hop + one inter-bank transfer drained; they use
    # disjoint resources (bank-0 RBM link vs the internal bus), so the
    # drain makespan is the slower of the two while the total sums both
    t = pim.DEFAULT_TIMING
    assert res.copy_ns == pytest.approx(t.t_aap + t.t_copy_bank)
    assert res.copy_total_ns == pytest.approx(
        2 * t.t_aap + t.t_rbm + t.t_copy_bank)
    assert res.copy_queue_ns == 0.0


def test_golden_v1_rejects_when_corrupted():
    """A malformed line in a committed fixture must fail at import."""
    text = _load("golden_v1.trace").replace("SHIFT 2 3 +1", "SHIFT 2 3 +2")
    with pytest.raises(ValueError, match="delta"):
        pim.PimProgram.from_trace(text)
