"""Monte-Carlo process-variation model vs paper Table 4."""
import jax
import pytest

from repro.core.pim import variation as V

KEY = jax.random.PRNGKey(7)
N = 40_000


def rate(p):
    return float(V.shift_failure_rate(KEY, p, n_trials=N))


def test_zero_variation_never_fails():
    assert rate(0.0) == 0.0


def test_5pct_close_to_paper():
    assert rate(5.0) == pytest.approx(0.005, abs=0.004)


def test_10pct_close_to_paper():
    assert rate(10.0) == pytest.approx(0.14, abs=0.04)


def test_20pct_close_to_paper():
    assert rate(20.0) == pytest.approx(0.30, abs=0.06)


def test_failure_rate_monotone_in_variation():
    rates = [rate(p) for p in (0.0, 5.0, 10.0, 20.0)]
    assert all(a < b for a, b in zip(rates, rates[1:]))


def test_nominal_margin_positive():
    """Charge-sharing physics: ~100 mV swing ≫ 55 mV requirement at 22nm."""
    import jax.numpy as jnp
    m = V._sense_margin(jnp.zeros((1, 1, 5)), V.TECH22)
    assert 0.03 < float(m[0, 0]) < 0.08
