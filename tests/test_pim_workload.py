"""Multi-phase workload scheduler: ``schedule_workload`` regression guards.

Locks down the heterogeneous-pipeline layer (DESIGN.md §11): a whole
multi-phase workload — alternating step plans with different command
streams, grouping, copy patterns, and async flags — lowers into ONE XLA
dispatch (segmented ``lax.scan`` chain, or a ``lax.switch`` scan for
data-dependent phase orders), bit-exact against per-step ``schedule()``.
Warm re-schedules with fresh payload data must be pure cache traffic:
no plan misses, no compile misses, no columnar rebuilds, no retraces.
"""
import importlib

import numpy as np
import pytest

from repro.core import pim
from repro.core.bitplane import PimVM
from repro.core.pim import exec as pim_exec
from repro.core.pim import ir

# the package re-exports schedule() the function, shadowing the module
pim_schedule = importlib.import_module("repro.core.pim.schedule")

WORDS = 8
ROWS = 32
T = pim.DEFAULT_TIMING


def _rand_row(rng, words=WORDS):
    return rng.integers(0, 2**32, (words,), dtype=np.uint32)


def _cfg(channels=1, ranks=1, banks_per_rank=4):
    return pim.DeviceConfig(channels=channels, ranks=ranks,
                            banks_per_rank=banks_per_rank,
                            num_rows=ROWS, words=WORDS)


# Mid-test counter resets (post-warm) go through the shared helper; the
# autouse conftest fixture already zeroes everything per-test.
_reset_stats = pim.reset_stats


def _compute_prog(data, k=4):
    b = pim.ProgramBuilder(ROWS, WORDS)
    b.issue()
    b.write_row(0, data)
    b.shift_k(0, 1, k)
    b.ambit_xor(0, 1, 2)
    b.read_row(2)
    return b.build()


def _readback_prog(rows=(0, 2)):
    b = pim.ProgramBuilder(ROWS, WORDS)
    for r in rows:
        b.read_row(r)
    return b.build()


def _workload(rng, cfg):
    """compute (fresh payloads per step) -> gather COPYs -> readback."""
    layout = [_compute_prog(_rand_row(rng), k=3), None,
              _compute_prog(_rand_row(rng), k=5), None]
    compute = pim.Phase(steps=tuple(
        [p.with_payloads([_rand_row(rng)]) if p is not None else None
         for p in layout]
        for _ in range(3)))
    gather = pim.gather_rows(cfg, [((0, 0, 2), (1, 0, 4)),
                                   ((2, 0, 2), (3, 0, 4))])
    readback = [_readback_prog((4,)) if b in (1, 3) else None
                for b in range(4)]
    return [compute, pim.Phase.repeat(gather, 2),
            pim.Phase.repeat(readback, 1)]


def _run_per_step(cfg, phases, order=None, async_host=False):
    """Per-step schedule() reference, consuming phase steps FIFO."""
    if order is None:
        seq = [(p, s) for p, ph in enumerate(phases) for s in ph.steps]
    else:
        cursors = [list(ph.steps) for ph in phases]
        seq = [(p, cursors[p].pop(0)) for p in order]
    dev = pim.make_device(cfg)
    reads = [[] for _ in phases]
    for p, step in seq:
        r = pim.schedule(dev, step, async_host=async_host)
        dev = r.state
        reads[p].append(r.reads)
    return dev, reads


def _assert_reads_equal(cfg, ref_reads, res):
    for p, pr in enumerate(res.phases):
        got = pr.reads
        for k in range(pr.n_steps):
            for slot in range(cfg.n_slots):
                assert len(ref_reads[p][k][slot]) == len(got[k][slot])
                for x, y in zip(ref_reads[p][k][slot], got[k][slot]):
                    assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# One dispatch, bit-exact vs per-step
# ---------------------------------------------------------------------------

def test_workload_matches_per_step_schedule():
    cfg = _cfg()
    rng = np.random.default_rng(0)
    phases = _workload(rng, cfg)
    dev, ref_reads = _run_per_step(cfg, phases)
    res = pim.schedule_workload(pim.make_device(cfg), phases)
    assert np.array_equal(np.asarray(dev.banks.bits),
                          np.asarray(res.state.banks.bits))
    _assert_reads_equal(cfg, ref_reads, res)
    assert res.order is None
    assert res.n_steps == 6


def test_workload_is_single_dispatch():
    cfg = _cfg()
    rng = np.random.default_rng(1)
    phases = _workload(rng, cfg)
    pim.schedule_workload(pim.make_device(cfg), phases)   # warm compile
    _reset_stats()
    pim.schedule_workload(pim.make_device(cfg), phases)
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 0


def test_warm_workload_with_fresh_payloads_rebuilds_nothing():
    """The satellite-6 guard: a warm re-schedule of the SAME phase
    sequence with brand-new payload data is pure cache traffic — zero
    plan misses, zero compile misses, zero columnar table rebuilds, zero
    driver retraces, one dispatch."""
    cfg = _cfg()
    rng = np.random.default_rng(2)
    layout = [_compute_prog(_rand_row(rng)), None, None, None]
    gather = pim.gather_rows(cfg, [((0, 0, 2), (1, 0, 4))])
    readback = [None, _readback_prog((4,)), None, None]

    def make_phases():
        # only the payload DATA is fresh; with_payloads shares columns
        compute = pim.Phase(steps=tuple(
            [layout[0].with_payloads([_rand_row(rng)]), None, None, None]
            for _ in range(3)))
        return [compute, pim.Phase.repeat(gather, 2),
                pim.Phase.repeat(readback, 1)]

    pim.schedule_workload(pim.make_device(cfg), make_phases())  # warm
    _reset_stats()
    builds0 = ir.COLUMN_STATS["builds"]
    res = pim.schedule_workload(pim.make_device(cfg), make_phases())
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_schedule.SCHED_STATS["plan_misses"] == 0
    assert pim_schedule.SCHED_STATS["compile_misses"] == 0
    assert pim_exec.RUNNER_STATS["traces"] == 0
    assert ir.COLUMN_STATS["builds"] == builds0
    assert res.n_steps == 6


def test_workload_plan_identity_is_stable_across_warm_calls():
    """Warm calls reuse the SAME PipelinePlan object (the jitted drivers
    are keyed on its identity), and its signature is deterministic."""
    cfg = _cfg()
    rng = np.random.default_rng(3)
    phases = _workload(rng, cfg)
    pim.schedule_workload(pim.make_device(cfg), phases)
    plans = list(pim_schedule._workload_plan_cache.values())
    pim.schedule_workload(pim.make_device(cfg), phases)
    plans2 = list(pim_schedule._workload_plan_cache.values())
    assert plans[-1] is plans2[-1]
    assert isinstance(plans[-1].signature, bytes)
    assert len(plans[-1].signature) == 16


# ---------------------------------------------------------------------------
# Switch lowering (data-dependent phase order)
# ---------------------------------------------------------------------------

def test_switch_order_matches_per_step_schedule():
    cfg = _cfg()
    rng = np.random.default_rng(4)
    phases = _workload(rng, cfg)
    order = [0, 1, 0, 2, 0, 1]          # interleaved, FIFO within phase
    dev, ref_reads = _run_per_step(cfg, phases, order=order)
    res = pim.schedule_workload(pim.make_device(cfg), phases, order=order)
    assert np.array_equal(np.asarray(dev.banks.bits),
                          np.asarray(res.state.banks.bits))
    _assert_reads_equal(cfg, ref_reads, res)
    assert res.order == tuple(order)


def test_switch_order_is_single_dispatch():
    cfg = _cfg()
    rng = np.random.default_rng(5)
    phases = _workload(rng, cfg)
    order = [0, 1, 0, 2, 0, 1]
    pim.schedule_workload(pim.make_device(cfg), phases, order=order)
    _reset_stats()
    pim.schedule_workload(pim.make_device(cfg), phases, order=order)
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 0


def test_switch_order_validation():
    cfg = _cfg()
    rng = np.random.default_rng(6)
    phases = _workload(rng, cfg)
    with pytest.raises(ValueError, match="out of range"):
        pim.schedule_workload(pim.make_device(cfg), phases,
                              order=[0, 1, 0, 3, 0, 1])
    with pytest.raises(ValueError, match="consumed FIFO"):
        pim.schedule_workload(pim.make_device(cfg), phases,
                              order=[0, 0, 0, 0, 1, 2])


# ---------------------------------------------------------------------------
# Phase descriptors & recurrence contract
# ---------------------------------------------------------------------------

def test_phase_descriptor_normalization():
    """(layout, n) pairs and bare step sequences are accepted and hit the
    SAME cached workload plan as the equivalent Phase objects."""
    cfg = _cfg()
    rng = np.random.default_rng(7)
    layout = [_compute_prog(_rand_row(rng)), None, None, None]
    gather = pim.gather_rows(cfg, [((0, 0, 2), (2, 0, 4))])
    explicit = [pim.Phase.repeat(layout, 2), pim.Phase.repeat(gather, 1)]
    sugar = [(layout, 2), [gather]]

    r1 = pim.schedule_workload(pim.make_device(cfg), explicit)
    _reset_stats()
    r2 = pim.schedule_workload(pim.make_device(cfg), sugar)
    assert pim_schedule.SCHED_STATS["plan_misses"] == 0
    assert pim_exec.RUNNER_STATS["traces"] == 0
    assert np.array_equal(np.asarray(r1.state.banks.bits),
                          np.asarray(r2.state.banks.bits))


def test_non_recurring_phase_raises():
    cfg = _cfg()
    rng = np.random.default_rng(8)
    s1 = [_compute_prog(_rand_row(rng), k=3), None, None, None]
    s2 = [_compute_prog(_rand_row(rng), k=7), None, None, None]
    with pytest.raises(ValueError, match="does not recur"):
        pim.schedule_workload(pim.make_device(cfg),
                              [pim.Phase(steps=(s1, s2))])


def test_empty_workload_raises():
    with pytest.raises(ValueError, match="at least one phase"):
        pim.schedule_workload(pim.make_device(_cfg()), [])


# ---------------------------------------------------------------------------
# Async credit across phase boundaries
# ---------------------------------------------------------------------------

def test_boundary_credit_matches_per_step_and_resets_on_sync():
    """Per-phase async overrides: an async phase leaves its last step's
    compute window as the boundary credit; a following SYNC phase resets
    it to zero (the credit-reset contract), bit-identical to the per-step
    reference at every boundary."""
    cfg = _cfg()
    rng = np.random.default_rng(9)
    layout = [_compute_prog(_rand_row(rng)), None,
              _compute_prog(_rand_row(rng)), None]
    phases = [pim.Phase.repeat(layout, 2, async_host=True),
              pim.Phase.repeat([None, _readback_prog((2,)), None, None], 1,
                               async_host=False)]

    dev = pim.make_device(cfg)
    boundary = []
    for ph in phases:
        for step in ph.steps:
            dev = pim.schedule(dev, step,
                               async_host=bool(ph.async_host)).state
        boundary.append(float(dev.host_credit_ns))

    res = pim.schedule_workload(pim.make_device(cfg), phases)
    assert boundary[0] > 0.0
    assert res.phases[0].boundary_credit_ns == pytest.approx(boundary[0],
                                                             rel=1e-6)
    assert res.phases[1].boundary_credit_ns == 0.0
    assert float(res.state.host_credit_ns) == 0.0
    np.testing.assert_allclose(float(dev.host_credit_ns),
                               float(res.state.host_credit_ns), atol=1e-6)


# ---------------------------------------------------------------------------
# PimVM.run_workload
# ---------------------------------------------------------------------------

def _vm_xor_step(vm, x):
    a = vm.load(x[0])
    b = vm.load(x[1])
    r = vm.xor(a, b)
    vm.free(a, b)
    return r


def _vm_and_not_step(vm, x):
    a = vm.load(x[0])
    b = vm.load(x[1])
    r = vm.and_(a, b)
    s = vm.not_(r)
    vm.free(a, b, r)
    return s


@pytest.mark.parametrize("n_banks", [1, 4])
def test_vm_run_workload_matches_reference(n_banks):
    rng = np.random.default_rng(10)
    vm = PimVM(width=8, num_rows=96, words=16, n_banks=n_banks,
               async_host=n_banks > 1)
    xs_a = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
            for _ in range(3)]
    xs_b = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
            for _ in range(2)]
    got_a, got_b = vm.run_workload([(_vm_xor_step, xs_a),
                                    (_vm_and_not_step, xs_b)])
    for k, (a, b) in enumerate(xs_a):
        assert np.array_equal(got_a[k], a ^ b), k
    for k, (a, b) in enumerate(xs_b):
        assert np.array_equal(got_b[k], (~(a & b)) & 0xFF), k


def test_vm_run_workload_is_one_dispatch_when_sharded():
    rng = np.random.default_rng(11)
    vm = PimVM(width=8, num_rows=96, words=16, n_banks=2)
    xs_a = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
            for _ in range(3)]
    xs_b = [(rng.integers(0, 256, vm.lanes), rng.integers(0, 256, vm.lanes))
            for _ in range(2)]
    phases = [(_vm_xor_step, xs_a), (_vm_and_not_step, xs_b)]
    vm.run_workload(phases)             # warm compile
    _reset_stats()
    vm.run_workload(phases)
    assert pim_schedule.SCHED_STATS["dispatches"] == 1
    assert pim_exec.RUNNER_STATS["traces"] == 0


def test_vm_run_workload_divergent_step_raises():
    rng = np.random.default_rng(12)
    vm = PimVM(width=8, num_rows=96, words=16)
    calls = {"n": 0}

    def bad_step(vm, x):
        calls["n"] += 1
        a = vm.load(x)
        return vm.not_(a) if calls["n"] > 1 else a

    with pytest.raises(ValueError, match="recorded a different"):
        vm.run_workload([(bad_step, [rng.integers(0, 256, vm.lanes),
                                     rng.integers(0, 256, vm.lanes)])])
