"""Serving engine behaviours beyond the system test."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import DECODE_STATS, greedy_generate

from util import make_inputs


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-4b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_temperature_sampling_differs_but_valid(setup):
    cfg, params = setup
    prompts = make_inputs(cfg, 2, 16, labels=False)
    greedy = greedy_generate(cfg, params, prompts, max_new_tokens=12)
    hot = greedy_generate(cfg, params, prompts, max_new_tokens=12,
                          temperature=1.5, key=jax.random.PRNGKey(7))
    assert hot.shape == greedy.shape
    assert int(hot.max()) < cfg.vocab_size and int(hot.min()) >= 0
    assert not jnp.array_equal(greedy, hot)


def test_batch_requests_independent(setup):
    """Request i's output must not depend on what else is in the batch."""
    cfg, params = setup
    prompts = make_inputs(cfg, 3, 16, labels=False)
    full = greedy_generate(cfg, params, prompts, max_new_tokens=6)
    solo = greedy_generate(
        cfg, params, {"tokens": prompts["tokens"][1:2]}, max_new_tokens=6)
    assert jnp.array_equal(full[1:2], solo)


def test_generate_respects_cache_budget(setup):
    cfg, params = setup
    prompts = make_inputs(cfg, 1, 8, labels=False)
    out = greedy_generate(cfg, params, prompts, max_new_tokens=4,
                          max_cache_len=16)
    assert out.shape == (1, 4)


def test_decode_loop_is_single_dispatch(setup):
    """The whole decode loop (sampling + key splits + decode_step) runs as
    ONE jitted scan: generating N tokens costs one dispatch after prefill,
    not N host round-trips — and the fold into the scan is greedy-stable."""
    cfg, params = setup
    prompts = make_inputs(cfg, 2, 16, labels=False)
    out1 = greedy_generate(cfg, params, prompts, max_new_tokens=8)
    DECODE_STATS["dispatches"] = 0
    out2 = greedy_generate(cfg, params, prompts, max_new_tokens=8)
    assert DECODE_STATS["dispatches"] == 1
    assert out1.shape == (2, 8)
    assert jnp.array_equal(out1, out2)      # greedy decode is deterministic


def test_zero_new_tokens_returns_empty(setup):
    """Regression: max_new_tokens=0 used to reach lax.scan(length=-1) and
    die with an opaque MLIR "invalid tensor dimension size" — it must be
    an empty (B, 0) result, with no prefill or decode dispatched."""
    cfg, params = setup
    prompts = make_inputs(cfg, 3, 8, labels=False)
    DECODE_STATS["dispatches"] = 0
    out = greedy_generate(cfg, params, prompts, max_new_tokens=0)
    assert out.shape == (3, 0)
    assert out.dtype == jnp.int32
    assert DECODE_STATS["dispatches"] == 0


def test_one_new_token_edge(setup):
    """length=0 scan edge: a single token comes from prefill sampling
    alone and must match the first column of a longer generation."""
    cfg, params = setup
    prompts = make_inputs(cfg, 2, 8, labels=False)
    one = greedy_generate(cfg, params, prompts, max_new_tokens=1)
    assert one.shape == (2, 1)
    more = greedy_generate(cfg, params, prompts, max_new_tokens=4)
    assert jnp.array_equal(one, more[:, :1])


def test_negative_new_tokens_rejected(setup):
    cfg, params = setup
    prompts = make_inputs(cfg, 1, 8, labels=False)
    with pytest.raises(ValueError, match="max_new_tokens"):
        greedy_generate(cfg, params, prompts, max_new_tokens=-1)


def test_ssm_arch_generates():
    cfg = get_config("falcon-mamba-7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = make_inputs(cfg, 2, 12, labels=False)
    out = greedy_generate(cfg, params, prompts, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab_size
