"""Sharding rule engine, data pipeline, HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, make_batch
from repro.data.synthetic import SyntheticTokens
from repro.launch import sharding
from repro.launch.hlo_analysis import HloModule, analyze, shape_bytes
from repro.launch.mesh import make_host_mesh
from repro.models import init_params


# --- sharding rules ---------------------------------------------------------

def test_param_rules_cover_all_archs():
    mesh = make_host_mesh()
    for arch in ("qwen3-moe-30b-a3b", "deepseek-v2-lite-16b",
                 "falcon-mamba-7b", "recurrentgemma-2b", "musicgen-medium"):
        cfg = get_config(arch, smoke=True)
        params = jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0)))
        _, report = sharding.param_shardings(cfg, mesh, params)
        assert not report.fallback_replicated, (arch,
                                                report.fallback_replicated)


def test_expected_specs():
    mesh = make_host_mesh()
    rep = sharding.ShardingReport()
    assert sharding.spec_for("stack/attn/wq", 3, mesh, rep) == \
        P(None, None, "model")
    assert sharding.spec_for("stack/attn/wo", 3, mesh, rep) == \
        P(None, "model", None)
    assert sharding.spec_for("stack/ffn/w1", 4, mesh, rep) == \
        P(None, "model", None, None)
    assert sharding.spec_for("embed", 2, mesh, rep) == P("model", None)
    assert sharding.spec_for("stack/ln1/w", 2, mesh, rep) == P(None, None)


def test_nondivisible_dims_degrade_to_replicated():
    mesh = make_host_mesh()          # model axis size = 1 → divisible always
    rep = sharding.ShardingReport()
    spec = sharding.spec_for("stack/attn/wq", 2, mesh, rep, shape=(7, 13))
    assert spec == P(None, None) or spec == P(None, "model")


def test_cache_shardings_pick_sequence_dim():
    mesh = make_host_mesh()
    tree = {"k": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), jnp.bfloat16)}
    sh = sharding.cache_shardings(mesh, tree, batch=8)
    spec = sh["k"].spec
    assert spec[1] is not None or spec == P()        # batch dim → dp axes


# --- data pipeline -----------------------------------------------------------

def test_stream_deterministic_and_stateless():
    s = SyntheticTokens(1000, seed=3)
    a = s.block(1000, 128)
    b = np.concatenate([s.block(1000, 64), s.block(1064, 64)])
    assert np.array_equal(a, b)


def test_make_batch_resume_equivalence():
    cfg = get_config("qwen3-4b", smoke=True)
    b1 = make_batch(cfg, batch=4, seq=32, step=7)
    b2 = make_batch(cfg, batch=4, seq=32, step=7)
    for k in b1:
        assert np.array_equal(b1[k], b2[k])


def test_make_batch_shards_disjoint_and_consistent():
    cfg = get_config("qwen3-4b", smoke=True)
    full = make_batch(cfg, batch=8, seq=32, step=3)
    lo = make_batch(cfg, batch=8, seq=32, step=3, lo=0, hi=4)
    hi = make_batch(cfg, batch=8, seq=32, step=3, lo=4, hi=8)
    assert np.array_equal(full["tokens"],
                          np.concatenate([lo["tokens"], hi["tokens"]]))


def test_labels_are_shifted_inputs():
    cfg = get_config("qwen3-4b", smoke=True)
    b = make_batch(cfg, batch=2, seq=32, step=0)
    # label[t] is the next token of the underlying stream
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_separator_positions_masked():
    cfg = get_config("qwen3-4b", smoke=True)
    b = make_batch(cfg, batch=4, seq=600, step=0)
    assert (b["mask"] == (b["labels"] != 0)).all()
    assert (b["mask"] == 0).sum() > 0               # doc_len=512 < 600


def test_prefetcher_orders_steps():
    cfg = get_config("qwen3-4b", smoke=True)
    pf = Prefetcher(lambda s: make_batch(cfg, batch=2, seq=16, step=s),
                    start_step=5)
    try:
        for expect in (5, 6, 7):
            step, batch = pf.get()
            assert step == expect
    finally:
        pf.close()


# --- HLO analyzer -------------------------------------------------------------

def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(bf16[8]{0}, s32[2,2]{1,0})") == 16 + 16
    assert shape_bytes("pred[7]") == 7


def test_loop_scaling_exact_on_scanned_matmul():
    L, B, D = 6, 8, 64

    def fn(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    compiled = jax.jit(fn).lower(xs, ws).compile()
    cost = analyze(compiled.as_text(), 1)
    assert cost.flops == 2 * L * B * D * D


def test_collective_ring_factors_synthetic():
    hlo = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %ag = f32[256]{0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
}
"""
    m = HloModule(hlo, 8)
    c = m.entry_cost()
    assert c.coll["all-reduce"] == 2 * 256 * 3 / 4
    assert c.coll["all-gather"] == 1024 * 3 / 4
