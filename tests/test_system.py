"""End-to-end behaviour tests: the paper's pipeline + the LM framework."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, applicable, get_config, skip_reason
from repro.models import init_params
from repro.serve.engine import greedy_generate

from util import make_inputs


def test_full_pim_pipeline_shift_then_crypto():
    """The paper's promise end to end: horizontal data, shifted in-DRAM,
    fed to GF arithmetic — no transposition anywhere, costs accounted."""
    from repro.core.bitplane import PimVM, gf
    vm = PimVM(width=8, num_rows=64, words=4)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, vm.lanes)
    reg = vm.load(data)
    shifted = vm.shift_elem(reg, +1)            # in-lane shift via mig cells
    x2 = gf.xtime(vm, reg)                       # GF(2^8) multiply-by-x
    assert np.array_equal(vm.read(shifted),
                          (data.astype(np.uint64) << np.uint64(1))
                          & np.uint64(0xFF))
    assert np.array_equal(vm.read(x2), gf.ref_xtime(data))
    assert vm.counts()["n_shift"] > 0
    assert vm.energy_nj > 0 and vm.time_ns > 0


def test_generate_deterministic_and_plausible():
    cfg = get_config("qwen3-4b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = make_inputs(cfg, 2, 16, labels=False)
    out1 = greedy_generate(cfg, params, prompts, max_new_tokens=8)
    out2 = greedy_generate(cfg, params, prompts, max_new_tokens=8)
    assert out1.shape == (2, 8)
    assert jnp.array_equal(out1, out2)
    assert int(out1.max()) < cfg.vocab_size


def test_applicability_matrix_covers_40_cells():
    from repro.configs import ARCH_IDS
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if not applicable(*c)]
    assert len(skips) == 6                       # DESIGN.md §5
    assert all(s == "long_500k" for _, s in skips)
    assert all(skip_reason(a, s) for a, s in skips)


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """Deliverable (e) in miniature: fresh process, 8 placeholder devices,
    lower+compile a smoke arch through the real dryrun machinery."""
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import dataclasses
import jax
from repro.configs import get_config, SHAPES
from repro.launch.dryrun import build_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen3-4b", smoke=True)
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
with mesh:
    fn, args, report, acct = build_cell(cfg, shape, mesh)
    compiled = fn.lower(*args).compile()
    print("OK", compiled.memory_analysis().temp_size_in_bytes)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_production_mesh_builders_are_lazy():
    """Importing mesh.py must not initialize jax devices; shapes per spec."""
    import inspect
    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod)
    assert "(2, 16, 16)" in src and "(16, 16)" in src and '"pod"' in src
