"""Training loop, NaN guard, microbatching, checkpoint/resume, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.models import init_params, loss_fn
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.train.loop import train_loop
from repro.train.step import init_train_state, make_train_step

from util import make_inputs

CFG = get_config("qwen3-4b", smoke=True)


def test_loss_decreases():
    params, hist = train_loop(CFG, steps=20, batch=8, seq=64,
                              opt_cfg=adamw.AdamWConfig(lr=1e-3),
                              log=lambda *a: None)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5])
    assert hist["skipped"] == 0


def test_checkpoint_resume_continues_exactly():
    with tempfile.TemporaryDirectory() as d:
        train_loop(CFG, steps=10, batch=4, seq=32, ckpt_dir=d,
                   ckpt_every=5, log=lambda *a: None)
        assert checkpoint.latest_step(d) == 10
        _, hist2 = train_loop(CFG, steps=14, batch=4, seq=32, ckpt_dir=d,
                              ckpt_every=5, log=lambda *a: None)
        assert len(hist2["loss"]) == 4       # resumed at step 10


def test_resume_matches_uninterrupted_run():
    """Fault-tolerance invariant: crash+restore == never crashed."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        train_loop(CFG, steps=8, batch=4, seq=32, ckpt_dir=d1,
                   ckpt_every=4, log=lambda *a: None)
        p_once, _ = train_loop(CFG, steps=8, batch=4, seq=32, ckpt_dir=d2,
                               ckpt_every=8, log=lambda *a: None)
        # run 1: interrupted at 4 (retention keeps step 4), resume to 8
        p_resumed, _ = train_loop(CFG, steps=8, batch=4, seq=32, ckpt_dir=d1,
                                  ckpt_every=4, log=lambda *a: None)
        for a, b in zip(jax.tree.leaves(p_once), jax.tree.leaves(p_resumed)):
            assert jnp.array_equal(a, b)


def test_nan_guard_skips_poisoned_step():
    params = init_params(CFG, jax.random.PRNGKey(0))
    train, frozen, opt = init_train_state(CFG, params)
    step = jax.jit(make_train_step(CFG, adamw.AdamWConfig(), lambda s: 1.0))
    batch = make_inputs(CFG, 4, 32)
    batch = dict(batch, mask=jnp.ones_like(batch["labels"], jnp.float32))
    poisoned = dict(batch)
    if "tokens" in poisoned:
        poisoned["mask"] = batch["mask"] * jnp.float32("nan")
    t1, o1, m1 = step(train, frozen, opt, poisoned)
    assert float(m1["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(train)):
        assert jnp.array_equal(a, b)          # params unchanged
    t2, o2, m2 = step(train, frozen, opt, batch)
    assert float(m2["skipped"]) == 0.0


def test_microbatching_matches_full_batch():
    params = init_params(CFG, jax.random.PRNGKey(1))
    train, frozen, opt = init_train_state(CFG, params)
    batch = make_inputs(CFG, 8, 32)
    s1 = jax.jit(make_train_step(CFG, adamw.AdamWConfig(lr=1e-2),
                                 lambda s: 1.0, microbatches=1))
    s2 = jax.jit(make_train_step(CFG, adamw.AdamWConfig(lr=1e-2),
                                 lambda s: 1.0, microbatches=2))
    p1, _, m1 = s1(train, frozen, opt, batch)
    p2, _, m2 = s2(train, frozen, opt, batch)
    # losses equal up to accumulation order
    assert float(jnp.abs(m1["loss"] - m2["loss"])) < 5e-3
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 5e-2


def test_grad_compression_error_feedback():
    from repro.optim import grad_compress as gc
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    codes, scale, resid = gc.compress(g)
    back = gc.decompress(codes, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-7
    # error feedback: residual carries exactly the rounding error
    assert float(jnp.max(jnp.abs((back + resid) - g))) < 1e-6


def test_lr_schedule_shape():
    s = [float(warmup_cosine(i, warmup_steps=10, total_steps=100))
         for i in (0, 5, 10, 50, 100)]
    assert s[0] == 0.0 and s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0)
    assert s[2] > s[3] > s[4] >= 0.1 - 1e-6


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    d = str(tmp_path)
    params = {"a": jnp.arange(4.0)}
    checkpoint.save(d, 1, params)
    os.makedirs(os.path.join(d, "step_00000002.tmp.999"), exist_ok=True)
    assert checkpoint.latest_step(d) == 1
    restored, _ = checkpoint.restore(d, 1, params)
    assert jnp.array_equal(restored["a"], params["a"])


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        checkpoint.save(d, s, {"x": jnp.ones(2) * s}, keep=2)
    steps = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(steps) == 2
    assert checkpoint.latest_step(d) == 5
