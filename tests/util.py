"""Shared test helpers: batch construction per modality."""
import jax.numpy as jnp
import numpy as np


def make_inputs(cfg, batch, seq, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio_frames":
        out = {"frame_embeds": jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)), jnp.float32)}
        if labels:
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size,
                             (batch, seq, cfg.n_codebooks)), jnp.int32)
        return out
    if cfg.frontend == "vision_patches":
        text = seq - cfg.n_patches
        out = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, text)), jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(batch, cfg.n_patches, cfg.d_model)),
                jnp.float32),
        }
        if labels:
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, text)), jnp.int32)
        return out
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if labels:
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    return out


def split_last(batch, cfg):
    """(prefix inputs, final-token inputs) for decode-consistency tests."""
    if cfg.frontend == "audio_frames":
        emb = batch["frame_embeds"]
        return ({"frame_embeds": emb[:, :-1]},
                {"frame_embeds": emb[:, -1:]})
    pre = {k: v for k, v in batch.items() if k != "labels"}
    pre = dict(pre, tokens=batch["tokens"][:, :-1])
    return pre, {"tokens": batch["tokens"][:, -1:]}
